//! Codec bake-off on real activations from every model: accuracy-side
//! (reconstruction error at matched ratios) and speed-side (wall
//! time) — the standalone version of Tables III/IV for people who
//! just want the codec library.
//!
//!     cargo run --release --example codec_comparison

use fourier_compress::codec::{self, rel_error, Codec};
use fourier_compress::model::executor::SplitExecutor;
use fourier_compress::model::tokenizer;
use fourier_compress::runtime::ArtifactStore;
use fourier_compress::tensor::Tensor;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::open("artifacts")?;
    for model in store.model_names() {
        let exec = SplitExecutor::new(&store, &model)?;
        let meta = exec.meta.clone();
        let ids = tokenizer::encode_prompt("Q mira hue ? A blue . Q rok den ? A cave .");
        let len = ids.len().min(meta.eval_seq);
        let (b, s, d) = (meta.eval_batch, meta.eval_seq, meta.d_model);
        let mut toks = Vec::new();
        for _ in 0..b {
            toks.extend(tokenizer::pad_to(&ids, s));
        }
        let acts = exec.activations(&Tensor::i32(vec![b, s], toks))?;
        let a1 = &acts[0].as_f32()[..len * d];

        println!("\n== {model} (layer-1 activation {len}x{d}) ==");
        println!("{:8} {:>7} {:>10} {:>12} {:>12}", "codec", "ratio",
                 "achieved", "rel-error", "time");
        for ratio in [6.0, 8.0, 10.0] {
            for name in ["fc", "topk", "qr", "fwsvd", "asvd", "svdllm"] {
                let c: Box<dyn Codec> = if name == "fc" {
                    Box::new(codec::fourier::FourierCodec::with_hint(meta.kd_band()))
                } else {
                    codec::by_name(name)?
                };
                let t0 = Instant::now();
                let p = c.compress(a1, len, d, ratio)?;
                let rec = c.decompress(&p)?;
                let dt = t0.elapsed();
                println!("{:8} {:>6.0}x {:>9.1}x {:>12.4} {:>10.1?}",
                         name, ratio, p.achieved_ratio(), rel_error(a1, &rec), dt);
            }
        }
    }
    Ok(())
}
