//! Fig-7 in miniature: the multi-client discrete-event simulation in
//! both regimes — compute-bound (1 unit) where link speed doesn't
//! help, and bandwidth-bound (8 units) where FourierCompress
//! multiplies client capacity.
//!
//!     cargo run --release --example scalability_sim

use fourier_compress::config::SimConfig;
use fourier_compress::sim::{simulate, Arm};

fn main() {
    let mut cfg = SimConfig {
        clients: vec![10, 50, 150, 500, 1000, 1500],
        link_gbps: vec![1.0, 10.0],
        horizon_s: 60.0,
        ..SimConfig::default()
    };

    for units in [1usize, 8] {
        cfg.compute_units = units;
        println!("\n=== {units} compute unit(s) ===");
        println!("{:>8} {:>6} {:>6} | {:>12} {:>12}", "clients", "gbps", "arm",
                 "mean resp s", "server util");
        for &g in &cfg.link_gbps.clone() {
            for &c in &cfg.clients.clone() {
                for (arm, tag) in [(Arm::Original, "orig"), (Arm::Fc, "fc"),
                                   (Arm::FcStream, "fcs"),
                                   (Arm::FcAdaptive, "fca")] {
                    let st = simulate(&cfg, c, g, arm);
                    println!("{:>8} {:>6.1} {:>6} | {:>12.3} {:>12.2}",
                             c, g, tag, st.mean_response_s, st.server_util);
                }
            }
        }
    }
    println!("\n(see `repro simulate` for the full Fig-7 sweep + JSON output)");
}
