//! End-to-end collaborative serving (E11 in DESIGN.md §5): a real
//! edge server + N device clients over loopback TCP with a simulated
//! 6G uplink.  Each client runs embed+layer1+pallas-FC locally and
//! generates answers autoregressively in the paper's recompute
//! regime; the server batches reconstructed activations across
//! clients.  Reports throughput, latency percentiles, and the wire
//! compression actually achieved.
//!
//!     cargo run --release --example collaborative_serving -- \
//!         [--clients 4] [--prompts 6] [--gbps 1.0] [--max-batch 4] \
//!         [--stream] [--keyframe-interval 32] [--drift 0.05] \
//!         [--adaptive] [--error-budget 1.0] [--target-step-ms 25] \
//!         [--entropy | --no-entropy] \
//!         [--prefill-chunk-rows 16] [--no-prefill]
//!
//! `--stream` switches the clients to the spectral delta stream
//! (`codec::stream`): keyframes on cadence/bucket promotion, sparse
//! coefficient deltas otherwise — the regime that removes the
//! recompute retransmission.  `--adaptive` turns on closed-loop
//! spectral rate control (`codec::rate`): each client rides the
//! bucket quality ladder the server advertises, downshifting when the
//! link cannot clear a step inside `--target-step-ms` and upshifting
//! back (with hysteresis) when it can, under `--error-budget`.
//! Entropy coding (`codec::wire`, negotiated via the ENTROPY
//! capability) is on by default: each frame body is losslessly
//! re-coded and shipped in whichever form is smaller; `--no-entropy`
//! pins the raw pre-entropy wire format.  Chunked prefill (negotiated
//! via the PREFILL capability) is also on by default: each prompt
//! ships as one keyframe chunk plus row-delta chunks of
//! `--prefill-chunk-rows` packed-plane rows instead of one monolithic
//! keyframe; `--no-prefill` pins the monolithic prompt path.

use fourier_compress::codec::rate::RateConfig;
use fourier_compress::codec::stream::{PrefillConfig, StreamConfig};
use fourier_compress::config::{FromJson, ServeConfig};
use fourier_compress::coordinator::{DeviceClient, EdgeServer};
use fourier_compress::net::Channel;
use fourier_compress::runtime::ArtifactStore;
use fourier_compress::util::cli::Args;
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let n_clients = args.usize_or("clients", 4);
    let n_prompts = args.usize_or("prompts", 6);
    let gbps = args.f64_or("gbps", 1.0);
    let max_batch = args.usize_or("max-batch", 4);
    let stream = args.has("stream");
    let stream_cfg = StreamConfig {
        keyframe_interval: args.usize_or("keyframe-interval", 32) as u32,
        drift_threshold: args.f64_or("drift", 0.05),
    };
    let adaptive = args.has("adaptive");
    // on unless --no-entropy; --entropy spells the default explicitly
    let entropy = args.has("entropy") || !args.has("no-entropy");
    // chunked prefill: on unless --no-prefill
    let prefill = !args.has("no-prefill");
    let prefill_cfg = PrefillConfig {
        chunk_rows: args.usize_or("prefill-chunk-rows", 16),
        drift_threshold: args.f64_or("drift", 0.05),
    };
    let rate_cfg = RateConfig {
        error_budget: args.f64_or("error-budget", 1.0),
        target_step_s: args.f64_or("target-step-ms", 25.0) / 1000.0,
        ..RateConfig::default()
    };

    let cfg = ServeConfig::load(None, &[
        "listen=127.0.0.1:0".into(),
        format!("max_batch={max_batch}"),
        "compute_units=1".into(),
    ])?;
    let store = Arc::new(ArtifactStore::open(cfg.artifacts.clone())?);
    let server = EdgeServer::start(cfg, store.clone())?;
    let addr = server.addr.to_string();
    println!("edge server up on {addr}; {n_clients} clients, link {gbps} Gbps");

    // fact-world prompts the build-time models were trained on
    let prompts = [
        "Q mira hue ? A", "Q rok den ? A", "Q zeb food ? A", "Q kol mood ? A",
        "Q fen hue ? A", "Q tas den ? A", "Q ulf job ? A", "Q vex size ? A",
    ];

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for cid in 0..n_clients {
        let addr = addr.clone();
        let store = store.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<_> {
            let channel = Channel::gbps(gbps, 100);
            let mut client = DeviceClient::connect(&addr, &store,
                                                   cid as u64 + 1, channel)?;
            if stream && !client.enable_stream(stream_cfg) {
                // the v2 handshake negotiated the capability away
                anyhow::bail!("server did not advertise the stream capability");
            }
            if adaptive && !client.enable_adaptive(rate_cfg) {
                anyhow::bail!("server did not advertise the ladder capability");
            }
            if entropy && !client.enable_entropy() {
                anyhow::bail!("server did not advertise the entropy capability");
            }
            if prefill && !client.enable_prefill(prefill_cfg) {
                anyhow::bail!("server did not advertise the prefill capability");
            }
            let mut gens = Vec::new();
            for p in 0..n_prompts {
                let prompt = prompts[(cid + p) % prompts.len()];
                let g = client.generate(prompt, 8)?;
                gens.push(g);
            }
            let stats = client.stats.clone();
            client.bye()?;
            Ok((gens, stats))
        }));
    }

    let mut total_tokens = 0usize;
    let mut total_bytes = 0u64;
    let mut total_raw = 0u64;
    let (mut keys, mut deltas, mut resyncs) = (0u64, 0u64, 0u64);
    let (mut switches, mut max_point) = (0u64, 0u8);
    let (mut eframes, mut efalls) = (0u64, 0u64);
    let (mut pre_coding, mut post_coding) = (0u64, 0u64);
    let (mut pf_prompts, mut pf_chunks, mut pf_keys) = (0u64, 0u64, 0u64);
    let (mut pf_bytes, mut pf_resyncs) = (0u64, 0u64);
    let mut rts: Vec<u64> = Vec::new();
    for (cid, h) in handles.into_iter().enumerate() {
        let (gens, stats) = h.join().unwrap()?;
        if cid == 0 {
            for g in gens.iter().take(3) {
                println!("  [{}] {:?} -> {:?}", cid, g.prompt, g.completion);
            }
        }
        total_tokens += gens.iter().map(|g| g.steps).sum::<usize>();
        total_bytes += stats.bytes_sent;
        total_raw += stats.bytes_uncompressed;
        keys += stats.key_frames;
        deltas += stats.delta_frames;
        resyncs += stats.resyncs;
        switches += stats.ladder_switches;
        max_point = max_point.max(stats.max_point);
        eframes += stats.entropy_frames;
        efalls += stats.entropy_fallbacks;
        pre_coding += stats.pre_coding_bytes;
        post_coding += stats.post_coding_bytes;
        pf_prompts += stats.prefill_prompts;
        pf_chunks += stats.prefill_chunks;
        pf_keys += stats.prefill_key_chunks;
        pf_bytes += stats.prefill_bytes;
        pf_resyncs += stats.prefill_resyncs;
        rts.extend(stats.round_trip_us);
    }
    let wall = t0.elapsed().as_secs_f64();
    rts.sort_unstable();
    let pct = |p: f64| rts.get(((rts.len() as f64 * p) as usize).min(rts.len() - 1))
        .copied().unwrap_or(0);

    println!("\n=== results ===");
    println!("tokens generated:   {total_tokens} in {wall:.2}s  \
              ({:.1} tok/s)", total_tokens as f64 / wall);
    println!("wire bytes:         {total_bytes} (raw would be {total_raw}, \
              {:.1}x compression)", total_raw as f64 / total_bytes.max(1) as f64);
    println!("step round-trip:    p50={}us p95={}us p99={}us",
             pct(0.50), pct(0.95), pct(0.99));
    if stream {
        println!("stream frames:      {keys} keyframes, {deltas} deltas, \
                  {resyncs} resyncs");
    }
    if adaptive {
        println!("rate control:       {switches} ladder switches, deepest \
                  point {max_point}");
    }
    if entropy {
        println!("entropy coding:     {eframes} coded frames, {efalls} raw \
                  fallbacks; coded bodies {pre_coding} B -> {post_coding} B \
                  ({:.2}x)",
                 pre_coding as f64 / post_coding.max(1) as f64);
    }
    if prefill {
        println!("chunked prefill:    {pf_prompts} prompts in {pf_chunks} \
                  chunks ({pf_keys} keyframe), {pf_bytes} B on the wire, \
                  {pf_resyncs} resyncs");
    }

    // server-side metrics
    println!("server metrics:     {}",
             server.metrics.to_json().to_string_compact());
    server.shutdown();
    Ok(())
}
