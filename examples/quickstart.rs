//! Quickstart: open the artifact store, pull a real layer-1
//! activation out of the model, and round-trip it through every
//! codec at the paper's average ratio — the 60-second tour of the
//! public API.
//!
//!     cargo run --release --example quickstart

use fourier_compress::codec::{self, rel_error, Codec};
use fourier_compress::model::executor::SplitExecutor;
use fourier_compress::model::tokenizer;
use fourier_compress::runtime::ArtifactStore;
use fourier_compress::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::open("artifacts")?;
    println!("PJRT platform: {}", store.runtime.platform());

    let exec = SplitExecutor::new(&store, "llamette-s")?;
    let meta = &exec.meta;
    println!("model {}: d={} layers={} params={}",
             meta.name, meta.d_model, meta.n_layers, meta.n_params);

    // a real prompt through embed + all layers; grab layer-1 output
    let prompt = "Q mira hue ? A blue .";
    let ids = tokenizer::encode_prompt(prompt);
    let len = ids.len();
    let (b, s) = (meta.eval_batch, meta.eval_seq);
    let mut toks = Vec::new();
    for _ in 0..b {
        toks.extend(tokenizer::pad_to(&ids, s));
    }
    let acts = exec.activations(&Tensor::i32(vec![b, s], toks))?;
    let d = meta.d_model;
    let a1 = &acts[0].as_f32()[..len * d]; // crop to the true length

    println!("\nlayer-1 activation {}x{} — codecs at ratio 7.6:", len, d);
    println!("{:8} {:>10} {:>12}", "codec", "ratio", "rel-error");
    for name in ["fc", "topk", "qr", "fwsvd", "asvd", "svdllm", "int8"] {
        let c: Box<dyn Codec> = if name == "fc" {
            Box::new(codec::fourier::FourierCodec::with_hint(meta.kd_band()))
        } else {
            codec::by_name(name)?
        };
        let p = c.compress(a1, len, d, 7.6)?;
        let rec = c.decompress(&p)?;
        println!("{:8} {:>9.1}x {:>12.4}", name, p.achieved_ratio(),
                 rel_error(a1, &rec));
    }

    // the same comparison on a DEEP activation: the layer-aware story
    let deep = &acts[meta.n_layers - 1].as_f32()[..len * d];
    let fc = codec::fourier::FourierCodec::with_hint(meta.kd_band());
    let p1 = fc.compress(a1, len, d, 7.6)?;
    let pl = fc.compress(deep, len, d, 7.6)?;
    println!("\nfc rel-error layer 1:  {:.4}", rel_error(a1, &fc.decompress(&p1)?));
    println!("fc rel-error layer {}: {:.4}   <- deep layers resist compression",
             meta.n_layers, rel_error(deep, &fc.decompress(&pl)?));
    Ok(())
}
