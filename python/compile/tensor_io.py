"""`.fcw` tensor container — the python↔rust weight/golden interchange.

Layout (little-endian):

    magic   b"FCW1"
    u32     n_tensors
    per tensor:
        u16     name_len
        bytes   name (utf-8)
        u8      dtype   (0 = f32, 1 = i32)
        u8      ndim
        u32*    dims
        bytes   row-major payload

Deliberately trivial so the rust reader (`rust/src/tensor/io.rs`) stays
dependency-free.  Used for model weights, golden test vectors, and any
array the experiment drivers exchange with the build step.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"FCW1"
DTYPES = {0: np.float32, 1: np.int32}
DTYPE_IDS = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def write_fcw(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.asarray(arr)
            if arr.dtype == np.float64:
                arr = arr.astype(np.float32)
            if arr.dtype in (np.int64, np.uint32, np.int16, np.uint8):
                arr = arr.astype(np.int32)
            if arr.dtype not in DTYPE_IDS:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPE_IDS[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(np.ascontiguousarray(arr).tobytes())


def read_fcw(path: str) -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (nl,) = struct.unpack("<H", f.read(2))
            name = f.read(nl).decode("utf-8")
            did, nd = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{nd}I", f.read(4 * nd)) if nd else ()
            dt = np.dtype(DTYPES[did])
            count = int(np.prod(dims)) if dims else 1
            arr = np.frombuffer(f.read(count * dt.itemsize), dtype=dt)
            out[name] = arr.reshape(dims).copy()
    return out
