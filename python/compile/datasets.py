"""Fact-world corpus + the 10 synthetic MCQ dataset generators.

Stand-ins for the paper's 10 commonsense-reasoning benchmarks
(DESIGN.md §2).  A closed "fact world" (entities with attributes and a
friend relation) yields a training corpus the build-time trainer
memorises; each dataset flavour probes that knowledge with a different
prompt structure, mirroring the paper's spread:

    oa  OpenBookQA      closed-book attribute recall (color)
    ae  ARC-Easy        closed-book attribute recall (home, common attrs)
    ac  ARC-Challenge   two-hop recall through the friend relation
    pa  PIQA            in-context physical comparison (answer in prompt)
    sa  SIQA            closed-book mood/social attribute recall
    wg  WinoGrande      in-context referent resolution (most fragile)
    cq  CommonsenseQA   category membership (which is a color?)
    qc  QASC            two-fact composition given in context
    la  LogiQA          negation/elimination over a binary attribute pair
    ca  CosmosQA        in-context recall with distractor facts

Like the paper's suite, the in-context tasks (pa, ca) are redundant and
compression-tolerant, while referent resolution (wg) hinges on fine
activation detail — this is what produces the dataset-adaptive ratios
of Table II.

Byte-level tokenizer: token = byte, plus BOS/EOS/PAD specials.
"""

from __future__ import annotations

import json
import random

from .configs import BOS_ID, EOS_ID, PAD_ID

# ---------------------------------------------------------------------------
# tokenizer
# ---------------------------------------------------------------------------

def encode(text: str) -> list[int]:
    return list(text.encode("utf-8"))


def decode(ids: list[int]) -> str:
    return bytes(i for i in ids if 0 <= i < 256).decode("utf-8", errors="replace")


def encode_prompt(text: str) -> list[int]:
    return [BOS_ID] + encode(text)


# ---------------------------------------------------------------------------
# the world
# ---------------------------------------------------------------------------

ENTITIES = [
    "mira", "rok", "zeb", "kol", "fen", "tas", "ulf", "vex",
    "nim", "ora", "pax", "quin", "rus", "sil", "tov", "una",
    "wex", "yan", "zara", "bru", "cal", "dex", "eli", "fay",
]

ATTRS = {
    "hue": ["red", "blue", "green", "gold", "gray"],
    "size": ["big", "small", "tiny", "huge"],
    "den": ["cave", "lake", "hill", "fort", "barn"],
    "food": ["figs", "corn", "fish", "nuts", "rice"],
    "mood": ["glad", "calm", "grim", "wild"],
    "job": ["smith", "guard", "baker", "scout"],
}

SIZE_RANK = {"tiny": 0, "small": 1, "big": 2, "huge": 3}


class World:
    """Deterministic assignment of attributes + a friend permutation."""

    def __init__(self, seed: int = 7):
        rng = random.Random(seed)
        self.facts: dict[str, dict[str, str]] = {}
        for e in ENTITIES:
            self.facts[e] = {a: rng.choice(vs) for a, vs in ATTRS.items()}
        ents = ENTITIES[:]
        rng.shuffle(ents)
        # derangement-ish friend cycle
        self.friend = {ents[i]: ents[(i + 1) % len(ents)] for i in range(len(ents))}
        self.rng = rng

    def attr(self, e: str, a: str) -> str:
        return self.facts[e][a]


# ---------------------------------------------------------------------------
# training corpus
# ---------------------------------------------------------------------------

def render_corpus(world: World, seed: int = 11, repeats: int = 6) -> str:
    """Fact statements + QA-format exemplars for every task flavour.

    The QA exemplars cover ALL entities (closed-book memorisation is
    the point — the paper's models saw their benchmarks' knowledge in
    pre-training too); the eval sets re-sample prompts/distractors, so
    items are not byte-identical to training lines.
    """
    rng = random.Random(seed)
    lines: list[str] = []
    for _ in range(repeats):
        for e in ENTITIES:
            for a, v in world.facts[e].items():
                lines.append(f"{e} {a} is {v} .")
                lines.append(f"Q {e} {a} ? A {v} .")
            f = world.friend[e]
            lines.append(f"friend of {e} is {f} .")
            for a in ("hue", "food", "den"):
                lines.append(f"Q friend of {e} {a} ? A {world.attr(f, a)} .")
        # category exemplars
        for a, vs in ATTRS.items():
            for v in vs:
                lines.append(f"{v} is a {a} .")
                other = [x for vv in ATTRS.values() for x in vv if x not in vs]
                d = rng.sample(other, 3)
                opts = d + [v]
                rng.shuffle(opts)
                lines.append(f"Q which is a {a} ? {' '.join(opts)} A {v} .")
        # in-context exemplars (pa / wg / qc / la / ca formats)
        for _ in range(len(ENTITIES)):
            a, b = rng.sample(ENTITIES, 2)
            sa_, sb = world.attr(a, "size"), world.attr(b, "size")
            if SIZE_RANK[sa_] == SIZE_RANK[sb]:
                continue
            win = a if SIZE_RANK[sa_] > SIZE_RANK[sb] else b
            lines.append(f"{a} is {sa_} . {b} is {sb} . Q bigger ? A {win} .")
        for _ in range(len(ENTITIES)):
            a, b = rng.sample(ENTITIES, 2)
            ca_, cb = world.attr(a, "hue"), world.attr(b, "hue")
            if ca_ == cb:
                continue
            pick = rng.choice([a, b])
            cv = world.attr(pick, "hue")
            lines.append(f"{a} met {b} . it was {cv} . Q {cv} one ? A {pick} .")
        for _ in range(len(ENTITIES)):
            e = rng.choice(ENTITIES)
            v, h = world.attr(e, "food"), world.attr(e, "den")
            lines.append(f"{e} food is {v} . {e} den is {h} . Q {e} food ? A {v} .")
        for _ in range(len(ENTITIES)):
            e = rng.choice(ENTITIES)
            cv = world.attr(e, "hue")
            wrong = rng.choice([c for c in ATTRS["hue"] if c != cv])
            lines.append(f"{e} hue is not {wrong} . Q {e} hue ? A {cv} .")
        for _ in range(len(ENTITIES)):
            e, d1 = rng.sample(ENTITIES, 2)
            cv = world.attr(e, "hue")
            lines.append(
                f"{d1} den is {world.attr(d1, 'den')} . {e} hue is {cv} . "
                f"Q {e} hue ? A {cv} ."
            )
    rng.shuffle(lines)
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# MCQ generators — each returns {prompt, choices[4], answer}
# ---------------------------------------------------------------------------

def _mcq(prompt: str, correct: str, distract: list[str], rng) -> dict:
    ds = rng.sample([d for d in distract if d != correct], 3)
    choices = ds + [correct]
    rng.shuffle(choices)
    return {"prompt": prompt, "choices": choices, "answer": choices.index(correct)}


def gen_attr_recall(world, rng, attr):
    e = rng.choice(ENTITIES)
    return _mcq(f"Q {e} {attr} ? A", world.attr(e, attr), ATTRS[attr], rng)


def gen_oa(world, rng):
    return gen_attr_recall(world, rng, "hue")


def gen_ae(world, rng):
    return gen_attr_recall(world, rng, rng.choice(["den", "food"]))


def gen_ac(world, rng):
    e = rng.choice(ENTITIES)
    a = rng.choice(["hue", "food", "den"])
    f = world.friend[e]
    return _mcq(f"Q friend of {e} {a} ? A", world.attr(f, a), ATTRS[a], rng)


def gen_pa(world, rng):
    while True:
        a, b = rng.sample(ENTITIES, 2)
        sa_, sb = world.attr(a, "size"), world.attr(b, "size")
        if SIZE_RANK[sa_] != SIZE_RANK[sb]:
            break
    win = a if SIZE_RANK[sa_] > SIZE_RANK[sb] else b
    lose = b if win == a else a
    prompt = f"{a} is {sa_} . {b} is {sb} . Q bigger ? A"
    others = [x for x in ENTITIES if x not in (a, b)]
    ds = rng.sample(others, 2) + [lose]
    choices = ds + [win]
    rng.shuffle(choices)
    return {"prompt": prompt, "choices": choices, "answer": choices.index(win)}


def gen_sa(world, rng):
    return gen_attr_recall(world, rng, "mood")


def gen_wg(world, rng):
    while True:
        a, b = rng.sample(ENTITIES, 2)
        if world.attr(a, "hue") != world.attr(b, "hue"):
            break
    pick = rng.choice([a, b])
    other = b if pick == a else a
    cv = world.attr(pick, "hue")
    prompt = f"{a} met {b} . it was {cv} . Q {cv} one ? A"
    others = [x for x in ENTITIES if x not in (a, b)]
    choices = rng.sample(others, 2) + [other, pick]
    rng.shuffle(choices)
    return {"prompt": prompt, "choices": choices, "answer": choices.index(pick)}


def gen_cq(world, rng):
    a = rng.choice(list(ATTRS))
    v = rng.choice(ATTRS[a])
    other = [x for aa, vs in ATTRS.items() if aa != a for x in vs]
    item = _mcq(f"Q which is a {a} ? A", v, other + [v], rng)
    # ensure exactly one member of the category among the choices
    fixed = [c if (c == v or c not in ATTRS[a]) else rng.choice(other)
             for c in item["choices"]]
    item["choices"] = fixed
    item["answer"] = fixed.index(v)
    return item


def gen_qc(world, rng):
    e = rng.choice(ENTITIES)
    v, h = world.attr(e, "food"), world.attr(e, "den")
    prompt = f"{e} food is {v} . {e} den is {h} . Q {e} food ? A"
    return _mcq(prompt, v, ATTRS["food"], rng)


def gen_la(world, rng):
    e = rng.choice(ENTITIES)
    cv = world.attr(e, "hue")
    wrong = rng.choice([c for c in ATTRS["hue"] if c != cv])
    prompt = f"{e} hue is not {wrong} . Q {e} hue ? A"
    item = _mcq(prompt, cv, ATTRS["hue"], rng)
    if wrong not in item["choices"]:
        # negated value must be a live distractor for the elimination
        for i, c in enumerate(item["choices"]):
            if c != cv:
                item["choices"][i] = wrong
                break
        item["answer"] = item["choices"].index(cv)
    return item


def gen_ca(world, rng):
    e, d1 = rng.sample(ENTITIES, 2)
    cv = world.attr(e, "hue")
    prompt = (f"{d1} den is {world.attr(d1, 'den')} . {e} hue is {cv} . "
              f"Q {e} hue ? A")
    return _mcq(prompt, cv, ATTRS["hue"], rng)


DATASETS = {
    "oa": gen_oa, "ae": gen_ae, "ac": gen_ac, "pa": gen_pa, "sa": gen_sa,
    "wg": gen_wg, "cq": gen_cq, "qc": gen_qc, "la": gen_la, "ca": gen_ca,
}

# paper-name mapping, for reports
PAPER_NAMES = {
    "oa": "OpenBookQA", "ae": "ARC-Easy", "ac": "ARC-Challenge", "pa": "PIQA",
    "sa": "SIQA", "wg": "WinoGrande", "cq": "CommonsenseQA", "qc": "QASC",
    "la": "LogiQA", "ca": "CosmosQA",
}


def gen_dataset(name: str, world: World, n: int, seed: int = 0) -> list[dict]:
    rng = random.Random(hash((name, seed)) & 0xFFFFFFFF)
    gen = DATASETS[name]
    items, seen = [], set()
    guard = 0
    while len(items) < n and guard < 50 * n:
        guard += 1
        it = gen(world, rng)
        key = (it["prompt"], tuple(it["choices"]))
        if key in seen:
            continue
        seen.add(key)
        items.append(it)
    return items


def write_jsonl(path: str, items: list[dict]) -> None:
    with open(path, "w") as f:
        for it in items:
            f.write(json.dumps(it) + "\n")


def max_item_len(items: list[dict]) -> int:
    return max(
        len(encode_prompt(it["prompt"])) + len(encode(" " + c + " ."))
        for it in items for c in it["choices"]
    )
