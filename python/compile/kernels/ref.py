"""Pure-jnp oracles for every pallas kernel.

These are the CORE correctness signal: pytest (with hypothesis sweeps)
asserts `kernels.* == ref.*` to tolerance, and `aot.py` dumps golden
vectors computed with these refs that the rust test-suite replays
against its native codec implementations.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# FourierCompress — centred truncated 2-D FFT (DESIGN.md §6)
# ---------------------------------------------------------------------------

def freq_indices(n: int, k: int) -> np.ndarray:
    """The k lowest-|frequency| DFT bins of an n-point axis, k odd.

    Returns [0, 1, .., h, n-h, .., n-1] with h = (k-1)//2 — i.e. the
    fftshift-centred block.  The set is closed under u -> (n-u) mod n,
    so the truncated spectrum of a real signal stays conjugate-
    symmetric and its inverse transform is exactly real.
    """
    if k < 1 or k > n:
        raise ValueError(f"k={k} out of range for n={n}")
    if k == n:  # full axis — every bin kept, trivially conjugate-closed
        return np.arange(n, dtype=np.int32)
    if k % 2 == 0:
        raise ValueError(f"k={k} must be odd (conjugate closure)")
    h = (k - 1) // 2
    return np.concatenate([np.arange(0, h + 1), np.arange(n - h, n)]).astype(np.int32)


def fc_compress_ref(a: jnp.ndarray, ks: int, kd: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """A[S,D] -> (re, im)[K_S, K_D]: FFT2 then gather the centred block."""
    s, d = a.shape
    spec = jnp.fft.fft2(a)
    u = jnp.asarray(freq_indices(s, ks))
    v = jnp.asarray(freq_indices(d, kd))
    block = spec[jnp.ix_(u, v)]
    return jnp.real(block).astype(jnp.float32), jnp.imag(block).astype(jnp.float32)


def fc_decompress_ref(re: jnp.ndarray, im: jnp.ndarray, s: int, d: int) -> jnp.ndarray:
    """(re, im)[K_S,K_D] -> A'[S,D]: scatter, IFFT2, take the real part.

    With the centred (conjugate-closed) frequency set, the imaginary
    part of the inverse transform is identically zero for blocks that
    came from a real signal; `real` only discards numerical dust.
    """
    ks, kd = re.shape
    u = jnp.asarray(freq_indices(s, ks))
    v = jnp.asarray(freq_indices(d, kd))
    spec = jnp.zeros((s, d), dtype=jnp.complex64)
    spec = spec.at[jnp.ix_(u, v)].set(re + 1j * im)
    return jnp.real(jnp.fft.ifft2(spec)).astype(jnp.float32)


def dft_matrices(n: int, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Forward/backward truncated DFT panels for the matmul formulation.

    fwd[k, n] has rows exp(-2πi u_j s / n) over the centred bins u_j;
    bwd[n, k] = exp(+2πi u_j s / n) / n.  Then

        block = fwd_S @ A @ fwd_D.T        (compress)
        A'    = Re( bwd_S @ block @ bwd_D.T )   (decompress)
    """
    u = freq_indices(n, k).astype(np.float64)
    s = np.arange(n, dtype=np.float64)
    ang = 2.0 * np.pi * np.outer(u, s) / n
    fwd = np.exp(-1j * ang)
    bwd = (np.exp(1j * ang) / n).T
    return fwd.astype(np.complex64), bwd.astype(np.complex64)


def fc_compress_matmul_ref(a: jnp.ndarray, ks: int, kd: int):
    """Same math as fc_compress_ref via two dense matmuls (MXU form)."""
    s, d = a.shape
    fs, _ = dft_matrices(s, ks)
    fd, _ = dft_matrices(d, kd)
    block = jnp.asarray(fs) @ a.astype(jnp.complex64) @ jnp.asarray(fd).T
    return jnp.real(block).astype(jnp.float32), jnp.imag(block).astype(jnp.float32)


def fc_decompress_matmul_ref(re: jnp.ndarray, im: jnp.ndarray, s: int, d: int):
    ks, kd = re.shape
    _, bs = dft_matrices(s, ks)
    _, bd = dft_matrices(d, kd)
    block = (re + 1j * im).astype(jnp.complex64)
    return jnp.real(jnp.asarray(bs) @ block @ jnp.asarray(bd).T).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Baseline codecs (golden vectors + python-side sanity checks)
# ---------------------------------------------------------------------------

def topk_ref(a: jnp.ndarray, k: int) -> jnp.ndarray:
    """Keep the k largest-|.| entries of A (stable tie-break), zero the rest."""
    flat = a.reshape(-1)
    if k >= flat.shape[0]:
        return a
    order = jnp.argsort(-jnp.abs(flat), stable=True)
    keep = jnp.zeros(flat.shape, dtype=bool).at[order[:k]].set(True)
    return (flat * keep).reshape(a.shape)


def svd_rank_r_ref(a: jnp.ndarray, r: int) -> jnp.ndarray:
    u, s, vt = jnp.linalg.svd(a, full_matrices=False)
    return (u[:, :r] * s[:r]) @ vt[:r, :]


# ---------------------------------------------------------------------------
# Transformer building blocks
# ---------------------------------------------------------------------------

def rmsnorm_ref(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x / jnp.sqrt(ms + eps)) * w


def causal_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """q,k,v: [H, S, hd] (kv already expanded to H heads). Causal softmax."""
    h, s, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    logits = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask[None, :, :], logits, jnp.float32(-1e30))
    m = jnp.max(logits, axis=-1, keepdims=True)
    probs = jnp.exp(logits - m)
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", probs, v)
