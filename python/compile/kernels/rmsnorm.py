"""L1 — fused RMSNorm pallas kernel.

Row-parallel over a (rows, D) view of the activations; one grid step
normalises a tile of rows entirely in VMEM (single read of x, fused
square/mean/rsqrt/scale — the memory-bound fusion the paper's client
device wants on the layer-1 path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 8


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = x * (1.0 / jnp.sqrt(ms + eps)) * w_ref[...]


@functools.partial(jax.jit, static_argnums=(2, 3))
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5,
            block_rows: int | None = None) -> jnp.ndarray:
    """RMSNorm over the last axis of x[..., D] with weight w[D]."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = block_rows or DEFAULT_BLOCK_ROWS
    if rows % br != 0:
        br = rows
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), jnp.float32),
        interpret=True,
    )(x2.astype(jnp.float32), w.astype(jnp.float32))
    return out.reshape(orig_shape)
