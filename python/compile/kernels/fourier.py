"""L1 — the FourierCompress pallas kernels (truncated 2-D DFT codec).

TPU restatement of the paper's cuFFT/FPGA insight (DESIGN.md §8): the
truncated 2-D FFT over the centred low-frequency bins is a pair of
skinny complex matmuls

    block[K_S, K_D] = F_S @ A @ F_D.T          (compress)
    A'[S, D]        = Re( B_S @ block @ B_D.T )  (decompress)

with fixed DFT panels F/B.  This maps onto the MXU instead of a
butterfly network.  The pallas schedule streams A (resp. A') through
VMEM in D-axis tiles while the skinny panels and the K_S×K_D
accumulator stay VMEM-resident — the BlockSpec plays the role the
paper's threadblock/DSP-slice pipeline played on GPU/FPGA.

Complex arithmetic is carried as separate re/im planes (no complex MXU
path).  `interpret=True` everywhere: the CPU PJRT client cannot run
Mosaic custom-calls; on-TPU performance is analysed statically in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import dft_matrices

# D-axis tile width for the HBM->VMEM stream. 128 matches the TPU lane
# width; shapes not divisible by the tile fall back to a single tile.
DEFAULT_BLOCK_D = 128


def _block_d(d: int, block_d: int | None) -> int:
    bd = block_d or DEFAULT_BLOCK_D
    if d % bd != 0:
        return d
    return bd


def _panels(n: int, k: int):
    fwd, bwd = dft_matrices(n, k)
    return (
        np.real(fwd).astype(np.float32),
        np.imag(fwd).astype(np.float32),
        np.real(bwd).astype(np.float32),
        np.imag(bwd).astype(np.float32),
    )


# ---------------------------------------------------------------------------
# compress:  A[S, D] -> (re, im)[K_S, K_D]
# ---------------------------------------------------------------------------

def _compress_kernel(a_ref, fdt_re_ref, fdt_im_ref, fs_re_ref, fs_im_ref,
                     out_re_ref, out_im_ref, t_re, t_im):
    """Grid step j: fold A[:, j-tile] into the T = A @ F_D.T accumulator;
    on the last step apply the sequence-axis panel and emit the block."""
    j = pl.program_id(0)
    nj = pl.num_programs(0)

    @pl.when(j == 0)
    def _init():
        t_re[...] = jnp.zeros_like(t_re)
        t_im[...] = jnp.zeros_like(t_im)

    a = a_ref[...]  # [S, BD]
    t_re[...] += a @ fdt_re_ref[...]  # [S, KD]
    t_im[...] += a @ fdt_im_ref[...]

    @pl.when(j == nj - 1)
    def _emit():
        fs_re = fs_re_ref[...]  # [KS, S]
        fs_im = fs_im_ref[...]
        tr, ti = t_re[...], t_im[...]
        out_re_ref[...] = fs_re @ tr - fs_im @ ti
        out_im_ref[...] = fs_re @ ti + fs_im @ tr


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def fc_compress(a: jnp.ndarray, ks: int, kd: int, block_d: int | None = None):
    """Pallas truncated-DFT compression of A[S, D] to a K_S×K_D block."""
    s, d = a.shape
    bd = _block_d(d, block_d)
    fs_re, fs_im, _, _ = _panels(s, ks)
    fd_re, fd_im, _, _ = _panels(d, kd)
    fdt_re = jnp.asarray(fd_re.T)  # [D, KD]
    fdt_im = jnp.asarray(fd_im.T)

    grid = (d // bd,)
    out = pl.pallas_call(
        _compress_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s, bd), lambda j: (0, j)),      # A tile streams
            pl.BlockSpec((bd, kd), lambda j: (j, 0)),     # F_D.T tile streams
            pl.BlockSpec((bd, kd), lambda j: (j, 0)),
            pl.BlockSpec((ks, s), lambda j: (0, 0)),      # F_S resident
            pl.BlockSpec((ks, s), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ks, kd), lambda j: (0, 0)),
            pl.BlockSpec((ks, kd), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ks, kd), jnp.float32),
            jax.ShapeDtypeStruct((ks, kd), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((s, kd), jnp.float32),
            pltpu.VMEM((s, kd), jnp.float32),
        ],
        interpret=True,
    )(a.astype(jnp.float32), fdt_re, fdt_im, jnp.asarray(fs_re), jnp.asarray(fs_im))
    return out[0], out[1]


# ---------------------------------------------------------------------------
# decompress:  (re, im)[K_S, K_D] -> A'[S, D]
# ---------------------------------------------------------------------------

def _decompress_kernel(re_ref, im_ref, bs_re_ref, bs_im_ref,
                       bdt_re_ref, bdt_im_ref, out_ref, c_re, c_im):
    """Grid step j: on the first step lift the block through the
    sequence-axis panel (C = B_S @ block, VMEM-resident); every step
    emits one D-tile of A' = Re(C @ B_D.T)."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _lift():
        br, bi = re_ref[...], im_ref[...]
        bs_re, bs_im = bs_re_ref[...], bs_im_ref[...]
        c_re[...] = bs_re @ br - bs_im @ bi
        c_im[...] = bs_re @ bi + bs_im @ br

    out_ref[...] = c_re[...] @ bdt_re_ref[...] - c_im[...] @ bdt_im_ref[...]


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def fc_decompress(re: jnp.ndarray, im: jnp.ndarray, s: int, d: int,
                  block_d: int | None = None):
    """Pallas truncated-IDFT reconstruction of A'[S, D] from the block."""
    ks, kd = re.shape
    bd = _block_d(d, block_d)
    _, _, bs_re, bs_im = _panels(s, ks)  # [S, KS]
    _, _, bd_re, bd_im = _panels(d, kd)  # [D, KD]
    bdt_re = jnp.asarray(bd_re.T)  # [KD, D]
    bdt_im = jnp.asarray(bd_im.T)

    grid = (d // bd,)
    out = pl.pallas_call(
        _decompress_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ks, kd), lambda j: (0, 0)),
            pl.BlockSpec((ks, kd), lambda j: (0, 0)),
            pl.BlockSpec((s, ks), lambda j: (0, 0)),
            pl.BlockSpec((s, ks), lambda j: (0, 0)),
            pl.BlockSpec((kd, bd), lambda j: (0, j)),
            pl.BlockSpec((kd, bd), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((s, bd), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((s, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((s, kd), jnp.float32),
            pltpu.VMEM((s, kd), jnp.float32),
        ],
        interpret=True,
    )(re.astype(jnp.float32), im.astype(jnp.float32),
      jnp.asarray(bs_re), jnp.asarray(bs_im), bdt_re, bdt_im)
    return out


def fc_compress_matmul(a: jnp.ndarray, ks: int, kd: int):
    """Truncated-DFT compress as two plain jnp matmuls (no pallas).

    This is the Table-IV "hardware" timing proxy: XLA lowers it to its
    optimized dense kernels, standing in for a cuFFT/FPGA offload the
    way the MXU would on a real TPU (DESIGN.md §2).  Identical math to
    `fc_compress`.
    """
    s, d = a.shape
    fs_re, fs_im, _, _ = _panels(s, ks)
    fd_re, fd_im, _, _ = _panels(d, kd)
    a = a.astype(jnp.float32)
    t_re = a @ jnp.asarray(fd_re.T)
    t_im = a @ jnp.asarray(fd_im.T)
    out_re = jnp.asarray(fs_re) @ t_re - jnp.asarray(fs_im) @ t_im
    out_im = jnp.asarray(fs_re) @ t_im + jnp.asarray(fs_im) @ t_re
    return out_re, out_im


def fc_decompress_matmul(re: jnp.ndarray, im: jnp.ndarray, s: int, d: int):
    """Inverse of `fc_compress_matmul` (real part of the lift)."""
    ks, kd = re.shape
    _, _, bs_re, bs_im = _panels(s, ks)
    _, _, bd_re, bd_im = _panels(d, kd)
    c_re = jnp.asarray(bs_re) @ re - jnp.asarray(bs_im) @ im
    c_im = jnp.asarray(bs_re) @ im + jnp.asarray(bs_im) @ re
    return c_re @ jnp.asarray(bd_re.T) - c_im @ jnp.asarray(bd_im.T)


def fc_roundtrip(a: jnp.ndarray, ks: int, kd: int) -> jnp.ndarray:
    re, im = fc_compress(a, ks, kd)
    return fc_decompress(re, im, a.shape[0], a.shape[1])


def vmem_footprint_bytes(s: int, d: int, ks: int, kd: int,
                         block_d: int | None = None) -> dict:
    """Static VMEM budget of the compress schedule (EXPERIMENTS.md §Perf).

    Resident: F_S panel (2·KS·S), T accumulator (2·S·KD), output block
    (2·KS·KD); streamed per step: A tile (S·BD) + F_D.T tile (2·BD·KD).
    """
    bd = _block_d(d, block_d)
    f32 = 4
    resident = (2 * ks * s + 2 * s * kd + 2 * ks * kd) * f32
    streamed = (s * bd + 2 * bd * kd) * f32
    macs = ks * s * d * 2 + ks * d * kd * 4  # complex folds
    return {
        "block_d": bd,
        "resident_bytes": resident,
        "streamed_bytes_per_step": streamed,
        "total_vmem_bytes": resident + 2 * streamed,  # double-buffered stream
        "mac_count": macs,
    }
