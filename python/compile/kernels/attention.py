"""L1 — causal attention pallas kernel (flash-style online softmax).

Grid over (head, query-tile).  Each step keeps a q tile, the running
(m, l, acc) online-softmax state, and streams k/v tiles through VMEM.
For the sequence lengths this repo ships (<=64) a single kv tile
suffices, but the online-softmax structure is kept so the kernel is
the real algorithm, not a toy softmax.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 16


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, seq: int):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)          # [BQ, hd]
    k = k_ref[0].astype(jnp.float32)          # [S, hd]
    v = v_ref[0].astype(jnp.float32)          # [S, hd]
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    logits = (q @ k.T) * scale                # [BQ, S]
    qpos = qi * block_q + jax.lax.iota(jnp.int32, block_q)
    kpos = jax.lax.iota(jnp.int32, seq)
    mask = kpos[None, :] <= qpos[:, None]
    logits = jnp.where(mask, logits, jnp.float32(-1e30))

    # online softmax over kv tiles (single tile here, state kept explicit)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = (p @ v) / l


@functools.partial(jax.jit, static_argnums=(3,))
def causal_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     block_q: int | None = None) -> jnp.ndarray:
    """q, k, v: [H, S, hd] (kv pre-expanded to H heads); causal output."""
    h, s, hd = q.shape
    bq = block_q or DEFAULT_BLOCK_Q
    if s % bq != 0:
        bq = s
    out = pl.pallas_call(
        functools.partial(_attn_kernel, block_q=bq, seq=s),
        grid=(h, s // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda hh, qi: (hh, qi, 0)),
            pl.BlockSpec((1, s, hd), lambda hh, qi: (hh, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda hh, qi: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda hh, qi: (hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, hd), jnp.float32),
        interpret=True,
    )(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
    return out
