"""L2 — the Llama-style decoder-only LM (build-time jax).

The model is written so that every weight is an explicit function
argument: the AOT artifacts (`aot.py`) close over *shapes* only, and
the rust runtime feeds weights loaded from `.fcw` files at execution
time.  One `layer_fwd` HLO therefore serves all layers of a model,
which is what lets the rust eval harness pick ANY split point
(DESIGN.md §3).

Weight layout per layer (canonical argument order — the manifest and
the rust side both rely on it):

    ln1, wq, wk, wv, wo, [bq, bk, bv,] ln2, w_gate, w_up, w_down

Model-level: tok_emb [V, D], final_norm [D], lm_head [D, V].
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels import ref as kref
from .kernels.attention import causal_attention as pallas_attention
from .kernels.fourier import fc_compress, fc_decompress
from .kernels.rmsnorm import rmsnorm as pallas_rmsnorm

LAYER_WEIGHTS = ("ln1", "wq", "wk", "wv", "wo", "ln2", "w_gate", "w_up", "w_down")
LAYER_WEIGHTS_BIAS = ("ln1", "wq", "wk", "wv", "bq", "bk", "bv", "wo",
                      "ln2", "w_gate", "w_up", "w_down")


def layer_weight_names(cfg: ModelConfig) -> tuple[str, ...]:
    return LAYER_WEIGHTS_BIAS if cfg.qkv_bias else LAYER_WEIGHTS


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key=None) -> dict[str, jnp.ndarray]:
    """Scaled-normal init; names are `tok_emb`, `layers.{i}.{w}`,
    `final_norm`, `lm_head`."""
    key = key if key is not None else jax.random.PRNGKey(cfg.seed)
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    kv = cfg.n_kv_heads * cfg.head_dim
    params: dict[str, jnp.ndarray] = {}

    def nrm(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale)

    keys = jax.random.split(key, 3 + cfg.n_layers)
    params["tok_emb"] = nrm(keys[0], (v, d), 0.02)
    params["final_norm"] = jnp.ones((d,), jnp.float32)
    params["lm_head"] = nrm(keys[1], (d, v), 1.0 / math.sqrt(d))
    out_scale = 1.0 / math.sqrt(2.0 * cfg.n_layers)
    for i in range(cfg.n_layers):
        lk = jax.random.split(keys[3 + i], 8)
        p = f"layers.{i}."
        params[p + "ln1"] = jnp.ones((d,), jnp.float32)
        params[p + "wq"] = nrm(lk[0], (d, d), 1.0 / math.sqrt(d))
        params[p + "wk"] = nrm(lk[1], (d, kv), 1.0 / math.sqrt(d))
        params[p + "wv"] = nrm(lk[2], (d, kv), 1.0 / math.sqrt(d))
        params[p + "wo"] = nrm(lk[3], (d, d), out_scale / math.sqrt(d))
        if cfg.qkv_bias:
            params[p + "bq"] = jnp.zeros((d,), jnp.float32)
            params[p + "bk"] = jnp.zeros((kv,), jnp.float32)
            params[p + "bv"] = jnp.zeros((kv,), jnp.float32)
        params[p + "ln2"] = jnp.ones((d,), jnp.float32)
        params[p + "w_gate"] = nrm(lk[4], (d, f), 1.0 / math.sqrt(d))
        params[p + "w_up"] = nrm(lk[5], (d, f), 1.0 / math.sqrt(d))
        params[p + "w_down"] = nrm(lk[6], (f, d), out_scale / math.sqrt(f))
    return params


def layer_params(params: dict, cfg: ModelConfig, i: int) -> list[jnp.ndarray]:
    return [params[f"layers.{i}.{n}"] for n in layer_weight_names(cfg)]


# ---------------------------------------------------------------------------
# layer-1 spectral bottleneck (DESIGN.md §2)
# ---------------------------------------------------------------------------

def lowpass_last(w: jnp.ndarray, bins: int) -> jnp.ndarray:
    """Project rows of w onto the lowest `bins` rfft bins of the last axis."""
    f = jnp.fft.rfft(w, axis=-1)
    mask = (jnp.arange(f.shape[-1]) < bins).astype(f.dtype)
    return jnp.fft.irfft(f * mask, n=w.shape[-1], axis=-1).astype(jnp.float32)


L1_PROJECTED = ("tok_emb", "layers.0.wo", "layers.0.w_down")


def project_l1(params: dict, cfg: ModelConfig) -> dict:
    """Constrain every residual-stream contribution up to the layer-1
    boundary (embeddings + layer-0 attention/MLP outputs) to the lowest
    `cfg.l1_freq_bins` hidden-axis frequencies.

    Training runs through this reparameterisation, so gradients stay in
    the subspace and the layer-1 activation is *exactly* band-limited
    along d — the tiny-model analogue of the early-layer spectral
    concentration the paper measures on Llama 3 / Qwen2.5.  Deeper
    layers are unconstrained, so compressibility decays with depth the
    same way it does in the paper (Fig 2/4).
    """
    out = dict(params)
    for k in L1_PROJECTED:
        out[k] = lowpass_last(params[k], cfg.l1_freq_bins)
    return out


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def rope_tables(seq: int, head_dim: int, theta: float):
    """cos/sin [S, hd/2] — computed with numpy so they fold to constants."""
    inv = 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))
    ang = np.outer(np.arange(seq, dtype=np.float64), inv)
    return (jnp.asarray(np.cos(ang), jnp.float32),
            jnp.asarray(np.sin(ang), jnp.float32))


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: [B, H, S, hd] rotated pairwise (x0,x1),(x2,x3),.."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    c = cos[None, None, :, :]
    s = sin[None, None, :, :]
    r1 = x1 * c - x2 * s
    r2 = x1 * s + x2 * c
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape)


def _rmsnorm(x, w, eps, use_pallas):
    if use_pallas:
        return pallas_rmsnorm(x, w, eps)
    return kref.rmsnorm_ref(x, w, eps)


def _attention(q, k, v, use_pallas):
    """q,k,v: [B, H, S, hd] -> [B, H, S, hd]"""
    if use_pallas:
        return jax.vmap(pallas_attention)(q, k, v)
    return jax.vmap(kref.causal_attention_ref)(q, k, v)


def layer_fwd(cfg: ModelConfig, h: jnp.ndarray, *w, use_pallas: bool = False
              ) -> jnp.ndarray:
    """One transformer block over h[B, S, D]; weights in canonical order."""
    names = layer_weight_names(cfg)
    p = dict(zip(names, w))
    b, s, d = h.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    x = _rmsnorm(h, p["ln1"], cfg.rms_eps, use_pallas)
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, nkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, nkv, hd).transpose(0, 2, 1, 3)
    cos, sin = rope_tables(s, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if nkv != nh:
        rep = nh // nkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    attn = _attention(q, k, v, use_pallas)
    attn = attn.transpose(0, 2, 1, 3).reshape(b, s, d)
    h = h + attn @ p["wo"]

    x = _rmsnorm(h, p["ln2"], cfg.rms_eps, use_pallas)
    gate = x @ p["w_gate"]
    up = x @ p["w_up"]
    h = h + (jax.nn.silu(gate) * up) @ p["w_down"]
    return h


def embed(tokens: jnp.ndarray, tok_emb: jnp.ndarray) -> jnp.ndarray:
    return tok_emb[tokens]


def head(cfg: ModelConfig, h: jnp.ndarray, final_norm: jnp.ndarray,
         lm_head: jnp.ndarray, use_pallas: bool = False) -> jnp.ndarray:
    x = _rmsnorm(h, final_norm, cfg.rms_eps, use_pallas)
    return x @ lm_head


# ---------------------------------------------------------------------------
# whole-model forward (training + goldens)
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
            use_pallas: bool = False) -> jnp.ndarray:
    """tokens[B, S] -> logits[B, S, V]."""
    h = embed(tokens, params["tok_emb"])
    for i in range(cfg.n_layers):
        h = layer_fwd(cfg, h, *layer_params(params, cfg, i), use_pallas=use_pallas)
    return head(cfg, h, params["final_norm"], params["lm_head"], use_pallas)


def activations(cfg: ModelConfig, params: dict, tokens: jnp.ndarray
                ) -> list[jnp.ndarray]:
    """Per-layer activation tensors [B, S, D] AFTER each block (layer 1 ==
    index 0) — the quantities the paper compresses/analyses (Fig 2)."""
    h = embed(tokens, params["tok_emb"])
    acts = []
    for i in range(cfg.n_layers):
        h = layer_fwd(cfg, h, *layer_params(params, cfg, i))
        acts.append(h)
    return acts


def split_forward(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
                  split: int, ks: int, kd: int) -> jnp.ndarray:
    """Reference split pipeline: client layers [0, split), FC codec on the
    boundary activation, server layers [split, L).  Golden for the rust
    end-to-end parity test."""
    h = embed(tokens, params["tok_emb"])
    for i in range(split):
        h = layer_fwd(cfg, h, *layer_params(params, cfg, i))

    def codec(a):
        re, im = kref.fc_compress_ref(a, ks, kd)
        return kref.fc_decompress_ref(re, im, a.shape[0], a.shape[1])

    h = jax.vmap(codec)(h)
    for i in range(split, cfg.n_layers):
        h = layer_fwd(cfg, h, *layer_params(params, cfg, i))
    return head(cfg, h, params["final_norm"], params["lm_head"])


# ---------------------------------------------------------------------------
# fused serving path (split k=1): pallas codec lowered into the artifacts
# ---------------------------------------------------------------------------

def client_fused(cfg: ModelConfig, tokens: jnp.ndarray, tok_emb: jnp.ndarray,
                 layer0: list[jnp.ndarray], ks: int, kd: int):
    """tokens[B,S] -> (re, im)[B, K_S, K_D]: embed + layer 1 + pallas
    fc_compress, one HLO module — the device-side request path."""
    h = embed(tokens, tok_emb)
    h = layer_fwd(cfg, h, *layer0)
    re, im = jax.vmap(lambda a: fc_compress(a, ks, kd))(h)
    return re, im


def server_fused(cfg: ModelConfig, re: jnp.ndarray, im: jnp.ndarray,
                 stacked: list[jnp.ndarray], final_norm: jnp.ndarray,
                 lm_head: jnp.ndarray, seq: int):
    """(re, im)[B,K_S,K_D] + stacked layer weights [L-1, ...] -> logits.

    Layers 2..L run under lax.scan over the stacked weights (bounds HLO
    size/compile time); reconstruction uses the pallas fc_decompress.
    """
    d = cfg.d_model
    h = jax.vmap(lambda r, i_: fc_decompress(r, i_, seq, d))(re, im)

    def body(hh, ws):
        return layer_fwd(cfg, hh, *ws), None

    h, _ = jax.lax.scan(body, h, tuple(stacked))
    return head(cfg, h, final_norm, lm_head)


def stack_layer_params(params: dict, cfg: ModelConfig, lo: int, hi: int
                       ) -> list[jnp.ndarray]:
    """Stack weights of layers [lo, hi) along a new leading axis for scan."""
    names = layer_weight_names(cfg)
    return [jnp.stack([params[f"layers.{i}.{n}"] for i in range(lo, hi)])
            for n in names]


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def loss_fn(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
            targets: jnp.ndarray, pad_id: int) -> jnp.ndarray:
    logits = forward(cfg, project_l1(params, cfg), tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = (targets != pad_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
