"""Build-time trainer for the four stand-in models.

Hand-rolled AdamW + cosine schedule (no optax in the image).  Trains on
random windows of the fact-world corpus; logs the loss curve (recorded
in EXPERIMENTS.md) and dumps weights as `.fcw`.

Run directly for one model:  python -m compile.train --model llamette-s
`aot.py` invokes train_model() for all four when weights are missing.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets as D
from . import model as M
from . import tensor_io
from .configs import MODELS, PAD_ID, ModelConfig, TrainConfig


def corpus_tokens(seed: int = 7) -> np.ndarray:
    world = D.World(seed)
    text = D.render_corpus(world, seed=seed + 4)
    return np.asarray(D.encode(text), dtype=np.int32)


def sample_batch(tokens: np.ndarray, rng: np.random.Generator, batch: int,
                 seq: int) -> tuple[np.ndarray, np.ndarray]:
    """Random corpus windows; x = window, y = next-token targets."""
    starts = rng.integers(0, len(tokens) - seq - 1, size=batch)
    x = np.stack([tokens[s:s + seq] for s in starts])
    y = np.stack([tokens[s + 1:s + seq + 1] for s in starts])
    return x, y


def adamw_init(params: dict) -> dict:
    return {
        "m": {k: jnp.zeros_like(v) for k, v in params.items()},
        "v": {k: jnp.zeros_like(v) for k, v in params.items()},
        "t": jnp.zeros((), jnp.int32),
    }


def lr_at(step, tc: TrainConfig):
    warm = jnp.minimum(1.0, (step + 1) / tc.warmup)
    prog = jnp.clip((step - tc.warmup) / max(1, tc.steps - tc.warmup), 0.0, 1.0)
    return tc.lr * warm * (0.5 * (1.0 + jnp.cos(math.pi * prog)))


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    b1, b2, eps = 0.9, 0.95, 1e-8
    decay_skip = ("ln1", "ln2", "final_norm", "bq", "bk", "bv")

    @jax.jit
    def step(params, opt, x, y):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, x, y, PAD_ID))(params)
        # global-norm clip
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads.values()))
        scale = jnp.minimum(1.0, tc.grad_clip / (gn + 1e-9))
        t = opt["t"] + 1
        lr = lr_at(t, tc)
        new_p, new_m, new_v = {}, {}, {}
        for k, g in grads.items():
            g = g * scale
            m = b1 * opt["m"][k] + (1 - b1) * g
            v = b2 * opt["v"][k] + (1 - b2) * g * g
            mh = m / (1 - b1 ** t)
            vh = v / (1 - b2 ** t)
            upd = mh / (jnp.sqrt(vh) + eps)
            if not any(k.endswith(sfx) for sfx in decay_skip):
                upd = upd + tc.weight_decay * params[k]
            new_p[k] = params[k] - lr * upd
            new_m[k], new_v[k] = m, v
        return new_p, {"m": new_m, "v": new_v, "t": t}, loss, gn

    return step


def train_model(cfg: ModelConfig, tc: TrainConfig, out_dir: str,
                verbose: bool = True) -> dict:
    """Train one model; writes <name>.fcw and <name>.train.json; returns
    the loss log."""
    tokens = corpus_tokens()
    rng = np.random.default_rng(tc.seed + cfg.seed)
    params = M.init_params(cfg)
    opt = adamw_init(params)
    step_fn = make_train_step(cfg, tc)

    log = {"model": cfg.name, "steps": [], "loss": [], "config": cfg.to_dict(),
           "train_config": tc.__dict__, "corpus_tokens": int(len(tokens))}
    t0 = time.time()
    for s in range(tc.steps):
        x, y = sample_batch(tokens, rng, tc.batch, tc.seq)
        params, opt, loss, gn = step_fn(params, opt, jnp.asarray(x), jnp.asarray(y))
        if s % tc.log_every == 0 or s == tc.steps - 1:
            lv = float(loss)
            log["steps"].append(s)
            log["loss"].append(lv)
            if verbose:
                print(f"[{cfg.name}] step {s:4d} loss {lv:.4f} "
                      f"gnorm {float(gn):.2f} ({time.time() - t0:.0f}s)")
    log["wall_seconds"] = time.time() - t0

    # Persist the EFFECTIVE weights (post layer-1 spectral projection):
    # everything downstream — artifacts, rust runtime — consumes these,
    # and forward(effective) == the reparameterised training forward.
    params = M.project_l1(params, cfg)
    os.makedirs(out_dir, exist_ok=True)
    tensor_io.write_fcw(os.path.join(out_dir, f"{cfg.name}.fcw"),
                        {k: np.asarray(v) for k, v in params.items()})
    with open(os.path.join(out_dir, f"{cfg.name}.train.json"), "w") as f:
        json.dump(log, f, indent=1)
    return params


def load_or_train(cfg: ModelConfig, tc: TrainConfig, out_dir: str) -> dict:
    path = os.path.join(out_dir, f"{cfg.name}.fcw")
    if os.path.exists(path):
        arrs = tensor_io.read_fcw(path)
        return {k: jnp.asarray(v) for k, v in arrs.items()}
    return train_model(cfg, tc, out_dir)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="llamette-s", choices=list(MODELS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--out", default="../artifacts/weights")
    args = ap.parse_args()
    tc = TrainConfig()
    if args.steps:
        tc = TrainConfig(steps=args.steps)
    train_model(MODELS[args.model], tc, args.out)


if __name__ == "__main__":
    main()
