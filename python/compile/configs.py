"""Model + experiment configuration registry.

The four model variants stand in for Llama 3-1B/3B and Qwen2.5-1.5B/3B
(see DESIGN.md §2).  They are genuine Llama-style decoder-only LMs:
RMSNorm, RoPE, (grouped-query) multi-head attention, SwiGLU MLP.  The
"qwenette" family differs from "llamette" the way Qwen differs from
Llama: QKV bias and grouped KV heads.

Everything downstream (trainer, AOT pipeline, rust runtime) reads model
geometry from this registry; `aot.py` serialises it into
``artifacts/manifest.json`` so the rust side never hardcodes shapes.
"""

from dataclasses import dataclass, field, asdict

# Byte-level tokenizer: 256 raw bytes + specials.
VOCAB_BYTES = 256
BOS_ID = 256
EOS_ID = 257
PAD_ID = 258
VOCAB_SIZE = 259

# Sequence buckets used by the eval harness and the serving batcher.
SEQ_BUCKETS = (16, 32, 48, 64)
# Eval pads every (prompt, choice) pair to this length.
EVAL_SEQ = 64
# Eval batch size baked into the composable artifacts.
EVAL_BATCH = 8


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int = VOCAB_SIZE
    max_seq: int = 64
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    qkv_bias: bool = False  # Qwen-style attention bias
    # Number of rfft bins the layer-1 residual contributions live in
    # (hidden-axis spectral bottleneck; DESIGN.md §2 — this induces the
    # early-layer spectral concentration the paper measures on Llama 3,
    # which emerges from scale there and from this inductive bias here).
    l1_freq_bins: int = 8
    seed: int = 0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def n_params(self) -> int:
        d, v, f, L = self.d_model, self.vocab_size, self.d_ff, self.n_layers
        hd = self.head_dim
        kv = self.n_kv_heads * hd
        attn = d * d + 2 * d * kv + d * d  # wq, wk, wv, wo
        if self.qkv_bias:
            attn += d + 2 * kv
        mlp = 3 * d * f
        norms = 2 * d
        return v * d + L * (attn + mlp + norms) + d + d * v

    def to_dict(self) -> dict:
        out = asdict(self)
        out["head_dim"] = self.head_dim
        out["n_params"] = self.n_params()
        return out


MODELS = {
    # stands in for Llama 3-1B
    "llamette-s": ModelConfig(
        name="llamette-s", d_model=96, n_layers=6, n_heads=4, n_kv_heads=4,
        d_ff=256, l1_freq_bins=7, seed=1,
    ),
    # stands in for Llama 3-3B
    "llamette-m": ModelConfig(
        name="llamette-m", d_model=128, n_layers=8, n_heads=4, n_kv_heads=4,
        d_ff=344, l1_freq_bins=8, seed=2,
    ),
    # stands in for Qwen2.5-1.5B
    "qwenette-s": ModelConfig(
        name="qwenette-s", d_model=96, n_layers=6, n_heads=6, n_kv_heads=2,
        d_ff=256, qkv_bias=True, l1_freq_bins=7, seed=3,
    ),
    # stands in for Qwen2.5-3B
    "qwenette-m": ModelConfig(
        name="qwenette-m", d_model=128, n_layers=8, n_heads=8, n_kv_heads=4,
        d_ff=344, qkv_bias=True, l1_freq_bins=8, seed=4,
    ),
}

# The model used for the fused serving artifacts + E2E example.
SERVING_MODEL = "llamette-m"


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 600
    batch: int = 16
    seq: int = 64
    lr: float = 1.5e-3
    warmup: int = 40
    weight_decay: float = 0.05
    grad_clip: float = 1.0
    log_every: int = 25
    seed: int = 1234


# Hidden sizes for the Table IV codec-timing artifacts (the paper's real
# model hidden sizes: Qwen2.5-1.5B=1536, Llama3-1B/Qwen2.5-3B=2048,
# Llama3-3B=3072).
TABLE4_HIDDEN = (1536, 2048, 3072)
TABLE4_SEQ = 256
TABLE4_RATIO = 8.0


def _odd_cap(x: int, cap: int) -> int:
    x = max(1, min(x, cap))
    if x % 2 == 0:
        x = x - 1 if x > 1 else (x + 1 if x + 1 <= cap else 1)
    # a full axis (x == cap) is allowed even when cap is even: keeping
    # every bin is trivially conjugate-closed
    return x


def fc_block(seq: int, hidden: int, ratio: float,
             kd_hint: int | None = None) -> tuple[int, int]:
    """Pick (K_S, K_D) hitting the target ratio under conjugate-
    symmetric payload accounting: the wire carries only the
    non-redundant half of the centred block, so

        payload floats = K_S * K_D      ratio = S*D / (K_S*K_D)

    (DESIGN.md §6).  The hidden axis absorbs most of the truncation —
    LLM layer-1 activations concentrate along d — with `kd_hint`
    letting the caller pass a calibrated hidden-axis width.
    """
    budget = max(1.0, seq * hidden / ratio)  # real-coeff budget
    kd = kd_hint if kd_hint is not None else max(3, round(hidden / 8.0))
    kd = _odd_cap(kd, hidden)
    ks = int(budget // kd)
    if ks >= seq:
        ks = seq  # full sequence axis (even allowed: whole axis kept)
    else:
        ks = _odd_cap(ks, seq)
    return ks, kd


def achieved_ratio(seq: int, hidden: int, ks: int, kd: int) -> float:
    """Conjugate-symmetric accounting: K_S*K_D real payload floats."""
    return seq * hidden / float(ks * kd)
