"""AOT pipeline: trains models (if weights are missing), generates
datasets, lowers every HLO artifact, and dumps golden vectors + the
manifest the rust runtime consumes.

Interchange format is HLO **text** (not serialized HloModuleProto):
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects; the text parser reassigns ids.
(See /opt/xla-example/README.md.)

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datasets as D
from . import model as M
from . import tensor_io
from .configs import (EVAL_BATCH, EVAL_SEQ, MODELS, PAD_ID, SEQ_BUCKETS,
                      SERVING_MODEL, TABLE4_HIDDEN, TABLE4_RATIO, TABLE4_SEQ,
                      TrainConfig, achieved_ratio, fc_block)
from .kernels import ref as kref
from .kernels.fourier import fc_compress, fc_decompress, vmem_footprint_bytes
from .train import load_or_train

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants: the default HLO printer elides big dense
    # literals as `{...}`, which the text parser silently reads back as
    # zeros — RoPE tables / DFT panels would vanish from the artifacts.
    return comp.as_hlo_text(print_large_constants=True)


def lower_to_file(fn, args, path: str) -> None:
    text = to_hlo_text(jax.jit(fn).lower(*args))
    with open(path, "w") as f:
        f.write(text)


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------


def build_composable(cfg, out_dir: str, manifest_model: dict) -> None:
    """embed / layer / head artifacts at the eval geometry (B=8, S=64).

    Weights are runtime arguments in the canonical order, so one layer
    HLO serves every layer of the model and the rust side can split at
    any depth (DESIGN.md §3)."""
    b, s, d = EVAL_BATCH, EVAL_SEQ, cfg.d_model
    v, f = cfg.vocab_size, cfg.d_ff
    kv = cfg.n_kv_heads * cfg.head_dim
    names = M.layer_weight_names(cfg)

    shapes = {
        "ln1": (d,), "wq": (d, d), "wk": (d, kv), "wv": (d, kv),
        "bq": (d,), "bk": (kv,), "bv": (kv,), "wo": (d, d), "ln2": (d,),
        "w_gate": (d, f), "w_up": (d, f), "w_down": (f, d),
    }
    layer_args = [spec((b, s, d))] + [spec(shapes[n]) for n in names]

    art = {}
    path = f"{cfg.name}_embed_b{b}_s{s}.hlo.txt"
    lower_to_file(lambda t, e: (M.embed(t, e),),
                  [spec((b, s), I32), spec((v, d))],
                  os.path.join(out_dir, path))
    art["embed"] = {"path": path, "weight_args": ["tok_emb"]}

    path = f"{cfg.name}_layer_b{b}_s{s}.hlo.txt"
    lower_to_file(lambda h, *w: (M.layer_fwd(cfg, h, *w),), layer_args,
                  os.path.join(out_dir, path))
    art["layer"] = {"path": path,
                    "weight_args": [f"layers.{{i}}.{n}" for n in names]}

    path = f"{cfg.name}_head_b{b}_s{s}.hlo.txt"
    lower_to_file(lambda h, fn_, lh: (M.head(cfg, h, fn_, lh),),
                  [spec((b, s, d)), spec((d,)), spec((d, v))],
                  os.path.join(out_dir, path))
    art["head"] = {"path": path, "weight_args": ["final_norm", "lm_head"]}

    manifest_model["artifacts"] = art
    manifest_model["eval_batch"] = b
    manifest_model["eval_seq"] = s


def build_serving(cfg, out_dir: str, ratio: float) -> dict:
    """Fused client/server artifacts with the pallas codec lowered in
    (split k=1 hot path), per sequence bucket and server batch size."""
    d, v = cfg.d_model, cfg.vocab_size
    names = M.layer_weight_names(cfg)
    kvd = cfg.n_kv_heads * cfg.head_dim
    f = cfg.d_ff
    shapes = {
        "ln1": (d,), "wq": (d, d), "wk": (d, kvd), "wv": (d, kvd),
        "bq": (d,), "bk": (kvd,), "bv": (kvd,), "wo": (d, d), "ln2": (d,),
        "w_gate": (d, f), "w_up": (d, f), "w_down": (f, d),
    }
    nstack = cfg.n_layers - 1
    serving = {"model": cfg.name, "ratio": ratio, "buckets": {},
               "layer_weight_names": list(names)}

    kd_hint = 2 * cfg.l1_freq_bins - 1  # calibrated to the model's layer-1 band
    for s in SEQ_BUCKETS:
        ks, kd = fc_block(s, d, ratio, kd_hint=kd_hint)
        bucket = {"ks": ks, "kd": kd,
                  "achieved_ratio": achieved_ratio(s, d, ks, kd),
                  "client": None, "server": {}}

        cl_args = ([spec((1, s), I32), spec((v, d))] +
                   [spec(shapes[n]) for n in names])
        path = f"{cfg.name}_client_s{s}.hlo.txt"
        lower_to_file(
            lambda t, e, *w, _s=s, _ks=ks, _kd=kd: M.client_fused(
                cfg, t, e, list(w), _ks, _kd),
            cl_args, os.path.join(out_dir, path))
        bucket["client"] = {"path": path,
                            "weight_args": ["tok_emb"] +
                            [f"layers.0.{n}" for n in names]}

        for bsz in (1, 4):
            sv_args = ([spec((bsz, ks, kd)), spec((bsz, ks, kd))] +
                       [spec((nstack,) + shapes[n]) for n in names] +
                       [spec((d,)), spec((d, v))])
            path = f"{cfg.name}_server_s{s}_b{bsz}.hlo.txt"
            lower_to_file(
                lambda re, im, *rest, _s=s: (M.server_fused(
                    cfg, re, im, list(rest[:-2]), rest[-2], rest[-1], _s),),
                sv_args, os.path.join(out_dir, path))
            bucket["server"][str(bsz)] = {
                "path": path,
                "weight_args": [f"stack.{n}" for n in names] +
                               ["final_norm", "lm_head"]}
        serving["buckets"][str(s)] = bucket
    return serving


def build_codec_hw(out_dir: str) -> dict:
    """Standalone pallas-codec artifacts at the paper's hidden sizes —
    the 'hardware-accelerated' column of Table IV (stands in for
    cuFFT/FPGA offload; see DESIGN.md §2)."""
    out = {"ratio": TABLE4_RATIO, "entries": []}
    for dh in TABLE4_HIDDEN:
        s = TABLE4_SEQ
        ks, kd = fc_block(s, dh, TABLE4_RATIO)
        cpath = f"fft_compress_{s}x{dh}.hlo.txt"
        dpath = f"fft_decompress_{s}x{dh}.hlo.txt"
        mmc = f"fft_compress_mm_{s}x{dh}.hlo.txt"
        mmd = f"fft_decompress_mm_{s}x{dh}.hlo.txt"
        lower_to_file(lambda a, _ks=ks, _kd=kd: fc_compress(a, _ks, _kd),
                      [spec((s, dh))], os.path.join(out_dir, cpath))
        lower_to_file(
            lambda re, im, _s=s, _d=dh: (fc_decompress(re, im, _s, _d),),
            [spec((ks, kd)), spec((ks, kd))], os.path.join(out_dir, dpath))
        from .kernels.fourier import fc_compress_matmul, fc_decompress_matmul
        lower_to_file(lambda a, _ks=ks, _kd=kd: fc_compress_matmul(a, _ks, _kd),
                      [spec((s, dh))], os.path.join(out_dir, mmc))
        lower_to_file(
            lambda re, im, _s=s, _d=dh: (fc_decompress_matmul(re, im, _s, _d),),
            [spec((ks, kd)), spec((ks, kd))], os.path.join(out_dir, mmd))
        out["entries"].append({
            "seq": s, "hidden": dh, "ks": ks, "kd": kd,
            "achieved_ratio": achieved_ratio(s, dh, ks, kd),
            "compress": cpath, "decompress": dpath,
            "compress_mm": mmc, "decompress_mm": mmd,
            "vmem": vmem_footprint_bytes(s, dh, ks, kd),
        })
    return out


def build_datasets(out_dir: str, n_items: int) -> dict:
    world = D.World(7)
    meta = {}
    os.makedirs(out_dir, exist_ok=True)
    for name in D.DATASETS:
        items = D.gen_dataset(name, world, n_items, seed=1)
        path = os.path.join(out_dir, f"{name}.jsonl")
        D.write_jsonl(path, items)
        meta[name] = {"path": f"data/{name}.jsonl", "n": len(items),
                      "paper_name": D.PAPER_NAMES[name],
                      "max_len": D.max_item_len(items)}
    return meta


def build_goldens(cfg, params, out_dir: str) -> str:
    """Golden vectors for the rust parity tests: full-model logits,
    split+FC logits, layer-1 activation, and codec io pairs."""
    rng = np.random.default_rng(99 + cfg.seed)
    b, s, d = 2, EVAL_SEQ, cfg.d_model
    world = D.World(7)
    items = D.gen_dataset("oa", world, b, seed=5)
    toks = np.full((b, s), PAD_ID, np.int32)
    for i, it in enumerate(items):
        ids = D.encode_prompt(it["prompt"] + " " + it["choices"][0] + " .")
        toks[i, :len(ids)] = ids[:s]

    ks, kd = fc_block(s, d, 8.0, kd_hint=2 * cfg.l1_freq_bins - 1)
    logits = M.forward(cfg, params, jnp.asarray(toks))
    logits_split = M.split_forward(cfg, params, jnp.asarray(toks), 1, ks, kd)
    acts = M.activations(cfg, params, jnp.asarray(toks))

    a = np.asarray(acts[0][0], np.float32)  # layer-1 activation, first row
    re, im = kref.fc_compress_ref(jnp.asarray(a), ks, kd)
    recon = kref.fc_decompress_ref(re, im, s, d)

    g = {
        "tokens": toks, "ks_kd": np.asarray([ks, kd], np.int32),
        "logits_full": np.asarray(logits, np.float32),
        "logits_split1_fc8": np.asarray(logits_split, np.float32),
        "act_layer1": np.asarray(acts[0], np.float32),
        "codec_a": a, "codec_re": np.asarray(re), "codec_im": np.asarray(im),
        "codec_recon": np.asarray(recon),
        "topk_recon": np.asarray(kref.topk_ref(jnp.asarray(a), a.size // 16)),
        "svd_r4_recon": np.asarray(kref.svd_rank_r_ref(jnp.asarray(a), 4)),
    }
    path = os.path.join(out_dir, f"{cfg.name}.golden.fcw")
    tensor_io.write_fcw(path, g)
    return f"golden/{cfg.name}.golden.fcw"


# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=None,
                    help="override training steps (smoke builds)")
    ap.add_argument("--items", type=int, default=192,
                    help="eval items per dataset")
    ap.add_argument("--models", default=None,
                    help="comma-separated subset of models")
    args = ap.parse_args()

    out = args.out
    for sub in ("", "weights", "data", "golden", "hlo"):
        os.makedirs(os.path.join(out, sub), exist_ok=True)
    hlo_dir = os.path.join(out, "hlo")

    tc = TrainConfig() if args.steps is None else TrainConfig(steps=args.steps)
    model_names = (args.models.split(",") if args.models else list(MODELS))

    manifest = {
        "generated_unix": int(time.time()),
        "vocab": {"size": 259, "bos": 256, "eos": 257, "pad": PAD_ID},
        "eval": {"batch": EVAL_BATCH, "seq": EVAL_SEQ},
        "seq_buckets": list(SEQ_BUCKETS),
        "models": {},
    }

    t0 = time.time()
    for name in model_names:
        cfg = MODELS[name]
        print(f"=== {name}: train/load ({cfg.n_params():,} params)")
        params = load_or_train(cfg, tc, os.path.join(out, "weights"))
        mm = cfg.to_dict()
        mm["weights"] = f"weights/{name}.fcw"
        mm["layer_weight_names"] = list(M.layer_weight_names(cfg))
        print(f"=== {name}: composable artifacts")
        build_composable(cfg, hlo_dir, mm)
        print(f"=== {name}: goldens")
        mm["golden"] = build_goldens(cfg, params, os.path.join(out, "golden"))
        manifest["models"][name] = mm

    if SERVING_MODEL in model_names:
        print("=== serving artifacts (fused client/server, pallas codec)")
        manifest["serving"] = build_serving(MODELS[SERVING_MODEL], hlo_dir, 8.0)

    print("=== codec hardware artifacts (Table IV)")
    manifest["codec_hw"] = build_codec_hw(hlo_dir)

    print("=== datasets")
    manifest["datasets"] = build_datasets(os.path.join(out, "data"), args.items)

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"AOT complete in {time.time() - t0:.0f}s -> {out}/manifest.json")


if __name__ == "__main__":
    main()
