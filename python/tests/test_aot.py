"""AOT bridge sanity: lowering produces parseable HLO text, tensor_io
round-trips, and a lowered artifact executes with the expected
numerics through jax's own runtime (the rust integration tests replay
the same artifacts through PJRT)."""

import os

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model as M, tensor_io
from compile.configs import MODELS


def test_tensor_io_roundtrip(tmp_path):
    path = os.path.join(tmp_path, "t.fcw")
    rng = np.random.default_rng(0)
    tensors = {
        "a": rng.standard_normal((3, 4)).astype(np.float32),
        "b": rng.integers(0, 100, (7,)).astype(np.int32),
        "scalar": np.float32(3.5).reshape(()),
        "deep.name.with.dots": rng.standard_normal((2, 2, 2)).astype(np.float32),
    }
    tensor_io.write_fcw(path, tensors)
    out = tensor_io.read_fcw(path)
    assert set(out) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(out[k], tensors[k])
        assert out[k].dtype == tensors[k].dtype


def test_tensor_io_rejects_bad_magic(tmp_path):
    path = os.path.join(tmp_path, "bad.fcw")
    with open(path, "wb") as f:
        f.write(b"NOPE\x00\x00\x00\x00")
    try:
        tensor_io.read_fcw(path)
        raise AssertionError("expected ValueError")
    except ValueError:
        pass


def test_hlo_text_lowering(tmp_path):
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "dot(" in text or "dot." in text


def test_layer_artifact_lowers_and_runs(tmp_path):
    """The per-layer artifact form (weights as args) matches the plain
    forward when executed via jax."""
    cfg = MODELS["llamette-s"]
    params = M.project_l1(M.init_params(cfg), cfg)
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 259, (1, 16)),
                       jnp.int32)

    def layer_art(h, *w):
        return (M.layer_fwd(cfg, h, *w),)

    h = M.embed(toks, params["tok_emb"])
    w0 = M.layer_params(params, cfg, 0)
    via_art = jax.jit(layer_art)(h, *w0)[0]
    direct = M.layer_fwd(cfg, h, *w0)
    np.testing.assert_allclose(np.asarray(via_art), np.asarray(direct),
                               rtol=1e-5, atol=1e-5)


def test_golden_fields_present_if_built():
    """When `make artifacts` has run, validate manifest + goldens are
    mutually consistent (skipped on a fresh tree)."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man_path = os.path.join(root, "manifest.json")
    if not os.path.exists(man_path):
        import pytest
        pytest.skip("artifacts not built")
    import json
    man = json.load(open(man_path))
    for name, mm in man["models"].items():
        g = tensor_io.read_fcw(os.path.join(root, mm["golden"]))
        for key in ("tokens", "logits_full", "logits_split1_fc8",
                    "act_layer1", "codec_a", "codec_re", "codec_im",
                    "codec_recon"):
            assert key in g, (name, key)
        assert g["logits_full"].shape == g["logits_split1_fc8"].shape
        hlo = os.path.join(root, "hlo", mm["artifacts"]["layer"]["path"])
        assert os.path.exists(hlo)
        assert "HloModule" in open(hlo).read(200)
