"""L2 correctness: model forward paths, split/fused parity, the
layer-1 spectral bottleneck, and pallas-vs-jnp agreement."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.configs import MODELS, fc_block, achieved_ratio
from compile.kernels import ref as kref


def toks(b, s, seed=0, vocab=259):
    return jnp.asarray(np.random.default_rng(seed).integers(0, vocab, (b, s)),
                       jnp.int32)


@pytest.fixture(scope="module")
def small():
    cfg = MODELS["llamette-s"]
    return cfg, M.init_params(cfg)


@pytest.fixture(scope="module")
def qwen():
    cfg = MODELS["qwenette-s"]
    return cfg, M.init_params(cfg)


def test_forward_shapes(small):
    cfg, p = small
    lg = M.forward(cfg, p, toks(2, 32))
    assert lg.shape == (2, 32, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg)).all()


def test_qwen_forward_shapes(qwen):
    cfg, p = qwen
    assert cfg.qkv_bias and cfg.n_kv_heads != cfg.n_heads
    lg = M.forward(cfg, p, toks(2, 16))
    assert lg.shape == (2, 16, cfg.vocab_size)


def test_param_count_matches_config(small):
    cfg, p = small
    assert cfg.n_params() == sum(int(np.prod(v.shape)) for v in p.values())


def test_causality(small):
    """Changing a future token must not change past logits."""
    cfg, p = small
    t1 = toks(1, 24, 1)
    t2 = t1.at[0, 20].set((t1[0, 20] + 1) % cfg.vocab_size)
    l1 = M.forward(cfg, p, t1)
    l2 = M.forward(cfg, p, t2)
    np.testing.assert_allclose(np.asarray(l1[0, :20]), np.asarray(l2[0, :20]),
                               rtol=1e-4, atol=1e-4)
    assert np.max(np.abs(np.asarray(l1[0, 20:]) - np.asarray(l2[0, 20:]))) > 1e-3


def test_pallas_kernels_match_jnp(small):
    cfg, p = small
    t = toks(1, 16, 3)
    l_jnp = M.forward(cfg, p, t, use_pallas=False)
    l_pal = M.forward(cfg, p, t, use_pallas=True)
    np.testing.assert_allclose(np.asarray(l_jnp), np.asarray(l_pal),
                               rtol=1e-4, atol=1e-4)


def test_attention_kernel_vs_ref():
    rng = np.random.default_rng(0)
    q, k, v = (jnp.asarray(rng.standard_normal((4, 32, 16)), jnp.float32)
               for _ in range(3))
    from compile.kernels.attention import causal_attention
    out = causal_attention(q, k, v)
    refo = kref.causal_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(refo),
                               rtol=1e-4, atol=1e-4)


def test_rmsnorm_kernel_vs_ref():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((3, 8, 96)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(96), jnp.float32)
    from compile.kernels.rmsnorm import rmsnorm
    np.testing.assert_allclose(np.asarray(rmsnorm(x, w)),
                               np.asarray(kref.rmsnorm_ref(x, w)),
                               rtol=1e-4, atol=1e-4)


def test_l1_projection_bandlimits_activation(small):
    cfg, p = small
    p = M.project_l1(p, cfg)
    acts = M.activations(cfg, p, toks(1, 32, 7))
    a = np.asarray(acts[0][0])  # layer-1 activation [S, D]
    spec = np.fft.rfft(a, axis=-1)
    assert np.max(np.abs(spec[:, cfg.l1_freq_bins:])) < 1e-3 * np.max(np.abs(spec))


def test_split_forward_lossless_at_band(small):
    """FC block covering the full sequence axis and the model's layer-1
    band must reproduce full-model logits exactly (to fp32 dust)."""
    cfg, p = small
    p = M.project_l1(p, cfg)
    t = toks(2, 32, 9)
    kd = 2 * cfg.l1_freq_bins - 1
    full = M.forward(cfg, p, t)
    split = M.split_forward(cfg, p, t, 1, 32, kd)
    np.testing.assert_allclose(np.asarray(split), np.asarray(full),
                               rtol=1e-3, atol=1e-3)


def test_fused_serving_matches_split(small):
    cfg, p = small
    p = M.project_l1(p, cfg)
    t = toks(1, 16, 11)
    ks, kd = fc_block(16, cfg.d_model, 8.0, kd_hint=2 * cfg.l1_freq_bins - 1)
    re, im = M.client_fused(cfg, t, p["tok_emb"], M.layer_params(p, cfg, 0),
                            ks, kd)
    stacked = M.stack_layer_params(p, cfg, 1, cfg.n_layers)
    fused = M.server_fused(cfg, re, im, stacked, p["final_norm"],
                           p["lm_head"], 16)
    split = M.split_forward(cfg, p, t, 1, ks, kd)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(split),
                               rtol=2e-3, atol=2e-3)


def test_fc_block_accounting():
    for s in (16, 32, 48, 64):
        for ratio in (6.0, 8.0, 10.0):
            ks, kd = fc_block(s, 128, ratio, kd_hint=15)
            assert 1 <= ks <= s and 1 <= kd <= 128
            assert kd % 2 == 1
            assert ks == s or ks % 2 == 1
            got = achieved_ratio(s, 128, ks, kd)
            assert got >= ratio * 0.8  # never undershoots badly


def test_loss_decreases_quick():
    from compile import train as T
    from compile.configs import TrainConfig
    cfg = MODELS["llamette-s"]
    tokens = T.corpus_tokens()
    rng = np.random.default_rng(0)
    params = M.init_params(cfg)
    opt = T.adamw_init(params)
    tc = TrainConfig(steps=8, batch=4, seq=32)
    step = T.make_train_step(cfg, tc)
    losses = []
    for _ in range(8):
        x, y = T.sample_batch(tokens, rng, 4, 32)
        params, opt, loss, _ = step(params, opt, jnp.asarray(x), jnp.asarray(y))
        losses.append(float(loss))
    assert losses[-1] < losses[0]
