"""The matmul-form codec (Table-IV hardware proxy) must agree with the
FFT-form reference and the pallas kernel."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fourier import (fc_compress, fc_compress_matmul,
                                     fc_decompress_matmul)


def rand(s, d, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal((s, d)),
                       jnp.float32)


@settings(max_examples=15, deadline=None)
@given(s=st.sampled_from([16, 32, 64]), d=st.sampled_from([64, 96, 128]),
       hks=st.integers(0, 3), hkd=st.integers(0, 6), seed=st.integers(0, 999))
def test_matmul_compress_matches_fft(s, d, hks, hkd, seed):
    ks, kd = 2 * hks + 1, 2 * hkd + 1
    a = rand(s, d, seed)
    re_m, im_m = fc_compress_matmul(a, ks, kd)
    re_f, im_f = ref.fc_compress_ref(a, ks, kd)
    np.testing.assert_allclose(np.asarray(re_m), np.asarray(re_f),
                               rtol=2e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(im_m), np.asarray(im_f),
                               rtol=2e-3, atol=5e-3)


def test_matmul_decompress_matches_fft():
    a = rand(32, 96, 7)
    re, im = ref.fc_compress_ref(a, 9, 13)
    out_m = fc_decompress_matmul(re, im, 32, 96)
    out_f = ref.fc_decompress_ref(re, im, 32, 96)
    np.testing.assert_allclose(np.asarray(out_m), np.asarray(out_f),
                               rtol=1e-3, atol=1e-4)


def test_matmul_matches_pallas():
    a = rand(16, 128, 9)
    re_m, im_m = fc_compress_matmul(a, 5, 15)
    re_p, im_p = fc_compress(a, 5, 15)
    np.testing.assert_allclose(np.asarray(re_m), np.asarray(re_p),
                               rtol=2e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(im_m), np.asarray(im_p),
                               rtol=2e-3, atol=5e-3)
