"""L1 correctness: pallas fourier kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes / block sizes / seeds; exact properties
(conjugate closure, real reconstruction, energy ordering) are asserted
directly.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fourier import (fc_compress, fc_decompress,
                                     fc_roundtrip, vmem_footprint_bytes)


def rand(s, d, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal((s, d)),
                       jnp.float32)


odd = st.integers(1, 7).map(lambda h: 2 * h + 1)


@settings(max_examples=25, deadline=None)
@given(
    s=st.sampled_from([8, 16, 24, 32]),
    d=st.sampled_from([32, 64, 96, 128]),
    hks=st.integers(0, 3),
    hkd=st.integers(0, 7),
    seed=st.integers(0, 10_000),
)
def test_compress_matches_ref(s, d, hks, hkd, seed):
    ks, kd = 2 * hks + 1, 2 * hkd + 1
    if ks > s or kd > d:
        return
    a = rand(s, d, seed)
    re_p, im_p = fc_compress(a, ks, kd)
    re_r, im_r = ref.fc_compress_ref(a, ks, kd)
    np.testing.assert_allclose(np.asarray(re_p), np.asarray(re_r),
                               rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(np.asarray(im_p), np.asarray(im_r),
                               rtol=2e-4, atol=2e-3)


@settings(max_examples=25, deadline=None)
@given(
    s=st.sampled_from([8, 16, 32]),
    d=st.sampled_from([32, 64, 128]),
    hks=st.integers(0, 3),
    hkd=st.integers(0, 7),
    seed=st.integers(0, 10_000),
)
def test_decompress_matches_ref(s, d, hks, hkd, seed):
    ks, kd = 2 * hks + 1, 2 * hkd + 1
    if ks > s or kd > d:
        return
    a = rand(s, d, seed)
    re, im = ref.fc_compress_ref(a, ks, kd)
    out_p = fc_decompress(re, im, s, d)
    out_r = ref.fc_decompress_ref(re, im, s, d)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=2e-4, atol=2e-3)


def test_matmul_form_equals_fft_form():
    a = rand(32, 96, 3)
    for ks, kd in [(5, 13), (17, 31), (31, 95)]:
        r1, i1 = ref.fc_compress_ref(a, ks, kd)
        r2, i2 = ref.fc_compress_matmul_ref(a, ks, kd)
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2),
                                   rtol=1e-3, atol=2e-3)
        o1 = ref.fc_decompress_ref(r1, i1, 32, 96)
        o2 = ref.fc_decompress_matmul_ref(r1, i1, 32, 96)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=1e-3, atol=2e-3)


def test_bandlimited_signal_is_exactly_recovered():
    """A signal synthesised from the kept bins must round-trip to
    numerical precision — the near-lossless guarantee on layer-1
    activations (whose hidden-axis band the trainer enforces)."""
    s, d, ks, kd = 32, 96, 9, 13
    rng = np.random.default_rng(5)
    u = ref.freq_indices(s, ks)
    v = ref.freq_indices(d, kd)
    spec = np.zeros((s, d), np.complex128)
    for ui in u:
        for vi in v:
            if spec[ui, vi] != 0:
                continue
            c = rng.standard_normal() + 1j * rng.standard_normal()
            spec[ui, vi] = c
            spec[(-ui) % s, (-vi) % d] = np.conj(c)
    a = jnp.asarray(np.real(np.fft.ifft2(spec)), jnp.float32)
    out = fc_roundtrip(a, ks, kd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a),
                               rtol=1e-4, atol=1e-5)


def test_reconstruction_is_real_valued():
    # imaginary part of the truncated inverse must vanish: compare the
    # ref (which takes .real) against an explicit complex ifft
    a = rand(16, 64, 7)
    re, im = ref.fc_compress_ref(a, 5, 9)
    u = ref.freq_indices(16, 5)
    v = ref.freq_indices(64, 9)
    spec = np.zeros((16, 64), np.complex128)
    spec[np.ix_(u, v)] = np.asarray(re) + 1j * np.asarray(im)
    full = np.fft.ifft2(spec)
    assert np.max(np.abs(full.imag)) < 1e-5


def test_freq_indices_conjugate_closed():
    for n in (8, 15, 64, 96):
        for k in (1, 3, 5, 7):
            idx = set(ref.freq_indices(n, k).tolist())
            assert {(-i) % n for i in idx} == idx
    # full axis allowed even when n is even
    assert len(ref.freq_indices(64, 64)) == 64


def test_freq_indices_rejects_even_partial():
    with pytest.raises(ValueError):
        ref.freq_indices(64, 8)
    with pytest.raises(ValueError):
        ref.freq_indices(8, 9)


def test_energy_monotone_in_block_size():
    a = rand(32, 96, 11)

    def err(ks, kd):
        out = ref.fc_decompress_ref(*ref.fc_compress_ref(a, ks, kd), 32, 96)
        return float(jnp.linalg.norm(out - a))

    errs = [err(k, k + 8) for k in (3, 9, 15, 21, 27)]
    assert all(e1 >= e2 - 1e-5 for e1, e2 in zip(errs, errs[1:]))


def test_block_d_sweep_same_result():
    a = rand(16, 128, 13)
    base = None
    for bd in (32, 64, 128):
        re, im = fc_compress(a, 5, 17, block_d=bd)
        if base is None:
            base = (np.asarray(re), np.asarray(im))
        else:
            np.testing.assert_allclose(np.asarray(re), base[0], rtol=1e-4,
                                       atol=1e-4)


def test_vmem_footprint_reported():
    fp = vmem_footprint_bytes(256, 2048, 63, 255)
    assert fp["total_vmem_bytes"] > 0
    assert fp["mac_count"] > 0
    # must fit a TPU core's ~16 MiB VMEM for the shapes we ship
    assert fp["total_vmem_bytes"] < 16 * 1024 * 1024
