"""Dataset generator invariants: answerability, vocabulary closure,
length budget, determinism, distinct choices."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import datasets as D
from compile.configs import EVAL_SEQ, BOS_ID


def world():
    return D.World(7)


def test_world_deterministic():
    w1, w2 = D.World(7), D.World(7)
    assert w1.facts == w2.facts and w1.friend == w2.friend
    assert D.World(8).facts != w1.facts


def test_corpus_nonempty_and_ascii():
    text = D.render_corpus(world())
    assert len(text) > 10_000
    assert all(ord(c) < 128 for c in set(text))


@settings(max_examples=20, deadline=None)
@given(name=st.sampled_from(sorted(D.DATASETS)), seed=st.integers(0, 100))
def test_items_well_formed(name, seed):
    items = D.gen_dataset(name, world(), 16, seed=seed)
    assert len(items) == 16
    for it in items:
        assert len(it["choices"]) == 4
        assert len(set(it["choices"])) == 4
        assert 0 <= it["answer"] < 4
        assert it["prompt"].endswith("A")


def test_items_fit_eval_seq():
    w = world()
    for name in D.DATASETS:
        items = D.gen_dataset(name, w, 128, seed=3)
        assert D.max_item_len(items) <= EVAL_SEQ, name


def test_generation_deterministic():
    w = world()
    a = D.gen_dataset("oa", w, 32, seed=5)
    b = D.gen_dataset("oa", w, 32, seed=5)
    assert a == b
    c = D.gen_dataset("oa", w, 32, seed=6)
    assert a != c


def test_answers_consistent_with_world():
    w = world()
    for it in D.gen_dataset("oa", w, 64, seed=1):
        ent = it["prompt"].split()[1]
        assert it["choices"][it["answer"]] == w.attr(ent, "hue")
    for it in D.gen_dataset("ac", w, 64, seed=1):
        toks = it["prompt"].split()
        ent, attr = toks[3], toks[4]
        assert it["choices"][it["answer"]] == w.attr(w.friend[ent], attr)


def test_la_negated_value_among_choices():
    w = world()
    for it in D.gen_dataset("la", w, 64, seed=2):
        neg = it["prompt"].split()[4]
        assert neg in it["choices"]
        assert it["choices"][it["answer"]] != neg


def test_pa_answer_is_bigger_entity():
    w = world()
    for it in D.gen_dataset("pa", w, 64, seed=2):
        t = it["prompt"].split()
        a, sa, b, sb = t[0], t[2], t[4], t[6]
        win = it["choices"][it["answer"]]
        assert win in (a, b)
        assert D.SIZE_RANK[w.attr(win, "size")] == max(
            D.SIZE_RANK[sa], D.SIZE_RANK[sb])


@settings(max_examples=30, deadline=None)
@given(text=st.text(alphabet=st.characters(min_codepoint=32, max_codepoint=126),
                    max_size=80))
def test_tokenizer_roundtrip(text):
    assert D.decode(D.encode(text)) == text
    ids = D.encode_prompt(text)
    assert ids[0] == BOS_ID
    assert all(0 <= i < 259 for i in ids)
