//! Row-major dense f64 matrix with just the operations the
//! factorization codecs need.  Matmul accumulates in f64 with a
//! blocked inner loop (see EXPERIMENTS.md §Perf for the iteration
//! that landed on this shape).

#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Mat {
        let r = rows.len();
        let c = rows[0].len();
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c);
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    pub fn from_f32(a: &[f32], rows: usize, cols: usize) -> Mat {
        assert_eq!(a.len(), rows * cols);
        Mat { rows, cols, data: a.iter().map(|&v| v as f64).collect() }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t[(c, r)] = self[(r, c)];
            }
        }
        t
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        // i-k-j loop order: streams `other` rows, accumulates into the
        // output row — cache-friendly for row-major data.
        for i in 0..m {
            let orow = &mut out.data[i * n..(i + 1) * n];
            for kk in 0..k {
                let a = self.data[i * k + kk];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn scale_cols(&mut self, scales: &[f64]) {
        assert_eq!(scales.len(), self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                self.data[r * self.cols + c] *= scales[c];
            }
        }
    }

    pub fn col_norms(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[c] += self[(r, c)] * self[(r, c)];
            }
        }
        out.iter_mut().for_each(|v| *v = v.sqrt());
        out
    }

    pub fn row_norms(&self) -> Vec<f64> {
        (0..self.rows)
            .map(|r| self.row(r).iter().map(|v| v * v).sum::<f64>().sqrt())
            .collect()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    pub fn rand_mat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut m = Mat::zeros(r, c);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    #[test]
    fn matmul_identity() {
        let a = rand_mat(5, 7, 1);
        assert_eq!(Mat::eye(5).matmul(&a).data, a.data);
        let prod = a.matmul(&Mat::eye(7));
        for (x, y) in prod.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = rand_mat(4, 9, 2);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matmul_associative() {
        let a = rand_mat(4, 5, 3);
        let b = rand_mat(5, 6, 4);
        let c = rand_mat(6, 3, 5);
        let l = a.matmul(&b).matmul(&c);
        let r = a.matmul(&b.matmul(&c));
        assert!(l.sub(&r).frob_norm() < 1e-10);
    }

    #[test]
    fn norms() {
        let a = Mat::from_rows(&[&[3.0, 0.0], &[0.0, 4.0]]);
        assert!((a.frob_norm() - 5.0).abs() < 1e-12);
        assert_eq!(a.col_norms(), vec![3.0, 4.0]);
        assert_eq!(a.row_norms(), vec![3.0, 4.0]);
    }
}
