//! Thin Householder QR: A (m×n) = Q (m×k) R (k×n), k = min(m, n).
//! Backs the QR baseline codec (rank-r truncation of Q·R).

use super::matrix::Mat;

/// Returns (Q, R) with Q having orthonormal columns and R upper
/// triangular (its first k rows; rows below the diagonal are zero).
pub fn qr_thin(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows, a.cols);
    let k = m.min(n);
    let mut r = a.clone();
    // Householder vectors stored per reflection
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(k);

    for j in 0..k {
        // build the reflector for column j below the diagonal
        let mut norm = 0.0;
        for i in j..m {
            norm += r[(i, j)] * r[(i, j)];
        }
        norm = norm.sqrt();
        let mut v = vec![0.0; m - j];
        if norm > 0.0 {
            let alpha = if r[(j, j)] >= 0.0 { -norm } else { norm };
            v[0] = r[(j, j)] - alpha;
            for i in j + 1..m {
                v[i - j] = r[(i, j)];
            }
            let vnorm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if vnorm > 1e-300 {
                v.iter_mut().for_each(|x| *x /= vnorm);
                // apply H = I - 2vv^T to the trailing block of R
                for c in j..n {
                    let mut dot = 0.0;
                    for i in j..m {
                        dot += v[i - j] * r[(i, c)];
                    }
                    for i in j..m {
                        r[(i, c)] -= 2.0 * v[i - j] * dot;
                    }
                }
            } else {
                v.iter_mut().for_each(|x| *x = 0.0);
            }
        }
        vs.push(v);
    }

    // accumulate Q = H_0 H_1 .. H_{k-1} applied to the thin identity
    let mut q = Mat::zeros(m, k);
    for j in 0..k {
        q[(j, j)] = 1.0;
    }
    for j in (0..k).rev() {
        let v = &vs[j];
        if v.iter().all(|&x| x == 0.0) {
            continue;
        }
        for c in 0..k {
            let mut dot = 0.0;
            for i in j..m {
                dot += v[i - j] * q[(i, c)];
            }
            for i in j..m {
                q[(i, c)] -= 2.0 * v[i - j] * dot;
            }
        }
    }

    // zero strictly-lower part of the thin R (numerical dust)
    let mut r_thin = Mat::zeros(k, n);
    for i in 0..k {
        for c in i..n {
            r_thin[(i, c)] = r[(i, c)];
        }
    }
    (q, r_thin)
}

/// Rank-r approximation via QR truncation: Q[:, :r] @ R[:r, :].
pub fn qr_rank_r(a: &Mat, rank: usize) -> Mat {
    let (q, r) = qr_thin(a);
    let rk = rank.min(q.cols);
    let mut qr_ = Mat::zeros(q.rows, rk);
    for i in 0..q.rows {
        for j in 0..rk {
            qr_[(i, j)] = q[(i, j)];
        }
    }
    let mut rr = Mat::zeros(rk, r.cols);
    for i in 0..rk {
        rr.row_mut(i).copy_from_slice(r.row(i));
    }
    qr_.matmul(&rr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_mat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut m = Mat::zeros(r, c);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    #[test]
    fn reconstructs() {
        for (m, n) in [(6, 4), (4, 6), (8, 8), (48, 96), (1, 5), (5, 1)] {
            let a = rand_mat(m, n, (m * 31 + n) as u64);
            let (q, r) = qr_thin(&a);
            let err = q.matmul(&r).sub(&a).frob_norm() / a.frob_norm().max(1e-12);
            assert!(err < 1e-10, "({m},{n}) err={err}");
        }
    }

    #[test]
    fn q_orthonormal() {
        let a = rand_mat(20, 12, 3);
        let (q, _) = qr_thin(&a);
        let qtq = q.transpose().matmul(&q);
        let err = qtq.sub(&Mat::eye(12)).frob_norm();
        assert!(err < 1e-10, "err={err}");
    }

    #[test]
    fn r_upper_triangular() {
        let a = rand_mat(10, 7, 4);
        let (_, r) = qr_thin(&a);
        for i in 0..r.rows {
            for j in 0..i.min(r.cols) {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn full_rank_truncation_is_exact() {
        let a = rand_mat(9, 5, 6);
        let approx = qr_rank_r(&a, 5);
        assert!(approx.sub(&a).frob_norm() < 1e-10);
    }

    #[test]
    fn truncation_error_decreases_with_rank() {
        let a = rand_mat(24, 16, 7);
        let mut last = f64::MAX;
        for r in [2, 4, 8, 12, 16] {
            let err = qr_rank_r(&a, r).sub(&a).frob_norm();
            assert!(err <= last + 1e-9, "rank={r}");
            last = err;
        }
    }

    #[test]
    fn handles_rank_deficient() {
        // two identical columns
        let mut a = rand_mat(8, 4, 8);
        for i in 0..8 {
            let v = a[(i, 0)];
            a[(i, 1)] = v;
        }
        let (q, r) = qr_thin(&a);
        assert!(q.matmul(&r).sub(&a).frob_norm() < 1e-9);
    }
}
