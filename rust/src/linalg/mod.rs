//! Dense linear-algebra substrate (f64): matrices, Householder QR,
//! one-sided Jacobi SVD.  Powers the QR / FWSVD / ASVD / SVD-LLM
//! baseline codecs — the dependency set has no LAPACK, so the paper's
//! comparison set is built from scratch and oracle-tested.

pub mod matrix;
pub mod qr;
pub mod svd;

pub use matrix::Mat;
pub use qr::qr_thin;
pub use svd::svd_thin;
