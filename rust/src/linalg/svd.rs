//! One-sided Jacobi SVD: A (m×n, m ≥ n internally; transposed
//! otherwise) = U Σ Vᵀ with singular values sorted descending.
//! Backs the FWSVD / ASVD / SVD-LLM baseline codecs.

use super::matrix::Mat;

pub struct Svd {
    pub u: Mat,      // m × k
    pub s: Vec<f64>, // k, descending
    pub vt: Mat,     // k × n
}

/// Thin SVD via one-sided Jacobi rotations on the columns of A.
pub fn svd_thin(a: &Mat) -> Svd {
    if a.rows >= a.cols {
        svd_tall(a)
    } else {
        // A = U S Vt  =>  At = V S Ut
        let t = svd_tall(&a.transpose());
        Svd { u: t.vt.transpose(), s: t.s, vt: t.u.transpose() }
    }
}

fn svd_tall(a: &Mat) -> Svd {
    let (m, n) = (a.rows, a.cols);
    debug_assert!(m >= n);
    // work on columns: w = A (copy), v = I
    let mut w = a.clone();
    let mut v = Mat::eye(n);
    let eps = 1e-12;
    let max_sweeps = 60;

    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // gram entries for the column pair
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..m {
                    let (x, y) = (w[(i, p)], w[(i, q)]);
                    app += x * x;
                    aqq += y * y;
                    apq += x * y;
                }
                if apq.abs() <= eps * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p,q) gram entry
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let (x, y) = (w[(i, p)], w[(i, q)]);
                    w[(i, p)] = c * x - s * y;
                    w[(i, q)] = s * x + c * y;
                }
                for i in 0..n {
                    let (x, y) = (v[(i, p)], v[(i, q)]);
                    v[(i, p)] = c * x - s * y;
                    v[(i, q)] = s * x + c * y;
                }
            }
        }
        if off < eps {
            break;
        }
    }

    // singular values = column norms of w; U = normalized columns
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = (0..n)
        .map(|c| (0..m).map(|i| w[(i, c)] * w[(i, c)]).sum::<f64>().sqrt())
        .collect();
    order.sort_by(|&x, &y| norms[y].partial_cmp(&norms[x]).unwrap());

    let mut u = Mat::zeros(m, n);
    let mut s = vec![0.0; n];
    let mut vt = Mat::zeros(n, n);
    for (new, &old) in order.iter().enumerate() {
        s[new] = norms[old];
        let inv = if norms[old] > 1e-300 { 1.0 / norms[old] } else { 0.0 };
        for i in 0..m {
            u[(i, new)] = w[(i, old)] * inv;
        }
        for i in 0..n {
            vt[(new, i)] = v[(i, old)];
        }
    }
    Svd { u, s, vt }
}

/// Best rank-r approximation from the thin SVD.
pub fn svd_rank_r(a: &Mat, rank: usize) -> Mat {
    let d = svd_thin(a);
    reconstruct_rank_r(&d, rank)
}

pub fn reconstruct_rank_r(d: &Svd, rank: usize) -> Mat {
    let k = rank.min(d.s.len());
    let (m, n) = (d.u.rows, d.vt.cols);
    let mut out = Mat::zeros(m, n);
    for r in 0..k {
        let s = d.s[r];
        for i in 0..m {
            let us = d.u[(i, r)] * s;
            if us == 0.0 {
                continue;
            }
            for j in 0..n {
                out[(i, j)] += us * d.vt[(r, j)];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_mat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut m = Mat::zeros(r, c);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    #[test]
    fn reconstructs_full() {
        for (m, n) in [(8, 5), (5, 8), (12, 12), (30, 20)] {
            let a = rand_mat(m, n, (m + 7 * n) as u64);
            let d = svd_thin(&a);
            let approx = reconstruct_rank_r(&d, m.min(n));
            let err = approx.sub(&a).frob_norm() / a.frob_norm();
            assert!(err < 1e-9, "({m},{n}) err={err}");
        }
    }

    #[test]
    fn singular_values_sorted_nonneg() {
        let a = rand_mat(16, 10, 3);
        let d = svd_thin(&a);
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(d.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn u_v_orthonormal() {
        let a = rand_mat(14, 9, 5);
        let d = svd_thin(&a);
        let utu = d.u.transpose().matmul(&d.u);
        assert!(utu.sub(&Mat::eye(9)).frob_norm() < 1e-9);
        let vvt = d.vt.matmul(&d.vt.transpose());
        assert!(vvt.sub(&Mat::eye(9)).frob_norm() < 1e-9);
    }

    #[test]
    fn known_diagonal() {
        let a = Mat::from_rows(&[&[3.0, 0.0], &[0.0, -2.0], &[0.0, 0.0]]);
        let d = svd_thin(&a);
        assert!((d.s[0] - 3.0).abs() < 1e-10);
        assert!((d.s[1] - 2.0).abs() < 1e-10);
    }

    #[test]
    fn rank_r_is_truncation_optimal_vs_qr() {
        // Eckart-Young: SVD rank-r error <= QR rank-r error
        let a = rand_mat(24, 18, 9);
        for r in [2, 5, 9] {
            let es = svd_rank_r(&a, r).sub(&a).frob_norm();
            let eq = crate::linalg::qr::qr_rank_r(&a, r).sub(&a).frob_norm();
            assert!(es <= eq + 1e-9, "rank {r}: svd {es} qr {eq}");
        }
    }

    #[test]
    fn rank_r_error_equals_tail_energy() {
        let a = rand_mat(20, 12, 11);
        let d = svd_thin(&a);
        for r in [1, 4, 8] {
            let err = reconstruct_rank_r(&d, r).sub(&a).frob_norm();
            let tail: f64 = d.s[r..].iter().map(|s| s * s).sum::<f64>().sqrt();
            assert!((err - tail).abs() < 1e-8, "rank {r}");
        }
    }

    #[test]
    fn low_rank_input_recovered_exactly() {
        let b = rand_mat(16, 3, 13);
        let c = rand_mat(3, 10, 14);
        let a = b.matmul(&c); // rank 3
        let err = svd_rank_r(&a, 3).sub(&a).frob_norm() / a.frob_norm();
        assert!(err < 1e-9);
    }
}
