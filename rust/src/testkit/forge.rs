//! The synthetic artifact forge: miniature models + manifest + goldens
//! from a seed (see the module docs in [`super`]).

use crate::codec::rate::{validate_ladder, LadderPoint};
use crate::codec::{block_ratio, fc_block, rel_error, Codec};
use crate::dsp::complex::C64;
use crate::dsp::fft2d;
use crate::linalg::matrix::Mat;
use crate::linalg::svd::svd_thin;
use crate::model::tokenizer;
use crate::runtime::interp::{self, LayerGeom};
use crate::runtime::ArtifactStore;
use crate::tensor::{io, Tensor};
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Geometry + seed of one forged model.  Mirrors the fields of
/// python/compile/configs.py `ModelConfig` at miniature scale.
#[derive(Debug, Clone)]
pub struct ForgeSpec {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub vocab_size: usize,
    pub max_seq: usize,
    pub rope_theta: f64,
    pub rms_eps: f64,
    pub qkv_bias: bool,
    /// hidden-axis rfft band of the layer-1 residual contributions
    /// (the forge band-limits `tok_emb`, `layers.0.wo`,
    /// `layers.0.w_down` to it, like python `project_l1`)
    pub l1_freq_bins: usize,
    /// hidden-axis widths of each bucket's quality ladder, descending
    /// (first = the primary serving block, used as the fc_block kd
    /// hint).  Every width must cover the layer-1 band
    /// (`kd >= kd_band()`), so *every ladder point reconstructs the
    /// band-limited boundary activation exactly* — lower points cut
    /// wire bytes without moving output tokens, which is what lets
    /// the adaptive serving tests assert bit-identical generations
    /// across points.  The row width ks is shared by all points.
    pub ladder_kds: Vec<usize>,
    pub eval_batch: usize,
    pub eval_seq: usize,
    /// serving sequence buckets (ascending)
    pub seq_buckets: Vec<usize>,
    /// server batch sizes lowered per bucket
    pub server_batches: Vec<usize>,
    /// serving target compression ratio
    pub ratio: f64,
    pub seed: u64,
}

impl ForgeSpec {
    /// The default miniature model: 2 layers, d_model 32, full byte
    /// vocab so the real tokenizer/client drive it unchanged.
    pub fn tiny() -> ForgeSpec {
        ForgeSpec {
            name: "forge-tiny".into(),
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 64,
            vocab_size: tokenizer::VOCAB_SIZE,
            max_seq: 32,
            rope_theta: 10000.0,
            rms_eps: 1e-5,
            qkv_bias: false,
            l1_freq_bins: 4,
            ladder_kds: vec![11, 9, 7],
            eval_batch: 2,
            eval_seq: 16,
            seq_buckets: vec![16, 32],
            server_batches: vec![1, 2],
            ratio: 8.0,
            seed: 0xF0C5,
        }
    }

    /// Qwen-style variant: grouped KV heads + QKV bias, so the
    /// hermetic suite exercises both attention formulations.
    pub fn tiny_gqa() -> ForgeSpec {
        ForgeSpec {
            name: "forge-gqa".into(),
            n_heads: 4,
            n_kv_heads: 2,
            qkv_bias: true,
            seed: 0xF0C6,
            ..ForgeSpec::tiny()
        }
    }

    /// Wide-slack variant for the adaptive rate-control suite: a
    /// narrow layer-1 band (3 centred bins) under a ladder spanning
    /// kd 15 -> 3, so the cheapest point cuts the primary point's
    /// wire bytes ~5x while every point still reconstructs the band
    /// exactly — the byte-win-with-token-parity regime the
    /// adaptive soak test and `benches/adaptive_bench.rs` pin.
    pub fn tiny_adaptive() -> ForgeSpec {
        ForgeSpec {
            name: "forge-adapt".into(),
            l1_freq_bins: 2,
            ladder_kds: vec![15, 7, 3],
            seed: 0xF0C7,
            ..ForgeSpec::tiny()
        }
    }

    /// Long-context variant for the chunked-prefill suite: the
    /// narrow band + wide ladder of [`ForgeSpec::tiny_adaptive`]
    /// under buckets spanning 128 -> 2048 tokens, so a
    /// multi-thousand-token prompt packs to a plane of thousands of
    /// floats (row chunking has something to chunk) while goldens
    /// stay self-consistent and the small bucket keeps the hermetic
    /// tests affordable.
    pub fn tiny_longctx() -> ForgeSpec {
        ForgeSpec {
            name: "forge-longctx".into(),
            l1_freq_bins: 2,
            ladder_kds: vec![31, 15, 7],
            max_seq: 2048,
            seq_buckets: vec![128, 2048],
            seed: 0xF0C8,
            ..ForgeSpec::tiny()
        }
    }

    /// Calibrated hidden-axis block width (`2·bins - 1`, the centred
    /// equivalent of the rfft band).
    pub fn kd_band(&self) -> usize {
        2 * self.l1_freq_bins - 1
    }

    fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    fn geom(&self) -> LayerGeom {
        LayerGeom {
            n_heads: self.n_heads,
            n_kv_heads: self.n_kv_heads,
            rope_theta: self.rope_theta,
            rms_eps: self.rms_eps as f32,
            qkv_bias: self.qkv_bias,
        }
    }

    fn layer_weight_names(&self) -> Vec<&'static str> {
        if self.qkv_bias {
            vec!["ln1", "wq", "wk", "wv", "bq", "bk", "bv", "wo", "ln2",
                 "w_gate", "w_up", "w_down"]
        } else {
            vec!["ln1", "wq", "wk", "wv", "wo", "ln2", "w_gate", "w_up",
                 "w_down"]
        }
    }

    fn validate(&self) -> Result<()> {
        ensure!(self.n_heads >= 1 && self.d_model % self.n_heads == 0,
                "{}: d_model {} not divisible by n_heads {}", self.name,
                self.d_model, self.n_heads);
        ensure!(self.head_dim() % 2 == 0,
                "{}: head_dim must be even for RoPE", self.name);
        ensure!(self.n_kv_heads >= 1 && self.n_heads % self.n_kv_heads == 0,
                "{}: n_heads {} not divisible by n_kv_heads {}", self.name,
                self.n_heads, self.n_kv_heads);
        ensure!(self.n_layers >= 2,
                "{}: split serving needs >= 2 layers", self.name);
        ensure!(!self.seq_buckets.is_empty() && !self.server_batches.is_empty(),
                "{}: empty bucket/batch lists", self.name);
        ensure!(self.eval_seq <= self.max_seq, "{}: eval_seq > max_seq",
                self.name);
        ensure!(self.eval_batch >= 1, "{}: eval_batch must be >= 1", self.name);
        ensure!(!self.ladder_kds.is_empty(), "{}: empty ladder_kds",
                self.name);
        for (i, &kd) in self.ladder_kds.iter().enumerate() {
            ensure!(crate::codec::valid_block_axis(self.d_model, kd),
                    "{}: ladder kd {kd} invalid for d_model {}", self.name,
                    self.d_model);
            ensure!(kd >= self.kd_band(),
                    "{}: ladder kd {kd} narrower than the layer-1 band {} — \
                     lower points would lose band content and break the \
                     cross-point token-parity contract", self.name,
                    self.kd_band());
            if i > 0 {
                ensure!(kd <= self.ladder_kds[i - 1],
                        "{}: ladder_kds must be non-increasing", self.name);
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// weights
// ---------------------------------------------------------------------------

fn normal_tensor(rng: &mut Rng, shape: Vec<usize>, scale: f32) -> Tensor {
    let n: usize = shape.iter().product();
    let mut v = vec![0.0f32; n];
    rng.fill_normal_f32(&mut v, scale);
    Tensor::f32(shape, v)
}

/// Project every row of a `[·, cols]` tensor onto the lowest `bins`
/// rfft bins of the last axis (python `lowpass_last`): the layer-1
/// spectral bottleneck that makes the boundary activation genuinely
/// band-limited, as the paper measures on real LLMs.
fn lowpass_rows(t: &mut Tensor, bins: usize) {
    let cols = *t.shape.last().expect("lowpass on scalar");
    if 2 * bins >= cols + 1 {
        return; // band covers the whole axis
    }
    let plan = fft2d::plan(cols);
    let mut buf = vec![C64::ZERO; cols];
    for row in t.as_f32_mut().chunks_mut(cols) {
        for (b, &v) in buf.iter_mut().zip(row.iter()) {
            *b = C64::from_re(v as f64);
        }
        plan.forward_in_place(&mut buf);
        for (u, b) in buf.iter_mut().enumerate() {
            if u.min(cols - u) >= bins {
                *b = C64::ZERO;
            }
        }
        plan.inverse_in_place(&mut buf);
        for (v, b) in row.iter_mut().zip(&buf) {
            *v = b.re as f32;
        }
    }
}

/// Deterministic scaled-normal init, canonical names (`tok_emb`,
/// `layers.{i}.{w}`, `final_norm`, `lm_head`), with the layer-1
/// residual contributions band-limited to `l1_freq_bins`.
pub fn init_weights(spec: &ForgeSpec) -> BTreeMap<String, Tensor> {
    let mut rng = Rng::new(spec.seed);
    let (d, f, v) = (spec.d_model, spec.d_ff, spec.vocab_size);
    let kv = spec.kv_dim();
    let inv_d = 1.0 / (d as f32).sqrt();
    let out_scale = 1.0 / (2.0 * spec.n_layers as f32).sqrt();

    let mut w = BTreeMap::new();
    let mut tok_emb = normal_tensor(&mut rng, vec![v, d], 0.02);
    lowpass_rows(&mut tok_emb, spec.l1_freq_bins);
    w.insert("tok_emb".to_string(), tok_emb);
    w.insert("final_norm".to_string(), Tensor::f32(vec![d], vec![1.0; d]));
    w.insert("lm_head".to_string(), normal_tensor(&mut rng, vec![d, v], inv_d));

    for i in 0..spec.n_layers {
        let p = format!("layers.{i}.");
        w.insert(p.clone() + "ln1", Tensor::f32(vec![d], vec![1.0; d]));
        w.insert(p.clone() + "wq", normal_tensor(&mut rng, vec![d, d], inv_d));
        w.insert(p.clone() + "wk", normal_tensor(&mut rng, vec![d, kv], inv_d));
        w.insert(p.clone() + "wv", normal_tensor(&mut rng, vec![d, kv], inv_d));
        if spec.qkv_bias {
            w.insert(p.clone() + "bq", normal_tensor(&mut rng, vec![d], 0.05));
            w.insert(p.clone() + "bk", normal_tensor(&mut rng, vec![kv], 0.05));
            w.insert(p.clone() + "bv", normal_tensor(&mut rng, vec![kv], 0.05));
        }
        let mut wo = normal_tensor(&mut rng, vec![d, d], out_scale * inv_d);
        w.insert(p.clone() + "ln2", Tensor::f32(vec![d], vec![1.0; d]));
        let w_gate = normal_tensor(&mut rng, vec![d, f], inv_d);
        let w_up = normal_tensor(&mut rng, vec![d, f], inv_d);
        let mut w_down =
            normal_tensor(&mut rng, vec![f, d], out_scale / (f as f32).sqrt());
        if i == 0 {
            // layer-1 boundary band-limit (python L1_PROJECTED)
            lowpass_rows(&mut wo, spec.l1_freq_bins);
            lowpass_rows(&mut w_down, spec.l1_freq_bins);
        }
        w.insert(p.clone() + "wo", wo);
        w.insert(p.clone() + "w_gate", w_gate);
        w.insert(p.clone() + "w_up", w_up);
        w.insert(p + "w_down", w_down);
    }
    w
}

// ---------------------------------------------------------------------------
// quality ladders + forged Parseval bounds
// ---------------------------------------------------------------------------

/// A deterministic reference activation from the family the forged
/// models produce at the layer-1 boundary: seeded normal rows,
/// band-limited to `bins` rfft bins on the hidden axis.  The forged
/// error bounds are measured on this family and the property suite
/// re-checks them against fresh samples from it.
pub fn band_limited_act(rows: usize, cols: usize, bins: usize, seed: u64)
    -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut t = normal_tensor(&mut rng, vec![rows, cols], 1.0);
    lowpass_rows(&mut t, bins);
    t.as_f32().to_vec()
}

/// Forged Parseval error bound for ladder point (ks, kd) of a bucket
/// whose primary block is (ks0, kd0): the worst *additional* relative
/// reconstruction error the point introduces over the primary block —
/// `rel_error(recon_primary, recon_point)` — on a small seeded
/// ensemble of [`band_limited_act`] samples, with 1.5x headroom and a
/// 1e-3 floor (the primary point itself forges the floor).  By
/// Parseval this is exactly the energy fraction of the
/// primary-minus-point frequency set, so the ensemble maximum
/// concentrates tightly and hundreds of fresh samples stay under the
/// bound (`tests/properties.rs` pins this).  It is the quantity the
/// rate controller budgets: what adaptivity may sacrifice relative to
/// the paper's fixed block, not the fixed block's own truncation
/// error.
pub fn forged_err_bound(rows: usize, cols: usize, bins: usize,
                        ks0: usize, kd0: usize, ks: usize, kd: usize)
    -> Result<f64> {
    let codec = crate::codec::fourier::FourierCodec::default();
    let mut worst = 0.0f64;
    for s in 0..4u64 {
        let seed = 0xB0_0D ^ (s * 7919)
            ^ ((rows as u64) << 17)
            ^ ((cols as u64) << 5);
        let a = band_limited_act(rows, cols, bins, seed);
        let r0 = codec
            .decompress(&codec.compress_block(&a, rows, cols, ks0, kd0)?)?;
        let ri = codec
            .decompress(&codec.compress_block(&a, rows, cols, ks, kd)?)?;
        worst = worst.max(rel_error(&r0, &ri));
    }
    Ok((worst * 1.5 + 1e-3).min(1.0))
}

/// The (ks, kd) quality ladder forged for one serving bucket: ks is
/// the paper's fixed-block row width at `ratio` (hinted by the
/// primary kd), kd sweeps `ladder_kds`, and each point carries its
/// forged Parseval bound (made monotone by construction, as
/// `codec::rate` requires).  Shared by the serving manifest, the
/// property suite, and the benches so there is exactly one source of
/// ladder truth.
pub fn bucket_ladder(bucket: usize, d_model: usize, bins: usize,
                     ladder_kds: &[usize], ratio: f64)
    -> Result<Vec<LadderPoint>> {
    ensure!(!ladder_kds.is_empty(), "empty ladder_kds");
    let (ks, kd0) = fc_block(bucket, d_model, ratio, Some(ladder_kds[0]));
    ensure!(kd0 == ladder_kds[0],
            "primary kd hint {} not honoured (got {kd0})", ladder_kds[0]);
    let mut out = Vec::with_capacity(ladder_kds.len());
    let mut floor = 0.0f64;
    for &kd in ladder_kds {
        let e = forged_err_bound(bucket, d_model, bins, ks, kd0, ks, kd)?;
        floor = floor.max(e);
        out.push(LadderPoint { ks, kd, err_bound: floor });
    }
    validate_ladder(&out)?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// reference codecs for the goldens
// ---------------------------------------------------------------------------

/// Stable top-k (|v| desc, index asc tie-break) — the naive reference
/// the optimised `codec::topk` sort must agree with.
pub fn naive_topk(a: &[f32], k: usize) -> Vec<f32> {
    let mut idx: Vec<usize> = (0..a.len()).collect();
    idx.sort_by(|&x, &y| {
        a[y].abs()
            .partial_cmp(&a[x].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(x.cmp(&y))
    });
    let mut out = vec![0.0f32; a.len()];
    for &i in idx.iter().take(k.min(a.len())) {
        out[i] = a[i];
    }
    out
}

/// Rank-`r` reconstruction straight from the Jacobi SVD (no payload
/// round-trip) — the reference for the SVD codec fixtures.
pub fn svd_rank_r(a: &[f32], rows: usize, cols: usize, r: usize) -> Vec<f32> {
    let svd = svd_thin(&Mat::from_f32(a, rows, cols));
    let r = r.min(svd.s.len());
    let mut out = vec![0.0f32; rows * cols];
    for i in 0..rows {
        for j in 0..cols {
            let mut acc = 0.0f64;
            for t in 0..r {
                acc += svd.u[(i, t)] * svd.s[t] * svd.vt[(t, j)];
            }
            out[i * cols + j] = acc as f32;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// goldens
// ---------------------------------------------------------------------------

fn layer_args(w: &BTreeMap<String, Tensor>, spec: &ForgeSpec, i: usize)
    -> Vec<Tensor> {
    spec.layer_weight_names()
        .iter()
        .map(|n| w[&format!("layers.{i}.{n}")].clone())
        .collect()
}

/// Golden vectors with the same tensor names the python AOT pipeline
/// dumps, computed with the reference interpreter + naive codec
/// references (see the module docs for why this is not circular).
fn build_goldens(spec: &ForgeSpec, w: &BTreeMap<String, Tensor>)
    -> Result<BTreeMap<String, Tensor>> {
    let (b, s, d) = (spec.eval_batch, spec.eval_seq, spec.d_model);
    let geom = spec.geom();
    let eps = spec.rms_eps as f32;

    // deterministic fact-world-style prompts, one per golden batch
    // row (the golden batch matches the manifest's eval_batch so the
    // parity tests compare every lane), padded/truncated to S
    let mut toks = Vec::with_capacity(b * s);
    for i in 0..b {
        let p = format!("Q mira hue {i} ? A blue .");
        toks.extend(tokenizer::pad_to(&tokenizer::encode_prompt(&p), s));
    }
    let tokens = Tensor::i32(vec![b, s], toks);

    // full forward + per-layer activations
    let mut h = interp::embed(&tokens, &w["tok_emb"])?;
    let mut acts = Vec::with_capacity(spec.n_layers);
    for i in 0..spec.n_layers {
        h = interp::layer_forward(&geom, &h, &layer_args(w, spec, i))?;
        acts.push(h.clone());
    }
    let logits_full =
        interp::head_forward(&h, &w["final_norm"], &w["lm_head"], eps)?;

    // split-1 + FC block at the golden ratio (8.0, python build_goldens)
    let (ks, kd) = fc_block(s, d, 8.0, Some(spec.kd_band()));
    let mut hs = acts[0].clone();
    {
        let data = hs.as_f32_mut();
        for e in 0..b {
            let a = data[e * s * d..(e + 1) * s * d].to_vec();
            let (re, im) = interp::fc_compress_naive(&a, s, d, ks, kd);
            let recon = interp::fc_decompress_naive(&re, &im, s, d, ks, kd);
            data[e * s * d..(e + 1) * s * d].copy_from_slice(&recon);
        }
    }
    for i in 1..spec.n_layers {
        hs = interp::layer_forward(&geom, &hs, &layer_args(w, spec, i))?;
    }
    let logits_split =
        interp::head_forward(&hs, &w["final_norm"], &w["lm_head"], eps)?;

    // codec fixtures on the first element's layer-1 activation
    let a: Vec<f32> = acts[0].as_f32()[..s * d].to_vec();
    let (re, im) = interp::fc_compress_naive(&a, s, d, ks, kd);
    let recon = interp::fc_decompress_naive(&re, &im, s, d, ks, kd);
    let k = a.len() / 16;

    let mut g = BTreeMap::new();
    g.insert("tokens".to_string(), tokens);
    g.insert("ks_kd".to_string(),
             Tensor::i32(vec![2], vec![ks as i32, kd as i32]));
    g.insert("logits_full".to_string(), logits_full);
    g.insert("logits_split1_fc8".to_string(), logits_split);
    g.insert("act_layer1".to_string(), acts[0].clone());
    g.insert("codec_a".to_string(), Tensor::f32(vec![s, d], a.clone()));
    g.insert("codec_re".to_string(), Tensor::f32(vec![ks, kd], re));
    g.insert("codec_im".to_string(), Tensor::f32(vec![ks, kd], im));
    g.insert("codec_recon".to_string(), Tensor::f32(vec![s, d], recon));
    g.insert("topk_recon".to_string(),
             Tensor::f32(vec![s, d], naive_topk(&a, k)));
    g.insert("svd_r4_recon".to_string(),
             Tensor::f32(vec![s, d], svd_rank_r(&a, s, d, 4)));
    Ok(g)
}

// ---------------------------------------------------------------------------
// manifest
// ---------------------------------------------------------------------------

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn st(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn layer_spec(op: &str, spec: &ForgeSpec) -> Json {
    let mut j = Json::obj();
    j.set("op", st(op));
    j.set("n_heads", num(spec.n_heads as f64));
    j.set("n_kv_heads", num(spec.n_kv_heads as f64));
    j.set("rope_theta", num(spec.rope_theta));
    j.set("rms_eps", num(spec.rms_eps));
    j.set("qkv_bias", Json::Bool(spec.qkv_bias));
    j
}

fn model_manifest(spec: &ForgeSpec, n_params: usize, interp_map: &mut Json)
    -> Json {
    let embed_name = format!("{}_embed.interp", spec.name);
    let layer_name = format!("{}_layer.interp", spec.name);
    let head_name = format!("{}_head.interp", spec.name);

    let mut espec = Json::obj();
    espec.set("op", st("embed"));
    interp_map.set(&embed_name, espec);
    interp_map.set(&layer_name, layer_spec("layer", spec));
    let mut hspec = Json::obj();
    hspec.set("op", st("head"));
    hspec.set("rms_eps", num(spec.rms_eps));
    interp_map.set(&head_name, hspec);

    let mut arts = Json::obj();
    for (key, name) in [("embed", &embed_name), ("layer", &layer_name),
                        ("head", &head_name)] {
        let mut a = Json::obj();
        a.set("path", st(name));
        arts.set(key, a);
    }

    let mut m = Json::obj();
    m.set("name", st(&spec.name));
    m.set("d_model", num(spec.d_model as f64));
    m.set("n_layers", num(spec.n_layers as f64));
    m.set("n_heads", num(spec.n_heads as f64));
    m.set("n_kv_heads", num(spec.n_kv_heads as f64));
    m.set("d_ff", num(spec.d_ff as f64));
    m.set("vocab_size", num(spec.vocab_size as f64));
    m.set("max_seq", num(spec.max_seq as f64));
    m.set("rope_theta", num(spec.rope_theta));
    m.set("rms_eps", num(spec.rms_eps));
    m.set("qkv_bias", Json::Bool(spec.qkv_bias));
    m.set("l1_freq_bins", num(spec.l1_freq_bins as f64));
    m.set("n_params", num(n_params as f64));
    m.set("weights", st(&format!("weights/{}.fcw", spec.name)));
    m.set("golden", st(&format!("golden/{}.golden.fcw", spec.name)));
    m.set("eval_batch", num(spec.eval_batch as f64));
    m.set("eval_seq", num(spec.eval_seq as f64));
    m.set("artifacts", arts);
    m.set("layer_weight_names",
          Json::Arr(spec.layer_weight_names().iter().map(|n| st(n)).collect()));
    m
}

fn serving_manifest(spec: &ForgeSpec, interp_map: &mut Json) -> Result<Json> {
    let d = spec.d_model;
    let mut buckets = Json::obj();
    for &bucket in &spec.seq_buckets {
        let ladder = bucket_ladder(bucket, d, spec.l1_freq_bins,
                                   &spec.ladder_kds, spec.ratio)?;
        let (ks, kd) = (ladder[0].ks, ladder[0].kd);
        let client_name = format!("{}_client_s{bucket}.interp", spec.name);
        let mut cspec = layer_spec("client_fused", spec);
        cspec.set("ks", num(ks as f64));
        cspec.set("kd", num(kd as f64));
        interp_map.set(&client_name, cspec);

        let mut client = Json::obj();
        client.set("path", st(&client_name));

        let mut servers = Json::obj();
        for &bsz in &spec.server_batches {
            let server_name =
                format!("{}_server_s{bucket}_b{bsz}.interp", spec.name);
            let mut sspec = layer_spec("server_fused", spec);
            sspec.set("seq", num(bucket as f64));
            interp_map.set(&server_name, sspec);
            let mut sj = Json::obj();
            sj.set("path", st(&server_name));
            servers.set(&bsz.to_string(), sj);
        }

        let mut bj = Json::obj();
        bj.set("ks", num(ks as f64));
        bj.set("kd", num(kd as f64));
        bj.set("achieved_ratio", num(block_ratio(bucket, d, ks, kd)));
        let mut lj = Vec::with_capacity(ladder.len());
        for p in &ladder {
            let mut pj = Json::obj();
            pj.set("ks", num(p.ks as f64));
            pj.set("kd", num(p.kd as f64));
            pj.set("err_bound", num(p.err_bound));
            lj.push(pj);
        }
        bj.set("ladder", Json::Arr(lj));
        bj.set("client", client);
        bj.set("server", servers);
        buckets.set(&bucket.to_string(), bj);
    }
    let mut serving = Json::obj();
    serving.set("model", st(&spec.name));
    serving.set("ratio", num(spec.ratio));
    serving.set("buckets", buckets);
    Ok(serving)
}

fn codec_hw_manifest(spec: &ForgeSpec, interp_map: &mut Json) -> Json {
    let (s, d) = (spec.eval_seq, spec.d_model);
    let (ks, kd) = fc_block(s, d, spec.ratio, None);
    let comp_name = format!("fc_compress_{s}x{d}.interp");
    let deco_name = format!("fc_decompress_{s}x{d}.interp");
    let mut cspec = Json::obj();
    cspec.set("op", st("fc_compress"));
    cspec.set("ks", num(ks as f64));
    cspec.set("kd", num(kd as f64));
    interp_map.set(&comp_name, cspec);
    let mut dspec = Json::obj();
    dspec.set("op", st("fc_decompress"));
    dspec.set("seq", num(s as f64));
    dspec.set("hidden", num(d as f64));
    interp_map.set(&deco_name, dspec);

    let mut e = Json::obj();
    e.set("seq", num(s as f64));
    e.set("hidden", num(d as f64));
    e.set("ks", num(ks as f64));
    e.set("kd", num(kd as f64));
    e.set("achieved_ratio", num(block_ratio(s, d, ks, kd)));
    e.set("compress", st(&comp_name));
    e.set("decompress", st(&deco_name));
    let mut hw = Json::obj();
    hw.set("ratio", num(spec.ratio));
    hw.set("entries", Json::Arr(vec![e]));
    hw
}

// ---------------------------------------------------------------------------
// tree assembly
// ---------------------------------------------------------------------------

/// Forge a complete artifact tree at `root`: weights + goldens for
/// every spec, serving/codec_hw sections for `serving_model`, and the
/// `interp` spec table.  Overwrites files, never deletes.
pub fn forge_tree(root: impl AsRef<Path>, specs: &[ForgeSpec],
                  serving_model: &str) -> Result<()> {
    let root = root.as_ref();
    ensure!(!specs.is_empty(), "forge_tree: no specs");
    let serving_spec = specs
        .iter()
        .find(|s| s.name == serving_model)
        .with_context(|| format!("serving model '{serving_model}' not among \
                                  forged specs"))?;
    for sub in ["weights", "golden"] {
        std::fs::create_dir_all(root.join(sub))
            .with_context(|| format!("creating {}/{sub}", root.display()))?;
    }

    let mut interp_map = Json::obj();
    let mut models = Json::obj();
    for spec in specs {
        spec.validate()?;
        let w = init_weights(spec);
        let n_params: usize = w.values().map(|t| t.len()).sum();
        io::write_fcw(root.join(format!("weights/{}.fcw", spec.name)), &w)?;
        let g = build_goldens(spec, &w)?;
        io::write_fcw(root.join(format!("golden/{}.golden.fcw", spec.name)),
                      &g)?;
        models.set(&spec.name, model_manifest(spec, n_params, &mut interp_map));
    }

    let serving = serving_manifest(serving_spec, &mut interp_map)?;
    let codec_hw = codec_hw_manifest(serving_spec, &mut interp_map);

    let mut vocab = Json::obj();
    vocab.set("size", num(tokenizer::VOCAB_SIZE as f64));
    vocab.set("bos", num(tokenizer::BOS as f64));
    vocab.set("eos", num(tokenizer::EOS as f64));
    vocab.set("pad", num(tokenizer::PAD as f64));

    let mut manifest = Json::obj();
    manifest.set("forged", Json::Bool(true));
    manifest.set("vocab", vocab);
    manifest.set("seq_buckets",
                 Json::Arr(serving_spec.seq_buckets.iter()
                           .map(|&b| num(b as f64)).collect()));
    manifest.set("models", models);
    manifest.set("serving", serving);
    manifest.set("codec_hw", codec_hw);
    manifest.set("interp", interp_map);

    std::fs::write(root.join("manifest.json"), manifest.to_string_pretty())
        .with_context(|| format!("writing {}/manifest.json", root.display()))?;
    Ok(())
}

/// A per-test scratch root under the system temp dir — unique per
/// (process, tag) so parallel tests never collide.
pub fn forge_root(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fc_forge_{}_{tag}", std::process::id()))
}

/// Forge the default tree (tiny + tiny-gqa, serving = tiny) into a
/// fresh per-test scratch dir and open it as an [`ArtifactStore`].
pub fn forged_store(tag: &str) -> Result<ArtifactStore> {
    forged_store_with(tag, &[ForgeSpec::tiny(), ForgeSpec::tiny_gqa()],
                      "forge-tiny")
}

/// Forge the long-context tree (serving = tiny-longctx) into a fresh
/// per-test scratch dir and open it — the chunked-prefill scenario
/// store.
pub fn forged_longctx_store(tag: &str) -> Result<ArtifactStore> {
    forged_store_with(tag, &[ForgeSpec::tiny_longctx()], "forge-longctx")
}

/// Forge a custom tree into a fresh per-test scratch dir and open it.
pub fn forged_store_with(tag: &str, specs: &[ForgeSpec], serving_model: &str)
    -> Result<ArtifactStore> {
    let root = forge_root(tag);
    let _ = std::fs::remove_dir_all(&root);
    forge_tree(&root, specs, serving_model)?;
    ArtifactStore::open(root)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::rel_error;

    #[test]
    fn weights_are_deterministic_and_bandlimited() {
        let spec = ForgeSpec::tiny();
        let w1 = init_weights(&spec);
        let w2 = init_weights(&spec);
        assert_eq!(w1, w2);
        assert_eq!(w1["tok_emb"].shape,
                   vec![spec.vocab_size, spec.d_model]);
        // a band-limited row must be exactly recoverable from its
        // lowest kd_band() centred bins
        let d = spec.d_model;
        let row: Vec<f32> = w1["tok_emb"].as_f32()[..d].to_vec();
        let (re, im) =
            crate::runtime::interp::fc_compress_naive(&row, 1, d, 1,
                                                      spec.kd_band());
        let back = crate::runtime::interp::fc_decompress_naive(
            &re, &im, 1, d, 1, spec.kd_band());
        assert!(rel_error(&row, &back) < 1e-5, "tok_emb row not band-limited");
    }

    #[test]
    fn naive_topk_matches_codec() {
        let a = crate::codec::rand_act(8, 16, 3);
        let k = a.len() / 16;
        use crate::codec::Codec;
        let codec = crate::codec::topk::TopkCodec;
        let p = codec
            .compress(&a, 8, 16, a.len() as f64 / (2.0 * k as f64))
            .unwrap();
        let got = codec.decompress(&p).unwrap();
        assert_eq!(got, naive_topk(&a, k));
    }

    #[test]
    fn svd_rank_r_reduces_error_with_rank() {
        let a = crate::codec::rand_act(12, 8, 5);
        let e2 = rel_error(&a, &svd_rank_r(&a, 12, 8, 2));
        let e4 = rel_error(&a, &svd_rank_r(&a, 12, 8, 4));
        let e8 = rel_error(&a, &svd_rank_r(&a, 12, 8, 8));
        assert!(e4 <= e2 + 1e-9);
        assert!(e8 <= e4 + 1e-9);
        assert!(rel_error(&a, &svd_rank_r(&a, 12, 8, 12)) < 1e-5);
    }

    #[test]
    fn forged_ladders_are_valid_band_covering_and_bound_respecting() {
        use crate::codec::fourier::FourierCodec;
        for spec in [ForgeSpec::tiny(), ForgeSpec::tiny_adaptive()] {
            spec.validate().unwrap();
            for &bucket in &spec.seq_buckets {
                let l = bucket_ladder(bucket, spec.d_model, spec.l1_freq_bins,
                                      &spec.ladder_kds, spec.ratio).unwrap();
                assert_eq!(l.len(), spec.ladder_kds.len(), "{}", spec.name);
                assert!(l.iter().all(|p| p.ks == l[0].ks),
                        "{}: ladder points must share ks", spec.name);
                // deterministic re-forge: the manifest's bounds are
                // reproducible
                let l2 = bucket_ladder(bucket, spec.d_model,
                                       spec.l1_freq_bins, &spec.ladder_kds,
                                       spec.ratio).unwrap();
                assert_eq!(l, l2);
                // a fresh band-limited sample: every point's extra
                // reconstruction error over the primary block stays
                // within its forged bound
                let a = band_limited_act(bucket, spec.d_model,
                                         spec.l1_freq_bins, 0xFEED);
                let codec = FourierCodec::default();
                let r0 = codec
                    .decompress(&codec.compress_block(&a, bucket,
                                                      spec.d_model, l[0].ks,
                                                      l[0].kd).unwrap())
                    .unwrap();
                for p in &l {
                    let rec = codec
                        .decompress(&codec.compress_block(&a, bucket,
                                                          spec.d_model, p.ks,
                                                          p.kd).unwrap())
                        .unwrap();
                    let err = rel_error(&r0, &rec);
                    assert!(err <= p.err_bound + 1e-9,
                            "{} bucket {bucket} {}x{}: err {err} > bound {}",
                            spec.name, p.ks, p.kd, p.err_bound);
                }
            }
        }
    }

    #[test]
    fn longctx_spec_validates_and_its_ladders_forge() {
        let spec = ForgeSpec::tiny_longctx();
        spec.validate().unwrap();
        assert_eq!(spec.kd_band(), 3);
        assert_eq!(spec.seq_buckets, vec![128, 2048]);
        let l = bucket_ladder(2048, spec.d_model, spec.l1_freq_bins,
                              &spec.ladder_kds, spec.ratio).unwrap();
        assert_eq!(l.len(), 3);
        // the prompt plane at the primary point must be thousands of
        // floats with a dominating row axis, or chunking the prompt
        // dimension has nothing to win
        assert!(l[0].ks * l[0].kd > 4_000,
                "primary plane too small: {}x{}", l[0].ks, l[0].kd);
        assert!(l[0].ks > 64, "row axis must dominate: ks {}", l[0].ks);
        // every point covers the band, so prefill chunks at any rung
        // keep the cross-point token-parity contract
        assert!(l.iter().all(|p| p.kd >= spec.kd_band()));
    }

    #[test]
    fn forged_tree_opens_and_serves_interp_executables() {
        let store = forged_store("forge_unit").unwrap();
        assert!(store.manifest.get("forged").is_some());
        let names = store.model_names();
        assert!(names.contains(&"forge-tiny".to_string()));
        assert!(names.contains(&"forge-gqa".to_string()));
        let meta = store.model_meta("forge-tiny").unwrap();
        let embed = meta.path("artifacts.embed.path").unwrap()
            .as_str().unwrap().to_string();
        let exe = store.get(&embed).unwrap();
        assert!(exe.is_interpreted());
        assert_eq!(store.cached_count(), 1);
        // unknown artifacts still produce the actionable stub error
        assert!(store.get("no_such_artifact.hlo").is_err());
    }
}
