//! Hermetic test kit: the synthetic artifact forge.
//!
//! `testkit::forge` deterministically generates miniature models
//! (weights in `.fcw`, manifest, serving bucket geometry, golden
//! vectors) entirely from [`crate::util::rng`] — no python, no XLA, no
//! wall clock — and writes them as a complete artifact tree that
//! [`crate::runtime::ArtifactStore`] opens exactly like a
//! python-built one.  The artifacts carry `interp` specs instead of
//! HLO files, so the store transparently serves
//! [`crate::runtime::interp`] reference-interpreter executables and
//! the full split-inference stack (device client → TCP → batcher →
//! CodecEngine → fused server graph) runs from a bare `cargo test`.
//!
//! ## Determinism contract
//!
//! Forging the same [`ForgeSpec`] twice produces **byte-identical**
//! trees: every weight and golden is derived from `ForgeSpec::seed`
//! through the deterministic xoshiro RNG, iteration orders are fixed
//! (`BTreeMap`, explicit name lists), and nothing reads the clock or
//! the environment.  `tests/hermetic_serving.rs::forge_is_deterministic`
//! pins this down.  Goldens are *self-consistent*: they are computed
//! with the same reference interpreter the runtime executes, plus
//! naive full-FFT / stable-top-k / direct-SVD references for the codec
//! fixtures — so golden-parity asserts cross-implementation agreement
//! (optimised codec vs naive transform), not just replay.

pub mod forge;

pub use forge::{band_limited_act, bucket_ladder, forge_tree, forged_err_bound,
                forged_longctx_store, forged_store, forged_store_with,
                naive_topk, svd_rank_r, ForgeSpec};
