//! 2-D FFT over row-major matrices: rows then columns, with a plan
//! cache keyed by axis length.  Column passes gather into a scratch
//! buffer to keep the butterflies on contiguous memory (measurably
//! faster than strided access on this substrate — see EXPERIMENTS.md
//! §Perf).
//!
//! The process-wide plan cache is the slow tier: steady-state request
//! paths go through a [`crate::codec::CodecEngine`], which holds its
//! own lock-free per-engine plan map and only falls back here on the
//! first sighting of a new axis length.  The shared tier itself uses
//! an `RwLock` so the common hit path is a read lock + `Arc` clone —
//! server workers no longer serialise on a `Mutex` per transform.

use super::complex::C64;
use super::fft::FftPlan;
use super::rfft::RfftPlan;
use crate::tensor::MatView;
use std::collections::HashMap;
use std::sync::{Arc, OnceLock, RwLock};

fn plan_cache() -> &'static RwLock<HashMap<usize, Arc<FftPlan>>> {
    static CACHE: OnceLock<RwLock<HashMap<usize, Arc<FftPlan>>>> = OnceLock::new();
    CACHE.get_or_init(|| RwLock::new(HashMap::new()))
}

/// Shared-tier plan lookup: read-locked fast path, write lock only on
/// a miss (double-checked so a racing fill stays consistent).
pub fn plan(n: usize) -> Arc<FftPlan> {
    if let Some(p) = plan_cache().read().unwrap().get(&n) {
        return p.clone();
    }
    let mut cache = plan_cache().write().unwrap();
    cache.entry(n).or_insert_with(|| Arc::new(FftPlan::new(n))).clone()
}

fn rplan_cache() -> &'static RwLock<HashMap<usize, Arc<RfftPlan>>> {
    static CACHE: OnceLock<RwLock<HashMap<usize, Arc<RfftPlan>>>> = OnceLock::new();
    CACHE.get_or_init(|| RwLock::new(HashMap::new()))
}

/// Shared-tier real-FFT plan lookup (same discipline as [`plan`]).
/// An even-length [`RfftPlan`] holds an `Arc` to the half-length
/// complex plan from the same cache, so the tables are shared.
pub fn rplan(n: usize) -> Arc<RfftPlan> {
    if let Some(p) = rplan_cache().read().unwrap().get(&n) {
        return p.clone();
    }
    let mut cache = rplan_cache().write().unwrap();
    cache.entry(n).or_insert_with(|| Arc::new(RfftPlan::new(n))).clone()
}

fn pass_rows(data: &mut [C64], rows: usize, cols: usize, inverse: bool) {
    let p = plan(cols);
    for r in 0..rows {
        let row = &mut data[r * cols..(r + 1) * cols];
        if inverse {
            p.inverse_in_place(row);
        } else {
            p.forward_in_place(row);
        }
    }
}

fn pass_cols(data: &mut [C64], rows: usize, cols: usize, inverse: bool) {
    let p = plan(rows);
    let mut col = vec![C64::ZERO; rows];
    for c in 0..cols {
        for r in 0..rows {
            col[r] = data[r * cols + c];
        }
        if inverse {
            p.inverse_in_place(&mut col);
        } else {
            p.forward_in_place(&mut col);
        }
        for r in 0..rows {
            data[r * cols + c] = col[r];
        }
    }
}

/// In-place 2-D forward FFT of a row-major `rows x cols` matrix.
pub fn fft2(data: &mut [C64], rows: usize, cols: usize) {
    assert_eq!(data.len(), rows * cols);
    pass_rows(data, rows, cols, false);
    pass_cols(data, rows, cols, false);
}

/// In-place 2-D inverse FFT (normalised by 1/(rows*cols)).
pub fn ifft2(data: &mut [C64], rows: usize, cols: usize) {
    assert_eq!(data.len(), rows * cols);
    pass_rows(data, rows, cols, true);
    pass_cols(data, rows, cols, true);
}

/// Forward 2-D FFT of a real f32 matrix into a fresh complex buffer.
pub fn fft2_real(a: MatView<'_>) -> Vec<C64> {
    let mut buf: Vec<C64> =
        a.as_slice().iter().map(|&v| C64::from_re(v as f64)).collect();
    fft2(&mut buf, a.rows(), a.cols());
    buf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::f64::consts::PI;

    fn dft2_direct(a: &[C64], rows: usize, cols: usize) -> Vec<C64> {
        let mut out = vec![C64::ZERO; rows * cols];
        for u in 0..rows {
            for v in 0..cols {
                let mut acc = C64::ZERO;
                for s in 0..rows {
                    for d in 0..cols {
                        let ang = -2.0 * PI
                            * (u as f64 * s as f64 / rows as f64
                                + v as f64 * d as f64 / cols as f64);
                        acc += a[s * cols + d] * C64::cis(ang);
                    }
                }
                out[u * cols + v] = acc;
            }
        }
        out
    }

    #[test]
    fn matches_direct_2d() {
        for (r, c) in [(4, 8), (6, 10), (5, 7), (16, 12)] {
            let mut rng = Rng::new((r * c) as u64);
            let a: Vec<C64> =
                (0..r * c).map(|_| C64::new(rng.normal(), rng.normal())).collect();
            let mut y = a.clone();
            fft2(&mut y, r, c);
            let want = dft2_direct(&a, r, c);
            for (got, w) in y.iter().zip(&want) {
                assert!((*got - *w).abs() < 1e-8, "({r},{c})");
            }
        }
    }

    #[test]
    fn roundtrip_2d() {
        let (r, c) = (48, 96);
        let mut rng = Rng::new(9);
        let a: Vec<C64> = (0..r * c).map(|_| C64::from_re(rng.normal())).collect();
        let mut y = a.clone();
        fft2(&mut y, r, c);
        ifft2(&mut y, r, c);
        for (got, w) in y.iter().zip(&a) {
            assert!((*got - *w).abs() < 1e-9);
        }
    }

    #[test]
    fn real_matrix_center_symmetry() {
        let (r, c) = (8, 12);
        let mut rng = Rng::new(4);
        let a: Vec<f32> = (0..r * c).map(|_| rng.normal() as f32).collect();
        let spec = fft2_real(MatView::new(&a, r, c));
        for u in 0..r {
            for v in 0..c {
                let m = spec[((r - u) % r) * c + (c - v) % c].conj();
                assert!((spec[u * c + v] - m).abs() < 1e-6);
            }
        }
    }
}
