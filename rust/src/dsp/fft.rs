//! FFT: iterative radix-2 Cooley-Tukey for power-of-two sizes,
//! Bluestein's chirp-z for everything else (activation matrices crop
//! to arbitrary sequence lengths in the eval path).
//!
//! A [`FftPlan`] precomputes twiddles / bit-reversal (and, for
//! Bluestein, the chirp and its padded transform) once per size; the
//! codec caches plans per (S, D), so the request-path cost is the
//! butterflies only.

use super::complex::C64;
use std::f64::consts::PI;

#[derive(Debug)]
enum Kind {
    Radix2 {
        rev: Vec<u32>,
        /// twiddle table: for stage length `len`, the `len/2` roots
        /// e^{-2πi k/len} are at offset `len/2 - 1`… flattened.
        twiddles: Vec<C64>,
    },
    Bluestein {
        m: usize,
        chirp: Vec<C64>,     // a_k = e^{-iπ k² / n}
        chirp_fft: Vec<C64>, // FFT of the zero-padded conjugate chirp
        inner: Box<FftPlan>,
    },
}

#[derive(Debug)]
pub struct FftPlan {
    pub n: usize,
    kind: Kind,
}

impl FftPlan {
    pub fn new(n: usize) -> FftPlan {
        assert!(n > 0);
        if n.is_power_of_two() {
            FftPlan { n, kind: Self::radix2(n) }
        } else {
            FftPlan { n, kind: Self::bluestein(n) }
        }
    }

    fn radix2(n: usize) -> Kind {
        let bits = n.trailing_zeros();
        let rev: Vec<u32> = if bits == 0 {
            vec![0]
        } else {
            (0..n as u32).map(|i| i.reverse_bits() >> (32 - bits)).collect()
        };
        // per-stage twiddles, concatenated: stage len=2,4,..,n
        let mut twiddles = Vec::with_capacity(n.max(1));
        let mut len = 2;
        while len <= n {
            for k in 0..len / 2 {
                twiddles.push(C64::cis(-2.0 * PI * k as f64 / len as f64));
            }
            len <<= 1;
        }
        Kind::Radix2 { rev, twiddles }
    }

    fn bluestein(n: usize) -> Kind {
        let m = (2 * n - 1).next_power_of_two();
        let inner = Box::new(FftPlan::new(m));
        // chirp a_k = e^{-iπ k²/n}; k² mod 2n avoids precision blowup
        let chirp: Vec<C64> = (0..n)
            .map(|k| {
                let e = ((k as u128 * k as u128) % (2 * n as u128)) as f64;
                C64::cis(-PI * e / n as f64)
            })
            .collect();
        // b_k = conj(chirp), padded circularly: b[0]=a0*, b[k]=b[m-k]=a_k*
        let mut b = vec![C64::ZERO; m];
        b[0] = chirp[0].conj();
        for k in 1..n {
            b[k] = chirp[k].conj();
            b[m - k] = chirp[k].conj();
        }
        inner.forward_in_place(&mut b);
        Kind::Bluestein { m, chirp, chirp_fft: b, inner }
    }

    /// Forward DFT, in place.  X[k] = Σ x[j] e^{-2πi jk/n}.
    pub fn forward_in_place(&self, data: &mut [C64]) {
        assert_eq!(data.len(), self.n);
        match &self.kind {
            Kind::Radix2 { rev, twiddles } => {
                radix2_pass(data, rev, twiddles);
            }
            Kind::Bluestein { m, chirp, chirp_fft, inner } => {
                let n = self.n;
                let mut a = vec![C64::ZERO; *m];
                for k in 0..n {
                    a[k] = data[k] * chirp[k];
                }
                inner.forward_in_place(&mut a);
                for (av, bv) in a.iter_mut().zip(chirp_fft.iter()) {
                    *av = *av * *bv;
                }
                inner.inverse_in_place(&mut a);
                for k in 0..n {
                    data[k] = a[k] * chirp[k];
                }
            }
        }
    }

    /// Inverse DFT (with 1/n normalisation), in place.
    pub fn inverse_in_place(&self, data: &mut [C64]) {
        // conjugate trick: ifft(x) = conj(fft(conj(x))) / n
        for v in data.iter_mut() {
            *v = v.conj();
        }
        self.forward_in_place(data);
        let inv = 1.0 / self.n as f64;
        for v in data.iter_mut() {
            *v = v.conj().scale(inv);
        }
    }
}

fn radix2_pass(data: &mut [C64], rev: &[u32], twiddles: &[C64]) {
    let n = data.len();
    for i in 0..n {
        let j = rev[i] as usize;
        if i < j {
            data.swap(i, j);
        }
    }
    let mut len = 2;
    let mut toff = 0;
    while len <= n {
        let half = len / 2;
        let tw = &twiddles[toff..toff + half];
        let mut base = 0;
        while base < n {
            for k in 0..half {
                let u = data[base + k];
                let v = data[base + k + half] * tw[k];
                data[base + k] = u + v;
                data[base + k + half] = u - v;
            }
            base += len;
        }
        toff += half;
        len <<= 1;
    }
}

/// Direct O(n²) DFT — the oracle the fft is tested against.
pub fn dft_direct(x: &[C64]) -> Vec<C64> {
    let n = x.len();
    (0..n)
        .map(|k| {
            let mut acc = C64::ZERO;
            for (j, &v) in x.iter().enumerate() {
                acc += v * C64::cis(-2.0 * PI * (j * k % n) as f64 / n as f64);
            }
            acc
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_signal(n: usize, seed: u64) -> Vec<C64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect()
    }

    fn max_err(a: &[C64], b: &[C64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (*x - *y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn matches_direct_dft_pow2() {
        for n in [1usize, 2, 4, 8, 64, 256] {
            let x = rand_signal(n, n as u64);
            let mut y = x.clone();
            FftPlan::new(n).forward_in_place(&mut y);
            assert!(max_err(&y, &dft_direct(&x)) < 1e-9 * n as f64, "n={n}");
        }
    }

    #[test]
    fn matches_direct_dft_arbitrary() {
        for n in [3usize, 5, 6, 7, 12, 31, 48, 96, 100, 259] {
            let x = rand_signal(n, n as u64 + 1);
            let mut y = x.clone();
            FftPlan::new(n).forward_in_place(&mut y);
            assert!(max_err(&y, &dft_direct(&x)) < 1e-8 * n as f64, "n={n}");
        }
    }

    #[test]
    fn roundtrip_identity() {
        for n in [8usize, 17, 48, 64, 96, 200] {
            let x = rand_signal(n, 77 + n as u64);
            let mut y = x.clone();
            let plan = FftPlan::new(n);
            plan.forward_in_place(&mut y);
            plan.inverse_in_place(&mut y);
            assert!(max_err(&y, &x) < 1e-9 * n as f64, "n={n}");
        }
    }

    #[test]
    fn parseval() {
        let n = 64;
        let x = rand_signal(n, 5);
        let mut y = x.clone();
        FftPlan::new(n).forward_in_place(&mut y);
        let ex: f64 = x.iter().map(|v| v.norm_sq()).sum();
        let ey: f64 = y.iter().map(|v| v.norm_sq()).sum::<f64>() / n as f64;
        assert!((ex - ey).abs() < 1e-9 * ex);
    }

    #[test]
    fn impulse_is_flat() {
        let n = 32;
        let mut x = vec![C64::ZERO; n];
        x[0] = C64::ONE;
        FftPlan::new(n).forward_in_place(&mut x);
        for v in x {
            assert!((v.re - 1.0).abs() < 1e-12 && v.im.abs() < 1e-12);
        }
    }

    #[test]
    fn real_input_conjugate_symmetry() {
        let n = 48; // non-pow2: exercises bluestein
        let mut rng = Rng::new(3);
        let x: Vec<C64> = (0..n).map(|_| C64::from_re(rng.normal())).collect();
        let mut y = x.clone();
        FftPlan::new(n).forward_in_place(&mut y);
        for k in 1..n {
            let d = y[k] - y[n - k].conj();
            assert!(d.abs() < 1e-9, "k={k}");
        }
    }
}
