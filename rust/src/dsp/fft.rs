//! FFT: iterative radix-2 Cooley-Tukey for power-of-two sizes,
//! Bluestein's chirp-z for everything else (activation matrices crop
//! to arbitrary sequence lengths in the eval path).
//!
//! A [`FftPlan`] precomputes twiddles / bit-reversal (and, for
//! Bluestein, the chirp and its padded transform) once per size; the
//! codec caches plans per (S, D), so the request-path cost is the
//! butterflies only.

use super::complex::C64;
use super::simd::{self, Level};
use std::cell::RefCell;
use std::f64::consts::PI;

#[derive(Debug)]
enum Kind {
    Radix2 {
        rev: Vec<u32>,
        /// twiddle table: for stage length `len`, the `len/2` roots
        /// e^{-2πi k/len} are at offset `len/2 - 1`… flattened.
        twiddles: Vec<C64>,
    },
    Bluestein {
        m: usize,
        chirp: Vec<C64>,     // a_k = e^{-iπ k² / n}
        chirp_fft: Vec<C64>, // FFT of the zero-padded conjugate chirp
        inner: Box<FftPlan>,
    },
}

#[derive(Debug)]
pub struct FftPlan {
    pub n: usize,
    kind: Kind,
}

impl FftPlan {
    pub fn new(n: usize) -> FftPlan {
        assert!(n > 0);
        if n.is_power_of_two() {
            FftPlan { n, kind: Self::radix2(n) }
        } else {
            FftPlan { n, kind: Self::bluestein(n) }
        }
    }

    fn radix2(n: usize) -> Kind {
        let bits = n.trailing_zeros();
        let rev: Vec<u32> = if bits == 0 {
            vec![0]
        } else {
            (0..n as u32).map(|i| i.reverse_bits() >> (32 - bits)).collect()
        };
        // per-stage twiddles, concatenated: stage len=2,4,..,n
        let mut twiddles = Vec::with_capacity(n.max(1));
        let mut len = 2;
        while len <= n {
            for k in 0..len / 2 {
                twiddles.push(C64::cis(-2.0 * PI * k as f64 / len as f64));
            }
            len <<= 1;
        }
        Kind::Radix2 { rev, twiddles }
    }

    fn bluestein(n: usize) -> Kind {
        let m = (2 * n - 1).next_power_of_two();
        let inner = Box::new(FftPlan::new(m));
        // chirp a_k = e^{-iπ k²/n}; k² mod 2n avoids precision blowup
        let chirp: Vec<C64> = (0..n)
            .map(|k| {
                let e = ((k as u128 * k as u128) % (2 * n as u128)) as f64;
                C64::cis(-PI * e / n as f64)
            })
            .collect();
        // b_k = conj(chirp), padded circularly: b[0]=a0*, b[k]=b[m-k]=a_k*
        let mut b = vec![C64::ZERO; m];
        b[0] = chirp[0].conj();
        for k in 1..n {
            b[k] = chirp[k].conj();
            b[m - k] = chirp[k].conj();
        }
        inner.forward_in_place(&mut b);
        Kind::Bluestein { m, chirp, chirp_fft: b, inner }
    }

    /// Forward DFT, in place.  X[k] = Σ x[j] e^{-2πi jk/n}.
    /// Dispatches at the process-detected SIMD level; use
    /// [`FftPlan::forward_with`] to pin a level explicitly.
    pub fn forward_in_place(&self, data: &mut [C64]) {
        self.forward_with(simd::detect(), data);
    }

    /// Forward DFT at an explicit kernel [`Level`] — the codec engine
    /// threads its own level through so parity tests can force the
    /// scalar reference path per engine.
    pub fn forward_with(&self, lv: Level, data: &mut [C64]) {
        assert_eq!(data.len(), self.n);
        match &self.kind {
            Kind::Radix2 { rev, twiddles } => {
                simd::radix2_pass(lv, data, rev, twiddles);
            }
            Kind::Bluestein { m, chirp, chirp_fft, inner } => {
                let n = self.n;
                // convolution scratch, recycled across calls (bluestein
                // column passes land in the codec hot path for non-pow2
                // sequence axes).  Never re-entered: the inner plan of a
                // Bluestein is always radix-2.
                BLUESTEIN_SCRATCH.with(|cell| {
                    let a = &mut *cell.borrow_mut();
                    a.clear();
                    a.resize(*m, C64::ZERO);
                    a[..n].copy_from_slice(data);
                    simd::cmul_in_place(lv, &mut a[..n], chirp);
                    inner.forward_with(lv, a);
                    simd::cmul_in_place(lv, a, chirp_fft);
                    inner.inverse_with(lv, a);
                    data.copy_from_slice(&a[..n]);
                    simd::cmul_in_place(lv, data, chirp);
                });
            }
        }
    }

    /// Inverse DFT (with 1/n normalisation), in place.
    pub fn inverse_in_place(&self, data: &mut [C64]) {
        self.inverse_with(simd::detect(), data);
    }

    /// Inverse DFT at an explicit kernel [`Level`].
    pub fn inverse_with(&self, lv: Level, data: &mut [C64]) {
        // conjugate trick: ifft(x) = conj(fft(conj(x))) / n
        simd::conj_in_place(lv, data);
        self.forward_with(lv, data);
        simd::conj_scale_in_place(lv, data, 1.0 / self.n as f64);
    }
}

thread_local! {
    static BLUESTEIN_SCRATCH: RefCell<Vec<C64>> = const { RefCell::new(Vec::new()) };
}

/// Direct O(n²) DFT — the oracle the fft is tested against.
pub fn dft_direct(x: &[C64]) -> Vec<C64> {
    let n = x.len();
    (0..n)
        .map(|k| {
            let mut acc = C64::ZERO;
            for (j, &v) in x.iter().enumerate() {
                acc += v * C64::cis(-2.0 * PI * (j * k % n) as f64 / n as f64);
            }
            acc
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_signal(n: usize, seed: u64) -> Vec<C64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect()
    }

    fn max_err(a: &[C64], b: &[C64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (*x - *y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn matches_direct_dft_pow2() {
        for n in [1usize, 2, 4, 8, 64, 256] {
            let x = rand_signal(n, n as u64);
            let mut y = x.clone();
            FftPlan::new(n).forward_in_place(&mut y);
            assert!(max_err(&y, &dft_direct(&x)) < 1e-9 * n as f64, "n={n}");
        }
    }

    #[test]
    fn matches_direct_dft_arbitrary() {
        for n in [3usize, 5, 6, 7, 12, 31, 48, 96, 100, 259] {
            let x = rand_signal(n, n as u64 + 1);
            let mut y = x.clone();
            FftPlan::new(n).forward_in_place(&mut y);
            assert!(max_err(&y, &dft_direct(&x)) < 1e-8 * n as f64, "n={n}");
        }
    }

    #[test]
    fn roundtrip_identity() {
        for n in [8usize, 17, 48, 64, 96, 200] {
            let x = rand_signal(n, 77 + n as u64);
            let mut y = x.clone();
            let plan = FftPlan::new(n);
            plan.forward_in_place(&mut y);
            plan.inverse_in_place(&mut y);
            assert!(max_err(&y, &x) < 1e-9 * n as f64, "n={n}");
        }
    }

    #[test]
    fn parseval() {
        let n = 64;
        let x = rand_signal(n, 5);
        let mut y = x.clone();
        FftPlan::new(n).forward_in_place(&mut y);
        let ex: f64 = x.iter().map(|v| v.norm_sq()).sum();
        let ey: f64 = y.iter().map(|v| v.norm_sq()).sum::<f64>() / n as f64;
        assert!((ex - ey).abs() < 1e-9 * ex);
    }

    #[test]
    fn impulse_is_flat() {
        let n = 32;
        let mut x = vec![C64::ZERO; n];
        x[0] = C64::ONE;
        FftPlan::new(n).forward_in_place(&mut x);
        for v in x {
            assert!((v.re - 1.0).abs() < 1e-12 && v.im.abs() < 1e-12);
        }
    }

    #[test]
    fn real_input_conjugate_symmetry() {
        let n = 48; // non-pow2: exercises bluestein
        let mut rng = Rng::new(3);
        let x: Vec<C64> = (0..n).map(|_| C64::from_re(rng.normal())).collect();
        let mut y = x.clone();
        FftPlan::new(n).forward_in_place(&mut y);
        for k in 1..n {
            let d = y[k] - y[n - k].conj();
            assert!(d.abs() < 1e-9, "k={k}");
        }
    }
}
