//! SIMD kernel layer for the codec hot path.
//!
//! Every kernel here exists in two byte-parity-pinned forms: a portable
//! scalar body (always compiled — it IS the reference semantics) and a
//! vector body selected at runtime behind the `simd` cargo feature.
//! The dispatch contract is strict: for any input, the vector body must
//! produce **bit-identical** output to the scalar body.  That is why
//!
//! * the complex kernels use the exact mul/add/sub sequence of
//!   [`C64`]'s operators (no FMA — a fused multiply-add rounds once
//!   where the scalar code rounds twice);
//! * the length-2 butterfly stage still multiplies by its twiddle
//!   `(1.0, -0.0)` — skipping the "trivial" multiply would flip signed
//!   zeros all over a sparse spectrum;
//! * int8 quantization emulates Rust's half-away-from-zero
//!   `f32::round` with a truncate-then-adjust sequence instead of the
//!   hardware's round-to-nearest-even (`_mm256_round_ps` and
//!   `floor(x + 0.5)` both disagree with `round` on ties).
//!
//! The parity is enforced by unit tests here and by the seeded
//! SIMD-vs-scalar suite in `tests/properties.rs`, which runs the whole
//! codec under both levels and compares wire bytes.
//!
//! Dispatch levels: `Avx2` on x86_64 (runtime-detected, covers the CI
//! and serving fleet), `Neon` on aarch64 for the f32 move/convert
//! kernels (butterflies and quantize stay scalar there until an
//! aarch64 CI leg exists).  Everything else — and every build without
//! `--features simd` — runs the scalar bodies.

use super::complex::C64;

/// Kernel dispatch level.  Obtain via [`detect`] (or force
/// [`Level::Scalar`] to pin the reference path, e.g. in parity tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    /// Portable scalar kernels — always compiled, the parity baseline.
    Scalar,
    /// AVX2 f64/f32 kernels (x86_64, `simd` feature, runtime-checked).
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// NEON f32 move/convert kernels (aarch64, `simd` feature).
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Level::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Level::Neon => "neon",
        }
    }
}

/// Best available level for this process.  Scalar unless the crate was
/// built with `--features simd` AND the CPU reports the target feature
/// at runtime (checked once, cached).
pub fn detect() -> Level {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        use std::sync::OnceLock;
        static LV: OnceLock<Level> = OnceLock::new();
        return *LV.get_or_init(|| {
            if std::arch::is_x86_feature_detected!("avx2") {
                Level::Avx2
            } else {
                Level::Scalar
            }
        });
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        // NEON is baseline on aarch64.
        return Level::Neon;
    }
    #[allow(unreachable_code)]
    Level::Scalar
}

// ---------------------------------------------------------------------------
// complex f64 kernels (FFT internals)
// ---------------------------------------------------------------------------

/// One full radix-2 pass: bit-reversal permutation + every butterfly
/// stage.  `twiddles` is the per-stage concatenated table built by
/// `FftPlan::radix2`.
pub fn radix2_pass(lv: Level, data: &mut [C64], rev: &[u32],
                   twiddles: &[C64]) {
    let n = data.len();
    // permutation is a memory shuffle — scalar at every level
    for i in 0..n {
        let j = rev[i] as usize;
        if i < j {
            data.swap(i, j);
        }
    }
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if lv == Level::Avx2 {
        // SAFETY: Level::Avx2 only exists after `detect` saw avx2.
        unsafe { butterflies_avx2(data, twiddles) };
        return;
    }
    let _ = lv;
    butterflies_scalar(data, twiddles);
}

fn butterflies_scalar(data: &mut [C64], twiddles: &[C64]) {
    let n = data.len();
    let mut len = 2;
    let mut toff = 0;
    while len <= n {
        let half = len / 2;
        let tw = &twiddles[toff..toff + half];
        let mut base = 0;
        while base < n {
            for k in 0..half {
                let u = data[base + k];
                let v = data[base + k + half] * tw[k];
                data[base + k] = u + v;
                data[base + k + half] = u - v;
            }
            base += len;
        }
        toff += half;
        len <<= 1;
    }
}

/// `a[i] *= b[i]` over equal-length slices (Bluestein chirp passes).
pub fn cmul_in_place(lv: Level, a: &mut [C64], b: &[C64]) {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if lv == Level::Avx2 {
        unsafe { cmul_avx2(a, b) };
        return;
    }
    let _ = lv;
    for (av, bv) in a.iter_mut().zip(b.iter()) {
        *av = *av * *bv;
    }
}

/// Conjugate every element (first half of the inverse-FFT trick).
pub fn conj_in_place(lv: Level, data: &mut [C64]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if lv == Level::Avx2 {
        unsafe { conj_avx2(data) };
        return;
    }
    let _ = lv;
    for v in data.iter_mut() {
        *v = v.conj();
    }
}

/// `data[i] = conj(data[i]) * k` (second half of the inverse trick).
pub fn conj_scale_in_place(lv: Level, data: &mut [C64], k: f64) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if lv == Level::Avx2 {
        unsafe { conj_scale_avx2(data, k) };
        return;
    }
    let _ = lv;
    for v in data.iter_mut() {
        *v = v.conj().scale(k);
    }
}

// ---------------------------------------------------------------------------
// f32 <-> C64 move/convert kernels (pack/unpack, rfft staging)
// ---------------------------------------------------------------------------

/// Widen consecutive f32 pairs into complex: `out += [(x[0], x[1]),
/// (x[2], x[3]), ...]`.  `x.len()` must be even.  This is the rfft
/// even-length pack: a real row reinterpreted as a half-length complex
/// signal.
pub fn widen_f32_pairs(lv: Level, x: &[f32], out: &mut Vec<C64>) {
    debug_assert_eq!(x.len() % 2, 0);
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if lv == Level::Avx2 {
        unsafe { widen_avx2(x, out) };
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if lv == Level::Neon {
        unsafe { widen_neon(x, out) };
        return;
    }
    let _ = lv;
    out.extend(x.chunks_exact(2).map(|c| C64::new(c[0] as f64, c[1] as f64)));
}

/// Narrow a complex slice to interleaved f32: `out += [re0, im0, re1,
/// im1, ...]`.  Used both for packing kept spectrum rows to the wire
/// and for emitting the irfft's (even, odd) sample pairs.
pub fn narrow_c64(lv: Level, src: &[C64], out: &mut Vec<f32>) {
    let old = out.len();
    out.resize(old + 2 * src.len(), 0.0);
    narrow_c64_slice(lv, src, &mut out[old..]);
}

/// [`narrow_c64`] into a caller-owned slice (`dst.len() == 2 *
/// src.len()`).
pub fn narrow_c64_slice(lv: Level, src: &[C64], dst: &mut [f32]) {
    debug_assert_eq!(dst.len(), 2 * src.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if lv == Level::Avx2 {
        unsafe { narrow_avx2(src, dst) };
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if lv == Level::Neon {
        unsafe { narrow_neon(src, dst) };
        return;
    }
    let _ = lv;
    for (c, d) in src.iter().zip(dst.chunks_exact_mut(2)) {
        d[0] = c.re as f32;
        d[1] = c.im as f32;
    }
}

/// `out += [a[0], b[0], a[1], b[1], ...]` (pack of a full spectrum
/// row's separate re/im planes).
pub fn interleave_f32(lv: Level, a: &[f32], b: &[f32], out: &mut Vec<f32>) {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if lv == Level::Avx2 {
        unsafe { interleave_avx2(a, b, out) };
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if lv == Level::Neon {
        unsafe { interleave_neon(a, b, out) };
        return;
    }
    let _ = lv;
    for (x, y) in a.iter().zip(b.iter()) {
        out.push(*x);
        out.push(*y);
    }
}

/// Inverse of [`interleave_f32`]: split `src` (length `2n`) into its
/// even elements (`a`) and odd elements (`b`), each length `n`.
pub fn deinterleave_f32(lv: Level, src: &[f32], a: &mut [f32],
                        b: &mut [f32]) {
    debug_assert_eq!(src.len(), a.len() + b.len());
    debug_assert_eq!(a.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if lv == Level::Avx2 {
        unsafe { deinterleave_avx2(src, a, b) };
        return;
    }
    let _ = lv;
    for (c, (x, y)) in src.chunks_exact(2).zip(a.iter_mut().zip(b.iter_mut())) {
        *x = c[0];
        *y = c[1];
    }
}

// ---------------------------------------------------------------------------
// int8 quantization kernels
// ---------------------------------------------------------------------------

/// Per-block absolute maximum (`fold(0.0, |m, v| m.max(v.abs()))`).
/// max is order-independent over finite floats, so the tree reduction
/// matches the scalar fold exactly.
pub fn absmax(lv: Level, x: &[f32]) -> f32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if lv == Level::Avx2 {
        return unsafe { absmax_avx2(x) };
    }
    let _ = lv;
    x.iter().fold(0.0f32, |m, v| m.max(v.abs()))
}

/// Quantize `x` to int8 at the hoisted reciprocal scale:
/// `(v * inv).round().clamp(-127.0, 127.0) as i8`, appended as raw
/// bytes.  Inputs must be finite (activation values always are); the
/// vector body's tie handling is pinned to Rust's half-away-from-zero
/// `round`, see module docs.
pub fn quantize_i8(lv: Level, x: &[f32], inv: f32, out: &mut Vec<u8>) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if lv == Level::Avx2 {
        unsafe { quantize_avx2(x, inv, out) };
        return;
    }
    let _ = lv;
    quantize_scalar(x, inv, out);
}

fn quantize_scalar(x: &[f32], inv: f32, out: &mut Vec<u8>) {
    for &v in x {
        let q = (v * inv).round().clamp(-127.0, 127.0) as i8;
        out.push(q as u8);
    }
}

/// Dequantize raw int8 bytes: `out += q as i8 as f32 * scale`.
pub fn dequantize_i8(lv: Level, q: &[u8], scale: f32, out: &mut Vec<f32>) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if lv == Level::Avx2 {
        unsafe { dequantize_avx2(q, scale, out) };
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if lv == Level::Neon {
        unsafe { dequantize_neon(q, scale, out) };
        return;
    }
    let _ = lv;
    out.extend(q.iter().map(|&b| (b as i8) as f32 * scale));
}

// ---------------------------------------------------------------------------
// AVX2 bodies
// ---------------------------------------------------------------------------

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    use super::C64;
    use std::arch::x86_64::*;

    /// Complex multiply of two ymm registers each holding two (re, im)
    /// f64 pairs, with the scalar operator's exact rounding:
    /// `re = ar*br - ai*bi; im = ai*br + ar*bi` (mul, mul, addsub).
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn cmul2(a: __m256d, b: __m256d) -> __m256d {
        let br = _mm256_movedup_pd(b); // [br0, br0, br1, br1]
        let bi = _mm256_permute_pd::<0b1111>(b); // [bi0, bi0, bi1, bi1]
        let asw = _mm256_permute_pd::<0b0101>(a); // [ai0, ar0, ai1, ar1]
        // addsub([ar*br, ai*br], [ai*bi, ar*bi])
        //   -> [ar*br - ai*bi, ai*br + ar*bi]
        _mm256_addsub_pd(_mm256_mul_pd(a, br), _mm256_mul_pd(asw, bi))
    }

    /// Butterfly stages of a radix-2 FFT (after bit-reversal).  Two
    /// butterflies per iteration; for n >= 4 every stage's half-count
    /// is even or the stage is the adjacent-pair stage, so there is no
    /// scalar tail.
    #[target_feature(enable = "avx2")]
    pub unsafe fn butterflies_avx2(data: &mut [C64], twiddles: &[C64]) {
        let n = data.len();
        if n < 4 {
            super::butterflies_scalar(data, twiddles);
            return;
        }
        let p = data.as_mut_ptr() as *mut f64;
        let tp = twiddles.as_ptr() as *const f64;

        // stage len == 2: adjacent (u, v) pairs; two butterflies span
        // two ymm loads.  The twiddle is (1.0, -0.0) but the multiply
        // still runs — see module docs on signed zeros.
        let tw0r = _mm256_set1_pd(twiddles[0].re);
        let tw0i = _mm256_set1_pd(twiddles[0].im);
        let mut i = 0;
        while i < n {
            let y0 = _mm256_loadu_pd(p.add(2 * i)); // [u0, v0]
            let y1 = _mm256_loadu_pd(p.add(2 * i + 4)); // [u1, v1]
            let u = _mm256_permute2f128_pd::<0x20>(y0, y1); // [u0, u1]
            let v = _mm256_permute2f128_pd::<0x31>(y0, y1); // [v0, v1]
            let vsw = _mm256_permute_pd::<0b0101>(v);
            let prod = _mm256_addsub_pd(_mm256_mul_pd(v, tw0r),
                                        _mm256_mul_pd(vsw, tw0i));
            let s = _mm256_add_pd(u, prod);
            let d = _mm256_sub_pd(u, prod);
            _mm256_storeu_pd(p.add(2 * i),
                             _mm256_permute2f128_pd::<0x20>(s, d));
            _mm256_storeu_pd(p.add(2 * i + 4),
                             _mm256_permute2f128_pd::<0x31>(s, d));
            i += 4;
        }

        // stages len >= 4: half >= 2, so the 2-wide kernel tiles the
        // k-loop exactly.
        let mut len = 4usize;
        let mut toff = 1usize; // past the len-2 stage's single twiddle
        while len <= n {
            let half = len / 2;
            let mut base = 0;
            while base < n {
                let mut k = 0;
                while k < half {
                    let ui = 2 * (base + k);
                    let vi = 2 * (base + k + half);
                    let u = _mm256_loadu_pd(p.add(ui));
                    let v = _mm256_loadu_pd(p.add(vi));
                    let t = _mm256_loadu_pd(tp.add(2 * (toff + k)));
                    let prod = cmul2(v, t);
                    _mm256_storeu_pd(p.add(ui), _mm256_add_pd(u, prod));
                    _mm256_storeu_pd(p.add(vi), _mm256_sub_pd(u, prod));
                    k += 2;
                }
                base += len;
            }
            toff += half;
            len <<= 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn cmul_avx2(a: &mut [C64], b: &[C64]) {
        let n = a.len();
        let pa = a.as_mut_ptr() as *mut f64;
        let pb = b.as_ptr() as *const f64;
        let mut i = 0;
        while i + 2 <= n {
            let va = _mm256_loadu_pd(pa.add(2 * i));
            let vb = _mm256_loadu_pd(pb.add(2 * i));
            _mm256_storeu_pd(pa.add(2 * i), cmul2(va, vb));
            i += 2;
        }
        if i < n {
            a[i] = a[i] * b[i];
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn conj_avx2(data: &mut [C64]) {
        let n = data.len();
        let p = data.as_mut_ptr() as *mut f64;
        let flip = _mm256_setr_pd(0.0, -0.0, 0.0, -0.0);
        let mut i = 0;
        while i + 2 <= n {
            let v = _mm256_loadu_pd(p.add(2 * i));
            _mm256_storeu_pd(p.add(2 * i), _mm256_xor_pd(v, flip));
            i += 2;
        }
        if i < n {
            data[i] = data[i].conj();
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn conj_scale_avx2(data: &mut [C64], k: f64) {
        let n = data.len();
        let p = data.as_mut_ptr() as *mut f64;
        let flip = _mm256_setr_pd(0.0, -0.0, 0.0, -0.0);
        let vk = _mm256_set1_pd(k);
        let mut i = 0;
        while i + 2 <= n {
            let v = _mm256_loadu_pd(p.add(2 * i));
            let c = _mm256_xor_pd(v, flip);
            _mm256_storeu_pd(p.add(2 * i), _mm256_mul_pd(c, vk));
            i += 2;
        }
        if i < n {
            data[i] = data[i].conj().scale(k);
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn widen_avx2(x: &[f32], out: &mut Vec<C64>) {
        let m = x.len() / 2; // complex count
        let old = out.len();
        out.reserve(m);
        let dst = (out.as_mut_ptr().add(old)) as *mut f64;
        let src = x.as_ptr();
        let mut i = 0; // f32 index
        while i + 4 <= x.len() {
            let v = _mm_loadu_ps(src.add(i));
            _mm256_storeu_pd(dst.add(i), _mm256_cvtps_pd(v));
            i += 4;
        }
        while i < x.len() {
            *dst.add(i) = *src.add(i) as f64;
            i += 1;
        }
        out.set_len(old + m);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn narrow_avx2(src: &[C64], dst: &mut [f32]) {
        let total = 2 * src.len(); // f64 count
        let sp = src.as_ptr() as *const f64;
        let dp = dst.as_mut_ptr();
        let mut i = 0;
        while i + 4 <= total {
            let v = _mm256_loadu_pd(sp.add(i));
            _mm_storeu_ps(dp.add(i), _mm256_cvtpd_ps(v));
            i += 4;
        }
        while i < total {
            *dp.add(i) = *sp.add(i) as f32;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn interleave_avx2(a: &[f32], b: &[f32], out: &mut Vec<f32>) {
        let n = a.len();
        let old = out.len();
        out.reserve(2 * n);
        let dst = out.as_mut_ptr().add(old);
        let mut i = 0;
        while i + 8 <= n {
            let va = _mm256_loadu_ps(a.as_ptr().add(i));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i));
            let lo = _mm256_unpacklo_ps(va, vb); // [a0 b0 a1 b1 | a4 b4 a5 b5]
            let hi = _mm256_unpackhi_ps(va, vb); // [a2 b2 a3 b3 | a6 b6 a7 b7]
            _mm256_storeu_ps(dst.add(2 * i),
                             _mm256_permute2f128_ps::<0x20>(lo, hi));
            _mm256_storeu_ps(dst.add(2 * i + 8),
                             _mm256_permute2f128_ps::<0x31>(lo, hi));
            i += 8;
        }
        while i < n {
            *dst.add(2 * i) = a[i];
            *dst.add(2 * i + 1) = b[i];
            i += 1;
        }
        out.set_len(old + 2 * n);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn deinterleave_avx2(src: &[f32], a: &mut [f32],
                                    b: &mut [f32]) {
        let n = a.len();
        let sp = src.as_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let v0 = _mm256_loadu_ps(sp.add(2 * i)); // [a0 b0 .. a3 b3]
            let v1 = _mm256_loadu_ps(sp.add(2 * i + 8)); // [a4 b4 .. a7 b7]
            // gather even (a) / odd (b) slots, then fix lane order
            let sa = _mm256_castpd_ps(_mm256_permute4x64_pd::<0b11_01_10_00>(
                _mm256_castps_pd(_mm256_shuffle_ps::<0b10_00_10_00>(v0, v1)),
            ));
            let sb = _mm256_castpd_ps(_mm256_permute4x64_pd::<0b11_01_10_00>(
                _mm256_castps_pd(_mm256_shuffle_ps::<0b11_01_11_01>(v0, v1)),
            ));
            _mm256_storeu_ps(a.as_mut_ptr().add(i), sa);
            _mm256_storeu_ps(b.as_mut_ptr().add(i), sb);
            i += 8;
        }
        while i < n {
            a[i] = *sp.add(2 * i);
            b[i] = *sp.add(2 * i + 1);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn absmax_avx2(x: &[f32]) -> f32 {
        let abs_mask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF));
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= x.len() {
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            acc = _mm256_max_ps(acc, _mm256_and_ps(v, abs_mask));
            i += 8;
        }
        // horizontal max of the accumulator
        let lo = _mm256_castps256_ps128(acc);
        let hi = _mm256_extractf128_ps::<1>(acc);
        let m4 = _mm_max_ps(lo, hi);
        let m2 = _mm_max_ps(m4, _mm_movehl_ps(m4, m4));
        let m1 = _mm_max_ss(m2, _mm_shuffle_ps::<0b01>(m2, m2));
        let mut m = _mm_cvtss_f32(m1);
        while i < x.len() {
            m = m.max(x[i].abs());
            i += 1;
        }
        m
    }

    /// `(v * inv).round().clamp(-127.0, 127.0) as i8` for 16 lanes per
    /// iteration.  `round` (half away from zero) is emulated as
    /// truncate + adjust: `x - trunc(x)` is exact (Sterbenz), so the
    /// `|frac| >= 0.5` tie test and the `±1` step reproduce the scalar
    /// result bit-for-bit on finite input.
    #[target_feature(enable = "avx2")]
    pub unsafe fn quantize_avx2(x: &[f32], inv: f32, out: &mut Vec<u8>) {
        let n = x.len();
        let old = out.len();
        out.reserve(n);
        let dst = out.as_mut_ptr().add(old);
        let vinv = _mm256_set1_ps(inv);
        let abs_mask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF));
        let sign_mask = _mm256_castsi256_ps(_mm256_set1_epi32(u32::MAX as i32 ^ 0x7FFF_FFFF));
        let one = _mm256_set1_ps(1.0);
        let half = _mm256_set1_ps(0.5);
        let lim_hi = _mm256_set1_ps(127.0);
        let lim_lo = _mm256_set1_ps(-127.0);

        #[target_feature(enable = "avx2")]
        #[inline]
        unsafe fn round8(x: __m256, abs_mask: __m256, sign_mask: __m256,
                         one: __m256, half: __m256, lim_lo: __m256,
                         lim_hi: __m256) -> __m256i {
            let t = _mm256_round_ps::<{ _MM_FROUND_TO_ZERO
                | _MM_FROUND_NO_EXC }>(x);
            let frac = _mm256_sub_ps(x, t);
            let tie = _mm256_cmp_ps::<_CMP_GE_OQ>(
                _mm256_and_ps(frac, abs_mask), half);
            let step = _mm256_or_ps(_mm256_and_ps(x, sign_mask), one);
            let r = _mm256_add_ps(t, _mm256_and_ps(tie, step));
            let c = _mm256_max_ps(_mm256_min_ps(r, lim_hi), lim_lo);
            _mm256_cvtps_epi32(c) // integral input: exact
        }

        let mut i = 0;
        while i + 16 <= n {
            let x0 = _mm256_mul_ps(_mm256_loadu_ps(x.as_ptr().add(i)), vinv);
            let x1 = _mm256_mul_ps(_mm256_loadu_ps(x.as_ptr().add(i + 8)),
                                   vinv);
            let q0 = round8(x0, abs_mask, sign_mask, one, half, lim_lo, lim_hi);
            let q1 = round8(x1, abs_mask, sign_mask, one, half, lim_lo, lim_hi);
            // i32x16 -> ordered i16x16 -> ordered i8x16
            let p16 = _mm256_permute4x64_epi64::<0b11_01_10_00>(
                _mm256_packs_epi32(q0, q1));
            let p8 = _mm_packs_epi16(_mm256_castsi256_si128(p16),
                                     _mm256_extracti128_si256::<1>(p16));
            _mm_storeu_si128(dst.add(i) as *mut __m128i, p8);
            i += 16;
        }
        while i < n {
            let q = (x[i] * inv).round().clamp(-127.0, 127.0) as i8;
            *dst.add(i) = q as u8;
            i += 1;
        }
        out.set_len(old + n);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dequantize_avx2(q: &[u8], scale: f32, out: &mut Vec<f32>) {
        let n = q.len();
        let old = out.len();
        out.reserve(n);
        let dst = out.as_mut_ptr().add(old);
        let vs = _mm256_set1_ps(scale);
        let mut i = 0;
        while i + 16 <= n {
            let bytes = _mm_loadu_si128(q.as_ptr().add(i) as *const __m128i);
            let lo = _mm256_cvtepi8_epi32(bytes);
            let hi = _mm256_cvtepi8_epi32(_mm_srli_si128::<8>(bytes));
            _mm256_storeu_ps(dst.add(i),
                             _mm256_mul_ps(_mm256_cvtepi32_ps(lo), vs));
            _mm256_storeu_ps(dst.add(i + 8),
                             _mm256_mul_ps(_mm256_cvtepi32_ps(hi), vs));
            i += 16;
        }
        while i < n {
            *dst.add(i) = (q[i] as i8) as f32 * scale;
            i += 1;
        }
        out.set_len(old + n);
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
use avx2::*;

// ---------------------------------------------------------------------------
// NEON bodies (f32 move/convert kernels only — see module docs)
// ---------------------------------------------------------------------------

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod neon {
    use super::C64;
    use std::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    pub unsafe fn widen_neon(x: &[f32], out: &mut Vec<C64>) {
        let m = x.len() / 2;
        let old = out.len();
        out.reserve(m);
        let dst = (out.as_mut_ptr().add(old)) as *mut f64;
        let mut i = 0;
        while i + 4 <= x.len() {
            let v = vld1q_f32(x.as_ptr().add(i));
            vst1q_f64(dst.add(i), vcvt_f64_f32(vget_low_f32(v)));
            vst1q_f64(dst.add(i + 2), vcvt_high_f64_f32(v));
            i += 4;
        }
        while i < x.len() {
            *dst.add(i) = x[i] as f64;
            i += 1;
        }
        out.set_len(old + m);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn narrow_neon(src: &[C64], dst: &mut [f32]) {
        let total = 2 * src.len();
        let sp = src.as_ptr() as *const f64;
        let dp = dst.as_mut_ptr();
        let mut i = 0;
        while i + 4 <= total {
            let lo = vcvt_f32_f64(vld1q_f64(sp.add(i)));
            let hi = vcvt_f32_f64(vld1q_f64(sp.add(i + 2)));
            vst1q_f32(dp.add(i), vcombine_f32(lo, hi));
            i += 4;
        }
        while i < total {
            *dp.add(i) = *sp.add(i) as f32;
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn interleave_neon(a: &[f32], b: &[f32], out: &mut Vec<f32>) {
        let n = a.len();
        let old = out.len();
        out.reserve(2 * n);
        let dst = out.as_mut_ptr().add(old);
        let mut i = 0;
        while i + 4 <= n {
            let va = vld1q_f32(a.as_ptr().add(i));
            let vb = vld1q_f32(b.as_ptr().add(i));
            vst1q_f32(dst.add(2 * i), vzip1q_f32(va, vb));
            vst1q_f32(dst.add(2 * i + 4), vzip2q_f32(va, vb));
            i += 4;
        }
        while i < n {
            *dst.add(2 * i) = a[i];
            *dst.add(2 * i + 1) = b[i];
            i += 1;
        }
        out.set_len(old + 2 * n);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dequantize_neon(q: &[u8], scale: f32, out: &mut Vec<f32>) {
        let n = q.len();
        let old = out.len();
        out.reserve(n);
        let dst = out.as_mut_ptr().add(old);
        let mut i = 0;
        while i + 8 <= n {
            let bytes = vld1_s8(q.as_ptr().add(i) as *const i8);
            let w = vmovl_s8(bytes); // i16x8
            let lo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(w)));
            let hi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(w)));
            vst1q_f32(dst.add(i), vmulq_n_f32(lo, scale));
            vst1q_f32(dst.add(i + 4), vmulq_n_f32(hi, scale));
            i += 8;
        }
        while i < n {
            *dst.add(i) = (q[i] as i8) as f32 * scale;
            i += 1;
        }
        out.set_len(old + n);
    }
}

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
use neon::*;

// ---------------------------------------------------------------------------
// parity tests — every vector body against its scalar twin
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The non-scalar level under test, if this build/CPU has one.
    fn vector_level() -> Option<Level> {
        match detect() {
            Level::Scalar => None,
            lv => Some(lv),
        }
    }

    fn rand_c64(n: usize, seed: u64) -> Vec<C64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect()
    }

    fn rand_f32(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn bits(c: &[C64]) -> Vec<(u64, u64)> {
        c.iter().map(|v| (v.re.to_bits(), v.im.to_bits())).collect()
    }

    #[test]
    fn detect_is_scalar_without_feature() {
        if cfg!(not(feature = "simd")) {
            assert_eq!(detect(), Level::Scalar);
        }
    }

    #[test]
    fn butterflies_bit_parity() {
        let Some(lv) = vector_level() else { return };
        for n in [2usize, 4, 8, 64, 256, 1024] {
            let plan = crate::dsp::fft::FftPlan::new(n);
            let x = rand_c64(n, n as u64);
            let mut a = x.clone();
            let mut b = x.clone();
            plan.forward_with(Level::Scalar, &mut a);
            plan.forward_with(lv, &mut b);
            assert_eq!(bits(&a), bits(&b), "forward n={n}");
            plan.inverse_with(Level::Scalar, &mut a);
            plan.inverse_with(lv, &mut b);
            assert_eq!(bits(&a), bits(&b), "inverse n={n}");
        }
    }

    #[test]
    fn bluestein_bit_parity() {
        let Some(lv) = vector_level() else { return };
        for n in [3usize, 31, 100, 255] {
            let plan = crate::dsp::fft::FftPlan::new(n);
            let x = rand_c64(n, 7 + n as u64);
            let mut a = x.clone();
            let mut b = x.clone();
            plan.forward_with(Level::Scalar, &mut a);
            plan.forward_with(lv, &mut b);
            assert_eq!(bits(&a), bits(&b), "bluestein n={n}");
        }
    }

    #[test]
    fn cmul_conj_parity() {
        let Some(lv) = vector_level() else { return };
        for n in [1usize, 2, 3, 17, 64] {
            let a0 = rand_c64(n, 1 + n as u64);
            let b = rand_c64(n, 2 + n as u64);
            let mut s = a0.clone();
            let mut v = a0.clone();
            cmul_in_place(Level::Scalar, &mut s, &b);
            cmul_in_place(lv, &mut v, &b);
            assert_eq!(bits(&s), bits(&v), "cmul n={n}");

            let mut s = a0.clone();
            let mut v = a0.clone();
            conj_in_place(Level::Scalar, &mut s);
            conj_in_place(lv, &mut v);
            assert_eq!(bits(&s), bits(&v), "conj n={n}");

            let mut s = a0.clone();
            let mut v = a0.clone();
            conj_scale_in_place(Level::Scalar, &mut s, 1.0 / n as f64);
            conj_scale_in_place(lv, &mut v, 1.0 / n as f64);
            assert_eq!(bits(&s), bits(&v), "conj_scale n={n}");
        }
    }

    #[test]
    fn move_convert_parity() {
        let Some(lv) = vector_level() else { return };
        for n in [0usize, 1, 2, 7, 8, 9, 31, 64] {
            let a = rand_f32(n, 3 + n as u64);
            let b = rand_f32(n, 4 + n as u64);
            let (mut s, mut v) = (vec![99.0f32], vec![99.0f32]);
            interleave_f32(Level::Scalar, &a, &b, &mut s);
            interleave_f32(lv, &a, &b, &mut v);
            assert_eq!(s, v, "interleave n={n}");

            let src = s;
            let mut sa = vec![0.0f32; n];
            let mut sb = vec![0.0f32; n];
            let mut va = vec![0.0f32; n];
            let mut vb = vec![0.0f32; n];
            deinterleave_f32(Level::Scalar, &src[1..], &mut sa, &mut sb);
            deinterleave_f32(lv, &src[1..], &mut va, &mut vb);
            assert_eq!((sa.clone(), sb.clone()), (va, vb), "deinterleave");
            assert_eq!((sa, sb), (a.clone(), b.clone()), "roundtrip");

            let pairs = rand_f32(2 * n, 5 + n as u64);
            let (mut s, mut v) = (Vec::new(), Vec::new());
            widen_f32_pairs(Level::Scalar, &pairs, &mut s);
            widen_f32_pairs(lv, &pairs, &mut v);
            assert_eq!(bits(&s), bits(&v), "widen n={n}");

            let c = rand_c64(n, 6 + n as u64);
            let (mut s, mut v) = (vec![1.0f32], vec![1.0f32]);
            narrow_c64(Level::Scalar, &c, &mut s);
            narrow_c64(lv, &c, &mut v);
            assert_eq!(
                s.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "narrow n={n}"
            );
        }
    }

    #[test]
    fn quantize_parity_including_ties() {
        let Some(lv) = vector_level() else { return };
        let mut rng = Rng::new(99);
        // random values plus adversarial tie/edge cases
        let mut x: Vec<f32> =
            (0..300).map(|_| (rng.normal() * 60.0) as f32).collect();
        x.extend_from_slice(&[
            0.5, -0.5, 1.5, -1.5, 2.5, -2.5, 126.5, -126.5, 127.49, -127.49,
            300.0, -300.0, 0.0, -0.0, 0.499_999_97, -0.499_999_97,
            0.500_000_06, -0.500_000_06,
        ]);
        for inv in [1.0f32, 0.37, 119.3] {
            let (mut s, mut v) = (vec![7u8], vec![7u8]);
            quantize_i8(Level::Scalar, &x, inv, &mut s);
            quantize_i8(lv, &x, inv, &mut v);
            assert_eq!(s, v, "quantize inv={inv}");
        }
        let q: Vec<u8> = (0..=255u32).map(|b| b as u8).collect();
        for scale in [1.0f32, 0.031_25, 3.7e-3] {
            let (mut s, mut v) = (vec![0.0f32], vec![0.0f32]);
            dequantize_i8(Level::Scalar, &q, scale, &mut s);
            dequantize_i8(lv, &q, scale, &mut v);
            assert_eq!(
                s.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "dequantize scale={scale}"
            );
        }
    }

    #[test]
    fn absmax_parity() {
        let Some(lv) = vector_level() else { return };
        for n in [0usize, 1, 7, 8, 9, 64, 100] {
            let x = rand_f32(n, 11 + n as u64);
            let s = absmax(Level::Scalar, &x);
            let v = absmax(lv, &x);
            assert_eq!(s.to_bits(), v.to_bits(), "absmax n={n}");
        }
    }
}
