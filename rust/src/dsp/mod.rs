//! Signal-processing substrate: complex arithmetic and FFTs built from
//! scratch (the dependency set has no math crates).  Drives the
//! software FourierCompress codec; the "hardware" codec path instead
//! executes the XLA-compiled truncated-DFT artifact (DESIGN.md §2).

pub mod complex;
pub mod fft;
pub mod fft2d;
pub mod rfft;
pub mod simd;

pub use complex::C64;
pub use fft::FftPlan;
pub use fft2d::{fft2, ifft2};
pub use rfft::RfftPlan;
pub use simd::Level;
