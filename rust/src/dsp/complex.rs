//! Complex arithmetic in f64 (FFT internals run in double precision;
//! codec payloads are cast to f32 at the wire boundary).

use std::ops::{Add, AddAssign, Mul, MulAssign, Neg, Sub};

// repr(C) pins the (re, im) adjacent-pair layout the SIMD kernels
// rely on when viewing &[C64] as &[f64] (dsp::simd).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
#[repr(C)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    pub const ZERO: C64 = C64 { re: 0.0, im: 0.0 };
    pub const ONE: C64 = C64 { re: 1.0, im: 0.0 };

    pub fn new(re: f64, im: f64) -> C64 {
        C64 { re, im }
    }

    pub fn from_re(re: f64) -> C64 {
        C64 { re, im: 0.0 }
    }

    /// e^{i theta}
    pub fn cis(theta: f64) -> C64 {
        let (s, c) = theta.sin_cos();
        C64 { re: c, im: s }
    }

    pub fn conj(self) -> C64 {
        C64 { re: self.re, im: -self.im }
    }

    pub fn norm_sq(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    pub fn abs(self) -> f64 {
        self.norm_sq().sqrt()
    }

    pub fn scale(self, k: f64) -> C64 {
        C64 { re: self.re * k, im: self.im * k }
    }
}

impl Add for C64 {
    type Output = C64;
    fn add(self, o: C64) -> C64 {
        C64 { re: self.re + o.re, im: self.im + o.im }
    }
}

impl AddAssign for C64 {
    fn add_assign(&mut self, o: C64) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl Sub for C64 {
    type Output = C64;
    fn sub(self, o: C64) -> C64 {
        C64 { re: self.re - o.re, im: self.im - o.im }
    }
}

impl Mul for C64 {
    type Output = C64;
    fn mul(self, o: C64) -> C64 {
        C64 {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

impl MulAssign for C64 {
    fn mul_assign(&mut self, o: C64) {
        *self = *self * o;
    }
}

impl Neg for C64 {
    type Output = C64;
    fn neg(self) -> C64 {
        C64 { re: -self.re, im: -self.im }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = C64::new(1.0, 2.0);
        let b = C64::new(3.0, -1.0);
        assert_eq!(a + b, C64::new(4.0, 1.0));
        assert_eq!(a - b, C64::new(-2.0, 3.0));
        assert_eq!(a * b, C64::new(5.0, 5.0));
        assert_eq!(a.conj(), C64::new(1.0, -2.0));
        assert!((a.abs() - 5.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn cis_unit_circle() {
        for k in 0..16 {
            let t = k as f64 * std::f64::consts::PI / 8.0;
            let c = C64::cis(t);
            assert!((c.abs() - 1.0).abs() < 1e-12);
        }
        let c = C64::cis(std::f64::consts::PI);
        assert!((c.re + 1.0).abs() < 1e-12 && c.im.abs() < 1e-12);
    }
}
