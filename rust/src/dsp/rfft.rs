//! Real-input FFT: the length-N transform of a real signal computed
//! through ONE length-N/2 complex FFT plus an O(N) split/merge twiddle
//! pass.  This generalizes the row-pair trick the fourier codec used
//! to inline (two real rows as re/im of one complex FFT) to a single
//! row, which is what both directions of the codec actually need:
//!
//! * **forward** — pack `x[2j], x[2j+1]` as the re/im of a half-length
//!   complex signal `z`, transform, then split each output bin by
//!   conjugate symmetry:
//!
//!   ```text
//!   E[k] = (Z[k] + conj(Z[m-k])) / 2          (FFT of even samples)
//!   O[k] = -i (Z[k] - conj(Z[m-k])) / 2       (FFT of odd samples)
//!   X[k] = E[k] + w^k O[k],   w = e^{-2πi/N},  m = N/2
//!   ```
//!
//! * **inverse** — un-split (`E[k] = (X[k] + conj(X[m-k]))/2`,
//!   `O[k] = conj(w^k) (X[k] - conj(X[m-k]))/2`), merge `Z[k] = E[k] +
//!   i O[k]`, one half-length inverse FFT, and the output's re/im
//!   lanes interleave back into the N real samples.
//!
//! A real N-point transform therefore costs an N/2-point complex FFT
//! plus O(N) — about half the butterflies of the complex transform the
//! decompress row pass used to run per row.  Only the `k <= N/2` half
//! spectrum is materialised; the upper half is implied by conjugate
//! symmetry (`X[N-k] = conj(X[k])`).
//!
//! Odd N falls back to a full complex transform of the widened signal
//! (no half-split exists); those axis lengths only occur in tests and
//! degenerate geometries — real hidden dimensions are even.

use super::complex::C64;
use super::fft::FftPlan;
use super::fft2d;
use super::simd::{self, Level};
use std::f64::consts::PI;
use std::sync::Arc;

#[derive(Debug)]
enum RKind {
    /// Even N: half-length complex plan + split/merge twiddles
    /// `tw[k] = e^{-2πik/N}` for `k = 0..=N/2`.
    Even { m: usize, half: Arc<FftPlan>, tw: Vec<C64> },
    /// Odd N (or 1): full-length complex fallback.
    Odd { full: Arc<FftPlan> },
}

/// Planned real-input FFT of a fixed length.  Shared through the
/// [`fft2d::rplan`] process cache and the per-engine map in
/// [`crate::codec::CodecEngine`].
#[derive(Debug)]
pub struct RfftPlan {
    n: usize,
    kind: RKind,
}

impl RfftPlan {
    pub fn new(n: usize) -> RfftPlan {
        assert!(n > 0);
        if n % 2 == 0 {
            let m = n / 2;
            let mut tw: Vec<C64> =
                (0..=m).map(|k| C64::cis(-2.0 * PI * k as f64 / n as f64)).collect();
            // pin the exactly-representable roots (cis(-π) carries a
            // ~1e-16 imaginary dust that would leak into X[m])
            tw[0] = C64::ONE;
            tw[m] = C64::new(-1.0, 0.0);
            RfftPlan { n, kind: RKind::Even { m, half: fft2d::plan(m), tw } }
        } else {
            RfftPlan { n, kind: RKind::Odd { full: fft2d::plan(n) } }
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of independent spectrum bins: `n/2 + 1`.
    pub fn half_len(&self) -> usize {
        self.n / 2 + 1
    }

    /// Bytes of precomputed tables held by this plan (twiddles only;
    /// the inner complex plan is shared and accounted separately).
    pub fn table_bytes(&self) -> usize {
        match &self.kind {
            RKind::Even { tw, .. } => tw.len() * std::mem::size_of::<C64>(),
            RKind::Odd { .. } => 0,
        }
    }

    /// Stage 1 of the forward transform: pack the real row into `z`
    /// (cleared first) and run the inner complex FFT.  Afterwards
    /// [`RfftPlan::bin`] reads any spectrum value `X[k]`, `k <= n/2`.
    ///
    /// Split into pack+bin (rather than always materialising the full
    /// half spectrum) because the codec's row pass keeps only K_D bins
    /// per row — the split twiddle work runs on the kept bins only.
    pub fn spectrum_into(&self, lv: Level, x: &[f32], z: &mut Vec<C64>) {
        assert_eq!(x.len(), self.n);
        z.clear();
        match &self.kind {
            RKind::Even { half, .. } => {
                simd::widen_f32_pairs(lv, x, z);
                half.forward_with(lv, z);
            }
            RKind::Odd { full } => {
                z.extend(x.iter().map(|&v| C64::from_re(v as f64)));
                full.forward_with(lv, z);
            }
        }
    }

    /// Spectrum bin `X[k]` (`k <= n/2`) from a buffer prepared by
    /// [`RfftPlan::spectrum_into`].
    #[inline]
    pub fn bin(&self, z: &[C64], k: usize) -> C64 {
        match &self.kind {
            RKind::Odd { .. } => z[k],
            RKind::Even { m, tw, .. } => {
                let m = *m;
                let a = z[k % m];
                let b = z[(m - k % m) % m].conj();
                let e = (a + b).scale(0.5);
                let d = (a - b).scale(0.5);
                // -i * d
                let o = C64::new(d.im, -d.re);
                e + tw[k] * o
            }
        }
    }

    /// Full forward half spectrum: `out[k] = X[k]` for `k = 0..=n/2`
    /// (cleared first; `z` is the complex scratch).
    pub fn forward_into(&self, lv: Level, x: &[f32], z: &mut Vec<C64>,
                        out: &mut Vec<C64>) {
        self.spectrum_into(lv, x, z);
        out.clear();
        out.reserve(self.half_len());
        for k in 0..self.half_len() {
            out.push(self.bin(z, k));
        }
    }

    /// Inverse transform from the half spectrum: `spec[k]` must hold
    /// `X[k]` for `k = 0..half_len()` (longer slices are fine — the
    /// codec hands whole spectrum rows); writes the `n` real samples
    /// into `dst` as f32.  `work` is complex scratch.
    pub fn inverse_into(&self, lv: Level, spec: &[C64], work: &mut Vec<C64>,
                        dst: &mut [f32]) {
        assert!(spec.len() >= self.half_len());
        assert_eq!(dst.len(), self.n);
        match &self.kind {
            RKind::Even { m, half, tw } => {
                let m = *m;
                work.clear();
                work.reserve(m);
                for k in 0..m {
                    let a = spec[k];
                    let b = spec[m - k].conj();
                    let e = (a + b).scale(0.5);
                    let d = (a - b).scale(0.5);
                    // O[k] = conj(w^k) * d;  Z[k] = E[k] + i O[k]
                    let o = d * tw[k].conj();
                    work.push(C64::new(e.re - o.im, e.im + o.re));
                }
                half.inverse_with(lv, work);
                // z[j] = (x[2j], x[2j+1]): the interleaved narrow IS
                // the real signal
                simd::narrow_c64_slice(lv, work, dst);
            }
            RKind::Odd { full } => {
                let n = self.n;
                work.clear();
                work.resize(n, C64::ZERO);
                work[0] = spec[0];
                for k in 1..=n / 2 {
                    work[k] = spec[k];
                    work[n - k] = spec[k].conj();
                }
                full.inverse_with(lv, work);
                for (w, d) in work.iter().zip(dst.iter_mut()) {
                    *d = w.re as f32;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::fft::dft_direct;
    use crate::util::rng::Rng;

    fn rand_row(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn direct_spectrum(x: &[f32]) -> Vec<C64> {
        let cx: Vec<C64> = x.iter().map(|&v| C64::from_re(v as f64)).collect();
        dft_direct(&cx)
    }

    #[test]
    fn forward_matches_direct_dft() {
        // even pow2, even bluestein, odd, tiny
        for n in [2usize, 4, 8, 64, 256, 6, 10, 48, 100, 2048, 1, 3, 7, 31] {
            let x = rand_row(n, n as u64);
            let plan = RfftPlan::new(n);
            let mut z = Vec::new();
            let mut out = Vec::new();
            plan.forward_into(Level::Scalar, &x, &mut z, &mut out);
            assert_eq!(out.len(), n / 2 + 1);
            let want = direct_spectrum(&x);
            for (k, got) in out.iter().enumerate() {
                let err = (*got - want[k]).abs();
                assert!(err < 1e-8 * (n as f64), "n={n} k={k} err={err}");
            }
        }
    }

    #[test]
    fn kept_bin_access_covers_whole_half_spectrum() {
        let n = 96;
        let x = rand_row(n, 9);
        let plan = RfftPlan::new(n);
        let mut z = Vec::new();
        plan.spectrum_into(Level::Scalar, &x, &mut z);
        let want = direct_spectrum(&x);
        // every k <= n/2 individually (the codec gathers sparse bins)
        for k in 0..=n / 2 {
            assert!((plan.bin(&z, k) - want[k]).abs() < 1e-9 * n as f64,
                    "k={k}");
        }
    }

    #[test]
    fn inverse_roundtrips() {
        for n in [2usize, 4, 8, 64, 6, 48, 100, 256, 1, 3, 31] {
            let x = rand_row(n, 100 + n as u64);
            let plan = RfftPlan::new(n);
            let mut z = Vec::new();
            let mut spec = Vec::new();
            plan.forward_into(Level::Scalar, &x, &mut z, &mut spec);
            let mut work = Vec::new();
            let mut back = vec![0.0f32; n];
            plan.inverse_into(Level::Scalar, &spec, &mut work, &mut back);
            for (a, b) in x.iter().zip(&back) {
                assert!((a - b).abs() < 1e-5, "n={n}");
            }
        }
    }

    #[test]
    fn inverse_accepts_full_spectrum_rows() {
        // the codec hands a whole `cols`-long spectrum row; the kernel
        // must only read the first n/2+1 bins
        let n = 48;
        let x = rand_row(n, 3);
        let plan = RfftPlan::new(n);
        let mut full: Vec<C64> = direct_spectrum(&x);
        // poison the mirrored half: must not be read
        for v in full.iter_mut().skip(n / 2 + 1) {
            *v = C64::new(1e30, -1e30);
        }
        let mut work = Vec::new();
        let mut back = vec![0.0f32; n];
        plan.inverse_into(Level::Scalar, &full, &mut work, &mut back);
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn nyquist_and_dc_bins_are_real() {
        let n = 64;
        let x = rand_row(n, 5);
        let plan = RfftPlan::new(n);
        let mut z = Vec::new();
        plan.spectrum_into(Level::Scalar, &x, &mut z);
        assert!(plan.bin(&z, 0).im.abs() < 1e-12, "DC");
        assert!(plan.bin(&z, n / 2).im.abs() < 1e-12, "Nyquist");
    }

    #[test]
    fn half_len_accounting() {
        assert_eq!(RfftPlan::new(8).half_len(), 5);
        assert_eq!(RfftPlan::new(7).half_len(), 4);
        assert_eq!(RfftPlan::new(1).half_len(), 1);
        assert_eq!(RfftPlan::new(2).half_len(), 2);
    }
}
