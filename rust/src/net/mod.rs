//! Simulated wireless channel: token-bucket bandwidth shaping +
//! propagation latency, wrapped around byte transfers, plus
//! deterministic frame-loss injection ([`DropPlan`]).
//!
//! Three uses: (1) the live coordinator's shaped transport wraps any
//! framed link in a [`Channel`] to emulate 6G link rates on loopback
//! or in-proc; (2) the DES (Fig 7) uses [`Channel::transfer_time`]
//! analytically; (3) the stream-resync tests lose selected frames via
//! a [`DropPlan`] instead of a lossy network.

use std::time::Duration;

/// Serialisation chunk for [`Channel::throttle`]: shaped links sleep
/// per chunk instead of one monolithic sleep, so a multi-MB transfer
/// (a large-bucket keyframe, an uncompressed baseline) yields the
/// thread repeatedly and interleaves with the other connections this
/// process is shaping instead of parking for whole seconds.
pub const THROTTLE_CHUNK_BYTES: usize = 256 * 1024;

#[derive(Debug, Clone, Copy)]
pub struct Channel {
    /// Link rate in bits per second (0 = unlimited).
    pub bits_per_sec: f64,
    /// One-way propagation latency.
    pub latency: Duration,
}

impl Channel {
    pub fn gbps(rate: f64, latency_us: u64) -> Channel {
        Channel {
            bits_per_sec: rate * 1e9,
            latency: Duration::from_micros(latency_us),
        }
    }

    pub fn unlimited() -> Channel {
        Channel { bits_per_sec: 0.0, latency: Duration::ZERO }
    }

    /// Whether this channel actually delays anything — false for
    /// [`Channel::unlimited`], letting callers (the device client's
    /// TCP connect path) skip the shaping decorator entirely on
    /// unshaped links.
    pub fn is_shaping(&self) -> bool {
        self.bits_per_sec > 0.0 || self.latency > Duration::ZERO
    }

    /// Time for `bytes` to cross the link (serialisation + propagation).
    pub fn transfer_time(&self, bytes: usize) -> Duration {
        let ser = if self.bits_per_sec > 0.0 {
            Duration::from_secs_f64(bytes as f64 * 8.0 / self.bits_per_sec)
        } else {
            Duration::ZERO
        };
        ser + self.latency
    }

    /// Number of per-chunk sleeps [`Channel::throttle`] performs for
    /// `bytes` (0 for an unshaped link or an empty transfer).
    pub fn throttle_chunks(&self, bytes: usize) -> usize {
        if self.bits_per_sec <= 0.0 || bytes == 0 {
            0
        } else {
            bytes.div_ceil(THROTTLE_CHUNK_BYTES)
        }
    }

    /// Sleep for the simulated transfer time (live-coordinator use).
    /// Serialisation is slept in [`THROTTLE_CHUNK_BYTES`] chunks — the
    /// total equals [`Channel::transfer_time`], but the thread wakes
    /// between chunks so concurrent shaped connections interleave.
    pub fn throttle(&self, bytes: usize) {
        if self.latency > Duration::ZERO {
            std::thread::sleep(self.latency);
        }
        if self.bits_per_sec <= 0.0 || bytes == 0 {
            return;
        }
        let mut remaining = bytes;
        while remaining > 0 {
            let chunk = remaining.min(THROTTLE_CHUNK_BYTES);
            std::thread::sleep(Duration::from_secs_f64(
                chunk as f64 * 8.0 / self.bits_per_sec));
            remaining -= chunk;
        }
    }
}

/// Deterministic time-varying link for the shaped transport: a
/// sequence of (frame count, [`Channel`]) phases applied by 0-based
/// send index, the last phase holding forever.  Index-based — not
/// wall-clock — so a test that says "frames 4..10 cross a collapsed
/// link" means exactly those frames on every run; the adaptive
/// rate-control suite drives its throttle step-down/recovery with
/// one of these.
#[derive(Debug, Clone)]
pub struct ChannelTrace {
    phases: Vec<(u64, Channel)>,
    sent: u64,
}

impl ChannelTrace {
    /// A trace of `(frames, channel)` phases.  Must be non-empty; the
    /// last phase's channel governs every send past the trace's end.
    pub fn new(phases: &[(u64, Channel)]) -> ChannelTrace {
        assert!(!phases.is_empty(), "empty channel trace");
        ChannelTrace { phases: phases.to_vec(), sent: 0 }
    }

    /// A single never-ending phase (equivalent to a plain `Channel`).
    pub fn constant(ch: Channel) -> ChannelTrace {
        ChannelTrace::new(&[(1, ch)])
    }

    /// The channel governing the next send, advancing the send index.
    pub fn next_channel(&mut self) -> Channel {
        let ch = self.channel_at(self.sent);
        self.sent += 1;
        ch
    }

    /// The channel a given 0-based send index crosses.
    pub fn channel_at(&self, index: u64) -> Channel {
        let mut start = 0u64;
        for &(frames, ch) in &self.phases {
            if index < start + frames {
                return ch;
            }
            start += frames;
        }
        self.phases.last().expect("non-empty trace").1
    }

    /// Frames sent through the trace so far.
    pub fn offered(&self) -> u64 {
        self.sent
    }
}

/// Deterministic frame-drop schedule for the shaped transport: the
/// frames whose 0-based send index appears in the plan are silently
/// discarded after "crossing" the link.  Deterministic by
/// construction — a test that drops frame 2 drops exactly frame 2 on
/// every run, so resync behaviour is assertable, not probabilistic.
#[derive(Debug, Clone, Default)]
pub struct DropPlan {
    indices: Vec<u64>,
    next: u64,
    dropped: u64,
}

impl DropPlan {
    /// Drop nothing (the plan every production link uses).
    pub fn none() -> DropPlan {
        DropPlan::default()
    }

    /// Drop exactly the frames at these 0-based send indices.
    pub fn at(indices: &[u64]) -> DropPlan {
        DropPlan { indices: indices.to_vec(), next: 0, dropped: 0 }
    }

    /// Advance the send counter; true means "lose this frame".
    pub fn should_drop(&mut self) -> bool {
        let i = self.next;
        self.next += 1;
        if self.indices.contains(&i) {
            self.dropped += 1;
            true
        } else {
            false
        }
    }

    /// Frames lost so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Frames offered so far (dropped or delivered).
    pub fn offered(&self) -> u64 {
        self.next
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drop_plan_is_deterministic_by_index() {
        let mut p = DropPlan::at(&[0, 2, 2, 5]);
        let got: Vec<bool> = (0..7).map(|_| p.should_drop()).collect();
        assert_eq!(got, vec![true, false, true, false, false, true, false]);
        assert_eq!(p.dropped(), 3);
        assert_eq!(p.offered(), 7);
        let mut none = DropPlan::none();
        assert!((0..100).all(|_| !none.should_drop()));
        assert_eq!(none.dropped(), 0);
    }

    #[test]
    fn transfer_time_scales_linearly() {
        let ch = Channel::gbps(1.0, 0);
        let t1 = ch.transfer_time(125_000_000); // 1 Gbit
        assert!((t1.as_secs_f64() - 1.0).abs() < 1e-9);
        let t2 = ch.transfer_time(250_000_000);
        assert!((t2.as_secs_f64() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn latency_added() {
        let ch = Channel::gbps(10.0, 500);
        let t = ch.transfer_time(0);
        assert_eq!(t, Duration::from_micros(500));
    }

    #[test]
    fn unlimited_is_zero() {
        assert_eq!(Channel::unlimited().transfer_time(1 << 30), Duration::ZERO);
        assert!(!Channel::unlimited().is_shaping());
        assert!(Channel::gbps(1.0, 0).is_shaping());
        assert!(Channel::gbps(0.0, 50).is_shaping());
    }

    #[test]
    fn faster_link_is_faster() {
        let b = 10_000_000usize;
        assert!(Channel::gbps(10.0, 0).transfer_time(b)
                < Channel::gbps(1.0, 0).transfer_time(b));
    }

    #[test]
    fn throttle_chunk_count() {
        let ch = Channel::gbps(1.0, 0);
        assert_eq!(ch.throttle_chunks(0), 0);
        assert_eq!(ch.throttle_chunks(1), 1);
        assert_eq!(ch.throttle_chunks(THROTTLE_CHUNK_BYTES), 1);
        assert_eq!(ch.throttle_chunks(THROTTLE_CHUNK_BYTES + 1), 2);
        // a 5 MiB transfer interleaves in 20 chunks rather than one
        // monolithic sleep
        assert_eq!(ch.throttle_chunks(5 * 1024 * 1024), 20);
        // unshaped links never sleep for serialisation
        assert_eq!(Channel::unlimited().throttle_chunks(1 << 30), 0);
    }

    #[test]
    fn channel_trace_phases_by_send_index_and_holds_last() {
        let fast = Channel::gbps(1.0, 0);
        let slow = Channel::gbps(0.001, 7);
        let mut t = ChannelTrace::new(&[(2, fast), (3, slow)]);
        let rates: Vec<f64> =
            (0..8).map(|_| t.next_channel().bits_per_sec).collect();
        assert_eq!(rates[..2], [1e9, 1e9]);
        // phase 2, then the last phase holds forever
        assert!(rates[2..].iter().all(|&r| (r - 1e6).abs() < 1.0),
                "rates {rates:?}");
        assert_eq!(t.offered(), 8);
        // index probe does not advance
        assert_eq!(t.channel_at(0).bits_per_sec, 1e9);
        assert_eq!(t.channel_at(100).latency, Duration::from_micros(7));
        assert_eq!(t.offered(), 8);
        // constant trace == the plain channel
        let mut c = ChannelTrace::constant(fast);
        for _ in 0..5 {
            assert_eq!(c.next_channel().bits_per_sec, 1e9);
        }
    }

    #[test]
    fn chunked_throttle_totals_transfer_time() {
        // fast link so the test stays quick: 1 MiB at 1 Gbps ~ 8.4 ms,
        // slept in 4 chunks
        let ch = Channel::gbps(1.0, 0);
        let bytes = 1024 * 1024;
        assert_eq!(ch.throttle_chunks(bytes), 4);
        let t0 = std::time::Instant::now();
        ch.throttle(bytes);
        let dt = t0.elapsed();
        // 10us slack: per-chunk Duration rounding never exceeds it,
        // while OS sleep overshoot keeps the real total above anyway
        let floor = ch.transfer_time(bytes)
            .saturating_sub(Duration::from_micros(10));
        assert!(dt >= floor, "slept {dt:?} < modelled {floor:?}");
    }
}
