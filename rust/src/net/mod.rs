//! Simulated wireless channel: token-bucket bandwidth shaping +
//! propagation latency, wrapped around byte transfers.
//!
//! Two uses: (1) the live coordinator wraps its TCP streams in a
//! [`Channel`] to emulate 6G link rates on loopback; (2) the DES
//! (Fig 7) uses [`Channel::transfer_time`] analytically.

use std::time::Duration;

#[derive(Debug, Clone, Copy)]
pub struct Channel {
    /// Link rate in bits per second (0 = unlimited).
    pub bits_per_sec: f64,
    /// One-way propagation latency.
    pub latency: Duration,
}

impl Channel {
    pub fn gbps(rate: f64, latency_us: u64) -> Channel {
        Channel {
            bits_per_sec: rate * 1e9,
            latency: Duration::from_micros(latency_us),
        }
    }

    pub fn unlimited() -> Channel {
        Channel { bits_per_sec: 0.0, latency: Duration::ZERO }
    }

    /// Time for `bytes` to cross the link (serialisation + propagation).
    pub fn transfer_time(&self, bytes: usize) -> Duration {
        let ser = if self.bits_per_sec > 0.0 {
            Duration::from_secs_f64(bytes as f64 * 8.0 / self.bits_per_sec)
        } else {
            Duration::ZERO
        };
        ser + self.latency
    }

    /// Sleep for the simulated transfer time (live-coordinator use).
    pub fn throttle(&self, bytes: usize) {
        let d = self.transfer_time(bytes);
        if d > Duration::ZERO {
            std::thread::sleep(d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_scales_linearly() {
        let ch = Channel::gbps(1.0, 0);
        let t1 = ch.transfer_time(125_000_000); // 1 Gbit
        assert!((t1.as_secs_f64() - 1.0).abs() < 1e-9);
        let t2 = ch.transfer_time(250_000_000);
        assert!((t2.as_secs_f64() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn latency_added() {
        let ch = Channel::gbps(10.0, 500);
        let t = ch.transfer_time(0);
        assert_eq!(t, Duration::from_micros(500));
    }

    #[test]
    fn unlimited_is_zero() {
        assert_eq!(Channel::unlimited().transfer_time(1 << 30), Duration::ZERO);
    }

    #[test]
    fn faster_link_is_faster() {
        let b = 10_000_000usize;
        assert!(Channel::gbps(10.0, 0).transfer_time(b)
                < Channel::gbps(1.0, 0).transfer_time(b));
    }
}
