//! The codec engine: per-session/per-worker state that makes the
//! steady-state compression path allocation-free.
//!
//! The paper's headline claim is *speed* — FourierCompress wins
//! because the transform is cheap on real hardware.  The one-shot
//! [`super::Codec::compress`] path used to re-allocate every scratch
//! buffer and re-derive the centred frequency index sets on every
//! call, once per generated token.  A [`CodecEngine`] hoists all of
//! that out of the loop:
//!
//! * **FFT plans** — a per-engine `HashMap<usize, Arc<FftPlan>>` with
//!   no lock at all; a miss falls back to the shared
//!   [`crate::dsp::fft2d::plan`] tier (an `RwLock`, read-locked on the
//!   hit path) and memoises the `Arc` locally, so after warm-up a
//!   decode loop never touches a lock.
//! * **Frequency index sets** — `freq_indices(n, k)` results cached
//!   per `(n, k)`; the (S, D, K_S, K_D) tuple of a bucket maps to two
//!   such entries.
//! * **Scratch arena** — the `narrow`/`z`/`col`/`block`/`spec`
//!   complex buffers and the f32/u32 scratch the codecs need, grown
//!   monotonically and reused across calls.  After the first call at
//!   a given shape, `compress_into`/`decompress_into` perform zero
//!   heap allocation (the engine-reuse test in
//!   `tests/codec_engine.rs` pins this down via
//!   [`CodecEngine::scratch_bytes`]).
//!
//! Ownership model (see rust/README.md §Codec engine architecture):
//! the device client owns one engine per session; the edge server owns
//! one per connection handler; the eval harness and the legacy
//! one-shot API share a thread-local engine.

use crate::dsp::complex::C64;
use crate::dsp::fft::FftPlan;
use crate::dsp::fft2d;
use crate::dsp::rfft::RfftPlan;
use crate::dsp::simd::{self, Level};
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

/// Accumulated per-stage wall time for the codec hot path, recorded by
/// [`CodecEngine`] when stage timing is enabled (zero-cost when it is
/// not: one `Option` branch per stage).  The stages mirror the
/// pipeline: row FFTs, column FFTs, conjugate-symmetric pack/scatter,
/// int8 quantize/dequantize, and wire-byte moves.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageTimes {
    pub row_fft: Duration,
    pub col_fft: Duration,
    pub pack: Duration,
    pub quant: Duration,
    pub wire: Duration,
}

pub struct CodecEngine {
    plans: HashMap<usize, Arc<FftPlan>>,
    rplans: HashMap<usize, Arc<RfftPlan>>,
    indices: HashMap<(usize, usize), Arc<Vec<usize>>>,
    /// Kernel dispatch level every transform/pack/quantize call on this
    /// engine uses.  Defaults to the process-detected best level;
    /// parity tests pin [`Level::Scalar`] per engine (no global state).
    pub(crate) simd: Level,
    pub(crate) timer: Option<Box<StageTimes>>,
    // scratch arena — pub(crate) so the codec impls can split-borrow
    // individual buffers without going through &mut self methods.
    pub(crate) narrow: Vec<C64>,
    pub(crate) z: Vec<C64>,
    pub(crate) col: Vec<C64>,
    pub(crate) block: Vec<C64>,
    pub(crate) spec: Vec<C64>,
    pub(crate) half: Vec<C64>,
    pub(crate) floats: Vec<f32>,
    pub(crate) bytes: Vec<u8>,
    pub(crate) indices32: Vec<u32>,
}

impl Default for CodecEngine {
    fn default() -> CodecEngine {
        CodecEngine::new()
    }
}

impl CodecEngine {
    pub fn new() -> CodecEngine {
        CodecEngine {
            plans: HashMap::new(),
            rplans: HashMap::new(),
            indices: HashMap::new(),
            simd: simd::detect(),
            timer: None,
            narrow: Vec::new(),
            z: Vec::new(),
            col: Vec::new(),
            block: Vec::new(),
            spec: Vec::new(),
            half: Vec::new(),
            floats: Vec::new(),
            bytes: Vec::new(),
            indices32: Vec::new(),
        }
    }

    /// Planned transform for axis length `n`: per-engine map first
    /// (no lock), shared tier on miss.
    pub fn plan(&mut self, n: usize) -> Arc<FftPlan> {
        self.plans.entry(n).or_insert_with(|| fft2d::plan(n)).clone()
    }

    /// Planned real-input transform for axis length `n` (same two-tier
    /// caching as [`CodecEngine::plan`]).
    pub fn rplan(&mut self, n: usize) -> Arc<RfftPlan> {
        self.rplans.entry(n).or_insert_with(|| fft2d::rplan(n)).clone()
    }

    /// Kernel level this engine dispatches at.
    pub fn simd_level(&self) -> Level {
        self.simd
    }

    /// Enable (process-detected level) or disable (scalar reference
    /// path) vector kernels for this engine.  Per-engine so a parity
    /// test can run both paths side by side.
    pub fn set_simd_enabled(&mut self, enabled: bool) {
        self.simd = if enabled { simd::detect() } else { Level::Scalar };
    }

    /// Start (or restart, zeroed) per-stage timing on this engine.
    pub fn enable_stage_timing(&mut self) {
        self.timer = Some(Box::new(StageTimes::default()));
    }

    /// Stop stage timing and drop the accumulator.
    pub fn disable_stage_timing(&mut self) {
        self.timer = None;
    }

    /// Accumulated stage times since [`enable_stage_timing`]
    /// (`None` when timing is off).
    ///
    /// [`enable_stage_timing`]: CodecEngine::enable_stage_timing
    pub fn stage_times(&self) -> Option<StageTimes> {
        self.timer.as_deref().copied()
    }

    /// Cached centred (conjugate-closed) frequency index set for
    /// keeping `k` of `n` bins.
    pub fn indices(&mut self, n: usize, k: usize) -> Arc<Vec<usize>> {
        self.indices
            .entry((n, k))
            .or_insert_with(|| Arc::new(super::freq_indices(n, k)))
            .clone()
    }

    /// Pre-warm the engine for a (rows, cols, ks, kd) block shape so
    /// the first request of a session pays no plan/index cost either.
    pub fn warm(&mut self, rows: usize, cols: usize, ks: usize, kd: usize) {
        self.plan(rows);
        self.plan(cols);
        self.rplan(cols);
        self.indices(rows, ks);
        self.indices(cols, kd);
    }

    pub fn cached_plans(&self) -> usize {
        self.plans.len()
    }

    pub fn cached_index_sets(&self) -> usize {
        self.indices.len()
    }

    /// Release all scratch capacity (the plan/index caches stay — they
    /// are shared `Arc`s and cheap).  The scratch arena otherwise
    /// retains its largest-ever footprint, which is the point for a
    /// per-session decode loop but worth trimming for long-lived
    /// engines that served one unusually large shape — e.g. the
    /// thread-local engine behind the legacy one-shot API.
    pub fn shrink_scratch(&mut self) {
        self.narrow = Vec::new();
        self.z = Vec::new();
        self.col = Vec::new();
        self.block = Vec::new();
        self.spec = Vec::new();
        self.half = Vec::new();
        self.floats = Vec::new();
        self.bytes = Vec::new();
        self.indices32 = Vec::new();
    }

    /// Total bytes of scratch capacity currently held.  The
    /// engine-reuse invariant: repeated `compress_into` calls on the
    /// same shape must not grow this after warm-up.
    pub fn scratch_bytes(&self) -> usize {
        (self.narrow.capacity()
            + self.z.capacity()
            + self.col.capacity()
            + self.block.capacity()
            + self.spec.capacity()
            + self.half.capacity())
            * std::mem::size_of::<C64>()
            + self.floats.capacity() * std::mem::size_of::<f32>()
            + self.bytes.capacity()
            + self.indices32.capacity() * std::mem::size_of::<u32>()
    }
}

/// Reset a complex scratch buffer to `n` zeros without shrinking its
/// capacity (the codecs' previous `vec![C64::ZERO; n]` semantics,
/// minus the allocation).
pub(crate) fn zeroed(buf: &mut Vec<C64>, n: usize) {
    buf.clear();
    buf.resize(n, C64::ZERO);
}

/// Time `$body` into the named [`StageTimes`] field when `$timer`
/// (an `&mut Option<Box<StageTimes>>`, usually split-borrowed out of a
/// [`CodecEngine`]) is engaged; one branch and no clock read when it
/// is not.
macro_rules! stage {
    ($timer:expr, $field:ident, $body:expr) => {{
        let __t0 = if $timer.is_some() {
            Some(std::time::Instant::now())
        } else {
            None
        };
        let __r = $body;
        if let (Some(__t), Some(__t0)) = ($timer.as_deref_mut(), __t0) {
            __t.$field += __t0.elapsed();
        }
        __r
    }};
}
pub(crate) use stage;

thread_local! {
    static THREAD_ENGINE: RefCell<CodecEngine> = RefCell::new(CodecEngine::new());
}

/// Run `f` with this thread's shared engine — the backing store for
/// the legacy one-shot `Codec::compress`/`decompress` API.  Callers
/// must not re-enter (codec `_into` implementations receive their
/// engine explicitly and never call back into this).
pub fn with_thread_engine<R>(f: impl FnOnce(&mut CodecEngine) -> R) -> R {
    THREAD_ENGINE.with(|e| f(&mut e.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_and_index_caches_fill_once() {
        let mut eng = CodecEngine::new();
        assert_eq!(eng.cached_plans(), 0);
        let p1 = eng.plan(64);
        let p2 = eng.plan(64);
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(eng.cached_plans(), 1);

        let i1 = eng.indices(96, 13);
        let i2 = eng.indices(96, 13);
        assert!(Arc::ptr_eq(&i1, &i2));
        assert_eq!(i1.as_slice(), super::super::freq_indices(96, 13).as_slice());
        assert_eq!(eng.cached_index_sets(), 1);
    }

    #[test]
    fn warm_prefills_both_axes() {
        let mut eng = CodecEngine::new();
        eng.warm(64, 128, 9, 15);
        assert_eq!(eng.cached_plans(), 2);
        assert_eq!(eng.cached_index_sets(), 2);
    }

    #[test]
    fn zeroed_reuses_capacity() {
        let mut buf = Vec::new();
        zeroed(&mut buf, 256);
        assert!(buf.iter().all(|c| *c == C64::ZERO));
        buf[3] = C64::ONE;
        let cap = buf.capacity();
        zeroed(&mut buf, 128);
        assert_eq!(buf.len(), 128);
        assert_eq!(buf.capacity(), cap, "shrank capacity");
        assert!(buf.iter().all(|c| *c == C64::ZERO));
    }

    #[test]
    fn shrink_scratch_releases_arena_but_keeps_caches() {
        let mut eng = CodecEngine::new();
        eng.plan(32);
        zeroed(&mut eng.spec, 1024);
        assert!(eng.scratch_bytes() > 0);
        eng.shrink_scratch();
        assert_eq!(eng.scratch_bytes(), 0);
        assert_eq!(eng.cached_plans(), 1);
    }

    #[test]
    fn thread_engine_persists_across_calls() {
        with_thread_engine(|e| {
            e.plan(48);
        });
        let n = with_thread_engine(|e| e.cached_plans());
        assert!(n >= 1);
    }
}
