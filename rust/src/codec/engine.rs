//! The codec engine: per-session/per-worker state that makes the
//! steady-state compression path allocation-free.
//!
//! The paper's headline claim is *speed* — FourierCompress wins
//! because the transform is cheap on real hardware.  The one-shot
//! [`super::Codec::compress`] path used to re-allocate every scratch
//! buffer and re-derive the centred frequency index sets on every
//! call, once per generated token.  A [`CodecEngine`] hoists all of
//! that out of the loop:
//!
//! * **FFT plans** — a per-engine `HashMap<usize, Arc<FftPlan>>` with
//!   no lock at all; a miss falls back to the shared
//!   [`crate::dsp::fft2d::plan`] tier (an `RwLock`, read-locked on the
//!   hit path) and memoises the `Arc` locally, so after warm-up a
//!   decode loop never touches a lock.
//! * **Frequency index sets** — `freq_indices(n, k)` results cached
//!   per `(n, k)`; the (S, D, K_S, K_D) tuple of a bucket maps to two
//!   such entries.
//! * **Scratch arena** — the `narrow`/`z`/`col`/`block`/`spec`
//!   complex buffers and the f32/u32 scratch the codecs need, grown
//!   monotonically and reused across calls.  After the first call at
//!   a given shape, `compress_into`/`decompress_into` perform zero
//!   heap allocation (the engine-reuse test in
//!   `tests/codec_engine.rs` pins this down via
//!   [`CodecEngine::scratch_bytes`]).
//!
//! Ownership model (see rust/README.md §Codec engine architecture):
//! the device client owns one engine per session; the edge server owns
//! one per connection handler; the eval harness and the legacy
//! one-shot API share a thread-local engine.

use crate::dsp::complex::C64;
use crate::dsp::fft::FftPlan;
use crate::dsp::fft2d;
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;

#[derive(Default)]
pub struct CodecEngine {
    plans: HashMap<usize, Arc<FftPlan>>,
    indices: HashMap<(usize, usize), Arc<Vec<usize>>>,
    // scratch arena — pub(crate) so the codec impls can split-borrow
    // individual buffers without going through &mut self methods.
    pub(crate) narrow: Vec<C64>,
    pub(crate) z: Vec<C64>,
    pub(crate) col: Vec<C64>,
    pub(crate) block: Vec<C64>,
    pub(crate) spec: Vec<C64>,
    pub(crate) floats: Vec<f32>,
    pub(crate) indices32: Vec<u32>,
}

impl CodecEngine {
    pub fn new() -> CodecEngine {
        CodecEngine::default()
    }

    /// Planned transform for axis length `n`: per-engine map first
    /// (no lock), shared tier on miss.
    pub fn plan(&mut self, n: usize) -> Arc<FftPlan> {
        self.plans.entry(n).or_insert_with(|| fft2d::plan(n)).clone()
    }

    /// Cached centred (conjugate-closed) frequency index set for
    /// keeping `k` of `n` bins.
    pub fn indices(&mut self, n: usize, k: usize) -> Arc<Vec<usize>> {
        self.indices
            .entry((n, k))
            .or_insert_with(|| Arc::new(super::freq_indices(n, k)))
            .clone()
    }

    /// Pre-warm the engine for a (rows, cols, ks, kd) block shape so
    /// the first request of a session pays no plan/index cost either.
    pub fn warm(&mut self, rows: usize, cols: usize, ks: usize, kd: usize) {
        self.plan(rows);
        self.plan(cols);
        self.indices(rows, ks);
        self.indices(cols, kd);
    }

    pub fn cached_plans(&self) -> usize {
        self.plans.len()
    }

    pub fn cached_index_sets(&self) -> usize {
        self.indices.len()
    }

    /// Release all scratch capacity (the plan/index caches stay — they
    /// are shared `Arc`s and cheap).  The scratch arena otherwise
    /// retains its largest-ever footprint, which is the point for a
    /// per-session decode loop but worth trimming for long-lived
    /// engines that served one unusually large shape — e.g. the
    /// thread-local engine behind the legacy one-shot API.
    pub fn shrink_scratch(&mut self) {
        self.narrow = Vec::new();
        self.z = Vec::new();
        self.col = Vec::new();
        self.block = Vec::new();
        self.spec = Vec::new();
        self.floats = Vec::new();
        self.indices32 = Vec::new();
    }

    /// Total bytes of scratch capacity currently held.  The
    /// engine-reuse invariant: repeated `compress_into` calls on the
    /// same shape must not grow this after warm-up.
    pub fn scratch_bytes(&self) -> usize {
        (self.narrow.capacity()
            + self.z.capacity()
            + self.col.capacity()
            + self.block.capacity()
            + self.spec.capacity())
            * std::mem::size_of::<C64>()
            + self.floats.capacity() * std::mem::size_of::<f32>()
            + self.indices32.capacity() * std::mem::size_of::<u32>()
    }
}

/// Reset a complex scratch buffer to `n` zeros without shrinking its
/// capacity (the codecs' previous `vec![C64::ZERO; n]` semantics,
/// minus the allocation).
pub(crate) fn zeroed(buf: &mut Vec<C64>, n: usize) {
    buf.clear();
    buf.resize(n, C64::ZERO);
}

thread_local! {
    static THREAD_ENGINE: RefCell<CodecEngine> = RefCell::new(CodecEngine::new());
}

/// Run `f` with this thread's shared engine — the backing store for
/// the legacy one-shot `Codec::compress`/`decompress` API.  Callers
/// must not re-enter (codec `_into` implementations receive their
/// engine explicitly and never call back into this).
pub fn with_thread_engine<R>(f: impl FnOnce(&mut CodecEngine) -> R) -> R {
    THREAD_ENGINE.with(|e| f(&mut e.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_and_index_caches_fill_once() {
        let mut eng = CodecEngine::new();
        assert_eq!(eng.cached_plans(), 0);
        let p1 = eng.plan(64);
        let p2 = eng.plan(64);
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(eng.cached_plans(), 1);

        let i1 = eng.indices(96, 13);
        let i2 = eng.indices(96, 13);
        assert!(Arc::ptr_eq(&i1, &i2));
        assert_eq!(i1.as_slice(), super::super::freq_indices(96, 13).as_slice());
        assert_eq!(eng.cached_index_sets(), 1);
    }

    #[test]
    fn warm_prefills_both_axes() {
        let mut eng = CodecEngine::new();
        eng.warm(64, 128, 9, 15);
        assert_eq!(eng.cached_plans(), 2);
        assert_eq!(eng.cached_index_sets(), 2);
    }

    #[test]
    fn zeroed_reuses_capacity() {
        let mut buf = Vec::new();
        zeroed(&mut buf, 256);
        assert!(buf.iter().all(|c| *c == C64::ZERO));
        buf[3] = C64::ONE;
        let cap = buf.capacity();
        zeroed(&mut buf, 128);
        assert_eq!(buf.len(), 128);
        assert_eq!(buf.capacity(), cap, "shrank capacity");
        assert!(buf.iter().all(|c| *c == C64::ZERO));
    }

    #[test]
    fn shrink_scratch_releases_arena_but_keeps_caches() {
        let mut eng = CodecEngine::new();
        eng.plan(32);
        zeroed(&mut eng.spec, 1024);
        assert!(eng.scratch_bytes() > 0);
        eng.shrink_scratch();
        assert_eq!(eng.scratch_bytes(), 0);
        assert_eq!(eng.cached_plans(), 1);
    }

    #[test]
    fn thread_engine_persists_across_calls() {
        with_thread_engine(|e| {
            e.plan(48);
        });
        let n = with_thread_engine(|e| e.cached_plans());
        assert!(n >= 1);
    }
}
