//! `codec::wire` — lossless entropy coding between the packed-block /
//! delta producers and the frame encoder.
//!
//! Three self-describing plane formats, all sharing the same 5-byte
//! header (`u8 mode | u32 count`) and the same try-and-compare
//! contract: the encoder builds every applicable mode, keeps the
//! smallest, and mode 0 is always the raw bytes — so a coded plane is
//! never larger than raw + [`PLANE_HEADER_BYTES`], and decode is
//! deterministic from the header alone.
//!
//! * **f32 planes** ([`encode_f32_plane`]): packed spectral blocks
//!   (recompute activations and stream keyframes).  Mode 1 splits
//!   each float into sign / exponent / mantissa and codes the
//!   exponent as a gamma-coded delta from its predecessor (spectral
//!   coefficients cluster in magnitude, so exponent deltas are
//!   small); exact zeros collapse to a flag bit.  Mode 2 re-slices
//!   the plane into its four byte planes and pushes each through the
//!   adaptive binary range coder with a per-plane bit-tree context.
//! * **i8 planes** ([`encode_i8_plane`]): quantized coefficient
//!   planes.  Mode 1 is zero-run + sign/magnitude (runs gamma-coded,
//!   magnitudes gamma-coded); mode 2 range-codes the bytes with a
//!   was-previous-zero context pair.
//! * **sorted index/value lists** ([`encode_updates`]): sparse delta
//!   updates.  Mode 1 sorts by index and Golomb-Rice codes the gaps
//!   with a per-frame parameter derived from the gap mean (carried in
//!   a 1-byte header), then hands the values to the f32 plane coder.
//!
//! The range coder is the classic adaptive binary arithmetic coder
//! (11-bit probabilities, shift-5 adaptation, byte-wise renormalizing
//! below 2^24 with carry propagation through a cache byte); byte
//! symbols ride an 8-level bit tree, MSB first.
//!
//! Every decoder returns typed errors on truncated, corrupt, or
//! oversized input — these functions parse attacker-controlled frame
//! bodies behind `ServingService::handle`.

use crate::util::bits::{BitReader, BitWriter};
use anyhow::{bail, ensure, Result};

/// Bytes every coded plane spends before its payload: `u8 mode` +
/// `u32 count`.
pub const PLANE_HEADER_BYTES: usize = 5;

/// Mode byte values shared by all three plane formats: mode 0 is
/// always the raw pass-through.
pub const MODE_RAW: u8 = 0;
/// f32: exponent-delta split; i8: zero-run + sign/magnitude; updates:
/// Rice-coded index gaps.
pub const MODE_SPLIT: u8 = 1;
/// Second-stage adaptive range coding (f32 byte planes / i8 bytes).
pub const MODE_RC: u8 = 2;

/// Upper bound on the element count a coded plane may declare —
/// matches the 64 MiB `MAX_FRAME` at 4 bytes per element, so a
/// corrupt count errors before any pathological allocation.
pub const MAX_PLANE: usize = 16 << 20;

// ---------------------------------------------------------------------------
// adaptive binary range coder (LZMA-style)
// ---------------------------------------------------------------------------

const RC_PROB_BITS: u32 = 11;
const RC_PROB_INIT: u16 = 1 << (RC_PROB_BITS - 1);
const RC_MOVE_BITS: u32 = 5;
const RC_TOP: u32 = 1 << 24;

struct RcEncoder {
    low: u64,
    range: u32,
    cache: u8,
    cache_size: u64,
    out: Vec<u8>,
}

impl RcEncoder {
    fn new() -> RcEncoder {
        RcEncoder { low: 0, range: u32::MAX, cache: 0, cache_size: 1,
                    out: Vec::new() }
    }

    fn encode(&mut self, prob: &mut u16, bit: u32) {
        let bound = (self.range >> RC_PROB_BITS) * (*prob as u32);
        if bit == 0 {
            self.range = bound;
            *prob += ((1u16 << RC_PROB_BITS) - *prob) >> RC_MOVE_BITS;
        } else {
            self.low += bound as u64;
            self.range -= bound;
            *prob -= *prob >> RC_MOVE_BITS;
        }
        while self.range < RC_TOP {
            self.shift_low();
            self.range <<= 8;
        }
    }

    fn shift_low(&mut self) {
        // flush the cache byte (plus any 0xFF run) once the carry can
        // no longer reach it
        if (self.low as u32) < 0xFF00_0000 || self.low > u32::MAX as u64 {
            let carry = (self.low >> 32) as u8;
            let mut b = self.cache;
            loop {
                self.out.push(b.wrapping_add(carry));
                b = 0xFF;
                self.cache_size -= 1;
                if self.cache_size == 0 {
                    break;
                }
            }
            self.cache = (self.low >> 24) as u8;
        }
        self.cache_size += 1;
        self.low = (self.low << 8) & u32::MAX as u64;
    }

    fn finish(mut self) -> Vec<u8> {
        for _ in 0..5 {
            self.shift_low();
        }
        self.out
    }
}

struct RcDecoder<'a> {
    code: u32,
    range: u32,
    buf: &'a [u8],
    pos: usize,
}

impl<'a> RcDecoder<'a> {
    fn new(buf: &'a [u8]) -> Result<RcDecoder<'a>> {
        let mut d = RcDecoder { code: 0, range: u32::MAX, buf, pos: 0 };
        for _ in 0..5 {
            let b = d.next_byte()?;
            d.code = (d.code << 8) | b as u32;
        }
        Ok(d)
    }

    fn next_byte(&mut self) -> Result<u8> {
        ensure!(self.pos < self.buf.len(),
                "range-coded stream truncated at byte {}", self.pos);
        let b = self.buf[self.pos];
        self.pos += 1;
        Ok(b)
    }

    fn decode(&mut self, prob: &mut u16) -> Result<u32> {
        let bound = (self.range >> RC_PROB_BITS) * (*prob as u32);
        let bit = if self.code < bound {
            self.range = bound;
            *prob += ((1u16 << RC_PROB_BITS) - *prob) >> RC_MOVE_BITS;
            0
        } else {
            self.code -= bound;
            self.range -= bound;
            *prob -= *prob >> RC_MOVE_BITS;
            1
        };
        while self.range < RC_TOP {
            let b = self.next_byte()?;
            self.code = (self.code << 8) | b as u32;
            self.range <<= 8;
        }
        Ok(bit)
    }
}

/// One byte symbol as an 8-level bit tree (255 adaptive contexts),
/// MSB first — the magnitude-symbol model of the second stage.
struct ByteTree([u16; 256]);

impl ByteTree {
    fn new() -> ByteTree {
        ByteTree([RC_PROB_INIT; 256])
    }

    fn encode(&mut self, rc: &mut RcEncoder, byte: u8) {
        let mut m = 1usize;
        for i in (0..8).rev() {
            let bit = ((byte >> i) & 1) as u32;
            rc.encode(&mut self.0[m], bit);
            m = (m << 1) | bit as usize;
        }
    }

    fn decode(&mut self, rc: &mut RcDecoder) -> Result<u8> {
        let mut m = 1usize;
        for _ in 0..8 {
            let bit = rc.decode(&mut self.0[m])?;
            m = (m << 1) | bit as usize;
        }
        Ok((m - 256) as u8)
    }
}

// ---------------------------------------------------------------------------
// f32 planes
// ---------------------------------------------------------------------------

fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

fn unzigzag(z: u64) -> i64 {
    ((z >> 1) as i64) ^ -((z & 1) as i64)
}

/// Mode 1: sign/exponent/mantissa split with gamma-coded exponent
/// deltas; exact zeros cost a flag bit instead of a mantissa.
fn split_f32(vals: &[f32]) -> Vec<u8> {
    let mut w = BitWriter::new();
    let mut prev_exp = 127i64;
    for v in vals {
        let bits = v.to_bits();
        let exp = ((bits >> 23) & 0xFF) as i64;
        let man = (bits & 0x7F_FFFF) as u64;
        w.write_gamma(zigzag(exp - prev_exp) + 1);
        prev_exp = exp;
        w.write_bit(bits >> 31 != 0);
        if exp == 0 {
            // zero or subnormal: the common exact-zero case collapses
            // to one flag bit
            w.write_bit(man != 0);
            if man != 0 {
                w.write_bits(man, 23);
            }
        } else {
            w.write_bits(man, 23);
        }
    }
    w.finish()
}

fn unsplit_f32(bytes: &[u8], n: usize, out: &mut Vec<f32>) -> Result<()> {
    let mut r = BitReader::new(bytes);
    let mut prev_exp = 127i64;
    for _ in 0..n {
        let d = unzigzag(r.read_gamma()?.checked_sub(1)
            .ok_or_else(|| anyhow::anyhow!("zero gamma symbol"))?);
        let exp = prev_exp + d;
        ensure!((0..=255).contains(&exp), "split exponent {exp} out of range");
        prev_exp = exp;
        let sign = r.read_bit()? as u32;
        let man = if exp == 0 {
            if r.read_bit()? { r.read_bits(23)? as u32 } else { 0 }
        } else {
            r.read_bits(23)? as u32
        };
        out.push(f32::from_bits((sign << 31) | ((exp as u32) << 23) | man));
    }
    ensure!(r.remaining_bits() < 8,
            "trailing split-plane bytes ({} bits)", r.remaining_bits());
    Ok(())
}

/// Mode 2: the plane re-sliced into its four byte planes (MSB plane
/// first: sign+exponent, then exponent-low+mantissa-high, then the
/// mantissa tail), each range-coded under its own bit-tree context.
fn rc_f32(vals: &[f32]) -> Vec<u8> {
    let mut rc = RcEncoder::new();
    let mut trees = [ByteTree::new(), ByteTree::new(), ByteTree::new(),
                     ByteTree::new()];
    for (p, tree) in trees.iter_mut().enumerate() {
        let shift = 8 * (3 - p) as u32;
        for v in vals {
            tree.encode(&mut rc, (v.to_bits() >> shift) as u8);
        }
    }
    rc.finish()
}

fn un_rc_f32(bytes: &[u8], n: usize, out: &mut Vec<f32>) -> Result<()> {
    let mut rc = RcDecoder::new(bytes)?;
    let mut trees = [ByteTree::new(), ByteTree::new(), ByteTree::new(),
                     ByteTree::new()];
    let start = out.len();
    out.resize(start + n, 0.0);
    for (p, tree) in trees.iter_mut().enumerate() {
        let shift = 8 * (3 - p) as u32;
        for v in out[start..].iter_mut() {
            let b = tree.decode(&mut rc)? as u32;
            *v = f32::from_bits(v.to_bits() | (b << shift));
        }
    }
    Ok(())
}

/// Entropy-code an f32 plane (packed spectral block).  Tries the
/// split and range-coded modes, keeps the smallest, and falls back to
/// raw — the output never exceeds `4·n + PLANE_HEADER_BYTES` bytes.
pub fn encode_f32_plane(vals: &[f32], out: &mut Vec<u8>) {
    assert!(vals.len() <= MAX_PLANE, "plane too large");
    let raw_len = 4 * vals.len();
    let split = split_f32(vals);
    let rc = rc_f32(vals);
    let (mode, best_len) = [(MODE_SPLIT, split.len()), (MODE_RC, rc.len())]
        .into_iter()
        .fold((MODE_RAW, raw_len), |best, cand| {
            if cand.1 < best.1 { cand } else { best }
        });
    out.reserve(PLANE_HEADER_BYTES + best_len);
    out.push(mode);
    out.extend_from_slice(&(vals.len() as u32).to_le_bytes());
    match mode {
        MODE_SPLIT => out.extend_from_slice(&split),
        MODE_RC => out.extend_from_slice(&rc),
        _ => crate::codec::Writer(out).f32s(vals),
    }
}

/// Decode an f32 plane coded by [`encode_f32_plane`].  Typed errors
/// on truncation, unknown modes, or oversized counts — never panics.
pub fn decode_f32_plane(bytes: &[u8], out: &mut Vec<f32>) -> Result<()> {
    let mut r = crate::codec::Reader::new(bytes);
    let mode = r.byte()?;
    let n = r.u32()? as usize;
    ensure!(n <= MAX_PLANE, "f32 plane count {n} too large");
    out.clear();
    out.reserve(n.min(4096));
    let body = r.take(r.remaining())?;
    match mode {
        MODE_RAW => {
            ensure!(body.len() == 4 * n,
                    "raw f32 plane length {} != 4x{n}", body.len());
            crate::codec::Reader::new(body).f32s(n, out)?;
        }
        MODE_SPLIT => unsplit_f32(body, n, out)?,
        MODE_RC => un_rc_f32(body, n, out)?,
        m => bail!("unknown f32 plane mode {m}"),
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// i8 planes
// ---------------------------------------------------------------------------

/// Mode 1: zero runs gamma-coded, nonzero symbols as sign bit +
/// gamma-coded magnitude.
fn zrun_i8(vals: &[i8]) -> Vec<u8> {
    let mut w = BitWriter::new();
    let mut i = 0usize;
    while i < vals.len() {
        let run = vals[i..].iter().take_while(|&&v| v == 0).count();
        w.write_gamma(run as u64 + 1);
        i += run;
        if i < vals.len() {
            let v = vals[i];
            w.write_bit(v < 0);
            w.write_gamma(v.unsigned_abs() as u64);
            i += 1;
        }
    }
    w.finish()
}

fn un_zrun_i8(bytes: &[u8], n: usize, out: &mut Vec<i8>) -> Result<()> {
    let mut r = BitReader::new(bytes);
    while out.len() < n {
        let run = r.read_gamma()? - 1;
        ensure!(run as usize <= n - out.len(),
                "zero run {run} overruns plane of {n}");
        out.resize(out.len() + run as usize, 0);
        if out.len() < n {
            let neg = r.read_bit()?;
            let mag = r.read_gamma()?;
            ensure!(mag <= 127 + neg as u64, "i8 magnitude {mag} out of range");
            out.push(if neg { -(mag as i64) as i8 } else { mag as i8 });
        }
    }
    ensure!(r.remaining_bits() < 8,
            "trailing i8 plane bytes ({} bits)", r.remaining_bits());
    Ok(())
}

/// Mode 2: bytes through the range coder, context = was the previous
/// symbol zero (zero-heavy quantized planes adapt both ways).
fn rc_i8(vals: &[i8]) -> Vec<u8> {
    let mut rc = RcEncoder::new();
    let mut trees = [ByteTree::new(), ByteTree::new()];
    let mut prev_zero = true;
    for &v in vals {
        trees[prev_zero as usize].encode(&mut rc, v as u8);
        prev_zero = v == 0;
    }
    rc.finish()
}

fn un_rc_i8(bytes: &[u8], n: usize, out: &mut Vec<i8>) -> Result<()> {
    let mut rc = RcDecoder::new(bytes)?;
    let mut trees = [ByteTree::new(), ByteTree::new()];
    let mut prev_zero = true;
    for _ in 0..n {
        let b = trees[prev_zero as usize].decode(&mut rc)? as i8;
        prev_zero = b == 0;
        out.push(b);
    }
    Ok(())
}

/// Entropy-code an int8 quantized coefficient plane.  Same contract
/// as [`encode_f32_plane`]: output never exceeds raw + header.
pub fn encode_i8_plane(vals: &[i8], out: &mut Vec<u8>) {
    assert!(vals.len() <= MAX_PLANE, "plane too large");
    let zrun = zrun_i8(vals);
    let rc = rc_i8(vals);
    let (mode, best_len) = [(MODE_SPLIT, zrun.len()), (MODE_RC, rc.len())]
        .into_iter()
        .fold((MODE_RAW, vals.len()), |best, cand| {
            if cand.1 < best.1 { cand } else { best }
        });
    out.reserve(PLANE_HEADER_BYTES + best_len);
    out.push(mode);
    out.extend_from_slice(&(vals.len() as u32).to_le_bytes());
    match mode {
        MODE_SPLIT => out.extend_from_slice(&zrun),
        MODE_RC => out.extend_from_slice(&rc),
        // SAFETY-free raw path: i8 and u8 share representation
        _ => out.extend(vals.iter().map(|&v| v as u8)),
    }
}

/// Decode an i8 plane coded by [`encode_i8_plane`].
pub fn decode_i8_plane(bytes: &[u8], out: &mut Vec<i8>) -> Result<()> {
    let mut r = crate::codec::Reader::new(bytes);
    let mode = r.byte()?;
    let n = r.u32()? as usize;
    ensure!(n <= MAX_PLANE, "i8 plane count {n} too large");
    out.clear();
    out.reserve(n.min(4096));
    let body = r.take(r.remaining())?;
    match mode {
        MODE_RAW => {
            ensure!(body.len() == n, "raw i8 plane length {} != {n}",
                    body.len());
            out.extend(body.iter().map(|&b| b as i8));
        }
        MODE_SPLIT => un_zrun_i8(body, n, out)?,
        MODE_RC => un_rc_i8(body, n, out)?,
        m => bail!("unknown i8 plane mode {m}"),
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// sorted index/value lists (sparse delta updates)
// ---------------------------------------------------------------------------

/// Rice parameter from the gap mean: the classic `floor(log2 mean)`
/// rule, clamped to the 1-byte header's documented 0..=31 range.
fn rice_k_for(gaps: &[u64]) -> u32 {
    let n = gaps.len().max(1) as u64;
    let mean = gaps.iter().sum::<u64>() / n;
    if mean < 1 { 0 } else { (63 - mean.leading_zeros() as u64).min(31) as u32 }
}

/// Entropy-code a sparse update list.  Mode 1 sorts by index, Rice-
/// codes the strictly-increasing index gaps (parameter from the gap
/// mean, carried in a 1-byte header), and routes the values through
/// the f32 plane coder; duplicate indices or an empty list fall back
/// to raw.  Output never exceeds `4 + 8·n + PLANE_HEADER_BYTES` — one
/// header over the legacy sparse body.
pub fn encode_updates(updates: &[(u32, f32)], out: &mut Vec<u8>) {
    assert!(updates.len() <= MAX_PLANE, "update list too large");
    let raw_len = 8 * updates.len();
    let coded = coded_updates(updates);
    let (mode, best) = match &coded {
        Some(c) if c.len() < raw_len => (MODE_SPLIT, c.len()),
        _ => (MODE_RAW, raw_len),
    };
    out.reserve(PLANE_HEADER_BYTES + best);
    out.push(mode);
    out.extend_from_slice(&(updates.len() as u32).to_le_bytes());
    if mode == MODE_SPLIT {
        out.extend_from_slice(&coded.expect("coded candidate"));
    } else {
        let mut w = crate::codec::Writer(out);
        for (i, v) in updates {
            w.u32(*i);
            w.f32(*v);
        }
    }
}

/// The mode-1 candidate body: `u32 gap_bytes | u8 rice_k | gaps |
/// f32-plane values`.  None when the list is empty or holds a
/// duplicate index (gap-1 coding needs strict monotonicity).
fn coded_updates(updates: &[(u32, f32)]) -> Option<Vec<u8>> {
    if updates.is_empty() {
        return None;
    }
    let mut sorted: Vec<(u32, f32)> = updates.to_vec();
    sorted.sort_unstable_by_key(|&(i, _)| i);
    if sorted.windows(2).any(|w| w[0].0 == w[1].0) {
        return None;
    }
    // gaps: the first index absolute, later ones minus the implied +1
    let gaps: Vec<u64> = sorted
        .iter()
        .enumerate()
        .map(|(j, &(i, _))| {
            if j == 0 { i as u64 } else { (i - sorted[j - 1].0 - 1) as u64 }
        })
        .collect();
    let k = rice_k_for(&gaps);
    let mut w = BitWriter::new();
    for &g in &gaps {
        w.write_rice(g, k);
    }
    let bits = w.finish();
    let mut body = Vec::with_capacity(5 + bits.len());
    body.extend_from_slice(&(1 + bits.len() as u32).to_le_bytes());
    body.push(k as u8);
    body.extend_from_slice(&bits);
    let vals: Vec<f32> = sorted.iter().map(|&(_, v)| v).collect();
    encode_f32_plane(&vals, &mut body);
    Some(body)
}

/// Decode an update list coded by [`encode_updates`].  Mode-1 lists
/// come back sorted by index (semantically equivalent: indices are
/// unique and application order does not matter); mode-0 lists keep
/// their original order byte-for-byte.
pub fn decode_updates(bytes: &[u8], out: &mut Vec<(u32, f32)>) -> Result<()> {
    let mut r = crate::codec::Reader::new(bytes);
    let mode = r.byte()?;
    let n = r.u32()? as usize;
    ensure!(n <= MAX_PLANE, "update count {n} too large");
    out.clear();
    out.reserve(n.min(4096));
    match mode {
        MODE_RAW => {
            ensure!(r.remaining() == 8 * n,
                    "raw update list length {} != 8x{n}", r.remaining());
            for _ in 0..n {
                let i = r.u32()?;
                let v = r.f32()?;
                out.push((i, v));
            }
        }
        MODE_SPLIT => {
            let gap_bytes = r.u32()? as usize;
            ensure!(gap_bytes >= 1 && gap_bytes <= r.remaining(),
                    "gap section length {gap_bytes} out of range");
            let section = r.take(gap_bytes)?;
            let k = section[0] as u32;
            ensure!(k <= 31, "rice parameter {k} out of range");
            let mut bits = BitReader::new(&section[1..]);
            let mut idx = 0u64;
            let mut values = Vec::new();
            decode_f32_plane(r.take(r.remaining())?, &mut values)?;
            ensure!(values.len() == n,
                    "update values {} != indices {n}", values.len());
            for (j, &v) in values.iter().enumerate() {
                let g = bits.read_rice(k)?;
                idx = if j == 0 { g } else {
                    idx.checked_add(g + 1)
                        .ok_or_else(|| anyhow::anyhow!("index overflow"))?
                };
                ensure!(idx <= u32::MAX as u64, "update index {idx} overflows");
                out.push((idx as u32, v));
            }
            ensure!(bits.remaining_bits() < 8,
                    "trailing gap bytes ({} bits)", bits.remaining_bits());
        }
        m => bail!("unknown update list mode {m}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip_f32(vals: &[f32]) -> usize {
        let mut enc = Vec::new();
        encode_f32_plane(vals, &mut enc);
        assert!(enc.len() <= 4 * vals.len() + PLANE_HEADER_BYTES,
                "expansion: {} > {}", enc.len(),
                4 * vals.len() + PLANE_HEADER_BYTES);
        let mut back = Vec::new();
        decode_f32_plane(&enc, &mut back).unwrap();
        assert_eq!(back.len(), vals.len());
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "bit-exactness");
        }
        enc.len()
    }

    #[test]
    fn f32_plane_roundtrips_bit_exact() {
        roundtrip_f32(&[]);
        roundtrip_f32(&[0.0]);
        roundtrip_f32(&[1.0, -2.5, 0.0, 3.25, f32::MIN_POSITIVE,
                        -f32::MIN_POSITIVE / 2.0, f32::MAX, f32::MIN,
                        f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0]);
        let mut rng = Rng::new(11);
        let vals: Vec<f32> = (0..500).map(|_| rng.normal() as f32).collect();
        roundtrip_f32(&vals);
    }

    #[test]
    fn clustered_magnitudes_compress() {
        // spectral-coefficient-like data: similar magnitudes, many
        // exact zeros — both coded modes should beat raw easily
        let mut rng = Rng::new(12);
        let vals: Vec<f32> = (0..2000)
            .map(|i| if i % 3 == 0 { 0.0 }
                 else { (rng.normal() * 0.01) as f32 })
            .collect();
        let n = roundtrip_f32(&vals);
        assert!(n < 4 * vals.len() * 9 / 10,
                "coded {} vs raw {}", n, 4 * vals.len());
    }

    #[test]
    fn incompressible_plane_falls_back_to_raw() {
        let mut rng = Rng::new(13);
        let vals: Vec<f32> = (0..257)
            .map(|_| f32::from_bits(rng.next_u64() as u32))
            .collect();
        let mut enc = Vec::new();
        encode_f32_plane(&vals, &mut enc);
        assert!(enc.len() <= 4 * vals.len() + PLANE_HEADER_BYTES);
        let mut back = Vec::new();
        decode_f32_plane(&enc, &mut back).unwrap();
        assert_eq!(vals.len(), back.len());
    }

    #[test]
    fn i8_plane_roundtrips_and_compresses_zeros() {
        let cases: Vec<Vec<i8>> = vec![
            vec![],
            vec![0; 100],
            vec![1, -1, 127, -128, 0, 0, 5],
            (0..=255u8).map(|b| b as i8).collect(),
        ];
        for vals in &cases {
            let mut enc = Vec::new();
            encode_i8_plane(vals, &mut enc);
            assert!(enc.len() <= vals.len() + PLANE_HEADER_BYTES);
            let mut back = Vec::new();
            decode_i8_plane(&enc, &mut back).unwrap();
            assert_eq!(&back, vals);
        }
        // zero-heavy quantized plane: large win
        let mut rng = Rng::new(21);
        let vals: Vec<i8> = (0..4000)
            .map(|_| if rng.below(8) == 0 { (rng.below(15) as i8) - 7 }
                 else { 0 })
            .collect();
        let mut enc = Vec::new();
        encode_i8_plane(&vals, &mut enc);
        assert!(enc.len() < vals.len() / 3,
                "zero-heavy plane coded {} of {}", enc.len(), vals.len());
        let mut back = Vec::new();
        decode_i8_plane(&enc, &mut back).unwrap();
        assert_eq!(back, vals);
    }

    #[test]
    fn updates_roundtrip_sorted() {
        let cases: Vec<Vec<(u32, f32)>> = vec![
            vec![],
            vec![(0, 1.0)],
            vec![(5, 1.0), (2, -2.0), (9, 0.5)], // unsorted input
            vec![(0, 1.0), (1, 2.0), (2, 3.0), (1000, -1.0)],
            vec![(u32::MAX, 7.0), (0, -7.0)],
            vec![(3, 1.0), (3, 2.0)], // duplicate index: raw fallback
        ];
        for ups in &cases {
            let mut enc = Vec::new();
            encode_updates(ups, &mut enc);
            assert!(enc.len() <= 8 * ups.len() + PLANE_HEADER_BYTES,
                    "expansion on {ups:?}");
            let mut back = Vec::new();
            decode_updates(&enc, &mut back).unwrap();
            let mut want = ups.clone();
            let mut got = back.clone();
            want.sort_by(|a, b| (a.0, a.1.to_bits()).cmp(&(b.0, b.1.to_bits())));
            got.sort_by(|a, b| (a.0, a.1.to_bits()).cmp(&(b.0, b.1.to_bits())));
            assert_eq!(want.len(), got.len());
            for (w, g) in want.iter().zip(&got) {
                assert_eq!(w.0, g.0);
                assert_eq!(w.1.to_bits(), g.1.to_bits());
            }
        }
    }

    #[test]
    fn dense_sorted_updates_compress_well() {
        // the shape stream deltas actually have: clustered indices
        // with small gaps, values of similar magnitude
        let mut rng = Rng::new(31);
        let mut idx = 0u32;
        let ups: Vec<(u32, f32)> = (0..400)
            .map(|_| {
                idx += 1 + rng.below(6) as u32;
                (idx, (rng.normal() * 0.02) as f32)
            })
            .collect();
        let mut enc = Vec::new();
        encode_updates(&ups, &mut enc);
        assert!(enc.len() * 3 < 8 * ups.len() * 2,
                "gap coding saved too little: {} vs {}", enc.len(),
                8 * ups.len());
        let mut back = Vec::new();
        decode_updates(&enc, &mut back).unwrap();
        assert_eq!(back, ups, "already-sorted input comes back identical");
    }

    #[test]
    fn corrupt_streams_error_never_panic() {
        let mut rng = Rng::new(0xE44);
        let vals: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let ups: Vec<(u32, f32)> = (0..32).map(|i| (i * 7, 0.5)).collect();
        let q: Vec<i8> = (0..64).map(|_| (rng.below(5) as i8) - 2).collect();
        let mut encs: Vec<Vec<u8>> = Vec::new();
        for m in [MODE_RAW, MODE_SPLIT, MODE_RC] {
            // force each mode byte onto each valid payload
            let mut e = Vec::new();
            encode_f32_plane(&vals, &mut e);
            e[0] = m;
            encs.push(e.clone());
            let mut e = Vec::new();
            encode_i8_plane(&q, &mut e);
            e[0] = m;
            encs.push(e.clone());
            let mut e = Vec::new();
            encode_updates(&ups, &mut e);
            e[0] = m.min(MODE_SPLIT);
            encs.push(e);
        }
        let mut f32_out = Vec::new();
        let mut i8_out = Vec::new();
        let mut up_out = Vec::new();
        for enc in &encs {
            // truncations
            for cut in 0..enc.len() {
                let _ = decode_f32_plane(&enc[..cut], &mut f32_out);
                let _ = decode_i8_plane(&enc[..cut], &mut i8_out);
                let _ = decode_updates(&enc[..cut], &mut up_out);
            }
            // seeded bit flips (mode byte, counts, rice k, payload)
            for _ in 0..400 {
                let mut e = enc.clone();
                let i = rng.below(e.len());
                e[i] ^= 1 << rng.below(8);
                let _ = decode_f32_plane(&e, &mut f32_out);
                let _ = decode_i8_plane(&e, &mut i8_out);
                let _ = decode_updates(&e, &mut up_out);
            }
        }
        // huge declared counts error before allocating
        for tid in 0..3 {
            let mut e = vec![MODE_SPLIT];
            e.extend_from_slice(&u32::MAX.to_le_bytes());
            e.extend_from_slice(&[0xAB; 16]);
            let r = match tid {
                0 => decode_f32_plane(&e, &mut f32_out).is_err(),
                1 => decode_i8_plane(&e, &mut i8_out).is_err(),
                _ => decode_updates(&e, &mut up_out).is_err(),
            };
            assert!(r, "oversized count must be a typed error");
        }
        // unknown mode bytes
        let mut e = vec![7u8, 1, 0, 0, 0, 0, 0, 0, 0];
        assert!(decode_f32_plane(&e, &mut f32_out).is_err());
        assert!(decode_i8_plane(&e, &mut i8_out).is_err());
        e[0] = 2; // MODE_RC is not a valid update-list mode
        assert!(decode_updates(&e, &mut up_out).is_err());
    }

    #[test]
    fn range_coder_roundtrips_random_bytes() {
        let mut rng = Rng::new(0x4C0DE);
        for case in 0..20 {
            let n = rng.below(300);
            let skew = rng.below(4) == 0;
            let bytes: Vec<u8> = (0..n)
                .map(|_| if skew { (rng.below(3)) as u8 }
                     else { rng.next_u64() as u8 })
                .collect();
            let mut rc = RcEncoder::new();
            let mut tree = ByteTree::new();
            for &b in &bytes {
                tree.encode(&mut rc, b);
            }
            let enc = rc.finish();
            let mut dec = RcDecoder::new(&enc).unwrap();
            let mut tree = ByteTree::new();
            for (i, &b) in bytes.iter().enumerate() {
                assert_eq!(tree.decode(&mut dec).unwrap(), b,
                           "case {case} byte {i}");
            }
        }
    }
}
