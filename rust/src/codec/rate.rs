//! Adaptive spectral rate control: a channel-aware (K_S, K_D) ladder
//! and the per-session controller that rides it.
//!
//! The paper fixes one low-frequency block per layer offline, but its
//! own trade-off curves (Fig 5/6) show the retained-coefficient
//! budget is a knob: on a fluctuating edge link a static (ks, kd)
//! either wastes accuracy headroom or blows the latency budget.  This
//! module closes the loop.  Each serving bucket carries a small
//! **quality ladder** of operating points — point 0 is the paper's
//! fixed block, later points keep nested, smaller centred blocks —
//! each with a *forged Parseval error bound* (`testkit::forge`
//! measures the additional reconstruction error the point introduces
//! over the primary block on the model's band-limited activation
//! family and bakes it into the manifest, with headroom).  The
//! ladder is advertised in the
//! `HelloAck` and a ladder-point id rides every Activation/Delta
//! header, so both sides always agree on which block a frame carries.
//!
//! The device-side [`RateController`] picks the point each step from
//!
//! * an **EWMA pace estimate** (seconds per bit, fed by transport
//!   send timing — under `net::Channel` shaping the send blocks for
//!   the emulated transfer time, so the measurement *is* the link),
//! * the stream codec's **measured drift**
//!   ([`crate::codec::stream::StreamEncoder::last_drift`]),
//!
//! under a caller-supplied **error budget**: a point is admissible
//! only while `err_bound + drift <= error_budget`, and among
//! admissible points the controller takes the highest-quality one
//! whose estimated transfer time fits the step deadline (falling back
//! to the cheapest admissible point on a link none fits).
//! **Hysteresis** keeps it from flapping: switches are spaced at
//! least `min_dwell_steps` apart and an upshift needs `up_margin`
//! headroom — except the *emergency* lane, where the current point
//! has become inadmissible (drift ate the budget) and quality is
//! restored immediately.  That emergency override is what makes the
//! safety invariant hold: after every [`RateController::step`], the
//! chosen point is within budget whenever any point is
//! (`tests/properties.rs` pins it).
//!
//! A ladder switch changes the block geometry, so in stream mode it
//! forces a keyframe exactly like bucket promotion — the server
//! rejects a delta that names a new point without one.

use crate::util::json::Json;
use anyhow::{ensure, Result};

/// One advertised operating point: the kept centred block and its
/// forged Parseval error bound — the *additional* relative
/// reconstruction error (Frobenius) the point introduces over the
/// bucket's primary block, measured offline on the model's
/// band-limited activation family (`testkit::forge::forged_err_bound`)
/// and baked into the manifest.  Point 0 carries the measurement
/// floor: riding the primary block sacrifices nothing by definition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LadderPoint {
    pub ks: usize,
    pub kd: usize,
    pub err_bound: f64,
}

/// Per-frame wire overhead the controller charges on top of the
/// packed floats when estimating a point's transfer time (frame
/// length prefix + type + Activation/Delta header) — an upper bound;
/// exactness does not matter for control.
pub const POINT_OVERHEAD_BYTES: usize = 35;

impl LadderPoint {
    /// Estimated wire bytes of one frame at this point (keyframe /
    /// Activation equivalent: the worst case the deadline must fit).
    pub fn frame_bytes(&self) -> usize {
        self.ks * self.kd * 4 + POINT_OVERHEAD_BYTES
    }
}

/// Controller policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct RateConfig {
    /// Max acceptable reconstruction error: forged point bound plus
    /// measured stream drift — the caller's quality contract.
    pub error_budget: f64,
    /// Target per-step uplink transfer time (seconds).
    pub target_step_s: f64,
    /// EWMA smoothing for the pace/drift estimates, in (0, 1].
    pub ewma_alpha: f64,
    /// Minimum steps at a point before a (non-emergency) switch.
    pub min_dwell_steps: u32,
    /// Upshift headroom: a higher-quality point is adopted only once
    /// its estimated transfer time fits `target_step_s / up_margin`,
    /// so a borderline link does not flap.  Must be >= 1.
    pub up_margin: f64,
}

impl Default for RateConfig {
    fn default() -> RateConfig {
        RateConfig {
            error_budget: 1.0,
            target_step_s: 0.05,
            ewma_alpha: 0.5,
            min_dwell_steps: 2,
            up_margin: 1.5,
        }
    }
}

/// Ladder shape invariants (geometry-independent): non-empty, quality
/// monotone — ks/kd non-increasing, err_bound non-decreasing in
/// [0, 1] — so "higher index" always means "cheaper and no better".
/// Geometry validity against a concrete (rows, cols) is checked where
/// those are known ([`ladder_from_manifest`], the forge, the server's
/// model load).
pub fn validate_ladder(ladder: &[LadderPoint]) -> Result<()> {
    ensure!(!ladder.is_empty(), "empty ladder");
    for (i, p) in ladder.iter().enumerate() {
        ensure!(p.ks >= 1 && p.kd >= 1, "ladder point {i}: zero axis");
        ensure!((0.0..=1.0).contains(&p.err_bound),
                "ladder point {i}: err_bound {} outside [0, 1]", p.err_bound);
        if i > 0 {
            let q = &ladder[i - 1];
            ensure!(p.ks <= q.ks && p.kd <= q.kd,
                    "ladder point {i} ({}x{}) not nested in point {} ({}x{})",
                    p.ks, p.kd, i - 1, q.ks, q.kd);
            ensure!(p.err_bound >= q.err_bound,
                    "ladder point {i}: err_bound not monotone");
        }
    }
    Ok(())
}

/// Parse one serving bucket's ladder from its manifest entry: the
/// primary `ks`/`kd` fields are point 0; an optional `ladder` array
/// (objects with `ks`, `kd`, `err_bound`) refines it.  A manifest
/// without a ladder (older artifact trees) yields the single primary
/// point with a vacuous bound of 1.0.  Every point is validated
/// against the bucket geometry and nesting under the primary block.
pub fn ladder_from_manifest(bj: &Json, rows: usize, cols: usize)
    -> Result<Vec<LadderPoint>> {
    let pks = bj.usize_or("ks", 0);
    let pkd = bj.usize_or("kd", 0);
    let ladder = match bj.get("ladder").and_then(|v| v.as_arr()) {
        None => vec![LadderPoint { ks: pks, kd: pkd, err_bound: 1.0 }],
        Some(arr) => {
            let mut out = Vec::with_capacity(arr.len());
            for e in arr {
                out.push(LadderPoint {
                    ks: e.usize_or("ks", 0),
                    kd: e.usize_or("kd", 0),
                    err_bound: e.f64_or("err_bound", 1.0),
                });
            }
            out
        }
    };
    validate_ladder(&ladder)?;
    ensure!(ladder[0].ks == pks && ladder[0].kd == pkd,
            "ladder point 0 ({}x{}) disagrees with the bucket's primary \
             block ({pks}x{pkd})", ladder[0].ks, ladder[0].kd);
    for (i, p) in ladder.iter().enumerate() {
        ensure!(super::valid_block_axis(rows, p.ks)
                    && super::valid_block_axis(cols, p.kd),
                "ladder point {i}: invalid block {}x{} for {rows}x{cols}",
                p.ks, p.kd);
    }
    Ok(ladder)
}

/// The per-session closed-loop controller.  Deterministic: its state
/// advances only through [`RateController::observe_send`],
/// [`RateController::observe_drift`], and [`RateController::step`] —
/// no clocks, no randomness — so the property suite can replay it.
#[derive(Debug, Clone)]
pub struct RateController {
    cfg: RateConfig,
    ladder: Vec<LadderPoint>,
    current: usize,
    pinned: Option<usize>,
    /// Steps spent at `current` since the last switch.
    dwell: u32,
    switches: u64,
    /// EWMA link pace in seconds per bit (0.0 until primed).  Pace —
    /// not rate — so a 100x slowdown registers multiplicatively
    /// within a couple of observations instead of averaging away.
    pace_s_per_bit: f64,
    /// EWMA of the stream codec's measured relative drift.
    drift: f64,
}

impl RateController {
    pub fn new(ladder: Vec<LadderPoint>, cfg: RateConfig)
        -> Result<RateController> {
        validate_ladder(&ladder)?;
        ensure!(cfg.error_budget > 0.0, "error_budget must be > 0");
        ensure!(cfg.target_step_s > 0.0, "target_step_s must be > 0");
        ensure!(cfg.ewma_alpha > 0.0 && cfg.ewma_alpha <= 1.0,
                "ewma_alpha must be in (0, 1]");
        ensure!(cfg.min_dwell_steps >= 1, "min_dwell_steps must be >= 1");
        ensure!(cfg.up_margin >= 1.0, "up_margin must be >= 1");
        Ok(RateController {
            cfg,
            ladder,
            current: 0,
            pinned: None,
            dwell: 0,
            switches: 0,
            pace_s_per_bit: 0.0,
            drift: 0.0,
        })
    }

    /// Swap the ladder (bucket promotion changes the geometry but not
    /// the link): the pace/drift estimates carry over, the point index
    /// is clamped into the new ladder.
    pub fn retarget(&mut self, ladder: Vec<LadderPoint>) -> Result<()> {
        validate_ladder(&ladder)?;
        self.current = self.current.min(ladder.len() - 1);
        if let Some(p) = self.pinned.as_mut() {
            if *p >= ladder.len() {
                // a clamped pin no longer measures what the caller
                // asked for — say so instead of silently re-pinning
                crate::warn_!("rate",
                              "pinned ladder point {} clamped to {} by a \
                               shorter ladder", *p, ladder.len() - 1);
                *p = ladder.len() - 1;
            }
        }
        self.ladder = ladder;
        Ok(())
    }

    /// Pin to one ladder point (the benches' fixed-point ablation
    /// lever): [`RateController::step`] holds it until unpinned.
    pub fn pin(&mut self, point: usize) -> Result<()> {
        ensure!(point < self.ladder.len(),
                "pin {point} outside ladder of {}", self.ladder.len());
        self.pinned = Some(point);
        self.current = point;
        Ok(())
    }

    pub fn ladder(&self) -> &[LadderPoint] {
        &self.ladder
    }

    pub fn point(&self) -> usize {
        self.current
    }

    pub fn current_point(&self) -> LadderPoint {
        self.ladder[self.current]
    }

    pub fn switches(&self) -> u64 {
        self.switches
    }

    /// Estimated link goodput in bits/s (0.0 until primed).
    pub fn goodput_bps(&self) -> f64 {
        if self.pace_s_per_bit > 0.0 {
            1.0 / self.pace_s_per_bit
        } else {
            0.0
        }
    }

    /// Feed one transport send: `bytes` took `elapsed_s` to clear the
    /// (possibly shaped) tx half.
    pub fn observe_send(&mut self, bytes: usize, elapsed_s: f64) {
        if bytes == 0 || elapsed_s <= 0.0 {
            return;
        }
        let inst = elapsed_s / (bytes * 8) as f64;
        self.pace_s_per_bit = if self.pace_s_per_bit <= 0.0 {
            inst
        } else {
            self.cfg.ewma_alpha * inst
                + (1.0 - self.cfg.ewma_alpha) * self.pace_s_per_bit
        };
    }

    /// Feed the stream codec's measured relative drift for the step
    /// (0.0 in the recompute regime / after a keyframe).
    pub fn observe_drift(&mut self, drift: f64) {
        let d = drift.max(0.0);
        self.drift = self.cfg.ewma_alpha * d
            + (1.0 - self.cfg.ewma_alpha) * self.drift;
    }

    fn admissible(&self, i: usize) -> bool {
        self.ladder[i].err_bound + self.drift
            <= self.cfg.error_budget + 1e-9
    }

    /// Estimated transfer time of one frame at point `i` (0.0 while
    /// the pace estimate is unprimed — optimism until measured).
    fn est_send_s(&self, i: usize) -> f64 {
        (self.ladder[i].frame_bytes() * 8) as f64 * self.pace_s_per_bit
    }

    /// The point the estimates call for, ignoring hysteresis: the
    /// highest-quality admissible point that fits the deadline, else
    /// the cheapest admissible point, else (nothing admissible) the
    /// highest-quality point — best effort under a blown budget.
    fn desired(&self) -> usize {
        let mut cheapest_adm = None;
        for i in 0..self.ladder.len() {
            if !self.admissible(i) {
                continue;
            }
            if self.est_send_s(i) <= self.cfg.target_step_s {
                return i;
            }
            cheapest_adm = Some(i);
        }
        cheapest_adm.unwrap_or(0)
    }

    /// The rung prompt-phase chunks ride: the deepest (cheapest)
    /// admissible point under the error budget.  The prompt plane is
    /// the largest single transfer of a session and is sent exactly
    /// once, so unlike [`RateController::step`] there is no deadline
    /// fit or hysteresis to weigh — any quality headroom the forged
    /// bounds leave is spent on wire bytes.  Pinned sessions hold the
    /// pin; with nothing admissible the primary point is best effort.
    /// Read-only: the decode-side dwell/switch state does not move.
    pub fn prefill_point(&self) -> usize {
        if let Some(p) = self.pinned {
            return p;
        }
        (0..self.ladder.len()).rev().find(|&i| self.admissible(i))
            .unwrap_or(0)
    }

    /// Advance one decode step and return the ladder point to use.
    /// Hysteresis lives here; the emergency lane (current point no
    /// longer within the error budget) bypasses it.
    pub fn step(&mut self) -> usize {
        if let Some(p) = self.pinned {
            self.current = p;
            return p;
        }
        let want = self.desired();
        if want != self.current {
            let emergency = !self.admissible(self.current);
            let rested = self.dwell >= self.cfg.min_dwell_steps;
            let upshift = want < self.current;
            let headroom = !upshift
                || self.est_send_s(want) * self.cfg.up_margin
                    <= self.cfg.target_step_s;
            if emergency || (rested && headroom) {
                self.current = want;
                self.dwell = 0;
                self.switches += 1;
            }
        }
        self.dwell = self.dwell.saturating_add(1);
        self.current
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ladder3() -> Vec<LadderPoint> {
        vec![
            LadderPoint { ks: 9, kd: 15, err_bound: 0.05 },
            LadderPoint { ks: 9, kd: 9, err_bound: 0.15 },
            LadderPoint { ks: 5, kd: 7, err_bound: 0.40 },
        ]
    }

    fn cfg() -> RateConfig {
        RateConfig {
            error_budget: 0.5,
            target_step_s: 0.01,
            ewma_alpha: 0.5,
            min_dwell_steps: 2,
            up_margin: 1.5,
        }
    }

    #[test]
    fn validation_rejects_malformed_ladders() {
        assert!(validate_ladder(&[]).is_err());
        let mut l = ladder3();
        assert!(validate_ladder(&l).is_ok());
        l[2].kd = 99; // not nested
        assert!(validate_ladder(&l).is_err());
        let mut l = ladder3();
        l[1].err_bound = 0.01; // bound not monotone
        assert!(validate_ladder(&l).is_err());
        let mut l = ladder3();
        l[0].err_bound = 1.5; // outside [0, 1]
        assert!(validate_ladder(&l).is_err());
        assert!(RateController::new(ladder3(), RateConfig {
            ewma_alpha: 0.0,
            ..cfg()
        }).is_err());
    }

    #[test]
    fn downshifts_on_a_slow_link_and_recovers() {
        let mut c = RateController::new(ladder3(), cfg()).unwrap();
        // fast link: point-0 frames clear in ~0.1 ms
        c.observe_send(575, 0.0001);
        for _ in 0..3 {
            assert_eq!(c.step(), 0);
        }
        // link collapses: the same frame now takes 100 ms
        let mut seen = Vec::new();
        for _ in 0..6 {
            c.observe_send(575, 0.1);
            seen.push(c.step());
        }
        assert_eq!(*seen.last().unwrap(), 2,
                   "slow link must ride the cheapest admissible point: \
                    {seen:?}");
        // link recovers: cheap frames clear fast again
        for _ in 0..8 {
            c.observe_send(175, 0.00002);
            c.step();
        }
        assert_eq!(c.point(), 0, "fast link must restore full quality");
        assert_eq!(c.switches(), 2, "exactly one down + one up switch");
    }

    #[test]
    fn drift_over_budget_forces_immediate_quality_upshift() {
        let mut c = RateController::new(ladder3(), cfg()).unwrap();
        // park on the cheapest point via a slow link
        for _ in 0..6 {
            c.observe_send(575, 0.1);
            c.step();
        }
        assert_eq!(c.point(), 2);
        // measured stream drift eats the budget: 0.40 + ~0.25 > 0.5
        c.observe_drift(0.5);
        let p = c.step();
        assert!(p < 2, "emergency upshift must bypass dwell, got {p}");
        assert!(c.ladder()[p].err_bound + 0.26 <= 0.51,
                "chosen point must be back within budget");
    }

    #[test]
    fn hysteresis_never_flaps_within_dwell() {
        let mut c = RateController::new(ladder3(), RateConfig {
            min_dwell_steps: 3,
            ..cfg()
        }).unwrap();
        // borderline link: alternate fast and slow observations
        let mut last = c.point();
        let mut switch_gaps = Vec::new();
        let mut since = 0u32;
        for i in 0..60 {
            if i % 2 == 0 {
                c.observe_send(575, 0.1); // slow
            } else {
                c.observe_send(575, 0.0001); // fast
            }
            let p = c.step();
            since += 1;
            if p != last {
                switch_gaps.push(since);
                since = 0;
                last = p;
            }
        }
        // drift is zero, so there are no emergency switches: every
        // switch must respect the dwell floor
        assert!(switch_gaps.iter().all(|&g| g >= 3),
                "switch gaps {switch_gaps:?} violate min_dwell");
    }

    #[test]
    fn pin_holds_and_retarget_clamps() {
        let mut c = RateController::new(ladder3(), cfg()).unwrap();
        c.pin(2).unwrap();
        c.observe_send(575, 0.00001); // blazing link
        assert_eq!(c.step(), 2, "pinned point must hold");
        assert!(c.pin(3).is_err());
        // bucket promotion onto a shorter ladder clamps the pin
        c.retarget(ladder3()[..2].to_vec()).unwrap();
        assert_eq!(c.step(), 1);
        assert_eq!(c.ladder().len(), 2);
    }

    #[test]
    fn prefill_point_rides_the_deepest_admissible_rung() {
        let mut c = RateController::new(ladder3(), cfg()).unwrap();
        // budget 0.5, bounds 0.05/0.15/0.40, no drift: deepest wins
        assert_eq!(c.prefill_point(), 2);
        // and the decode-side state never moved
        assert_eq!(c.point(), 0);
        assert_eq!(c.switches(), 0);
        // measured drift eats the budget (EWMA 0.45): only point 0
        // stays admissible
        c.observe_drift(0.9);
        assert_eq!(c.prefill_point(), 0);
        // a pin overrides the choice
        c.pin(1).unwrap();
        assert_eq!(c.prefill_point(), 1);
    }

    #[test]
    fn manifest_ladder_parsing() {
        let j = crate::util::json::parse(
            r#"{"ks": 9, "kd": 15, "ladder": [
                 {"ks": 9, "kd": 15, "err_bound": 0.1},
                 {"ks": 9, "kd": 9, "err_bound": 0.2}]}"#).unwrap();
        let l = ladder_from_manifest(&j, 16, 32).unwrap();
        assert_eq!(l.len(), 2);
        assert_eq!((l[0].ks, l[0].kd), (9, 15));
        assert!((l[1].err_bound - 0.2).abs() < 1e-12);
        // no ladder array: single vacuous point
        let j = crate::util::json::parse(r#"{"ks": 9, "kd": 15}"#).unwrap();
        let l = ladder_from_manifest(&j, 16, 32).unwrap();
        assert_eq!(l.len(), 1);
        assert_eq!(l[0].err_bound, 1.0);
        // point 0 disagreeing with the primary block is a bug
        let j = crate::util::json::parse(
            r#"{"ks": 9, "kd": 15, "ladder": [
                 {"ks": 7, "kd": 15, "err_bound": 0.1}]}"#).unwrap();
        assert!(ladder_from_manifest(&j, 16, 32).is_err());
        // geometry invalid for the bucket (even, non-full axis)
        let j = crate::util::json::parse(
            r#"{"ks": 4, "kd": 15, "ladder": [
                 {"ks": 4, "kd": 15, "err_bound": 0.1}]}"#).unwrap();
        assert!(ladder_from_manifest(&j, 16, 32).is_err());
    }
}
