//! Low-rank factorization baselines: QR truncation [53] and the SVD
//! family — FWSVD [25], ASVD [26], SVD-LLM [27] — adapted from their
//! weight-compression formulations to the activation-compression
//! setting the paper evaluates them in (Table III).
//!
//! Wire body (qr / svd*):  u16 r | extras | U·diag (rows×r) | Vt (r×cols)
//! where `extras` are the per-variant side vectors (FWSVD row weights,
//! ASVD column scales); SVD-LLM's whitening transform is derived from
//! the payload itself on the decoder side, so it ships no extras.

use super::engine::CodecEngine;
use super::{Codec, Payload, Reader, Writer};
use crate::linalg::matrix::Mat;
use crate::linalg::qr::qr_thin;
use crate::linalg::svd::svd_thin;
use crate::tensor::MatView;
use anyhow::{ensure, Result};

// NOTE: the factorization codecs are cold-path baselines (the paper's
// Table IV shows them orders of magnitude slower than FC); they write
// through the engine-owned payload/output buffers like every codec,
// but their internal QR/SVD working set still allocates `Mat`s — the
// allocation-free invariant is only claimed for the serving codec.

/// rank such that r·(rows+cols) + extras ≈ rows·cols / ratio
fn rank_for_ratio(rows: usize, cols: usize, ratio: f64, extra_floats: usize)
    -> usize {
    let budget = (rows * cols) as f64 / ratio - extra_floats as f64;
    ((budget / (rows + cols) as f64).floor() as usize).clamp(1, rows.min(cols))
}

fn write_factors(w: &mut Writer, us: &Mat, vt: &Mat, r: usize) {
    for i in 0..us.rows {
        for j in 0..r {
            w.f32(us[(i, j)] as f32);
        }
    }
    for i in 0..r {
        for j in 0..vt.cols {
            w.f32(vt[(i, j)] as f32);
        }
    }
}

fn read_factors(rd: &mut Reader, rows: usize, cols: usize, r: usize)
    -> Result<(Mat, Mat)> {
    let mut us = Mat::zeros(rows, r);
    for i in 0..rows {
        for j in 0..r {
            us[(i, j)] = rd.f32()? as f64;
        }
    }
    let mut vt = Mat::zeros(r, cols);
    for i in 0..r {
        for j in 0..cols {
            vt[(i, j)] = rd.f32()? as f64;
        }
    }
    Ok((us, vt))
}

// ---------------------------------------------------------------------------
// QR
// ---------------------------------------------------------------------------

pub struct QrCodec;

impl Codec for QrCodec {
    fn name(&self) -> &'static str {
        "qr"
    }

    fn compress_into(&self, _eng: &mut CodecEngine, a: MatView<'_>,
                     ratio: f64, out: &mut Payload) -> Result<()> {
        let (rows, cols) = (a.rows(), a.cols());
        let r = rank_for_ratio(rows, cols, ratio, 0);
        let m = Mat::from_f32(a.as_slice(), rows, cols);
        let (q, rr) = qr_thin(&m);
        out.reset("qr", rows, cols);
        let mut w = Writer(&mut out.body);
        w.u16(r as u16);
        write_factors(&mut w, &q, &rr, r);
        Ok(())
    }

    fn decompress_into(&self, _eng: &mut CodecEngine, p: &Payload,
                       out: &mut Vec<f32>) -> Result<()> {
        let mut rd = Reader::new(&p.body);
        let r = rd.u16()? as usize;
        ensure!(r >= 1 && r <= p.rows.min(p.cols), "bad rank {r}");
        let (q, rr) = read_factors(&mut rd, p.rows, p.cols, r)?;
        ensure!(rd.remaining() == 0, "trailing payload bytes");
        out.clear();
        out.extend(q.matmul(&rr).to_f32());
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// SVD family
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SvdVariant {
    /// plain truncated SVD
    Plain,
    /// FWSVD: importance-weighted rows (Fisher proxy = row energy)
    Fwsvd,
    /// ASVD: activation-magnitude column scaling before decomposition
    Asvd,
    /// SVD-LLM: whitening (Cholesky of the row-Gram) before decomposition
    SvdLlm,
}

pub struct SvdCodec {
    pub variant: SvdVariant,
}

impl SvdCodec {
    pub fn plain() -> SvdCodec {
        SvdCodec { variant: SvdVariant::Plain }
    }
    pub fn fwsvd() -> SvdCodec {
        SvdCodec { variant: SvdVariant::Fwsvd }
    }
    pub fn asvd() -> SvdCodec {
        SvdCodec { variant: SvdVariant::Asvd }
    }
    pub fn svdllm() -> SvdCodec {
        SvdCodec { variant: SvdVariant::SvdLlm }
    }

    fn extra_floats(&self, rows: usize, cols: usize) -> usize {
        match self.variant {
            SvdVariant::Plain | SvdVariant::SvdLlm => 0,
            SvdVariant::Fwsvd => rows,
            SvdVariant::Asvd => cols,
        }
    }
}

impl Codec for SvdCodec {
    fn name(&self) -> &'static str {
        match self.variant {
            SvdVariant::Plain => "svd",
            SvdVariant::Fwsvd => "fwsvd",
            SvdVariant::Asvd => "asvd",
            SvdVariant::SvdLlm => "svdllm",
        }
    }

    fn compress_into(&self, _eng: &mut CodecEngine, a: MatView<'_>,
                     ratio: f64, out: &mut Payload) -> Result<()> {
        let (rows, cols) = (a.rows(), a.cols());
        let extras = self.extra_floats(rows, cols);
        let r = rank_for_ratio(rows, cols, ratio, extras);
        let mut m = Mat::from_f32(a.as_slice(), rows, cols);

        out.reset(self.name(), rows, cols);
        let mut w = Writer(&mut out.body);
        w.u16(r as u16);

        // pre-transform
        let mut row_w: Vec<f64> = vec![];
        let mut col_s: Vec<f64> = vec![];
        match self.variant {
            SvdVariant::Plain => {}
            SvdVariant::Fwsvd => {
                // weight rows by their energy (importance proxy)
                row_w = m
                    .row_norms()
                    .iter()
                    .map(|&n| (n / (cols as f64).sqrt()).max(1e-3))
                    .collect();
                for (i, &wi) in row_w.iter().enumerate() {
                    for v in m.row_mut(i) {
                        *v *= wi;
                    }
                }
                for &wi in &row_w {
                    w.f32(wi as f32);
                }
            }
            SvdVariant::Asvd => {
                // scale columns by mean |activation|^alpha (alpha = 0.5)
                col_s = (0..cols)
                    .map(|c| {
                        let mean: f64 = (0..rows)
                            .map(|rr| m[(rr, c)].abs())
                            .sum::<f64>()
                            / rows as f64;
                        mean.max(1e-4).sqrt()
                    })
                    .collect();
                m.scale_cols(&col_s);
                for &s in &col_s {
                    w.f32(s as f32);
                }
            }
            SvdVariant::SvdLlm => {
                // whiten rows: L^{-1} A with L = chol(AAᵀ/cols + λI).
                // The decoder cannot rebuild L (it never sees A), so we
                // fold L back into U before transmission — whitening
                // here only *guides* which directions the truncation
                // keeps, exactly the role it plays in SVD-LLM.
                let l = chol_row_gram(&m, 1e-3);
                let li = lower_inverse(&l);
                let wm = li.matmul(&m);
                let d = svd_thin(&wm);
                let mut us = l.matmul(&d.u); // unwhiten the left factor
                for i in 0..us.rows {
                    for j in 0..us.cols {
                        us[(i, j)] *= d.s[j];
                    }
                }
                write_factors(&mut w, &us, &d.vt, r);
                return Ok(());
            }
        }

        let d = svd_thin(&m);
        let mut us = d.u.clone();
        for i in 0..us.rows {
            for j in 0..us.cols {
                us[(i, j)] *= d.s[j];
            }
        }
        write_factors(&mut w, &us, &d.vt, r);
        let _ = (&row_w, &col_s);
        Ok(())
    }

    fn decompress_into(&self, _eng: &mut CodecEngine, p: &Payload,
                       out: &mut Vec<f32>) -> Result<()> {
        let (rows, cols) = (p.rows, p.cols);
        let mut rd = Reader::new(&p.body);
        let r = rd.u16()? as usize;
        ensure!(r >= 1 && r <= rows.min(cols), "bad rank {r}");

        let mut row_w: Vec<f64> = vec![];
        let mut col_s: Vec<f64> = vec![];
        match self.variant {
            SvdVariant::Fwsvd => {
                for _ in 0..rows {
                    row_w.push(rd.f32()? as f64);
                }
            }
            SvdVariant::Asvd => {
                for _ in 0..cols {
                    col_s.push(rd.f32()? as f64);
                }
            }
            _ => {}
        }
        let (us, vt) = read_factors(&mut rd, rows, cols, r)?;
        ensure!(rd.remaining() == 0, "trailing payload bytes");
        let mut rec = us.matmul(&vt);

        // undo pre-transforms
        match self.variant {
            SvdVariant::Fwsvd => {
                for i in 0..rows {
                    let inv = 1.0 / row_w[i].max(1e-12);
                    for v in rec.row_mut(i) {
                        *v *= inv;
                    }
                }
            }
            SvdVariant::Asvd => {
                let inv: Vec<f64> = col_s.iter().map(|&s| 1.0 / s.max(1e-12)).collect();
                rec.scale_cols(&inv);
            }
            _ => {}
        }
        out.clear();
        out.extend(rec.to_f32());
        Ok(())
    }
}

/// Cholesky of (A Aᵀ / cols + lambda I), lower triangular.
fn chol_row_gram(a: &Mat, lambda: f64) -> Mat {
    let n = a.rows;
    let mut g = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let dot: f64 = a.row(i).iter().zip(a.row(j)).map(|(x, y)| x * y).sum();
            let v = dot / a.cols as f64 + if i == j { lambda } else { 0.0 };
            g[(i, j)] = v;
            g[(j, i)] = v;
        }
    }
    // in-place cholesky
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = g[(i, j)];
            for k in 0..j {
                sum -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                l[(i, j)] = sum.max(1e-12).sqrt();
            } else {
                l[(i, j)] = sum / l[(j, j)];
            }
        }
    }
    l
}

/// Inverse of a lower-triangular matrix by forward substitution.
fn lower_inverse(l: &Mat) -> Mat {
    let n = l.rows;
    let mut inv = Mat::zeros(n, n);
    for col in 0..n {
        inv[(col, col)] = 1.0 / l[(col, col)];
        for i in col + 1..n {
            let mut sum = 0.0;
            for k in col..i {
                sum -= l[(i, k)] * inv[(k, col)];
            }
            inv[(i, col)] = sum / l[(i, i)];
        }
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{rand_act, rel_error, Codec};

    #[test]
    fn qr_low_rank_input_exact() {
        // rank-3 matrix survives rank>=3 truncation exactly
        let b = Mat::from_f32(&rand_act(24, 3, 1), 24, 3);
        let c = Mat::from_f32(&rand_act(3, 48, 2), 3, 48);
        let a = b.matmul(&c).to_f32();
        let codec = QrCodec;
        // ratio so that rank >= 3: r = 24*48/(ratio*72) >= 3 -> ratio <= 5.3
        let out = codec.roundtrip(&a, 24, 48, 5.0).unwrap();
        assert!(rel_error(&a, &out) < 1e-5);
    }

    #[test]
    fn svd_beats_qr_at_same_ratio() {
        // Eckart-Young at the codec level
        let a = rand_act(48, 96, 3);
        let e_svd = rel_error(&a, &SvdCodec::plain().roundtrip(&a, 48, 96, 6.0).unwrap());
        let e_qr = rel_error(&a, &QrCodec.roundtrip(&a, 48, 96, 6.0).unwrap());
        assert!(e_svd <= e_qr + 1e-9, "svd {e_svd} qr {e_qr}");
    }

    #[test]
    fn all_variants_roundtrip_reasonably() {
        let a = rand_act(32, 64, 4);
        for codec in [SvdCodec::plain(), SvdCodec::fwsvd(), SvdCodec::asvd(),
                      SvdCodec::svdllm()] {
            let out = codec.roundtrip(&a, 32, 64, 4.0).unwrap();
            let err = rel_error(&a, &out);
            assert!(err < 1.0, "{} err {err}", codec.name());
        }
    }

    #[test]
    fn payload_sizes_match_rank_accounting() {
        let a = rand_act(40, 80, 5);
        for (codec, extras) in [(SvdCodec::plain(), 0usize),
                                (SvdCodec::fwsvd(), 40),
                                (SvdCodec::asvd(), 80)] {
            let p = codec.compress(&a, 40, 80, 8.0).unwrap();
            let floats = (p.body.len() - 2) / 4;
            assert_eq!((floats - extras) % (40 + 80), 0, "{}", codec.name());
            assert!(p.achieved_ratio() >= 8.0 * 0.7, "{}", codec.name());
        }
    }

    #[test]
    fn cholesky_correct() {
        let a = Mat::from_f32(&rand_act(8, 20, 6), 8, 20);
        let l = chol_row_gram(&a, 1e-3);
        // L Lᵀ == gram
        let llt = l.matmul(&l.transpose());
        for i in 0..8 {
            for j in 0..8 {
                let dot: f64 = a.row(i).iter().zip(a.row(j)).map(|(x, y)| x * y).sum();
                let g = dot / 20.0 + if i == j { 1e-3 } else { 0.0 };
                assert!((llt[(i, j)] - g).abs() < 1e-9);
            }
        }
        let li = lower_inverse(&l);
        let eye = li.matmul(&l);
        assert!(eye.sub(&Mat::eye(8)).frob_norm() < 1e-8);
    }
}
