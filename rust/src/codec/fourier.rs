//! The FourierCompress codec (software path): 2-D FFT, centred
//! low-frequency block retention, conjugate-symmetric wire packing,
//! zero-pad + inverse FFT reconstruction.
//!
//! Wire body:  u16 ks | u16 kd | f32 × (packed coefficients)
//!
//! Packing walks the kept frequency set in canonical (row-major over
//! the centred index lists) order and stores, for each coefficient
//! whose (u, v) is lexicographically <= its conjugate mirror, `re`
//! (and `im` unless the point is self-conjugate).  The decoder
//! regenerates mirrors, so a K_S×K_D complex block costs exactly
//! K_S·K_D floats — this is the "conjugate symmetry-aware" transport
//! the paper describes, applied to transmission as well as
//! reconstruction (DESIGN.md §6).
//!
//! All entry points are `_into`-style over a [`CodecEngine`]: plans,
//! frequency index sets, and every scratch buffer (`narrow`, `z`,
//! `col`, `block`, `spec`) live in the engine, so the per-token decode
//! loop re-uses them and performs zero heap allocation after warm-up.
//! The plain-named wrappers route through the thread-local engine and
//! stay byte-compatible with the pre-engine codec.

use super::engine::{self, CodecEngine};
use super::{block_ratio, fc_block, Codec, Payload, Reader, Writer};
use crate::dsp::complex::C64;
use crate::tensor::MatView;

use anyhow::{ensure, Result};

#[derive(Debug, Clone, Default)]
pub struct FourierCodec {
    /// Calibrated hidden-axis block width (None = D/8 heuristic).
    pub kd_hint: Option<usize>,
}

impl FourierCodec {
    pub fn with_hint(kd_hint: usize) -> FourierCodec {
        FourierCodec { kd_hint: Some(kd_hint) }
    }

    /// Compress with an explicit block (the eval sweeps use this).
    ///
    /// Perf note (EXPERIMENTS.md §Perf): only the K_D kept spectrum
    /// columns are needed, so after the row FFT pass the column pass
    /// runs on K_D columns instead of all D — ~40% cheaper than a full
    /// fft2 at the shipped block shapes.
    pub fn compress_block_into(&self, eng: &mut CodecEngine, a: MatView<'_>,
                               ks: usize, kd: usize, out: &mut Payload)
        -> Result<()> {
        let (rows, cols) = (a.rows(), a.cols());
        let ui = eng.indices(rows, ks);
        let vi = eng.indices(cols, kd);
        let plan_s = eng.plan(rows);
        let plan_d = eng.plan(cols);
        let data = a.as_slice();

        let CodecEngine { narrow, z, col, block, .. } = eng;
        engine::zeroed(narrow, rows * kd); // [rows, K_D]
        engine::zeroed(z, cols);

        // row pass with the two-for-one real-FFT trick: pack row pairs
        // (r, r+1) as re/im of ONE complex FFT and split by conjugate
        // symmetry — halves the row-pass FFT count; only the K_D kept
        // columns are materialised (EXPERIMENTS.md §Perf, iter 2).
        let mut r = 0;
        while r < rows {
            let hi = (r + 1 < rows) as usize;
            for v in 0..cols {
                z[v] = C64::new(data[r * cols + v] as f64,
                                if hi == 1 { data[(r + 1) * cols + v] as f64 }
                                else { 0.0 });
            }
            plan_d.forward_in_place(z);
            for (j, &v) in vi.iter().enumerate() {
                let zc = z[v];
                let zm = z[(cols - v) % cols].conj();
                narrow[r * kd + j] = (zc + zm).scale(0.5);
                if hi == 1 {
                    // (zc - zm) / (2i) = -i (zc - zm) / 2
                    let d = (zc - zm).scale(0.5);
                    narrow[(r + 1) * kd + j] = C64::new(d.im, -d.re);
                }
            }
            r += 2;
        }
        // selective column pass over the K_D kept columns
        engine::zeroed(block, ks * kd);
        engine::zeroed(col, rows);
        for j in 0..kd {
            for rr in 0..rows {
                col[rr] = narrow[rr * kd + j];
            }
            plan_s.forward_in_place(col);
            for (i, &u) in ui.iter().enumerate() {
                block[i * kd + j] = col[u];
            }
        }

        out.reset("fc", rows, cols);
        let mut w = Writer(&mut out.body);
        w.u16(ks as u16);
        w.u16(kd as u16);
        for (i, &u) in ui.iter().enumerate() {
            for (j, &v) in vi.iter().enumerate() {
                let (mu, mv) = ((rows - u) % rows, (cols - v) % cols);
                if (u, v) > (mu, mv) {
                    continue; // mirror carries it
                }
                let c = block[i * kd + j];
                w.f32(c.re as f32);
                if (u, v) != (mu, mv) {
                    w.f32(c.im as f32);
                }
            }
        }
        Ok(())
    }

    /// One-shot explicit-block compression (legacy API; thread-local
    /// engine).
    pub fn compress_block(&self, a: &[f32], rows: usize, cols: usize,
                          ks: usize, kd: usize) -> Result<Payload> {
        ensure!(a.len() == rows * cols, "shape mismatch");
        let view = MatView::new(a, rows, cols);
        engine::with_thread_engine(|eng| {
            let mut out = Payload::empty();
            self.compress_block_into(eng, view, ks, kd, &mut out)?;
            Ok(out)
        })
    }
}

impl Codec for FourierCodec {
    fn name(&self) -> &'static str {
        "fc"
    }

    fn compress_into(&self, eng: &mut CodecEngine, a: MatView<'_>, ratio: f64,
                     out: &mut Payload) -> Result<()> {
        let (ks, kd) = fc_block(a.rows(), a.cols(), ratio, self.kd_hint);
        debug_assert!(block_ratio(a.rows(), a.cols(), ks, kd) >= ratio * 0.8);
        self.compress_block_into(eng, a, ks, kd, out)
    }

    fn decompress_into(&self, eng: &mut CodecEngine, p: &Payload,
                       out: &mut Vec<f32>) -> Result<()> {
        let (rows, cols) = (p.rows, p.cols);
        let mut r = Reader::new(&p.body);
        let ks = r.u16()? as usize;
        let kd = r.u16()? as usize;
        ensure!(super::valid_block_axis(rows, ks) && super::valid_block_axis(cols, kd),
                "bad block {ks}x{kd} for {rows}x{cols}");
        let ui = eng.indices(rows, ks);
        let vi = eng.indices(cols, kd);
        let plan_s = eng.plan(rows);
        let plan_d = eng.plan(cols);

        // scatter the conjugate-completed block into the (sparse) spectrum
        let CodecEngine { spec, col, .. } = eng;
        engine::zeroed(spec, rows * cols);
        for &u in ui.iter() {
            for &v in vi.iter() {
                let (mu, mv) = ((rows - u) % rows, (cols - v) % cols);
                if (u, v) > (mu, mv) {
                    continue;
                }
                let re = r.f32()? as f64;
                let im = if (u, v) != (mu, mv) { r.f32()? as f64 } else { 0.0 };
                spec[u * cols + v] = C64::new(re, im);
                spec[mu * cols + mv] = C64::new(re, -im);
            }
        }
        ensure!(r.remaining() == 0, "trailing payload bytes");
        // inverse column pass only where columns are non-zero, then
        // inverse row pass (EXPERIMENTS.md §Perf)
        engine::zeroed(col, rows);
        for &v in vi.iter() {
            for rr in 0..rows {
                col[rr] = spec[rr * cols + v];
            }
            plan_s.inverse_in_place(col);
            for rr in 0..rows {
                spec[rr * cols + v] = col[rr];
            }
        }
        for rr in 0..rows {
            plan_d.inverse_in_place(&mut spec[rr * cols..(rr + 1) * cols]);
        }
        out.clear();
        out.reserve(rows * cols);
        out.extend(spec.iter().map(|c| c.re as f32));
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// block pack/unpack — the serving path's wire transform
// ---------------------------------------------------------------------------
//
// The fused client HLO emits the FULL (re, im) K_S×K_D block; these
// helpers convert it to/from the non-redundant float packing used by
// the Activation frame, so the serving path pays the same wire bytes
// as the software codec.  The `_into` forms reuse the caller's
// buffers and the engine's cached index sets.

/// index of frequency `u` inside the centred list for (n, k)
fn block_pos(n: usize, k: usize, u: usize) -> usize {
    if k == n {
        return u;
    }
    let h = (k - 1) / 2;
    if u <= h {
        u
    } else {
        u - (n - k)
    }
}

/// Pack a full (re, im) block (row-major ks×kd) into the symmetric
/// half representation, appended into `out` (cleared first).
/// `rows`/`cols` are the pre-compression matrix dims the block was
/// computed for.
pub fn pack_block_into(eng: &mut CodecEngine, re: &[f32], im: &[f32],
                       rows: usize, cols: usize, ks: usize, kd: usize,
                       out: &mut Vec<f32>) {
    let ui = eng.indices(rows, ks);
    let vi = eng.indices(cols, kd);
    out.clear();
    out.reserve(ks * kd);
    for (i, &u) in ui.iter().enumerate() {
        for (j, &v) in vi.iter().enumerate() {
            let (mu, mv) = ((rows - u) % rows, (cols - v) % cols);
            if (u, v) > (mu, mv) {
                continue;
            }
            out.push(re[i * kd + j]);
            if (u, v) != (mu, mv) {
                out.push(im[i * kd + j]);
            }
        }
    }
}

/// One-shot [`pack_block_into`] (legacy API; thread-local engine).
pub fn pack_block(re: &[f32], im: &[f32], rows: usize, cols: usize,
                  ks: usize, kd: usize) -> Vec<f32> {
    engine::with_thread_engine(|eng| {
        let mut out = Vec::new();
        pack_block_into(eng, re, im, rows, cols, ks, kd, &mut out);
        out
    })
}

/// Inverse of [`pack_block_into`]: regenerate the full (re, im)
/// planes into the caller's buffers (cleared first).
pub fn unpack_block_into(eng: &mut CodecEngine, packed: &[f32],
                         rows: usize, cols: usize, ks: usize, kd: usize,
                         re: &mut Vec<f32>, im: &mut Vec<f32>) -> Result<()> {
    let ui = eng.indices(rows, ks);
    let vi = eng.indices(cols, kd);
    re.clear();
    re.resize(ks * kd, 0.0);
    im.clear();
    im.resize(ks * kd, 0.0);
    let mut pos = 0usize;
    let take = |n: &mut usize| -> Result<f32> {
        ensure!(*n < packed.len(), "packed block truncated");
        let v = packed[*n];
        *n += 1;
        Ok(v)
    };
    for (i, &u) in ui.iter().enumerate() {
        for (j, &v) in vi.iter().enumerate() {
            let (mu, mv) = ((rows - u) % rows, (cols - v) % cols);
            if (u, v) > (mu, mv) {
                continue;
            }
            let r = take(&mut pos)?;
            let iv = if (u, v) != (mu, mv) { take(&mut pos)? } else { 0.0 };
            re[i * kd + j] = r;
            im[i * kd + j] = iv;
            // mirror position inside the block
            let (mi, mj) = (block_pos(rows, ks, mu), block_pos(cols, kd, mv));
            re[mi * kd + mj] = r;
            im[mi * kd + mj] = -iv;
        }
    }
    ensure!(pos == packed.len(), "trailing packed floats");
    Ok(())
}

/// One-shot [`unpack_block_into`] (legacy API; thread-local engine).
pub fn unpack_block(packed: &[f32], rows: usize, cols: usize,
                    ks: usize, kd: usize) -> Result<(Vec<f32>, Vec<f32>)> {
    engine::with_thread_engine(|eng| {
        let (mut re, mut im) = (Vec::new(), Vec::new());
        unpack_block_into(eng, packed, rows, cols, ks, kd, &mut re, &mut im)?;
        Ok((re, im))
    })
}

// ---------------------------------------------------------------------------
// nested-block crop/embed — the adaptive rate ladder's transform
// ---------------------------------------------------------------------------
//
// A ladder point (`codec::rate`) keeps a centred block nested inside
// the bucket's primary block (ks1 <= ks0, kd1 <= kd0): its frequency
// set is a subset of the primary's, so the device can *crop* the full
// (re, im) block its fused executable already emits — no second
// compile per point — and the server *embeds* the small block back
// into a zeroed primary-geometry block, the truncated frequencies
// reconstructing as zero exactly like FC truncation itself.

fn ensure_nested(rows: usize, cols: usize, ks0: usize, kd0: usize,
                 ks1: usize, kd1: usize) -> Result<()> {
    ensure!(ks1 <= ks0 && kd1 <= kd0,
            "block {ks1}x{kd1} not nested in {ks0}x{kd0}");
    ensure!(super::valid_block_axis(rows, ks0)
                && super::valid_block_axis(cols, kd0)
                && super::valid_block_axis(rows, ks1)
                && super::valid_block_axis(cols, kd1),
            "invalid nested blocks {ks1}x{kd1} <= {ks0}x{kd0} \
             for {rows}x{cols}");
    Ok(())
}

/// Crop a full (re, im) `ks0`×`kd0` block to the nested ladder point
/// `ks1`×`kd1` (buffers cleared first).  A pure gather: the centred
/// index set for a smaller odd width is a subset of the larger one's.
pub fn crop_block_into(eng: &mut CodecEngine, re0: &[f32], im0: &[f32],
                       rows: usize, cols: usize, ks0: usize, kd0: usize,
                       ks1: usize, kd1: usize,
                       re1: &mut Vec<f32>, im1: &mut Vec<f32>) -> Result<()> {
    ensure_nested(rows, cols, ks0, kd0, ks1, kd1)?;
    ensure!(re0.len() == ks0 * kd0 && im0.len() == ks0 * kd0,
            "crop source carries {} floats, geometry wants {}", re0.len(),
            ks0 * kd0);
    let ui = eng.indices(rows, ks1);
    let vi = eng.indices(cols, kd1);
    re1.clear();
    im1.clear();
    re1.reserve(ks1 * kd1);
    im1.reserve(ks1 * kd1);
    for &u in ui.iter() {
        let i0 = block_pos(rows, ks0, u);
        for &v in vi.iter() {
            let j0 = block_pos(cols, kd0, v);
            re1.push(re0[i0 * kd0 + j0]);
            im1.push(im0[i0 * kd0 + j0]);
        }
    }
    Ok(())
}

/// Inverse of [`crop_block_into`]: scatter a nested `ks1`×`kd1` block
/// into a zeroed `ks0`×`kd0` primary block (buffers cleared first).
pub fn embed_block_into(eng: &mut CodecEngine, re1: &[f32], im1: &[f32],
                        rows: usize, cols: usize, ks1: usize, kd1: usize,
                        ks0: usize, kd0: usize,
                        re0: &mut Vec<f32>, im0: &mut Vec<f32>) -> Result<()> {
    ensure_nested(rows, cols, ks0, kd0, ks1, kd1)?;
    ensure!(re1.len() == ks1 * kd1 && im1.len() == ks1 * kd1,
            "embed source carries {} floats, geometry wants {}", re1.len(),
            ks1 * kd1);
    let ui = eng.indices(rows, ks1);
    let vi = eng.indices(cols, kd1);
    re0.clear();
    re0.resize(ks0 * kd0, 0.0);
    im0.clear();
    im0.resize(ks0 * kd0, 0.0);
    for (a, &u) in ui.iter().enumerate() {
        let i0 = block_pos(rows, ks0, u);
        for (b, &v) in vi.iter().enumerate() {
            let j0 = block_pos(cols, kd0, v);
            re0[i0 * kd0 + j0] = re1[a * kd1 + b];
            im0[i0 * kd0 + j0] = im1[a * kd1 + b];
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{freq_indices, rand_act, rel_error};

    #[test]
    fn pack_unpack_roundtrip() {
        let (rows, cols, ks, kd) = (32usize, 128usize, 9usize, 15usize);
        // build a conjugate-symmetric block from a real matrix
        let a = rand_act(rows, cols, 42);
        let spec = crate::dsp::fft2d::fft2_real(MatView::new(&a, rows, cols));
        let ui = freq_indices(rows, ks);
        let vi = freq_indices(cols, kd);
        let mut re = vec![0.0f32; ks * kd];
        let mut im = vec![0.0f32; ks * kd];
        for (i, &u) in ui.iter().enumerate() {
            for (j, &v) in vi.iter().enumerate() {
                re[i * kd + j] = spec[u * cols + v].re as f32;
                im[i * kd + j] = spec[u * cols + v].im as f32;
            }
        }
        let packed = pack_block(&re, &im, rows, cols, ks, kd);
        assert_eq!(packed.len(), ks * kd);
        let (re2, im2) = unpack_block(&packed, rows, cols, ks, kd).unwrap();
        for (a, b) in re.iter().zip(&re2) {
            assert!((a - b).abs() < 1e-5);
        }
        for (a, b) in im.iter().zip(&im2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn pack_full_axis_block() {
        // ks == rows (even full axis) exercises the k == n branch
        let (rows, cols, ks, kd) = (16usize, 64usize, 16usize, 7usize);
        let a = rand_act(rows, cols, 7);
        let spec = crate::dsp::fft2d::fft2_real(MatView::new(&a, rows, cols));
        let ui = freq_indices(rows, ks);
        let vi = freq_indices(cols, kd);
        let mut re = vec![0.0f32; ks * kd];
        let mut im = vec![0.0f32; ks * kd];
        for (i, &u) in ui.iter().enumerate() {
            for (j, &v) in vi.iter().enumerate() {
                re[i * kd + j] = spec[u * cols + v].re as f32;
                im[i * kd + j] = spec[u * cols + v].im as f32;
            }
        }
        let packed = pack_block(&re, &im, rows, cols, ks, kd);
        // self-conjugate points: (0,0) and (rows/2, 0) -> ks*kd floats
        assert_eq!(packed.len(), ks * kd);
        let (re2, im2) = unpack_block(&packed, rows, cols, ks, kd).unwrap();
        for (a, b) in re.iter().zip(&re2) {
            assert!((a - b).abs() < 1e-5);
        }
        for (a, b) in im.iter().zip(&im2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn payload_floats_equal_ks_kd() {
        let (rows, cols) = (48, 96);
        let a = rand_act(rows, cols, 1);
        let c = FourierCodec::default();
        for (ks, kd) in [(5, 13), (47, 13), (48, 11), (1, 1)] {
            let p = c.compress_block(&a, rows, cols, ks, kd).unwrap();
            let floats = (p.body.len() - 4) / 4;
            assert_eq!(floats, ks * kd, "block {ks}x{kd}");
        }
    }

    #[test]
    fn bandlimited_roundtrip_exact() {
        // signal synthesised inside the kept band -> exact recovery
        let (rows, cols, ks, kd) = (32usize, 96usize, 9usize, 13usize);
        let ui = freq_indices(rows, ks);
        let vi = freq_indices(cols, kd);
        let mut rng = crate::util::rng::Rng::new(3);
        let mut spec = vec![C64::ZERO; rows * cols];
        for &u in &ui {
            for &v in &vi {
                let (mu, mv) = ((rows - u) % rows, (cols - v) % cols);
                if (u, v) > (mu, mv) {
                    continue;
                }
                let c = if (u, v) == (mu, mv) {
                    C64::new(rng.normal(), 0.0)
                } else {
                    C64::new(rng.normal(), rng.normal())
                };
                spec[u * cols + v] = c;
                spec[mu * cols + mv] = c.conj();
            }
        }
        crate::dsp::fft2d::ifft2(&mut spec, rows, cols);
        let a: Vec<f32> = spec.iter().map(|c| c.re as f32).collect();

        let codec = FourierCodec::default();
        let p = codec.compress_block(&a, rows, cols, ks, kd).unwrap();
        let out = codec.decompress(&p).unwrap();
        assert!(rel_error(&a, &out) < 1e-5);
    }

    #[test]
    fn full_block_is_lossless() {
        let (rows, cols) = (16, 31);
        let a = rand_act(rows, cols, 9);
        let codec = FourierCodec::default();
        let p = codec.compress_block(&a, rows, cols, rows, cols).unwrap();
        let out = codec.decompress(&p).unwrap();
        assert!(rel_error(&a, &out) < 1e-5);
    }

    #[test]
    fn deterministic_bytes() {
        let a = rand_act(24, 48, 5);
        let codec = FourierCodec::default();
        let p1 = codec.compress(&a, 24, 48, 8.0).unwrap();
        let p2 = codec.compress(&a, 24, 48, 8.0).unwrap();
        assert_eq!(p1, p2);
    }

    #[test]
    fn engine_path_matches_legacy_bytes() {
        // the tentpole invariant: compress_into over a caller-owned
        // engine emits exactly the bytes the one-shot path emits
        let (rows, cols) = (31, 100);
        let a = rand_act(rows, cols, 11);
        let codec = FourierCodec::default();
        let legacy = codec.compress(&a, rows, cols, 6.0).unwrap();

        let mut eng = CodecEngine::new();
        let mut p = Payload::empty();
        for _ in 0..3 {
            codec.compress_into(&mut eng, MatView::new(&a, rows, cols), 6.0,
                                &mut p).unwrap();
            assert_eq!(p, legacy);
        }
        let mut out = Vec::new();
        codec.decompress_into(&mut eng, &p, &mut out).unwrap();
        assert_eq!(out, codec.decompress(&legacy).unwrap());
    }

    #[test]
    fn arbitrary_sizes_roundtrip() {
        // non-pow2 both axes (bluestein path), incl. odd row counts
        for (rows, cols) in [(31, 96), (17, 60), (48, 100), (5, 7)] {
            let a = rand_act(rows, cols, (rows * cols) as u64);
            let codec = FourierCodec::default();
            let out = codec.roundtrip(&a, rows, cols, 4.0).unwrap();
            assert_eq!(out.len(), a.len());
            assert!(out.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn kd_hint_respected() {
        let a = rand_act(64, 128, 6);
        let codec = FourierCodec::with_hint(15);
        let p = codec.compress(&a, 64, 128, 8.0).unwrap();
        let mut r = Reader::new(&p.body);
        let _ks = r.u16().unwrap();
        assert_eq!(r.u16().unwrap(), 15);
    }

    /// Naive reference: full 2-D FFT, gather the centred block, scatter
    /// into a zero spectrum, inverse FFT (the `runtime::interp` codec
    /// path, which mirrors python kernels/ref.py).
    fn naive_roundtrip(a: &[f32], rows: usize, cols: usize, ks: usize,
                       kd: usize) -> Vec<f32> {
        use crate::runtime::interp::{fc_compress_naive, fc_decompress_naive};
        let (re, im) = fc_compress_naive(a, rows, cols, ks, kd);
        fc_decompress_naive(&re, &im, rows, cols, ks, kd)
    }

    /// Largest valid centred width ≤ k for an n-point axis.
    fn oddify(k: usize, n: usize) -> usize {
        let k = k.clamp(1, n);
        if k == n || k % 2 == 1 { k } else { k - 1 }
    }

    /// Reconstruction disagreement normalised by the INPUT energy —
    /// stable even for near-empty blocks (a (1,1) block reconstructs
    /// to ~zero, which would blow up a plain relative error).
    fn recon_err(input: &[f32], want: &[f32], got: &[f32]) -> f64 {
        let num: f64 = want.iter().zip(got)
            .map(|(x, y)| ((x - y) as f64).powi(2)).sum();
        let den: f64 = input.iter().map(|x| (*x as f64).powi(2)).sum();
        (num / den.max(1e-30)).sqrt()
    }

    #[test]
    fn edge_blocks_match_naive_full_fft() {
        // odd row/column counts, ks == 1, kd == cols (full axis), tiny
        // axes — every edge the serving geometry can produce, pinned
        // against the naive full-FFT reference
        for (rows, cols) in
            [(7usize, 9usize), (5, 32), (17, 31), (16, 7), (32, 128)] {
            let a = rand_act(rows, cols, (rows * 31 + cols) as u64);
            let codec = FourierCodec::default();
            let ks_small = oddify(3, rows);
            let kd_small = oddify(5, cols);
            for (ks, kd) in [
                (1, 1),
                (1, kd_small),
                (ks_small, 1),
                (1, cols),
                (rows, 1),
                (rows, cols),
                (rows, kd_small),
                (ks_small, cols),
                (ks_small, kd_small),
            ] {
                let p = codec.compress_block(&a, rows, cols, ks, kd).unwrap();
                // conjugate-symmetric packing: exactly ks*kd floats
                assert_eq!((p.body.len() - 4) / 4, ks * kd,
                           "({rows},{cols}) block {ks}x{kd}: payload size");
                let got = codec.decompress(&p).unwrap();
                let want = naive_roundtrip(&a, rows, cols, ks, kd);
                let err = recon_err(&a, &want, &got);
                assert!(err < 1e-5,
                        "({rows},{cols}) block {ks}x{kd}: err {err}");
            }
        }
    }

    #[test]
    fn cropped_true_len_rows_match_naive() {
        // the serving path crops to true_len rows before compressing
        // (PAD rows are never sent): odd / minimal true_len values
        // over a padded bucket must round-trip like the naive path
        let (bucket, cols) = (16usize, 32usize);
        let a = rand_act(bucket, cols, 77);
        let codec = FourierCodec::default();
        for true_len in [1usize, 5, 11, 15] {
            let crop = &a[..true_len * cols];
            let ks = oddify(9, true_len);
            let kd = 7usize;
            let p = codec.compress_block(crop, true_len, cols, ks, kd).unwrap();
            assert_eq!((p.body.len() - 4) / 4, ks * kd, "len {true_len}");
            let got = codec.decompress(&p).unwrap();
            assert_eq!(got.len(), true_len * cols);
            let want = naive_roundtrip(crop, true_len, cols, ks, kd);
            let err = recon_err(crop, &want, &got);
            assert!(err < 1e-5, "true_len {true_len}: err {err}");
        }
    }

    #[test]
    fn pack_unpack_edge_blocks() {
        // pack/unpack (the wire transform around the fused artifacts)
        // on the same edge geometries: ks == 1, kd == cols, odd axes
        for (rows, cols) in [(7usize, 9usize), (5, 32), (16, 7)] {
            let a = rand_act(rows, cols, (rows + cols) as u64);
            let spec = crate::dsp::fft2d::fft2_real(MatView::new(&a, rows, cols));
            for (ks, kd) in [(1usize, 1usize), (1, oddify(5, cols)),
                             (oddify(3, rows), cols), (rows, cols)] {
                let ui = freq_indices(rows, ks);
                let vi = freq_indices(cols, kd);
                let mut re = vec![0.0f32; ks * kd];
                let mut im = vec![0.0f32; ks * kd];
                for (i, &u) in ui.iter().enumerate() {
                    for (j, &v) in vi.iter().enumerate() {
                        re[i * kd + j] = spec[u * cols + v].re as f32;
                        im[i * kd + j] = spec[u * cols + v].im as f32;
                    }
                }
                let packed = pack_block(&re, &im, rows, cols, ks, kd);
                assert_eq!(packed.len(), ks * kd,
                           "({rows},{cols}) {ks}x{kd}: packed count");
                let (re2, im2) =
                    unpack_block(&packed, rows, cols, ks, kd).unwrap();
                for (x, y) in re.iter().zip(&re2) {
                    assert!((x - y).abs() < 1e-5);
                }
                for (x, y) in im.iter().zip(&im2) {
                    assert!((x - y).abs() < 1e-5);
                }
                // a truncated packing must be rejected, not mirrored
                if packed.len() > 1 {
                    assert!(unpack_block(&packed[..packed.len() - 1], rows,
                                         cols, ks, kd).is_err());
                }
            }
        }
    }

    #[test]
    fn crop_then_embed_keeps_exactly_the_nested_frequencies() {
        let (rows, cols, ks0, kd0, ks1, kd1) = (16usize, 32, 9, 15, 5, 7);
        let a = rand_act(rows, cols, 21);
        let spec = crate::dsp::fft2d::fft2_real(MatView::new(&a, rows, cols));
        let gather = |ks: usize, kd: usize| -> (Vec<f32>, Vec<f32>) {
            let ui = freq_indices(rows, ks);
            let vi = freq_indices(cols, kd);
            let mut re = vec![0.0f32; ks * kd];
            let mut im = vec![0.0f32; ks * kd];
            for (i, &u) in ui.iter().enumerate() {
                for (j, &v) in vi.iter().enumerate() {
                    re[i * kd + j] = spec[u * cols + v].re as f32;
                    im[i * kd + j] = spec[u * cols + v].im as f32;
                }
            }
            (re, im)
        };
        let (re0, im0) = gather(ks0, kd0);
        let (want_re, want_im) = gather(ks1, kd1);

        let mut eng = CodecEngine::new();
        let (mut re1, mut im1) = (Vec::new(), Vec::new());
        crop_block_into(&mut eng, &re0, &im0, rows, cols, ks0, kd0, ks1, kd1,
                        &mut re1, &mut im1).unwrap();
        // the crop is exactly the directly-gathered small block
        assert_eq!(re1, want_re);
        assert_eq!(im1, want_im);

        // embed back: nested frequencies survive bit-exactly, the
        // truncated ones are zero
        let (mut bre, mut bim) = (Vec::new(), Vec::new());
        embed_block_into(&mut eng, &re1, &im1, rows, cols, ks1, kd1, ks0, kd0,
                         &mut bre, &mut bim).unwrap();
        let ui1: std::collections::HashSet<_> =
            freq_indices(rows, ks1).into_iter().collect();
        let vi1: std::collections::HashSet<_> =
            freq_indices(cols, kd1).into_iter().collect();
        for (i, &u) in freq_indices(rows, ks0).iter().enumerate() {
            for (j, &v) in freq_indices(cols, kd0).iter().enumerate() {
                let kept = ui1.contains(&u) && vi1.contains(&v);
                if kept {
                    assert_eq!(bre[i * kd0 + j].to_bits(),
                               re0[i * kd0 + j].to_bits());
                    assert_eq!(bim[i * kd0 + j].to_bits(),
                               im0[i * kd0 + j].to_bits());
                } else {
                    assert_eq!(bre[i * kd0 + j], 0.0);
                    assert_eq!(bim[i * kd0 + j], 0.0);
                }
            }
        }

        // embedding into the primary reconstructs identically to
        // compressing straight at the small block: the serving
        // path's ladder-point equivalence
        let codec = FourierCodec::default();
        let small = codec.compress_block(&a, rows, cols, ks1, kd1).unwrap();
        let want = codec.decompress(&small).unwrap();
        let packed_embedded = pack_block(&bre, &bim, rows, cols, ks0, kd0);
        let via_primary = codec
            .decompress(&{
                let mut p = Payload::empty();
                p.reset("fc", rows, cols);
                let mut w = Writer(&mut p.body);
                w.u16(ks0 as u16);
                w.u16(kd0 as u16);
                for v in &packed_embedded {
                    w.f32(*v);
                }
                p
            })
            .unwrap();
        for (x, y) in want.iter().zip(&via_primary) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn crop_and_embed_reject_non_nested_or_misshapen() {
        let mut eng = CodecEngine::new();
        let (mut re, mut im) = (Vec::new(), Vec::new());
        // not nested: kd1 > kd0
        assert!(crop_block_into(&mut eng, &[0.0; 45], &[0.0; 45], 16, 32, 9,
                                5, 5, 7, &mut re, &mut im).is_err());
        // invalid axis (even, non-full)
        assert!(crop_block_into(&mut eng, &[0.0; 45], &[0.0; 45], 16, 32, 9,
                                5, 4, 5, &mut re, &mut im).is_err());
        // wrong source length
        assert!(crop_block_into(&mut eng, &[0.0; 7], &[0.0; 7], 16, 32, 9, 5,
                                5, 5, &mut re, &mut im).is_err());
        assert!(embed_block_into(&mut eng, &[0.0; 7], &[0.0; 7], 16, 32, 5, 5,
                                 9, 5, &mut re, &mut im).is_err());
    }

    #[test]
    fn rejects_corrupt_payload() {
        let a = rand_act(16, 32, 8);
        let codec = FourierCodec::default();
        let mut p = codec.compress(&a, 16, 32, 8.0).unwrap();
        p.body.truncate(p.body.len() - 3);
        assert!(codec.decompress(&p).is_err());
        let mut p2 = codec.compress(&a, 16, 32, 8.0).unwrap();
        p2.body[0] = 0xFF; // ks out of range
        p2.body[1] = 0xFF;
        assert!(codec.decompress(&p2).is_err());
    }
}
