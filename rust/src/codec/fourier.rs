//! The FourierCompress codec (software path): 2-D FFT, centred
//! low-frequency block retention, conjugate-symmetric wire packing,
//! zero-pad + inverse FFT reconstruction.
//!
//! Wire body:  u16 ks | u16 kd | f32 × (packed coefficients)
//!
//! Packing walks the kept frequency set in canonical (row-major over
//! the centred index lists) order and stores, for each coefficient
//! whose (u, v) is lexicographically <= its conjugate mirror, `re`
//! (and `im` unless the point is self-conjugate).  The decoder
//! regenerates mirrors, so a K_S×K_D complex block costs exactly
//! K_S·K_D floats — this is the "conjugate symmetry-aware" transport
//! the paper describes, applied to transmission as well as
//! reconstruction (DESIGN.md §6).
//!
//! Hot-path structure (rust/README.md §Codec hot path): both
//! directions run their row pass through [`crate::dsp::RfftPlan`] —
//! one half-length complex FFT plus an O(D) twiddle split per real
//! row.  Compress keeps only the K_D wanted bins per row (mirrored
//! bins by conjugate symmetry) and runs the column FFT over K_D
//! columns; decompress inverts only the columns the irfft row pass
//! actually reads (`v <= D/2`) and reconstructs each row with the
//! half-spectrum inverse.  Pack/unpack and the wire moves go through
//! the `dsp::simd` kernels; the whole pipeline dispatches at the
//! engine's [`crate::dsp::Level`].
//!
//! All entry points are `_into`-style over a [`CodecEngine`]: plans,
//! frequency index sets, and every scratch buffer (`narrow`, `z`,
//! `col`, `block`, `spec`, `half`, `floats`) live in the engine, so
//! the per-token decode loop re-uses them and performs zero heap
//! allocation after warm-up.  The plain-named wrappers route through
//! the thread-local engine and stay byte-compatible with the
//! pre-engine codec.

use super::engine::{self, stage, CodecEngine};
use super::{block_ratio, fc_block, Codec, Payload, Reader, Writer};
use crate::dsp::complex::C64;
use crate::dsp::simd;
use crate::tensor::MatView;

use anyhow::{ensure, Result};

#[derive(Debug, Clone, Default)]
pub struct FourierCodec {
    /// Calibrated hidden-axis block width (None = D/8 heuristic).
    pub kd_hint: Option<usize>,
}

impl FourierCodec {
    pub fn with_hint(kd_hint: usize) -> FourierCodec {
        FourierCodec { kd_hint: Some(kd_hint) }
    }

    /// Compress with an explicit block (the eval sweeps use this).
    ///
    /// Perf note (EXPERIMENTS.md §Perf): each row costs one real-input
    /// FFT (a D/2-point complex transform + O(D) split) instead of a
    /// D-point complex transform, and only the K_D kept spectrum
    /// columns are materialised, so the column pass runs on K_D
    /// columns instead of all D.
    pub fn compress_block_into(&self, eng: &mut CodecEngine, a: MatView<'_>,
                               ks: usize, kd: usize, out: &mut Payload)
        -> Result<()> {
        let (rows, cols) = (a.rows(), a.cols());
        let ui = eng.indices(rows, ks);
        let vi = eng.indices(cols, kd);
        let plan_s = eng.plan(rows);
        let rplan_d = eng.rplan(cols);
        let lv = eng.simd;
        let data = a.as_slice();

        let CodecEngine { narrow, z, col, block, floats, timer, .. } = eng;
        engine::zeroed(narrow, rows * kd); // [rows, K_D]

        // row pass: one rfft per row; kept bins past D/2 come from
        // conjugate symmetry (X[v] = conj(X[D - v])).  No pair trick,
        // so an odd row count has no half-wasted tail transform.
        stage!(timer, row_fft, {
            for r in 0..rows {
                rplan_d.spectrum_into(lv, &data[r * cols..(r + 1) * cols], z);
                for (j, &v) in vi.iter().enumerate() {
                    narrow[r * kd + j] = if v <= cols / 2 {
                        rplan_d.bin(z, v)
                    } else {
                        rplan_d.bin(z, cols - v).conj()
                    };
                }
            }
        });

        // selective column pass over the K_D kept columns
        stage!(timer, col_fft, {
            engine::zeroed(block, ks * kd);
            engine::zeroed(col, rows);
            for j in 0..kd {
                for rr in 0..rows {
                    col[rr] = narrow[rr * kd + j];
                }
                plan_s.forward_with(lv, col);
                for (i, &u) in ui.iter().enumerate() {
                    block[i * kd + j] = col[u];
                }
            }
        });

        // pack: the lexicographic (u, v) <= (mu, mv) rule, factored by
        // row class.  A row whose mirror row differs ships every
        // column's (re, im) — exactly the interleaved f32 narrowing of
        // the C64 block row — in one bulk kernel; a self-mirrored row
        // walks per column; a mirrored-away row ships nothing.
        stage!(timer, pack, {
            floats.clear();
            floats.reserve(ks * kd);
            for (i, &u) in ui.iter().enumerate() {
                let mu = (rows - u) % rows;
                if u > mu {
                    continue; // mirror row carries it
                }
                let brow = &block[i * kd..(i + 1) * kd];
                if u < mu {
                    simd::narrow_c64(lv, brow, floats);
                } else {
                    for (j, &v) in vi.iter().enumerate() {
                        let mv = (cols - v) % cols;
                        if v > mv {
                            continue;
                        }
                        floats.push(brow[j].re as f32);
                        if v != mv {
                            floats.push(brow[j].im as f32);
                        }
                    }
                }
            }
        });

        stage!(timer, wire, {
            out.reset("fc", rows, cols);
            let mut w = Writer(&mut out.body);
            w.u16(ks as u16);
            w.u16(kd as u16);
            w.f32s(floats);
        });
        Ok(())
    }

    /// One-shot explicit-block compression (legacy API; thread-local
    /// engine).
    pub fn compress_block(&self, a: &[f32], rows: usize, cols: usize,
                          ks: usize, kd: usize) -> Result<Payload> {
        ensure!(a.len() == rows * cols, "shape mismatch");
        let view = MatView::new(a, rows, cols);
        engine::with_thread_engine(|eng| {
            let mut out = Payload::empty();
            self.compress_block_into(eng, view, ks, kd, &mut out)?;
            Ok(out)
        })
    }
}

impl Codec for FourierCodec {
    fn name(&self) -> &'static str {
        "fc"
    }

    fn compress_into(&self, eng: &mut CodecEngine, a: MatView<'_>, ratio: f64,
                     out: &mut Payload) -> Result<()> {
        let (ks, kd) = fc_block(a.rows(), a.cols(), ratio, self.kd_hint);
        debug_assert!(block_ratio(a.rows(), a.cols(), ks, kd) >= ratio * 0.8);
        self.compress_block_into(eng, a, ks, kd, out)
    }

    fn decompress_into(&self, eng: &mut CodecEngine, p: &Payload,
                       out: &mut Vec<f32>) -> Result<()> {
        let (rows, cols) = (p.rows, p.cols);
        let mut r = Reader::new(&p.body);
        let ks = r.u16()? as usize;
        let kd = r.u16()? as usize;
        ensure!(super::valid_block_axis(rows, ks) && super::valid_block_axis(cols, kd),
                "bad block {ks}x{kd} for {rows}x{cols}");
        let ui = eng.indices(rows, ks);
        let vi = eng.indices(cols, kd);
        let plan_s = eng.plan(rows);
        let rplan_d = eng.rplan(cols);
        let lv = eng.simd;

        let CodecEngine { spec, col, half, floats, timer, .. } = eng;

        // wire: one bulk little-endian move of the packed float stream
        stage!(timer, wire, {
            let count = r.remaining() / 4;
            ensure!(r.remaining() == count * 4, "trailing payload bytes");
            floats.clear();
            r.f32s(count, floats)?;
        });

        // scatter the conjugate-completed block into the (sparse)
        // spectrum
        stage!(timer, pack, {
            engine::zeroed(spec, rows * cols);
            let packed: &[f32] = floats;
            let mut pos = 0usize;
            for &u in ui.iter() {
                let mu = (rows - u) % rows;
                for &v in vi.iter() {
                    let mv = (cols - v) % cols;
                    if (u, v) > (mu, mv) {
                        continue;
                    }
                    ensure!(pos < packed.len(), "payload truncated");
                    let re = packed[pos] as f64;
                    pos += 1;
                    let im = if (u, v) != (mu, mv) {
                        ensure!(pos < packed.len(), "payload truncated");
                        let x = packed[pos] as f64;
                        pos += 1;
                        x
                    } else {
                        0.0
                    };
                    spec[u * cols + v] = C64::new(re, im);
                    spec[mu * cols + mv] = C64::new(re, -im);
                }
            }
            ensure!(pos == packed.len(), "trailing payload floats");
        });

        // inverse column pass: the irfft row pass below only reads
        // bins v <= D/2 of each row, so the mirrored kept columns
        // (v > D/2) never need transforming — half the column work.
        stage!(timer, col_fft, {
            engine::zeroed(col, rows);
            for &v in vi.iter() {
                if v > cols / 2 {
                    continue;
                }
                for rr in 0..rows {
                    col[rr] = spec[rr * cols + v];
                }
                plan_s.inverse_with(lv, col);
                for rr in 0..rows {
                    spec[rr * cols + v] = col[rr];
                }
            }
        });

        // inverse row pass: each spectrum row is conjugate-symmetric
        // (the scatter wrote exact mirrors), so the half-spectrum
        // inverse reconstructs the real row directly.
        stage!(timer, row_fft, {
            out.clear();
            out.resize(rows * cols, 0.0);
            for rr in 0..rows {
                rplan_d.inverse_into(lv, &spec[rr * cols..(rr + 1) * cols],
                                     half,
                                     &mut out[rr * cols..(rr + 1) * cols]);
            }
        });
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// block pack/unpack — the serving path's wire transform
// ---------------------------------------------------------------------------
//
// The fused client HLO emits the FULL (re, im) K_S×K_D block; these
// helpers convert it to/from the non-redundant float packing used by
// the Activation frame, so the serving path pays the same wire bytes
// as the software codec.  The `_into` forms reuse the caller's
// buffers and the engine's cached index sets.

/// index of frequency `u` inside the centred list for (n, k)
fn block_pos(n: usize, k: usize, u: usize) -> usize {
    if k == n {
        return u;
    }
    let h = (k - 1) / 2;
    if u <= h {
        u
    } else {
        u - (n - k)
    }
}

/// Pack a full (re, im) block (row-major ks×kd) into the symmetric
/// half representation, appended into `out` (cleared first).
/// `rows`/`cols` are the pre-compression matrix dims the block was
/// computed for.
pub fn pack_block_into(eng: &mut CodecEngine, re: &[f32], im: &[f32],
                       rows: usize, cols: usize, ks: usize, kd: usize,
                       out: &mut Vec<f32>) {
    let ui = eng.indices(rows, ks);
    let vi = eng.indices(cols, kd);
    let lv = eng.simd;
    out.clear();
    out.reserve(ks * kd);
    for (i, &u) in ui.iter().enumerate() {
        let mu = (rows - u) % rows;
        if u > mu {
            continue; // mirror row carries it
        }
        let rrow = &re[i * kd..(i + 1) * kd];
        let irow = &im[i * kd..(i + 1) * kd];
        if u < mu {
            simd::interleave_f32(lv, rrow, irow, out);
        } else {
            for (j, &v) in vi.iter().enumerate() {
                let mv = (cols - v) % cols;
                if v > mv {
                    continue;
                }
                out.push(rrow[j]);
                if v != mv {
                    out.push(irow[j]);
                }
            }
        }
    }
}

/// One-shot [`pack_block_into`] (legacy API; thread-local engine).
pub fn pack_block(re: &[f32], im: &[f32], rows: usize, cols: usize,
                  ks: usize, kd: usize) -> Vec<f32> {
    engine::with_thread_engine(|eng| {
        let mut out = Vec::new();
        pack_block_into(eng, re, im, rows, cols, ks, kd, &mut out);
        out
    })
}

/// Inverse of [`pack_block_into`]: regenerate the full (re, im)
/// planes into the caller's buffers (cleared first).
pub fn unpack_block_into(eng: &mut CodecEngine, packed: &[f32],
                         rows: usize, cols: usize, ks: usize, kd: usize,
                         re: &mut Vec<f32>, im: &mut Vec<f32>) -> Result<()> {
    let ui = eng.indices(rows, ks);
    let vi = eng.indices(cols, kd);
    let lv = eng.simd;
    re.clear();
    re.resize(ks * kd, 0.0);
    im.clear();
    im.resize(ks * kd, 0.0);
    let mut pos = 0usize;
    for (i, &u) in ui.iter().enumerate() {
        let mu = (rows - u) % rows;
        if u > mu {
            continue;
        }
        let mi = block_pos(rows, ks, mu);
        if u < mu {
            // full row: 2·kd interleaved floats split straight into
            // the (re, im) planes, then the mirror row regenerated
            // through the column-mirror permutation
            ensure!(pos + 2 * kd <= packed.len(), "packed block truncated");
            {
                let rrow = &mut re[i * kd..(i + 1) * kd];
                let irow = &mut im[i * kd..(i + 1) * kd];
                simd::deinterleave_f32(lv, &packed[pos..pos + 2 * kd], rrow,
                                       irow);
            }
            pos += 2 * kd;
            for (j, &v) in vi.iter().enumerate() {
                let mj = block_pos(cols, kd, (cols - v) % cols);
                re[mi * kd + mj] = re[i * kd + j];
                im[mi * kd + mj] = -im[i * kd + j];
            }
        } else {
            // self-mirrored row (u == mu, so mi == i)
            for (j, &v) in vi.iter().enumerate() {
                let mv = (cols - v) % cols;
                if v > mv {
                    continue;
                }
                ensure!(pos < packed.len(), "packed block truncated");
                let r = packed[pos];
                pos += 1;
                let iv = if v != mv {
                    ensure!(pos < packed.len(), "packed block truncated");
                    let x = packed[pos];
                    pos += 1;
                    x
                } else {
                    0.0
                };
                let mj = block_pos(cols, kd, mv);
                re[i * kd + j] = r;
                im[i * kd + j] = iv;
                re[i * kd + mj] = r;
                im[i * kd + mj] = -iv;
            }
        }
    }
    ensure!(pos == packed.len(), "trailing packed floats");
    Ok(())
}

/// One-shot [`unpack_block_into`] (legacy API; thread-local engine).
pub fn unpack_block(packed: &[f32], rows: usize, cols: usize,
                    ks: usize, kd: usize) -> Result<(Vec<f32>, Vec<f32>)> {
    engine::with_thread_engine(|eng| {
        let (mut re, mut im) = (Vec::new(), Vec::new());
        unpack_block_into(eng, packed, rows, cols, ks, kd, &mut re, &mut im)?;
        Ok((re, im))
    })
}

// ---------------------------------------------------------------------------
// nested-block crop/embed — the adaptive rate ladder's transform
// ---------------------------------------------------------------------------
//
// A ladder point (`codec::rate`) keeps a centred block nested inside
// the bucket's primary block (ks1 <= ks0, kd1 <= kd0): its frequency
// set is a subset of the primary's, so the device can *crop* the full
// (re, im) block its fused executable already emits — no second
// compile per point — and the server *embeds* the small block back
// into a zeroed primary-geometry block, the truncated frequencies
// reconstructing as zero exactly like FC truncation itself.

fn ensure_nested(rows: usize, cols: usize, ks0: usize, kd0: usize,
                 ks1: usize, kd1: usize) -> Result<()> {
    ensure!(ks1 <= ks0 && kd1 <= kd0,
            "block {ks1}x{kd1} not nested in {ks0}x{kd0}");
    ensure!(super::valid_block_axis(rows, ks0)
                && super::valid_block_axis(cols, kd0)
                && super::valid_block_axis(rows, ks1)
                && super::valid_block_axis(cols, kd1),
            "invalid nested blocks {ks1}x{kd1} <= {ks0}x{kd0} \
             for {rows}x{cols}");
    Ok(())
}

/// The nested width `k1`'s index positions inside a `k0`-wide centred
/// block, as (start, len) runs: the low frequencies occupy the block's
/// first `h1 + 1` slots and the high (negative) frequencies its last
/// `h1` (`h1 = (k1 - 1) / 2`); a full axis (`k1 == n`, which forces
/// `k0 == n`) is one identity run.  Contiguity is what lets crop/embed
/// be straight slice copies instead of per-element gathers.
fn axis_segments(n: usize, k0: usize, k1: usize) -> [(usize, usize); 2] {
    if k1 == n {
        [(0, k0), (0, 0)]
    } else {
        let h1 = (k1 - 1) / 2;
        [(0, h1 + 1), (k0 - h1, h1)]
    }
}

/// Crop a full (re, im) `ks0`×`kd0` block to the nested ladder point
/// `ks1`×`kd1` (buffers cleared first).  Pure contiguous-run copies:
/// the centred index set for a smaller odd width is a subset of the
/// larger one's, occupying its leading/trailing rows and columns.
pub fn crop_block_into(_eng: &mut CodecEngine, re0: &[f32], im0: &[f32],
                       rows: usize, cols: usize, ks0: usize, kd0: usize,
                       ks1: usize, kd1: usize,
                       re1: &mut Vec<f32>, im1: &mut Vec<f32>) -> Result<()> {
    ensure_nested(rows, cols, ks0, kd0, ks1, kd1)?;
    ensure!(re0.len() == ks0 * kd0 && im0.len() == ks0 * kd0,
            "crop source carries {} floats, geometry wants {}", re0.len(),
            ks0 * kd0);
    let rseg = axis_segments(rows, ks0, ks1);
    let cseg = axis_segments(cols, kd0, kd1);
    re1.clear();
    im1.clear();
    re1.reserve(ks1 * kd1);
    im1.reserve(ks1 * kd1);
    for &(r0, rlen) in &rseg {
        for i0 in r0..r0 + rlen {
            for &(c0, clen) in &cseg {
                let s = i0 * kd0 + c0;
                re1.extend_from_slice(&re0[s..s + clen]);
                im1.extend_from_slice(&im0[s..s + clen]);
            }
        }
    }
    Ok(())
}

/// Inverse of [`crop_block_into`]: scatter a nested `ks1`×`kd1` block
/// into a zeroed `ks0`×`kd0` primary block (buffers cleared first).
pub fn embed_block_into(_eng: &mut CodecEngine, re1: &[f32], im1: &[f32],
                        rows: usize, cols: usize, ks1: usize, kd1: usize,
                        ks0: usize, kd0: usize,
                        re0: &mut Vec<f32>, im0: &mut Vec<f32>) -> Result<()> {
    ensure_nested(rows, cols, ks0, kd0, ks1, kd1)?;
    ensure!(re1.len() == ks1 * kd1 && im1.len() == ks1 * kd1,
            "embed source carries {} floats, geometry wants {}", re1.len(),
            ks1 * kd1);
    let rseg = axis_segments(rows, ks0, ks1);
    let cseg = axis_segments(cols, kd0, kd1);
    re0.clear();
    re0.resize(ks0 * kd0, 0.0);
    im0.clear();
    im0.resize(ks0 * kd0, 0.0);
    let mut src = 0usize;
    for &(r0, rlen) in &rseg {
        for i0 in r0..r0 + rlen {
            for &(c0, clen) in &cseg {
                let d = i0 * kd0 + c0;
                re0[d..d + clen].copy_from_slice(&re1[src..src + clen]);
                im0[d..d + clen].copy_from_slice(&im1[src..src + clen]);
                src += clen;
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// baseline — the pre-rfft reference pipeline
// ---------------------------------------------------------------------------

/// The previous engine pipeline, kept verbatim (allocating, scalar
/// kernels pinned): row-pair complex FFTs + a full complex inverse row
/// pass.  `benches/microbench.rs` measures the rfft+SIMD path against
/// this, and the odd-rows test uses it as an independent oracle.  Not
/// part of the serving API.
#[doc(hidden)]
pub mod baseline {
    use super::*;
    use crate::codec::freq_indices;
    use crate::dsp::fft2d;
    use crate::dsp::simd::Level;

    pub fn compress_block(a: &[f32], rows: usize, cols: usize, ks: usize,
                          kd: usize) -> Result<Payload> {
        ensure!(a.len() == rows * cols, "shape mismatch");
        let ui = freq_indices(rows, ks);
        let vi = freq_indices(cols, kd);
        let plan_s = fft2d::plan(rows);
        let plan_d = fft2d::plan(cols);
        let mut narrow = vec![C64::ZERO; rows * kd];
        let mut z = vec![C64::ZERO; cols];
        // row-pair trick: rows (r, r+1) as re/im of one complex FFT;
        // an odd tail row runs with a dead zero imaginary lane
        let mut r = 0;
        while r < rows {
            let hi = (r + 1 < rows) as usize;
            for v in 0..cols {
                z[v] = C64::new(a[r * cols + v] as f64,
                                if hi == 1 { a[(r + 1) * cols + v] as f64 }
                                else { 0.0 });
            }
            plan_d.forward_with(Level::Scalar, &mut z);
            for (j, &v) in vi.iter().enumerate() {
                let zc = z[v];
                let zm = z[(cols - v) % cols].conj();
                narrow[r * kd + j] = (zc + zm).scale(0.5);
                if hi == 1 {
                    let d = (zc - zm).scale(0.5);
                    narrow[(r + 1) * kd + j] = C64::new(d.im, -d.re);
                }
            }
            r += 2;
        }
        let mut block = vec![C64::ZERO; ks * kd];
        let mut col = vec![C64::ZERO; rows];
        for j in 0..kd {
            for rr in 0..rows {
                col[rr] = narrow[rr * kd + j];
            }
            plan_s.forward_with(Level::Scalar, &mut col);
            for (i, &u) in ui.iter().enumerate() {
                block[i * kd + j] = col[u];
            }
        }
        let mut out = Payload::empty();
        out.reset("fc", rows, cols);
        let mut w = Writer(&mut out.body);
        w.u16(ks as u16);
        w.u16(kd as u16);
        for (i, &u) in ui.iter().enumerate() {
            for (j, &v) in vi.iter().enumerate() {
                let (mu, mv) = ((rows - u) % rows, (cols - v) % cols);
                if (u, v) > (mu, mv) {
                    continue;
                }
                let c = block[i * kd + j];
                w.f32(c.re as f32);
                if (u, v) != (mu, mv) {
                    w.f32(c.im as f32);
                }
            }
        }
        Ok(out)
    }

    pub fn decompress(p: &Payload) -> Result<Vec<f32>> {
        let (rows, cols) = (p.rows, p.cols);
        let mut r = Reader::new(&p.body);
        let ks = r.u16()? as usize;
        let kd = r.u16()? as usize;
        ensure!(crate::codec::valid_block_axis(rows, ks)
                    && crate::codec::valid_block_axis(cols, kd),
                "bad block {ks}x{kd} for {rows}x{cols}");
        let ui = freq_indices(rows, ks);
        let vi = freq_indices(cols, kd);
        let plan_s = fft2d::plan(rows);
        let plan_d = fft2d::plan(cols);
        let mut spec = vec![C64::ZERO; rows * cols];
        for &u in ui.iter() {
            for &v in vi.iter() {
                let (mu, mv) = ((rows - u) % rows, (cols - v) % cols);
                if (u, v) > (mu, mv) {
                    continue;
                }
                let re = r.f32()? as f64;
                let im = if (u, v) != (mu, mv) { r.f32()? as f64 } else { 0.0 };
                spec[u * cols + v] = C64::new(re, im);
                spec[mu * cols + mv] = C64::new(re, -im);
            }
        }
        ensure!(r.remaining() == 0, "trailing payload bytes");
        let mut col = vec![C64::ZERO; rows];
        for &v in vi.iter() {
            for rr in 0..rows {
                col[rr] = spec[rr * cols + v];
            }
            plan_s.inverse_with(Level::Scalar, &mut col);
            for rr in 0..rows {
                spec[rr * cols + v] = col[rr];
            }
        }
        for rr in 0..rows {
            plan_d.inverse_with(Level::Scalar,
                                &mut spec[rr * cols..(rr + 1) * cols]);
        }
        Ok(spec.iter().map(|c| c.re as f32).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{freq_indices, rand_act, rel_error};

    #[test]
    fn pack_unpack_roundtrip() {
        let (rows, cols, ks, kd) = (32usize, 128usize, 9usize, 15usize);
        // build a conjugate-symmetric block from a real matrix
        let a = rand_act(rows, cols, 42);
        let spec = crate::dsp::fft2d::fft2_real(MatView::new(&a, rows, cols));
        let ui = freq_indices(rows, ks);
        let vi = freq_indices(cols, kd);
        let mut re = vec![0.0f32; ks * kd];
        let mut im = vec![0.0f32; ks * kd];
        for (i, &u) in ui.iter().enumerate() {
            for (j, &v) in vi.iter().enumerate() {
                re[i * kd + j] = spec[u * cols + v].re as f32;
                im[i * kd + j] = spec[u * cols + v].im as f32;
            }
        }
        let packed = pack_block(&re, &im, rows, cols, ks, kd);
        assert_eq!(packed.len(), ks * kd);
        let (re2, im2) = unpack_block(&packed, rows, cols, ks, kd).unwrap();
        for (a, b) in re.iter().zip(&re2) {
            assert!((a - b).abs() < 1e-5);
        }
        for (a, b) in im.iter().zip(&im2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn pack_full_axis_block() {
        // ks == rows (even full axis) exercises the k == n branch
        let (rows, cols, ks, kd) = (16usize, 64usize, 16usize, 7usize);
        let a = rand_act(rows, cols, 7);
        let spec = crate::dsp::fft2d::fft2_real(MatView::new(&a, rows, cols));
        let ui = freq_indices(rows, ks);
        let vi = freq_indices(cols, kd);
        let mut re = vec![0.0f32; ks * kd];
        let mut im = vec![0.0f32; ks * kd];
        for (i, &u) in ui.iter().enumerate() {
            for (j, &v) in vi.iter().enumerate() {
                re[i * kd + j] = spec[u * cols + v].re as f32;
                im[i * kd + j] = spec[u * cols + v].im as f32;
            }
        }
        let packed = pack_block(&re, &im, rows, cols, ks, kd);
        // self-conjugate points: (0,0) and (rows/2, 0) -> ks*kd floats
        assert_eq!(packed.len(), ks * kd);
        let (re2, im2) = unpack_block(&packed, rows, cols, ks, kd).unwrap();
        for (a, b) in re.iter().zip(&re2) {
            assert!((a - b).abs() < 1e-5);
        }
        for (a, b) in im.iter().zip(&im2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn payload_floats_equal_ks_kd() {
        let (rows, cols) = (48, 96);
        let a = rand_act(rows, cols, 1);
        let c = FourierCodec::default();
        for (ks, kd) in [(5, 13), (47, 13), (48, 11), (1, 1)] {
            let p = c.compress_block(&a, rows, cols, ks, kd).unwrap();
            let floats = (p.body.len() - 4) / 4;
            assert_eq!(floats, ks * kd, "block {ks}x{kd}");
        }
    }

    #[test]
    fn bandlimited_roundtrip_exact() {
        // signal synthesised inside the kept band -> exact recovery
        let (rows, cols, ks, kd) = (32usize, 96usize, 9usize, 13usize);
        let ui = freq_indices(rows, ks);
        let vi = freq_indices(cols, kd);
        let mut rng = crate::util::rng::Rng::new(3);
        let mut spec = vec![C64::ZERO; rows * cols];
        for &u in &ui {
            for &v in &vi {
                let (mu, mv) = ((rows - u) % rows, (cols - v) % cols);
                if (u, v) > (mu, mv) {
                    continue;
                }
                let c = if (u, v) == (mu, mv) {
                    C64::new(rng.normal(), 0.0)
                } else {
                    C64::new(rng.normal(), rng.normal())
                };
                spec[u * cols + v] = c;
                spec[mu * cols + mv] = c.conj();
            }
        }
        crate::dsp::fft2d::ifft2(&mut spec, rows, cols);
        let a: Vec<f32> = spec.iter().map(|c| c.re as f32).collect();

        let codec = FourierCodec::default();
        let p = codec.compress_block(&a, rows, cols, ks, kd).unwrap();
        let out = codec.decompress(&p).unwrap();
        assert!(rel_error(&a, &out) < 1e-5);
    }

    #[test]
    fn full_block_is_lossless() {
        let (rows, cols) = (16, 31);
        let a = rand_act(rows, cols, 9);
        let codec = FourierCodec::default();
        let p = codec.compress_block(&a, rows, cols, rows, cols).unwrap();
        let out = codec.decompress(&p).unwrap();
        assert!(rel_error(&a, &out) < 1e-5);
    }

    #[test]
    fn deterministic_bytes() {
        let a = rand_act(24, 48, 5);
        let codec = FourierCodec::default();
        let p1 = codec.compress(&a, 24, 48, 8.0).unwrap();
        let p2 = codec.compress(&a, 24, 48, 8.0).unwrap();
        assert_eq!(p1, p2);
    }

    #[test]
    fn engine_path_matches_legacy_bytes() {
        // the tentpole invariant: compress_into over a caller-owned
        // engine emits exactly the bytes the one-shot path emits
        let (rows, cols) = (31, 100);
        let a = rand_act(rows, cols, 11);
        let codec = FourierCodec::default();
        let legacy = codec.compress(&a, rows, cols, 6.0).unwrap();

        let mut eng = CodecEngine::new();
        let mut p = Payload::empty();
        for _ in 0..3 {
            codec.compress_into(&mut eng, MatView::new(&a, rows, cols), 6.0,
                                &mut p).unwrap();
            assert_eq!(p, legacy);
        }
        let mut out = Vec::new();
        codec.decompress_into(&mut eng, &p, &mut out).unwrap();
        assert_eq!(out, codec.decompress(&legacy).unwrap());
    }

    #[test]
    fn arbitrary_sizes_roundtrip() {
        // non-pow2 both axes (bluestein path), incl. odd row counts
        for (rows, cols) in [(31, 96), (17, 60), (48, 100), (5, 7)] {
            let a = rand_act(rows, cols, (rows * cols) as u64);
            let codec = FourierCodec::default();
            let out = codec.roundtrip(&a, rows, cols, 4.0).unwrap();
            assert_eq!(out.len(), a.len());
            assert!(out.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn kd_hint_respected() {
        let a = rand_act(64, 128, 6);
        let codec = FourierCodec::with_hint(15);
        let p = codec.compress(&a, 64, 128, 8.0).unwrap();
        let mut r = Reader::new(&p.body);
        let _ks = r.u16().unwrap();
        assert_eq!(r.u16().unwrap(), 15);
    }

    /// Naive reference: full 2-D FFT, gather the centred block, scatter
    /// into a zero spectrum, inverse FFT (the `runtime::interp` codec
    /// path, which mirrors python kernels/ref.py).
    fn naive_roundtrip(a: &[f32], rows: usize, cols: usize, ks: usize,
                       kd: usize) -> Vec<f32> {
        use crate::runtime::interp::{fc_compress_naive, fc_decompress_naive};
        let (re, im) = fc_compress_naive(a, rows, cols, ks, kd);
        fc_decompress_naive(&re, &im, rows, cols, ks, kd)
    }

    /// Largest valid centred width ≤ k for an n-point axis.
    fn oddify(k: usize, n: usize) -> usize {
        let k = k.clamp(1, n);
        if k == n || k % 2 == 1 { k } else { k - 1 }
    }

    /// Reconstruction disagreement normalised by the INPUT energy —
    /// stable even for near-empty blocks (a (1,1) block reconstructs
    /// to ~zero, which would blow up a plain relative error).
    fn recon_err(input: &[f32], want: &[f32], got: &[f32]) -> f64 {
        let num: f64 = want.iter().zip(got)
            .map(|(x, y)| ((x - y) as f64).powi(2)).sum();
        let den: f64 = input.iter().map(|x| (*x as f64).powi(2)).sum();
        (num / den.max(1e-30)).sqrt()
    }

    #[test]
    fn edge_blocks_match_naive_full_fft() {
        // odd row/column counts, ks == 1, kd == cols (full axis), tiny
        // axes — every edge the serving geometry can produce, pinned
        // against the naive full-FFT reference
        for (rows, cols) in
            [(7usize, 9usize), (5, 32), (17, 31), (16, 7), (32, 128)] {
            let a = rand_act(rows, cols, (rows * 31 + cols) as u64);
            let codec = FourierCodec::default();
            let ks_small = oddify(3, rows);
            let kd_small = oddify(5, cols);
            for (ks, kd) in [
                (1, 1),
                (1, kd_small),
                (ks_small, 1),
                (1, cols),
                (rows, 1),
                (rows, cols),
                (rows, kd_small),
                (ks_small, cols),
                (ks_small, kd_small),
            ] {
                let p = codec.compress_block(&a, rows, cols, ks, kd).unwrap();
                // conjugate-symmetric packing: exactly ks*kd floats
                assert_eq!((p.body.len() - 4) / 4, ks * kd,
                           "({rows},{cols}) block {ks}x{kd}: payload size");
                let got = codec.decompress(&p).unwrap();
                let want = naive_roundtrip(&a, rows, cols, ks, kd);
                let err = recon_err(&a, &want, &got);
                assert!(err < 1e-5,
                        "({rows},{cols}) block {ks}x{kd}: err {err}");
            }
        }
    }

    #[test]
    fn odd_rows_match_baseline_and_naive() {
        // the rfft row pass has no odd-row tail (one real transform
        // per row, where the pair trick ran its last transform with a
        // dead zero imaginary lane); pin odd-row geometries against
        // both the naive full-FFT reference and the pre-rfft baseline
        // pipeline, and pin byte determinism
        for (rows, cols) in
            [(7usize, 16usize), (17, 32), (31, 100), (1, 8), (9, 9)] {
            let a = rand_act(rows, cols, (rows * 7 + cols) as u64);
            let codec = FourierCodec::default();
            let ks = oddify(5, rows);
            let kd = oddify(7, cols);
            let p = codec.compress_block(&a, rows, cols, ks, kd).unwrap();
            let got = codec.decompress(&p).unwrap();
            let want = naive_roundtrip(&a, rows, cols, ks, kd);
            let err = recon_err(&a, &want, &got);
            assert!(err < 1e-5, "({rows},{cols}): err {err}");

            let bp = baseline::compress_block(&a, rows, cols, ks, kd).unwrap();
            assert_eq!(p.body.len(), bp.body.len(),
                       "({rows},{cols}): wire layout drifted from baseline");
            let bout = baseline::decompress(&bp).unwrap();
            let berr = recon_err(&a, &bout, &got);
            assert!(berr < 1e-4, "({rows},{cols}) vs baseline: err {berr}");

            let p2 = codec.compress_block(&a, rows, cols, ks, kd).unwrap();
            assert_eq!(p, p2, "({rows},{cols}): nondeterministic bytes");
        }
    }

    #[test]
    fn stage_timer_accumulates_and_disables() {
        let (rows, cols) = (32usize, 64usize);
        let a = rand_act(rows, cols, 13);
        let codec = FourierCodec::default();
        let mut eng = CodecEngine::new();
        eng.enable_stage_timing();
        let mut p = Payload::empty();
        codec.compress_block_into(&mut eng, MatView::new(&a, rows, cols), 9,
                                  15, &mut p).unwrap();
        let mut out = Vec::new();
        codec.decompress_into(&mut eng, &p, &mut out).unwrap();
        let t = eng.stage_times().unwrap();
        assert!(t.row_fft > std::time::Duration::ZERO, "row_fft");
        assert!(t.col_fft > std::time::Duration::ZERO, "col_fft");
        assert!(t.pack + t.wire > std::time::Duration::ZERO, "pack+wire");
        eng.disable_stage_timing();
        assert!(eng.stage_times().is_none());
        // timing must not perturb the bytes
        let plain = codec.compress_block(&a, rows, cols, 9, 15).unwrap();
        assert_eq!(p, plain);
    }

    #[test]
    fn cropped_true_len_rows_match_naive() {
        // the serving path crops to true_len rows before compressing
        // (PAD rows are never sent): odd / minimal true_len values
        // over a padded bucket must round-trip like the naive path
        let (bucket, cols) = (16usize, 32usize);
        let a = rand_act(bucket, cols, 77);
        let codec = FourierCodec::default();
        for true_len in [1usize, 5, 11, 15] {
            let crop = &a[..true_len * cols];
            let ks = oddify(9, true_len);
            let kd = 7usize;
            let p = codec.compress_block(crop, true_len, cols, ks, kd).unwrap();
            assert_eq!((p.body.len() - 4) / 4, ks * kd, "len {true_len}");
            let got = codec.decompress(&p).unwrap();
            assert_eq!(got.len(), true_len * cols);
            let want = naive_roundtrip(crop, true_len, cols, ks, kd);
            let err = recon_err(crop, &want, &got);
            assert!(err < 1e-5, "true_len {true_len}: err {err}");
        }
    }

    #[test]
    fn pack_unpack_edge_blocks() {
        // pack/unpack (the wire transform around the fused artifacts)
        // on the same edge geometries: ks == 1, kd == cols, odd axes
        for (rows, cols) in [(7usize, 9usize), (5, 32), (16, 7)] {
            let a = rand_act(rows, cols, (rows + cols) as u64);
            let spec = crate::dsp::fft2d::fft2_real(MatView::new(&a, rows, cols));
            for (ks, kd) in [(1usize, 1usize), (1, oddify(5, cols)),
                             (oddify(3, rows), cols), (rows, cols)] {
                let ui = freq_indices(rows, ks);
                let vi = freq_indices(cols, kd);
                let mut re = vec![0.0f32; ks * kd];
                let mut im = vec![0.0f32; ks * kd];
                for (i, &u) in ui.iter().enumerate() {
                    for (j, &v) in vi.iter().enumerate() {
                        re[i * kd + j] = spec[u * cols + v].re as f32;
                        im[i * kd + j] = spec[u * cols + v].im as f32;
                    }
                }
                let packed = pack_block(&re, &im, rows, cols, ks, kd);
                assert_eq!(packed.len(), ks * kd,
                           "({rows},{cols}) {ks}x{kd}: packed count");
                let (re2, im2) =
                    unpack_block(&packed, rows, cols, ks, kd).unwrap();
                for (x, y) in re.iter().zip(&re2) {
                    assert!((x - y).abs() < 1e-5);
                }
                for (x, y) in im.iter().zip(&im2) {
                    assert!((x - y).abs() < 1e-5);
                }
                // a truncated packing must be rejected, not mirrored
                if packed.len() > 1 {
                    assert!(unpack_block(&packed[..packed.len() - 1], rows,
                                         cols, ks, kd).is_err());
                }
            }
        }
    }

    #[test]
    fn crop_then_embed_keeps_exactly_the_nested_frequencies() {
        let (rows, cols, ks0, kd0, ks1, kd1) = (16usize, 32, 9, 15, 5, 7);
        let a = rand_act(rows, cols, 21);
        let spec = crate::dsp::fft2d::fft2_real(MatView::new(&a, rows, cols));
        let gather = |ks: usize, kd: usize| -> (Vec<f32>, Vec<f32>) {
            let ui = freq_indices(rows, ks);
            let vi = freq_indices(cols, kd);
            let mut re = vec![0.0f32; ks * kd];
            let mut im = vec![0.0f32; ks * kd];
            for (i, &u) in ui.iter().enumerate() {
                for (j, &v) in vi.iter().enumerate() {
                    re[i * kd + j] = spec[u * cols + v].re as f32;
                    im[i * kd + j] = spec[u * cols + v].im as f32;
                }
            }
            (re, im)
        };
        let (re0, im0) = gather(ks0, kd0);
        let (want_re, want_im) = gather(ks1, kd1);

        let mut eng = CodecEngine::new();
        let (mut re1, mut im1) = (Vec::new(), Vec::new());
        crop_block_into(&mut eng, &re0, &im0, rows, cols, ks0, kd0, ks1, kd1,
                        &mut re1, &mut im1).unwrap();
        // the crop is exactly the directly-gathered small block
        assert_eq!(re1, want_re);
        assert_eq!(im1, want_im);

        // embed back: nested frequencies survive bit-exactly, the
        // truncated ones are zero
        let (mut bre, mut bim) = (Vec::new(), Vec::new());
        embed_block_into(&mut eng, &re1, &im1, rows, cols, ks1, kd1, ks0, kd0,
                         &mut bre, &mut bim).unwrap();
        let ui1: std::collections::HashSet<_> =
            freq_indices(rows, ks1).into_iter().collect();
        let vi1: std::collections::HashSet<_> =
            freq_indices(cols, kd1).into_iter().collect();
        for (i, &u) in freq_indices(rows, ks0).iter().enumerate() {
            for (j, &v) in freq_indices(cols, kd0).iter().enumerate() {
                let kept = ui1.contains(&u) && vi1.contains(&v);
                if kept {
                    assert_eq!(bre[i * kd0 + j].to_bits(),
                               re0[i * kd0 + j].to_bits());
                    assert_eq!(bim[i * kd0 + j].to_bits(),
                               im0[i * kd0 + j].to_bits());
                } else {
                    assert_eq!(bre[i * kd0 + j], 0.0);
                    assert_eq!(bim[i * kd0 + j], 0.0);
                }
            }
        }

        // embedding into the primary reconstructs identically to
        // compressing straight at the small block: the serving
        // path's ladder-point equivalence
        let codec = FourierCodec::default();
        let small = codec.compress_block(&a, rows, cols, ks1, kd1).unwrap();
        let want = codec.decompress(&small).unwrap();
        let packed_embedded = pack_block(&bre, &bim, rows, cols, ks0, kd0);
        let via_primary = codec
            .decompress(&{
                let mut p = Payload::empty();
                p.reset("fc", rows, cols);
                let mut w = Writer(&mut p.body);
                w.u16(ks0 as u16);
                w.u16(kd0 as u16);
                for v in &packed_embedded {
                    w.f32(*v);
                }
                p
            })
            .unwrap();
        for (x, y) in want.iter().zip(&via_primary) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn crop_covers_full_axis_and_degenerate_widths() {
        // k1 == n (identity axis), k1 == 1 (DC only), k1 == k0 — the
        // segment decomposition's edges, pinned against a per-element
        // gather oracle
        let (rows, cols) = (8usize, 12usize);
        let mut eng = CodecEngine::new();
        for (ks0, kd0, ks1, kd1) in [
            (rows, cols, rows, cols),
            (rows, cols, 1, 1),
            (rows, cols, 5, 7),
            (5, 7, 5, 7),
            (7, 11, 1, 11),
            (7, cols, 3, cols),
        ] {
            let n0 = ks0 * kd0;
            let re0: Vec<f32> = (0..n0).map(|x| x as f32).collect();
            let im0: Vec<f32> = (0..n0).map(|x| -(x as f32)).collect();
            let (mut re1, mut im1) = (Vec::new(), Vec::new());
            crop_block_into(&mut eng, &re0, &im0, rows, cols, ks0, kd0, ks1,
                            kd1, &mut re1, &mut im1).unwrap();
            // oracle: gather through the centred index lists
            let ui0 = freq_indices(rows, ks0);
            let vi0 = freq_indices(cols, kd0);
            let pos = |list: &[usize], u: usize| {
                list.iter().position(|&x| x == u).unwrap()
            };
            let mut want_re = Vec::new();
            for &u in &freq_indices(rows, ks1) {
                for &v in &freq_indices(cols, kd1) {
                    want_re.push(re0[pos(&ui0, u) * kd0 + pos(&vi0, v)]);
                }
            }
            assert_eq!(re1, want_re, "{ks0}x{kd0} -> {ks1}x{kd1}");
            assert_eq!(im1.len(), ks1 * kd1);

            // embed is crop's right inverse on the nested entries
            let (mut bre, mut bim) = (Vec::new(), Vec::new());
            embed_block_into(&mut eng, &re1, &im1, rows, cols, ks1, kd1, ks0,
                             kd0, &mut bre, &mut bim).unwrap();
            let (mut re2, mut im2) = (Vec::new(), Vec::new());
            crop_block_into(&mut eng, &bre, &bim, rows, cols, ks0, kd0, ks1,
                            kd1, &mut re2, &mut im2).unwrap();
            assert_eq!(re1, re2);
            assert_eq!(im1, im2);
        }
    }

    #[test]
    fn crop_and_embed_reject_non_nested_or_misshapen() {
        let mut eng = CodecEngine::new();
        let (mut re, mut im) = (Vec::new(), Vec::new());
        // not nested: kd1 > kd0
        assert!(crop_block_into(&mut eng, &[0.0; 45], &[0.0; 45], 16, 32, 9,
                                5, 5, 7, &mut re, &mut im).is_err());
        // invalid axis (even, non-full)
        assert!(crop_block_into(&mut eng, &[0.0; 45], &[0.0; 45], 16, 32, 9,
                                5, 4, 5, &mut re, &mut im).is_err());
        // wrong source length
        assert!(crop_block_into(&mut eng, &[0.0; 7], &[0.0; 7], 16, 32, 9, 5,
                                5, 5, &mut re, &mut im).is_err());
        assert!(embed_block_into(&mut eng, &[0.0; 7], &[0.0; 7], 16, 32, 5, 5,
                                 9, 5, &mut re, &mut im).is_err());
    }

    #[test]
    fn rejects_corrupt_payload() {
        let a = rand_act(16, 32, 8);
        let codec = FourierCodec::default();
        let mut p = codec.compress(&a, 16, 32, 8.0).unwrap();
        p.body.truncate(p.body.len() - 3);
        assert!(codec.decompress(&p).is_err());
        let mut p2 = codec.compress(&a, 16, 32, 8.0).unwrap();
        p2.body[0] = 0xFF; // ks out of range
        p2.body[1] = 0xFF;
        assert!(codec.decompress(&p2).is_err());
        // a whole missing float (4-byte aligned truncation) must also
        // be rejected, by the scatter's position accounting
        let mut p3 = codec.compress(&a, 16, 32, 8.0).unwrap();
        p3.body.truncate(p3.body.len() - 4);
        assert!(codec.decompress(&p3).is_err());
    }
}
