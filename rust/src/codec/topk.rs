//! Top-k sparsification baseline (Split fine-tuning [24]): keep the k
//! largest-|·| entries, transmit (index, value) pairs.  Each kept
//! entry costs 8 bytes, so k = S·D/(2·ratio).
//!
//! Selection is a full sort by (|v| desc, idx asc) — matching how the
//! framework baselines implement `topk` (and keeping payload bytes
//! deterministic under ties).  The sort permutation lives in the
//! engine's u32 scratch so the steady-state path allocates nothing.

use super::engine::CodecEngine;
use super::{Codec, Payload, Reader, Writer};
use crate::tensor::MatView;
use anyhow::{ensure, Result};

pub struct TopkCodec;

impl TopkCodec {
    pub fn k_for_ratio(n: usize, ratio: f64) -> usize {
        ((n as f64 / (2.0 * ratio)).floor() as usize).clamp(1, n)
    }
}

impl Codec for TopkCodec {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn compress_into(&self, eng: &mut CodecEngine, a: MatView<'_>, ratio: f64,
                     out: &mut Payload) -> Result<()> {
        let data = a.as_slice();
        let k = Self::k_for_ratio(data.len(), ratio);
        let idx = &mut eng.indices32;
        idx.clear();
        idx.extend(0..data.len() as u32);
        // unstable sort: the comparator is a total order (index
        // tie-break), so the permutation — and the payload bytes —
        // are identical to a stable sort, without its temp-buffer
        // allocation.
        idx.sort_unstable_by(|&x, &y| {
            let (ax, ay) = (data[x as usize].abs(), data[y as usize].abs());
            ay.partial_cmp(&ax).unwrap_or(std::cmp::Ordering::Equal)
                .then(x.cmp(&y))
        });
        let kept = &mut idx[..k];
        kept.sort_unstable(); // ascending index order compresses deltas well

        out.reset("topk", a.rows(), a.cols());
        let mut w = Writer(&mut out.body);
        w.u32(k as u32);
        for &i in kept.iter() {
            w.u32(i);
        }
        for &i in kept.iter() {
            w.f32(data[i as usize]);
        }
        Ok(())
    }

    fn decompress_into(&self, eng: &mut CodecEngine, p: &Payload,
                       out: &mut Vec<f32>) -> Result<()> {
        let mut r = Reader::new(&p.body);
        let k = r.u32()? as usize;
        let n = p.rows * p.cols;
        ensure!(k <= n, "k={k} exceeds matrix size {n}");
        out.clear();
        out.resize(n, 0.0);
        let indices = &mut eng.indices32;
        indices.clear();
        indices.reserve(k);
        for _ in 0..k {
            let i = r.u32()?;
            ensure!((i as usize) < n, "index {i} out of range");
            indices.push(i);
        }
        for &i in indices.iter() {
            out[i as usize] = r.f32()?;
        }
        ensure!(r.remaining() == 0, "trailing payload bytes");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{rand_act, rel_error};

    #[test]
    fn keeps_largest_magnitudes() {
        let a = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 1.0];
        let c = TopkCodec;
        // ratio chosen so k=3 of 6
        let p = c.compress(&a, 2, 3, 1.0).unwrap();
        let out = c.decompress(&p).unwrap();
        assert_eq!(out, vec![0.0, -5.0, 0.0, 3.0, 0.0, 1.0]);
    }

    #[test]
    fn ratio_accounting() {
        let a = rand_act(64, 128, 1);
        let c = TopkCodec;
        for ratio in [4.0, 8.0, 16.0] {
            let p = c.compress(&a, 64, 128, ratio).unwrap();
            let got = p.achieved_ratio();
            assert!(got >= ratio * 0.9 && got <= ratio * 1.3,
                    "ratio {ratio} got {got}");
        }
    }

    #[test]
    fn error_bounded_by_dropped_energy() {
        let a = rand_act(32, 32, 2);
        let c = TopkCodec;
        let out = c.roundtrip(&a, 32, 32, 4.0).unwrap();
        // kept entries are exact; dropped entries contribute all error
        let mut dropped: f64 = 0.0;
        let mut total: f64 = 0.0;
        for (x, y) in a.iter().zip(&out) {
            total += (*x as f64) * (*x as f64);
            if *y == 0.0 {
                dropped += (*x as f64) * (*x as f64);
            } else {
                assert_eq!(x, y);
            }
        }
        let expected = (dropped / total).sqrt();
        assert!((rel_error(&a, &out) - expected).abs() < 1e-6);
    }

    #[test]
    fn deterministic_under_ties() {
        let a = vec![1.0f32; 64];
        let c = TopkCodec;
        let p1 = c.compress(&a, 8, 8, 4.0).unwrap();
        let p2 = c.compress(&a, 8, 8, 4.0).unwrap();
        assert_eq!(p1, p2);
    }

    #[test]
    fn rejects_corrupt() {
        let a = rand_act(8, 8, 3);
        let c = TopkCodec;
        let mut p = c.compress(&a, 8, 8, 4.0).unwrap();
        // out-of-range index
        p.body[4] = 0xFF;
        p.body[5] = 0xFF;
        p.body[6] = 0xFF;
        p.body[7] = 0xFF;
        assert!(c.decompress(&p).is_err());
    }
}
