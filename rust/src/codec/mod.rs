//! Activation codecs: FourierCompress and every baseline the paper
//! compares against (Table III/IV), all operating on a row-major
//! `S x D` f32 activation matrix and producing a self-describing wire
//! payload.
//!
//! Payload accounting follows DESIGN.md §6: the achieved ratio is
//! `raw bytes / payload bytes` with raw = 4·S·D.  FourierCompress
//! packs only the non-redundant half of the conjugate-symmetric block,
//! so a K_S×K_D complex block costs K_S·K_D floats on the wire.
//!
//! Two ratio accountings exist and each consumer picks one
//! deliberately (they used to be conflated — see [`Payload`]):
//!
//! * [`Payload::achieved_ratio`] — body bytes only.  This is the
//!   *codec* ratio the paper's Tables II/III report and what the
//!   golden-parity fixtures pin (the python reference has no framing).
//! * [`Payload::wire_ratio`] — framed bytes, including the 12-byte
//!   Activation frame header.  This is the *transport* ratio; Fig 6's
//!   transfer-time model and the serving metrics use it.

pub mod engine;
pub mod fourier;
pub mod lowrank;
pub mod quant;
pub mod rate;
pub mod stream;
pub mod topk;
pub mod wire;

pub use engine::{with_thread_engine, CodecEngine, StageTimes};

use crate::tensor::MatView;
use anyhow::{bail, ensure, Result};

/// Bytes the coordinator's Activation frame adds around a codec body
/// (session/request routing + block geometry).
pub const FRAME_HEADER_BYTES: usize = 12;

/// A compressed activation as it crosses the wire.
///
/// Reusable: `reset` clears the body while keeping its capacity, so a
/// decode loop that owns one `Payload` and calls
/// [`Codec::compress_into`] per token allocates nothing after warm-up.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Payload {
    pub codec: String,
    pub rows: usize,
    pub cols: usize,
    /// Codec-specific body (the transmitted bytes).
    pub body: Vec<u8>,
}

impl Payload {
    /// An empty payload to be filled by [`Codec::compress_into`].
    pub fn empty() -> Payload {
        Payload::default()
    }

    /// Re-initialise for a fresh compression without releasing the
    /// body's capacity.
    pub fn reset(&mut self, codec: &str, rows: usize, cols: usize) {
        self.codec.clear();
        self.codec.push_str(codec);
        self.rows = rows;
        self.cols = cols;
        self.body.clear();
    }

    /// Bytes on the wire: body + the frame header the protocol adds.
    pub fn wire_bytes(&self) -> usize {
        self.body.len() + FRAME_HEADER_BYTES
    }

    /// Codec compression ratio over the body only (no framing) — the
    /// accounting Tables II/III and the codec unit/parity tests use.
    pub fn achieved_ratio(&self) -> f64 {
        (self.rows * self.cols * 4) as f64 / self.body.len().max(1) as f64
    }

    /// Transport compression ratio over the framed bytes — the
    /// accounting Fig 6 and the serving metrics use.  Always ≤
    /// [`Payload::achieved_ratio`].
    pub fn wire_ratio(&self) -> f64 {
        (self.rows * self.cols * 4) as f64 / self.wire_bytes() as f64
    }
}

/// An activation codec.  Implementations must be deterministic: the
/// same input and ratio produce byte-identical payloads (the golden
/// parity tests rely on it).
///
/// The primary API is `_into`-style: the caller owns a
/// [`CodecEngine`] (plans, index sets, scratch) and the output
/// buffers, so the steady-state decode loop performs zero heap
/// allocation.  The one-shot `compress`/`decompress` methods are thin
/// wrappers over a thread-local engine kept for convenience and for
/// wire-format parity with the pre-engine codebase — they produce
/// byte-identical payloads.
pub trait Codec: Send + Sync {
    fn name(&self) -> &'static str;

    /// Compress `a` at the target ratio into `out` (reusing `out`'s
    /// buffers; `out` is reset first).
    fn compress_into(&self, eng: &mut CodecEngine, a: MatView<'_>, ratio: f64,
                     out: &mut Payload) -> Result<()>;

    /// Reconstruct the full rows × cols matrix into `out` (cleared
    /// first, capacity reused).
    fn decompress_into(&self, eng: &mut CodecEngine, p: &Payload,
                       out: &mut Vec<f32>) -> Result<()>;

    /// One-shot compression (legacy API; thread-local engine).
    fn compress(&self, a: &[f32], rows: usize, cols: usize, ratio: f64)
        -> Result<Payload> {
        ensure!(a.len() == rows * cols, "shape mismatch");
        let view = MatView::new(a, rows, cols);
        with_thread_engine(|eng| {
            let mut out = Payload::empty();
            self.compress_into(eng, view, ratio, &mut out)?;
            Ok(out)
        })
    }

    /// One-shot reconstruction (legacy API; thread-local engine).
    fn decompress(&self, p: &Payload) -> Result<Vec<f32>> {
        with_thread_engine(|eng| {
            let mut out = Vec::new();
            self.decompress_into(eng, p, &mut out)?;
            Ok(out)
        })
    }

    /// Convenience: compress-then-decompress (the eval harness path).
    fn roundtrip(&self, a: &[f32], rows: usize, cols: usize, ratio: f64)
        -> Result<Vec<f32>> {
        self.decompress(&self.compress(a, rows, cols, ratio)?)
    }
}

/// All codec names in the paper's comparison order.
pub const ALL_CODECS: &[&str] =
    &["fc", "topk", "qr", "fwsvd", "asvd", "svdllm", "int8", "none"];

pub fn by_name(name: &str) -> Result<Box<dyn Codec>> {
    Ok(match name {
        "fc" | "fourier" => Box::new(fourier::FourierCodec::default()),
        "topk" => Box::new(topk::TopkCodec),
        "qr" => Box::new(lowrank::QrCodec),
        "fwsvd" => Box::new(lowrank::SvdCodec::fwsvd()),
        "asvd" => Box::new(lowrank::SvdCodec::asvd()),
        "svdllm" => Box::new(lowrank::SvdCodec::svdllm()),
        "svd" => Box::new(lowrank::SvdCodec::plain()),
        "int8" => Box::new(quant::Int8Codec::default()),
        "none" => Box::new(NoneCodec),
        other => bail!("unknown codec '{other}'"),
    })
}

/// Pass-through codec (the paper's uncompressed baseline).
pub struct NoneCodec;

impl Codec for NoneCodec {
    fn name(&self) -> &'static str {
        "none"
    }

    fn compress_into(&self, _eng: &mut CodecEngine, a: MatView<'_>,
                     _ratio: f64, out: &mut Payload) -> Result<()> {
        out.reset("none", a.rows(), a.cols());
        out.body.reserve(a.len() * 4);
        for v in a.as_slice() {
            out.body.extend_from_slice(&v.to_le_bytes());
        }
        Ok(())
    }

    fn decompress_into(&self, _eng: &mut CodecEngine, p: &Payload,
                       out: &mut Vec<f32>) -> Result<()> {
        if p.body.len() != p.rows * p.cols * 4 {
            bail!("none codec: bad body size");
        }
        out.clear();
        out.reserve(p.rows * p.cols);
        out.extend(p.body
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])));
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// shared byte helpers
// ---------------------------------------------------------------------------

/// Little-endian byte writer over a caller-owned buffer: the codecs
/// append straight into `Payload::body`, so a reused payload keeps
/// its capacity and the hot path allocates nothing.
pub(crate) struct Writer<'a>(pub &'a mut Vec<u8>);

impl Writer<'_> {
    pub fn u16(&mut self, v: u16) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    pub fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    pub fn f32(&mut self, v: f32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    /// Bulk little-endian append of a float slice — one `memcpy` on
    /// little-endian targets (all supported ones), byte-identical to
    /// the per-element [`Writer::f32`] loop.
    pub fn f32s(&mut self, vals: &[f32]) {
        #[cfg(target_endian = "little")]
        {
            // SAFETY: f32 has no padding bytes, so 4·len initialised
            // bytes start at the slice base; LE memory order is
            // exactly the wire order f32() emits.
            let bytes = unsafe {
                std::slice::from_raw_parts(vals.as_ptr() as *const u8,
                                           4 * vals.len())
            };
            self.0.extend_from_slice(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        for v in vals {
            self.f32(*v);
        }
    }
}

pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }
    pub fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }
    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    pub fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    /// Bulk read of `n` little-endian floats, appended into `out` —
    /// the decode-side twin of [`Writer::f32s`].
    pub fn f32s(&mut self, n: usize, out: &mut Vec<f32>) -> Result<()> {
        let bytes = self.take(4 * n)?;
        let old = out.len();
        out.reserve(n);
        #[cfg(target_endian = "little")]
        // SAFETY: `bytes` is 4·n readable bytes, every bit pattern is
        // a valid f32, and the destination capacity was just reserved;
        // byte-for-byte this is the from_le_bytes loop below.
        unsafe {
            std::ptr::copy_nonoverlapping(bytes.as_ptr(),
                                          out.as_mut_ptr().add(old)
                                              as *mut u8,
                                          4 * n);
            out.set_len(old + n);
        }
        #[cfg(not(target_endian = "little"))]
        out.extend(bytes.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])));
        #[cfg(not(target_endian = "little"))]
        let _ = old;
        Ok(())
    }
    pub fn byte(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("payload truncated at {} (+{n})", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

// ---------------------------------------------------------------------------
// block-size selection (port of python configs.fc_block)
// ---------------------------------------------------------------------------

pub(crate) fn odd_cap(x: usize, cap: usize) -> usize {
    let mut x = x.clamp(1, cap);
    if x % 2 == 0 {
        if x > 1 {
            x -= 1;
        } else if x + 1 <= cap {
            x += 1;
        }
    }
    x
}

/// Choose (K_S, K_D) for a target ratio under conjugate-symmetric
/// accounting (payload floats = K_S·K_D).  `kd_hint` carries the
/// calibrated hidden-axis width (from the manifest or from
/// [`calibrate_block`]).
pub fn fc_block(seq: usize, hidden: usize, ratio: f64, kd_hint: Option<usize>)
    -> (usize, usize) {
    let budget = ((seq * hidden) as f64 / ratio).max(1.0);
    let kd = odd_cap(
        kd_hint.unwrap_or(((hidden as f64) / 8.0).round().max(3.0) as usize),
        hidden,
    );
    let ks = (budget / kd as f64) as usize;
    let ks = if ks >= seq { seq } else { odd_cap(ks.max(1), seq) };
    (ks, kd)
}

pub fn block_ratio(seq: usize, hidden: usize, ks: usize, kd: usize) -> f64 {
    (seq * hidden) as f64 / (ks * kd) as f64
}

/// Whether keeping `k` of `n` bins is a valid centred block width:
/// in range, and odd unless the full axis is kept — the invariant
/// `freq_indices` asserts.  The single source of truth for payload
/// validation and the coordinator's engine warm-up gating.
pub fn valid_block_axis(n: usize, k: usize) -> bool {
    k >= 1 && k <= n && (k == n || k % 2 == 1)
}

/// Centred (conjugate-closed) frequency index set — public for the
/// analysis driver and the benches.
pub fn centered_indices(n: usize, k: usize) -> Vec<usize> {
    freq_indices(n, k)
}

pub(crate) fn freq_indices(n: usize, k: usize) -> Vec<usize> {
    assert!(k >= 1 && k <= n, "k={k} n={n}");
    if k == n {
        return (0..n).collect();
    }
    assert!(k % 2 == 1, "k={k} must be odd for n={n}");
    let h = (k - 1) / 2;
    let mut v: Vec<usize> = (0..=h).collect();
    v.extend(n - h..n);
    v
}

/// Spectral calibration: given sample activations, pick the hidden-
/// axis width K_D whose centred block captures the most energy within
/// the float budget implied by `ratio`.  This is how a deployment
/// discovers the model's layer-1 band without training internals.
pub fn calibrate_block(samples: &[MatView<'_>], ratio: f64)
    -> Option<usize> {
    use crate::dsp::fft2d::fft2_real;
    let first = samples.first()?;
    let (rows, cols) = (first.rows(), first.cols());
    let mut energy = vec![0.0f64; rows * cols];
    let mut used = 0;
    for a in samples {
        if a.rows() != rows || a.cols() != cols {
            continue;
        }
        let spec = fft2_real(*a);
        for (e, s) in energy.iter_mut().zip(&spec) {
            *e += s.norm_sq();
        }
        used += 1;
    }
    if used == 0 {
        return None;
    }
    let budget = ((rows * cols) as f64 / ratio).max(1.0);
    let mut best: Option<(f64, usize)> = None;
    let mut kd = 3usize;
    while kd <= cols {
        let ks_raw = (budget / kd as f64) as usize;
        if ks_raw >= 1 {
            let ks = if ks_raw >= rows { rows } else { odd_cap(ks_raw, rows) };
            let e = block_energy(&energy, rows, cols, ks, kd);
            if best.map(|(be, _)| e > be).unwrap_or(true) {
                best = Some((e, kd));
            }
        }
        kd += 2;
    }
    best.map(|(_, kd)| kd)
}

fn block_energy(energy: &[f64], rows: usize, cols: usize, ks: usize, kd: usize)
    -> f64 {
    let ui = freq_indices(rows, ks);
    let vi = freq_indices(cols, kd);
    let mut e = 0.0;
    for &u in &ui {
        for &v in &vi {
            e += energy[u * cols + v];
        }
    }
    e
}

/// Relative Frobenius reconstruction error — the Fig 2(a) metric.
pub fn rel_error(a: &[f32], b: &[f32]) -> f64 {
    let num: f64 = a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum();
    let den: f64 = a.iter().map(|x| (*x as f64).powi(2)).sum();
    (num / den.max(1e-30)).sqrt()
}

#[cfg(test)]
pub(crate) fn rand_act(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
    let mut rng = crate::util::rng::Rng::new(seed);
    (0..rows * cols).map(|_| rng.normal() as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_codec_roundtrip_exact() {
        let a = rand_act(8, 16, 1);
        let c = NoneCodec;
        let out = c.roundtrip(&a, 8, 16, 1.0).unwrap();
        assert_eq!(out, a);
        let p = c.compress(&a, 8, 16, 1.0).unwrap();
        assert!((p.achieved_ratio() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn all_codecs_constructible() {
        for name in ALL_CODECS {
            by_name(name).unwrap();
        }
        assert!(by_name("bogus").is_err());
    }

    #[test]
    fn every_codec_hits_target_ratio() {
        let (rows, cols) = (48, 96);
        let a = rand_act(rows, cols, 2);
        for name in ["fc", "topk", "qr", "fwsvd", "asvd", "svdllm"] {
            let c = by_name(name).unwrap();
            for ratio in [4.0, 8.0, 12.0] {
                let p = c.compress(&a, rows, cols, ratio).unwrap();
                let got = p.achieved_ratio();
                assert!(got >= ratio * 0.7,
                        "{name} ratio {ratio}: achieved {got:.2}");
                let out = c.decompress(&p).unwrap();
                assert_eq!(out.len(), rows * cols);
                assert!(out.iter().all(|v| v.is_finite()), "{name}");
            }
        }
    }

    #[test]
    fn roundtrip_error_nondecreasing_in_ratio() {
        let (rows, cols) = (32, 64);
        let a = rand_act(rows, cols, 3);
        for name in ["fc", "topk", "qr", "svd"] {
            let c = by_name(name).unwrap();
            let mut last = -1.0f64;
            for ratio in [2.0, 4.0, 8.0, 16.0] {
                let out = c.roundtrip(&a, rows, cols, ratio).unwrap();
                let err = rel_error(&a, &out);
                assert!(err >= last - 0.05, "{name} ratio {ratio}");
                last = err;
            }
        }
    }

    #[test]
    fn fc_block_accounting() {
        for (s, d) in [(16, 96), (48, 128), (64, 128), (256, 2048)] {
            for ratio in [6.0, 8.0, 10.0] {
                let (ks, kd) = fc_block(s, d, ratio, None);
                assert!(ks <= s && kd <= d);
                let got = block_ratio(s, d, ks, kd);
                assert!(got >= ratio * 0.8, "({s},{d}) ratio {ratio} got {got}");
            }
        }
    }

    #[test]
    fn freq_indices_conjugate_closed() {
        for n in [8usize, 48, 96] {
            for k in [1usize, 3, 7, 13] {
                if k > n {
                    continue;
                }
                let idx = freq_indices(n, k);
                let set: std::collections::HashSet<_> = idx.iter().copied().collect();
                for &u in &idx {
                    assert!(set.contains(&((n - u) % n)));
                }
            }
            assert_eq!(freq_indices(n, n).len(), n);
        }
    }

    #[test]
    fn calibration_finds_bandlimited_axis() {
        // synthesise an activation band-limited to 13 hidden bins
        let (rows, cols) = (32, 96);
        let mut rng = crate::util::rng::Rng::new(7);
        let mut a = vec![0.0f32; rows * cols];
        for bin in 0..7usize {
            let amp = rng.normal() as f32;
            let ph = rng.f64() as f32 * 6.28;
            for r in 0..rows {
                let rowamp = 1.0 + 0.3 * (r as f32 / rows as f32).sin();
                for c in 0..cols {
                    let ang = 6.283_185_5 * bin as f32 * c as f32 / cols as f32 + ph;
                    a[r * cols + c] += amp * rowamp * ang.cos();
                }
            }
        }
        let kd = calibrate_block(&[MatView::new(&a, rows, cols)], 8.0).unwrap();
        assert!((11..=17).contains(&kd), "calibrated kd={kd}");
    }

    #[test]
    fn bulk_f32_wire_helpers_match_scalar() {
        let vals: Vec<f32> = (0..33).map(|i| (i as f32) * 0.37 - 5.0).collect();
        let mut a = Vec::new();
        let mut w = Writer(&mut a);
        w.f32s(&vals);
        w.f32s(&[]); // empty append is a no-op
        let mut b = Vec::new();
        let mut w2 = Writer(&mut b);
        for v in &vals {
            w2.f32(*v);
        }
        assert_eq!(a, b);

        let mut r = Reader::new(&a);
        let mut back = vec![9.0f32]; // appended after a sentinel
        r.f32s(vals.len(), &mut back).unwrap();
        assert_eq!(back[0], 9.0);
        assert_eq!(&back[1..], vals.as_slice());
        assert_eq!(r.remaining(), 0);

        let mut short = Reader::new(&a[..7]);
        assert!(short.f32s(2, &mut back).is_err());
    }

    #[test]
    fn rel_error_basics() {
        let a = vec![1.0f32, 2.0, 2.0];
        assert_eq!(rel_error(&a, &a), 0.0);
        let b = vec![0.0f32, 0.0, 0.0];
        assert!((rel_error(&a, &b) - 1.0).abs() < 1e-9);
    }
}
