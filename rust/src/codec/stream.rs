//! Spectral delta streaming: a session-stateful temporal codec over
//! the FourierCompress block, applied along **both** of the serving
//! stack's bandwidth cliffs — the per-token decode loop and the
//! prompt-phase (prefill) transfer.
//!
//! Decode steps have not retransmitted the full (prompt + *t*)×D
//! activation since the delta stream landed: inside one serving
//! bucket the block geometry is fixed and only the rows from the
//! appended token onward change, so most of the K_S×K_D spectral
//! coefficients drift by little between steps.  This module streams
//! that block temporally, the way atsc streams frames of a time
//! series:
//!
//! * a **keyframe** carries the full conjugate-symmetric packing
//!   (exactly the floats an Activation frame carries) and
//!   unconditionally resynchronises the receiver;
//! * a **delta frame** carries only the coefficients whose last
//!   transmitted value drifted, as `(u32 index, f32 value)` updates
//!   into the packed vector — int-indexed like atsc's
//!   `FrequencyPoint`, 8 wire bytes per coefficient.
//!
//! The [`StreamEncoder`] (device side) keeps the last transmitted
//! packed block per session and picks per step: keyframe when the
//! geometry changed (bucket promotion), every
//! [`StreamConfig::keyframe_interval`] frames, on
//! [`StreamEncoder::force_keyframe`] (resync), or when a delta would
//! cost more wire bytes than the keyframe it replaces; otherwise a
//! delta whose *unsent* drift is bounded by
//! [`StreamConfig::drift_threshold`].  Updates are exact f32
//! replacements, so encoder and decoder state never diverge — with a
//! zero threshold the stream is bit-identical to retransmitting the
//! packed block every step.
//!
//! ## Prefill chunks
//!
//! The first frame of a conversation — the prompt-phase block — has
//! no previous step to delta against, so it used to cross the wire as
//! one monolithic keyframe.  [`split_prefill`] reuses the same
//! Parseval-bounded delta machinery *spatially, across the prompt
//! dimension*: the packed plane is cut into fixed-row chunks
//! ([`PrefillConfig::chunk_rows`] rows of `kd` floats), chunk 0 ships
//! as a **keyframe chunk**, and every later chunk ships as row
//! deltas against the previous chunk's transmitted rows (falling back
//! to a keyframe chunk when the delta would be denser than raw).  On
//! a band-limited hidden axis adjacent row groups agree on every
//! out-of-band slot, so the delta chunks collapse to the in-band
//! columns.  The [`PrefillAssembler`] (server side) reassembles the
//! plane chunk by chunk, hard-fails sequence gaps, and resyncs only
//! on a restart from keyframe chunk 0 — the same no-silent-drift
//! contract the decode stream has.  A completed prefill plane seeds
//! the decode stream ([`StreamEncoder::seed`] /
//! [`StreamDecoder::apply_key`]) so decode step 1 can ride a delta
//! against the prompt state.
//!
//! ## Drift accounting
//!
//! Drift is measured in the spectral domain with conjugate-mirror
//! weights (a packed re/im pair stands for a coefficient *and* its
//! mirror, so it carries weight 2; a self-conjugate slot weight 1).
//! By Parseval this weighted relative error equals the relative error
//! between the *reconstructions* of the stale and the true block, so
//! `drift_threshold` directly bounds the per-step reconstruction
//! error the stream adds on top of the FC truncation the keyframe
//! regime already has.  Prefill chunks budget the same way, but
//! against the *whole-plane* energy prorated by chunk length, so the
//! cumulative drift across every chunk of one prompt stays under the
//! advertised [`PrefillConfig::drift_threshold`].
//!
//! The [`StreamDecoder`] (server side) reconstructs from per-session
//! state and **hard-fails on sequence gaps**: a lost or reordered
//! delta desynchronises the session until the next keyframe, which
//! recovers byte-identical state (`tests/stream_serving.rs` pins
//! this).  The decoder never guesses — silent drift is the one failure
//! mode a lossy activation link cannot afford.
//!
//! All frame kinds — keyframes, deltas, and prefill chunks — compose
//! with the lossless entropy layer ([`super::wire`], negotiated via
//! [`crate::coordinator::protocol::caps::ENTROPY`]): a packed plane
//! or chunk slice and a sparse update list each have a coded wire
//! form the transport ships when it is smaller than the raw one.  The
//! stream codec itself is unaware — coding happens at the frame
//! boundary, on exactly the bytes [`StreamStep::body_bytes`] /
//! [`PrefillChunk::body_bytes`] count.

use super::engine::CodecEngine;
use super::{valid_block_axis, Payload, Writer};
use anyhow::{bail, ensure, Result};

/// Wire bytes per sparse coefficient update (u32 index + f32 value).
pub const UPDATE_WIRE_BYTES: usize = 8;

/// Block geometry of one stream: the pre-compression matrix shape and
/// the kept centred block.  Any change forces a keyframe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockGeom {
    pub rows: usize,
    pub cols: usize,
    pub ks: usize,
    pub kd: usize,
}

/// Encoder policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct StreamConfig {
    /// Force a keyframe every this many frames (1 = every frame).
    pub keyframe_interval: u32,
    /// Max relative spectral drift a delta frame may leave unsent
    /// (0.0 = deltas replace every changed coefficient exactly).
    pub drift_threshold: f64,
}

impl Default for StreamConfig {
    fn default() -> StreamConfig {
        StreamConfig { keyframe_interval: 32, drift_threshold: 0.05 }
    }
}

/// One encoded stream frame, written into caller-owned buffers so the
/// per-token loop allocates nothing after warm-up (the `packed` /
/// `updates` vectors are moved into the wire frame and recovered, like
/// the client's Activation scratch).
#[derive(Debug, Default)]
pub struct StreamStep {
    pub seq: u32,
    pub keyframe: bool,
    /// Keyframe payload: the full packed block (empty for deltas).
    pub packed: Vec<f32>,
    /// Delta payload: sparse updates (empty for keyframes).
    pub updates: Vec<(u32, f32)>,
}

impl StreamStep {
    /// Codec-body wire bytes of this frame (the protocol adds
    /// [`crate::coordinator::protocol::STREAM_HEADER_BYTES`] on top).
    pub fn body_bytes(&self) -> usize {
        if self.keyframe {
            self.packed.len() * 4
        } else {
            4 + self.updates.len() * UPDATE_WIRE_BYTES
        }
    }
}

/// Conjugate-mirror energy weight per packed float slot, in exactly
/// the order [`super::fourier::pack_block_into`] emits: self-conjugate
/// coefficients contribute their own energy (weight 1), every other
/// packed pair stands for the coefficient and its mirror (weight 2 on
/// both the re and the im slot).
fn mirror_weights(eng: &mut CodecEngine, g: BlockGeom, out: &mut Vec<f32>) {
    let ui = eng.indices(g.rows, g.ks);
    let vi = eng.indices(g.cols, g.kd);
    out.clear();
    out.reserve(g.ks * g.kd);
    for &u in ui.iter() {
        for &v in vi.iter() {
            let (mu, mv) = ((g.rows - u) % g.rows, (g.cols - v) % g.cols);
            if (u, v) > (mu, mv) {
                continue; // mirror carries it
            }
            if (u, v) == (mu, mv) {
                out.push(1.0); // self-conjugate: re only
            } else {
                out.push(2.0); // re
                out.push(2.0); // im
            }
        }
    }
}

/// Assemble the `fc` wire payload for a packed coefficient block, so
/// stream state reconstructs through the ordinary
/// [`super::fourier::FourierCodec`] decompression path (the benches
/// and drift tests use this bridge).
pub fn fc_payload(geom: BlockGeom, packed: &[f32]) -> Payload {
    let mut p = Payload::empty();
    p.reset("fc", geom.rows, geom.cols);
    let mut w = Writer(&mut p.body);
    w.u16(geom.ks as u16);
    w.u16(geom.kd as u16);
    w.f32s(packed);
    p
}

// ---------------------------------------------------------------------------
// encoder (device side)
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct StreamEncoder {
    cfg: StreamConfig,
    geom: Option<BlockGeom>,
    /// Last transmitted packed block — mirrors the decoder exactly.
    state: Vec<f32>,
    weight: Vec<f32>,
    seq: u32,
    since_key: u32,
    force_key: bool,
    /// Relative spectral drift the last encoded frame left unsent
    /// (0.0 after a keyframe) — the measurement the adaptive rate
    /// controller (`codec::rate`) consumes.
    last_drift: f64,
    /// Scratch: (drift energy, index) candidates, largest first.
    cand: Vec<(f64, u32)>,
}

impl StreamEncoder {
    pub fn new(cfg: StreamConfig) -> StreamEncoder {
        StreamEncoder {
            cfg: StreamConfig {
                keyframe_interval: cfg.keyframe_interval.max(1),
                drift_threshold: cfg.drift_threshold.max(0.0),
            },
            geom: None,
            state: Vec::new(),
            weight: Vec::new(),
            seq: 0,
            since_key: 0,
            force_key: false,
            last_drift: 0.0,
            cand: Vec::new(),
        }
    }

    pub fn config(&self) -> StreamConfig {
        self.cfg
    }

    /// The encoder's view of the receiver state (the last transmitted
    /// packed block).
    pub fn state(&self) -> &[f32] {
        &self.state
    }

    pub fn next_seq(&self) -> u32 {
        self.seq
    }

    /// Relative spectral drift (mirror-weighted, i.e. by Parseval a
    /// reconstruction-error delta) the most recent frame left unsent:
    /// bounded by [`StreamConfig::drift_threshold`] for deltas, 0.0
    /// for keyframes.  The adaptive rate controller's second input.
    pub fn last_drift(&self) -> f64 {
        self.last_drift
    }

    /// Make the next frame a keyframe regardless of cadence — the
    /// client calls this when the server reports lost stream state
    /// (TTL eviction, sequence gap) to resynchronise.
    pub fn force_keyframe(&mut self) {
        self.force_key = true;
    }

    /// Seed the encoder from an externally transmitted plane — the
    /// chunked-prefill handoff.  After [`split_prefill`] ships a
    /// prompt plane the server seeds its [`StreamDecoder`] with
    /// `apply_key(0, geom, plane)`; calling `seed` with the same
    /// transmitted plane (`split_prefill`'s `state` output) puts the
    /// encoder in the matching state, so decode step 1 rides a delta
    /// with sequence number 1 instead of paying a fresh keyframe.
    pub fn seed(&mut self, eng: &mut CodecEngine, geom: BlockGeom,
                state: &[f32]) -> Result<()> {
        ensure!(valid_block_axis(geom.rows, geom.ks)
                    && valid_block_axis(geom.cols, geom.kd),
                "invalid stream block {}x{} for {}x{}", geom.ks, geom.kd,
                geom.rows, geom.cols);
        ensure!(state.len() == geom.ks * geom.kd,
                "seed plane carries {} floats, geometry wants {}", state.len(),
                geom.ks * geom.kd);
        mirror_weights(eng, geom, &mut self.weight);
        self.geom = Some(geom);
        self.state.clear();
        self.state.extend_from_slice(state);
        self.seq = 1;
        self.since_key = 0;
        self.force_key = false;
        self.last_drift = 0.0;
        Ok(())
    }

    /// Encode the current packed block as the next stream frame into
    /// `out` (buffers reused, cleared first).  Exactly one frame is
    /// produced per call and the encoder state advances with it, so
    /// the caller must transmit every encoded frame (or
    /// [`StreamEncoder::force_keyframe`] afterwards).
    pub fn encode_into(&mut self, eng: &mut CodecEngine, geom: BlockGeom,
                       packed: &[f32], out: &mut StreamStep) -> Result<()> {
        ensure!(valid_block_axis(geom.rows, geom.ks)
                    && valid_block_axis(geom.cols, geom.kd),
                "invalid stream block {}x{} for {}x{}", geom.ks, geom.kd,
                geom.rows, geom.cols);
        let geom_changed = self.geom != Some(geom);
        if geom_changed {
            mirror_weights(eng, geom, &mut self.weight);
            self.geom = Some(geom);
        }
        ensure!(packed.len() == self.weight.len(),
                "packed block {} floats, geometry wants {}", packed.len(),
                self.weight.len());

        out.seq = self.seq;
        out.packed.clear();
        out.updates.clear();

        let need_key = self.force_key
            || geom_changed
            || self.state.len() != packed.len()
            || self.since_key + 1 >= self.cfg.keyframe_interval;
        if !need_key {
            // candidate updates: coefficients whose last transmitted
            // value drifted, by mirror-weighted energy
            let e_cur: f64 = packed
                .iter()
                .zip(&self.weight)
                .map(|(&p, &w)| w as f64 * p as f64 * p as f64)
                .sum();
            self.cand.clear();
            let mut drift = 0.0f64;
            for (i, (&p, &s)) in packed.iter().zip(&self.state).enumerate() {
                if p != s {
                    let d = self.weight[i] as f64
                        * (p as f64 - s as f64)
                        * (p as f64 - s as f64);
                    drift += d;
                    self.cand.push((d, i as u32));
                }
            }
            let thr = self.cfg.drift_threshold;
            let target = thr * thr * e_cur;
            if drift > target {
                // largest drift first; index tie-break keeps the wire
                // bytes deterministic
                self.cand
                    .sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
                for &(d, i) in &self.cand {
                    out.updates.push((i, packed[i as usize]));
                    drift -= d;
                    if drift <= target {
                        break;
                    }
                }
            }
            // a dense delta is a false economy: 8 wire bytes per
            // update vs 4 per keyframe float — fall back to a keyframe
            if out.updates.len() * UPDATE_WIRE_BYTES < packed.len() * 4 {
                for &(i, v) in &out.updates {
                    self.state[i as usize] = v;
                }
                self.last_drift = if e_cur > 0.0 {
                    (drift.max(0.0) / e_cur).sqrt()
                } else {
                    0.0
                };
                out.keyframe = false;
                self.since_key += 1;
                self.seq = self.seq.wrapping_add(1);
                return Ok(());
            }
            out.updates.clear();
        }

        self.last_drift = 0.0;
        out.keyframe = true;
        out.packed.extend_from_slice(packed);
        self.state.clear();
        self.state.extend_from_slice(packed);
        self.force_key = false;
        self.since_key = 0;
        self.seq = self.seq.wrapping_add(1);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// decoder (server side)
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
pub struct StreamDecoder {
    geom: Option<BlockGeom>,
    state: Vec<f32>,
    next_seq: u32,
    synced: bool,
}

impl StreamDecoder {
    pub fn new() -> StreamDecoder {
        StreamDecoder::default()
    }

    /// The current packed block (empty until the first keyframe).
    pub fn block(&self) -> &[f32] {
        &self.state
    }

    pub fn geom(&self) -> Option<BlockGeom> {
        self.geom
    }

    pub fn next_seq(&self) -> u32 {
        self.next_seq
    }

    pub fn is_synced(&self) -> bool {
        self.synced
    }

    /// Apply a keyframe: unconditional resync at any sequence number.
    pub fn apply_key(&mut self, seq: u32, geom: BlockGeom, packed: &[f32])
        -> Result<()> {
        ensure!(valid_block_axis(geom.rows, geom.ks)
                    && valid_block_axis(geom.cols, geom.kd),
                "invalid stream block {}x{} for {}x{}", geom.ks, geom.kd,
                geom.rows, geom.cols);
        // the conjugate-symmetric packing is exactly ks*kd floats
        ensure!(packed.len() == geom.ks * geom.kd,
                "keyframe carries {} floats, geometry wants {}", packed.len(),
                geom.ks * geom.kd);
        self.state.clear();
        self.state.extend_from_slice(packed);
        self.geom = Some(geom);
        self.next_seq = seq.wrapping_add(1);
        self.synced = true;
        Ok(())
    }

    /// Apply a delta.  Hard-fails — and desynchronises, so every
    /// further delta is refused until a keyframe — on a sequence gap,
    /// a geometry change, a missing keyframe, or an out-of-range
    /// index.  State is untouched on failure.
    pub fn apply_delta(&mut self, seq: u32, geom: BlockGeom,
                       updates: &[(u32, f32)]) -> Result<()> {
        if !self.synced {
            bail!("stream not synced: keyframe required");
        }
        if self.geom != Some(geom) {
            self.synced = false;
            bail!("stream geometry changed without a keyframe");
        }
        if seq != self.next_seq {
            self.synced = false;
            bail!("stream gap: got seq {seq}, expected {}", self.next_seq);
        }
        if let Some(&(i, _)) =
            updates.iter().find(|&&(i, _)| i as usize >= self.state.len()) {
            self.synced = false;
            bail!("update index {i} out of range ({} coefficients)",
                  self.state.len());
        }
        for &(i, v) in updates {
            self.state[i as usize] = v;
        }
        self.next_seq = self.next_seq.wrapping_add(1);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// prefill chunks (prompt-phase streaming)
// ---------------------------------------------------------------------------

/// Prefill chunking knobs (device side).
#[derive(Debug, Clone, Copy)]
pub struct PrefillConfig {
    /// Packed-plane rows (`kd` floats each) per chunk.  The wire cost
    /// of a resync is one chunk, not the whole plane, so smaller
    /// chunks recover cheaper but pay more per-chunk header overhead.
    pub chunk_rows: usize,
    /// Max relative spectral drift (mirror-weighted, whole-plane) the
    /// chunked prompt may leave unsent across *all* chunks combined
    /// (0.0 = the reassembled plane is bit-identical to the input).
    pub drift_threshold: f64,
}

impl Default for PrefillConfig {
    fn default() -> PrefillConfig {
        PrefillConfig { chunk_rows: 16, drift_threshold: 0.01 }
    }
}

/// One prompt-phase chunk: a contiguous row range of the packed
/// plane, shipped either raw (keyframe chunk) or as sparse updates
/// against the previous chunk's transmitted rows.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PrefillChunk {
    /// Position in the chunk sequence (0-based; chunk 0 is always a
    /// keyframe chunk and defines the chunk length).
    pub index: u32,
    /// Set on the final chunk of the plane.
    pub last: bool,
    /// Keyframe chunk: `packed` carries the raw row slice.  Otherwise
    /// `updates` carries chunk-local sparse replacements.
    pub keyframe: bool,
    pub packed: Vec<f32>,
    pub updates: Vec<(u32, f32)>,
}

impl PrefillChunk {
    /// Codec-body wire bytes of this chunk (the protocol adds
    /// [`crate::coordinator::protocol::PREFILL_HEADER_BYTES`] on top).
    pub fn body_bytes(&self) -> usize {
        if self.keyframe {
            self.packed.len() * 4
        } else {
            4 + self.updates.len() * UPDATE_WIRE_BYTES
        }
    }
}

/// Split a packed prompt-phase plane into prefill chunks: one
/// keyframe chunk (chunk 0) plus row-delta chunks, each delta'd
/// against the *previous chunk's transmitted rows*.  `state` receives
/// the transmitted plane — exactly what a [`PrefillAssembler`]
/// reassembles, bit for bit — and the return value is the relative
/// spectral drift `state` carries vs `packed`, which stays under
/// `cfg.drift_threshold`: each chunk's unsent-drift budget is the
/// whole-plane threshold prorated by chunk length, so the chunk
/// budgets sum to the advertised bound.  Chunks where the delta would
/// out-weigh raw rows fall back to mid-sequence keyframe chunks.
pub fn split_prefill(eng: &mut CodecEngine, geom: BlockGeom, packed: &[f32],
                     cfg: PrefillConfig, chunks: &mut Vec<PrefillChunk>,
                     state: &mut Vec<f32>) -> Result<f64> {
    ensure!(valid_block_axis(geom.rows, geom.ks)
                && valid_block_axis(geom.cols, geom.kd),
            "invalid prefill block {}x{} for {}x{}", geom.ks, geom.kd,
            geom.rows, geom.cols);
    let n = geom.ks * geom.kd;
    ensure!(packed.len() == n,
            "packed plane {} floats, geometry wants {n}", packed.len());
    ensure!(cfg.chunk_rows >= 1, "prefill chunk_rows must be >= 1");
    let chunk_len = (cfg.chunk_rows * geom.kd).min(n);
    let n_chunks = n.div_ceil(chunk_len);

    let mut weight = Vec::new();
    mirror_weights(eng, geom, &mut weight);
    let e_plane: f64 = packed
        .iter()
        .zip(&weight)
        .map(|(&p, &w)| w as f64 * p as f64 * p as f64)
        .sum();
    let thr = cfg.drift_threshold.max(0.0);

    chunks.clear();
    state.clear();
    state.reserve(n);
    let mut cand: Vec<(f64, u32)> = Vec::new();
    let mut leftover = 0.0f64;
    for ci in 0..n_chunks {
        let lo = ci * chunk_len;
        let hi = (lo + chunk_len).min(n);
        let cur = &packed[lo..hi];
        let mut chunk = PrefillChunk {
            index: ci as u32,
            last: ci + 1 == n_chunks,
            keyframe: ci == 0,
            packed: Vec::new(),
            updates: Vec::new(),
        };
        if ci > 0 {
            // candidate updates vs the previous chunk's *transmitted*
            // rows (every non-final chunk is full-length, so the
            // predictor always covers the current chunk)
            let pred = &state[lo - chunk_len..lo - chunk_len + cur.len()];
            cand.clear();
            let mut drift = 0.0f64;
            for (j, (&c, &s)) in cur.iter().zip(pred).enumerate() {
                if c != s {
                    let d = weight[lo + j] as f64
                        * (c as f64 - s as f64)
                        * (c as f64 - s as f64);
                    drift += d;
                    cand.push((d, j as u32));
                }
            }
            // whole-plane budget prorated by chunk length: the chunk
            // budgets sum to thr^2 * e_plane across the prompt
            let budget = thr * thr * e_plane * cur.len() as f64 / n as f64;
            if drift > budget {
                cand.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
                for &(d, j) in &cand {
                    chunk.updates.push((j, cur[j as usize]));
                    drift -= d;
                    if drift <= budget {
                        break;
                    }
                }
            }
            if chunk.updates.len() * UPDATE_WIRE_BYTES >= cur.len() * 4 {
                // dense fallback: a mid-sequence keyframe chunk
                chunk.keyframe = true;
                chunk.updates.clear();
            } else {
                leftover += drift.max(0.0);
                let base = state.len() - chunk_len;
                for j in 0..cur.len() {
                    let v = state[base + j];
                    state.push(v);
                }
                let snap = state.len() - cur.len();
                for &(j, v) in &chunk.updates {
                    state[snap + j as usize] = v;
                }
            }
        }
        if chunk.keyframe {
            chunk.packed.extend_from_slice(cur);
            state.extend_from_slice(cur);
        }
        chunks.push(chunk);
    }
    Ok(if e_plane > 0.0 { (leftover / e_plane).sqrt() } else { 0.0 })
}

/// Server-side prefill reassembly: applies chunks in order and yields
/// the full packed plane when the last one lands.
///
/// Failure policy mirrors the decode stream's no-silent-drift
/// contract, adapted to a burst of frames the client sends before it
/// reads replies: the *first* violation (sequence gap, geometry
/// change, bad slice length, out-of-range update) hard-fails — the
/// caller turns that into one typed reject — and every further
/// non-restart chunk is swallowed silently, so the straggling tail of
/// an already-doomed burst cannot flood the client with stale errors
/// while it resends.  Only a keyframe chunk at index 0 (a restart)
/// resynchronises.
#[derive(Debug, Default)]
pub struct PrefillAssembler {
    geom: Option<BlockGeom>,
    /// Chunk length in floats, learned from chunk 0's payload.
    chunk_len: usize,
    plane: Vec<f32>,
    next_index: u32,
    active: bool,
    rejected: bool,
}

impl PrefillAssembler {
    pub fn new() -> PrefillAssembler {
        PrefillAssembler::default()
    }

    /// A prefill is mid-assembly (some chunks applied, last not seen).
    pub fn is_active(&self) -> bool {
        self.active
    }

    /// The assembler refused a chunk and is dropping the rest of the
    /// burst until a restart from keyframe chunk 0.
    pub fn is_rejected(&self) -> bool {
        self.rejected
    }

    fn fail(&mut self, msg: String) -> anyhow::Error {
        self.active = false;
        self.rejected = true;
        anyhow::anyhow!(msg)
    }

    /// Apply one chunk.  Returns `Ok(Some(plane))` when the last
    /// chunk completes the plane (assembler returns to idle),
    /// `Ok(None)` mid-assembly or while silently dropping a doomed
    /// burst, and `Err` exactly once per violation.
    pub fn apply(&mut self, geom: BlockGeom, index: u32, last: bool,
                 keyframe: bool, packed: &[f32], updates: &[(u32, f32)])
        -> Result<Option<Vec<f32>>> {
        ensure!(valid_block_axis(geom.rows, geom.ks)
                    && valid_block_axis(geom.cols, geom.kd),
                "invalid prefill block {}x{} for {}x{}", geom.ks, geom.kd,
                geom.rows, geom.cols);
        let n = geom.ks * geom.kd;
        if keyframe && index == 0 {
            // restart: unconditional resync, like a decode keyframe
            self.active = false;
            self.rejected = false;
            if packed.is_empty() || packed.len() > n
                || (packed.len() < n && packed.len() % geom.kd != 0) {
                return Err(self.fail(format!(
                    "prefill chunk 0 carries {} floats; want whole rows of \
                     {} up to {n}", packed.len(), geom.kd)));
            }
            if last && packed.len() != n {
                return Err(self.fail(format!(
                    "single-chunk prefill carries {} floats, plane wants {n}",
                    packed.len())));
            }
            self.geom = Some(geom);
            self.chunk_len = packed.len();
            self.plane.clear();
            self.plane.extend_from_slice(packed);
            self.next_index = 1;
            if last {
                self.chunk_len = 0;
                self.geom = None;
                return Ok(Some(std::mem::take(&mut self.plane)));
            }
            if packed.len() == n {
                return Err(self.fail(
                    "prefill chunk 0 filled the plane without a last flag"
                        .into()));
            }
            self.active = true;
            return Ok(None);
        }
        if self.rejected {
            return Ok(None); // doomed burst: swallow until a restart
        }
        if !self.active {
            return Err(self.fail(format!(
                "prefill chunk {index} without a keyframe chunk 0")));
        }
        if self.geom != Some(geom) {
            return Err(self.fail(
                "prefill geometry changed mid-assembly".into()));
        }
        if index != self.next_index {
            return Err(self.fail(format!(
                "prefill chunk gap: got {index}, expected {}",
                self.next_index)));
        }
        let lo = index as usize * self.chunk_len;
        if lo >= n {
            return Err(self.fail(format!(
                "prefill chunk {index} starts past the plane ({n} floats)")));
        }
        let hi = (lo + self.chunk_len).min(n);
        let cur_len = hi - lo;
        if keyframe {
            if packed.len() != cur_len {
                return Err(self.fail(format!(
                    "prefill keyframe chunk {index} carries {} floats, \
                     want {cur_len}", packed.len())));
            }
            self.plane.extend_from_slice(packed);
        } else {
            if let Some(&(j, _)) =
                updates.iter().find(|&&(j, _)| j as usize >= cur_len) {
                return Err(self.fail(format!(
                    "prefill update index {j} out of range ({cur_len} \
                     floats in chunk {index})")));
            }
            let base = lo - self.chunk_len;
            for j in 0..cur_len {
                let v = self.plane[base + j];
                self.plane.push(v);
            }
            let snap = self.plane.len() - cur_len;
            for &(j, v) in updates {
                self.plane[snap + j as usize] = v;
            }
        }
        self.next_index += 1;
        if last {
            if hi != n {
                return Err(self.fail(format!(
                    "prefill ended at {hi} of {n} floats")));
            }
            self.active = false;
            self.chunk_len = 0;
            self.geom = None;
            return Ok(Some(std::mem::take(&mut self.plane)));
        }
        if hi == n {
            return Err(self.fail(
                "prefill chunks filled the plane without a last flag".into()));
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::fourier::FourierCodec;
    use crate::codec::{rel_error, Codec};
    use crate::util::rng::Rng;

    const GEOM: BlockGeom = BlockGeom { rows: 16, cols: 32, ks: 5, kd: 7 };

    fn rand_packed(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn first_frame_is_keyframe_and_roundtrips() {
        let mut enc = StreamEncoder::new(StreamConfig::default());
        let mut dec = StreamDecoder::new();
        let mut eng = CodecEngine::new();
        let mut out = StreamStep::default();
        let p = rand_packed(35, 1);
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(out.keyframe);
        assert_eq!(out.seq, 0);
        assert_eq!(out.packed, p);
        dec.apply_key(out.seq, GEOM, &out.packed).unwrap();
        assert_eq!(bits(dec.block()), bits(&p));
        assert_eq!(dec.next_seq(), 1);
    }

    #[test]
    fn unchanged_block_yields_empty_delta() {
        let mut enc = StreamEncoder::new(StreamConfig {
            keyframe_interval: 64,
            drift_threshold: 0.05,
        });
        let mut eng = CodecEngine::new();
        let mut out = StreamStep::default();
        let p = rand_packed(35, 2);
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(out.keyframe);
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(!out.keyframe);
        assert!(out.updates.is_empty());
        assert_eq!(out.seq, 1);
        assert_eq!(out.body_bytes(), 4);
    }

    #[test]
    fn threshold_zero_deltas_are_exact() {
        let mut enc = StreamEncoder::new(StreamConfig {
            keyframe_interval: 1024,
            drift_threshold: 0.0,
        });
        let mut dec = StreamDecoder::new();
        let mut eng = CodecEngine::new();
        let mut out = StreamStep::default();
        let mut rng = Rng::new(3);
        let mut p = rand_packed(35, 4);
        for step in 0..20u32 {
            if step > 0 {
                // sparse mutation: two coefficients move per step
                for _ in 0..2 {
                    let i = rng.below(p.len());
                    p[i] = rng.normal() as f32;
                }
            }
            enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
            if out.keyframe {
                dec.apply_key(out.seq, GEOM, &out.packed).unwrap();
            } else {
                assert!(out.updates.len() <= 2, "step {step}");
                dec.apply_delta(out.seq, GEOM, &out.updates).unwrap();
            }
            // zero threshold: decoder state tracks the truth bit for bit
            assert_eq!(bits(dec.block()), bits(&p), "step {step}");
        }
    }

    #[test]
    fn keyframe_interval_forced() {
        let mut enc = StreamEncoder::new(StreamConfig {
            keyframe_interval: 4,
            drift_threshold: 0.05,
        });
        let mut eng = CodecEngine::new();
        let mut out = StreamStep::default();
        let p = rand_packed(35, 5);
        let mut kinds = Vec::new();
        for _ in 0..9 {
            enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
            kinds.push(out.keyframe);
        }
        assert_eq!(kinds, vec![true, false, false, false, true, false, false,
                               false, true]);
    }

    #[test]
    fn geometry_change_forces_keyframe() {
        let mut enc = StreamEncoder::new(StreamConfig {
            keyframe_interval: 64,
            drift_threshold: 0.05,
        });
        let mut eng = CodecEngine::new();
        let mut out = StreamStep::default();
        let p = rand_packed(35, 6);
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(!out.keyframe);
        // bucket promotion: 16 -> 32 rows
        let g2 = BlockGeom { rows: 32, cols: 32, ks: 5, kd: 7 };
        enc.encode_into(&mut eng, g2, &p, &mut out).unwrap();
        assert!(out.keyframe, "geometry change must resync");
        // and returning to the old geometry resyncs again
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(out.keyframe);
    }

    #[test]
    fn dense_change_falls_back_to_keyframe() {
        let mut enc = StreamEncoder::new(StreamConfig {
            keyframe_interval: 64,
            drift_threshold: 0.0,
        });
        let mut eng = CodecEngine::new();
        let mut out = StreamStep::default();
        let p = rand_packed(35, 7);
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        // every coefficient moves: a delta would cost 8 bytes per
        // coefficient vs the keyframe's 4 — must fall back
        let p2 = rand_packed(35, 8);
        enc.encode_into(&mut eng, GEOM, &p2, &mut out).unwrap();
        assert!(out.keyframe);
        assert_eq!(out.packed, p2);
    }

    #[test]
    fn drift_threshold_bounds_reconstruction_error() {
        // Parseval: the mirror-weighted spectral drift equals the
        // relative error between the reconstructions of the stale and
        // the true block — the property that makes drift_threshold a
        // reconstruction-error bound
        let thr = 0.3;
        let mut enc = StreamEncoder::new(StreamConfig {
            keyframe_interval: 1024,
            drift_threshold: thr,
        });
        let mut dec = StreamDecoder::new();
        let mut eng = CodecEngine::new();
        let mut out = StreamStep::default();
        let codec = FourierCodec::default();
        let mut rng = Rng::new(9);
        let mut p = rand_packed(35, 10);
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        dec.apply_key(out.seq, GEOM, &out.packed).unwrap();
        for step in 0..16 {
            for _ in 0..4 {
                let i = rng.below(p.len());
                p[i] += 0.4 * rng.normal() as f32;
            }
            enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
            if out.keyframe {
                // dense-change fallback: exact, so trivially in bound
                dec.apply_key(out.seq, GEOM, &out.packed).unwrap();
            } else {
                dec.apply_delta(out.seq, GEOM, &out.updates).unwrap();
            }
            let want = codec.decompress(&fc_payload(GEOM, &p)).unwrap();
            let got = codec.decompress(&fc_payload(GEOM, dec.block())).unwrap();
            let err = rel_error(&want, &got);
            assert!(err <= thr * 1.01 + 1e-6, "step {step}: drift {err}");
        }
    }

    #[test]
    fn last_drift_bounded_by_threshold_and_zero_on_keyframes() {
        let thr = 0.3;
        let mut enc = StreamEncoder::new(StreamConfig {
            keyframe_interval: 1024,
            drift_threshold: thr,
        });
        let mut eng = CodecEngine::new();
        let mut out = StreamStep::default();
        let mut rng = Rng::new(21);
        let mut p = rand_packed(35, 22);
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(out.keyframe);
        assert_eq!(enc.last_drift(), 0.0, "keyframes leave no drift");
        for step in 0..12 {
            for _ in 0..3 {
                let i = rng.below(p.len());
                p[i] += 0.4 * rng.normal() as f32;
            }
            enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
            if out.keyframe {
                assert_eq!(enc.last_drift(), 0.0, "step {step}");
            } else {
                assert!(enc.last_drift() <= thr + 1e-9,
                        "step {step}: drift {} > threshold", enc.last_drift());
            }
        }
        // a forced keyframe resets the measurement
        enc.force_keyframe();
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(out.keyframe);
        assert_eq!(enc.last_drift(), 0.0);
    }

    #[test]
    fn gap_rejected_until_keyframe() {
        let mut enc = StreamEncoder::new(StreamConfig {
            keyframe_interval: 1024,
            drift_threshold: 0.0,
        });
        let mut dec = StreamDecoder::new();
        let mut eng = CodecEngine::new();
        let mut out = StreamStep::default();
        let mut p = rand_packed(35, 11);
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        dec.apply_key(out.seq, GEOM, &out.packed).unwrap();
        // frame 1 encoded but DROPPED on the wire
        p[3] = 9.0;
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(!out.keyframe);
        // frame 2 arrives: sequence gap -> hard fail, desync
        p[4] = -9.0;
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(dec.apply_delta(out.seq, GEOM, &out.updates).is_err());
        assert!(!dec.is_synced());
        // further deltas refused until a keyframe
        p[5] = 1.5;
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(dec.apply_delta(out.seq, GEOM, &out.updates).is_err());
        // resync: keyframe recovers byte-identical state
        enc.force_keyframe();
        p[6] = 2.5;
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(out.keyframe);
        dec.apply_key(out.seq, GEOM, &out.packed).unwrap();
        assert_eq!(bits(dec.block()), bits(&p));
        // and the stream continues
        p[7] = -2.5;
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(!out.keyframe);
        dec.apply_delta(out.seq, GEOM, &out.updates).unwrap();
        assert_eq!(bits(dec.block()), bits(&p));
    }

    #[test]
    fn decoder_rejects_bad_inputs() {
        let mut dec = StreamDecoder::new();
        // delta before any keyframe
        assert!(dec.apply_delta(0, GEOM, &[]).is_err());
        // keyframe with the wrong float count
        assert!(dec.apply_key(0, GEOM, &[0.0; 7]).is_err());
        // keyframe with invalid geometry (even, non-full axis)
        let bad = BlockGeom { rows: 16, cols: 32, ks: 4, kd: 7 };
        assert!(dec.apply_key(0, bad, &[0.0; 28]).is_err());
        // out-of-range update index desyncs
        dec.apply_key(0, GEOM, &[0.0; 35]).unwrap();
        assert!(dec.apply_delta(1, GEOM, &[(35, 1.0)]).is_err());
        assert!(!dec.is_synced());
    }

    #[test]
    fn mirror_weights_match_packed_energy() {
        // weighted packed energy must equal the full kept-block
        // spectral energy (both coefficient and mirror)
        use crate::codec::{freq_indices, rand_act};
        use crate::dsp::fft2d::fft2_real;
        use crate::tensor::MatView;
        let (g, seed) = (GEOM, 13u64);
        let a = rand_act(g.rows, g.cols, seed);
        let spec = fft2_real(MatView::new(&a, g.rows, g.cols));
        let ui = freq_indices(g.rows, g.ks);
        let vi = freq_indices(g.cols, g.kd);
        let mut full = 0.0f64;
        for &u in &ui {
            for &v in &vi {
                full += spec[u * g.cols + v].norm_sq();
            }
        }
        let mut re = vec![0.0f32; g.ks * g.kd];
        let mut im = vec![0.0f32; g.ks * g.kd];
        for (i, &u) in ui.iter().enumerate() {
            for (j, &v) in vi.iter().enumerate() {
                re[i * g.kd + j] = spec[u * g.cols + v].re as f32;
                im[i * g.kd + j] = spec[u * g.cols + v].im as f32;
            }
        }
        let packed = crate::codec::fourier::pack_block(&re, &im, g.rows,
                                                       g.cols, g.ks, g.kd);
        let mut eng = CodecEngine::new();
        let mut w = Vec::new();
        mirror_weights(&mut eng, g, &mut w);
        assert_eq!(w.len(), packed.len());
        let weighted: f64 = packed
            .iter()
            .zip(&w)
            .map(|(&p, &wt)| wt as f64 * p as f64 * p as f64)
            .sum();
        let rel = (weighted - full).abs() / full.max(1e-30);
        assert!(rel < 1e-5, "weighted {weighted} vs full {full}");
    }

    fn assemble(geom: BlockGeom, chunks: &[PrefillChunk]) -> Vec<f32> {
        let mut asm = PrefillAssembler::new();
        let mut plane = None;
        for c in chunks {
            let got = asm
                .apply(geom, c.index, c.last, c.keyframe, &c.packed,
                       &c.updates)
                .unwrap();
            assert_eq!(got.is_some(), c.last, "chunk {}", c.index);
            plane = got.or(plane);
        }
        plane.expect("last chunk yields the plane")
    }

    #[test]
    fn prefill_zero_threshold_roundtrips_bit_exact() {
        let mut eng = CodecEngine::new();
        let p = rand_packed(35, 30);
        let (mut chunks, mut state) = (Vec::new(), Vec::new());
        let cfg = PrefillConfig { chunk_rows: 2, drift_threshold: 0.0 };
        let drift =
            split_prefill(&mut eng, GEOM, &p, cfg, &mut chunks, &mut state)
                .unwrap();
        assert_eq!(drift, 0.0);
        assert_eq!(bits(&state), bits(&p), "zero threshold is lossless");
        assert!(chunks[0].keyframe && chunks[0].index == 0);
        assert!(chunks.last().unwrap().last);
        assert_eq!(chunks.len(), 35usize.div_ceil(2 * GEOM.kd));
        assert_eq!(bits(&assemble(GEOM, &chunks)), bits(&p));
    }

    #[test]
    fn prefill_band_limited_rows_collapse_to_sparse_deltas() {
        // rows that agree outside a narrow column band: delta chunks
        // carry only the in-band slots, the chunked-prompt win
        let mut eng = CodecEngine::new();
        let g = BlockGeom { rows: 64, cols: 32, ks: 21, kd: 7 };
        let mut rng = Rng::new(31);
        let mut p = vec![0.0f32; g.ks * g.kd];
        for r in 0..g.ks {
            for c in 0..2 {
                p[r * g.kd + c] = rng.normal() as f32; // in-band
            }
            for c in 2..g.kd {
                p[r * g.kd + c] = 1e-7 * rng.normal() as f32; // noise
            }
        }
        let (mut chunks, mut state) = (Vec::new(), Vec::new());
        let cfg = PrefillConfig { chunk_rows: 3, drift_threshold: 0.01 };
        let drift =
            split_prefill(&mut eng, g, &p, cfg, &mut chunks, &mut state)
                .unwrap();
        assert!(drift <= 0.01, "drift {drift}");
        let body: usize = chunks.iter().map(|c| c.body_bytes()).sum();
        assert!(body * 2 <= p.len() * 4,
                "chunked body {body} B vs monolithic {} B", p.len() * 4);
        for c in &chunks[1..] {
            assert!(!c.keyframe, "chunk {} fell back dense", c.index);
            // noise slots stay unsent: only in-band columns update
            assert!(c.updates.len() <= 2 * cfg.chunk_rows, "chunk {}",
                    c.index);
        }
        assert_eq!(bits(&assemble(g, &chunks)), bits(&state));
    }

    #[test]
    fn prefill_drift_bounds_reconstruction_error() {
        let thr = 0.3;
        let codec = FourierCodec::default();
        let mut eng = CodecEngine::new();
        let p = rand_packed(35, 32);
        let (mut chunks, mut state) = (Vec::new(), Vec::new());
        let cfg = PrefillConfig { chunk_rows: 1, drift_threshold: thr };
        let drift =
            split_prefill(&mut eng, GEOM, &p, cfg, &mut chunks, &mut state)
                .unwrap();
        assert!(drift <= thr + 1e-9, "reported drift {drift}");
        let want = codec.decompress(&fc_payload(GEOM, &p)).unwrap();
        let got = codec.decompress(&fc_payload(GEOM, &state)).unwrap();
        let err = rel_error(&want, &got);
        assert!(err <= thr * 1.01 + 1e-6, "cumulative drift {err}");
    }

    #[test]
    fn prefill_assembler_gap_rejects_once_then_swallows_until_restart() {
        let mut eng = CodecEngine::new();
        let p = rand_packed(35, 33);
        let (mut chunks, mut state) = (Vec::new(), Vec::new());
        let cfg = PrefillConfig { chunk_rows: 1, drift_threshold: 0.0 };
        split_prefill(&mut eng, GEOM, &p, cfg, &mut chunks, &mut state)
            .unwrap();
        assert!(chunks.len() >= 4);
        let mut asm = PrefillAssembler::new();
        let c0 = &chunks[0];
        asm.apply(GEOM, 0, c0.last, true, &c0.packed, &c0.updates).unwrap();
        // chunk 1 dropped on the wire; chunk 2 arrives -> gap, one
        // typed failure, then the rest of the burst is swallowed
        let c2 = &chunks[2];
        assert!(asm
            .apply(GEOM, 2, c2.last, c2.keyframe, &c2.packed, &c2.updates)
            .is_err());
        assert!(asm.is_rejected());
        let c3 = &chunks[3];
        assert!(asm
            .apply(GEOM, 3, c3.last, c3.keyframe, &c3.packed, &c3.updates)
            .unwrap()
            .is_none());
        // restart from keyframe chunk 0 recovers bit-exact
        let mut plane = None;
        for c in &chunks {
            plane = asm
                .apply(GEOM, c.index, c.last, c.keyframe, &c.packed,
                       &c.updates)
                .unwrap()
                .or(plane);
        }
        assert_eq!(bits(&plane.unwrap()), bits(&p));
        assert!(!asm.is_active() && !asm.is_rejected());
    }

    #[test]
    fn prefill_assembler_rejects_bad_inputs() {
        let mut asm = PrefillAssembler::new();
        // delta chunk out of nowhere
        assert!(asm.apply(GEOM, 1, false, false, &[], &[]).is_err());
        assert!(asm.is_rejected());
        // chunk 0 with a partial row
        let mut asm = PrefillAssembler::new();
        assert!(asm.apply(GEOM, 0, false, true, &[0.0; 5], &[]).is_err());
        // chunk 0 flagged last but short of the plane
        let mut asm = PrefillAssembler::new();
        assert!(asm.apply(GEOM, 0, true, true, &[0.0; 7], &[]).is_err());
        // full plane in chunk 0 without the last flag
        let mut asm = PrefillAssembler::new();
        assert!(asm.apply(GEOM, 0, false, true, &[0.0; 35], &[]).is_err());
        // out-of-range update index
        let mut asm = PrefillAssembler::new();
        asm.apply(GEOM, 0, false, true, &[0.0; 7], &[]).unwrap();
        assert!(asm
            .apply(GEOM, 1, false, false, &[], &[(7, 1.0)])
            .is_err());
        assert!(asm.is_rejected());
    }

    #[test]
    fn seeded_encoder_continues_a_prefilled_stream() {
        let mut eng = CodecEngine::new();
        let p = rand_packed(35, 34);
        let (mut chunks, mut state) = (Vec::new(), Vec::new());
        let cfg = PrefillConfig { chunk_rows: 2, drift_threshold: 0.0 };
        split_prefill(&mut eng, GEOM, &p, cfg, &mut chunks, &mut state)
            .unwrap();
        // server side: the reassembled plane seeds the decode stream
        let mut dec = StreamDecoder::new();
        dec.apply_key(0, GEOM, &assemble(GEOM, &chunks)).unwrap();
        // client side: seed from the transmitted plane
        let mut enc = StreamEncoder::new(StreamConfig {
            keyframe_interval: 1024,
            drift_threshold: 0.0,
        });
        enc.seed(&mut eng, GEOM, &state).unwrap();
        assert_eq!(enc.next_seq(), 1);
        // decode step 1 rides a delta, no keyframe repayment
        let mut p2 = p.clone();
        p2[3] = 9.0;
        let mut out = StreamStep::default();
        enc.encode_into(&mut eng, GEOM, &p2, &mut out).unwrap();
        assert!(!out.keyframe, "seeded stream must not re-keyframe");
        assert_eq!(out.seq, 1);
        dec.apply_delta(out.seq, GEOM, &out.updates).unwrap();
        assert_eq!(bits(dec.block()), bits(&p2));
    }
}
