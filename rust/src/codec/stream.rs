//! Spectral delta streaming: a session-stateful temporal codec over
//! the FourierCompress block that kills the recompute regime's
//! bandwidth amplification.
//!
//! In the paper's recompute regime (Fig 1/Fig 7) decode step *t*
//! retransmits the full (prompt + *t*)×D activation, so wire bytes per
//! conversation grow quadratically with output length.  But
//! consecutive steps compress *nearly the same matrix*: inside one
//! serving bucket the block geometry is fixed and only the rows from
//! the appended token onward change, so most of the K_S×K_D spectral
//! coefficients drift by little.  This module streams that block
//! temporally, the way atsc streams frames of a time series:
//!
//! * a **keyframe** carries the full conjugate-symmetric packing
//!   (exactly the floats an Activation frame carries) and
//!   unconditionally resynchronises the receiver;
//! * a **delta frame** carries only the coefficients whose last
//!   transmitted value drifted, as `(u32 index, f32 value)` updates
//!   into the packed vector — int-indexed like atsc's
//!   `FrequencyPoint`, 8 wire bytes per coefficient.
//!
//! The [`StreamEncoder`] (device side) keeps the last transmitted
//! packed block per session and picks per step: keyframe when the
//! geometry changed (bucket promotion), every
//! [`StreamConfig::keyframe_interval`] frames, on
//! [`StreamEncoder::force_keyframe`] (resync), or when a delta would
//! cost more wire bytes than the keyframe it replaces; otherwise a
//! delta whose *unsent* drift is bounded by
//! [`StreamConfig::drift_threshold`].  Updates are exact f32
//! replacements, so encoder and decoder state never diverge — with a
//! zero threshold the stream is bit-identical to the recompute regime.
//!
//! ## Drift accounting
//!
//! Drift is measured in the spectral domain with conjugate-mirror
//! weights (a packed re/im pair stands for a coefficient *and* its
//! mirror, so it carries weight 2; a self-conjugate slot weight 1).
//! By Parseval this weighted relative error equals the relative error
//! between the *reconstructions* of the stale and the true block, so
//! `drift_threshold` directly bounds the per-step reconstruction
//! error the stream adds on top of the FC truncation the keyframe
//! regime already has.
//!
//! The [`StreamDecoder`] (server side) reconstructs from per-session
//! state and **hard-fails on sequence gaps**: a lost or reordered
//! delta desynchronises the session until the next keyframe, which
//! recovers byte-identical state (`tests/stream_serving.rs` pins
//! this).  The decoder never guesses — silent drift is the one failure
//! mode a lossy activation link cannot afford.
//!
//! Both frame kinds compose with the lossless entropy layer
//! ([`super::wire`], negotiated via
//! [`crate::coordinator::protocol::caps::ENTROPY`]): a keyframe's
//! packed plane and a delta's sparse update list each have a coded
//! wire form the transport ships when it is smaller than the raw one.
//! The stream codec itself is unaware — coding happens at the frame
//! boundary, on exactly the bytes [`StreamStep::body_bytes`] counts.

use super::engine::CodecEngine;
use super::{valid_block_axis, Payload, Writer};
use anyhow::{bail, ensure, Result};

/// Wire bytes per sparse coefficient update (u32 index + f32 value).
pub const UPDATE_WIRE_BYTES: usize = 8;

/// Block geometry of one stream: the pre-compression matrix shape and
/// the kept centred block.  Any change forces a keyframe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockGeom {
    pub rows: usize,
    pub cols: usize,
    pub ks: usize,
    pub kd: usize,
}

/// Encoder policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct StreamConfig {
    /// Force a keyframe every this many frames (1 = every frame).
    pub keyframe_interval: u32,
    /// Max relative spectral drift a delta frame may leave unsent
    /// (0.0 = deltas replace every changed coefficient exactly).
    pub drift_threshold: f64,
}

impl Default for StreamConfig {
    fn default() -> StreamConfig {
        StreamConfig { keyframe_interval: 32, drift_threshold: 0.05 }
    }
}

/// One encoded stream frame, written into caller-owned buffers so the
/// per-token loop allocates nothing after warm-up (the `packed` /
/// `updates` vectors are moved into the wire frame and recovered, like
/// the client's Activation scratch).
#[derive(Debug, Default)]
pub struct StreamStep {
    pub seq: u32,
    pub keyframe: bool,
    /// Keyframe payload: the full packed block (empty for deltas).
    pub packed: Vec<f32>,
    /// Delta payload: sparse updates (empty for keyframes).
    pub updates: Vec<(u32, f32)>,
}

impl StreamStep {
    /// Codec-body wire bytes of this frame (the protocol adds
    /// [`crate::coordinator::protocol::STREAM_HEADER_BYTES`] on top).
    pub fn body_bytes(&self) -> usize {
        if self.keyframe {
            self.packed.len() * 4
        } else {
            4 + self.updates.len() * UPDATE_WIRE_BYTES
        }
    }
}

/// Conjugate-mirror energy weight per packed float slot, in exactly
/// the order [`super::fourier::pack_block_into`] emits: self-conjugate
/// coefficients contribute their own energy (weight 1), every other
/// packed pair stands for the coefficient and its mirror (weight 2 on
/// both the re and the im slot).
fn mirror_weights(eng: &mut CodecEngine, g: BlockGeom, out: &mut Vec<f32>) {
    let ui = eng.indices(g.rows, g.ks);
    let vi = eng.indices(g.cols, g.kd);
    out.clear();
    out.reserve(g.ks * g.kd);
    for &u in ui.iter() {
        for &v in vi.iter() {
            let (mu, mv) = ((g.rows - u) % g.rows, (g.cols - v) % g.cols);
            if (u, v) > (mu, mv) {
                continue; // mirror carries it
            }
            if (u, v) == (mu, mv) {
                out.push(1.0); // self-conjugate: re only
            } else {
                out.push(2.0); // re
                out.push(2.0); // im
            }
        }
    }
}

/// Assemble the `fc` wire payload for a packed coefficient block, so
/// stream state reconstructs through the ordinary
/// [`super::fourier::FourierCodec`] decompression path (the benches
/// and drift tests use this bridge).
pub fn fc_payload(geom: BlockGeom, packed: &[f32]) -> Payload {
    let mut p = Payload::empty();
    p.reset("fc", geom.rows, geom.cols);
    let mut w = Writer(&mut p.body);
    w.u16(geom.ks as u16);
    w.u16(geom.kd as u16);
    w.f32s(packed);
    p
}

// ---------------------------------------------------------------------------
// encoder (device side)
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct StreamEncoder {
    cfg: StreamConfig,
    geom: Option<BlockGeom>,
    /// Last transmitted packed block — mirrors the decoder exactly.
    state: Vec<f32>,
    weight: Vec<f32>,
    seq: u32,
    since_key: u32,
    force_key: bool,
    /// Relative spectral drift the last encoded frame left unsent
    /// (0.0 after a keyframe) — the measurement the adaptive rate
    /// controller (`codec::rate`) consumes.
    last_drift: f64,
    /// Scratch: (drift energy, index) candidates, largest first.
    cand: Vec<(f64, u32)>,
}

impl StreamEncoder {
    pub fn new(cfg: StreamConfig) -> StreamEncoder {
        StreamEncoder {
            cfg: StreamConfig {
                keyframe_interval: cfg.keyframe_interval.max(1),
                drift_threshold: cfg.drift_threshold.max(0.0),
            },
            geom: None,
            state: Vec::new(),
            weight: Vec::new(),
            seq: 0,
            since_key: 0,
            force_key: false,
            last_drift: 0.0,
            cand: Vec::new(),
        }
    }

    pub fn config(&self) -> StreamConfig {
        self.cfg
    }

    /// The encoder's view of the receiver state (the last transmitted
    /// packed block).
    pub fn state(&self) -> &[f32] {
        &self.state
    }

    pub fn next_seq(&self) -> u32 {
        self.seq
    }

    /// Relative spectral drift (mirror-weighted, i.e. by Parseval a
    /// reconstruction-error delta) the most recent frame left unsent:
    /// bounded by [`StreamConfig::drift_threshold`] for deltas, 0.0
    /// for keyframes.  The adaptive rate controller's second input.
    pub fn last_drift(&self) -> f64 {
        self.last_drift
    }

    /// Make the next frame a keyframe regardless of cadence — the
    /// client calls this when the server reports lost stream state
    /// (TTL eviction, sequence gap) to resynchronise.
    pub fn force_keyframe(&mut self) {
        self.force_key = true;
    }

    /// Encode the current packed block as the next stream frame into
    /// `out` (buffers reused, cleared first).  Exactly one frame is
    /// produced per call and the encoder state advances with it, so
    /// the caller must transmit every encoded frame (or
    /// [`StreamEncoder::force_keyframe`] afterwards).
    pub fn encode_into(&mut self, eng: &mut CodecEngine, geom: BlockGeom,
                       packed: &[f32], out: &mut StreamStep) -> Result<()> {
        ensure!(valid_block_axis(geom.rows, geom.ks)
                    && valid_block_axis(geom.cols, geom.kd),
                "invalid stream block {}x{} for {}x{}", geom.ks, geom.kd,
                geom.rows, geom.cols);
        let geom_changed = self.geom != Some(geom);
        if geom_changed {
            mirror_weights(eng, geom, &mut self.weight);
            self.geom = Some(geom);
        }
        ensure!(packed.len() == self.weight.len(),
                "packed block {} floats, geometry wants {}", packed.len(),
                self.weight.len());

        out.seq = self.seq;
        out.packed.clear();
        out.updates.clear();

        let need_key = self.force_key
            || geom_changed
            || self.state.len() != packed.len()
            || self.since_key + 1 >= self.cfg.keyframe_interval;
        if !need_key {
            // candidate updates: coefficients whose last transmitted
            // value drifted, by mirror-weighted energy
            let e_cur: f64 = packed
                .iter()
                .zip(&self.weight)
                .map(|(&p, &w)| w as f64 * p as f64 * p as f64)
                .sum();
            self.cand.clear();
            let mut drift = 0.0f64;
            for (i, (&p, &s)) in packed.iter().zip(&self.state).enumerate() {
                if p != s {
                    let d = self.weight[i] as f64
                        * (p as f64 - s as f64)
                        * (p as f64 - s as f64);
                    drift += d;
                    self.cand.push((d, i as u32));
                }
            }
            let thr = self.cfg.drift_threshold;
            let target = thr * thr * e_cur;
            if drift > target {
                // largest drift first; index tie-break keeps the wire
                // bytes deterministic
                self.cand
                    .sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
                for &(d, i) in &self.cand {
                    out.updates.push((i, packed[i as usize]));
                    drift -= d;
                    if drift <= target {
                        break;
                    }
                }
            }
            // a dense delta is a false economy: 8 wire bytes per
            // update vs 4 per keyframe float — fall back to a keyframe
            if out.updates.len() * UPDATE_WIRE_BYTES < packed.len() * 4 {
                for &(i, v) in &out.updates {
                    self.state[i as usize] = v;
                }
                self.last_drift = if e_cur > 0.0 {
                    (drift.max(0.0) / e_cur).sqrt()
                } else {
                    0.0
                };
                out.keyframe = false;
                self.since_key += 1;
                self.seq = self.seq.wrapping_add(1);
                return Ok(());
            }
            out.updates.clear();
        }

        self.last_drift = 0.0;
        out.keyframe = true;
        out.packed.extend_from_slice(packed);
        self.state.clear();
        self.state.extend_from_slice(packed);
        self.force_key = false;
        self.since_key = 0;
        self.seq = self.seq.wrapping_add(1);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// decoder (server side)
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
pub struct StreamDecoder {
    geom: Option<BlockGeom>,
    state: Vec<f32>,
    next_seq: u32,
    synced: bool,
}

impl StreamDecoder {
    pub fn new() -> StreamDecoder {
        StreamDecoder::default()
    }

    /// The current packed block (empty until the first keyframe).
    pub fn block(&self) -> &[f32] {
        &self.state
    }

    pub fn geom(&self) -> Option<BlockGeom> {
        self.geom
    }

    pub fn next_seq(&self) -> u32 {
        self.next_seq
    }

    pub fn is_synced(&self) -> bool {
        self.synced
    }

    /// Apply a keyframe: unconditional resync at any sequence number.
    pub fn apply_key(&mut self, seq: u32, geom: BlockGeom, packed: &[f32])
        -> Result<()> {
        ensure!(valid_block_axis(geom.rows, geom.ks)
                    && valid_block_axis(geom.cols, geom.kd),
                "invalid stream block {}x{} for {}x{}", geom.ks, geom.kd,
                geom.rows, geom.cols);
        // the conjugate-symmetric packing is exactly ks*kd floats
        ensure!(packed.len() == geom.ks * geom.kd,
                "keyframe carries {} floats, geometry wants {}", packed.len(),
                geom.ks * geom.kd);
        self.state.clear();
        self.state.extend_from_slice(packed);
        self.geom = Some(geom);
        self.next_seq = seq.wrapping_add(1);
        self.synced = true;
        Ok(())
    }

    /// Apply a delta.  Hard-fails — and desynchronises, so every
    /// further delta is refused until a keyframe — on a sequence gap,
    /// a geometry change, a missing keyframe, or an out-of-range
    /// index.  State is untouched on failure.
    pub fn apply_delta(&mut self, seq: u32, geom: BlockGeom,
                       updates: &[(u32, f32)]) -> Result<()> {
        if !self.synced {
            bail!("stream not synced: keyframe required");
        }
        if self.geom != Some(geom) {
            self.synced = false;
            bail!("stream geometry changed without a keyframe");
        }
        if seq != self.next_seq {
            self.synced = false;
            bail!("stream gap: got seq {seq}, expected {}", self.next_seq);
        }
        if let Some(&(i, _)) =
            updates.iter().find(|&&(i, _)| i as usize >= self.state.len()) {
            self.synced = false;
            bail!("update index {i} out of range ({} coefficients)",
                  self.state.len());
        }
        for &(i, v) in updates {
            self.state[i as usize] = v;
        }
        self.next_seq = self.next_seq.wrapping_add(1);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::fourier::FourierCodec;
    use crate::codec::{rel_error, Codec};
    use crate::util::rng::Rng;

    const GEOM: BlockGeom = BlockGeom { rows: 16, cols: 32, ks: 5, kd: 7 };

    fn rand_packed(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn first_frame_is_keyframe_and_roundtrips() {
        let mut enc = StreamEncoder::new(StreamConfig::default());
        let mut dec = StreamDecoder::new();
        let mut eng = CodecEngine::new();
        let mut out = StreamStep::default();
        let p = rand_packed(35, 1);
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(out.keyframe);
        assert_eq!(out.seq, 0);
        assert_eq!(out.packed, p);
        dec.apply_key(out.seq, GEOM, &out.packed).unwrap();
        assert_eq!(bits(dec.block()), bits(&p));
        assert_eq!(dec.next_seq(), 1);
    }

    #[test]
    fn unchanged_block_yields_empty_delta() {
        let mut enc = StreamEncoder::new(StreamConfig {
            keyframe_interval: 64,
            drift_threshold: 0.05,
        });
        let mut eng = CodecEngine::new();
        let mut out = StreamStep::default();
        let p = rand_packed(35, 2);
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(out.keyframe);
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(!out.keyframe);
        assert!(out.updates.is_empty());
        assert_eq!(out.seq, 1);
        assert_eq!(out.body_bytes(), 4);
    }

    #[test]
    fn threshold_zero_deltas_are_exact() {
        let mut enc = StreamEncoder::new(StreamConfig {
            keyframe_interval: 1024,
            drift_threshold: 0.0,
        });
        let mut dec = StreamDecoder::new();
        let mut eng = CodecEngine::new();
        let mut out = StreamStep::default();
        let mut rng = Rng::new(3);
        let mut p = rand_packed(35, 4);
        for step in 0..20u32 {
            if step > 0 {
                // sparse mutation: two coefficients move per step
                for _ in 0..2 {
                    let i = rng.below(p.len());
                    p[i] = rng.normal() as f32;
                }
            }
            enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
            if out.keyframe {
                dec.apply_key(out.seq, GEOM, &out.packed).unwrap();
            } else {
                assert!(out.updates.len() <= 2, "step {step}");
                dec.apply_delta(out.seq, GEOM, &out.updates).unwrap();
            }
            // zero threshold: decoder state tracks the truth bit for bit
            assert_eq!(bits(dec.block()), bits(&p), "step {step}");
        }
    }

    #[test]
    fn keyframe_interval_forced() {
        let mut enc = StreamEncoder::new(StreamConfig {
            keyframe_interval: 4,
            drift_threshold: 0.05,
        });
        let mut eng = CodecEngine::new();
        let mut out = StreamStep::default();
        let p = rand_packed(35, 5);
        let mut kinds = Vec::new();
        for _ in 0..9 {
            enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
            kinds.push(out.keyframe);
        }
        assert_eq!(kinds, vec![true, false, false, false, true, false, false,
                               false, true]);
    }

    #[test]
    fn geometry_change_forces_keyframe() {
        let mut enc = StreamEncoder::new(StreamConfig {
            keyframe_interval: 64,
            drift_threshold: 0.05,
        });
        let mut eng = CodecEngine::new();
        let mut out = StreamStep::default();
        let p = rand_packed(35, 6);
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(!out.keyframe);
        // bucket promotion: 16 -> 32 rows
        let g2 = BlockGeom { rows: 32, cols: 32, ks: 5, kd: 7 };
        enc.encode_into(&mut eng, g2, &p, &mut out).unwrap();
        assert!(out.keyframe, "geometry change must resync");
        // and returning to the old geometry resyncs again
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(out.keyframe);
    }

    #[test]
    fn dense_change_falls_back_to_keyframe() {
        let mut enc = StreamEncoder::new(StreamConfig {
            keyframe_interval: 64,
            drift_threshold: 0.0,
        });
        let mut eng = CodecEngine::new();
        let mut out = StreamStep::default();
        let p = rand_packed(35, 7);
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        // every coefficient moves: a delta would cost 8 bytes per
        // coefficient vs the keyframe's 4 — must fall back
        let p2 = rand_packed(35, 8);
        enc.encode_into(&mut eng, GEOM, &p2, &mut out).unwrap();
        assert!(out.keyframe);
        assert_eq!(out.packed, p2);
    }

    #[test]
    fn drift_threshold_bounds_reconstruction_error() {
        // Parseval: the mirror-weighted spectral drift equals the
        // relative error between the reconstructions of the stale and
        // the true block — the property that makes drift_threshold a
        // reconstruction-error bound
        let thr = 0.3;
        let mut enc = StreamEncoder::new(StreamConfig {
            keyframe_interval: 1024,
            drift_threshold: thr,
        });
        let mut dec = StreamDecoder::new();
        let mut eng = CodecEngine::new();
        let mut out = StreamStep::default();
        let codec = FourierCodec::default();
        let mut rng = Rng::new(9);
        let mut p = rand_packed(35, 10);
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        dec.apply_key(out.seq, GEOM, &out.packed).unwrap();
        for step in 0..16 {
            for _ in 0..4 {
                let i = rng.below(p.len());
                p[i] += 0.4 * rng.normal() as f32;
            }
            enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
            if out.keyframe {
                // dense-change fallback: exact, so trivially in bound
                dec.apply_key(out.seq, GEOM, &out.packed).unwrap();
            } else {
                dec.apply_delta(out.seq, GEOM, &out.updates).unwrap();
            }
            let want = codec.decompress(&fc_payload(GEOM, &p)).unwrap();
            let got = codec.decompress(&fc_payload(GEOM, dec.block())).unwrap();
            let err = rel_error(&want, &got);
            assert!(err <= thr * 1.01 + 1e-6, "step {step}: drift {err}");
        }
    }

    #[test]
    fn last_drift_bounded_by_threshold_and_zero_on_keyframes() {
        let thr = 0.3;
        let mut enc = StreamEncoder::new(StreamConfig {
            keyframe_interval: 1024,
            drift_threshold: thr,
        });
        let mut eng = CodecEngine::new();
        let mut out = StreamStep::default();
        let mut rng = Rng::new(21);
        let mut p = rand_packed(35, 22);
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(out.keyframe);
        assert_eq!(enc.last_drift(), 0.0, "keyframes leave no drift");
        for step in 0..12 {
            for _ in 0..3 {
                let i = rng.below(p.len());
                p[i] += 0.4 * rng.normal() as f32;
            }
            enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
            if out.keyframe {
                assert_eq!(enc.last_drift(), 0.0, "step {step}");
            } else {
                assert!(enc.last_drift() <= thr + 1e-9,
                        "step {step}: drift {} > threshold", enc.last_drift());
            }
        }
        // a forced keyframe resets the measurement
        enc.force_keyframe();
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(out.keyframe);
        assert_eq!(enc.last_drift(), 0.0);
    }

    #[test]
    fn gap_rejected_until_keyframe() {
        let mut enc = StreamEncoder::new(StreamConfig {
            keyframe_interval: 1024,
            drift_threshold: 0.0,
        });
        let mut dec = StreamDecoder::new();
        let mut eng = CodecEngine::new();
        let mut out = StreamStep::default();
        let mut p = rand_packed(35, 11);
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        dec.apply_key(out.seq, GEOM, &out.packed).unwrap();
        // frame 1 encoded but DROPPED on the wire
        p[3] = 9.0;
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(!out.keyframe);
        // frame 2 arrives: sequence gap -> hard fail, desync
        p[4] = -9.0;
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(dec.apply_delta(out.seq, GEOM, &out.updates).is_err());
        assert!(!dec.is_synced());
        // further deltas refused until a keyframe
        p[5] = 1.5;
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(dec.apply_delta(out.seq, GEOM, &out.updates).is_err());
        // resync: keyframe recovers byte-identical state
        enc.force_keyframe();
        p[6] = 2.5;
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(out.keyframe);
        dec.apply_key(out.seq, GEOM, &out.packed).unwrap();
        assert_eq!(bits(dec.block()), bits(&p));
        // and the stream continues
        p[7] = -2.5;
        enc.encode_into(&mut eng, GEOM, &p, &mut out).unwrap();
        assert!(!out.keyframe);
        dec.apply_delta(out.seq, GEOM, &out.updates).unwrap();
        assert_eq!(bits(dec.block()), bits(&p));
    }

    #[test]
    fn decoder_rejects_bad_inputs() {
        let mut dec = StreamDecoder::new();
        // delta before any keyframe
        assert!(dec.apply_delta(0, GEOM, &[]).is_err());
        // keyframe with the wrong float count
        assert!(dec.apply_key(0, GEOM, &[0.0; 7]).is_err());
        // keyframe with invalid geometry (even, non-full axis)
        let bad = BlockGeom { rows: 16, cols: 32, ks: 4, kd: 7 };
        assert!(dec.apply_key(0, bad, &[0.0; 28]).is_err());
        // out-of-range update index desyncs
        dec.apply_key(0, GEOM, &[0.0; 35]).unwrap();
        assert!(dec.apply_delta(1, GEOM, &[(35, 1.0)]).is_err());
        assert!(!dec.is_synced());
    }

    #[test]
    fn mirror_weights_match_packed_energy() {
        // weighted packed energy must equal the full kept-block
        // spectral energy (both coefficient and mirror)
        use crate::codec::{freq_indices, rand_act};
        use crate::dsp::fft2d::fft2_real;
        use crate::tensor::MatView;
        let (g, seed) = (GEOM, 13u64);
        let a = rand_act(g.rows, g.cols, seed);
        let spec = fft2_real(MatView::new(&a, g.rows, g.cols));
        let ui = freq_indices(g.rows, g.ks);
        let vi = freq_indices(g.cols, g.kd);
        let mut full = 0.0f64;
        for &u in &ui {
            for &v in &vi {
                full += spec[u * g.cols + v].norm_sq();
            }
        }
        let mut re = vec![0.0f32; g.ks * g.kd];
        let mut im = vec![0.0f32; g.ks * g.kd];
        for (i, &u) in ui.iter().enumerate() {
            for (j, &v) in vi.iter().enumerate() {
                re[i * g.kd + j] = spec[u * g.cols + v].re as f32;
                im[i * g.kd + j] = spec[u * g.cols + v].im as f32;
            }
        }
        let packed = crate::codec::fourier::pack_block(&re, &im, g.rows,
                                                       g.cols, g.ks, g.kd);
        let mut eng = CodecEngine::new();
        let mut w = Vec::new();
        mirror_weights(&mut eng, g, &mut w);
        assert_eq!(w.len(), packed.len());
        let weighted: f64 = packed
            .iter()
            .zip(&w)
            .map(|(&p, &wt)| wt as f64 * p as f64 * p as f64)
            .sum();
        let rel = (weighted - full).abs() / full.max(1e-30);
        assert!(rel < 1e-5, "weighted {weighted} vs full {full}");
    }
}
