//! Blockwise INT8 absmax quantization — the quantization arm of the
//! paper's related-work comparison (an ablation here: its ratio is
//! capped near 4×, which is exactly the paper's argument for
//! transform-domain compression at ratios 6-10×).
//!
//! Wire body: u16 block | u32 n | f32 scales[ceil(n/block)] | i8 q[n]

use super::engine::{stage, CodecEngine};
use super::{Codec, Payload, Reader, Writer};
use crate::dsp::simd;
use crate::tensor::MatView;
use anyhow::{ensure, Result};

pub struct Int8Codec {
    pub block: usize,
}

impl Default for Int8Codec {
    fn default() -> Self {
        Int8Codec { block: 64 }
    }
}

impl Codec for Int8Codec {
    fn name(&self) -> &'static str {
        "int8"
    }

    fn compress_into(&self, eng: &mut CodecEngine, a: MatView<'_>,
                     _ratio: f64, out: &mut Payload) -> Result<()> {
        let data = a.as_slice();
        let n = data.len();
        let nb = n.div_ceil(self.block);
        let lv = eng.simd;
        let CodecEngine { floats: scales, bytes, timer, .. } = eng;

        // per-block absmax scales + int8 bodies, staged in the
        // engine's scratch so the wire write below is two bulk moves
        stage!(timer, quant, {
            scales.clear();
            scales.reserve(nb);
            bytes.clear();
            bytes.reserve(n);
            for chunk in data.chunks(self.block) {
                let absmax = simd::absmax(lv, chunk);
                let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
                scales.push(scale);
                // per-block reciprocal hoisted out of the inner loop:
                // one divide per block — scale is never zero, see above
                simd::quantize_i8(lv, chunk, 1.0 / scale, bytes);
            }
        });

        stage!(timer, wire, {
            out.reset("int8", a.rows(), a.cols());
            let mut w = Writer(&mut out.body);
            w.u16(self.block as u16);
            w.u32(n as u32);
            w.f32s(scales);
            w.0.extend_from_slice(bytes);
        });
        Ok(())
    }

    fn decompress_into(&self, eng: &mut CodecEngine, p: &Payload,
                       out: &mut Vec<f32>) -> Result<()> {
        let mut r = Reader::new(&p.body);
        let block = r.u16()? as usize;
        let n = r.u32()? as usize;
        ensure!(n == p.rows * p.cols, "element count mismatch");
        ensure!(block > 0, "zero block");
        let nb = n.div_ceil(block);
        let lv = eng.simd;
        let CodecEngine { floats: scales, timer, .. } = eng;

        // wire: one bulk scale read, one borrow of the int8 body
        let q = stage!(timer, wire, {
            scales.clear();
            r.f32s(nb, scales)?;
            let q = r.take(n)?;
            ensure!(r.remaining() == 0, "trailing payload bytes");
            q
        });

        stage!(timer, quant, {
            out.clear();
            out.reserve(n);
            // scale lookup hoisted per block, kernel per chunk
            for (chunk, &scale) in q.chunks(block).zip(scales.iter()) {
                simd::dequantize_i8(lv, chunk, scale, out);
            }
        });
        Ok(())
    }
}

/// The raw int8 lane of an [`Int8Codec`] payload: parses the wire
/// body and returns the quantized values as `i8` (scales skipped).
/// This is what the entropy layer (`codec::wire::encode_i8_plane`)
/// codes in the related-work ablation benches — the zero-run + sign /
/// magnitude path needs signed values, not the wire's raw bytes.
pub fn i8_plane(p: &Payload) -> Result<Vec<i8>> {
    let mut r = Reader::new(&p.body);
    let block = r.u16()? as usize;
    let n = r.u32()? as usize;
    ensure!(block > 0, "zero block");
    let mut scales = Vec::new();
    r.f32s(n.div_ceil(block), &mut scales)?;
    let q = r.take(n)?;
    ensure!(r.remaining() == 0, "trailing payload bytes");
    Ok(q.iter().map(|&b| b as i8).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{rand_act, rel_error};

    #[test]
    fn quantization_error_small() {
        let a = rand_act(32, 64, 1);
        let c = Int8Codec::default();
        let out = c.roundtrip(&a, 32, 64, 4.0).unwrap();
        assert!(rel_error(&a, &out) < 0.02);
    }

    #[test]
    fn ratio_is_near_four() {
        let a = rand_act(64, 128, 2);
        let p = Int8Codec::default().compress(&a, 64, 128, 4.0).unwrap();
        let r = p.achieved_ratio();
        assert!((3.5..4.1).contains(&r), "ratio {r}");
    }

    #[test]
    fn zeros_survive() {
        let a = vec![0.0f32; 128];
        let c = Int8Codec::default();
        let out = c.roundtrip(&a, 8, 16, 4.0).unwrap();
        assert_eq!(out, a);
    }

    #[test]
    fn outlier_block_isolated() {
        // an outlier in one block must not degrade other blocks
        let mut a = vec![0.01f32; 128];
        a[0] = 100.0;
        let c = Int8Codec { block: 64 };
        let out = c.roundtrip(&a, 8, 16, 4.0).unwrap();
        // second block (indices 64..) is outlier-free and near-exact
        for i in 64..128 {
            assert!((out[i] - 0.01).abs() < 1e-4);
        }
    }

    #[test]
    fn i8_plane_matches_dequant_sign() {
        let a = rand_act(8, 16, 7);
        let p = Int8Codec::default().compress(&a, 8, 16, 4.0).unwrap();
        let q = i8_plane(&p).unwrap();
        assert_eq!(q.len(), 128);
        // quantization preserves sign (absmax scaling, zero maps to 0)
        for (x, &v) in a.iter().zip(&q) {
            if v != 0 {
                assert_eq!(x.is_sign_negative(), v < 0, "{x} vs {v}");
            }
        }
    }

    #[test]
    fn non_multiple_length() {
        let a = rand_act(5, 13, 3); // 65 elements, block 64
        let c = Int8Codec::default();
        let out = c.roundtrip(&a, 5, 13, 4.0).unwrap();
        assert_eq!(out.len(), 65);
        assert!(rel_error(&a, &out) < 0.02);
    }
}
