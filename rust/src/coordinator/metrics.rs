//! Serving metrics: counters + latency histograms, dumped as JSON via
//! the Stats frame and at shutdown.

use crate::util::hist::Histogram;
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub tokens: AtomicU64,
    pub batches: AtomicU64,
    pub batch_size_sum: AtomicU64,
    pub bytes_rx: AtomicU64,
    pub bytes_tx: AtomicU64,
    /// Spectral stream split: keyframe vs delta frames and their wire
    /// bytes (both also counted in `bytes_rx`), plus rejected frames
    /// (sequence gap / evicted state → client keyframe resync).
    pub key_frames: AtomicU64,
    pub delta_frames: AtomicU64,
    pub key_bytes_rx: AtomicU64,
    pub delta_bytes_rx: AtomicU64,
    pub stream_rejects: AtomicU64,
    /// Handshake split: `Hello` frames seen, and how many were
    /// rejected for a bad magic or protocol version (typed
    /// version-mismatch rejects, the v2 negotiation's failure lane).
    pub hellos: AtomicU64,
    pub proto_rejects: AtomicU64,
    /// Adaptive rate control (`codec::rate`): ladder-point switches
    /// observed across sessions, and the dwell — in *frames*, via the
    /// histogram's unit-generic core — sessions spent at a point
    /// before switching away.
    pub ladder_switches: AtomicU64,
    /// Poll-loop lifecycle: connections registered with the shared
    /// poll workers, connections retired (peer closed / errored /
    /// Bye), and connections cut by the per-connection idle deadline
    /// (a hung peer must never park a poll worker — it gets dropped
    /// here instead).
    pub conns_opened: AtomicU64,
    pub conns_closed: AtomicU64,
    pub idle_disconnects: AtomicU64,
    /// Entropy-coded wire layer (`codec::wire`): data frames that
    /// arrived entropy-coded, the wire bytes saved versus the raw
    /// packed encoding of the same payloads, and frames a capable
    /// client sent raw because coding would not have shrunk them
    /// (try-and-compare fallback, observed server-side).
    pub entropy_frames: AtomicU64,
    pub entropy_bytes_saved: AtomicU64,
    pub entropy_fallbacks: AtomicU64,
    /// Chunked prefill (`codec::stream` prefill mode): prompt-phase
    /// chunk frames seen, how many were keyframe chunks (chunk 0 or a
    /// mid-sequence dense fallback), their wire bytes (also counted in
    /// `bytes_rx`), chunks rejected (sequence gap / bad geometry →
    /// client restarts from chunk 0), and prompts fully reassembled.
    pub prefill_chunks: AtomicU64,
    pub prefill_key_chunks: AtomicU64,
    pub prefill_bytes_rx: AtomicU64,
    pub prefill_rejects: AtomicU64,
    pub prefill_prompts: AtomicU64,
    pub ladder_dwell_frames: Histogram,
    pub queue_wait_us: Histogram,
    pub decompress_us: Histogram,
    pub exec_us: Histogram,
    pub e2e_us: Histogram,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batch_size_sum.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        let g = |a: &AtomicU64| Json::Num(a.load(Ordering::Relaxed) as f64);
        j.set("requests", g(&self.requests));
        j.set("tokens", g(&self.tokens));
        j.set("batches", g(&self.batches));
        j.set("mean_batch_size", Json::Num(self.mean_batch_size()));
        j.set("bytes_rx", g(&self.bytes_rx));
        j.set("bytes_tx", g(&self.bytes_tx));
        j.set("key_frames", g(&self.key_frames));
        j.set("delta_frames", g(&self.delta_frames));
        j.set("key_bytes_rx", g(&self.key_bytes_rx));
        j.set("delta_bytes_rx", g(&self.delta_bytes_rx));
        j.set("stream_rejects", g(&self.stream_rejects));
        j.set("hellos", g(&self.hellos));
        j.set("proto_rejects", g(&self.proto_rejects));
        j.set("ladder_switches", g(&self.ladder_switches));
        j.set("conns_opened", g(&self.conns_opened));
        j.set("conns_closed", g(&self.conns_closed));
        j.set("idle_disconnects", g(&self.idle_disconnects));
        j.set("entropy_frames", g(&self.entropy_frames));
        j.set("entropy_bytes_saved", g(&self.entropy_bytes_saved));
        j.set("entropy_fallbacks", g(&self.entropy_fallbacks));
        j.set("prefill_chunks", g(&self.prefill_chunks));
        j.set("prefill_key_chunks", g(&self.prefill_key_chunks));
        j.set("prefill_bytes_rx", g(&self.prefill_bytes_rx));
        j.set("prefill_rejects", g(&self.prefill_rejects));
        j.set("prefill_prompts", g(&self.prefill_prompts));
        for (name, h) in [("queue_wait_us", &self.queue_wait_us),
                          ("decompress_us", &self.decompress_us),
                          ("exec_us", &self.exec_us),
                          ("e2e_us", &self.e2e_us),
                          ("ladder_dwell_frames", &self.ladder_dwell_frames)] {
            let mut hj = Json::obj();
            hj.set("count", Json::Num(h.count() as f64));
            hj.set("mean", Json::Num(h.mean()));
            hj.set("p50", Json::Num(h.percentile(50.0) as f64));
            hj.set("p95", Json::Num(h.percentile(95.0) as f64));
            hj.set("p99", Json::Num(h.percentile(99.0) as f64));
            hj.set("max", Json::Num(h.max() as f64));
            j.set(name, hj);
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_shape() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.batches.fetch_add(2, Ordering::Relaxed);
        m.batch_size_sum.fetch_add(5, Ordering::Relaxed);
        m.e2e_us.record_us(1000);
        m.key_frames.fetch_add(1, Ordering::Relaxed);
        m.delta_bytes_rx.fetch_add(64, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.usize_or("requests", 0), 3);
        assert!((j.f64_or("mean_batch_size", 0.0) - 2.5).abs() < 1e-9);
        assert_eq!(j.path("e2e_us.count").unwrap().as_usize(), Some(1));
        assert_eq!(j.usize_or("key_frames", 0), 1);
        assert_eq!(j.usize_or("delta_bytes_rx", 0), 64);
        assert_eq!(j.usize_or("stream_rejects", 9), 0);
        m.hellos.fetch_add(2, Ordering::Relaxed);
        m.proto_rejects.fetch_add(1, Ordering::Relaxed);
        m.ladder_switches.fetch_add(3, Ordering::Relaxed);
        m.ladder_dwell_frames.record(12);
        m.conns_opened.fetch_add(4, Ordering::Relaxed);
        m.conns_closed.fetch_add(3, Ordering::Relaxed);
        m.idle_disconnects.fetch_add(1, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.usize_or("conns_opened", 0), 4);
        assert_eq!(j.usize_or("conns_closed", 0), 3);
        assert_eq!(j.usize_or("idle_disconnects", 0), 1);
        assert_eq!(j.usize_or("hellos", 0), 2);
        assert_eq!(j.usize_or("proto_rejects", 0), 1);
        assert_eq!(j.usize_or("ladder_switches", 0), 3);
        assert_eq!(j.path("ladder_dwell_frames.count").unwrap().as_usize(),
                   Some(1));
        m.entropy_frames.fetch_add(7, Ordering::Relaxed);
        m.entropy_bytes_saved.fetch_add(512, Ordering::Relaxed);
        m.entropy_fallbacks.fetch_add(1, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.usize_or("entropy_frames", 0), 7);
        assert_eq!(j.usize_or("entropy_bytes_saved", 0), 512);
        assert_eq!(j.usize_or("entropy_fallbacks", 0), 1);
        m.prefill_chunks.fetch_add(6, Ordering::Relaxed);
        m.prefill_key_chunks.fetch_add(2, Ordering::Relaxed);
        m.prefill_bytes_rx.fetch_add(2048, Ordering::Relaxed);
        m.prefill_rejects.fetch_add(1, Ordering::Relaxed);
        m.prefill_prompts.fetch_add(1, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.usize_or("prefill_chunks", 0), 6);
        assert_eq!(j.usize_or("prefill_key_chunks", 0), 2);
        assert_eq!(j.usize_or("prefill_bytes_rx", 0), 2048);
        assert_eq!(j.usize_or("prefill_rejects", 0), 1);
        assert_eq!(j.usize_or("prefill_prompts", 0), 1);
    }
}
