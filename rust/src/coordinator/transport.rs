//! Transport abstraction: a framed, ordered, bidirectional link
//! between a device client and the serving core.
//!
//! The serving stack never touches sockets directly — it speaks
//! [`Frame`]s through a [`Transport`], which splits into a sending
//! ([`FrameTx`]) and a receiving ([`FrameRx`]) half so the server's
//! writer thread and reader loop (and the client's send/await pair)
//! can live on different threads.  Three implementations:
//!
//! * [`TcpTransport`] — the production medium: length-prefixed frames
//!   over a `TcpStream` (nodelay, buffered halves).
//! * [`InProcTransport`] — an mpsc-backed pair with **zero sockets**:
//!   hermetic tests, the sim's live probe, and benches drive the real
//!   serving core through it.  Frames still cross the link as encoded
//!   bytes, so the full encode/decode path is exercised and byte
//!   accounting matches TCP exactly.
//! * [`ShapedTransport`] — a decorator composing any inner transport
//!   with [`Channel`] bandwidth/latency shaping and deterministic
//!   frame-drop injection ([`DropPlan`]) for stream-resync testing.
//!
//! Contract every impl must honour: frames arrive **in send order**,
//! exactly once per direction (unless a shaping decorator explicitly
//! drops them), and `recv` returns `Err` on a closed peer — there is
//! no silent truncation and no reordering.  Every rx half also
//! offers the non-blocking [`FrameRx::try_recv`] readiness hook the
//! server's poll loop is built on: `Ok(None)` when no complete frame
//! is buffered (partial frames accumulate invisibly), with the same
//! order/exactly-once guarantees as `recv`.

use super::protocol::{Frame, FRAME_OVERHEAD_BYTES, MAX_FRAME};
use crate::net::{Channel, ChannelTrace, DropPlan};
use anyhow::{anyhow, bail, Result};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Sending half of a framed link.
pub trait FrameTx: Send {
    /// Write one already-encoded frame (the full wire image:
    /// length-prefix + type + body); returns its length.  Impls and
    /// decorators work at this level so a frame is serialised exactly
    /// once per send, however deep the decorator stack.
    fn send_encoded(&mut self, bytes: &[u8]) -> Result<usize>;

    /// Encode + write one frame; returns the wire bytes it occupied,
    /// which the byte accounting on both sides records.
    fn send(&mut self, frame: &Frame) -> Result<usize> {
        self.send_encoded(&frame.encode())
    }
}

/// Receiving half of a framed link.
///
/// Two receive disciplines share one half:
///
/// * [`FrameRx::recv`] blocks until a frame arrives and returns `Err`
///   once the peer is gone — the device client's await-the-token
///   path, bounded (60 s) so a hung peer surfaces as an error.
/// * [`FrameRx::try_recv`] is the **readiness hook** the server's
///   poll loop runs on: it never blocks — `Ok(Some)` hands back one
///   complete frame, `Ok(None)` means no complete frame is buffered
///   right now (a half-written frame stays buffered until its bytes
///   arrive), and `Err` means the peer disconnected or broke framing.
///   One rx half must not interleave both disciplines concurrently,
///   but may switch between them (the TCP impl flips the socket's
///   blocking mode lazily).
pub trait FrameRx: Send {
    fn recv(&mut self) -> Result<Frame>;

    /// Non-blocking receive: `Ok(Some(frame))` if a complete frame
    /// was ready, `Ok(None)` if not, `Err` on disconnect/protocol
    /// breakage.
    fn try_recv(&mut self) -> Result<Option<Frame>>;
}

/// A framed, ordered, bidirectional byte link.
pub trait Transport: Send {
    /// Consume the transport into its two directional halves.
    fn split(self: Box<Self>) -> Result<(Box<dyn FrameTx>, Box<dyn FrameRx>)>;
    /// Human-readable peer label for logs.
    fn peer(&self) -> String;
}

// ---------------------------------------------------------------------------
// TCP
// ---------------------------------------------------------------------------

/// Length-prefixed frames over a `TcpStream` — the current production
/// medium, now one impl among equals.
pub struct TcpTransport {
    stream: TcpStream,
}

impl TcpTransport {
    /// Client side: connect with nodelay and a 60 s read timeout (a
    /// hung server must surface as an error, not a wedged device).
    pub fn connect(addr: impl ToSocketAddrs) -> Result<TcpTransport> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(60)))?;
        Ok(TcpTransport { stream })
    }

    /// Server side: adopt an accepted connection.
    pub fn from_stream(stream: TcpStream) -> Result<TcpTransport> {
        stream.set_nodelay(true)?;
        Ok(TcpTransport { stream })
    }
}

impl Transport for TcpTransport {
    fn split(self: Box<Self>) -> Result<(Box<dyn FrameTx>, Box<dyn FrameRx>)> {
        let TcpTransport { stream } = *self;
        let reader = stream.try_clone()?;
        Ok((Box::new(TcpTx { stream }),
            Box::new(TcpRx { stream: reader, buf: Vec::new(), pos: 0,
                             nonblocking: false })))
    }

    fn peer(&self) -> String {
        self.stream
            .peer_addr()
            .map(|a| format!("tcp:{a}"))
            .unwrap_or_else(|_| "tcp:?".into())
    }
}

/// How long a TCP send keeps retrying against a back-pressured
/// socket before declaring the peer hung — the write-direction twin
/// of the 60 s read timeout.
const TCP_SEND_BOUND: Duration = Duration::from_secs(60);

struct TcpTx {
    stream: TcpStream,
}

impl FrameTx for TcpTx {
    fn send_encoded(&mut self, bytes: &[u8]) -> Result<usize> {
        // the tx half shares its file description (and so its
        // blocking flag) with the rx half: when the poll loop has the
        // socket in non-blocking mode a full send buffer surfaces as
        // WouldBlock here, so writes retry with a short sleep instead
        // of assuming blocking semantics — bounded, because a peer
        // that stops reading must become an error, not a wedged
        // worker
        let t0 = Instant::now();
        let mut off = 0usize;
        while off < bytes.len() {
            match self.stream.write(&bytes[off..]) {
                Ok(0) => bail!("tcp send: peer closed"),
                Ok(n) => off += n,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if t0.elapsed() > TCP_SEND_BOUND {
                        bail!("tcp send: peer stalled for {}s",
                              TCP_SEND_BOUND.as_secs());
                    }
                    std::thread::sleep(Duration::from_micros(100));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(bytes.len())
    }
}

struct TcpRx {
    stream: TcpStream,
    /// Accumulated wire bytes not yet parsed into frames; `pos` is
    /// the consumed prefix.  A half-written frame simply stays here
    /// across `try_recv` calls until the rest of its bytes arrive —
    /// frame boundaries never depend on read-call boundaries.
    buf: Vec<u8>,
    pos: usize,
    nonblocking: bool,
}

impl TcpRx {
    fn set_mode(&mut self, nonblocking: bool) -> Result<()> {
        if self.nonblocking != nonblocking {
            self.stream.set_nonblocking(nonblocking)?;
            self.nonblocking = nonblocking;
        }
        Ok(())
    }

    /// Parse one complete frame out of the buffer, if present.
    fn parse_frame(&mut self) -> Result<Option<Frame>> {
        let avail = self.buf.len() - self.pos;
        if avail < FRAME_OVERHEAD_BYTES {
            return Ok(None);
        }
        let b = &self.buf[self.pos..];
        let len = u32::from_le_bytes([b[0], b[1], b[2], b[3]]) as usize;
        if len > MAX_FRAME {
            bail!("frame too large: {len}");
        }
        let total = FRAME_OVERHEAD_BYTES + len;
        if avail < total {
            return Ok(None);
        }
        let mut cur =
            std::io::Cursor::new(&self.buf[self.pos..self.pos + total]);
        let frame = Frame::read_from(&mut cur)?;
        self.pos += total;
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos > (1 << 16) {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        Ok(Some(frame))
    }

    /// One read into the buffer; `Ok(0)` is the peer closing.
    fn fill(&mut self) -> std::io::Result<usize> {
        let mut chunk = [0u8; 16 * 1024];
        let n = self.stream.read(&mut chunk)?;
        if n > 0 {
            self.buf.extend_from_slice(&chunk[..n]);
        }
        Ok(n)
    }
}

impl FrameRx for TcpRx {
    fn recv(&mut self) -> Result<Frame> {
        self.set_mode(false)?;
        loop {
            if let Some(f) = self.parse_frame()? {
                return Ok(f);
            }
            match self.fill() {
                Ok(0) => bail!("tcp recv: peer closed"),
                Ok(_) => {}
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                // a configured read timeout (the client's 60 s
                // hung-peer bound) surfaces here as WouldBlock or
                // TimedOut — both are errors, like before
                Err(e) => return Err(e.into()),
            }
        }
    }

    fn try_recv(&mut self) -> Result<Option<Frame>> {
        self.set_mode(true)?;
        loop {
            if let Some(f) = self.parse_frame()? {
                return Ok(Some(f));
            }
            match self.fill() {
                Ok(0) => bail!("tcp recv: peer closed"),
                Ok(_) => {}
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    return Ok(None);
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// in-process
// ---------------------------------------------------------------------------

/// An mpsc-backed transport pair: no sockets, no OS at all, but
/// frames still cross the link as encoded byte vectors so both ends
/// run the exact wire encode/decode path (including [`Frame`]'s
/// size/alignment checks) and per-frame byte counts are identical to
/// TCP.
pub struct InProcTransport {
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<Vec<u8>>,
    label: &'static str,
}

impl InProcTransport {
    /// A connected (device, server) pair.
    pub fn pair() -> (InProcTransport, InProcTransport) {
        let (c2s_tx, c2s_rx) = mpsc::channel();
        let (s2c_tx, s2c_rx) = mpsc::channel();
        (InProcTransport { tx: c2s_tx, rx: s2c_rx, label: "inproc:device" },
         InProcTransport { tx: s2c_tx, rx: c2s_rx, label: "inproc:server" })
    }
}

impl Transport for InProcTransport {
    fn split(self: Box<Self>) -> Result<(Box<dyn FrameTx>, Box<dyn FrameRx>)> {
        let InProcTransport { tx, rx, .. } = *self;
        Ok((Box::new(InProcTx { tx }), Box::new(InProcRx { rx })))
    }

    fn peer(&self) -> String {
        self.label.to_string()
    }
}

struct InProcTx {
    tx: mpsc::Sender<Vec<u8>>,
}

impl FrameTx for InProcTx {
    fn send_encoded(&mut self, bytes: &[u8]) -> Result<usize> {
        let n = bytes.len();
        self.tx
            .send(bytes.to_vec())
            .map_err(|_| anyhow!("in-proc peer disconnected"))?;
        Ok(n)
    }

    // direct (undecorated) sends move the encoded vector instead of
    // copying it through the slice-level path
    fn send(&mut self, frame: &Frame) -> Result<usize> {
        let bytes = frame.encode();
        let n = bytes.len();
        self.tx
            .send(bytes)
            .map_err(|_| anyhow!("in-proc peer disconnected"))?;
        Ok(n)
    }
}

struct InProcRx {
    rx: mpsc::Receiver<Vec<u8>>,
}

impl FrameRx for InProcRx {
    fn recv(&mut self) -> Result<Frame> {
        // same hung-peer bound as TcpTransport::connect's read
        // timeout: a wedged service must turn into a test failure,
        // not a CI job that hangs until the job-level timeout.  Only
        // the *client's* await path blocks here — the server's poll
        // loop runs exclusively on `try_recv`, so one hung peer can
        // never park a shared poll worker for these 60 s (the
        // per-connection idle deadline reaps it instead).
        let bytes = self
            .rx
            .recv_timeout(Duration::from_secs(60))
            .map_err(|e| anyhow!("in-proc recv: {e}"))?;
        let mut cur = std::io::Cursor::new(bytes);
        Frame::read_from(&mut cur)
    }

    fn try_recv(&mut self) -> Result<Option<Frame>> {
        match self.rx.try_recv() {
            Ok(bytes) => {
                let mut cur = std::io::Cursor::new(bytes);
                Frame::read_from(&mut cur).map(Some)
            }
            Err(mpsc::TryRecvError::Empty) => Ok(None),
            Err(mpsc::TryRecvError::Disconnected) => {
                Err(anyhow!("in-proc recv: peer disconnected"))
            }
        }
    }
}

// ---------------------------------------------------------------------------
// shaped decorator
// ---------------------------------------------------------------------------

/// Decorator composing any inner transport with [`Channel`] shaping
/// (uplink serialisation + propagation sleeps on every send) and a
/// deterministic [`DropPlan`] that silently discards selected frames
/// by send index — the lever the stream-resync tests pull to lose a
/// delta "on the wire" without a lossy network.
///
/// Only the send direction is shaped/dropped: the device uplink is
/// the bottleneck the paper models, and dropping server replies would
/// test the client's timeout, not the stream protocol.
pub struct ShapedTransport {
    inner: Box<dyn Transport>,
    channel: Channel,
    /// Time-varying override: when set, each send crosses the channel
    /// the trace assigns to its 0-based send index (the fluctuating
    /// links the adaptive rate-control suite emulates).
    trace: Option<ChannelTrace>,
    drop: DropPlan,
}

impl ShapedTransport {
    pub fn new(inner: Box<dyn Transport>, channel: Channel, drop: DropPlan)
        -> ShapedTransport {
        ShapedTransport { inner, channel, trace: None, drop }
    }

    /// A shaped transport whose per-send channel follows a
    /// deterministic [`ChannelTrace`] instead of one fixed channel.
    pub fn with_trace(inner: Box<dyn Transport>, trace: ChannelTrace,
                      drop: DropPlan) -> ShapedTransport {
        ShapedTransport {
            inner,
            channel: Channel::unlimited(),
            trace: Some(trace),
            drop,
        }
    }
}

impl Transport for ShapedTransport {
    fn split(self: Box<Self>) -> Result<(Box<dyn FrameTx>, Box<dyn FrameRx>)> {
        let ShapedTransport { inner, channel, trace, drop } = *self;
        let peer = inner.peer();
        let (tx, rx) = inner.split()?;
        Ok((Box::new(ShapedTx { inner: tx, channel, trace, drop, peer }), rx))
    }

    fn peer(&self) -> String {
        format!("shaped({})", self.inner.peer())
    }
}

struct ShapedTx {
    inner: Box<dyn FrameTx>,
    channel: Channel,
    trace: Option<ChannelTrace>,
    drop: DropPlan,
    peer: String,
}

impl FrameTx for ShapedTx {
    fn send_encoded(&mut self, bytes: &[u8]) -> Result<usize> {
        let n = bytes.len();
        let channel = match self.trace.as_mut() {
            Some(t) => t.next_channel(),
            None => self.channel,
        };
        if self.drop.should_drop() {
            // the frame is lost after crossing the link: it still
            // costs the sender its transfer time and byte budget
            channel.throttle(n);
            crate::debug!("transport", "{}: dropped frame type {} ({n} B)",
                          self.peer, bytes.get(4).copied().unwrap_or(0xFF));
            return Ok(n);
        }
        // sleep the emulated transfer time BEFORE the peer can see
        // the frame — the server must not start computing while the
        // bytes are still "on the wire" (no-op on unshaped channels)
        channel.throttle(n);
        self.inner.send_encoded(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{caps, ErrorCode};

    fn sample_frames() -> Vec<Frame> {
        vec![
            Frame::hello(7, caps::STREAM | caps::CODEC_FC, "llamette-m"),
            Frame::Activation {
                session: 1, request: 2, bucket: 16, true_len: 9, ks: 3, kd: 3,
                point: 0, packed: vec![0.5; 9],
                coded: vec![],
            },
            Frame::Token { request: 2, token: 65, logprob: -0.5 },
            Frame::Error { code: ErrorCode::StreamReject, msg: "gap".into() },
            Frame::Bye,
        ]
    }

    #[test]
    fn inproc_roundtrips_frames_in_order() {
        let (device, server) = InProcTransport::pair();
        let (mut dtx, mut drx) = Box::new(device).split().unwrap();
        let (mut stx, mut srx) = Box::new(server).split().unwrap();
        for f in sample_frames() {
            let n = dtx.send(&f).unwrap();
            assert_eq!(n, f.encode().len(), "reported wire bytes");
            assert_eq!(srx.recv().unwrap(), f);
        }
        // and the reverse direction
        let tok = Frame::Token { request: 9, token: 1, logprob: 0.0 };
        stx.send(&tok).unwrap();
        assert_eq!(drx.recv().unwrap(), tok);
    }

    #[test]
    fn inproc_disconnect_is_error_not_hang() {
        let (device, server) = InProcTransport::pair();
        let (dtx, drx) = Box::new(device).split().unwrap();
        drop(dtx);
        drop(drx);
        let (mut stx, mut srx) = Box::new(server).split().unwrap();
        assert!(stx.send(&Frame::Bye).is_err());
        assert!(srx.recv().is_err());
    }

    #[test]
    fn tcp_transport_roundtrips_over_loopback() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let echo = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let t = TcpTransport::from_stream(stream).unwrap();
            let (mut tx, mut rx) = (Box::new(t) as Box<dyn Transport>)
                .split().unwrap();
            loop {
                match rx.recv() {
                    Ok(Frame::Bye) | Err(_) => break,
                    Ok(f) => { tx.send(&f).unwrap(); }
                }
            }
        });
        let t = TcpTransport::connect(addr).unwrap();
        assert!(t.peer().starts_with("tcp:"));
        let (mut tx, mut rx) = (Box::new(t) as Box<dyn Transport>)
            .split().unwrap();
        for f in sample_frames() {
            if matches!(f, Frame::Bye) {
                continue;
            }
            tx.send(&f).unwrap();
            assert_eq!(rx.recv().unwrap(), f, "echo mismatch");
        }
        tx.send(&Frame::Bye).unwrap();
        echo.join().unwrap();
    }

    #[test]
    fn inproc_try_recv_is_nonblocking_and_ordered() {
        let (device, server) = InProcTransport::pair();
        let (mut dtx, _drx) = Box::new(device).split().unwrap();
        let (_stx, mut srx) = Box::new(server).split().unwrap();
        // nothing sent yet: readiness reports None, never blocks
        assert!(srx.try_recv().unwrap().is_none());
        let frames = sample_frames();
        for f in &frames {
            dtx.send(f).unwrap();
        }
        for f in &frames {
            assert_eq!(srx.try_recv().unwrap().as_ref(), Some(f));
        }
        assert!(srx.try_recv().unwrap().is_none());
        // peer gone: readiness turns into an error, like recv
        drop(dtx);
        assert!(srx.try_recv().is_err());
    }

    #[test]
    fn tcp_try_recv_reassembles_half_written_frames() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut stream = std::net::TcpStream::connect(addr).unwrap();
            stream.set_nodelay(true).unwrap();
            let frames = sample_frames();
            let wire: Vec<u8> =
                frames.iter().flat_map(|f| f.encode()).collect();
            // dribble the byte stream in 3-byte slivers so every
            // frame crosses the link half-written at least once
            for chunk in wire.chunks(3) {
                stream.write_all(chunk).unwrap();
                stream.flush().unwrap();
                std::thread::sleep(Duration::from_micros(200));
            }
            // leave a dangling half frame, then disconnect
            let tail = Frame::GetStats.encode();
            stream.write_all(&tail[..2]).unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let t = TcpTransport::from_stream(stream).unwrap();
        let (_tx, mut rx) = (Box::new(t) as Box<dyn Transport>)
            .split().unwrap();
        let mut got = Vec::new();
        loop {
            match rx.try_recv() {
                Ok(Some(f)) => got.push(f),
                // no complete frame buffered: poll again — exactly
                // what the serve loop does between visits
                Ok(None) => std::thread::sleep(Duration::from_micros(100)),
                Err(_) => break, // disconnect with a dangling half frame
            }
        }
        assert_eq!(got, sample_frames(),
                   "slivered frames must reassemble in order");
        writer.join().unwrap();
    }

    #[test]
    fn tcp_rx_switches_between_blocking_and_readiness() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut stream = std::net::TcpStream::connect(addr).unwrap();
            for f in sample_frames() {
                stream.write_all(&f.encode()).unwrap();
                stream.flush().unwrap();
                std::thread::sleep(Duration::from_millis(1));
            }
        });
        let (stream, _) = listener.accept().unwrap();
        let t = TcpTransport::from_stream(stream).unwrap();
        let (_tx, mut rx) = (Box::new(t) as Box<dyn Transport>)
            .split().unwrap();
        let want = sample_frames();
        // alternate disciplines frame by frame: blocking recv, then
        // poll try_recv until ready — no frame lost or reordered
        for (i, f) in want.iter().enumerate() {
            let got = if i % 2 == 0 {
                rx.recv().unwrap()
            } else {
                loop {
                    if let Some(g) = rx.try_recv().unwrap() {
                        break g;
                    }
                    std::thread::sleep(Duration::from_micros(100));
                }
            };
            assert_eq!(&got, f);
        }
        writer.join().unwrap();
    }

    #[test]
    fn shaped_drops_exactly_the_planned_indices() {
        let (device, server) = InProcTransport::pair();
        let shaped = ShapedTransport::new(Box::new(device),
                                          Channel::unlimited(),
                                          DropPlan::at(&[1, 3]));
        assert!(shaped.peer().starts_with("shaped("));
        let (mut dtx, _drx) = Box::new(shaped).split().unwrap();
        let (_stx, mut srx) = Box::new(server).split().unwrap();
        let frames: Vec<Frame> = (0..5)
            .map(|i| Frame::Token { request: i, token: i as i32,
                                    logprob: 0.0 })
            .collect();
        for f in &frames {
            // dropped frames still report their wire size
            assert_eq!(dtx.send(f).unwrap(), f.encode().len());
        }
        // only indices 0, 2, 4 arrive, in order
        for want in [0u64, 2, 4] {
            match srx.recv().unwrap() {
                Frame::Token { request, .. } => assert_eq!(request, want),
                other => panic!("unexpected {other:?}"),
            }
        }
        drop(dtx);
        assert!(srx.recv().is_err(), "no ghost frames after the plan");
    }
}
