//! Transport abstraction: a framed, ordered, bidirectional link
//! between a device client and the serving core.
//!
//! The serving stack never touches sockets directly — it speaks
//! [`Frame`]s through a [`Transport`], which splits into a sending
//! ([`FrameTx`]) and a receiving ([`FrameRx`]) half so the server's
//! writer thread and reader loop (and the client's send/await pair)
//! can live on different threads.  Three implementations:
//!
//! * [`TcpTransport`] — the production medium: length-prefixed frames
//!   over a `TcpStream` (nodelay, buffered halves).
//! * [`InProcTransport`] — an mpsc-backed pair with **zero sockets**:
//!   hermetic tests, the sim's live probe, and benches drive the real
//!   serving core through it.  Frames still cross the link as encoded
//!   bytes, so the full encode/decode path is exercised and byte
//!   accounting matches TCP exactly.
//! * [`ShapedTransport`] — a decorator composing any inner transport
//!   with [`Channel`] bandwidth/latency shaping and deterministic
//!   frame-drop injection ([`DropPlan`]) for stream-resync testing.
//!
//! Contract every impl must honour: frames arrive **in send order**,
//! exactly once per direction (unless a shaping decorator explicitly
//! drops them), and `recv` returns `Err` on a closed peer — there is
//! no silent truncation and no reordering.

use super::protocol::Frame;
use crate::net::{Channel, ChannelTrace, DropPlan};
use anyhow::{anyhow, Result};
use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::mpsc;
use std::time::Duration;

/// Sending half of a framed link.
pub trait FrameTx: Send {
    /// Write one already-encoded frame (the full wire image:
    /// length-prefix + type + body); returns its length.  Impls and
    /// decorators work at this level so a frame is serialised exactly
    /// once per send, however deep the decorator stack.
    fn send_encoded(&mut self, bytes: &[u8]) -> Result<usize>;

    /// Encode + write one frame; returns the wire bytes it occupied,
    /// which the byte accounting on both sides records.
    fn send(&mut self, frame: &Frame) -> Result<usize> {
        self.send_encoded(&frame.encode())
    }
}

/// Receiving half of a framed link.  `recv` blocks until a frame
/// arrives and returns `Err` once the peer is gone.
pub trait FrameRx: Send {
    fn recv(&mut self) -> Result<Frame>;
}

/// A framed, ordered, bidirectional byte link.
pub trait Transport: Send {
    /// Consume the transport into its two directional halves.
    fn split(self: Box<Self>) -> Result<(Box<dyn FrameTx>, Box<dyn FrameRx>)>;
    /// Human-readable peer label for logs.
    fn peer(&self) -> String;
}

// ---------------------------------------------------------------------------
// TCP
// ---------------------------------------------------------------------------

/// Length-prefixed frames over a `TcpStream` — the current production
/// medium, now one impl among equals.
pub struct TcpTransport {
    stream: TcpStream,
}

impl TcpTransport {
    /// Client side: connect with nodelay and a 60 s read timeout (a
    /// hung server must surface as an error, not a wedged device).
    pub fn connect(addr: impl ToSocketAddrs) -> Result<TcpTransport> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(60)))?;
        Ok(TcpTransport { stream })
    }

    /// Server side: adopt an accepted connection.
    pub fn from_stream(stream: TcpStream) -> Result<TcpTransport> {
        stream.set_nodelay(true)?;
        Ok(TcpTransport { stream })
    }
}

impl Transport for TcpTransport {
    fn split(self: Box<Self>) -> Result<(Box<dyn FrameTx>, Box<dyn FrameRx>)> {
        let TcpTransport { stream } = *self;
        let reader = stream.try_clone()?;
        Ok((Box::new(TcpTx { w: BufWriter::new(stream) }),
            Box::new(TcpRx { r: BufReader::new(reader) })))
    }

    fn peer(&self) -> String {
        self.stream
            .peer_addr()
            .map(|a| format!("tcp:{a}"))
            .unwrap_or_else(|_| "tcp:?".into())
    }
}

struct TcpTx {
    w: BufWriter<TcpStream>,
}

impl FrameTx for TcpTx {
    fn send_encoded(&mut self, bytes: &[u8]) -> Result<usize> {
        self.w.write_all(bytes)?;
        self.w.flush()?;
        Ok(bytes.len())
    }
}

struct TcpRx {
    r: BufReader<TcpStream>,
}

impl FrameRx for TcpRx {
    fn recv(&mut self) -> Result<Frame> {
        Frame::read_from(&mut self.r)
    }
}

// ---------------------------------------------------------------------------
// in-process
// ---------------------------------------------------------------------------

/// An mpsc-backed transport pair: no sockets, no OS at all, but
/// frames still cross the link as encoded byte vectors so both ends
/// run the exact wire encode/decode path (including [`Frame`]'s
/// size/alignment checks) and per-frame byte counts are identical to
/// TCP.
pub struct InProcTransport {
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<Vec<u8>>,
    label: &'static str,
}

impl InProcTransport {
    /// A connected (device, server) pair.
    pub fn pair() -> (InProcTransport, InProcTransport) {
        let (c2s_tx, c2s_rx) = mpsc::channel();
        let (s2c_tx, s2c_rx) = mpsc::channel();
        (InProcTransport { tx: c2s_tx, rx: s2c_rx, label: "inproc:device" },
         InProcTransport { tx: s2c_tx, rx: c2s_rx, label: "inproc:server" })
    }
}

impl Transport for InProcTransport {
    fn split(self: Box<Self>) -> Result<(Box<dyn FrameTx>, Box<dyn FrameRx>)> {
        let InProcTransport { tx, rx, .. } = *self;
        Ok((Box::new(InProcTx { tx }), Box::new(InProcRx { rx })))
    }

    fn peer(&self) -> String {
        self.label.to_string()
    }
}

struct InProcTx {
    tx: mpsc::Sender<Vec<u8>>,
}

impl FrameTx for InProcTx {
    fn send_encoded(&mut self, bytes: &[u8]) -> Result<usize> {
        let n = bytes.len();
        self.tx
            .send(bytes.to_vec())
            .map_err(|_| anyhow!("in-proc peer disconnected"))?;
        Ok(n)
    }

    // direct (undecorated) sends move the encoded vector instead of
    // copying it through the slice-level path
    fn send(&mut self, frame: &Frame) -> Result<usize> {
        let bytes = frame.encode();
        let n = bytes.len();
        self.tx
            .send(bytes)
            .map_err(|_| anyhow!("in-proc peer disconnected"))?;
        Ok(n)
    }
}

struct InProcRx {
    rx: mpsc::Receiver<Vec<u8>>,
}

impl FrameRx for InProcRx {
    fn recv(&mut self) -> Result<Frame> {
        // same hung-peer bound as TcpTransport::connect's read
        // timeout: a wedged service must turn into a test failure,
        // not a CI job that hangs until the job-level timeout
        let bytes = self
            .rx
            .recv_timeout(Duration::from_secs(60))
            .map_err(|e| anyhow!("in-proc recv: {e}"))?;
        let mut cur = std::io::Cursor::new(bytes);
        Frame::read_from(&mut cur)
    }
}

// ---------------------------------------------------------------------------
// shaped decorator
// ---------------------------------------------------------------------------

/// Decorator composing any inner transport with [`Channel`] shaping
/// (uplink serialisation + propagation sleeps on every send) and a
/// deterministic [`DropPlan`] that silently discards selected frames
/// by send index — the lever the stream-resync tests pull to lose a
/// delta "on the wire" without a lossy network.
///
/// Only the send direction is shaped/dropped: the device uplink is
/// the bottleneck the paper models, and dropping server replies would
/// test the client's timeout, not the stream protocol.
pub struct ShapedTransport {
    inner: Box<dyn Transport>,
    channel: Channel,
    /// Time-varying override: when set, each send crosses the channel
    /// the trace assigns to its 0-based send index (the fluctuating
    /// links the adaptive rate-control suite emulates).
    trace: Option<ChannelTrace>,
    drop: DropPlan,
}

impl ShapedTransport {
    pub fn new(inner: Box<dyn Transport>, channel: Channel, drop: DropPlan)
        -> ShapedTransport {
        ShapedTransport { inner, channel, trace: None, drop }
    }

    /// A shaped transport whose per-send channel follows a
    /// deterministic [`ChannelTrace`] instead of one fixed channel.
    pub fn with_trace(inner: Box<dyn Transport>, trace: ChannelTrace,
                      drop: DropPlan) -> ShapedTransport {
        ShapedTransport {
            inner,
            channel: Channel::unlimited(),
            trace: Some(trace),
            drop,
        }
    }
}

impl Transport for ShapedTransport {
    fn split(self: Box<Self>) -> Result<(Box<dyn FrameTx>, Box<dyn FrameRx>)> {
        let ShapedTransport { inner, channel, trace, drop } = *self;
        let peer = inner.peer();
        let (tx, rx) = inner.split()?;
        Ok((Box::new(ShapedTx { inner: tx, channel, trace, drop, peer }), rx))
    }

    fn peer(&self) -> String {
        format!("shaped({})", self.inner.peer())
    }
}

struct ShapedTx {
    inner: Box<dyn FrameTx>,
    channel: Channel,
    trace: Option<ChannelTrace>,
    drop: DropPlan,
    peer: String,
}

impl FrameTx for ShapedTx {
    fn send_encoded(&mut self, bytes: &[u8]) -> Result<usize> {
        let n = bytes.len();
        let channel = match self.trace.as_mut() {
            Some(t) => t.next_channel(),
            None => self.channel,
        };
        if self.drop.should_drop() {
            // the frame is lost after crossing the link: it still
            // costs the sender its transfer time and byte budget
            channel.throttle(n);
            crate::debug!("transport", "{}: dropped frame type {} ({n} B)",
                          self.peer, bytes.get(4).copied().unwrap_or(0xFF));
            return Ok(n);
        }
        // sleep the emulated transfer time BEFORE the peer can see
        // the frame — the server must not start computing while the
        // bytes are still "on the wire" (no-op on unshaped channels)
        channel.throttle(n);
        self.inner.send_encoded(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{caps, ErrorCode};

    fn sample_frames() -> Vec<Frame> {
        vec![
            Frame::hello(7, caps::STREAM | caps::CODEC_FC, "llamette-m"),
            Frame::Activation {
                session: 1, request: 2, bucket: 16, true_len: 9, ks: 3, kd: 3,
                point: 0, packed: vec![0.5; 9],
            },
            Frame::Token { request: 2, token: 65, logprob: -0.5 },
            Frame::Error { code: ErrorCode::StreamReject, msg: "gap".into() },
            Frame::Bye,
        ]
    }

    #[test]
    fn inproc_roundtrips_frames_in_order() {
        let (device, server) = InProcTransport::pair();
        let (mut dtx, mut drx) = Box::new(device).split().unwrap();
        let (mut stx, mut srx) = Box::new(server).split().unwrap();
        for f in sample_frames() {
            let n = dtx.send(&f).unwrap();
            assert_eq!(n, f.encode().len(), "reported wire bytes");
            assert_eq!(srx.recv().unwrap(), f);
        }
        // and the reverse direction
        let tok = Frame::Token { request: 9, token: 1, logprob: 0.0 };
        stx.send(&tok).unwrap();
        assert_eq!(drx.recv().unwrap(), tok);
    }

    #[test]
    fn inproc_disconnect_is_error_not_hang() {
        let (device, server) = InProcTransport::pair();
        let (dtx, drx) = Box::new(device).split().unwrap();
        drop(dtx);
        drop(drx);
        let (mut stx, mut srx) = Box::new(server).split().unwrap();
        assert!(stx.send(&Frame::Bye).is_err());
        assert!(srx.recv().is_err());
    }

    #[test]
    fn tcp_transport_roundtrips_over_loopback() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let echo = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let t = TcpTransport::from_stream(stream).unwrap();
            let (mut tx, mut rx) = (Box::new(t) as Box<dyn Transport>)
                .split().unwrap();
            loop {
                match rx.recv() {
                    Ok(Frame::Bye) | Err(_) => break,
                    Ok(f) => { tx.send(&f).unwrap(); }
                }
            }
        });
        let t = TcpTransport::connect(addr).unwrap();
        assert!(t.peer().starts_with("tcp:"));
        let (mut tx, mut rx) = (Box::new(t) as Box<dyn Transport>)
            .split().unwrap();
        for f in sample_frames() {
            if matches!(f, Frame::Bye) {
                continue;
            }
            tx.send(&f).unwrap();
            assert_eq!(rx.recv().unwrap(), f, "echo mismatch");
        }
        tx.send(&Frame::Bye).unwrap();
        echo.join().unwrap();
    }

    #[test]
    fn shaped_drops_exactly_the_planned_indices() {
        let (device, server) = InProcTransport::pair();
        let shaped = ShapedTransport::new(Box::new(device),
                                          Channel::unlimited(),
                                          DropPlan::at(&[1, 3]));
        assert!(shaped.peer().starts_with("shaped("));
        let (mut dtx, _drx) = Box::new(shaped).split().unwrap();
        let (_stx, mut srx) = Box::new(server).split().unwrap();
        let frames: Vec<Frame> = (0..5)
            .map(|i| Frame::Token { request: i, token: i as i32,
                                    logprob: 0.0 })
            .collect();
        for f in &frames {
            // dropped frames still report their wire size
            assert_eq!(dtx.send(f).unwrap(), f.encode().len());
        }
        // only indices 0, 2, 4 arrive, in order
        for want in [0u64, 2, 4] {
            match srx.recv().unwrap() {
                Frame::Token { request, .. } => assert_eq!(request, want),
                other => panic!("unexpected {other:?}"),
            }
        }
        drop(dtx);
        assert!(srx.recv().is_err(), "no ghost frames after the plan");
    }
}
