//! The device-side client: runs the fused client HLO (embed + layer 1
//! + pallas FC compress) locally, packs the block with conjugate
//! symmetry, ships it through any [`Transport`] (TCP, in-proc, or a
//! bandwidth-shaped decorator), and drives autoregressive generation —
//! either in the paper's recompute regime (every token re-sends the
//! grown prompt's compressed activation) or, with
//! [`DeviceClient::enable_stream`], through the spectral delta stream
//! (`codec::stream`).
//!
//! Connections start with the v2 handshake: the client announces its
//! protocol version + capability bits and checks the server's
//! [`Frame::HelloAck`] — version, capability intersection, and bucket
//! geometry against the local manifest — so features are *negotiated*
//! (a server without the stream capability downgrades the client to
//! the recompute regime) and manifest drift fails the connection
//! instead of the codec.  Server `Error` frames surface as structured
//! [`ServerError`]s; only [`ErrorCode::StreamReject`] triggers the
//! transparent keyframe resync.

use super::obs::span_id;
use super::protocol::{caps, ErrorCode, Frame, ServerError, PROTOCOL_VERSION};
use super::transport::{FrameRx, FrameTx, ShapedTransport, TcpTransport,
                       Transport};
use crate::codec::fourier::{crop_block_into, pack_block_into};
use crate::codec::rate::{ladder_from_manifest, LadderPoint, RateConfig,
                         RateController};
use crate::codec::stream::{split_prefill, BlockGeom, PrefillChunk,
                           PrefillConfig, StreamConfig, StreamEncoder,
                           StreamStep, UPDATE_WIRE_BYTES};
use crate::codec::wire;
use crate::codec::CodecEngine;
use crate::model::tokenizer;
use crate::model::weights::Weights;
use crate::model::ModelMeta;
use crate::net::{Channel, DropPlan};
use crate::runtime::{ArtifactStore, Executable};
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

/// Capabilities this client implementation requests in its `Hello`.
pub const CLIENT_CAPS: u32 = caps::STREAM | caps::CODEC_FC | caps::LADDER
    | caps::ENTROPY | caps::PREFILL;

struct ClientBucket {
    ks: usize,
    kd: usize,
    /// Quality ladder (`codec::rate`); point 0 == (ks, kd) above.
    ladder: Vec<LadderPoint>,
    exe: Arc<Executable>,
}

/// Adaptive rate-control state: the per-session controller plus the
/// bucket whose ladder it is currently driving (retargeted on bucket
/// promotion, estimates carried over).
struct AdaptiveState {
    ctrl: RateController,
    bucket: usize,
}

pub struct DeviceClient {
    session: u64,
    tx: Box<dyn FrameTx>,
    rx: Box<dyn FrameRx>,
    d_model: usize,
    buckets: BTreeMap<usize, ClientBucket>,
    client_args: Vec<Tensor>, // tok_emb + layer-0 weights
    next_request: u64,
    /// Per-session codec engine: index sets + scratch survive the
    /// whole autoregressive generation, so the per-token loop packs
    /// without re-deriving or re-allocating anything.
    engine: CodecEngine,
    /// Reusable packed-coefficient buffer (moved into the Activation
    /// frame for the send, then recovered).
    packed_scratch: Vec<f32>,
    /// Stream mode: the session-stateful delta encoder (None =
    /// recompute regime, the default).
    encoder: Option<StreamEncoder>,
    /// Reusable stream-frame buffers (moved into the Delta frame for
    /// the send, then recovered).
    step_scratch: StreamStep,
    /// Adaptive rate control (None = pinned to the primary point).
    adaptive: Option<AdaptiveState>,
    /// Chunked prefill (None = prompts ship as one monolithic frame).
    prefill: Option<PrefillConfig>,
    /// Reusable prefill chunk buffers (each chunk's payload is moved
    /// into its wire frame for the send, then recovered).
    chunk_scratch: Vec<PrefillChunk>,
    /// The transmitted prompt plane `split_prefill` reconstructs —
    /// exactly what the server's assembler holds, so it seeds the
    /// decode stream after the prompt completes.
    prefill_state: Vec<f32>,
    /// Entropy-coded wire format (`codec::wire`): when enabled, each
    /// data-frame body is losslessly entropy-coded and shipped coded
    /// only when that wins over the raw encoding (try-and-compare).
    entropy: bool,
    /// Reusable entropy-coded body buffer (moved into the frame for
    /// the send, then recovered — the raw-frame twin of
    /// `packed_scratch`).
    coded_scratch: Vec<u8>,
    /// Reusable planes for cropping the fused executable's full block
    /// down to a non-primary ladder point.
    crop_re: Vec<f32>,
    crop_im: Vec<f32>,
    /// Ladder point the previous step shipped (switch accounting).
    last_point: u8,
    /// Send timestamps of requests in flight through the split-phase
    /// [`DeviceClient::step_send`] / [`DeviceClient::step_recv`] API
    /// (round-trip accounting).
    inflight: Vec<(u64, Instant)>,
    /// Trace span of the most recent prepared step — the same id the
    /// server mints for this (session, request), derived purely from
    /// the pair so no wire bytes change (see [`span_id`]).
    last_span: u64,
    /// Capability bits the server advertised in its `HelloAck`.
    server_caps: u32,
    /// Bucket quality ladders the server advertised (validated
    /// against the local manifest at connect).
    server_buckets: Vec<super::protocol::BucketAdvert>,
    pub stats: ClientStats,
}

#[derive(Debug, Default, Clone)]
pub struct ClientStats {
    pub requests: u64,
    pub bytes_sent: u64,
    pub bytes_uncompressed: u64,
    pub client_compute_us: u64,
    pub round_trip_us: Vec<u64>,
    /// Stream mode: keyframes / delta frames sent, and keyframe
    /// resyncs after a server-side stream rejection.
    pub key_frames: u64,
    pub delta_frames: u64,
    pub resyncs: u64,
    /// Stream mode: wire bytes shipped as keyframes vs delta frames
    /// (each includes the frame header) — lets a test reconcile the
    /// server's `bytes_rx` against client-side accounting.
    pub key_bytes: u64,
    pub delta_bytes: u64,
    /// Adaptive rate control: ladder-point switches this session
    /// performed and the deepest (cheapest) point it ever rode —
    /// `max_point > 0` means the session downshifted at least once.
    pub ladder_switches: u64,
    pub max_point: u8,
    /// Entropy-coded wire layer (`codec::wire`): frames shipped coded
    /// vs raw fallbacks (coding would not have shrunk the body), plus
    /// the pre/post-coding byte split over the coded frames' bodies —
    /// `pre_coding_bytes` is what those bodies would have cost raw,
    /// `post_coding_bytes` what actually crossed the wire.
    pub entropy_frames: u64,
    pub entropy_fallbacks: u64,
    pub pre_coding_bytes: u64,
    pub post_coding_bytes: u64,
    /// Chunked prefill: prompts shipped chunked, the chunk frames
    /// that carried them (keyframe chunks separately), their wire
    /// bytes (headers included, also counted in `bytes_sent`), and
    /// full-prompt resends after a server-side prefill rejection.
    pub prefill_prompts: u64,
    pub prefill_chunks: u64,
    pub prefill_key_chunks: u64,
    pub prefill_bytes: u64,
    pub prefill_resyncs: u64,
}

impl ClientStats {
    pub fn compression_ratio(&self) -> f64 {
        self.bytes_uncompressed as f64 / self.bytes_sent.max(1) as f64
    }
}

/// A decode step compressed and ready to ship (see
/// [`DeviceClient::step`] / [`DeviceClient::step_send`]).
struct PreparedStep {
    request: u64,
    bucket: usize,
    len: usize,
    ks: usize,
    kd: usize,
    point: u8,
    packed: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct Generation {
    pub prompt: String,
    pub completion: String,
    pub tokens: Vec<i32>,
    pub steps: usize,
}

impl DeviceClient {
    /// TCP convenience: connect to `addr` with the uplink shaped by
    /// `channel` — an unshaped channel ([`Channel::unlimited`]) skips
    /// the shaping decorator entirely.
    pub fn connect(addr: &str, store: &ArtifactStore, session: u64,
                   channel: Channel) -> Result<DeviceClient> {
        let tcp = Box::new(TcpTransport::connect(addr)?);
        let transport: Box<dyn Transport> = if channel.is_shaping() {
            Box::new(ShapedTransport::new(tcp, channel, DropPlan::none()))
        } else {
            tcp
        };
        Self::connect_over(transport, store, session)
    }

    /// Connect over any transport — the in-proc/shaped entry point
    /// the hermetic tests, benches, and the sim's live probe use.
    /// Performs the full v2 handshake before returning.
    pub fn connect_over(transport: Box<dyn Transport>, store: &ArtifactStore,
                        session: u64) -> Result<DeviceClient> {
        let serving = store
            .manifest
            .get("serving")
            .ok_or_else(|| anyhow!("manifest has no serving section"))?;
        let model = serving.str_or("model", "");
        let meta = ModelMeta::from_manifest(&model, store.model_meta(&model)?)?;
        let weights = Weights::load(&store.root, &meta)?;
        let mut client_args = weights.embed_args()?;
        client_args.extend(weights.layer_args(&meta, 0)?);

        let mut buckets = BTreeMap::new();
        for (bstr, bj) in serving.get("buckets").and_then(|b| b.as_obj())
            .ok_or_else(|| anyhow!("serving.buckets missing"))? {
            let bucket: usize = bstr.parse()?;
            let path = bj.path("client.path").and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("bucket {bucket}: no client artifact"))?;
            let ladder = ladder_from_manifest(bj, bucket, meta.d_model)
                .map_err(|e| anyhow!("manifest bucket {bucket}: {e}"))?;
            buckets.insert(bucket, ClientBucket {
                ks: bj.usize_or("ks", 0),
                kd: bj.usize_or("kd", 0),
                ladder,
                exe: store.get(path)?,
            });
        }

        // pre-warm the engine for every ladder point of every bucket
        // this session can use; a geometry the codec cannot serve is
        // a manifest bug — fail the connection now, not with a panic
        // mid-generation (ladder_from_manifest has already validated
        // each point's block axes and nesting).
        let mut engine = CodecEngine::new();
        for (&bucket, cb) in &buckets {
            if !crate::codec::valid_block_axis(bucket, cb.ks)
                || !crate::codec::valid_block_axis(meta.d_model, cb.kd) {
                bail!("manifest bucket {bucket}: invalid block {}x{} for \
                       {bucket}x{}", cb.ks, cb.kd, meta.d_model);
            }
            for lp in &cb.ladder {
                engine.warm(bucket, meta.d_model, lp.ks, lp.kd);
            }
        }

        let (tx, rx) = transport.split()?;
        let mut client = DeviceClient {
            session,
            tx,
            rx,
            d_model: meta.d_model,
            buckets,
            client_args,
            next_request: 1,
            engine,
            packed_scratch: Vec::new(),
            encoder: None,
            step_scratch: StreamStep::default(),
            adaptive: None,
            prefill: None,
            chunk_scratch: Vec::new(),
            prefill_state: Vec::new(),
            entropy: false,
            coded_scratch: Vec::new(),
            crop_re: Vec::new(),
            crop_im: Vec::new(),
            last_point: 0,
            inflight: Vec::new(),
            last_span: 0,
            server_caps: 0,
            server_buckets: Vec::new(),
            stats: ClientStats::default(),
        };
        client.handshake(model)?;
        Ok(client)
    }

    /// Send `Hello`, await `HelloAck`, and validate what the server
    /// advertised: protocol version, and bucket geometry agreeing
    /// with the local manifest (both sides must compress/reconstruct
    /// the same ks×kd blocks — drift here used to corrupt silently).
    fn handshake(&mut self, model: String) -> Result<()> {
        self.send(&Frame::hello(self.session, CLIENT_CAPS, model))?;
        match self.recv()? {
            Frame::HelloAck { version, caps: server_caps, buckets } => {
                ensure!(version == PROTOCOL_VERSION,
                        "server speaks protocol v{version}, \
                         client v{PROTOCOL_VERSION}");
                ensure!(buckets.len() == self.buckets.len(),
                        "server serves {} buckets, local manifest has {}",
                        buckets.len(), self.buckets.len());
                for adv in &buckets {
                    let Some(cb) = self.buckets.get(&(adv.bucket as usize))
                    else {
                        bail!("bucket geometry drift: server advertises \
                               bucket {}, local manifest lacks it",
                              adv.bucket);
                    };
                    let (aks, akd) = adv.primary();
                    ensure!(cb.ks == aks as usize && cb.kd == akd as usize,
                            "bucket geometry drift: server advertises \
                             {}:{}x{}, local manifest disagrees",
                            adv.bucket, aks, akd);
                    // the advertised ladder must be a prefix of the
                    // local one (a server without the ladder
                    // capability advertises only point 0) — point ids
                    // are meaningless if the two sides' ladders drift
                    ensure!(adv.ladder.len() <= cb.ladder.len(),
                            "bucket {}: server advertises {} ladder \
                             points, local manifest has {}", adv.bucket,
                            adv.ladder.len(), cb.ladder.len());
                    for (i, le) in adv.ladder.iter().enumerate() {
                        let lp = &cb.ladder[i];
                        ensure!(lp.ks == le.ks as usize
                                    && lp.kd == le.kd as usize,
                                "bucket {} ladder point {i} drift: server \
                                 {}x{}, local {}x{}", adv.bucket, le.ks,
                                le.kd, lp.ks, lp.kd);
                    }
                }
                // the usable ladder is what the server advertised: a
                // controller fed extra local-only points would
                // downshift to geometry the server rejects as
                // bad-request mid-generation
                for adv in &buckets {
                    if let Some(cb) = self.buckets.get_mut(&(adv.bucket
                                                            as usize)) {
                        cb.ladder.truncate(adv.ladder.len().max(1));
                    }
                }
                self.server_caps = server_caps;
                self.server_buckets = buckets;
                Ok(())
            }
            Frame::Error { code, msg } => Err(ServerError { code, msg }.into()),
            other => bail!("handshake: unexpected frame {}", other.type_id()),
        }
    }

    fn send(&mut self, frame: &Frame) -> Result<()> {
        let n = self.tx.send(frame)?;
        self.stats.bytes_sent += n as u64;
        Ok(())
    }

    fn recv(&mut self) -> Result<Frame> {
        self.rx.recv()
    }

    /// Capability bits the server advertised in its `HelloAck`.
    pub fn server_caps(&self) -> u32 {
        self.server_caps
    }

    /// Capabilities in effect on this connection (client ∩ server).
    pub fn negotiated_caps(&self) -> u32 {
        self.server_caps & CLIENT_CAPS
    }

    /// The bucket quality ladders the server advertised at handshake.
    pub fn server_buckets(&self) -> &[super::protocol::BucketAdvert] {
        &self.server_buckets
    }

    /// Trace span of the most recent prepared step — matches the span
    /// the server mints for the same (session, request) pair, with no
    /// extra wire bytes.  0 before the first step.
    pub fn last_span(&self) -> u64 {
        self.last_span
    }

    /// Pick the smallest bucket that fits `len` tokens.
    fn bucket_for(&self, len: usize) -> Option<usize> {
        self.buckets.keys().copied().find(|&b| b >= len)
    }

    /// Switch this session to the spectral delta stream: subsequent
    /// steps send keyframes/deltas (`Frame::Delta`) instead of full
    /// Activation frames.  Returns false (and stays in the recompute
    /// regime) when the handshake did not negotiate the stream
    /// capability — the clean downgrade path.  Enabling
    /// mid-generation is safe — the fresh encoder's first frame is a
    /// keyframe.
    #[must_use = "a false return means the server refused the stream \
                  capability and the client stays in the recompute regime"]
    pub fn enable_stream(&mut self, cfg: StreamConfig) -> bool {
        if self.negotiated_caps() & caps::STREAM == 0 {
            crate::warn_!("client",
                          "session {}: server lacks the stream capability; \
                           staying in the recompute regime", self.session);
            return false;
        }
        self.encoder = Some(StreamEncoder::new(cfg));
        true
    }

    pub fn stream_enabled(&self) -> bool {
        self.encoder.is_some()
    }

    /// Switch this session to adaptive spectral rate control
    /// (`codec::rate`): each step the per-session [`RateController`]
    /// picks a ladder point from the EWMA goodput estimate (fed by
    /// transport send timing) and the stream codec's measured drift,
    /// under `cfg.error_budget`.  Returns false (and stays pinned to
    /// the primary point) when the handshake did not negotiate the
    /// ladder capability — the clean downgrade path.  Composes with
    /// [`DeviceClient::enable_stream`]: a ladder switch changes the
    /// block geometry, which forces a stream keyframe exactly like
    /// bucket promotion.
    #[must_use = "a false return means the server refused the ladder \
                  capability and the client stays at the primary point"]
    pub fn enable_adaptive(&mut self, cfg: RateConfig) -> bool {
        if self.negotiated_caps() & caps::LADDER == 0 {
            crate::warn_!("client",
                          "session {}: server lacks the ladder capability; \
                           staying at the primary point", self.session);
            return false;
        }
        let Some((&bucket, cb)) = self.buckets.iter().next() else {
            return false;
        };
        match RateController::new(cb.ladder.clone(), cfg) {
            Ok(ctrl) => {
                self.adaptive = Some(AdaptiveState { ctrl, bucket });
                true
            }
            Err(e) => {
                crate::warn_!("client", "session {}: bad rate config: {e:#}",
                              self.session);
                false
            }
        }
    }

    pub fn adaptive_enabled(&self) -> bool {
        self.adaptive.is_some()
    }

    /// Switch this session to the entropy-coded wire format
    /// (`codec::wire`): every subsequent Activation / Delta body is
    /// losslessly entropy-coded and ships coded only when that beats
    /// the raw encoding (try-and-compare; a frame coding cannot
    /// shrink falls back to raw and counts as `entropy_fallbacks`).
    /// Tokens are bit-identical either way — the coding is lossless.
    /// Returns false (staying on raw frames) when the handshake did
    /// not negotiate the entropy capability — the clean downgrade
    /// path against pre-entropy servers.
    #[must_use = "a false return means the server refused the entropy \
                  capability and the client stays on raw frames"]
    pub fn enable_entropy(&mut self) -> bool {
        if self.negotiated_caps() & caps::ENTROPY == 0 {
            crate::warn_!("client",
                          "session {}: server lacks the entropy capability; \
                           staying on raw frames", self.session);
            return false;
        }
        self.entropy = true;
        true
    }

    pub fn entropy_enabled(&self) -> bool {
        self.entropy
    }

    /// Switch this session to chunked prefill (`codec::stream`
    /// prefill mode): [`DeviceClient::send_prompt`] splits the
    /// prompt-phase plane into one keyframe chunk plus row-delta
    /// chunks (`Frame::PrefillChunk`) instead of one monolithic
    /// Activation/keyframe, reusing the Parseval-bounded delta
    /// machinery across the prompt dimension.  Returns false (prompts
    /// keep shipping monolithic) when the handshake did not negotiate
    /// the prefill capability — the clean downgrade path against
    /// pre-prefill servers.  Composes with the stream (the completed
    /// prompt seeds the delta encoder), adaptive (prompt chunks ride
    /// [`RateController::prefill_point`]), and entropy (each chunk
    /// body is try-and-compare coded) levers.
    #[must_use = "a false return means the server refused the prefill \
                  capability and prompts ship as monolithic frames"]
    pub fn enable_prefill(&mut self, cfg: PrefillConfig) -> bool {
        if self.negotiated_caps() & caps::PREFILL == 0 {
            crate::warn_!("client",
                          "session {}: server lacks the prefill capability; \
                           prompts ship monolithic", self.session);
            return false;
        }
        if cfg.chunk_rows == 0 {
            crate::warn_!("client",
                          "session {}: prefill chunk_rows must be >= 1",
                          self.session);
            return false;
        }
        self.prefill = Some(cfg);
        true
    }

    pub fn prefill_enabled(&self) -> bool {
        self.prefill.is_some()
    }

    /// Pin the session to one advertised ladder point (the benches'
    /// fixed-point ablation lever): adaptive accounting still runs
    /// but the point never moves.  Returns false without the ladder
    /// capability or for a point outside the ladder.
    pub fn pin_ladder_point(&mut self, point: u8) -> bool {
        let enabled_here = self.adaptive.is_none();
        if enabled_here && !self.enable_adaptive(RateConfig::default()) {
            return false;
        }
        let st = self.adaptive.as_mut().expect("adaptive state");
        if st.ctrl.pin(point as usize).is_ok() {
            true
        } else {
            // a failed pin must not leave free-running rate control
            // enabled as a side effect — the caller asked for a fixed
            // point, not adaptation
            if enabled_here {
                self.adaptive = None;
            }
            false
        }
    }

    /// The ladder point the next step will ride (0 without adaptive
    /// rate control).
    pub fn current_point(&self) -> u8 {
        self.adaptive.as_ref().map(|s| s.ctrl.point() as u8).unwrap_or(0)
    }

    /// One decode step: compress the current context (at the ladder
    /// point the rate controller picks, if adaptive), send, await
    /// token.
    pub fn step(&mut self, context: &[i32]) -> Result<(i32, f32)> {
        let ps = self.prepare_step(context)?;
        let request = ps.request;
        let t1 = Instant::now();
        let reply = if self.encoder.is_some() {
            let r = self.stream_step(request, ps.bucket, ps.len, ps.ks,
                                     ps.kd, ps.point, &ps.packed);
            self.packed_scratch = ps.packed;
            r?
        } else {
            self.send_activation(ps)?;
            self.await_token(request)?
        };
        self.stats.round_trip_us.push(t1.elapsed().as_micros() as u64);
        Ok(reply)
    }

    /// Split-phase decode, send half: compress the context and ship
    /// the Activation frame *without* waiting for the token — the
    /// other half is [`DeviceClient::step_recv`].  This is how a
    /// pipelined driver keeps many sessions in flight from one thread
    /// (send a step on every client, then collect every token).
    /// Recompute regime only: the delta stream's keyframe-resync
    /// protocol needs the lockstep [`DeviceClient::step`] loop.
    pub fn step_send(&mut self, context: &[i32]) -> Result<u64> {
        ensure!(self.encoder.is_none(),
                "step_send: stream mode requires the lockstep step() loop");
        let ps = self.prepare_step(context)?;
        let request = ps.request;
        self.send_activation(ps)?;
        self.inflight.push((request, Instant::now()));
        Ok(request)
    }

    /// Split-phase decode, receive half: await the token for a
    /// request previously shipped by [`DeviceClient::step_send`].
    pub fn step_recv(&mut self, request: u64) -> Result<(i32, f32)> {
        let reply = self.await_token(request)?;
        if let Some(i) = self.inflight.iter().position(|&(r, _)| r == request) {
            let (_, t) = self.inflight.swap_remove(i);
            self.stats.round_trip_us.push(t.elapsed().as_micros() as u64);
        }
        Ok(reply)
    }

    /// The shared front half of a decode step: pick the bucket and
    /// ladder point, run the fused client executable, and pack the
    /// block at that point's geometry.  The packed buffer travels in
    /// the returned [`PreparedStep`] and is recovered into
    /// `packed_scratch` by whichever send path consumes it.
    fn prepare_step(&mut self, context: &[i32]) -> Result<PreparedStep> {
        self.prepare_step_at(context, false)
    }

    /// [`DeviceClient::prepare_step`], optionally at the prefill
    /// ladder rung: `prefill: true` packs the prompt at
    /// [`RateController::prefill_point`] — the deepest admissible
    /// point, read *after* the controller retargets onto the prompt's
    /// bucket — without advancing the decode-side controller.
    fn prepare_step_at(&mut self, context: &[i32], prefill: bool)
        -> Result<PreparedStep> {
        let len = context.len();
        let bucket = self
            .bucket_for(len)
            .ok_or_else(|| anyhow!("context {len} exceeds largest bucket"))?;
        // adaptive: retarget the controller on bucket promotion (pace
        // and drift estimates carry over — the link did not change),
        // then advance it one step to pick this step's ladder point
        let point: u8 = match self.adaptive.as_mut() {
            Some(st) => {
                if st.bucket != bucket {
                    st.ctrl.retarget(self.buckets[&bucket].ladder.clone())?;
                    st.bucket = bucket;
                }
                if prefill {
                    st.ctrl.prefill_point() as u8
                } else {
                    st.ctrl.step() as u8
                }
            }
            None => 0,
        };
        if point != self.last_point {
            self.stats.ladder_switches += 1;
            self.last_point = point;
        }
        self.stats.max_point = self.stats.max_point.max(point);

        let cb = &self.buckets[&bucket];
        let lp = cb.ladder[point as usize];
        let tokens = Tensor::i32(vec![1, bucket], tokenizer::pad_to(context, bucket));

        let t0 = Instant::now();
        let mut args = vec![tokens];
        args.extend(self.client_args.iter().cloned());
        let out = cb.exe.run(&args)?; // [re, im] each [1, ks0, kd0]
        let (ks, kd) = (lp.ks, lp.kd);
        let mut packed = std::mem::take(&mut self.packed_scratch);
        if point == 0 {
            pack_block_into(&mut self.engine, out[0].as_f32(), out[1].as_f32(),
                            bucket, self.d_model, ks, kd, &mut packed);
        } else {
            // non-primary point: gather the nested sub-block out of
            // the full block the fused executable already emitted —
            // no per-point artifact — then pack that
            let mut cre = std::mem::take(&mut self.crop_re);
            let mut cim = std::mem::take(&mut self.crop_im);
            crop_block_into(&mut self.engine, out[0].as_f32(),
                            out[1].as_f32(), bucket, self.d_model, cb.ks,
                            cb.kd, ks, kd, &mut cre, &mut cim)?;
            pack_block_into(&mut self.engine, &cre, &cim, bucket,
                            self.d_model, ks, kd, &mut packed);
            self.crop_re = cre;
            self.crop_im = cim;
        }
        self.stats.client_compute_us += t0.elapsed().as_micros() as u64;
        self.stats.bytes_uncompressed += (bucket * self.d_model * 4) as u64;

        let request = self.next_request;
        self.next_request += 1;
        self.last_span = span_id(self.session, request);
        Ok(PreparedStep { request, bucket, len, ks, kd, point, packed })
    }

    /// Ship a prepared step as a recompute Activation frame,
    /// recovering the coefficient buffer for the next step.  With the
    /// entropy format enabled the packed plane is coded first and the
    /// smaller of the two encodings crosses the wire.
    fn send_activation(&mut self, ps: PreparedStep) -> Result<()> {
        let mut packed = ps.packed;
        let mut coded = std::mem::take(&mut self.coded_scratch);
        coded.clear();
        if self.entropy {
            wire::encode_f32_plane(&packed, &mut coded);
            let raw = packed.len() * 4;
            if coded.len() < raw {
                self.stats.entropy_frames += 1;
                self.stats.pre_coding_bytes += raw as u64;
                self.stats.post_coding_bytes += coded.len() as u64;
            } else {
                self.stats.entropy_fallbacks += 1;
                coded.clear();
            }
        }
        let is_coded = !coded.is_empty();
        if is_coded {
            // the coded bytes carry the step; the packed plane never
            // leaves, so recover it for the next step right away
            self.packed_scratch = std::mem::take(&mut packed);
        }
        let frame = Frame::Activation {
            session: self.session,
            request: ps.request,
            bucket: ps.bucket as u16,
            true_len: ps.len as u16,
            ks: ps.ks as u16,
            kd: ps.kd as u16,
            point: ps.point,
            packed,
            coded,
        };
        self.timed_send(&frame)?;
        if let Frame::Activation { packed, coded, .. } = frame {
            if !is_coded {
                self.packed_scratch = packed;
            }
            self.coded_scratch = coded;
        }
        self.stats.requests += 1;
        Ok(())
    }

    /// Send one frame, timing the tx half and feeding the adaptive
    /// controller's pace estimate — under a shaped link the send
    /// blocks for the emulated transfer time, so the measurement *is*
    /// the link.
    fn timed_send(&mut self, frame: &Frame) -> Result<()> {
        let b0 = self.stats.bytes_sent;
        let t = Instant::now();
        self.send(frame)?;
        if let Some(st) = self.adaptive.as_mut() {
            st.ctrl.observe_send((self.stats.bytes_sent - b0) as usize,
                                 t.elapsed().as_secs_f64());
        }
        Ok(())
    }

    /// Wait for this request's Token, skipping stale replies.
    fn await_token(&mut self, request: u64) -> Result<(i32, f32)> {
        loop {
            match self.recv()? {
                Frame::Token { request: r, token, logprob } if r == request => {
                    return Ok((token, logprob));
                }
                Frame::Token { .. } => continue, // stale reply
                Frame::Error { code, msg } => {
                    return Err(ServerError { code, msg }.into());
                }
                other => bail!("unexpected frame {}", other.type_id()),
            }
        }
    }

    /// One stream-mode send: encode the packed block as a keyframe or
    /// delta against the per-session encoder state.  If the server
    /// rejects a delta with [`ErrorCode::StreamReject`] (stream state
    /// TTL-evicted, sequence gap), force a keyframe carrying the same
    /// activation and retry once — the resync protocol.  Any other
    /// error code is fatal and surfaces as a [`ServerError`].
    #[allow(clippy::too_many_arguments)]
    fn stream_step(&mut self, request: u64, bucket: usize, len: usize,
                   ks: usize, kd: usize, point: u8, packed: &[f32])
        -> Result<(i32, f32)> {
        let geom = BlockGeom { rows: bucket, cols: self.d_model, ks, kd };
        let mut counted = false;
        for attempt in 0..2 {
            {
                let enc = self.encoder.as_mut().expect("stream mode");
                enc.encode_into(&mut self.engine, geom, packed,
                                &mut self.step_scratch)?;
            }
            // the codec's measured leftover drift is the rate
            // controller's second input (alongside the send pace): as
            // drift approaches the error budget the controller
            // upshifts back toward the primary point
            let drift = self.encoder.as_ref().expect("stream mode")
                .last_drift();
            if let Some(st) = self.adaptive.as_mut() {
                st.ctrl.observe_drift(drift);
            }
            let keyframe = self.step_scratch.keyframe;
            let mut packed = std::mem::take(&mut self.step_scratch.packed);
            let mut updates = std::mem::take(&mut self.step_scratch.updates);
            let mut coded = std::mem::take(&mut self.coded_scratch);
            coded.clear();
            if self.entropy {
                let raw = if keyframe {
                    packed.len() * 4
                } else {
                    4 + updates.len() * UPDATE_WIRE_BYTES
                };
                if keyframe {
                    wire::encode_f32_plane(&packed, &mut coded);
                } else {
                    wire::encode_updates(&updates, &mut coded);
                }
                if coded.len() < raw {
                    self.stats.entropy_frames += 1;
                    self.stats.pre_coding_bytes += raw as u64;
                    self.stats.post_coding_bytes += coded.len() as u64;
                } else {
                    self.stats.entropy_fallbacks += 1;
                    coded.clear();
                }
            }
            let is_coded = !coded.is_empty();
            if is_coded {
                // the coded bytes carry the step; the raw buffers
                // never leave, so recover them right away
                self.step_scratch.packed = std::mem::take(&mut packed);
                self.step_scratch.updates = std::mem::take(&mut updates);
            }
            let frame = Frame::Delta {
                session: self.session,
                request,
                seq: self.step_scratch.seq,
                keyframe,
                bucket: bucket as u16,
                true_len: len as u16,
                ks: ks as u16,
                kd: kd as u16,
                point,
                packed,
                updates,
                coded,
            };
            let b0 = self.stats.bytes_sent;
            self.timed_send(&frame)?;
            let sent = self.stats.bytes_sent - b0;
            if keyframe {
                self.stats.key_frames += 1;
                self.stats.key_bytes += sent;
            } else {
                self.stats.delta_frames += 1;
                self.stats.delta_bytes += sent;
            }
            // recover the frame buffers so the next step reuses them
            if let Frame::Delta { packed, updates, coded, .. } = frame {
                if !is_coded {
                    self.step_scratch.packed = packed;
                    self.step_scratch.updates = updates;
                }
                self.coded_scratch = coded;
            }
            if !counted {
                self.stats.requests += 1;
                counted = true;
            }
            loop {
                match self.recv()? {
                    Frame::Token { request: r, token, logprob }
                        if r == request => {
                        return Ok((token, logprob));
                    }
                    Frame::Token { .. } => continue, // stale reply
                    Frame::Error { code: ErrorCode::StreamReject, msg }
                        if !keyframe && attempt == 0 => {
                        // the server lost the stream state (TTL
                        // eviction, restart) or saw a gap: resync with
                        // a keyframe carrying the same activation
                        crate::debug!("client", "stream resync: {msg}");
                        self.stats.resyncs += 1;
                        self.encoder.as_mut().expect("stream mode")
                            .force_keyframe();
                        break;
                    }
                    Frame::Error { code, msg } => {
                        return Err(ServerError { code, msg }.into());
                    }
                    other => bail!("unexpected frame {}", other.type_id()),
                }
            }
        }
        bail!("stream resync failed: keyframe rejected")
    }

    /// Ship the prompt-phase activation and await the first token.
    /// With chunked prefill enabled the packed prompt plane is split
    /// into one keyframe chunk plus row-delta chunks
    /// ([`split_prefill`]) and streamed as `Frame::PrefillChunk`s at
    /// the prefill ladder rung; otherwise this is exactly
    /// [`DeviceClient::step`].  If the server rejects a chunk
    /// ([`ErrorCode::StreamReject`]: chunk-index gap, TTL-evicted
    /// mid-assembly state) the whole chunk sequence is resent once
    /// from chunk 0 — the keyframe-chunk resync protocol.  On success
    /// the delta encoder (stream mode) is seeded from the transmitted
    /// plane, so the first decode step rides a delta instead of
    /// paying a fresh keyframe.
    pub fn send_prompt(&mut self, context: &[i32]) -> Result<(i32, f32)> {
        let Some(cfg) = self.prefill else {
            return self.step(context);
        };
        let t1 = Instant::now();
        let ps = self.prepare_step_at(context, true)?;
        let request = ps.request;
        let geom = BlockGeom { rows: ps.bucket, cols: self.d_model,
                               ks: ps.ks, kd: ps.kd };
        let mut chunks = std::mem::take(&mut self.chunk_scratch);
        let mut state = std::mem::take(&mut self.prefill_state);
        split_prefill(&mut self.engine, geom, &ps.packed, cfg, &mut chunks,
                      &mut state)?;
        let mut reply = None;
        'attempt: for attempt in 0..2 {
            for ci in 0..chunks.len() {
                let (index, last, keyframe) =
                    (chunks[ci].index, chunks[ci].last, chunks[ci].keyframe);
                let mut packed = std::mem::take(&mut chunks[ci].packed);
                let mut updates = std::mem::take(&mut chunks[ci].updates);
                let mut coded = std::mem::take(&mut self.coded_scratch);
                coded.clear();
                if self.entropy {
                    let raw = if keyframe {
                        packed.len() * 4
                    } else {
                        4 + updates.len() * UPDATE_WIRE_BYTES
                    };
                    if keyframe {
                        wire::encode_f32_plane(&packed, &mut coded);
                    } else {
                        wire::encode_updates(&updates, &mut coded);
                    }
                    if coded.len() < raw {
                        self.stats.entropy_frames += 1;
                        self.stats.pre_coding_bytes += raw as u64;
                        self.stats.post_coding_bytes += coded.len() as u64;
                    } else {
                        self.stats.entropy_fallbacks += 1;
                        coded.clear();
                    }
                }
                let is_coded = !coded.is_empty();
                if is_coded {
                    // the coded bytes carry the chunk; the raw buffers
                    // never leave, so recover them right away
                    chunks[ci].packed = std::mem::take(&mut packed);
                    chunks[ci].updates = std::mem::take(&mut updates);
                }
                let frame = Frame::PrefillChunk {
                    session: self.session,
                    request,
                    bucket: ps.bucket as u16,
                    true_len: ps.len as u16,
                    ks: ps.ks as u16,
                    kd: ps.kd as u16,
                    point: ps.point,
                    index,
                    last,
                    keyframe,
                    packed,
                    updates,
                    coded,
                };
                let b0 = self.stats.bytes_sent;
                self.timed_send(&frame)?;
                self.stats.prefill_bytes += self.stats.bytes_sent - b0;
                self.stats.prefill_chunks += 1;
                if keyframe {
                    self.stats.prefill_key_chunks += 1;
                }
                // recover the chunk + coded buffers for reuse (resend
                // attempt / next prompt)
                if let Frame::PrefillChunk { packed, updates, coded, .. }
                    = frame {
                    if !is_coded {
                        chunks[ci].packed = packed;
                        chunks[ci].updates = updates;
                    }
                    self.coded_scratch = coded;
                }
            }
            if attempt == 0 {
                self.stats.requests += 1;
            }
            loop {
                match self.recv()? {
                    Frame::Token { request: r, token, logprob }
                        if r == request => {
                        reply = Some((token, logprob));
                        break 'attempt;
                    }
                    Frame::Token { .. } => continue, // stale reply
                    Frame::Error { code: ErrorCode::StreamReject, msg }
                        if attempt == 0 => {
                        // the server lost or refused the mid-assembly
                        // state (chunk gap after a drop, TTL eviction):
                        // resend the whole sequence — its chunk 0 is
                        // the keyframe-chunk restart
                        crate::debug!("client", "prefill resync: {msg}");
                        self.stats.prefill_resyncs += 1;
                        break;
                    }
                    Frame::Error { code, msg } => {
                        return Err(ServerError { code, msg }.into());
                    }
                    other => bail!("unexpected frame {}", other.type_id()),
                }
            }
        }
        let Some(reply) = reply else {
            bail!("prefill resync failed: restarted chunk sequence rejected");
        };
        self.stats.prefill_prompts += 1;
        // hand the stream encoder the transmitted plane the server's
        // assembler reconstructed, so decode step 1 can be a delta
        if self.encoder.is_some() {
            self.encoder.as_mut().expect("stream mode")
                .seed(&mut self.engine, geom, &state)?;
        }
        self.packed_scratch = ps.packed;
        self.chunk_scratch = chunks;
        self.prefill_state = state;
        self.stats.round_trip_us.push(t1.elapsed().as_micros() as u64);
        Ok(reply)
    }

    /// Autoregressive generation (recompute regime).
    pub fn generate(&mut self, prompt: &str, max_new: usize) -> Result<Generation> {
        let mut context = tokenizer::encode_prompt(prompt);
        let mut produced = Vec::new();
        let max_bucket = *self.buckets.keys().last().unwrap_or(&64);
        for step_i in 0..max_new {
            if context.len() >= max_bucket {
                break;
            }
            // the first step is the prompt phase: send_prompt ships
            // it chunked when prefill is enabled, and falls back to
            // an ordinary step otherwise
            let (token, _lp) = if step_i == 0 {
                self.send_prompt(&context)?
            } else {
                self.step(&context)?
            };
            context.push(token);
            produced.push(token);
            if token == tokenizer::EOS || token == tokenizer::PAD {
                break;
            }
            // sentence terminator in the fact-world corpus
            if token == b'.' as i32 && produced.len() > 1 {
                break;
            }
        }
        Ok(Generation {
            prompt: prompt.to_string(),
            completion: tokenizer::decode(&produced),
            tokens: produced.clone(),
            steps: produced.len(),
        })
    }

    pub fn server_stats(&mut self) -> Result<String> {
        self.send(&Frame::GetStats)?;
        loop {
            match self.recv()? {
                Frame::Stats { json } => return Ok(json),
                Frame::Token { .. } => continue,
                Frame::Error { code, msg } => {
                    return Err(ServerError { code, msg }.into());
                }
                other => bail!("unexpected frame {}", other.type_id()),
            }
        }
    }

    pub fn bye(&mut self) -> Result<()> {
        self.send(&Frame::Bye)
    }
}
