//! L3 coordinator — the serving system: a device-side client runs
//! embed + layer 1 + the pallas FC codec (one fused HLO), ships the
//! compressed block over a (optionally bandwidth-shaped) TCP link; the
//! edge server reconstructs and finishes the model inside dynamically
//! formed batches, with per-session state and metrics.
//!
//! Generation follows the paper's recompute regime: every decode step
//! re-sends the (growing) prompt's compressed activation — this is
//! precisely the bandwidth amplification Fig 1 describes and Fig 7
//! measures; `kv-cache mode` is analysed as an ablation in
//! EXPERIMENTS.md.

pub mod batcher;
pub mod client;
pub mod metrics;
pub mod protocol;
pub mod server;
pub mod session;

pub use client::DeviceClient;
pub use server::{EdgeServer, ServerHandle};
