//! L3 coordinator — the serving system, redesigned around three
//! seams (the "serving API v2"):
//!
//! * [`transport`] — a [`transport::Transport`] is any framed,
//!   ordered, bidirectional link: TCP for production, in-proc
//!   (mpsc-backed, zero sockets) for hermetic tests and the sim's
//!   live probe, and a shaped decorator adding bandwidth emulation +
//!   deterministic frame drops.
//! * [`protocol`] — versioned frames with a negotiated handshake:
//!   `Hello` (magic + version + capability bits) is answered by
//!   `HelloAck` (server capabilities + bucket geometry), and every
//!   `Error` carries a typed code.
//! * [`server::ServingService`] — the transport-agnostic service
//!   core: sessions, dynamic batching, metrics, and frame semantics
//!   behind a typed `handle(frame) -> Response` API; the TCP accept
//!   loop and the in-proc connector are thin adapters over it.
//!
//! The core is sharded and event-driven: session state lives in a
//! [`ShardedSessions`] table (hash-partitioned, per-shard locks),
//! every connection is multiplexed over a fixed [`PollPool`] of
//! readiness-polling workers (no thread per connection), and the
//! continuous [`BatchFeed`] of per-bucket micro-queues feeds the
//! compute workers directly.
//!
//! A device-side [`DeviceClient`] runs embed + layer 1 + the pallas
//! FC codec (one fused HLO), negotiates features at connect, and
//! ships compressed blocks — full recompute activations or spectral
//! stream deltas — to the service, which reconstructs and finishes
//! the model inside dynamically formed batches.
//!
//! Generation follows the paper's recompute regime by default: every
//! decode step re-sends the (growing) prompt's compressed activation
//! — this is precisely the bandwidth amplification Fig 1 describes
//! and Fig 7 measures; the spectral delta stream (`codec::stream`)
//! removes it when both sides negotiate the stream capability.

pub mod batcher;
pub mod client;
pub mod metrics;
pub mod obs;
pub mod poll;
pub mod protocol;
pub mod server;
pub mod session;
pub mod transport;

pub use batcher::{BatchFeed, Feed};
pub use client::{DeviceClient, CLIENT_CAPS};
pub use obs::{span_id, FlightEvent, FlightKind, FlightRecorder, Obs,
              StepTrace, Tracer};
pub use poll::PollPool;
pub use server::{serve_transport, start_service, EdgeServer, Reply,
                 Response, ServerHandle, ServiceHandle, ServingService};
pub use session::{SessionManager, ShardedSessions};
pub use transport::{FrameRx, FrameTx, InProcTransport, ShapedTransport,
                    TcpTransport, Transport};
