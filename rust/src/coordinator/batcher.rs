//! Dynamic batcher: groups decompressed activations by sequence
//! bucket and flushes a batch when it reaches `max_batch` or its
//! oldest member ages past the deadline — the standard
//! continuous-batching policy scaled to this testbed.  A `max_batch
//! == 1` configuration is the paper-faithful no-batching ablation.
//!
//! Two layers live here.  [`Batcher`] is the single-threaded policy
//! core (unit-testable, no locks).  [`BatchFeed`] is the shared
//! continuous feed the serving core runs on: per-bucket micro-queues
//! behind their own mutexes so a push from one connection's poll
//! worker never contends with a push to a different bucket, plus a
//! condvar gate the compute workers park on.  The flush policy is
//! identical to `Batcher` by construction (full buckets first, then
//! deadline-expired, oldest head winning with the bucket id breaking
//! ties) — the `feed_matches_batcher_policy` test pins that.

use std::collections::HashMap;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One queued request (activation already unpacked to the full block).
pub struct Pending<T> {
    pub item: T,
    pub enqueued: Instant,
}

/// Bucketed accumulation with deadline flushing.  Generic over the
/// item type so the policy is unit-testable without a runtime.
pub struct Batcher<T> {
    queues: HashMap<usize, Vec<Pending<T>>>,
    pub max_batch: usize,
    pub deadline: Duration,
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize, deadline: Duration) -> Batcher<T> {
        Batcher { queues: HashMap::new(), max_batch, deadline }
    }

    pub fn push(&mut self, bucket: usize, item: T) {
        self.queues
            .entry(bucket)
            .or_default()
            .push(Pending { item, enqueued: Instant::now() });
    }

    /// A bucket ready to flush right now, if any: full buckets first,
    /// then deadline-expired ones.  Selection is deterministic —
    /// among candidates the one whose head waited longest wins, the
    /// bucket id breaking ties — where it used to iterate the
    /// `HashMap` and flush whichever candidate hash order surfaced
    /// first (a run-to-run nondeterminism the batching tests could
    /// never pin).
    pub fn ready_bucket(&self, now: Instant) -> Option<usize> {
        let full = self
            .queues
            .iter()
            .filter(|(_, q)| q.len() >= self.max_batch)
            .filter_map(|(&b, q)| q.first().map(|p| (p.enqueued, b)))
            .min()
            .map(|(_, b)| b);
        if full.is_some() {
            return full;
        }
        self.queues
            .iter()
            .filter_map(|(&b, q)| q.first().map(|p| (p.enqueued, b)))
            .filter(|&(t, _)| now.duration_since(t) >= self.deadline)
            .min()
            .map(|(_, b)| b)
    }

    /// Pop up to `max_batch` items from the bucket.
    pub fn take(&mut self, bucket: usize) -> Vec<Pending<T>> {
        let q = self.queues.entry(bucket).or_default();
        let n = q.len().min(self.max_batch);
        let rest = q.split_off(n);
        let out = std::mem::replace(q, rest);
        if self.queues.get(&bucket).map(|q| q.is_empty()).unwrap_or(false) {
            self.queues.remove(&bucket);
        }
        out
    }

    /// Time until the next deadline flush (None if nothing queued).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queues
            .values()
            .filter_map(|q| q.first())
            .map(|p| {
                self.deadline
                    .checked_sub(now.duration_since(p.enqueued))
                    .unwrap_or(Duration::ZERO)
            })
            .min()
    }

    pub fn queued(&self) -> usize {
        self.queues.values().map(|q| q.len()).sum()
    }
}

/// What a compute worker gets back from [`BatchFeed::wait_take`].
pub enum Feed<T> {
    /// A flushable group: the bucket id and its (≤ `max_batch`) items.
    Group(usize, Vec<Pending<T>>),
    /// Nothing became ready within the caller's patience.
    TimedOut,
    /// The feed is closed and fully drained — workers should exit.
    Closed,
}

struct Gate {
    /// Bumped on every push/close so waiters can detect a wakeup they
    /// raced past (scan found nothing, push landed before the park).
    seq: u64,
    closed: bool,
}

/// Continuous cross-connection batching feed.  One instance is shared
/// by every poll worker (producers) and every compute worker
/// (consumers); there is no dedicated batcher thread and no global
/// queue lock — each bucket has its own micro-queue mutex, and the
/// condvar gate is only touched to park/wake.
pub struct BatchFeed<T> {
    /// Sorted by bucket id; fixed at construction from the model's
    /// bucket set so a push is a binary search + one bucket lock.
    buckets: Vec<(usize, Mutex<Vec<Pending<T>>>)>,
    max_batch: usize,
    deadline: Duration,
    gate: Mutex<Gate>,
    cv: Condvar,
}

impl<T> BatchFeed<T> {
    pub fn new(bucket_ids: &[usize], max_batch: usize, deadline: Duration) -> BatchFeed<T> {
        let mut ids: Vec<usize> = bucket_ids.to_vec();
        ids.sort_unstable();
        ids.dedup();
        BatchFeed {
            buckets: ids.into_iter().map(|b| (b, Mutex::new(Vec::new()))).collect(),
            max_batch: max_batch.max(1),
            deadline,
            gate: Mutex::new(Gate { seq: 0, closed: false }),
            cv: Condvar::new(),
        }
    }

    fn slot(&self, bucket: usize) -> Option<&Mutex<Vec<Pending<T>>>> {
        self.buckets
            .binary_search_by_key(&bucket, |(b, _)| *b)
            .ok()
            .map(|i| &self.buckets[i].1)
    }

    /// Enqueue into the bucket's micro-queue and wake one consumer.
    /// Returns false (item dropped) if the bucket is unknown or the
    /// feed is closed — the caller should fail the request, not spin.
    pub fn push(&self, bucket: usize, item: T) -> bool {
        let Some(slot) = self.slot(bucket) else { return false };
        {
            let g = self.gate.lock().unwrap();
            if g.closed {
                return false;
            }
        }
        slot.lock().unwrap().push(Pending { item, enqueued: Instant::now() });
        let mut g = self.gate.lock().unwrap();
        g.seq += 1;
        drop(g);
        self.cv.notify_one();
        true
    }

    /// The `Batcher::ready_bucket` policy over the micro-queues: full
    /// buckets first, then deadline-expired ones, the oldest head
    /// winning and the bucket id breaking ties.  When `flush_all` is
    /// set (shutdown drain) any non-empty bucket qualifies.
    fn ready_bucket(&self, now: Instant, flush_all: bool) -> Option<usize> {
        let mut full: Option<(Instant, usize)> = None;
        let mut aged: Option<(Instant, usize)> = None;
        for (b, q) in &self.buckets {
            let q = q.lock().unwrap();
            let Some(head) = q.first() else { continue };
            let key = (head.enqueued, *b);
            if q.len() >= self.max_batch {
                full = Some(full.map_or(key, |k| k.min(key)));
            }
            if flush_all || now.duration_since(head.enqueued) >= self.deadline {
                aged = Some(aged.map_or(key, |k| k.min(key)));
            }
        }
        full.or(aged).map(|(_, b)| b)
    }

    /// Earliest pending deadline across buckets (for park timeouts).
    fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.buckets
            .iter()
            .filter_map(|(_, q)| q.lock().unwrap().first().map(|p| p.enqueued))
            .map(|t| self.deadline.checked_sub(now.duration_since(t)).unwrap_or(Duration::ZERO))
            .min()
    }

    fn take(&self, bucket: usize) -> Vec<Pending<T>> {
        let Some(slot) = self.slot(bucket) else { return Vec::new() };
        let mut q = slot.lock().unwrap();
        let n = q.len().min(self.max_batch);
        let rest = q.split_off(n);
        std::mem::replace(&mut *q, rest)
    }

    /// Block until a group is ready, the feed closes (and drains), or
    /// `patience` elapses.  Many compute workers may wait at once;
    /// each flushed group goes to exactly one of them.
    pub fn wait_take(&self, patience: Duration) -> Feed<T> {
        let give_up = Instant::now() + patience;
        loop {
            let (seq0, closed) = {
                let g = self.gate.lock().unwrap();
                (g.seq, g.closed)
            };
            let now = Instant::now();
            if let Some(b) = self.ready_bucket(now, closed) {
                let got = self.take(b);
                if !got.is_empty() {
                    // siblings may still have work; pass the wakeup on
                    self.cv.notify_one();
                    return Feed::Group(b, got);
                }
                continue; // another worker drained it between scan and take
            }
            if closed {
                return Feed::Closed;
            }
            if now >= give_up {
                return Feed::TimedOut;
            }
            let mut wait = give_up - now;
            if let Some(d) = self.next_deadline(now) {
                wait = wait.min(d.max(Duration::from_micros(50)));
            }
            let g = self.gate.lock().unwrap();
            if g.seq == seq0 && !g.closed {
                let _ = self.cv.wait_timeout(g, wait).unwrap();
            }
        }
    }

    /// Close the feed: pushes start failing, parked workers wake, and
    /// `wait_take` flushes whatever is still queued before reporting
    /// [`Feed::Closed`].
    pub fn close(&self) {
        let mut g = self.gate.lock().unwrap();
        g.closed = true;
        g.seq += 1;
        drop(g);
        self.cv.notify_all();
    }

    pub fn queued(&self) -> usize {
        self.buckets.iter().map(|(_, q)| q.lock().unwrap().len()).sum()
    }

    /// Momentary per-bucket depths `(bucket id, queued items)` — the
    /// queue-depth gauge the observability snapshot reads.
    pub fn depths(&self) -> Vec<(usize, usize)> {
        self.buckets
            .iter()
            .map(|(b, q)| (*b, q.lock().unwrap().len()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flushes_when_full() {
        let mut b: Batcher<u32> = Batcher::new(4, Duration::from_secs(10));
        for i in 0..4 {
            b.push(32, i);
        }
        assert_eq!(b.ready_bucket(Instant::now()), Some(32));
        let got = b.take(32);
        assert_eq!(got.len(), 4);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn does_not_mix_buckets() {
        let mut b: Batcher<u32> = Batcher::new(2, Duration::from_secs(10));
        b.push(16, 1);
        b.push(32, 2);
        b.push(16, 3);
        assert_eq!(b.ready_bucket(Instant::now()), Some(16));
        let got = b.take(16);
        assert_eq!(got.len(), 2);
        assert!(got.iter().all(|p| [1, 3].contains(&p.item)));
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn deadline_flush() {
        let mut b: Batcher<u32> = Batcher::new(8, Duration::from_millis(5));
        b.push(64, 7);
        assert_eq!(b.ready_bucket(Instant::now()), None);
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(b.ready_bucket(Instant::now()), Some(64));
        assert_eq!(b.take(64).len(), 1);
    }

    #[test]
    fn take_caps_at_max_batch() {
        let mut b: Batcher<u32> = Batcher::new(3, Duration::from_secs(10));
        for i in 0..7 {
            b.push(48, i);
        }
        assert_eq!(b.take(48).len(), 3);
        assert_eq!(b.queued(), 4);
        // FIFO order preserved
        let next = b.take(48);
        assert_eq!(next[0].item, 3);
    }

    #[test]
    fn next_deadline_monotone() {
        let mut b: Batcher<u32> = Batcher::new(8, Duration::from_millis(100));
        assert!(b.next_deadline(Instant::now()).is_none());
        b.push(16, 1);
        let d = b.next_deadline(Instant::now()).unwrap();
        assert!(d <= Duration::from_millis(100));
    }

    #[test]
    fn full_bucket_flush_precedes_deadline_flush() {
        let mut b: Batcher<u32> = Batcher::new(2, Duration::from_millis(5));
        b.push(16, 1); // will age past the deadline
        std::thread::sleep(Duration::from_millis(10));
        b.push(32, 2);
        b.push(32, 3); // full right now
        // both buckets are flushable; the full one must win
        assert_eq!(b.ready_bucket(Instant::now()), Some(32),
                   "full-bucket flush must take precedence");
        assert_eq!(b.take(32).len(), 2);
        // then the aged bucket drains via its deadline
        assert_eq!(b.ready_bucket(Instant::now()), Some(16));
        assert_eq!(b.take(16).len(), 1);
        assert_eq!(b.ready_bucket(Instant::now()), None);
    }

    #[test]
    fn max_batch_one_is_paper_faithful_no_batching() {
        // the paper's unbatched ablation: every request flushes alone,
        // immediately, in FIFO order — the deadline never matters
        let mut b: Batcher<u32> = Batcher::new(1, Duration::from_secs(10));
        for i in 0..5 {
            b.push(64, i);
        }
        for want in 0..5u32 {
            let bucket = b.ready_bucket(Instant::now())
                .expect("max_batch=1 queues are always ready");
            assert_eq!(bucket, 64);
            let got = b.take(bucket);
            assert_eq!(got.len(), 1, "no batching at max_batch=1");
            assert_eq!(got[0].item, want);
        }
        assert_eq!(b.queued(), 0);
        assert_eq!(b.ready_bucket(Instant::now()), None);
    }

    #[test]
    fn aged_bucket_starves_behind_busy_bucket_until_it_drains() {
        let mut b: Batcher<u32> = Batcher::new(2, Duration::from_millis(10));
        b.push(16, 99);
        std::thread::sleep(Duration::from_millis(30)); // 16 is now aged
        // a busy bucket that keeps refilling to max_batch is serviced
        // first every round — the aged bucket waits behind it (this is
        // the documented full-first policy, pinned here)
        for round in 0..3u32 {
            b.push(32, round);
            b.push(32, round + 100);
            assert_eq!(b.ready_bucket(Instant::now()), Some(32),
                       "round {round}: full bucket must still win");
            assert_eq!(b.take(32).len(), 2);
        }
        // the moment no bucket is full, the aged one flushes — even
        // though the busy bucket still holds a (younger) item
        b.push(32, 7);
        assert_eq!(b.ready_bucket(Instant::now()), Some(16),
                   "aged bucket must flush once no bucket is full");
        assert_eq!(b.take(16).len(), 1);
        assert_eq!(b.queued(), 1); // the young 32-item is still queued
    }

    #[test]
    fn ready_bucket_is_deterministic_oldest_head_first() {
        // two simultaneously-full buckets: the one whose head waited
        // longest flushes first, regardless of HashMap hash order —
        // and the answer is stable across repeated queries
        let mut b: Batcher<u32> = Batcher::new(1, Duration::from_secs(10));
        b.push(64, 1);
        std::thread::sleep(Duration::from_millis(2));
        b.push(16, 2);
        b.push(48, 3);
        for _ in 0..100 {
            assert_eq!(b.ready_bucket(Instant::now()), Some(64),
                       "oldest full head must win");
        }
        assert_eq!(b.take(64).len(), 1);
        // 16 and 48 were pushed back to back; whichever head is older
        // wins — and that answer never changes between queries
        let first = b.ready_bucket(Instant::now()).unwrap();
        for _ in 0..100 {
            assert_eq!(b.ready_bucket(Instant::now()), Some(first));
        }
        b.take(first);

        // expired path: same oldest-head-first rule
        let mut b: Batcher<u32> = Batcher::new(8, Duration::from_millis(5));
        b.push(48, 1);
        std::thread::sleep(Duration::from_millis(2));
        b.push(32, 2);
        std::thread::sleep(Duration::from_millis(10)); // both expired
        for _ in 0..100 {
            assert_eq!(b.ready_bucket(Instant::now()), Some(48),
                       "oldest expired head must win");
        }
        assert_eq!(b.take(48).len(), 1);
        assert_eq!(b.ready_bucket(Instant::now()), Some(32));
    }

    // property-style sweep: conservation — everything pushed is taken
    // exactly once, never crossing buckets
    #[test]
    fn conservation_property() {
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..50 {
            let max_batch = 1 + rng.below(6);
            let mut b: Batcher<(usize, u64)> =
                Batcher::new(max_batch, Duration::from_secs(100));
            let n = 1 + rng.below(40);
            let mut pushed: Vec<(usize, u64)> = Vec::new();
            for i in 0..n {
                let bucket = [16usize, 32, 48, 64][rng.below(4)];
                b.push(bucket, (bucket, i as u64));
                pushed.push((bucket, i as u64));
            }
            let mut taken = Vec::new();
            while b.queued() > 0 {
                let bucket = *b.queues.keys().next().unwrap();
                for p in b.take(bucket) {
                    assert_eq!(p.item.0, bucket, "item crossed buckets");
                    taken.push(p.item);
                }
            }
            taken.sort();
            pushed.sort();
            assert_eq!(taken, pushed);
        }
    }

    // ---- BatchFeed: the shared continuous feed ----

    #[test]
    fn feed_flushes_full_bucket_immediately() {
        let f: BatchFeed<u32> =
            BatchFeed::new(&[16, 32], 2, Duration::from_secs(10));
        assert!(f.push(32, 1));
        assert!(f.push(32, 2));
        match f.wait_take(Duration::from_millis(50)) {
            Feed::Group(b, items) => {
                assert_eq!(b, 32);
                assert_eq!(items.len(), 2);
            }
            _ => panic!("full bucket must flush without waiting"),
        }
        assert!(matches!(f.wait_take(Duration::from_millis(1)), Feed::TimedOut));
    }

    #[test]
    fn feed_rejects_unknown_bucket_and_push_after_close() {
        let f: BatchFeed<u32> =
            BatchFeed::new(&[16], 4, Duration::from_secs(10));
        assert!(!f.push(99, 1), "unknown bucket must be refused");
        f.close();
        assert!(!f.push(16, 1), "push after close must be refused");
        assert!(matches!(f.wait_take(Duration::from_millis(1)), Feed::Closed));
    }

    #[test]
    fn feed_close_drains_remainder_before_reporting_closed() {
        let f: BatchFeed<u32> =
            BatchFeed::new(&[16, 32], 8, Duration::from_secs(100));
        f.push(16, 1);
        std::thread::sleep(Duration::from_millis(2));
        f.push(32, 2);
        f.close();
        // neither bucket is full or expired, but close flushes both —
        // oldest head first — before workers are released
        match f.wait_take(Duration::from_millis(50)) {
            Feed::Group(b, items) => {
                assert_eq!(b, 16);
                assert_eq!(items.len(), 1);
            }
            _ => panic!("close must drain queued work"),
        }
        match f.wait_take(Duration::from_millis(50)) {
            Feed::Group(b, _) => assert_eq!(b, 32),
            _ => panic!("close must drain every bucket"),
        }
        assert!(matches!(f.wait_take(Duration::from_millis(1)), Feed::Closed));
    }

    #[test]
    fn feed_wakes_parked_consumer_on_push() {
        use std::sync::Arc;
        let f: Arc<BatchFeed<u32>> =
            Arc::new(BatchFeed::new(&[64], 1, Duration::from_secs(10)));
        let g = Arc::clone(&f);
        let consumer = std::thread::spawn(move || {
            match g.wait_take(Duration::from_secs(5)) {
                Feed::Group(64, items) => items[0].item,
                _ => panic!("consumer should receive the pushed item"),
            }
        });
        std::thread::sleep(Duration::from_millis(10)); // let it park
        let t0 = Instant::now();
        assert!(f.push(64, 7));
        assert_eq!(consumer.join().unwrap(), 7);
        assert!(t0.elapsed() < Duration::from_secs(1),
                "push must wake the parked consumer, not wait out the timeout");
    }

    #[test]
    fn feed_matches_batcher_policy() {
        // drive identical workloads through the lock-free-ish feed and
        // the reference Batcher; flush order must agree exactly
        let mut rng = crate::util::rng::Rng::new(41);
        for _ in 0..30 {
            let max_batch = 1 + rng.below(4);
            let ids = [16usize, 32, 48, 64];
            let feed: BatchFeed<u64> =
                BatchFeed::new(&ids, max_batch, Duration::ZERO);
            let mut reference: Batcher<u64> =
                Batcher::new(max_batch, Duration::ZERO);
            let n = 1 + rng.below(30);
            for i in 0..n {
                let b = ids[rng.below(4)];
                // interleave so enqueue timestamps order the same way
                assert!(feed.push(b, i as u64));
                reference.push(b, i as u64);
            }
            // deadline ZERO: everything is aged, so the pure policy
            // (full-first, oldest-head, bucket-id tiebreak) decides
            loop {
                let want = reference.ready_bucket(Instant::now());
                match feed.wait_take(Duration::from_millis(5)) {
                    Feed::Group(b, items) => {
                        assert_eq!(Some(b), want, "flush order diverged");
                        let got: Vec<u64> =
                            items.iter().map(|p| p.item).collect();
                        let refs: Vec<u64> = reference
                            .take(b)
                            .iter()
                            .map(|p| p.item)
                            .collect();
                        assert_eq!(got, refs, "group contents diverged");
                    }
                    Feed::TimedOut => {
                        assert_eq!(want, None);
                        break;
                    }
                    Feed::Closed => unreachable!(),
                }
            }
            assert_eq!(feed.queued(), 0);
            assert_eq!(reference.queued(), 0);
        }
    }

    #[test]
    fn feed_concurrent_producers_conserve_items() {
        use std::sync::Arc;
        let ids = [16usize, 32, 48];
        let f: Arc<BatchFeed<u64>> =
            Arc::new(BatchFeed::new(&ids, 3, Duration::from_millis(1)));
        let mut producers = Vec::new();
        for t in 0..8u64 {
            let f = Arc::clone(&f);
            producers.push(std::thread::spawn(move || {
                for i in 0..50u64 {
                    let tag = t * 1000 + i;
                    assert!(f.push(ids[(tag % 3) as usize], tag));
                }
            }));
        }
        let drainer = {
            let f = Arc::clone(&f);
            std::thread::spawn(move || {
                let mut got = Vec::new();
                let mut dry_rounds = 0;
                while got.len() < 400 {
                    match f.wait_take(Duration::from_millis(100)) {
                        Feed::Group(b, items) => {
                            dry_rounds = 0;
                            for p in items {
                                assert_eq!(ids[(p.item % 3) as usize], b,
                                           "item crossed buckets");
                                got.push(p.item);
                            }
                        }
                        Feed::TimedOut => {
                            dry_rounds += 1;
                            assert!(dry_rounds < 50,
                                    "feed went dry at {} of 400 items",
                                    got.len());
                        }
                        Feed::Closed => break,
                    }
                }
                got
            })
        };
        for p in producers {
            p.join().unwrap();
        }
        let mut got = drainer.join().unwrap();
        got.sort_unstable();
        let mut want: Vec<u64> =
            (0..8).flat_map(|t| (0..50).map(move |i| t * 1000 + i)).collect();
        want.sort_unstable();
        assert_eq!(got, want, "every pushed item drained exactly once");
    }
}
