//! Dynamic batcher: groups decompressed activations by sequence
//! bucket and flushes a batch when it reaches `max_batch` or its
//! oldest member ages past the deadline — the standard
//! continuous-batching policy scaled to this testbed.  A `max_batch
//! == 1` configuration is the paper-faithful no-batching ablation.

use std::collections::HashMap;
use std::time::{Duration, Instant};

/// One queued request (activation already unpacked to the full block).
pub struct Pending<T> {
    pub item: T,
    pub enqueued: Instant,
}

/// Bucketed accumulation with deadline flushing.  Generic over the
/// item type so the policy is unit-testable without a runtime.
pub struct Batcher<T> {
    queues: HashMap<usize, Vec<Pending<T>>>,
    pub max_batch: usize,
    pub deadline: Duration,
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize, deadline: Duration) -> Batcher<T> {
        Batcher { queues: HashMap::new(), max_batch, deadline }
    }

    pub fn push(&mut self, bucket: usize, item: T) {
        self.queues
            .entry(bucket)
            .or_default()
            .push(Pending { item, enqueued: Instant::now() });
    }

    /// A bucket ready to flush right now, if any: full buckets first,
    /// then deadline-expired ones.  Selection is deterministic —
    /// among candidates the one whose head waited longest wins, the
    /// bucket id breaking ties — where it used to iterate the
    /// `HashMap` and flush whichever candidate hash order surfaced
    /// first (a run-to-run nondeterminism the batching tests could
    /// never pin).
    pub fn ready_bucket(&self, now: Instant) -> Option<usize> {
        let full = self
            .queues
            .iter()
            .filter(|(_, q)| q.len() >= self.max_batch)
            .filter_map(|(&b, q)| q.first().map(|p| (p.enqueued, b)))
            .min()
            .map(|(_, b)| b);
        if full.is_some() {
            return full;
        }
        self.queues
            .iter()
            .filter_map(|(&b, q)| q.first().map(|p| (p.enqueued, b)))
            .filter(|&(t, _)| now.duration_since(t) >= self.deadline)
            .min()
            .map(|(_, b)| b)
    }

    /// Pop up to `max_batch` items from the bucket.
    pub fn take(&mut self, bucket: usize) -> Vec<Pending<T>> {
        let q = self.queues.entry(bucket).or_default();
        let n = q.len().min(self.max_batch);
        let rest = q.split_off(n);
        let out = std::mem::replace(q, rest);
        if self.queues.get(&bucket).map(|q| q.is_empty()).unwrap_or(false) {
            self.queues.remove(&bucket);
        }
        out
    }

    /// Time until the next deadline flush (None if nothing queued).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queues
            .values()
            .filter_map(|q| q.first())
            .map(|p| {
                self.deadline
                    .checked_sub(now.duration_since(p.enqueued))
                    .unwrap_or(Duration::ZERO)
            })
            .min()
    }

    pub fn queued(&self) -> usize {
        self.queues.values().map(|q| q.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flushes_when_full() {
        let mut b: Batcher<u32> = Batcher::new(4, Duration::from_secs(10));
        for i in 0..4 {
            b.push(32, i);
        }
        assert_eq!(b.ready_bucket(Instant::now()), Some(32));
        let got = b.take(32);
        assert_eq!(got.len(), 4);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn does_not_mix_buckets() {
        let mut b: Batcher<u32> = Batcher::new(2, Duration::from_secs(10));
        b.push(16, 1);
        b.push(32, 2);
        b.push(16, 3);
        assert_eq!(b.ready_bucket(Instant::now()), Some(16));
        let got = b.take(16);
        assert_eq!(got.len(), 2);
        assert!(got.iter().all(|p| [1, 3].contains(&p.item)));
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn deadline_flush() {
        let mut b: Batcher<u32> = Batcher::new(8, Duration::from_millis(5));
        b.push(64, 7);
        assert_eq!(b.ready_bucket(Instant::now()), None);
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(b.ready_bucket(Instant::now()), Some(64));
        assert_eq!(b.take(64).len(), 1);
    }

    #[test]
    fn take_caps_at_max_batch() {
        let mut b: Batcher<u32> = Batcher::new(3, Duration::from_secs(10));
        for i in 0..7 {
            b.push(48, i);
        }
        assert_eq!(b.take(48).len(), 3);
        assert_eq!(b.queued(), 4);
        // FIFO order preserved
        let next = b.take(48);
        assert_eq!(next[0].item, 3);
    }

    #[test]
    fn next_deadline_monotone() {
        let mut b: Batcher<u32> = Batcher::new(8, Duration::from_millis(100));
        assert!(b.next_deadline(Instant::now()).is_none());
        b.push(16, 1);
        let d = b.next_deadline(Instant::now()).unwrap();
        assert!(d <= Duration::from_millis(100));
    }

    #[test]
    fn full_bucket_flush_precedes_deadline_flush() {
        let mut b: Batcher<u32> = Batcher::new(2, Duration::from_millis(5));
        b.push(16, 1); // will age past the deadline
        std::thread::sleep(Duration::from_millis(10));
        b.push(32, 2);
        b.push(32, 3); // full right now
        // both buckets are flushable; the full one must win
        assert_eq!(b.ready_bucket(Instant::now()), Some(32),
                   "full-bucket flush must take precedence");
        assert_eq!(b.take(32).len(), 2);
        // then the aged bucket drains via its deadline
        assert_eq!(b.ready_bucket(Instant::now()), Some(16));
        assert_eq!(b.take(16).len(), 1);
        assert_eq!(b.ready_bucket(Instant::now()), None);
    }

    #[test]
    fn max_batch_one_is_paper_faithful_no_batching() {
        // the paper's unbatched ablation: every request flushes alone,
        // immediately, in FIFO order — the deadline never matters
        let mut b: Batcher<u32> = Batcher::new(1, Duration::from_secs(10));
        for i in 0..5 {
            b.push(64, i);
        }
        for want in 0..5u32 {
            let bucket = b.ready_bucket(Instant::now())
                .expect("max_batch=1 queues are always ready");
            assert_eq!(bucket, 64);
            let got = b.take(bucket);
            assert_eq!(got.len(), 1, "no batching at max_batch=1");
            assert_eq!(got[0].item, want);
        }
        assert_eq!(b.queued(), 0);
        assert_eq!(b.ready_bucket(Instant::now()), None);
    }

    #[test]
    fn aged_bucket_starves_behind_busy_bucket_until_it_drains() {
        let mut b: Batcher<u32> = Batcher::new(2, Duration::from_millis(10));
        b.push(16, 99);
        std::thread::sleep(Duration::from_millis(30)); // 16 is now aged
        // a busy bucket that keeps refilling to max_batch is serviced
        // first every round — the aged bucket waits behind it (this is
        // the documented full-first policy, pinned here)
        for round in 0..3u32 {
            b.push(32, round);
            b.push(32, round + 100);
            assert_eq!(b.ready_bucket(Instant::now()), Some(32),
                       "round {round}: full bucket must still win");
            assert_eq!(b.take(32).len(), 2);
        }
        // the moment no bucket is full, the aged one flushes — even
        // though the busy bucket still holds a (younger) item
        b.push(32, 7);
        assert_eq!(b.ready_bucket(Instant::now()), Some(16),
                   "aged bucket must flush once no bucket is full");
        assert_eq!(b.take(16).len(), 1);
        assert_eq!(b.queued(), 1); // the young 32-item is still queued
    }

    #[test]
    fn ready_bucket_is_deterministic_oldest_head_first() {
        // two simultaneously-full buckets: the one whose head waited
        // longest flushes first, regardless of HashMap hash order —
        // and the answer is stable across repeated queries
        let mut b: Batcher<u32> = Batcher::new(1, Duration::from_secs(10));
        b.push(64, 1);
        std::thread::sleep(Duration::from_millis(2));
        b.push(16, 2);
        b.push(48, 3);
        for _ in 0..100 {
            assert_eq!(b.ready_bucket(Instant::now()), Some(64),
                       "oldest full head must win");
        }
        assert_eq!(b.take(64).len(), 1);
        // 16 and 48 were pushed back to back; whichever head is older
        // wins — and that answer never changes between queries
        let first = b.ready_bucket(Instant::now()).unwrap();
        for _ in 0..100 {
            assert_eq!(b.ready_bucket(Instant::now()), Some(first));
        }
        b.take(first);

        // expired path: same oldest-head-first rule
        let mut b: Batcher<u32> = Batcher::new(8, Duration::from_millis(5));
        b.push(48, 1);
        std::thread::sleep(Duration::from_millis(2));
        b.push(32, 2);
        std::thread::sleep(Duration::from_millis(10)); // both expired
        for _ in 0..100 {
            assert_eq!(b.ready_bucket(Instant::now()), Some(48),
                       "oldest expired head must win");
        }
        assert_eq!(b.take(48).len(), 1);
        assert_eq!(b.ready_bucket(Instant::now()), Some(32));
    }

    // property-style sweep: conservation — everything pushed is taken
    // exactly once, never crossing buckets
    #[test]
    fn conservation_property() {
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..50 {
            let max_batch = 1 + rng.below(6);
            let mut b: Batcher<(usize, u64)> =
                Batcher::new(max_batch, Duration::from_secs(100));
            let n = 1 + rng.below(40);
            let mut pushed: Vec<(usize, u64)> = Vec::new();
            for i in 0..n {
                let bucket = [16usize, 32, 48, 64][rng.below(4)];
                b.push(bucket, (bucket, i as u64));
                pushed.push((bucket, i as u64));
            }
            let mut taken = Vec::new();
            while b.queued() > 0 {
                let bucket = *b.queues.keys().next().unwrap();
                for p in b.take(bucket) {
                    assert_eq!(p.item.0, bucket, "item crossed buckets");
                    taken.push(p.item);
                }
            }
            taken.sort();
            pushed.sort();
            assert_eq!(taken, pushed);
        }
    }
}
