//! The edge serving stack, split along three seams:
//!
//! * [`ServingModel`] — the fused server executables per (bucket,
//!   batch) plus the stacked weights they consume.
//! * [`ServingService`] — the transport-agnostic service core: it
//!   owns sessions, the dynamic batcher feed, metrics, handshake
//!   negotiation, and all frame semantics behind the typed
//!   [`ServingService::handle`] API.  It never sees a socket.
//! * Transport adapters — every connection (TCP, in-proc, shaped) is
//!   registered with a shared [`PollPool`]: a fixed set of worker
//!   threads multiplexing all links through non-blocking
//!   `try_recv` readiness, so session count is no longer capped by OS
//!   threads.  [`EdgeServer`] is the thin TCP accept loop,
//!   [`ServiceHandle::connect_inproc`] the zero-socket connector the
//!   hermetic tests, benches, and the sim's live probe use;
//!   [`serve_transport`] remains as the dedicated-thread adapter for
//!   embedders that want one.
//!
//! Session state is partitioned into a [`ShardedSessions`] table
//! (session-id hash → independently-locked shard) so the data path
//! never takes a global session lock, and batching is continuous: the
//! poll workers push unpacked blocks into a shared
//! [`BatchFeed`] of per-bucket micro-queues that the compute workers
//! (one per accelerator unit) drain directly — there is no dedicated
//! batcher thread, and a filling batch never waits on a slow
//! connection.

use super::batcher::{BatchFeed, Feed};
use super::metrics::Metrics;
use super::obs::{DumpOnPanic, FlightKind, Obs, StepTrace, TraceInFlight};
use super::poll::PollPool;
use super::protocol::{caps, BucketAdvert, ErrorCode, Frame, LadderEntry,
                      ACTIVATION_HEADER_BYTES, PREFILL_HEADER_BYTES,
                      PROTOCOL_MAGIC, PROTOCOL_VERSION, STREAM_HEADER_BYTES};
use super::session::{SessionManager, ShardedSessions};
use super::transport::{InProcTransport, TcpTransport, Transport};
use crate::codec::fourier::{embed_block_into, unpack_block_into};
use crate::codec::rate::{ladder_from_manifest, LadderPoint};
use crate::codec::stream::{BlockGeom, UPDATE_WIRE_BYTES};
use crate::codec::wire;
use crate::codec::CodecEngine;
use crate::config::ServeConfig;
use crate::model::weights::Weights;
use crate::model::ModelMeta;
use crate::runtime::{ArtifactStore, Executable};
use crate::tensor::Tensor;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BucketMeta {
    pub bucket: usize,
    pub ks: usize,
    pub kd: usize,
    /// The bucket's quality ladder (`codec::rate`): point 0 is the
    /// primary (ks, kd) block above; later points keep nested smaller
    /// blocks with monotone forged error bounds.  Manifests without a
    /// ladder get the single primary point.
    pub ladder: Vec<LadderPoint>,
}

/// The serving-side model: fused server executables per (bucket,
/// batch), plus the stacked weights they consume.
pub struct ServingModel {
    pub model: String,
    pub d_model: usize,
    pub vocab: usize,
    pub buckets: BTreeMap<usize, BucketMeta>,
    exes: HashMap<(usize, usize), Arc<Executable>>, // (bucket, b)
    server_args: Vec<Tensor>,                       // stacked + head weights
    pub batch_sizes: Vec<usize>,                    // available b, desc
}

impl ServingModel {
    pub fn load(store: &ArtifactStore) -> Result<ServingModel> {
        let serving = store
            .manifest
            .get("serving")
            .ok_or_else(|| anyhow!("manifest has no serving section"))?;
        let model = serving.str_or("model", "");
        let meta = ModelMeta::from_manifest(&model, store.model_meta(&model)?)?;
        let weights = Weights::load(&store.root, &meta)?;
        let mut server_args = weights.stacked_layer_args(&meta, 1, meta.n_layers)?;
        server_args.extend(weights.head_args()?);

        let mut buckets = BTreeMap::new();
        let mut exes = HashMap::new();
        let mut batch_sizes: Vec<usize> = Vec::new();
        let bmap = serving
            .get("buckets")
            .and_then(|b| b.as_obj())
            .ok_or_else(|| anyhow!("serving.buckets missing"))?;
        for (bstr, bj) in bmap {
            let bucket: usize = bstr.parse()?;
            let ks = bj.usize_or("ks", 0);
            let kd = bj.usize_or("kd", 0);
            let ladder = ladder_from_manifest(bj, bucket, meta.d_model)
                .with_context(|| format!("bucket {bucket} ladder"))?;
            buckets.insert(bucket, BucketMeta { bucket, ks, kd, ladder });
            let servers = bj
                .get("server")
                .and_then(|s| s.as_obj())
                .ok_or_else(|| anyhow!("bucket {bucket}: no server artifacts"))?;
            for (bs, sj) in servers {
                let b: usize = bs.parse()?;
                let path = sj.str_or("path", "");
                exes.insert((bucket, b), store.get(&path)?);
                if !batch_sizes.contains(&b) {
                    batch_sizes.push(b);
                }
            }
        }
        batch_sizes.sort_unstable_by(|a, b| b.cmp(a));
        // reject unservable bucket geometry at load time — the codec
        // engines warm from this table and freq_indices asserts on it
        for (&bucket, bm) in &buckets {
            if !crate::codec::valid_block_axis(bucket, bm.ks)
                || !crate::codec::valid_block_axis(meta.d_model, bm.kd) {
                bail!("manifest bucket {bucket}: invalid block {}x{} for \
                       {bucket}x{}", bm.ks, bm.kd, meta.d_model);
            }
        }
        Ok(ServingModel { model, d_model: meta.d_model, vocab: meta.vocab_size,
                          buckets, exes, server_args, batch_sizes })
    }

    /// The bucket quality-ladder table as advertised in the
    /// `HelloAck`.  `full_ladder: false` truncates every ladder to
    /// its primary point — the `ServeConfig::ladder = false` lever,
    /// paired with withholding the [`caps::LADDER`] bit.
    pub fn bucket_adverts(&self, full_ladder: bool) -> Vec<BucketAdvert> {
        self.buckets
            .values()
            .map(|bm| {
                let n = if full_ladder { bm.ladder.len() } else { 1 };
                BucketAdvert {
                    bucket: bm.bucket as u16,
                    ladder: bm.ladder[..n]
                        .iter()
                        .map(|p| LadderEntry {
                            ks: p.ks as u16,
                            kd: p.kd as u16,
                            err_bound: p.err_bound as f32,
                        })
                        .collect(),
                }
            })
            .collect()
    }

    /// Execute a group (same bucket) and return per-item next-token
    /// (argmax at true_len-1) + logprob.
    pub fn run_group(&self, bucket: usize, items: &[GroupItem])
        -> Result<Vec<(i32, f32)>> {
        let bm = self.buckets.get(&bucket)
            .ok_or_else(|| anyhow!("unknown bucket {bucket}"))?;
        let (ks, kd) = (bm.ks, bm.kd);
        let mut out = Vec::with_capacity(items.len());
        let mut off = 0usize;
        while off < items.len() {
            let remaining = items.len() - off;
            // largest available batch size; pad short groups by
            // repeating the last element (only its own lane is read)
            let b = *self
                .batch_sizes
                .iter()
                .find(|&&b| b <= remaining)
                .unwrap_or(self.batch_sizes.last().unwrap());
            let chunk = &items[off..(off + b).min(items.len())];
            let mut re = Vec::with_capacity(b * ks * kd);
            let mut im = Vec::with_capacity(b * ks * kd);
            for i in 0..b {
                let it = chunk.get(i).unwrap_or(chunk.last().unwrap());
                if it.re.len() != ks * kd {
                    bail!("block size mismatch: {} vs {}", it.re.len(), ks * kd);
                }
                re.extend_from_slice(&it.re);
                im.extend_from_slice(&it.im);
            }
            let exe = self.exes.get(&(bucket, b))
                .ok_or_else(|| anyhow!("no artifact for ({bucket},{b})"))?;
            let mut args = vec![
                Tensor::f32(vec![b, ks, kd], re),
                Tensor::f32(vec![b, ks, kd], im),
            ];
            args.extend(self.server_args.iter().cloned());
            let logits = exe.run(&args)?.remove(0); // [b, S, V]
            let v = self.vocab;
            for (i, it) in chunk.iter().enumerate() {
                let pos = it.true_len.clamp(1, bucket) - 1;
                let row = &logits.as_f32()[i * bucket * v + pos * v
                                           ..i * bucket * v + (pos + 1) * v];
                let (mut best, mut bi) = (f32::MIN, 0usize);
                for (t, &x) in row.iter().enumerate() {
                    if x > best {
                        best = x;
                        bi = t;
                    }
                }
                let lp = crate::eval::scorer::log_softmax_at(row, bi) as f32;
                out.push((bi as i32, lp));
            }
            off += chunk.len();
        }
        Ok(out)
    }
}

pub struct GroupItem {
    pub session: u64,
    pub request: u64,
    pub true_len: usize,
    pub re: Vec<f32>,
    pub im: Vec<f32>,
    pub reply: mpsc::Sender<Reply>,
    pub t_rx: Instant,
    /// In-flight per-step trace when this step is sampled (carried
    /// in-process only — never serialized).
    pub trace: Option<Box<TraceInFlight>>,
}

/// What flows back over a connection's reply channel: the frame to
/// send plus, for sampled steps, the in-flight trace the writer
/// finalizes once the reply is on the wire (the tx stage is the last
/// stamp, so only the flushing thread can take it).
pub struct Reply {
    pub frame: Frame,
    pub trace: Option<Box<TraceInFlight>>,
}

impl From<Frame> for Reply {
    fn from(frame: Frame) -> Reply {
        Reply { frame, trace: None }
    }
}

/// Immediate outcome of [`ServingService::handle`] for one inbound
/// frame.  Asynchronous results (tokens from the batcher workers)
/// flow through the connection's reply channel, never through this.
pub enum Response {
    /// Nothing to send now.
    None,
    /// Send this frame to the peer.
    Reply(Frame),
    /// The connection is done (client `Bye` or service shutdown).
    Close,
}

/// Per-connection state owned by the transport adapter and threaded
/// through [`ServingService::handle`]: the warm codec engine, the
/// reply channel the batcher answers on, and what the handshake
/// negotiated.
pub struct ConnState {
    engine: CodecEngine,
    reply: mpsc::Sender<Reply>,
    peer: String,
    /// Reusable planes for unpacking a non-primary ladder point
    /// before embedding it into the primary block (they never leave
    /// the connection, unlike the GroupItem's re/im).
    point_re: Vec<f32>,
    point_im: Vec<f32>,
    client_caps: u32,
    /// This connection's ownership nonce (nonzero, unique per
    /// connection) — recorded as the session's `owner` at handshake
    /// so no other live connection can `Hello` the same session.
    conn_id: u64,
    /// The session this connection handshook (valid once
    /// `hello_done`).  Data frames must name exactly this session — a
    /// connection cannot act on (or resurrect) other tenants'
    /// sessions.
    session: u64,
    hello_done: bool,
    /// The connection has sent at least one entropy-coded data frame
    /// — raw frames after this point are the client's try-and-compare
    /// fallback and get recorded as such.  Gating on it keeps plain
    /// pre-entropy clients from flooding the flight ring with
    /// spurious fallback events.
    saw_entropy: bool,
}

impl ConnState {
    /// Capabilities in effect on this connection (client ∩ server).
    pub fn negotiated_caps(&self, server_caps: u32) -> u32 {
        self.client_caps & server_caps
    }

    /// The peer label the connection was opened with (diagnostics).
    pub fn peer(&self) -> &str {
        &self.peer
    }

    /// The session this connection handshook (0 before `Hello`) —
    /// lets the poll loop attribute idle disconnects to a session.
    pub fn session(&self) -> u64 {
        if self.hello_done { self.session } else { 0 }
    }
}

/// The transport-agnostic serving core: sessions, batching feed,
/// metrics, and frame semantics.  One instance serves every
/// connection regardless of medium; adapters call
/// [`ServingService::open_conn`] once per link and then
/// [`ServingService::handle`] per frame.
pub struct ServingService {
    model: Arc<ServingModel>,
    pub metrics: Arc<Metrics>,
    /// Session state, hash-partitioned into independently-locked
    /// shards — no frame ever takes a global session lock.
    sessions: ShardedSessions,
    /// The continuous batching feed the compute workers drain.
    feed: Arc<BatchFeed<GroupItem>>,
    /// Capability bits this server advertises in `HelloAck`.
    pub caps: u32,
    /// Advertise full quality ladders in `HelloAck` (paired with
    /// [`caps::LADDER`]); false truncates the advert to point 0.
    advertise_ladder: bool,
    /// Connection-nonce source for session ownership (starts at 1 —
    /// owner 0 means "unowned").
    next_conn: std::sync::atomic::AtomicU64,
    /// The service's observability bundle: tracer, flight recorder,
    /// and the per-shard/bucket/worker metric families.
    obs: Arc<Obs>,
}

impl ServingService {
    /// The service's observability bundle.
    pub fn obs(&self) -> &Arc<Obs> {
        &self.obs
    }

    /// The shard index session `id` lives in (so tests and dumps can
    /// cross-check flight events against the session table's layout).
    pub fn shard_of(&self, id: u64) -> usize {
        self.sessions.shard_of(id)
    }

    /// Live sessions across every shard (momentary gauge).
    pub fn session_count(&self) -> usize {
        self.sessions.len()
    }

    /// The full Stats-frame JSON: every legacy flat key from
    /// [`Metrics::to_json`] unchanged, plus the sharded families as
    /// `shards` / `buckets` / `workers` arrays.
    pub fn stats_json(&self) -> Json {
        let mut j = self.metrics.to_json();
        let lens = self.sessions.shard_lens();
        let mut shards = Vec::with_capacity(self.obs.shards.len());
        for (i, m) in self.obs.shards.iter().enumerate() {
            let mut sj = Json::obj();
            sj.set("live", Json::Num(lens.get(i).copied().unwrap_or(0) as f64));
            sj.set("admitted",
                   Json::Num(m.admitted.load(Ordering::Relaxed) as f64));
            sj.set("evicted",
                   Json::Num(m.evicted.load(Ordering::Relaxed) as f64));
            shards.push(sj);
        }
        j.set("shards", Json::Arr(shards));
        let depths = self.feed.depths();
        let mut buckets = Vec::with_capacity(self.obs.buckets.len());
        for (b, m) in &self.obs.buckets {
            let mut bj = Json::obj();
            bj.set("bucket", Json::Num(*b as f64));
            let depth = depths.iter().find(|(id, _)| id == b)
                .map(|(_, d)| *d).unwrap_or(0);
            bj.set("depth", Json::Num(depth as f64));
            bj.set("enqueued",
                   Json::Num(m.enqueued.load(Ordering::Relaxed) as f64));
            bj.set("groups",
                   Json::Num(m.groups.load(Ordering::Relaxed) as f64));
            bj.set("pre_bytes",
                   Json::Num(m.pre_bytes.load(Ordering::Relaxed) as f64));
            bj.set("post_bytes",
                   Json::Num(m.post_bytes.load(Ordering::Relaxed) as f64));
            let mut wj = Json::obj();
            wj.set("count", Json::Num(m.wait_us.count() as f64));
            wj.set("mean", Json::Num(m.wait_us.mean()));
            wj.set("p99", Json::Num(m.wait_us.percentile(99.0) as f64));
            bj.set("wait_us", wj);
            buckets.push(bj);
        }
        j.set("buckets", Json::Arr(buckets));
        let mut workers = Vec::with_capacity(self.obs.workers.len());
        for m in &self.obs.workers {
            let mut wj = Json::obj();
            wj.set("visits", Json::Num(m.visits.load(Ordering::Relaxed) as f64));
            wj.set("frames", Json::Num(m.frames.load(Ordering::Relaxed) as f64));
            wj.set("naps", Json::Num(m.naps.load(Ordering::Relaxed) as f64));
            wj.set("busy_us",
                   Json::Num(m.busy_us.load(Ordering::Relaxed) as f64));
            workers.push(wj);
        }
        j.set("workers", Json::Arr(workers));
        j.set("sessions", Json::Num(self.sessions.len() as f64));
        j
    }
    /// Per-connection setup: a codec engine pre-warmed for every
    /// servable bucket (geometry was validated by
    /// [`ServingModel::load`], so warming cannot trip the
    /// freq_indices asserts).
    pub fn open_conn(&self, reply: mpsc::Sender<Reply>, peer: String)
        -> ConnState {
        let mut engine = CodecEngine::new();
        for (&bucket, bm) in &self.model.buckets {
            // only servable points are warmed: with the ladder
            // withheld, non-primary geometries are rejected before
            // they ever reach the codec
            let n = if self.advertise_ladder { bm.ladder.len() } else { 1 };
            for lp in &bm.ladder[..n] {
                engine.warm(bucket, self.model.d_model, lp.ks, lp.kd);
            }
        }
        let conn_id = self.next_conn.fetch_add(1, Ordering::Relaxed);
        ConnState { engine, reply, peer, point_re: Vec::new(),
                    point_im: Vec::new(), client_caps: 0, conn_id, session: 0,
                    hello_done: false, saw_entropy: false }
    }

    /// Connection teardown: release the session-ownership binding so
    /// a legitimate reconnect (same session, new connection) is
    /// admitted immediately.  Called by the poll loop (and
    /// [`serve_transport`]) on every exit path.
    pub fn close_conn(&self, conn: &ConnState) {
        if conn.hello_done {
            self.sessions.release_owner(conn.session, conn.conn_id);
        }
    }

    /// The handshake + session-binding gate every data frame passes:
    /// a frame before `Hello`, or naming a session other than the one
    /// this connection handshook, is a typed unknown-session reject.
    fn session_gate(&self, conn: &ConnState, session: u64)
        -> Option<Response> {
        if !conn.hello_done {
            return Some(Self::err(ErrorCode::UnknownSession,
                                  "handshake required".into()));
        }
        if session != conn.session {
            return Some(Self::err(
                ErrorCode::UnknownSession,
                format!("session {session} is not bound to this connection \
                         (handshook {})", conn.session)));
        }
        None
    }

    fn err(code: ErrorCode, msg: String) -> Response {
        Response::Reply(Frame::Error { code, msg })
    }

    /// Bucket + ladder-point agreement check shared by the Activation
    /// and Delta arms: the frame's point id must exist in the
    /// bucket's ladder and its (ks, kd) must match that point's
    /// geometry.  Returns the point's block geometry.
    fn checked_point(&self, bucket: usize, point: u8, ks: u16, kd: u16)
        -> Option<(usize, usize)> {
        let bm = self.model.buckets.get(&bucket)?;
        let lp = bm.ladder.get(point as usize)?;
        (lp.ks == ks as usize && lp.kd == kd as usize)
            .then_some((lp.ks, lp.kd))
    }

    /// Lazy decode of an entropy-coded wire body ([`codec::wire`],
    /// negotiated via [`caps::ENTROPY`]).  `Frame::decode` carries the
    /// coded bytes opaquely so the frame layer stays stateless; this
    /// is where they become a packed plane (keyframe / recompute) or a
    /// sparse update list (delta), where a malformed bitstream turns
    /// into a typed `BadRequest` instead of a panic, and where the
    /// entropy counters and per-bucket pre/post byte split are fed.
    /// Raw frames pass through untouched — but a raw frame on a
    /// connection that already sent coded ones is the client's
    /// try-and-compare fallback, recorded for the flight ring.
    #[allow(clippy::too_many_arguments)]
    #[allow(clippy::type_complexity)]
    fn take_entropy_body(&self, conn: &mut ConnState, session: u64, seq: u32,
                         bucket: usize, keyframe: bool, coded: Vec<u8>,
                         packed: Vec<f32>, updates: Vec<(u32, f32)>)
        -> std::result::Result<(Vec<f32>, Vec<(u32, f32)>), Response> {
        let shard = self.sessions.shard_of(session) as u16;
        if coded.is_empty() {
            if conn.saw_entropy {
                self.metrics.entropy_fallbacks.fetch_add(1, Ordering::Relaxed);
                self.obs.flight.record(FlightKind::EntropyFallback, session,
                                       shard, seq, keyframe as u64);
            }
            return Ok((packed, updates));
        }
        if conn.negotiated_caps(self.caps) & caps::ENTROPY == 0 {
            return Err(Self::err(
                ErrorCode::BadRequest,
                "entropy capability not negotiated".into()));
        }
        conn.saw_entropy = true;
        let (packed, updates, pre) = if keyframe {
            let mut vals = Vec::new();
            if let Err(e) = wire::decode_f32_plane(&coded, &mut vals) {
                self.obs.flight.record(FlightKind::BadRequest, session,
                                       shard, seq, bucket as u64);
                return Err(Self::err(ErrorCode::BadRequest,
                                     format!("entropy: {e}")));
            }
            let pre = vals.len() as u64 * 4;
            (vals, updates, pre)
        } else {
            let mut ups = Vec::new();
            if let Err(e) = wire::decode_updates(&coded, &mut ups) {
                self.obs.flight.record(FlightKind::BadRequest, session,
                                       shard, seq, bucket as u64);
                return Err(Self::err(ErrorCode::BadRequest,
                                     format!("entropy: {e}")));
            }
            let pre = (4 + ups.len() * UPDATE_WIRE_BYTES) as u64;
            (packed, ups, pre)
        };
        let post = coded.len() as u64;
        self.metrics.entropy_frames.fetch_add(1, Ordering::Relaxed);
        self.metrics.entropy_bytes_saved
            .fetch_add(pre.saturating_sub(post), Ordering::Relaxed);
        if let Some(bm) = self.obs.bucket(bucket) {
            bm.pre_bytes.fetch_add(pre, Ordering::Relaxed);
            bm.post_bytes.fetch_add(post, Ordering::Relaxed);
        }
        Ok((packed, updates))
    }

    /// Shared tail of both data arms: unpack a packed block with the
    /// connection's warm engine — a non-primary ladder point is then
    /// embedded into the bucket's primary block, its truncated
    /// frequencies zero, so the fused server executable always sees
    /// its compiled geometry — and hand the result to the batcher.
    /// `re`/`im` are owned by the GroupItem (they cross the batcher
    /// thread boundary), but the index sets and unpack bookkeeping
    /// come from the warm engine.
    #[allow(clippy::too_many_arguments)]
    fn unpack_and_enqueue(&self, conn: &mut ConnState, session: u64,
                          request: u64, bucket: usize, pks: usize, pkd: usize,
                          true_len: u16, block: &[f32], t_rx: Instant,
                          seq: u32, mut trace: Option<Box<TraceInFlight>>)
        -> Response {
        let bm = &self.model.buckets[&bucket];
        let (ks0, kd0) = (bm.ks, bm.kd);
        let d = self.model.d_model;
        let t0 = Instant::now();
        // a sampled step borrows the connection engine's stage timer
        // for the duration of its own unpack — unsampled frames on the
        // same connection never pay the per-stage clock reads
        if trace.is_some() {
            conn.engine.enable_stage_timing();
        }
        let (mut re, mut im) = (Vec::new(), Vec::new());
        let unpacked = if pks == ks0 && pkd == kd0 {
            unpack_block_into(&mut conn.engine, block, bucket, d, pks, pkd,
                              &mut re, &mut im)
        } else {
            let mut sre = std::mem::take(&mut conn.point_re);
            let mut sim = std::mem::take(&mut conn.point_im);
            let r = unpack_block_into(&mut conn.engine, block, bucket, d, pks,
                                      pkd, &mut sre, &mut sim)
                .and_then(|_| embed_block_into(&mut conn.engine, &sre, &sim,
                                               bucket, d, pks, pkd, ks0, kd0,
                                               &mut re, &mut im));
            conn.point_re = sre;
            conn.point_im = sim;
            r
        };
        let spent = t0.elapsed();
        self.metrics.decompress_us.record_dur(spent);
        if let Some(t) = trace.as_mut() {
            t.decompress_us = spent.as_micros() as u64;
            t.codec = conn.engine.stage_times().unwrap_or_default();
            conn.engine.disable_stage_timing();
        }
        if let Err(e) = unpacked {
            self.obs.flight.record(FlightKind::BadRequest, session,
                                   self.sessions.shard_of(session) as u16,
                                   seq, bucket as u64);
            return Self::err(ErrorCode::BadRequest, format!("unpack: {e}"));
        }
        let item = GroupItem {
            session,
            request,
            true_len: true_len as usize,
            re,
            im,
            reply: conn.reply.clone(),
            t_rx,
            trace,
        };
        if !self.feed.push(bucket, item) {
            return Response::Close; // service shutting down
        }
        if let Some(bm) = self.obs.bucket(bucket) {
            bm.enqueued.fetch_add(1, Ordering::Relaxed);
        }
        Response::None
    }

    /// Handle one inbound frame against this connection's state.
    /// Every protocol decision lives here; transports only move
    /// bytes.
    pub fn handle(&self, conn: &mut ConnState, frame: Frame) -> Response {
        match frame {
            Frame::Hello { magic, version, caps: client_caps, session,
                           model } => {
                self.metrics.hellos.fetch_add(1, Ordering::Relaxed);
                if magic != PROTOCOL_MAGIC {
                    self.metrics.proto_rejects.fetch_add(1, Ordering::Relaxed);
                    self.obs.flight.record(
                        FlightKind::ProtoReject, session,
                        self.sessions.shard_of(session) as u16, 0,
                        magic as u64);
                    crate::debug!("service", "{}: bad magic {magic:#010x}",
                                  conn.peer);
                    return Self::err(ErrorCode::VersionMismatch,
                                     format!("bad magic {magic:#010x}"));
                }
                if version != PROTOCOL_VERSION {
                    self.metrics.proto_rejects.fetch_add(1, Ordering::Relaxed);
                    self.obs.flight.record(
                        FlightKind::ProtoReject, session,
                        self.sessions.shard_of(session) as u16, 0,
                        version as u64);
                    crate::debug!("service", "{}: protocol v{version}",
                                  conn.peer);
                    return Self::err(
                        ErrorCode::VersionMismatch,
                        format!("protocol v{version} unsupported \
                                 (server speaks v{PROTOCOL_VERSION})"));
                }
                // admission is atomic within the session's shard: the
                // ownership check comes first (a refused takeover must
                // not refresh or rewrite the foreign session), and
                // bind_owner cannot fail because the shard lock is
                // held across the check
                let conn_id = conn.conn_id;
                let gate = self.sessions.with(session, |s| {
                    if s.owned_by_other(session, conn_id) {
                        return Some(Self::err(
                            ErrorCode::AdmissionRefused,
                            format!("session {session} is bound to another \
                                     live connection")));
                    }
                    if !s.hello(session, &model, client_caps) {
                        return Some(Self::err(ErrorCode::AdmissionRefused,
                                              "admission refused".into()));
                    }
                    s.bind_owner(session, conn_id);
                    None
                });
                if let Some(reject) = gate {
                    return reject;
                }
                // re-handshaking onto a different session releases the
                // old binding — a separate, sequential lock of the old
                // session's shard (shard locks never nest)
                if conn.hello_done && conn.session != session {
                    self.sessions.release_owner(conn.session, conn.conn_id);
                }
                conn.client_caps = client_caps;
                conn.session = session;
                conn.hello_done = true;
                Response::Reply(Frame::HelloAck {
                    version: PROTOCOL_VERSION,
                    caps: self.caps,
                    buckets: self.model.bucket_adverts(self.advertise_ladder),
                })
            }
            Frame::Activation { session, request, bucket, true_len, ks, kd,
                                point, packed, coded } => {
                let t_rx = Instant::now();
                self.metrics.requests.fetch_add(1, Ordering::Relaxed);
                let body_wire = if coded.is_empty() {
                    packed.len() * 4
                } else {
                    coded.len()
                };
                self.metrics.bytes_rx.fetch_add(
                    (body_wire + ACTIVATION_HEADER_BYTES) as u64,
                    Ordering::Relaxed);
                if let Some(reject) = self.session_gate(conn, session) {
                    return reject;
                }
                if point != 0
                    && conn.negotiated_caps(self.caps) & caps::LADDER == 0 {
                    return Self::err(
                        ErrorCode::BadRequest,
                        "ladder capability not negotiated".into());
                }
                let bucket = bucket as usize;
                let Some((pks, pkd)) =
                    self.checked_point(bucket, point, ks, kd)
                else {
                    self.obs.flight.record(
                        FlightKind::BadRequest, session,
                        self.sessions.shard_of(session) as u16, 0,
                        bucket as u64);
                    return Self::err(
                        ErrorCode::BadRequest,
                        format!("bad bucket {bucket} point {point} \
                                 ({ks}x{kd})"));
                };
                let (packed, _) = match self.take_entropy_body(
                    conn, session, 0, bucket, true, coded, packed,
                    Vec::new()) {
                    Ok(pu) => pu,
                    Err(reject) => return reject,
                };
                {
                    let body = body_wire as u64;
                    let admitted = self.sessions.with(session, |s| {
                        if !s.touch(session, body) {
                            // recompute requests are stateless: an
                            // evicted session is re-admitted like a
                            // stream keyframe rather than failed
                            // mid-generation — only live-table
                            // admission pressure refuses
                            if !s.readmit(session) {
                                return false;
                            }
                            s.touch(session, body);
                        }
                        true
                    });
                    if !admitted {
                        return Self::err(ErrorCode::AdmissionRefused,
                                         "admission refused".into());
                    }
                }
                let mut trace = self.obs.tracer.begin(session, request, t_rx);
                if let Some(t) = trace.as_mut() {
                    t.bucket = bucket;
                    t.point = point;
                    t.shard = self.sessions.shard_of(session);
                }
                let resp = self.unpack_and_enqueue(conn, session, request,
                                                   bucket, pks, pkd, true_len,
                                                   &packed, t_rx, 0, trace);
                // record the ladder point only for frames that were
                // actually served: a rejected body must not move the
                // session's point (a stream running at another point
                // would get a spurious switch-requires-keyframe
                // reject) nor fabricate switch metrics
                if matches!(resp, Response::None) {
                    let switched = self.sessions.note_point(session, point);
                    if let Some(dwell) = switched {
                        self.metrics.ladder_switches
                            .fetch_add(1, Ordering::Relaxed);
                        self.metrics.ladder_dwell_frames.record(dwell);
                        self.obs.flight.record(
                            FlightKind::LadderSwitch, session,
                            self.sessions.shard_of(session) as u16, 0,
                            point as u64);
                    }
                }
                resp
            }
            Frame::Delta { session, request, seq, keyframe, bucket, true_len,
                           ks, kd, point, packed, updates, coded } => {
                let t_rx = Instant::now();
                self.metrics.requests.fetch_add(1, Ordering::Relaxed);
                let body_bytes = if !coded.is_empty() {
                    coded.len()
                } else if keyframe {
                    packed.len() * 4
                } else {
                    4 + updates.len() * UPDATE_WIRE_BYTES
                };
                let wire = (body_bytes + STREAM_HEADER_BYTES) as u64;
                self.metrics.bytes_rx.fetch_add(wire, Ordering::Relaxed);
                if let Some(reject) = self.session_gate(conn, session) {
                    return reject;
                }
                if conn.negotiated_caps(self.caps) & caps::STREAM == 0 {
                    return Self::err(
                        ErrorCode::BadRequest,
                        "stream capability not negotiated".into());
                }
                if point != 0
                    && conn.negotiated_caps(self.caps) & caps::LADDER == 0 {
                    return Self::err(
                        ErrorCode::BadRequest,
                        "ladder capability not negotiated".into());
                }
                let bucket = bucket as usize;
                let Some((bks, bkd)) =
                    self.checked_point(bucket, point, ks, kd)
                else {
                    self.obs.flight.record(
                        FlightKind::BadRequest, session,
                        self.sessions.shard_of(session) as u16, seq,
                        bucket as u64);
                    return Self::err(
                        ErrorCode::BadRequest,
                        format!("bad bucket {bucket} point {point} \
                                 ({ks}x{kd})"));
                };
                let (packed, updates) = match self.take_entropy_body(
                    conn, session, seq, bucket, keyframe, coded, packed,
                    updates) {
                    Ok(pu) => pu,
                    Err(reject) => return reject,
                };
                // only frames a negotiated peer aims at a real stream
                // count in the key/delta wire split (in-sequence
                // rejections still count — stream_rejects marks them);
                // rogue or mis-negotiated frames must not fabricate
                // stream traffic in the byte-win accounting
                if keyframe {
                    self.metrics.key_frames.fetch_add(1, Ordering::Relaxed);
                    self.metrics.key_bytes_rx.fetch_add(wire,
                                                        Ordering::Relaxed);
                } else {
                    self.metrics.delta_frames.fetch_add(1, Ordering::Relaxed);
                    self.metrics.delta_bytes_rx.fetch_add(wire,
                                                          Ordering::Relaxed);
                }
                let geom = BlockGeom { rows: bucket,
                                       cols: self.model.d_model,
                                       ks: bks, kd: bkd };
                // apply the frame to the per-session decoder state
                // under the session's shard lock — any failure (gap,
                // evicted state, admission) surfaces as a StreamReject
                // the client answers with a keyframe resync
                let applied = self.sessions.with(session, |s| {
                    apply_stream_frame(s, session, seq, keyframe, point,
                                       geom, body_bytes as u64, &packed,
                                       &updates)
                });
                let shard = self.sessions.shard_of(session) as u16;
                let (block, switched, resynced) = match applied {
                    Ok(ok) => ok,
                    Err(e) => {
                        self.metrics.stream_rejects.fetch_add(
                            1, Ordering::Relaxed);
                        self.obs.flight.record(FlightKind::StreamReject,
                                               session, shard, seq,
                                               point as u64);
                        return Self::err(ErrorCode::StreamReject,
                                         format!("stream: {e:#}"));
                    }
                };
                if resynced {
                    self.obs.flight.record(FlightKind::KeyframeResync,
                                           session, shard, seq,
                                           point as u64);
                }
                if let Some(dwell) = switched {
                    self.metrics.ladder_switches
                        .fetch_add(1, Ordering::Relaxed);
                    self.metrics.ladder_dwell_frames.record(dwell);
                    self.obs.flight.record(FlightKind::LadderSwitch, session,
                                           shard, seq, point as u64);
                }
                let mut trace = self.obs.tracer.begin(session, request, t_rx);
                if let Some(t) = trace.as_mut() {
                    t.bucket = bucket;
                    t.point = point;
                    t.shard = shard as usize;
                }
                self.unpack_and_enqueue(conn, session, request, bucket, bks,
                                        bkd, true_len, &block, t_rx, seq,
                                        trace)
            }
            Frame::PrefillChunk { session, request, bucket, true_len, ks, kd,
                                  point, index, last, keyframe, packed,
                                  updates, coded } => {
                let t_rx = Instant::now();
                let body_bytes = if !coded.is_empty() {
                    coded.len()
                } else if keyframe {
                    packed.len() * 4
                } else {
                    4 + updates.len() * UPDATE_WIRE_BYTES
                };
                let wire = (body_bytes + PREFILL_HEADER_BYTES) as u64;
                self.metrics.bytes_rx.fetch_add(wire, Ordering::Relaxed);
                if let Some(reject) = self.session_gate(conn, session) {
                    return reject;
                }
                if conn.negotiated_caps(self.caps) & caps::PREFILL == 0 {
                    return Self::err(
                        ErrorCode::BadRequest,
                        "prefill capability not negotiated".into());
                }
                if point != 0
                    && conn.negotiated_caps(self.caps) & caps::LADDER == 0 {
                    return Self::err(
                        ErrorCode::BadRequest,
                        "ladder capability not negotiated".into());
                }
                let bucket = bucket as usize;
                let Some((bks, bkd)) =
                    self.checked_point(bucket, point, ks, kd)
                else {
                    self.obs.flight.record(
                        FlightKind::BadRequest, session,
                        self.sessions.shard_of(session) as u16, index,
                        bucket as u64);
                    return Self::err(
                        ErrorCode::BadRequest,
                        format!("bad bucket {bucket} point {point} \
                                 ({ks}x{kd})"));
                };
                let (packed, updates) = match self.take_entropy_body(
                    conn, session, index, bucket, keyframe, coded, packed,
                    updates) {
                    Ok(pu) => pu,
                    Err(reject) => return reject,
                };
                // only frames a negotiated peer aims at a real prompt
                // count in the prefill wire split — same reasoning as
                // the stream key/delta accounting above
                self.metrics.prefill_chunks.fetch_add(1, Ordering::Relaxed);
                if keyframe {
                    self.metrics.prefill_key_chunks
                        .fetch_add(1, Ordering::Relaxed);
                }
                self.metrics.prefill_bytes_rx.fetch_add(wire,
                                                        Ordering::Relaxed);
                let geom = BlockGeom { rows: bucket,
                                       cols: self.model.d_model,
                                       ks: bks, kd: bkd };
                let shard = self.sessions.shard_of(session) as u16;
                // apply the chunk to the per-session assembler under
                // the shard lock.  A keyframe chunk 0 (re-)admits the
                // session like a stream keyframe; anything else needs
                // live mid-assembly state.  On completion the decode
                // stream is seeded from the assembled plane inside the
                // same critical section, so the client's first decode
                // delta can never race an unseeded decoder.
                let body = body_bytes as u64;
                let applied = self.sessions.with(session, |sm| {
                    let asm = if keyframe && index == 0 {
                        sm.prefill_restart(session, body).ok_or_else(
                            || anyhow!("prefill admission refused"))?
                    } else {
                        sm.prefill_assembler(session, body).ok_or_else(
                            || anyhow!("prefill state evicted; restart \
                                        from chunk 0"))?
                    };
                    let done = asm.apply(geom, index, last, keyframe,
                                         &packed, &updates)?;
                    if let Some(plane) = done {
                        if !sm.seed_stream_from_prefill(session, geom,
                                                        &plane, point) {
                            bail!("prefill stream seed failed");
                        }
                        return Ok(Some(plane));
                    }
                    Ok(None)
                });
                let plane = match applied {
                    Ok(Some(plane)) => plane,
                    // absorbed mid-assembly chunk, or a silently
                    // swallowed stray after a reject the client has
                    // already been told about
                    Ok(None) => return Response::None,
                    Err(e) => {
                        self.metrics.prefill_rejects
                            .fetch_add(1, Ordering::Relaxed);
                        self.obs.flight.record(FlightKind::PrefillReject,
                                               session, shard, index,
                                               point as u64);
                        return Self::err(ErrorCode::StreamReject,
                                         format!("prefill: {e:#}"));
                    }
                };
                // one reassembled prompt = one request = one token,
                // like the monolithic Activation path
                self.metrics.requests.fetch_add(1, Ordering::Relaxed);
                self.metrics.prefill_prompts.fetch_add(1, Ordering::Relaxed);
                let mut trace = self.obs.tracer.begin(session, request, t_rx);
                if let Some(t) = trace.as_mut() {
                    t.bucket = bucket;
                    t.point = point;
                    t.shard = shard as usize;
                }
                let resp = self.unpack_and_enqueue(conn, session, request,
                                                   bucket, bks, bkd, true_len,
                                                   &plane, t_rx, index, trace);
                if matches!(resp, Response::None) {
                    if let Some(dwell) = self.sessions.note_point(session,
                                                                  point) {
                        self.metrics.ladder_switches
                            .fetch_add(1, Ordering::Relaxed);
                        self.metrics.ladder_dwell_frames.record(dwell);
                        self.obs.flight.record(
                            FlightKind::LadderSwitch, session, shard, index,
                            point as u64);
                    }
                }
                resp
            }
            Frame::GetStats => Response::Reply(Frame::Stats {
                json: self.stats_json().to_string_compact() }),
            Frame::Bye => Response::Close,
            other => Self::err(ErrorCode::BadRequest,
                               format!("unexpected frame {}",
                                       other.type_id())),
        }
    }
}

/// Apply one stream frame to the session's decoder (keyframe:
/// re-admit + reseed; delta: live session + in-sequence only) and
/// return a copy of the resulting packed block plus the completed
/// dwell when the frame switched the session's ladder point.  A
/// ladder switch is only legal on a keyframe — the geometry changed,
/// so the decoder state is stale by construction — a delta naming a
/// new point is rejected like a sequence gap and the client resyncs.
/// The caller holds the session lock for the whole operation so the
/// decode state can never interleave with another frame of the same
/// session; the copy keeps the critical section to the decoder apply
/// — unpacking happens outside the lock, like the Activation path.
/// `body_bytes` is the codec-body size charged to the session
/// (headerless, matching the Activation path's accounting).  The
/// final bool reports a keyframe *resync*: a mid-stream keyframe that
/// re-seeded a desynced (evicted or never-seeded) decoder — the
/// client-visible recovery event the flight recorder tracks.
#[allow(clippy::too_many_arguments)]
fn apply_stream_frame(sessions: &mut SessionManager, session: u64, seq: u32,
                      keyframe: bool, point: u8, geom: BlockGeom,
                      body_bytes: u64, packed: &[f32],
                      updates: &[(u32, f32)])
    -> Result<(Vec<f32>, Option<u64>, bool)> {
    // continuity is validated against the STREAM's own point (moved
    // only by keyframes) — an interleaved recompute frame at another
    // point must not poison an in-sequence delta
    let prev = sessions.stream_point_of(session);
    if !keyframe && prev.is_some_and(|p| p != point) {
        bail!("ladder switch (point {} -> {point}) requires a keyframe",
              prev.unwrap());
    }
    let was_synced = sessions
        .get(session)
        .map(|s| s.stream.is_synced())
        .unwrap_or(false);
    // a keyframe at seq 0 is the normal stream start, not a recovery
    let resynced = keyframe && !was_synced && seq != 0;
    let block = {
        let dec = if keyframe {
            sessions.stream_key_decoder(session, body_bytes)
                .ok_or_else(|| anyhow!("stream admission refused"))?
        } else {
            sessions.stream_delta_decoder(session, body_bytes)
                .ok_or_else(|| anyhow!("stream state evicted; keyframe \
                                        required"))?
        };
        if keyframe {
            dec.apply_key(seq, geom, packed)?;
        } else {
            dec.apply_delta(seq, geom, updates)?;
        }
        dec.block().to_vec()
    };
    if keyframe {
        sessions.set_stream_point(session, point);
    }
    Ok((block, sessions.note_point(session, point), resynced))
}

/// Pump one transport through the service core on the caller's
/// thread: a writer thread drains the reply channel into the tx half
/// while this thread feeds inbound frames to
/// [`ServingService::handle`].  Returns when the peer disconnects,
/// says `Bye`, or the service shuts down.  The serving stack itself
/// multiplexes connections through the [`PollPool`] instead; this
/// dedicated-thread adapter remains for embedders that want one
/// blocking loop per link.
pub fn serve_transport(service: Arc<ServingService>,
                       transport: Box<dyn Transport>) -> Result<()> {
    let peer = transport.peer();
    let (mut tx, mut rx) = transport.split()?;

    // writer thread: serialises replies from batcher workers + us,
    // and stamps sampled steps' tx stage once the frame is on the wire
    let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
    let svc = service.clone();
    let wh = std::thread::spawn(move || {
        while let Ok(reply) = reply_rx.recv() {
            let t0 = Instant::now();
            match tx.send(&reply.frame) {
                Ok(n) => {
                    svc.metrics.bytes_tx.fetch_add(n as u64,
                                                   Ordering::Relaxed);
                    if let Some(t) = reply.trace {
                        svc.obs.tracer.finish(StepTrace::finish(
                            *t, t0.elapsed().as_micros() as u64));
                    }
                }
                Err(_) => break,
            }
        }
    });

    let mut conn = service.open_conn(reply_tx.clone(), peer);
    loop {
        let frame = match rx.recv() {
            Ok(f) => f,
            Err(_) => break, // disconnect
        };
        match service.handle(&mut conn, frame) {
            Response::None => {}
            Response::Reply(f) => {
                if reply_tx.send(f.into()).is_err() {
                    break;
                }
            }
            Response::Close => break,
        }
    }
    service.close_conn(&conn);
    drop(conn);
    drop(reply_tx);
    let _ = wh.join();
    Ok(())
}

/// A running service core (poll pool + batching feed + compute
/// workers) with no listener attached: transports are plugged in via
/// [`ServiceHandle::serve`] or [`ServiceHandle::connect_inproc`].
/// [`EdgeServer::start`] wraps one of these with a TCP accept loop.
pub struct ServiceHandle {
    service: Arc<ServingService>,
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    poll: Arc<PollPool>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ServiceHandle {
    pub fn service(&self) -> Arc<ServingService> {
        self.service.clone()
    }

    /// Register one transport with the shared poll pool — no
    /// per-connection thread is spawned.
    pub fn serve(&self, transport: Box<dyn Transport>) {
        if let Err(e) = self.poll.register(transport) {
            crate::debug!("conn", "register: {e:#}");
        }
    }

    /// Open a zero-socket connection to this service: returns the
    /// device half of an [`InProcTransport`] pair whose server half
    /// is already registered with the poll pool.
    pub fn connect_inproc(&self) -> InProcTransport {
        let (device, server) = InProcTransport::pair();
        self.serve(Box::new(server));
        device
    }

    /// Live connections registered with the poll pool (diagnostic).
    pub fn conn_count(&self) -> usize {
        self.poll.conn_count()
    }

    /// The service's observability bundle (tracer, flight recorder,
    /// sharded metric families).
    pub fn obs(&self) -> &Arc<Obs> {
        self.service.obs()
    }

    /// Snapshot the flight recorder: the most recent structured
    /// events, oldest first.
    pub fn dump_flight(&self) -> Vec<super::obs::FlightEvent> {
        self.service.obs.flight.dump()
    }

    /// Snapshot-timeline JSONL lines emitted so far (one per
    /// `snapshot_interval_ms` tick, plus one final line at shutdown).
    pub fn snapshots(&self) -> Vec<String> {
        self.service.obs.snapshots()
    }

    /// Completed per-step traces retained by the tracer.
    pub fn traces(&self) -> Vec<StepTrace> {
        self.service.obs.tracer.completed()
    }

    /// Stop and join everything, in dependency order: the poll
    /// workers first (no new work enters the feed, registered
    /// connections are retired and their session bindings released),
    /// then the feed is closed (compute workers drain what's queued
    /// and exit), then the workers are joined.  No thread survives
    /// this call.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.poll.shutdown();
        self.service.feed.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // the flight recorder's last words: anything it saw is part of
        // the service's post-mortem record (debug level — soaks that
        // deliberately provoke rejects stay quiet by default)
        if !self.service.obs.flight.is_empty() {
            crate::debug!("service", "shutdown {}",
                          self.service.obs.flight.dump_text());
        }
    }
}

/// Start the service core: model load, sharded session table, the
/// continuous [`BatchFeed`], a compute-worker pool sized to
/// `cfg.compute_units`, and the [`PollPool`] connection multiplexer
/// sized to `cfg.poll_workers`.  No listener — see
/// [`EdgeServer::start`] for the TCP adapter.
pub fn start_service(cfg: &ServeConfig, store: Arc<ArtifactStore>)
    -> Result<ServiceHandle> {
    let model = Arc::new(ServingModel::load(&store)?);
    let metrics = Arc::new(Metrics::new());
    let sessions = ShardedSessions::new(
        Duration::from_secs(cfg.session_ttl_s), 100_000, cfg.shards);
    let stop = Arc::new(AtomicBool::new(false));

    let bucket_ids: Vec<usize> = model.buckets.keys().copied().collect();
    let feed: Arc<BatchFeed<GroupItem>> = Arc::new(BatchFeed::new(
        &bucket_ids, cfg.max_batch,
        Duration::from_micros(cfg.batch_deadline_us)));
    let obs = Arc::new(Obs::new(cfg.trace_sample, cfg.shards, &bucket_ids,
                                cfg.poll_workers));
    sessions.attach_obs(&obs.shards, &obs.flight);
    let mut handles = Vec::new();

    // compute workers — one thread per accelerator unit, pulling
    // flushed groups straight off the shared feed (no batcher thread,
    // no hand-off channel)
    for wid in 0..cfg.compute_units {
        let feed = feed.clone();
        let model = model.clone();
        let metrics = metrics.clone();
        let stop = stop.clone();
        let obs = obs.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("fc-compute-{wid}"))
                .spawn(move || {
                    let _postmortem = DumpOnPanic(obs.flight.clone());
                    loop {
                        let group = feed.wait_take(Duration::from_millis(50));
                        match group {
                            Feed::Group(bucket, group) => {
                                metrics.batches.fetch_add(1, Ordering::Relaxed);
                                metrics.batch_size_sum.fetch_add(
                                    group.len() as u64, Ordering::Relaxed);
                                let bucket_obs = obs.bucket(bucket);
                                if let Some(bm) = bucket_obs {
                                    bm.groups.fetch_add(1, Ordering::Relaxed);
                                }
                                let now = Instant::now();
                                let mut items: Vec<GroupItem> = group
                                    .into_iter()
                                    .map(|p| {
                                        let wait = now.duration_since(p.enqueued);
                                        metrics.queue_wait_us.record_dur(wait);
                                        if let Some(bm) = bucket_obs {
                                            bm.wait_us.record(
                                                wait.as_micros() as u64);
                                        }
                                        let mut item = p.item;
                                        if let Some(t) = item.trace.as_mut() {
                                            t.queue_wait_us =
                                                wait.as_micros() as u64;
                                        }
                                        item
                                    })
                                    .collect();
                                let t0 = Instant::now();
                                match model.run_group(bucket, &items) {
                                    Ok(results) => {
                                        let spent = t0.elapsed();
                                        metrics.exec_us.record_dur(spent);
                                        for (it, (token, logprob)) in
                                            items.iter_mut().zip(results) {
                                            metrics.tokens
                                                .fetch_add(1, Ordering::Relaxed);
                                            metrics.e2e_us.record_dur(
                                                it.t_rx.elapsed());
                                            let mut trace = it.trace.take();
                                            if let Some(t) = trace.as_mut() {
                                                t.exec_us =
                                                    spent.as_micros() as u64;
                                            }
                                            let _ = it.reply.send(Reply {
                                                frame: Frame::Token {
                                                    request: it.request, token,
                                                    logprob },
                                                trace });
                                        }
                                    }
                                    Err(e) => {
                                        crate::error!("worker",
                                                      "unit {wid}: {e:#}");
                                        for it in &items {
                                            let _ = it.reply.send(Frame::Error {
                                                code: ErrorCode::Internal,
                                                msg: format!("{e:#}") }.into());
                                        }
                                    }
                                }
                            }
                            Feed::TimedOut => {
                                if stop.load(Ordering::SeqCst) {
                                    break;
                                }
                            }
                            Feed::Closed => break,
                        }
                    }
                })
                .expect("spawn compute worker"));
    }

    let mut server_caps = caps::CODEC_FC;
    if cfg.stream {
        server_caps |= caps::STREAM;
    }
    if cfg.ladder {
        server_caps |= caps::LADDER;
    }
    if cfg.entropy {
        server_caps |= caps::ENTROPY;
    }
    if cfg.prefill {
        server_caps |= caps::PREFILL;
    }
    let service = Arc::new(ServingService {
        model,
        metrics: metrics.clone(),
        sessions,
        feed,
        caps: server_caps,
        advertise_ladder: cfg.ladder,
        next_conn: std::sync::atomic::AtomicU64::new(1),
        obs,
    });
    let idle = (cfg.idle_deadline_ms > 0)
        .then(|| Duration::from_millis(cfg.idle_deadline_ms));
    let poll = Arc::new(PollPool::start(service.clone(), cfg.poll_workers,
                                        idle));

    // snapshot timeline: one delta-metrics JSONL line per tick, plus
    // a final line at shutdown so even a short run has a timeline
    if cfg.snapshot_interval_ms > 0 {
        let svc = service.clone();
        let poll = poll.clone();
        let stop = stop.clone();
        let interval = Duration::from_millis(cfg.snapshot_interval_ms);
        handles.push(
            std::thread::Builder::new()
                .name("fc-obs-snap".into())
                .spawn(move || {
                    let start = Instant::now();
                    let snap = |m: &Metrics| -> [u64; 6] {
                        [m.tokens.load(Ordering::Relaxed),
                         m.requests.load(Ordering::Relaxed),
                         m.batches.load(Ordering::Relaxed),
                         m.bytes_rx.load(Ordering::Relaxed),
                         m.bytes_tx.load(Ordering::Relaxed),
                         m.stream_rejects.load(Ordering::Relaxed)]
                    };
                    let mut last = snap(&svc.metrics);
                    loop {
                        let wake = Instant::now() + interval;
                        while Instant::now() < wake
                            && !stop.load(Ordering::SeqCst) {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        let stopping = stop.load(Ordering::SeqCst);
                        let cur = snap(&svc.metrics);
                        let mut j = Json::obj();
                        j.set("t_ms", Json::Num(
                            start.elapsed().as_millis() as f64));
                        for (i, key) in ["tokens", "requests", "batches",
                                         "bytes_rx", "bytes_tx",
                                         "stream_rejects"]
                            .iter().enumerate() {
                            j.set(key, Json::Num(
                                cur[i].saturating_sub(last[i]) as f64));
                        }
                        j.set("queued",
                              Json::Num(svc.feed.queued() as f64));
                        j.set("conns",
                              Json::Num(poll.conn_count() as f64));
                        j.set("sessions",
                              Json::Num(svc.sessions.len() as f64));
                        svc.obs.push_snapshot(j.to_string_compact());
                        last = cur;
                        if stopping {
                            break;
                        }
                    }
                })
                .expect("spawn snapshot thread"));
    }

    Ok(ServiceHandle { service, metrics, stop, poll, handles })
}

pub struct EdgeServer;

/// A service core plus its TCP accept loop.  `connect_inproc` still
/// works — TCP and in-proc clients share the same sessions, batcher,
/// and metrics.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    pub metrics: Arc<Metrics>,
    inner: ServiceHandle,
}

impl ServerHandle {
    /// Zero-socket connection into the same running service.
    pub fn connect_inproc(&self) -> InProcTransport {
        self.inner.connect_inproc()
    }

    pub fn service(&self) -> Arc<ServingService> {
        self.inner.service()
    }

    pub fn shutdown(self) {
        self.inner.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr); // unblock accept
        self.inner.shutdown();
    }
}

impl EdgeServer {
    /// Start the service core and its TCP transport adapter; returns
    /// once the socket is listening.
    pub fn start(cfg: ServeConfig, store: Arc<ArtifactStore>)
        -> Result<ServerHandle> {
        let mut inner = start_service(&cfg, store)?;

        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("binding {}", cfg.listen))?;
        let addr = listener.local_addr()?;
        crate::info!("server", "listening on {addr} model={} units={} batch<= {}",
                     inner.service.model.model, cfg.compute_units,
                     cfg.max_batch);

        // accept loop: a thin adapter — every accepted stream is a
        // TcpTransport registered with the shared poll pool (no
        // per-connection thread)
        {
            let stop = inner.stop.clone();
            let poll = inner.poll.clone();
            inner.handles.push(std::thread::spawn(move || {
                for conn in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let registered = TcpTransport::from_stream(stream)
                                .and_then(|t| poll.register(Box::new(t)));
                            if let Err(e) = registered {
                                crate::debug!("conn", "setup: {e:#}");
                            }
                        }
                        Err(e) => crate::warn_!("server", "accept: {e}"),
                    }
                }
            }));
        }

        Ok(ServerHandle { addr, metrics: inner.metrics.clone(), inner })
    }
}
