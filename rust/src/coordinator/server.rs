//! The edge server: TCP accept loop → per-connection readers → shared
//! dynamic batcher → a worker pool sized to the accelerator count
//! (compute units), executing the fused server HLOs (reconstruct +
//! layers 2..L + head).  Thread-per-connection with a writer channel
//! per client; the batcher and workers communicate over mpsc.

use super::batcher::Batcher;
use super::metrics::Metrics;
use super::protocol::{Frame, STREAM_HEADER_BYTES};
use super::session::SessionManager;
use crate::codec::fourier::unpack_block_into;
use crate::codec::stream::{BlockGeom, UPDATE_WIRE_BYTES};
use crate::codec::CodecEngine;
use crate::config::ServeConfig;
use crate::model::weights::Weights;
use crate::model::ModelMeta;
use crate::runtime::{ArtifactStore, Executable};
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BucketMeta {
    pub bucket: usize,
    pub ks: usize,
    pub kd: usize,
}

/// The serving-side model: fused server executables per (bucket,
/// batch), plus the stacked weights they consume.
pub struct ServingModel {
    pub model: String,
    pub d_model: usize,
    pub vocab: usize,
    pub buckets: BTreeMap<usize, BucketMeta>,
    exes: HashMap<(usize, usize), Arc<Executable>>, // (bucket, b)
    server_args: Vec<Tensor>,                       // stacked + head weights
    pub batch_sizes: Vec<usize>,                    // available b, desc
}

impl ServingModel {
    pub fn load(store: &ArtifactStore) -> Result<ServingModel> {
        let serving = store
            .manifest
            .get("serving")
            .ok_or_else(|| anyhow!("manifest has no serving section"))?;
        let model = serving.str_or("model", "");
        let meta = ModelMeta::from_manifest(&model, store.model_meta(&model)?)?;
        let weights = Weights::load(&store.root, &meta)?;
        let mut server_args = weights.stacked_layer_args(&meta, 1, meta.n_layers)?;
        server_args.extend(weights.head_args()?);

        let mut buckets = BTreeMap::new();
        let mut exes = HashMap::new();
        let mut batch_sizes: Vec<usize> = Vec::new();
        let bmap = serving
            .get("buckets")
            .and_then(|b| b.as_obj())
            .ok_or_else(|| anyhow!("serving.buckets missing"))?;
        for (bstr, bj) in bmap {
            let bucket: usize = bstr.parse()?;
            let ks = bj.usize_or("ks", 0);
            let kd = bj.usize_or("kd", 0);
            buckets.insert(bucket, BucketMeta { bucket, ks, kd });
            let servers = bj
                .get("server")
                .and_then(|s| s.as_obj())
                .ok_or_else(|| anyhow!("bucket {bucket}: no server artifacts"))?;
            for (bs, sj) in servers {
                let b: usize = bs.parse()?;
                let path = sj.str_or("path", "");
                exes.insert((bucket, b), store.get(&path)?);
                if !batch_sizes.contains(&b) {
                    batch_sizes.push(b);
                }
            }
        }
        batch_sizes.sort_unstable_by(|a, b| b.cmp(a));
        // reject unservable bucket geometry at load time — the codec
        // engines warm from this table and freq_indices asserts on it
        for (&bucket, bm) in &buckets {
            if !crate::codec::valid_block_axis(bucket, bm.ks)
                || !crate::codec::valid_block_axis(meta.d_model, bm.kd) {
                bail!("manifest bucket {bucket}: invalid block {}x{} for \
                       {bucket}x{}", bm.ks, bm.kd, meta.d_model);
            }
        }
        Ok(ServingModel { model, d_model: meta.d_model, vocab: meta.vocab_size,
                          buckets, exes, server_args, batch_sizes })
    }

    /// Execute a group (same bucket) and return per-item next-token
    /// (argmax at true_len-1) + logprob.
    pub fn run_group(&self, bucket: usize, items: &[GroupItem])
        -> Result<Vec<(i32, f32)>> {
        let bm = self.buckets.get(&bucket)
            .ok_or_else(|| anyhow!("unknown bucket {bucket}"))?;
        let (ks, kd) = (bm.ks, bm.kd);
        let mut out = Vec::with_capacity(items.len());
        let mut off = 0usize;
        while off < items.len() {
            let remaining = items.len() - off;
            // largest available batch size; pad short groups by
            // repeating the last element (only its own lane is read)
            let b = *self
                .batch_sizes
                .iter()
                .find(|&&b| b <= remaining)
                .unwrap_or(self.batch_sizes.last().unwrap());
            let chunk = &items[off..(off + b).min(items.len())];
            let mut re = Vec::with_capacity(b * ks * kd);
            let mut im = Vec::with_capacity(b * ks * kd);
            for i in 0..b {
                let it = chunk.get(i).unwrap_or(chunk.last().unwrap());
                if it.re.len() != ks * kd {
                    bail!("block size mismatch: {} vs {}", it.re.len(), ks * kd);
                }
                re.extend_from_slice(&it.re);
                im.extend_from_slice(&it.im);
            }
            let exe = self.exes.get(&(bucket, b))
                .ok_or_else(|| anyhow!("no artifact for ({bucket},{b})"))?;
            let mut args = vec![
                Tensor::f32(vec![b, ks, kd], re),
                Tensor::f32(vec![b, ks, kd], im),
            ];
            args.extend(self.server_args.iter().cloned());
            let logits = exe.run(&args)?.remove(0); // [b, S, V]
            let v = self.vocab;
            for (i, it) in chunk.iter().enumerate() {
                let pos = it.true_len.clamp(1, bucket) - 1;
                let row = &logits.as_f32()[i * bucket * v + pos * v
                                           ..i * bucket * v + (pos + 1) * v];
                let (mut best, mut bi) = (f32::MIN, 0usize);
                for (t, &x) in row.iter().enumerate() {
                    if x > best {
                        best = x;
                        bi = t;
                    }
                }
                let lp = crate::eval::scorer::log_softmax_at(row, bi) as f32;
                out.push((bi as i32, lp));
            }
            off += chunk.len();
        }
        Ok(out)
    }
}

pub struct GroupItem {
    pub session: u64,
    pub request: u64,
    pub true_len: usize,
    pub re: Vec<f32>,
    pub im: Vec<f32>,
    pub reply: mpsc::Sender<Frame>,
    pub t_rx: Instant,
}

enum Job {
    Group { bucket: usize, items: Vec<GroupItem> },
}

pub struct EdgeServer;

pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    pub metrics: Arc<Metrics>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr); // unblock accept
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl EdgeServer {
    /// Start the server; returns once the socket is listening.
    pub fn start(cfg: ServeConfig, store: Arc<ArtifactStore>)
        -> Result<ServerHandle> {
        let model = Arc::new(ServingModel::load(&store)?);
        let metrics = Arc::new(Metrics::new());
        let sessions = Arc::new(Mutex::new(SessionManager::new(
            Duration::from_secs(cfg.session_ttl_s), 100_000)));
        let stop = Arc::new(AtomicBool::new(false));

        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("binding {}", cfg.listen))?;
        let addr = listener.local_addr()?;
        crate::info!("server", "listening on {addr} model={} units={} batch<= {}",
                     model.model, cfg.compute_units, cfg.max_batch);

        // batcher input + worker job channels
        let (breq_tx, breq_rx) = mpsc::channel::<(usize, GroupItem)>();
        let (job_tx, job_rx) = mpsc::channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let mut handles = Vec::new();

        // batcher thread
        {
            let stop = stop.clone();
            let metrics = metrics.clone();
            let max_batch = cfg.max_batch;
            let deadline = Duration::from_micros(cfg.batch_deadline_us);
            handles.push(std::thread::spawn(move || {
                let mut batcher: Batcher<GroupItem> = Batcher::new(max_batch, deadline);
                loop {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let wait = batcher
                        .next_deadline(Instant::now())
                        .unwrap_or(Duration::from_millis(50))
                        .min(Duration::from_millis(50));
                    match breq_rx.recv_timeout(wait) {
                        Ok((bucket, item)) => batcher.push(bucket, item),
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                    while let Some(bucket) = batcher.ready_bucket(Instant::now()) {
                        let group = batcher.take(bucket);
                        metrics.batches.fetch_add(1, Ordering::Relaxed);
                        metrics.batch_size_sum
                            .fetch_add(group.len() as u64, Ordering::Relaxed);
                        let now = Instant::now();
                        let items: Vec<GroupItem> = group
                            .into_iter()
                            .map(|p| {
                                metrics.queue_wait_us.record(
                                    now.duration_since(p.enqueued));
                                p.item
                            })
                            .collect();
                        if job_tx.send(Job::Group { bucket, items }).is_err() {
                            return;
                        }
                    }
                }
            }));
        }

        // worker pool — one thread per compute unit
        for wid in 0..cfg.compute_units {
            let job_rx = job_rx.clone();
            let model = model.clone();
            let metrics = metrics.clone();
            let stop = stop.clone();
            handles.push(std::thread::spawn(move || loop {
                let job = {
                    let rx = job_rx.lock().unwrap();
                    rx.recv_timeout(Duration::from_millis(50))
                };
                match job {
                    Ok(Job::Group { bucket, items }) => {
                        let t0 = Instant::now();
                        match model.run_group(bucket, &items) {
                            Ok(results) => {
                                metrics.exec_us.record(t0.elapsed());
                                for (it, (token, logprob)) in
                                    items.iter().zip(results) {
                                    metrics.tokens.fetch_add(1, Ordering::Relaxed);
                                    metrics.e2e_us.record(it.t_rx.elapsed());
                                    let _ = it.reply.send(Frame::Token {
                                        request: it.request, token, logprob });
                                }
                            }
                            Err(e) => {
                                crate::error!("worker", "unit {wid}: {e:#}");
                                for it in &items {
                                    let _ = it.reply.send(Frame::Error {
                                        msg: format!("{e:#}") });
                                }
                            }
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }));
        }

        // accept loop
        {
            let stop = stop.clone();
            let metrics = metrics.clone();
            let model = model.clone();
            handles.push(std::thread::spawn(move || {
                for conn in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let breq_tx = breq_tx.clone();
                            let metrics = metrics.clone();
                            let sessions = sessions.clone();
                            let model = model.clone();
                            std::thread::spawn(move || {
                                if let Err(e) = handle_conn(stream, breq_tx,
                                                            metrics, sessions,
                                                            model) {
                                    crate::debug!("conn", "closed: {e:#}");
                                }
                            });
                        }
                        Err(e) => crate::warn_!("server", "accept: {e}"),
                    }
                }
            }));
        }

        Ok(ServerHandle { addr, stop, metrics, handles })
    }
}

/// Apply one stream frame to the session's decoder (keyframe:
/// re-admit + reseed; delta: live session + in-sequence only) and
/// return a copy of the resulting packed block.  The caller holds the
/// session lock for the whole operation so the decode state can never
/// interleave with another frame of the same session; the copy keeps
/// the critical section to the decoder apply — unpacking happens
/// outside the lock, like the Activation path.  `body_bytes` is the
/// codec-body size charged to the session (headerless, matching the
/// Activation path's accounting).
fn apply_stream_frame(sessions: &mut SessionManager, session: u64, seq: u32,
                      keyframe: bool, geom: BlockGeom, body_bytes: u64,
                      packed: &[f32], updates: &[(u32, f32)])
    -> Result<Vec<f32>> {
    let dec = if keyframe {
        sessions.stream_key_decoder(session, body_bytes)
            .ok_or_else(|| anyhow!("stream admission refused"))?
    } else {
        sessions.stream_delta_decoder(session, body_bytes)
            .ok_or_else(|| anyhow!("stream state evicted; keyframe required"))?
    };
    if keyframe {
        dec.apply_key(seq, geom, packed)?;
    } else {
        dec.apply_delta(seq, geom, updates)?;
    }
    Ok(dec.block().to_vec())
}

fn handle_conn(stream: TcpStream, breq_tx: mpsc::Sender<(usize, GroupItem)>,
               metrics: Arc<Metrics>, sessions: Arc<Mutex<SessionManager>>,
               model: Arc<ServingModel>) -> Result<()> {
    stream.set_nodelay(true)?;
    let mut reader = std::io::BufReader::new(stream.try_clone()?);
    let writer = stream;
    // per-connection codec engine: cached index sets survive across
    // this session's requests, and workers never contend on a shared
    // plan-cache lock (the old global Mutex<HashMap> is gone — the
    // shared tier is an RwLock reached only on a per-engine miss).
    // geometry was validated by ServingModel::load, so warming cannot
    // trip the freq_indices asserts
    let mut engine = CodecEngine::new();
    for (&bucket, bm) in &model.buckets {
        engine.warm(bucket, model.d_model, bm.ks, bm.kd);
    }

    // writer thread: serialises replies from batcher workers + us
    let (reply_tx, reply_rx) = mpsc::channel::<Frame>();
    let mtx = metrics.clone();
    let wh = std::thread::spawn(move || {
        let mut w = std::io::BufWriter::new(writer);
        while let Ok(frame) = reply_rx.recv() {
            let bytes = frame.encode();
            mtx.bytes_tx.fetch_add(bytes.len() as u64, Ordering::Relaxed);
            if std::io::Write::write_all(&mut w, &bytes).is_err() {
                break;
            }
            let _ = std::io::Write::flush(&mut w);
        }
    });

    loop {
        let frame = match Frame::read_from(&mut reader) {
            Ok(f) => f,
            Err(_) => break, // disconnect
        };
        match frame {
            Frame::Hello { session, model: m } => {
                let ok = sessions.lock().unwrap().hello(session, &m);
                if !ok {
                    let _ = reply_tx.send(Frame::Error {
                        msg: "admission refused".into() });
                }
            }
            Frame::Activation { session, request, bucket, true_len, ks, kd,
                                packed } => {
                let t_rx = Instant::now();
                metrics.requests.fetch_add(1, Ordering::Relaxed);
                metrics.bytes_rx.fetch_add((packed.len() * 4 + 24) as u64,
                                           Ordering::Relaxed);
                sessions.lock().unwrap()
                    .touch(session, (packed.len() * 4) as u64);
                let bucket = bucket as usize;
                let bm = match model.buckets.get(&bucket) {
                    Some(bm) if bm.ks == ks as usize && bm.kd == kd as usize => bm,
                    _ => {
                        let _ = reply_tx.send(Frame::Error {
                            msg: format!("bad bucket {bucket}/{ks}x{kd}") });
                        continue;
                    }
                };
                let t0 = Instant::now();
                // re/im are owned by the GroupItem (they cross the
                // batcher thread boundary), but the index sets and
                // unpack bookkeeping come from the warm engine.
                let (mut re, mut im) = (Vec::new(), Vec::new());
                let unpacked = unpack_block_into(&mut engine, &packed, bucket,
                                                 model.d_model, bm.ks, bm.kd,
                                                 &mut re, &mut im);
                metrics.decompress_us.record(t0.elapsed());
                match unpacked {
                    Ok(()) => {
                        let item = GroupItem {
                            session, request,
                            true_len: true_len as usize,
                            re, im,
                            reply: reply_tx.clone(),
                            t_rx,
                        };
                        if breq_tx.send((bucket, item)).is_err() {
                            break;
                        }
                    }
                    Err(e) => {
                        let _ = reply_tx.send(Frame::Error {
                            msg: format!("unpack: {e}") });
                    }
                }
            }
            Frame::Delta { session, request, seq, keyframe, bucket, true_len,
                           ks, kd, packed, updates } => {
                let t_rx = Instant::now();
                metrics.requests.fetch_add(1, Ordering::Relaxed);
                let body_bytes = if keyframe {
                    packed.len() * 4
                } else {
                    4 + updates.len() * UPDATE_WIRE_BYTES
                };
                let wire = (body_bytes + STREAM_HEADER_BYTES) as u64;
                metrics.bytes_rx.fetch_add(wire, Ordering::Relaxed);
                if keyframe {
                    metrics.key_frames.fetch_add(1, Ordering::Relaxed);
                    metrics.key_bytes_rx.fetch_add(wire, Ordering::Relaxed);
                } else {
                    metrics.delta_frames.fetch_add(1, Ordering::Relaxed);
                    metrics.delta_bytes_rx.fetch_add(wire, Ordering::Relaxed);
                }
                let bucket = bucket as usize;
                let (bks, bkd) = match model.buckets.get(&bucket) {
                    Some(bm) if bm.ks == ks as usize
                        && bm.kd == kd as usize => (bm.ks, bm.kd),
                    _ => {
                        let _ = reply_tx.send(Frame::Error {
                            msg: format!("bad bucket {bucket}/{ks}x{kd}") });
                        continue;
                    }
                };
                let geom = BlockGeom { rows: bucket, cols: model.d_model,
                                       ks: bks, kd: bkd };
                // apply the frame to the per-session decoder state
                // under the session lock — any failure (gap, evicted
                // state, admission) surfaces as an Error the client
                // answers with a keyframe resync
                let applied = {
                    let mut guard = sessions.lock().unwrap();
                    apply_stream_frame(&mut guard, session, seq, keyframe,
                                       geom, body_bytes as u64, &packed,
                                       &updates)
                };
                match applied {
                    Ok(block) => {
                        let t0 = Instant::now();
                        let (mut re, mut im) = (Vec::new(), Vec::new());
                        let unpacked = unpack_block_into(
                            &mut engine, &block, bucket, model.d_model, bks,
                            bkd, &mut re, &mut im);
                        metrics.decompress_us.record(t0.elapsed());
                        match unpacked {
                            Ok(()) => {
                                let item = GroupItem {
                                    session, request,
                                    true_len: true_len as usize,
                                    re, im,
                                    reply: reply_tx.clone(),
                                    t_rx,
                                };
                                if breq_tx.send((bucket, item)).is_err() {
                                    break;
                                }
                            }
                            Err(e) => {
                                let _ = reply_tx.send(Frame::Error {
                                    msg: format!("unpack: {e}") });
                            }
                        }
                    }
                    Err(e) => {
                        metrics.stream_rejects.fetch_add(1, Ordering::Relaxed);
                        let _ = reply_tx.send(Frame::Error {
                            msg: format!("stream: {e:#}") });
                    }
                }
            }
            Frame::GetStats => {
                let _ = reply_tx.send(Frame::Stats {
                    json: metrics.to_json().to_string_compact() });
            }
            Frame::Bye => break,
            other => {
                let _ = reply_tx.send(Frame::Error {
                    msg: format!("unexpected frame {}", other.type_id()) });
            }
        }
    }
    drop(reply_tx);
    let _ = wh.join();
    Ok(())
}
