//! Wire protocol: length-prefixed binary frames over TCP.
//!
//!   u32 body_len | u8 frame_type | body
//!
//! Frames:
//!   Hello      c→s  u64 session | u16 model_len | model
//!   Activation c→s  u64 session | u64 request | u16 bucket | u16 true_len
//!                   | u16 ks | u16 kd | f32 packed[·]  (conjugate-sym pack)
//!   Token      s→c  u64 request | i32 token | f32 logprob
//!   GetStats   c→s  (empty)
//!   Stats      s→c  u32 json_len | json
//!   Error      s→c  u16 msg_len | msg
//!   Bye        c→s  (empty)
//!   Delta      c→s  u64 session | u64 request | u32 seq | u8 keyframe
//!                   | u16 bucket | u16 true_len | u16 ks | u16 kd
//!                   | keyframe=1: f32 packed[·]   (full block)
//!                   | keyframe=0: u32 count | (u32 idx | f32 val)[count]
//!
//! `Delta` is the spectral stream's frame (`codec::stream`): `seq` is
//! the per-session stream sequence number and `keyframe` selects
//! between a full conjugate-symmetric block and sparse coefficient
//! updates into it.  The server keeps per-session decoder state and
//! hard-fails deltas that arrive out of sequence.

use anyhow::{bail, ensure, Result};
use std::io::{Read, Write};

pub const MAX_FRAME: usize = 64 << 20;

/// Body-header bytes of a `Delta` frame (session + request + seq +
/// keyframe flag + bucket + true_len + ks + kd) — the stream
/// counterpart of the Activation frame's 24-byte header, used by the
/// wire-byte accounting.
pub const STREAM_HEADER_BYTES: usize = 29;

#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    Hello { session: u64, model: String },
    Activation {
        session: u64,
        request: u64,
        bucket: u16,
        true_len: u16,
        ks: u16,
        kd: u16,
        packed: Vec<f32>,
    },
    Token { request: u64, token: i32, logprob: f32 },
    GetStats,
    Stats { json: String },
    Error { msg: String },
    Bye,
    /// Spectral stream frame: a keyframe carries the full packed
    /// block in `packed` (and `updates` is empty); a delta carries
    /// sparse `(index, value)` coefficient updates (and `packed` is
    /// empty).
    Delta {
        session: u64,
        request: u64,
        seq: u32,
        keyframe: bool,
        bucket: u16,
        true_len: u16,
        ks: u16,
        kd: u16,
        packed: Vec<f32>,
        updates: Vec<(u32, f32)>,
    },
}

impl Frame {
    pub fn type_id(&self) -> u8 {
        match self {
            Frame::Hello { .. } => 0,
            Frame::Activation { .. } => 1,
            Frame::Token { .. } => 2,
            Frame::GetStats => 3,
            Frame::Stats { .. } => 4,
            Frame::Error { .. } => 5,
            Frame::Bye => 6,
            Frame::Delta { .. } => 7,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::new();
        match self {
            Frame::Hello { session, model } => {
                b.extend_from_slice(&session.to_le_bytes());
                b.extend_from_slice(&(model.len() as u16).to_le_bytes());
                b.extend_from_slice(model.as_bytes());
            }
            Frame::Activation { session, request, bucket, true_len, ks, kd,
                                packed } => {
                b.extend_from_slice(&session.to_le_bytes());
                b.extend_from_slice(&request.to_le_bytes());
                b.extend_from_slice(&bucket.to_le_bytes());
                b.extend_from_slice(&true_len.to_le_bytes());
                b.extend_from_slice(&ks.to_le_bytes());
                b.extend_from_slice(&kd.to_le_bytes());
                for v in packed {
                    b.extend_from_slice(&v.to_le_bytes());
                }
            }
            Frame::Token { request, token, logprob } => {
                b.extend_from_slice(&request.to_le_bytes());
                b.extend_from_slice(&token.to_le_bytes());
                b.extend_from_slice(&logprob.to_le_bytes());
            }
            Frame::GetStats | Frame::Bye => {}
            Frame::Stats { json } => {
                b.extend_from_slice(&(json.len() as u32).to_le_bytes());
                b.extend_from_slice(json.as_bytes());
            }
            Frame::Error { msg } => {
                b.extend_from_slice(&(msg.len() as u16).to_le_bytes());
                b.extend_from_slice(msg.as_bytes());
            }
            Frame::Delta { session, request, seq, keyframe, bucket, true_len,
                           ks, kd, packed, updates } => {
                b.extend_from_slice(&session.to_le_bytes());
                b.extend_from_slice(&request.to_le_bytes());
                b.extend_from_slice(&seq.to_le_bytes());
                b.push(*keyframe as u8);
                b.extend_from_slice(&bucket.to_le_bytes());
                b.extend_from_slice(&true_len.to_le_bytes());
                b.extend_from_slice(&ks.to_le_bytes());
                b.extend_from_slice(&kd.to_le_bytes());
                if *keyframe {
                    for v in packed {
                        b.extend_from_slice(&v.to_le_bytes());
                    }
                } else {
                    b.extend_from_slice(&(updates.len() as u32).to_le_bytes());
                    for (i, v) in updates {
                        b.extend_from_slice(&i.to_le_bytes());
                        b.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
        }
        let mut out = Vec::with_capacity(5 + b.len());
        out.extend_from_slice(&(b.len() as u32).to_le_bytes());
        out.push(self.type_id());
        out.extend_from_slice(&b);
        out
    }

    pub fn decode(type_id: u8, body: &[u8]) -> Result<Frame> {
        let mut r = crate::codec::Reader::new(body);
        Ok(match type_id {
            0 => {
                let session = u64_of(&mut r)?;
                let n = r.u16()? as usize;
                let model = String::from_utf8(r.take(n)?.to_vec())?;
                Frame::Hello { session, model }
            }
            1 => {
                let session = u64_of(&mut r)?;
                let request = u64_of(&mut r)?;
                let bucket = r.u16()?;
                let true_len = r.u16()?;
                let ks = r.u16()?;
                let kd = r.u16()?;
                let mut packed = Vec::with_capacity(r.remaining() / 4);
                while r.remaining() >= 4 {
                    packed.push(r.f32()?);
                }
                ensure!(r.remaining() == 0,
                        "activation body not f32-aligned ({} stray bytes)",
                        r.remaining());
                Frame::Activation { session, request, bucket, true_len, ks, kd,
                                    packed }
            }
            2 => {
                let request = u64_of(&mut r)?;
                let token = r.u32()? as i32;
                let logprob = r.f32()?;
                Frame::Token { request, token, logprob }
            }
            3 => Frame::GetStats,
            4 => {
                let n = r.u32()? as usize;
                Frame::Stats { json: String::from_utf8(r.take(n)?.to_vec())? }
            }
            5 => {
                let n = r.u16()? as usize;
                Frame::Error { msg: String::from_utf8(r.take(n)?.to_vec())? }
            }
            6 => Frame::Bye,
            7 => {
                let session = u64_of(&mut r)?;
                let request = u64_of(&mut r)?;
                let seq = r.u32()?;
                let kf = r.byte()?;
                ensure!(kf <= 1, "bad keyframe flag {kf}");
                let keyframe = kf == 1;
                let bucket = r.u16()?;
                let true_len = r.u16()?;
                let ks = r.u16()?;
                let kd = r.u16()?;
                let (packed, updates) = if keyframe {
                    let mut p = Vec::with_capacity(r.remaining() / 4);
                    while r.remaining() >= 4 {
                        p.push(r.f32()?);
                    }
                    ensure!(r.remaining() == 0,
                            "keyframe body not f32-aligned ({} stray bytes)",
                            r.remaining());
                    (p, Vec::new())
                } else {
                    let n = r.u32()? as usize;
                    let mut u = Vec::with_capacity(n.min(r.remaining() / 8));
                    for _ in 0..n {
                        let i = r.u32()?;
                        let v = r.f32()?;
                        u.push((i, v));
                    }
                    ensure!(r.remaining() == 0,
                            "trailing delta bytes ({})", r.remaining());
                    (Vec::new(), u)
                };
                Frame::Delta { session, request, seq, keyframe, bucket,
                               true_len, ks, kd, packed, updates }
            }
            t => bail!("unknown frame type {t}"),
        })
    }

    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(&self.encode())?;
        w.flush()?;
        Ok(())
    }

    pub fn read_from(r: &mut impl Read) -> Result<Frame> {
        let mut hdr = [0u8; 5];
        r.read_exact(&mut hdr)?;
        let len = u32::from_le_bytes([hdr[0], hdr[1], hdr[2], hdr[3]]) as usize;
        if len > MAX_FRAME {
            bail!("frame too large: {len}");
        }
        let mut body = vec![0u8; len];
        r.read_exact(&mut body)?;
        Frame::decode(hdr[4], &body)
    }
}

fn u64_of(r: &mut crate::codec::Reader) -> Result<u64> {
    let b = r.take(8)?;
    Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: Frame) {
        let enc = f.encode();
        let mut cur = std::io::Cursor::new(enc);
        let back = Frame::read_from(&mut cur).unwrap();
        assert_eq!(back, f);
    }

    #[test]
    fn all_frames_roundtrip() {
        roundtrip(Frame::Hello { session: 7, model: "llamette-m".into() });
        roundtrip(Frame::Activation {
            session: 1, request: 42, bucket: 32, true_len: 29, ks: 32, kd: 15,
            packed: vec![1.0, -2.5, 0.0, 3.25],
        });
        roundtrip(Frame::Token { request: 42, token: 101, logprob: -0.75 });
        roundtrip(Frame::GetStats);
        roundtrip(Frame::Stats { json: r#"{"n": 3}"#.into() });
        roundtrip(Frame::Error { msg: "bad bucket".into() });
        roundtrip(Frame::Bye);
        roundtrip(Frame::Delta {
            session: 3, request: 9, seq: 4, keyframe: true, bucket: 16,
            true_len: 12, ks: 5, kd: 3, packed: vec![0.5; 15],
            updates: vec![],
        });
        roundtrip(Frame::Delta {
            session: 3, request: 10, seq: 5, keyframe: false, bucket: 16,
            true_len: 13, ks: 5, kd: 3, packed: vec![],
            updates: vec![(0, 1.0), (7, -2.5), (14, 0.125)],
        });
        // empty delta: the "nothing drifted" frame is legal and tiny
        roundtrip(Frame::Delta {
            session: 3, request: 11, seq: 6, keyframe: false, bucket: 16,
            true_len: 13, ks: 5, kd: 3, packed: vec![], updates: vec![],
        });
    }

    #[test]
    fn rejects_unknown_type() {
        assert!(Frame::decode(99, &[]).is_err());
    }

    #[test]
    fn rejects_oversized() {
        let mut bytes = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        bytes.push(3);
        let mut cur = std::io::Cursor::new(bytes);
        assert!(Frame::read_from(&mut cur).is_err());
    }

    /// Every variant, for the truncation sweeps below.
    fn all_variants() -> Vec<Frame> {
        vec![
            Frame::Hello { session: 7, model: "llamette-m".into() },
            Frame::Activation {
                session: 1, request: 42, bucket: 32, true_len: 29, ks: 3,
                kd: 3, packed: vec![1.0, -2.5, 0.0, 3.25, 0.5, -1.0, 2.0,
                                    0.25, 9.0],
            },
            Frame::Token { request: 42, token: 101, logprob: -0.75 },
            Frame::GetStats,
            Frame::Stats { json: r#"{"n": 3}"#.into() },
            Frame::Error { msg: "bad bucket".into() },
            Frame::Bye,
            Frame::Delta {
                session: 1, request: 43, seq: 2, keyframe: true, bucket: 32,
                true_len: 29, ks: 3, kd: 3, packed: vec![1.0; 9],
                updates: vec![],
            },
            Frame::Delta {
                session: 1, request: 44, seq: 3, keyframe: false, bucket: 32,
                true_len: 30, ks: 3, kd: 3, packed: vec![],
                updates: vec![(2, 0.5), (8, -1.0)],
            },
        ]
    }

    #[test]
    fn every_truncated_stream_errors() {
        // cutting the byte stream anywhere — inside the 5-byte header
        // or inside the body — must yield an error, never a bogus frame
        for f in all_variants() {
            let enc = f.encode();
            for cut in 0..enc.len() {
                let mut cur = std::io::Cursor::new(enc[..cut].to_vec());
                assert!(Frame::read_from(&mut cur).is_err(),
                        "type {} truncated at {cut}/{} decoded", f.type_id(),
                        enc.len());
            }
        }
    }

    #[test]
    fn truncated_body_is_decode_error() {
        // bodies shorter than their fields declare
        assert!(Frame::decode(0, &[1, 2]).is_err()); // hello: no session
        // hello: model_len 5 but only 1 byte of model
        assert!(Frame::decode(
            0, &[0, 0, 0, 0, 0, 0, 0, 0, 5, 0, b'a']).is_err());
        assert!(Frame::decode(1, &[0; 10]).is_err()); // activation header
        assert!(Frame::decode(2, &[0; 10]).is_err()); // token: needs 16
        assert!(Frame::decode(4, &[255, 0, 0, 0]).is_err()); // stats: len 255
        assert!(Frame::decode(5, &[9, 0]).is_err()); // error: msg_len 9
    }

    #[test]
    fn activation_rejects_partial_trailing_float() {
        let f = Frame::Activation {
            session: 1, request: 2, bucket: 16, true_len: 8, ks: 3, kd: 3,
            packed: vec![1.0; 9],
        };
        let mut enc = f.encode();
        // append 2 stray bytes to the body and patch the length prefix
        enc.extend_from_slice(&[0xAA, 0xBB]);
        let body_len = (enc.len() - 5) as u32;
        enc[..4].copy_from_slice(&body_len.to_le_bytes());
        let mut cur = std::io::Cursor::new(enc);
        assert!(Frame::read_from(&mut cur).is_err(),
                "stray non-f32 bytes must not be silently dropped");
    }

    #[test]
    fn empty_stream_is_clean_eof_error() {
        let mut cur = std::io::Cursor::new(Vec::<u8>::new());
        assert!(Frame::read_from(&mut cur).is_err());
    }

    #[test]
    fn delta_decode_rejections() {
        // bad keyframe flag
        let f = Frame::Delta {
            session: 1, request: 2, seq: 0, keyframe: false, bucket: 16,
            true_len: 8, ks: 3, kd: 3, packed: vec![], updates: vec![(1, 2.0)],
        };
        let enc = f.encode();
        let mut body = enc[5..].to_vec();
        body[20] = 2; // keyframe flag offset: 8 + 8 + 4
        assert!(Frame::decode(7, &body).is_err());

        // keyframe with a partial trailing float
        let kf = Frame::Delta {
            session: 1, request: 2, seq: 0, keyframe: true, bucket: 16,
            true_len: 8, ks: 3, kd: 3, packed: vec![1.0; 9], updates: vec![],
        };
        let mut kenc = kf.encode();
        kenc.extend_from_slice(&[0xAA, 0xBB]);
        let body_len = (kenc.len() - 5) as u32;
        kenc[..4].copy_from_slice(&body_len.to_le_bytes());
        let mut cur = std::io::Cursor::new(kenc);
        assert!(Frame::read_from(&mut cur).is_err());

        // delta whose count promises more updates than the body holds
        let d = Frame::Delta {
            session: 1, request: 2, seq: 0, keyframe: false, bucket: 16,
            true_len: 8, ks: 3, kd: 3, packed: vec![],
            updates: vec![(1, 2.0), (3, 4.0)],
        };
        let denc = d.encode();
        let mut dbody = denc[5..].to_vec();
        dbody[29] = 3; // count offset: STREAM_HEADER_BYTES
        assert!(Frame::decode(7, &dbody).is_err());
        // ...and trailing bytes after the promised updates
        let mut tbody = denc[5..].to_vec();
        tbody[29] = 1;
        assert!(Frame::decode(7, &tbody).is_err());
    }

    #[test]
    fn delta_wire_bytes_accounting() {
        // keyframe: header + 4 bytes per packed float
        let kf = Frame::Delta {
            session: 0, request: 0, seq: 1, keyframe: true, bucket: 64,
            true_len: 64, ks: 33, kd: 15, packed: vec![0.0; 33 * 15],
            updates: vec![],
        };
        assert_eq!(kf.encode().len(), 5 + STREAM_HEADER_BYTES + 33 * 15 * 4);
        // delta: header + count + 8 bytes per update
        let d = Frame::Delta {
            session: 0, request: 0, seq: 2, keyframe: false, bucket: 64,
            true_len: 64, ks: 33, kd: 15, packed: vec![],
            updates: vec![(0, 1.0); 7],
        };
        assert_eq!(d.encode().len(), 5 + STREAM_HEADER_BYTES + 4 + 7 * 8);
    }

    #[test]
    fn wire_bytes_accounting() {
        // activation frame payload cost = 16 + header floats (paper's
        // transmitted volume is dominated by packed[·])
        let f = Frame::Activation {
            session: 0, request: 0, bucket: 64, true_len: 64, ks: 64, kd: 15,
            packed: vec![0.0; 64 * 15],
        };
        let enc = f.encode();
        assert_eq!(enc.len(), 5 + 24 + 64 * 15 * 4);
    }
}
