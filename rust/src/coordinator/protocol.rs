//! Wire protocol v2: length-prefixed binary frames over any
//! [`super::transport::Transport`].
//!
//!   u32 body_len | u8 frame_type | body
//!
//! Frames:
//!   Hello      c→s  u32 magic | u16 version | u32 caps | u64 session
//!                   | u16 model_len | model
//!   Activation c→s  u64 session | u64 request | u16 bucket | u16 true_len
//!                   | u16 ks | u16 kd | u8 point
//!                   | f32 packed[·]  (conjugate-sym pack)
//!                   (entropy: point bit 7 set, body = codec::wire
//!                   f32 plane instead of raw packed floats)
//!   Token      s→c  u64 request | i32 token | f32 logprob
//!   GetStats   c→s  (empty)
//!   Stats      s→c  u32 json_len | json
//!   Error      s→c  u8 code | u16 msg_len | msg
//!   Bye        c→s  (empty)
//!   Delta      c→s  u64 session | u64 request | u32 seq | u8 keyframe
//!                   | u16 bucket | u16 true_len | u16 ks | u16 kd | u8 point
//!                   | keyframe=1: f32 packed[·]   (full block)
//!                   | keyframe=0: u32 count | (u32 idx | f32 val)[count]
//!                   (entropy: keyframe bit 1 set, body = codec::wire
//!                   f32 plane (keyframe) or update list (sparse))
//!   HelloAck   s→c  u16 version | u32 caps | u16 bucket_count
//!                   | per bucket: u16 bucket | u8 n
//!                   | n x (u16 ks | u16 kd | f32 err_bound)
//!   PrefillChunk c→s u64 session | u64 request | u16 bucket
//!                   | u16 true_len | u16 ks | u16 kd | u8 point
//!                   | u32 index | u8 flags
//!                   | flags bit0 (keyframe chunk): f32 packed[·]
//!                     (a raw row slice of the packed plane)
//!                   | else: u32 count | (u32 idx | f32 val)[count]
//!                     (chunk-local sparse updates)
//!                   (flags bit1 = last chunk; bit2 = entropy-coded
//!                   body, a codec::wire f32 plane or update list)
//!
//! The v2 handshake replaces the old unversioned `Hello {session,
//! model}`: the client leads with [`PROTOCOL_MAGIC`], its protocol
//! version, and a capability bitset ([`caps`]); the server answers
//! with [`Frame::HelloAck`] advertising its own capabilities and the
//! bucket geometry it serves, so the client *negotiates* features
//! (stream, int8, codec set) instead of assuming its local manifest
//! matches the server's.  A version or magic mismatch is answered
//! with a typed [`ErrorCode::VersionMismatch`] reject, never silent
//! drift.
//!
//! `Delta` is the spectral stream's frame (`codec::stream`): `seq` is
//! the per-session stream sequence number and `keyframe` selects
//! between a full conjugate-symmetric block and sparse coefficient
//! updates into it.  The server keeps per-session decoder state and
//! hard-fails deltas that arrive out of sequence, answering with
//! [`ErrorCode::StreamReject`] so the client resyncs via keyframe.
//!
//! Entropy coding ([`caps::ENTROPY`], `codec::wire`) rides the
//! existing data frames without a version bump: when both sides
//! advertised the cap, a sender may flag a frame as entropy-coded via
//! spare flag bits in the existing header (Activation: bit 7 of the
//! ladder point byte; Delta: bit 1 of the keyframe byte) and replace
//! the raw payload with a self-describing `codec::wire` plane.
//! `Frame::decode` carries the coded bytes opaquely in `coded` — the
//! service decodes them lazily so corrupt bitstreams become typed
//! [`ErrorCode::BadRequest`] rejects, and a peer that never
//! negotiated the cap never sees a flag bit (legacy frames stay
//! byte-identical).
//!
//! `PrefillChunk` ([`caps::PREFILL`], `codec::stream::split_prefill`)
//! streams the prompt-phase block as fixed-row chunks — one keyframe
//! chunk (index 0, raw rows) plus row-delta chunks — again with no
//! version bump: a client that never negotiated the cap sends the
//! prompt as the usual monolithic Activation/Delta keyframe,
//! byte-identical to pre-prefill traffic.  The server reassembles
//! per-session, hard-fails chunk sequence gaps with
//! [`ErrorCode::StreamReject`] (the client restarts from chunk 0),
//! and a `Token` for the chunked request only follows the last chunk.

use anyhow::{bail, ensure, Result};
use std::io::{Read, Write};

pub const MAX_FRAME: usize = 64 << 20;

/// First field of every `Hello`: lets the server drop non-protocol
/// peers (and v1 clients, whose first body bytes are a session id)
/// with a typed reject instead of misparsing them.  ASCII "FCRP".
pub const PROTOCOL_MAGIC: u32 = 0x4643_5250;

/// Wire protocol version.  v1 was the unversioned `Hello {session,
/// model}` era; v2 introduced the negotiated handshake; v3 added the
/// adaptive rate ladder (a point byte on every Activation/Delta
/// header and per-bucket ladders in the HelloAck) — an incompatible
/// re-layout, which is exactly what the version field is for.  The
/// server rejects any other version with
/// [`ErrorCode::VersionMismatch`].
pub const PROTOCOL_VERSION: u16 = 3;

/// Bytes every frame pays on the wire before its body: u32 body_len +
/// u8 frame_type.
pub const FRAME_OVERHEAD_BYTES: usize = 5;

/// Fixed body-header bytes of a `Hello` frame (magic + version + caps
/// + session + model_len); the model string follows.
pub const HELLO_HEADER_BYTES: usize = 20;

/// Fixed body-header bytes of an `Activation` frame (session +
/// request + bucket + true_len + ks + kd + ladder point); the packed
/// block follows.
pub const ACTIVATION_HEADER_BYTES: usize = 25;

/// Full body of a `Token` frame (request + token + logprob).
pub const TOKEN_BODY_BYTES: usize = 16;

/// Fixed body-header bytes of a `Stats` frame (json_len).
pub const STATS_HEADER_BYTES: usize = 4;

/// Fixed body-header bytes of an `Error` frame (code + msg_len).
pub const ERROR_HEADER_BYTES: usize = 3;

/// Body-header bytes of a `Delta` frame (session + request + seq +
/// keyframe flag + bucket + true_len + ks + kd + ladder point) — the
/// stream counterpart of the Activation frame's
/// [`ACTIVATION_HEADER_BYTES`], used by the wire-byte accounting.
pub const STREAM_HEADER_BYTES: usize = 30;

/// Body-header bytes of a `PrefillChunk` frame (session + request +
/// bucket + true_len + ks + kd + ladder point + chunk index + flags)
/// — the prompt-phase counterpart of [`STREAM_HEADER_BYTES`], used by
/// the prefill wire-byte accounting.
pub const PREFILL_HEADER_BYTES: usize = 30;

/// Fixed body-header bytes of a `HelloAck` frame (version + caps +
/// bucket_count); [`HELLO_ACK_BUCKET_BYTES`] per advertised bucket
/// follow.
pub const HELLO_ACK_HEADER_BYTES: usize = 8;

/// Fixed bytes per bucket advertisement in a `HelloAck` (bucket +
/// ladder point count); [`HELLO_ACK_POINT_BYTES`] per point follow.
pub const HELLO_ACK_BUCKET_BYTES: usize = 3;

/// Bytes per quality-ladder point in a `HelloAck` bucket
/// advertisement (ks + kd + err_bound).
pub const HELLO_ACK_POINT_BYTES: usize = 8;

/// Capability bits negotiated by the handshake.  The effective
/// feature set of a connection is the intersection of the client's
/// `Hello.caps` and the server's `HelloAck.caps`; either side simply
/// not setting a bit is a *clean downgrade*, never an error.
pub mod caps {
    /// Spectral delta streaming ([`super::Frame::Delta`]).
    pub const STREAM: u32 = 1 << 0;
    /// Int8-quantised payloads (reserved: the int8 codec tier exists
    /// but no wire frame carries it yet).
    pub const INT8: u32 = 1 << 1;
    /// The FourierCompress codec (conjugate-symmetric packed blocks).
    pub const CODEC_FC: u32 = 1 << 2;
    /// The top-k sparse codec (reserved for future wire payloads).
    pub const CODEC_TOPK: u32 = 1 << 3;
    /// Adaptive spectral rate control (`codec::rate`): the server
    /// accepts data frames at the non-primary ladder points it
    /// advertises in its `HelloAck`.
    pub const LADDER: u32 = 1 << 4;
    /// Lossless entropy-coded payloads (`codec::wire`): Activation
    /// and Delta bodies may arrive as coded planes behind the spare
    /// header flag bits.  Negotiated like every other cap — a sender
    /// must never set a flag bit toward a peer that did not advertise
    /// this.
    pub const ENTROPY: u32 = 1 << 5;
    /// Chunked prefill streaming ([`super::Frame::PrefillChunk`]):
    /// the server reassembles a prompt-phase plane from one keyframe
    /// chunk plus row-delta chunks instead of requiring a monolithic
    /// transfer.  Un-negotiated sessions stay byte-identical.
    pub const PREFILL: u32 = 1 << 6;
}

/// Typed reason byte carried by every [`Frame::Error`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrorCode {
    /// Bad magic or unsupported protocol version in `Hello`.
    VersionMismatch = 1,
    /// Data frame arrived before a successful handshake on this
    /// connection, or named a session other than the one the
    /// connection handshook (the handshake *binds* connection and
    /// session — no cross-tenant serving or resurrection).  An
    /// *evicted own session* is not this: stateless recompute
    /// requests are transparently re-admitted, and stream frames get
    /// a [`ErrorCode::StreamReject`] resync instead.
    UnknownSession = 2,
    /// Stream frame refused: sequence gap, evicted decoder state, or
    /// stream admission pressure — the client answers with a keyframe
    /// resync.
    StreamReject = 3,
    /// Server-side execution failure.
    Internal = 4,
    /// Malformed or un-negotiated request (bad bucket geometry,
    /// unexpected frame, stream frames without the stream capability).
    BadRequest = 5,
    /// Session admission refused: the table is full of live sessions.
    AdmissionRefused = 6,
}

impl ErrorCode {
    pub fn from_u8(b: u8) -> Option<ErrorCode> {
        Some(match b {
            1 => ErrorCode::VersionMismatch,
            2 => ErrorCode::UnknownSession,
            3 => ErrorCode::StreamReject,
            4 => ErrorCode::Internal,
            5 => ErrorCode::BadRequest,
            6 => ErrorCode::AdmissionRefused,
            _ => return None,
        })
    }
}

impl std::fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ErrorCode::VersionMismatch => "version-mismatch",
            ErrorCode::UnknownSession => "unknown-session",
            ErrorCode::StreamReject => "stream-reject",
            ErrorCode::Internal => "internal",
            ErrorCode::BadRequest => "bad-request",
            ErrorCode::AdmissionRefused => "admission-refused",
        })
    }
}

/// A [`Frame::Error`] surfaced as a structured Rust error by
/// `DeviceClient`: callers match or `downcast_ref::<ServerError>()`
/// on the code instead of parsing message strings.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServerError {
    pub code: ErrorCode,
    pub msg: String,
}

impl std::fmt::Display for ServerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server error [{}]: {}", self.code, self.msg)
    }
}

impl std::error::Error for ServerError {}

/// One (ks, kd) operating point of a bucket's quality ladder as it
/// crosses the wire, with its forged Parseval error bound — the
/// additional reconstruction error the point introduces over the
/// bucket's primary block (see `codec::rate`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LadderEntry {
    pub ks: u16,
    pub kd: u16,
    pub err_bound: f32,
}

/// One bucket's advertisement in a [`Frame::HelloAck`]: the sequence
/// bucket and its quality ladder — point 0 is the primary geometry
/// (the paper's fixed block), later points keep nested, smaller
/// centred blocks a rate-controlled client may downshift to.
#[derive(Debug, Clone, PartialEq)]
pub struct BucketAdvert {
    pub bucket: u16,
    pub ladder: Vec<LadderEntry>,
}

impl BucketAdvert {
    /// The primary (point-0) block geometry; (0, 0) for a malformed
    /// pointless advert, which callers reject like a bucketless ack.
    pub fn primary(&self) -> (u16, u16) {
        self.ladder.first().map(|p| (p.ks, p.kd)).unwrap_or((0, 0))
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    Hello {
        magic: u32,
        version: u16,
        caps: u32,
        session: u64,
        model: String,
    },
    Activation {
        session: u64,
        request: u64,
        bucket: u16,
        true_len: u16,
        ks: u16,
        kd: u16,
        /// Quality-ladder point the (ks, kd) block belongs to (0 =
        /// the bucket's primary geometry); the server validates it
        /// against the ladder it advertised.
        point: u8,
        packed: Vec<f32>,
        /// Entropy-coded body (`codec::wire` f32 plane).  Invariant:
        /// non-empty ⇔ the frame crossed the wire entropy-coded, and
        /// then `packed` is empty.  Requires [`caps::ENTROPY`] on
        /// both sides; flagged on the wire via bit 7 of `point`.
        coded: Vec<u8>,
    },
    Token { request: u64, token: i32, logprob: f32 },
    GetStats,
    Stats { json: String },
    Error { code: ErrorCode, msg: String },
    Bye,
    /// Spectral stream frame: a keyframe carries the full packed
    /// block in `packed` (and `updates` is empty); a delta carries
    /// sparse `(index, value)` coefficient updates (and `packed` is
    /// empty).
    Delta {
        session: u64,
        request: u64,
        seq: u32,
        keyframe: bool,
        bucket: u16,
        true_len: u16,
        ks: u16,
        kd: u16,
        /// Quality-ladder point of the stream's current geometry; a
        /// ladder switch must arrive as a keyframe (the geometry
        /// changed), so a delta naming a new point is rejected.
        point: u8,
        packed: Vec<f32>,
        updates: Vec<(u32, f32)>,
        /// Entropy-coded body: a `codec::wire` f32 plane (keyframe)
        /// or update list (sparse delta).  Invariant: non-empty ⇔
        /// entropy-coded on the wire, and then `packed`/`updates`
        /// are empty.  Flagged via bit 1 of the keyframe byte.
        coded: Vec<u8>,
    },
    /// Server's handshake answer: its protocol version, capability
    /// bits, and the bucket quality ladders it serves — the client
    /// checks the geometry against its local manifest so
    /// device/server manifest drift fails the connection instead of
    /// the codec.
    HelloAck {
        version: u16,
        caps: u32,
        buckets: Vec<BucketAdvert>,
    },
    /// One chunk of a chunked prompt-phase transfer
    /// (`codec::stream::split_prefill`): a keyframe chunk carries a
    /// raw row slice of the packed plane in `packed`; a delta chunk
    /// carries chunk-local sparse updates against the previous
    /// chunk's rows.  The `Token` answer follows the `last` chunk.
    PrefillChunk {
        session: u64,
        request: u64,
        bucket: u16,
        true_len: u16,
        ks: u16,
        kd: u16,
        /// Quality-ladder point of the whole chunked plane — prefill
        /// may ride a cheaper rung than decode.
        point: u8,
        /// 0-based chunk index; chunk 0 is always a keyframe chunk
        /// and defines the chunk length.
        index: u32,
        /// Final chunk of the plane.
        last: bool,
        /// Keyframe chunk (raw rows) vs delta chunk (updates).
        keyframe: bool,
        packed: Vec<f32>,
        updates: Vec<(u32, f32)>,
        /// Entropy-coded body: a `codec::wire` f32 plane (keyframe
        /// chunk) or update list (delta chunk).  Invariant: non-empty
        /// ⇔ entropy-coded on the wire, and then `packed`/`updates`
        /// are empty.  Flagged via bit 2 of the flags byte.
        coded: Vec<u8>,
    },
}

impl Frame {
    /// A `Hello` carrying the current magic + protocol version.
    pub fn hello(session: u64, caps: u32, model: impl Into<String>) -> Frame {
        Frame::Hello {
            magic: PROTOCOL_MAGIC,
            version: PROTOCOL_VERSION,
            caps,
            session,
            model: model.into(),
        }
    }

    pub fn type_id(&self) -> u8 {
        match self {
            Frame::Hello { .. } => 0,
            Frame::Activation { .. } => 1,
            Frame::Token { .. } => 2,
            Frame::GetStats => 3,
            Frame::Stats { .. } => 4,
            Frame::Error { .. } => 5,
            Frame::Bye => 6,
            Frame::Delta { .. } => 7,
            Frame::HelloAck { .. } => 8,
            Frame::PrefillChunk { .. } => 9,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::new();
        match self {
            Frame::Hello { magic, version, caps, session, model } => {
                b.extend_from_slice(&magic.to_le_bytes());
                b.extend_from_slice(&version.to_le_bytes());
                b.extend_from_slice(&caps.to_le_bytes());
                b.extend_from_slice(&session.to_le_bytes());
                b.extend_from_slice(&(model.len() as u16).to_le_bytes());
                b.extend_from_slice(model.as_bytes());
            }
            Frame::Activation { session, request, bucket, true_len, ks, kd,
                                point, packed, coded } => {
                b.extend_from_slice(&session.to_le_bytes());
                b.extend_from_slice(&request.to_le_bytes());
                b.extend_from_slice(&bucket.to_le_bytes());
                b.extend_from_slice(&true_len.to_le_bytes());
                b.extend_from_slice(&ks.to_le_bytes());
                b.extend_from_slice(&kd.to_le_bytes());
                if coded.is_empty() {
                    b.push(*point);
                    crate::codec::Writer(&mut b).f32s(packed);
                } else {
                    debug_assert!(packed.is_empty(),
                                  "coded and packed are exclusive");
                    b.push(*point | 0x80);
                    b.extend_from_slice(coded);
                }
            }
            Frame::Token { request, token, logprob } => {
                b.extend_from_slice(&request.to_le_bytes());
                b.extend_from_slice(&token.to_le_bytes());
                b.extend_from_slice(&logprob.to_le_bytes());
            }
            Frame::GetStats | Frame::Bye => {}
            Frame::Stats { json } => {
                b.extend_from_slice(&(json.len() as u32).to_le_bytes());
                b.extend_from_slice(json.as_bytes());
            }
            Frame::Error { code, msg } => {
                b.push(*code as u8);
                b.extend_from_slice(&(msg.len() as u16).to_le_bytes());
                b.extend_from_slice(msg.as_bytes());
            }
            Frame::Delta { session, request, seq, keyframe, bucket, true_len,
                           ks, kd, point, packed, updates, coded } => {
                b.extend_from_slice(&session.to_le_bytes());
                b.extend_from_slice(&request.to_le_bytes());
                b.extend_from_slice(&seq.to_le_bytes());
                b.push(*keyframe as u8 | if coded.is_empty() { 0 } else { 2 });
                b.extend_from_slice(&bucket.to_le_bytes());
                b.extend_from_slice(&true_len.to_le_bytes());
                b.extend_from_slice(&ks.to_le_bytes());
                b.extend_from_slice(&kd.to_le_bytes());
                b.push(*point);
                if !coded.is_empty() {
                    debug_assert!(packed.is_empty() && updates.is_empty(),
                                  "coded and raw bodies are exclusive");
                    b.extend_from_slice(coded);
                } else if *keyframe {
                    crate::codec::Writer(&mut b).f32s(packed);
                } else {
                    b.extend_from_slice(&(updates.len() as u32).to_le_bytes());
                    for (i, v) in updates {
                        b.extend_from_slice(&i.to_le_bytes());
                        b.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
            Frame::HelloAck { version, caps, buckets } => {
                b.extend_from_slice(&version.to_le_bytes());
                b.extend_from_slice(&caps.to_le_bytes());
                b.extend_from_slice(&(buckets.len() as u16).to_le_bytes());
                for g in buckets {
                    b.extend_from_slice(&g.bucket.to_le_bytes());
                    b.push(g.ladder.len() as u8);
                    for p in &g.ladder {
                        b.extend_from_slice(&p.ks.to_le_bytes());
                        b.extend_from_slice(&p.kd.to_le_bytes());
                        b.extend_from_slice(&p.err_bound.to_le_bytes());
                    }
                }
            }
            Frame::PrefillChunk { session, request, bucket, true_len, ks, kd,
                                  point, index, last, keyframe, packed,
                                  updates, coded } => {
                b.extend_from_slice(&session.to_le_bytes());
                b.extend_from_slice(&request.to_le_bytes());
                b.extend_from_slice(&bucket.to_le_bytes());
                b.extend_from_slice(&true_len.to_le_bytes());
                b.extend_from_slice(&ks.to_le_bytes());
                b.extend_from_slice(&kd.to_le_bytes());
                b.push(*point);
                b.extend_from_slice(&index.to_le_bytes());
                b.push(*keyframe as u8
                       | (*last as u8) << 1
                       | if coded.is_empty() { 0 } else { 4 });
                if !coded.is_empty() {
                    debug_assert!(packed.is_empty() && updates.is_empty(),
                                  "coded and raw bodies are exclusive");
                    b.extend_from_slice(coded);
                } else if *keyframe {
                    crate::codec::Writer(&mut b).f32s(packed);
                } else {
                    b.extend_from_slice(&(updates.len() as u32).to_le_bytes());
                    for (i, v) in updates {
                        b.extend_from_slice(&i.to_le_bytes());
                        b.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
        }
        let mut out = Vec::with_capacity(FRAME_OVERHEAD_BYTES + b.len());
        out.extend_from_slice(&(b.len() as u32).to_le_bytes());
        out.push(self.type_id());
        out.extend_from_slice(&b);
        out
    }

    pub fn decode(type_id: u8, body: &[u8]) -> Result<Frame> {
        let mut r = crate::codec::Reader::new(body);
        Ok(match type_id {
            0 => {
                // magic + version lead the body so a Hello from a
                // different protocol era still *decodes* (the foreign
                // remainder is not parsed) and reaches the service,
                // which answers with a typed VersionMismatch — a v1
                // peer gets a reject frame, not a silent disconnect.
                let magic = r.u32()?;
                let version = r.u16()?;
                if magic != PROTOCOL_MAGIC || version != PROTOCOL_VERSION {
                    return Ok(Frame::Hello {
                        magic, version, caps: 0, session: 0,
                        model: String::new(),
                    });
                }
                let caps = r.u32()?;
                let session = u64_of(&mut r)?;
                let n = r.u16()? as usize;
                let model = String::from_utf8(r.take(n)?.to_vec())?;
                ensure!(r.remaining() == 0,
                        "trailing hello bytes ({})", r.remaining());
                Frame::Hello { magic, version, caps, session, model }
            }
            1 => {
                let session = u64_of(&mut r)?;
                let request = u64_of(&mut r)?;
                let bucket = r.u16()?;
                let true_len = r.u16()?;
                let ks = r.u16()?;
                let kd = r.u16()?;
                let point = r.byte()?;
                let (packed, coded) = if point & 0x80 != 0 {
                    // entropy-coded body: carried opaquely, decoded
                    // lazily by the service behind the cap check
                    let c = r.take(r.remaining())?.to_vec();
                    ensure!(!c.is_empty(), "empty entropy-coded activation");
                    (Vec::new(), c)
                } else {
                    let mut p = Vec::new();
                    r.f32s(r.remaining() / 4, &mut p)?;
                    ensure!(r.remaining() == 0,
                            "activation body not f32-aligned ({} stray bytes)",
                            r.remaining());
                    (p, Vec::new())
                };
                Frame::Activation { session, request, bucket, true_len, ks, kd,
                                    point: point & 0x7F, packed, coded }
            }
            2 => {
                let request = u64_of(&mut r)?;
                let token = r.u32()? as i32;
                let logprob = r.f32()?;
                Frame::Token { request, token, logprob }
            }
            3 => Frame::GetStats,
            4 => {
                let n = r.u32()? as usize;
                Frame::Stats { json: String::from_utf8(r.take(n)?.to_vec())? }
            }
            5 => {
                let c = r.byte()?;
                let code = ErrorCode::from_u8(c)
                    .ok_or_else(|| anyhow::anyhow!("unknown error code {c}"))?;
                let n = r.u16()? as usize;
                let msg = String::from_utf8(r.take(n)?.to_vec())?;
                Frame::Error { code, msg }
            }
            6 => Frame::Bye,
            7 => {
                let session = u64_of(&mut r)?;
                let request = u64_of(&mut r)?;
                let seq = r.u32()?;
                let kf = r.byte()?;
                ensure!(kf <= 3, "bad keyframe flag {kf}");
                let keyframe = kf & 1 == 1;
                let is_coded = kf & 2 != 0;
                let bucket = r.u16()?;
                let true_len = r.u16()?;
                let ks = r.u16()?;
                let kd = r.u16()?;
                let point = r.byte()?;
                let (packed, updates, coded) = if is_coded {
                    let c = r.take(r.remaining())?.to_vec();
                    ensure!(!c.is_empty(), "empty entropy-coded delta");
                    (Vec::new(), Vec::new(), c)
                } else if keyframe {
                    let mut p = Vec::new();
                    r.f32s(r.remaining() / 4, &mut p)?;
                    ensure!(r.remaining() == 0,
                            "keyframe body not f32-aligned ({} stray bytes)",
                            r.remaining());
                    (p, Vec::new(), Vec::new())
                } else {
                    let n = r.u32()? as usize;
                    let mut u = Vec::with_capacity(n.min(r.remaining() / 8));
                    for _ in 0..n {
                        let i = r.u32()?;
                        let v = r.f32()?;
                        u.push((i, v));
                    }
                    ensure!(r.remaining() == 0,
                            "trailing delta bytes ({})", r.remaining());
                    (Vec::new(), u, Vec::new())
                };
                Frame::Delta { session, request, seq, keyframe, bucket,
                               true_len, ks, kd, point, packed, updates,
                               coded }
            }
            8 => {
                let version = r.u16()?;
                let caps = r.u32()?;
                let n = r.u16()? as usize;
                let mut buckets =
                    Vec::with_capacity(n.min(r.remaining()
                                             / HELLO_ACK_BUCKET_BYTES));
                for _ in 0..n {
                    let bucket = r.u16()?;
                    let points = r.byte()? as usize;
                    let mut ladder = Vec::with_capacity(
                        points.min(r.remaining() / HELLO_ACK_POINT_BYTES));
                    for _ in 0..points {
                        let ks = r.u16()?;
                        let kd = r.u16()?;
                        let err_bound = r.f32()?;
                        ladder.push(LadderEntry { ks, kd, err_bound });
                    }
                    buckets.push(BucketAdvert { bucket, ladder });
                }
                ensure!(r.remaining() == 0,
                        "trailing hello-ack bytes ({})", r.remaining());
                Frame::HelloAck { version, caps, buckets }
            }
            9 => {
                let session = u64_of(&mut r)?;
                let request = u64_of(&mut r)?;
                let bucket = r.u16()?;
                let true_len = r.u16()?;
                let ks = r.u16()?;
                let kd = r.u16()?;
                let point = r.byte()?;
                let index = r.u32()?;
                let flags = r.byte()?;
                ensure!(flags <= 7, "bad prefill flags {flags}");
                let keyframe = flags & 1 == 1;
                let last = flags & 2 != 0;
                let is_coded = flags & 4 != 0;
                let (packed, updates, coded) = if is_coded {
                    let c = r.take(r.remaining())?.to_vec();
                    ensure!(!c.is_empty(),
                            "empty entropy-coded prefill chunk");
                    (Vec::new(), Vec::new(), c)
                } else if keyframe {
                    let mut p = Vec::new();
                    r.f32s(r.remaining() / 4, &mut p)?;
                    ensure!(r.remaining() == 0,
                            "prefill chunk body not f32-aligned ({} stray \
                             bytes)", r.remaining());
                    (p, Vec::new(), Vec::new())
                } else {
                    let n = r.u32()? as usize;
                    let mut u = Vec::with_capacity(n.min(r.remaining() / 8));
                    for _ in 0..n {
                        let i = r.u32()?;
                        let v = r.f32()?;
                        u.push((i, v));
                    }
                    ensure!(r.remaining() == 0,
                            "trailing prefill chunk bytes ({})",
                            r.remaining());
                    (Vec::new(), u, Vec::new())
                };
                Frame::PrefillChunk { session, request, bucket, true_len, ks,
                                      kd, point, index, last, keyframe,
                                      packed, updates, coded }
            }
            t => bail!("unknown frame type {t}"),
        })
    }

    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(&self.encode())?;
        w.flush()?;
        Ok(())
    }

    pub fn read_from(r: &mut impl Read) -> Result<Frame> {
        let mut hdr = [0u8; FRAME_OVERHEAD_BYTES];
        r.read_exact(&mut hdr)?;
        let len = u32::from_le_bytes([hdr[0], hdr[1], hdr[2], hdr[3]]) as usize;
        if len > MAX_FRAME {
            bail!("frame too large: {len}");
        }
        let mut body = vec![0u8; len];
        r.read_exact(&mut body)?;
        Frame::decode(hdr[4], &body)
    }
}

fn u64_of(r: &mut crate::codec::Reader) -> Result<u64> {
    let b = r.take(8)?;
    Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(f: Frame) {
        let enc = f.encode();
        let mut cur = std::io::Cursor::new(enc);
        let back = Frame::read_from(&mut cur).unwrap();
        assert_eq!(back, f);
    }

    fn advert(bucket: u16, points: &[(u16, u16, f32)]) -> BucketAdvert {
        BucketAdvert {
            bucket,
            ladder: points
                .iter()
                .map(|&(ks, kd, err_bound)| LadderEntry { ks, kd, err_bound })
                .collect(),
        }
    }

    #[test]
    fn all_frames_roundtrip() {
        roundtrip(Frame::hello(7, caps::STREAM | caps::CODEC_FC, "llamette-m"));
        roundtrip(Frame::Activation {
            session: 1, request: 42, bucket: 32, true_len: 29, ks: 32, kd: 15,
            point: 0, packed: vec![1.0, -2.5, 0.0, 3.25],
            coded: vec![],
        });
        // a downshifted ladder point rides the same header
        roundtrip(Frame::Activation {
            session: 1, request: 43, bucket: 32, true_len: 29, ks: 32, kd: 7,
            point: 2, packed: vec![1.0, -2.5],
            coded: vec![],
        });
        roundtrip(Frame::Token { request: 42, token: 101, logprob: -0.75 });
        roundtrip(Frame::GetStats);
        roundtrip(Frame::Stats { json: r#"{"n": 3}"#.into() });
        roundtrip(Frame::Error {
            code: ErrorCode::BadRequest, msg: "bad bucket".into() });
        roundtrip(Frame::Bye);
        roundtrip(Frame::Delta {
            session: 3, request: 9, seq: 4, keyframe: true, bucket: 16,
            true_len: 12, ks: 5, kd: 3, point: 1, packed: vec![0.5; 15],
            updates: vec![],
            coded: vec![],
        });
        roundtrip(Frame::Delta {
            session: 3, request: 10, seq: 5, keyframe: false, bucket: 16,
            true_len: 13, ks: 5, kd: 3, point: 0, packed: vec![],
            updates: vec![(0, 1.0), (7, -2.5), (14, 0.125)],
            coded: vec![],
        });
        // empty delta: the "nothing drifted" frame is legal and tiny
        roundtrip(Frame::Delta {
            session: 3, request: 11, seq: 6, keyframe: false, bucket: 16,
            true_len: 13, ks: 5, kd: 3, point: 0, packed: vec![],
            updates: vec![],
            coded: vec![],
        });
        // entropy-coded bodies ride the spare flag bits (the coded
        // bytes are opaque at this layer)
        roundtrip(Frame::Activation {
            session: 1, request: 44, bucket: 32, true_len: 29, ks: 32, kd: 15,
            point: 2, packed: vec![], coded: vec![1, 4, 0, 0, 0, 0xAB, 0xCD],
        });
        roundtrip(Frame::Delta {
            session: 3, request: 12, seq: 7, keyframe: true, bucket: 16,
            true_len: 12, ks: 5, kd: 3, point: 1, packed: vec![],
            updates: vec![], coded: vec![2, 1, 0, 0, 0, 0x55],
        });
        roundtrip(Frame::Delta {
            session: 3, request: 13, seq: 8, keyframe: false, bucket: 16,
            true_len: 13, ks: 5, kd: 3, point: 0, packed: vec![],
            updates: vec![], coded: vec![0, 0, 0, 0, 0],
        });
        roundtrip(Frame::HelloAck {
            version: PROTOCOL_VERSION, caps: caps::STREAM | caps::CODEC_FC,
            buckets: vec![
                advert(16, &[(9, 15, 0.05), (9, 9, 0.2), (5, 7, 0.5)]),
                advert(32, &[(17, 15, 0.04)]),
            ],
        });
        // a bucketless ack is legal on the wire (rejected higher up)
        roundtrip(Frame::HelloAck { version: 1, caps: 0, buckets: vec![] });
        // ...as is a pointless bucket advertisement
        roundtrip(Frame::HelloAck {
            version: PROTOCOL_VERSION, caps: 0,
            buckets: vec![advert(16, &[])],
        });
        // prefill chunks: keyframe chunk, delta chunk, last-flagged,
        // and an entropy-coded body
        roundtrip(Frame::PrefillChunk {
            session: 4, request: 20, bucket: 128, true_len: 100, ks: 17,
            kd: 11, point: 0, index: 0, last: false, keyframe: true,
            packed: vec![1.0, -2.5, 0.0, 3.25], updates: vec![],
            coded: vec![],
        });
        roundtrip(Frame::PrefillChunk {
            session: 4, request: 20, bucket: 128, true_len: 100, ks: 17,
            kd: 11, point: 1, index: 3, last: false, keyframe: false,
            packed: vec![], updates: vec![(0, 1.0), (7, -2.5)],
            coded: vec![],
        });
        roundtrip(Frame::PrefillChunk {
            session: 4, request: 20, bucket: 128, true_len: 100, ks: 17,
            kd: 11, point: 0, index: 8, last: true, keyframe: false,
            packed: vec![], updates: vec![], coded: vec![],
        });
        roundtrip(Frame::PrefillChunk {
            session: 4, request: 21, bucket: 128, true_len: 100, ks: 17,
            kd: 11, point: 0, index: 0, last: false, keyframe: true,
            packed: vec![], updates: vec![], coded: vec![1, 4, 0, 0, 0, 0xEE],
        });
    }

    #[test]
    fn rejects_unknown_type() {
        assert!(Frame::decode(99, &[]).is_err());
    }

    /// A Hello from a different protocol era (v1 layout, or any
    /// future shape) must still decode into a rejectable Hello — the
    /// service's typed VersionMismatch is unreachable if foreign
    /// handshakes die in the parser.
    #[test]
    fn foreign_era_hello_decodes_to_rejectable_hello() {
        // v1 layout: u64 session | u16 model_len | model
        let mut v1 = Vec::new();
        v1.extend_from_slice(&9u64.to_le_bytes());
        v1.extend_from_slice(&(10u16).to_le_bytes());
        v1.extend_from_slice(b"llamette-m");
        match Frame::decode(0, &v1).unwrap() {
            Frame::Hello { magic, .. } => {
                assert_ne!(magic, PROTOCOL_MAGIC, "v1 bytes are not magic");
            }
            other => panic!("expected Hello, got {}", other.type_id()),
        }
        // current magic, future version, longer body: still decodes
        let future = PROTOCOL_VERSION + 1;
        let mut vf = Vec::new();
        vf.extend_from_slice(&PROTOCOL_MAGIC.to_le_bytes());
        vf.extend_from_slice(&future.to_le_bytes());
        vf.extend_from_slice(&[0xAB; 40]); // unknown future payload
        match Frame::decode(0, &vf).unwrap() {
            Frame::Hello { magic, version, .. } => {
                assert_eq!(magic, PROTOCOL_MAGIC);
                assert_eq!(version, future);
            }
            other => panic!("expected Hello, got {}", other.type_id()),
        }
        // fewer than magic+version bytes is still a decode error
        assert!(Frame::decode(0, &[1, 2]).is_err());
    }

    #[test]
    fn rejects_unknown_error_code() {
        let f = Frame::Error { code: ErrorCode::Internal, msg: "x".into() };
        let enc = f.encode();
        let mut body = enc[FRAME_OVERHEAD_BYTES..].to_vec();
        body[0] = 200; // not a defined ErrorCode
        assert!(Frame::decode(5, &body).is_err());
    }

    #[test]
    fn rejects_oversized() {
        let mut bytes = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        bytes.push(3);
        let mut cur = std::io::Cursor::new(bytes);
        assert!(Frame::read_from(&mut cur).is_err());
    }

    /// Every variant, for the truncation sweeps below.
    fn all_variants() -> Vec<Frame> {
        vec![
            Frame::hello(7, caps::STREAM, "llamette-m"),
            Frame::Activation {
                session: 1, request: 42, bucket: 32, true_len: 29, ks: 3,
                kd: 3, point: 0,
                packed: vec![1.0, -2.5, 0.0, 3.25, 0.5, -1.0, 2.0, 0.25, 9.0],
                coded: vec![],
            },
            Frame::Token { request: 42, token: 101, logprob: -0.75 },
            Frame::GetStats,
            Frame::Stats { json: r#"{"n": 3}"#.into() },
            Frame::Error { code: ErrorCode::BadRequest,
                           msg: "bad bucket".into() },
            Frame::Bye,
            Frame::Delta {
                session: 1, request: 43, seq: 2, keyframe: true, bucket: 32,
                true_len: 29, ks: 3, kd: 3, point: 1, packed: vec![1.0; 9],
                updates: vec![],
                coded: vec![],
            },
            Frame::Delta {
                session: 1, request: 44, seq: 3, keyframe: false, bucket: 32,
                true_len: 30, ks: 3, kd: 3, point: 0, packed: vec![],
                updates: vec![(2, 0.5), (8, -1.0)],
                coded: vec![],
            },
            Frame::Activation {
                session: 1, request: 45, bucket: 32, true_len: 29, ks: 3,
                kd: 3, point: 1, packed: vec![],
                coded: vec![1, 9, 0, 0, 0, 0xDE, 0xAD, 0xBE, 0xEF],
            },
            Frame::Delta {
                session: 1, request: 46, seq: 4, keyframe: false, bucket: 32,
                true_len: 30, ks: 3, kd: 3, point: 0, packed: vec![],
                updates: vec![], coded: vec![0, 0, 0, 0, 0],
            },
            Frame::HelloAck {
                version: PROTOCOL_VERSION, caps: caps::STREAM,
                buckets: vec![advert(16, &[(9, 15, 0.1), (9, 7, 0.3)])],
            },
            Frame::PrefillChunk {
                session: 1, request: 47, bucket: 32, true_len: 29, ks: 3,
                kd: 3, point: 0, index: 0, last: false, keyframe: true,
                packed: vec![1.0, -2.0, 3.0], updates: vec![],
                coded: vec![],
            },
            Frame::PrefillChunk {
                session: 1, request: 47, bucket: 32, true_len: 29, ks: 3,
                kd: 3, point: 0, index: 2, last: true, keyframe: false,
                packed: vec![], updates: vec![(1, 0.5), (2, -1.5)],
                coded: vec![],
            },
            Frame::PrefillChunk {
                session: 1, request: 48, bucket: 32, true_len: 29, ks: 3,
                kd: 3, point: 1, index: 0, last: false, keyframe: true,
                packed: vec![], updates: vec![],
                coded: vec![1, 3, 0, 0, 0, 0xBE, 0xEF],
            },
        ]
    }

    #[test]
    fn every_truncated_stream_errors() {
        // cutting the byte stream anywhere — inside the 5-byte header
        // or inside the body — must yield an error, never a bogus frame
        for f in all_variants() {
            let enc = f.encode();
            for cut in 0..enc.len() {
                let mut cur = std::io::Cursor::new(enc[..cut].to_vec());
                assert!(Frame::read_from(&mut cur).is_err(),
                        "type {} truncated at {cut}/{} decoded", f.type_id(),
                        enc.len());
            }
        }
    }

    #[test]
    fn truncated_body_is_decode_error() {
        // bodies shorter than their fields declare
        assert!(Frame::decode(0, &[1, 2]).is_err()); // hello: no header
        // hello: model_len 5 but only 1 byte of model
        let mut h = Frame::hello(0, 0, "abcde").encode()[FRAME_OVERHEAD_BYTES..]
            .to_vec();
        h.truncate(HELLO_HEADER_BYTES + 1);
        assert!(Frame::decode(0, &h).is_err());
        assert!(Frame::decode(1, &[0; 10]).is_err()); // activation header
        assert!(Frame::decode(2, &[0; 10]).is_err()); // token: needs 16
        assert!(Frame::decode(4, &[255, 0, 0, 0]).is_err()); // stats: len 255
        assert!(Frame::decode(5, &[4, 9, 0]).is_err()); // error: msg_len 9
        // hello-ack: 3 buckets promised, body holds 1
        let mut a = Frame::HelloAck {
            version: 2, caps: 0,
            buckets: vec![advert(16, &[(3, 3, 0.5)])],
        }.encode()[FRAME_OVERHEAD_BYTES..].to_vec();
        a[6] = 3;
        assert!(Frame::decode(8, &a).is_err());
        // hello-ack: bucket promises 4 ladder points, body holds 1
        let mut a = Frame::HelloAck {
            version: 2, caps: 0,
            buckets: vec![advert(16, &[(3, 3, 0.5)])],
        }.encode()[FRAME_OVERHEAD_BYTES..].to_vec();
        a[HELLO_ACK_HEADER_BYTES + 2] = 4; // point count of bucket 0
        assert!(Frame::decode(8, &a).is_err());
    }

    #[test]
    fn activation_rejects_partial_trailing_float() {
        let f = Frame::Activation {
            session: 1, request: 2, bucket: 16, true_len: 8, ks: 3, kd: 3,
            point: 0, packed: vec![1.0; 9],
            coded: vec![],
        };
        let mut enc = f.encode();
        // append 2 stray bytes to the body and patch the length prefix
        enc.extend_from_slice(&[0xAA, 0xBB]);
        let body_len = (enc.len() - FRAME_OVERHEAD_BYTES) as u32;
        enc[..4].copy_from_slice(&body_len.to_le_bytes());
        let mut cur = std::io::Cursor::new(enc);
        assert!(Frame::read_from(&mut cur).is_err(),
                "stray non-f32 bytes must not be silently dropped");
    }

    #[test]
    fn empty_stream_is_clean_eof_error() {
        let mut cur = std::io::Cursor::new(Vec::<u8>::new());
        assert!(Frame::read_from(&mut cur).is_err());
    }

    #[test]
    fn delta_decode_rejections() {
        // bad keyframe flag
        let f = Frame::Delta {
            session: 1, request: 2, seq: 0, keyframe: false, bucket: 16,
            true_len: 8, ks: 3, kd: 3, point: 0, packed: vec![],
            updates: vec![(1, 2.0)],
            coded: vec![],
        };
        let enc = f.encode();
        let mut body = enc[FRAME_OVERHEAD_BYTES..].to_vec();
        body[20] = 4; // keyframe flag offset: 8 + 8 + 4; 4 > coded|kf
        assert!(Frame::decode(7, &body).is_err());

        // the coded flag (bit 1) with an empty body is malformed
        let mut body = enc[FRAME_OVERHEAD_BYTES..].to_vec();
        body[20] = 2;
        body.truncate(STREAM_HEADER_BYTES);
        assert!(Frame::decode(7, &body).is_err(),
                "empty entropy-coded delta must not decode");
        // ...but with a body it decodes, carrying the bytes opaquely
        let mut body = enc[FRAME_OVERHEAD_BYTES..].to_vec();
        body[20] = 2;
        match Frame::decode(7, &body).unwrap() {
            Frame::Delta { keyframe, packed, updates, coded, .. } => {
                assert!(!keyframe);
                assert!(packed.is_empty() && updates.is_empty());
                assert_eq!(coded.len(), 4 + 8); // former count + 1 update
            }
            other => panic!("expected Delta, got {}", other.type_id()),
        }

        // keyframe with a partial trailing float
        let kf = Frame::Delta {
            session: 1, request: 2, seq: 0, keyframe: true, bucket: 16,
            true_len: 8, ks: 3, kd: 3, point: 0, packed: vec![1.0; 9],
            updates: vec![],
            coded: vec![],
        };
        let mut kenc = kf.encode();
        kenc.extend_from_slice(&[0xAA, 0xBB]);
        let body_len = (kenc.len() - FRAME_OVERHEAD_BYTES) as u32;
        kenc[..4].copy_from_slice(&body_len.to_le_bytes());
        let mut cur = std::io::Cursor::new(kenc);
        assert!(Frame::read_from(&mut cur).is_err());

        // delta whose count promises more updates than the body holds
        let d = Frame::Delta {
            session: 1, request: 2, seq: 0, keyframe: false, bucket: 16,
            true_len: 8, ks: 3, kd: 3, point: 0, packed: vec![],
            updates: vec![(1, 2.0), (3, 4.0)],
            coded: vec![],
        };
        let denc = d.encode();
        let mut dbody = denc[FRAME_OVERHEAD_BYTES..].to_vec();
        dbody[STREAM_HEADER_BYTES] = 3; // update count leads the body
        assert!(Frame::decode(7, &dbody).is_err());
        // ...and trailing bytes after the promised updates
        let mut tbody = denc[FRAME_OVERHEAD_BYTES..].to_vec();
        tbody[STREAM_HEADER_BYTES] = 1;
        assert!(Frame::decode(7, &tbody).is_err());
    }

    #[test]
    fn delta_wire_bytes_accounting() {
        // keyframe: header + 4 bytes per packed float
        let kf = Frame::Delta {
            session: 0, request: 0, seq: 1, keyframe: true, bucket: 64,
            true_len: 64, ks: 33, kd: 15, point: 0, packed: vec![0.0; 33 * 15],
            updates: vec![],
            coded: vec![],
        };
        assert_eq!(kf.encode().len(),
                   FRAME_OVERHEAD_BYTES + STREAM_HEADER_BYTES + 33 * 15 * 4);
        // delta: header + count + 8 bytes per update
        let d = Frame::Delta {
            session: 0, request: 0, seq: 2, keyframe: false, bucket: 64,
            true_len: 64, ks: 33, kd: 15, point: 0, packed: vec![],
            updates: vec![(0, 1.0); 7],
            coded: vec![],
        };
        assert_eq!(d.encode().len(),
                   FRAME_OVERHEAD_BYTES + STREAM_HEADER_BYTES + 4 + 7 * 8);
    }

    #[test]
    fn wire_bytes_accounting() {
        // activation frame payload cost = header + packed floats (the
        // paper's transmitted volume is dominated by packed[·])
        let f = Frame::Activation {
            session: 0, request: 0, bucket: 64, true_len: 64, ks: 64, kd: 15,
            point: 0, packed: vec![0.0; 64 * 15],
            coded: vec![],
        };
        let enc = f.encode();
        assert_eq!(enc.len(),
                   FRAME_OVERHEAD_BYTES + ACTIVATION_HEADER_BYTES
                   + 64 * 15 * 4);
    }

    /// Entropy-coded frames: the flag bits are pinned to the wire
    /// (Activation point bit 7, Delta keyframe bit 1), an empty coded
    /// body is malformed, and a frame built without `coded` encodes
    /// byte-identically to the pre-entropy layout — the mixed-version
    /// guarantee.
    #[test]
    fn entropy_flag_bits_are_pinned() {
        let act = Frame::Activation {
            session: 1, request: 2, bucket: 16, true_len: 8, ks: 3, kd: 3,
            point: 5, packed: vec![], coded: vec![0xAA, 0xBB, 0xCC],
        };
        let enc = act.encode();
        // point byte is the last header byte; bit 7 flags the coding
        assert_eq!(enc[FRAME_OVERHEAD_BYTES + ACTIVATION_HEADER_BYTES - 1],
                   5 | 0x80);
        assert_eq!(enc.len(),
                   FRAME_OVERHEAD_BYTES + ACTIVATION_HEADER_BYTES + 3);
        roundtrip(act);
        // flag set but body empty: malformed
        let hdr = &enc[FRAME_OVERHEAD_BYTES
                       ..FRAME_OVERHEAD_BYTES + ACTIVATION_HEADER_BYTES];
        assert!(Frame::decode(1, hdr).is_err(),
                "empty entropy-coded activation must not decode");

        let delta = Frame::Delta {
            session: 1, request: 2, seq: 3, keyframe: true, bucket: 16,
            true_len: 8, ks: 3, kd: 3, point: 0, packed: vec![],
            updates: vec![], coded: vec![0x11; 6],
        };
        let enc = delta.encode();
        assert_eq!(enc[FRAME_OVERHEAD_BYTES + 20], 1 | 2,
                   "keyframe byte carries the coded flag in bit 1");
        assert_eq!(enc.len(),
                   FRAME_OVERHEAD_BYTES + STREAM_HEADER_BYTES + 6);
        roundtrip(delta);

        // without coded, the encoding is byte-identical to pre-entropy:
        // no flag bit, packed floats in place (legacy peers parse it)
        let legacy = Frame::Activation {
            session: 9, request: 8, bucket: 32, true_len: 20, ks: 3, kd: 3,
            point: 1, packed: vec![1.5; 9], coded: vec![],
        };
        let enc = legacy.encode();
        assert_eq!(enc[FRAME_OVERHEAD_BYTES + ACTIVATION_HEADER_BYTES - 1], 1);
        assert_eq!(enc.len(),
                   FRAME_OVERHEAD_BYTES + ACTIVATION_HEADER_BYTES + 9 * 4);
    }

    /// Satellite pin: for every frame variant, the documented header
    /// byte constants exactly match what `encode()` emits — a
    /// constant drifting from the wire layout breaks every byte
    /// accounting built on it.
    #[test]
    fn header_constants_match_encode_lengths() {
        let body_len = |f: &Frame| f.encode().len() - FRAME_OVERHEAD_BYTES;

        let model = "m";
        assert_eq!(body_len(&Frame::hello(1, 0, model)),
                   HELLO_HEADER_BYTES + model.len());

        assert_eq!(body_len(&Frame::Activation {
            session: 0, request: 0, bucket: 16, true_len: 8, ks: 0, kd: 0,
            point: 0, packed: vec![],
            coded: vec![],
        }), ACTIVATION_HEADER_BYTES);

        assert_eq!(body_len(&Frame::Token {
            request: 0, token: 0, logprob: 0.0,
        }), TOKEN_BODY_BYTES);

        assert_eq!(body_len(&Frame::GetStats), 0);
        assert_eq!(body_len(&Frame::Bye), 0);

        let json = "{}";
        assert_eq!(body_len(&Frame::Stats { json: json.into() }),
                   STATS_HEADER_BYTES + json.len());

        let msg = "boom";
        assert_eq!(body_len(&Frame::Error {
            code: ErrorCode::Internal, msg: msg.into(),
        }), ERROR_HEADER_BYTES + msg.len());

        // a keyframe delta's body is exactly the stream header + block
        assert_eq!(body_len(&Frame::Delta {
            session: 0, request: 0, seq: 0, keyframe: true, bucket: 16,
            true_len: 8, ks: 0, kd: 0, point: 0, packed: vec![],
            updates: vec![],
            coded: vec![],
        }), STREAM_HEADER_BYTES);
        // a sparse delta adds its u32 count even when empty
        assert_eq!(body_len(&Frame::Delta {
            session: 0, request: 0, seq: 0, keyframe: false, bucket: 16,
            true_len: 8, ks: 0, kd: 0, point: 0, packed: vec![],
            updates: vec![],
            coded: vec![],
        }), STREAM_HEADER_BYTES + 4);

        // a keyframe prefill chunk's body is exactly the header
        assert_eq!(body_len(&Frame::PrefillChunk {
            session: 0, request: 0, bucket: 16, true_len: 8, ks: 0, kd: 0,
            point: 0, index: 0, last: false, keyframe: true, packed: vec![],
            updates: vec![], coded: vec![],
        }), PREFILL_HEADER_BYTES);
        // a delta chunk adds its u32 count even when empty
        assert_eq!(body_len(&Frame::PrefillChunk {
            session: 0, request: 0, bucket: 16, true_len: 8, ks: 0, kd: 0,
            point: 0, index: 1, last: true, keyframe: false, packed: vec![],
            updates: vec![], coded: vec![],
        }), PREFILL_HEADER_BYTES + 4);

        assert_eq!(body_len(&Frame::HelloAck {
            version: 2, caps: 0, buckets: vec![],
        }), HELLO_ACK_HEADER_BYTES);
        // 3 buckets x 2 ladder points each
        assert_eq!(body_len(&Frame::HelloAck {
            version: 2, caps: 0,
            buckets: vec![advert(16, &[(3, 3, 0.5), (3, 1, 0.9)]); 3],
        }), HELLO_ACK_HEADER_BYTES + 3 * HELLO_ACK_BUCKET_BYTES
            + 6 * HELLO_ACK_POINT_BYTES);
    }

    /// Prefill chunk wire pins: the flags byte layout (bit 0
    /// keyframe, bit 1 last, bit 2 entropy-coded), malformed-flag and
    /// empty-coded rejects, and delta-chunk body alignment.
    #[test]
    fn prefill_flags_are_pinned() {
        let kf = Frame::PrefillChunk {
            session: 1, request: 2, bucket: 16, true_len: 8, ks: 3, kd: 3,
            point: 5, index: 0, last: false, keyframe: true,
            packed: vec![1.5; 3], updates: vec![], coded: vec![],
        };
        let enc = kf.encode();
        // flags is the last header byte
        assert_eq!(enc[FRAME_OVERHEAD_BYTES + PREFILL_HEADER_BYTES - 1], 1);
        assert_eq!(enc.len(),
                   FRAME_OVERHEAD_BYTES + PREFILL_HEADER_BYTES + 3 * 4);
        roundtrip(kf);

        let last_coded = Frame::PrefillChunk {
            session: 1, request: 2, bucket: 16, true_len: 8, ks: 3, kd: 3,
            point: 0, index: 4, last: true, keyframe: false,
            packed: vec![], updates: vec![], coded: vec![0xAA, 0xBB],
        };
        let enc = last_coded.encode();
        assert_eq!(enc[FRAME_OVERHEAD_BYTES + PREFILL_HEADER_BYTES - 1],
                   2 | 4, "last flag in bit 1, coded flag in bit 2");
        assert_eq!(enc.len(),
                   FRAME_OVERHEAD_BYTES + PREFILL_HEADER_BYTES + 2);
        roundtrip(last_coded);

        // undefined flag bits are malformed
        let mut body = enc[FRAME_OVERHEAD_BYTES..].to_vec();
        body[PREFILL_HEADER_BYTES - 1] = 8;
        assert!(Frame::decode(9, &body).is_err());
        // coded flag with an empty body is malformed
        let mut body = enc[FRAME_OVERHEAD_BYTES..].to_vec();
        body.truncate(PREFILL_HEADER_BYTES);
        assert!(Frame::decode(9, &body).is_err(),
                "empty entropy-coded prefill chunk must not decode");

        // keyframe chunk with a partial trailing float
        let kenc = Frame::PrefillChunk {
            session: 1, request: 2, bucket: 16, true_len: 8, ks: 3, kd: 3,
            point: 0, index: 0, last: false, keyframe: true,
            packed: vec![1.0; 3], updates: vec![], coded: vec![],
        }.encode();
        let mut body = kenc[FRAME_OVERHEAD_BYTES..].to_vec();
        body.extend_from_slice(&[0xAA, 0xBB]);
        assert!(Frame::decode(9, &body).is_err());

        // delta chunk promising more updates than the body holds
        let denc = Frame::PrefillChunk {
            session: 1, request: 2, bucket: 16, true_len: 8, ks: 3, kd: 3,
            point: 0, index: 1, last: false, keyframe: false,
            packed: vec![], updates: vec![(1, 2.0)], coded: vec![],
        }.encode();
        let mut body = denc[FRAME_OVERHEAD_BYTES..].to_vec();
        body[PREFILL_HEADER_BYTES] = 3; // update count leads the body
        assert!(Frame::decode(9, &body).is_err());
        // huge declared count must error without allocating
        let mut body = denc[FRAME_OVERHEAD_BYTES..].to_vec();
        body[PREFILL_HEADER_BYTES..PREFILL_HEADER_BYTES + 4]
            .copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Frame::decode(9, &body).is_err());
    }

    /// Satellite pin: `Frame::decode` over seeded-random type ids and
    /// bodies returns errors, never panics (and never over-allocates
    /// from attacker-controlled counts).
    #[test]
    fn decode_random_bodies_never_panics() {
        let mut rng = Rng::new(0xF0_22ED);
        for _ in 0..20_000 {
            let tid = rng.below(12) as u8; // valid ids 0..=9 + invalid
            let len = rng.below(300);
            let body: Vec<u8> =
                (0..len).map(|_| rng.next_u64() as u8).collect();
            let _ = Frame::decode(tid, &body); // Err is fine; panic is not
        }
        // bit-flip corruption of every valid variant's encoding
        for f in all_variants() {
            let enc = f.encode();
            if enc.len() <= FRAME_OVERHEAD_BYTES {
                continue;
            }
            for _ in 0..256 {
                let mut body = enc[FRAME_OVERHEAD_BYTES..].to_vec();
                let i = rng.below(body.len());
                body[i] ^= 1 << rng.below(8);
                let _ = Frame::decode(enc[4], &body);
            }
        }
        // huge declared counts must error without allocating
        let mut sparse = Frame::Delta {
            session: 0, request: 0, seq: 0, keyframe: false, bucket: 1,
            true_len: 1, ks: 1, kd: 1, point: 0, packed: vec![],
            updates: vec![],
            coded: vec![],
        }.encode()[FRAME_OVERHEAD_BYTES..].to_vec();
        let off = STREAM_HEADER_BYTES;
        sparse[off..off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Frame::decode(7, &sparse).is_err());
        let mut ack = Frame::HelloAck { version: 2, caps: 0, buckets: vec![] }
            .encode()[FRAME_OVERHEAD_BYTES..].to_vec();
        ack[6..8].copy_from_slice(&u16::MAX.to_le_bytes());
        assert!(Frame::decode(8, &ack).is_err());
        // ...and a huge ladder-point count inside one advert
        let mut ack = Frame::HelloAck {
            version: 2, caps: 0, buckets: vec![advert(16, &[])],
        }.encode()[FRAME_OVERHEAD_BYTES..].to_vec();
        ack[HELLO_ACK_HEADER_BYTES + 2] = u8::MAX;
        assert!(Frame::decode(8, &ack).is_err());
    }
}
