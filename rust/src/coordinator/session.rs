//! Session manager: per-client server-side state with TTL + LRU
//! eviction.  Sessions carry the server half of the spectral stream
//! (`codec::stream::StreamDecoder`): a keyframe (re-)admits a session
//! and reseeds its decoder; a delta requires a live, synced session —
//! TTL eviction mid-stream therefore forces the client through a
//! keyframe resync, never through silent state divergence.
//!
//! At serving scale the table is wrapped in [`ShardedSessions`]: N
//! independently-locked [`SessionManager`] shards keyed by a
//! session-id hash, so concurrent connections touching different
//! sessions never contend on one global lock.  Every operation names
//! exactly one session id, which makes per-shard locking trivially
//! correct; the TTL/LRU and ownership invariants hold *per shard*
//! (admission pressure is a per-shard budget of
//! `max_sessions / shards`).

use crate::codec::stream::{BlockGeom, PrefillAssembler, StreamDecoder};
use crate::coordinator::obs::{FlightKind, FlightRecorder, ShardMetrics};
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// `aux` word of a [`FlightKind::SessionEvict`] event: dropped by the
/// TTL (sweep or delta-path expiry).
pub const EVICT_TTL: u64 = 1;
/// `aux` word of a [`FlightKind::SessionEvict`] event: LRU-displaced
/// by a new session under admission pressure.
pub const EVICT_LRU: u64 = 2;

#[derive(Debug)]
pub struct Session {
    pub id: u64,
    pub model: String,
    /// Capability bits the client advertised in its v2 `Hello` —
    /// recorded so operators (and future multi-link sessions) can see
    /// what each session negotiated, not just what it sends.
    pub caps: u32,
    /// Nonzero while a live connection owns this session (the
    /// connection's nonce); released at connection teardown.  A
    /// `Hello` for a session owned by another live connection is
    /// refused — no cross-tenant takeover while the owner is
    /// connected.
    pub owner: u64,
    pub created: Instant,
    pub last_seen: Instant,
    pub requests: u64,
    pub bytes_rx: u64,
    /// Per-session spectral stream decoder state (reset by every
    /// keyframe); dropped with the session on eviction, which is what
    /// makes eviction mid-stream safe.
    pub stream: StreamDecoder,
    /// Quality-ladder point the session's data frames currently ride
    /// (`codec::rate`; 0 = the bucket's primary block) and how many
    /// frames it has dwelt there — switches feed the server's
    /// dwell-time histogram.
    pub point: u8,
    pub point_frames: u64,
    /// Ladder point of the session's *stream* geometry, tracked
    /// separately from the dwell accounting above: only stream
    /// keyframes move it, so an interleaved recompute frame at a
    /// different point cannot poison in-sequence delta validation.
    pub stream_point: u8,
    /// Per-session chunked-prefill reassembly state
    /// (`codec::stream::PrefillAssembler`): dropped with the session
    /// on eviction, so a mid-prefill eviction forces the client to
    /// restart from keyframe chunk 0 — never silent reassembly drift.
    pub prefill: PrefillAssembler,
}

pub struct SessionManager {
    sessions: HashMap<u64, Session>,
    ttl: Duration,
    max_sessions: usize,
    /// Observability hook: this manager's shard index plus the shared
    /// per-shard counters and flight recorder.  Attached by the
    /// serving core via [`ShardedSessions::attach_obs`]; absent for
    /// bare managers (unit tests), in which case admissions and
    /// evictions simply go unrecorded.
    obs: Option<(u16, Arc<ShardMetrics>, Arc<FlightRecorder>)>,
}

impl SessionManager {
    pub fn new(ttl: Duration, max_sessions: usize) -> SessionManager {
        SessionManager { sessions: HashMap::new(), ttl, max_sessions, obs: None }
    }

    /// Attach the per-shard observability hook (shard index, counter
    /// family, flight recorder).
    pub fn set_obs(&mut self, shard: u16, metrics: Arc<ShardMetrics>,
                   flight: Arc<FlightRecorder>) {
        self.obs = Some((shard, metrics, flight));
    }

    fn note_admitted(&self) {
        if let Some((_, m, _)) = &self.obs {
            m.admitted.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn note_evicted(&self, id: u64, cause: u64) {
        if let Some((shard, m, flight)) = &self.obs {
            m.evicted.fetch_add(1, Ordering::Relaxed);
            flight.record(FlightKind::SessionEvict, id, *shard, 0, cause);
        }
    }

    /// Register (or refresh) a session from a handshake, recording
    /// the client's advertised capability bits.  Returns false if the
    /// table is full even after eviction — admission control.
    pub fn hello(&mut self, id: u64, model: &str, caps: u32) -> bool {
        if !self.admit(id, model) {
            return false;
        }
        if let Some(s) = self.sessions.get_mut(&id) {
            s.caps = caps;
        }
        true
    }

    /// Re-admit a session outside a handshake.  Recompute-regime
    /// requests are stateless, so a TTL/LRU-evicted session resumes
    /// here (with empty model and untouched caps) instead of failing
    /// the client mid-generation — the Activation-path analogue of
    /// the stream keyframe's re-admission.  Returns false only under
    /// live-table admission pressure.
    pub fn readmit(&mut self, id: u64) -> bool {
        self.admit(id, "")
    }

    /// Admission under the TTL/LRU rules, without touching the
    /// recorded capability bits — the keyframe re-admission path,
    /// which must not erase what the handshake negotiated.
    fn admit(&mut self, id: u64, model: &str) -> bool {
        self.evict_expired();
        if !self.sessions.contains_key(&id) && self.sessions.len() >= self.max_sessions {
            // LRU eviction of the stalest entry
            if let Some((&stale, _)) = self
                .sessions
                .iter()
                .min_by_key(|(_, s)| s.last_seen)
            {
                // never evict a session seen within the TTL window
                if self.sessions[&stale].last_seen.elapsed() < self.ttl {
                    return false;
                }
                self.sessions.remove(&stale);
                self.note_evicted(stale, EVICT_LRU);
            }
        }
        let now = Instant::now();
        if let Some(s) = self.sessions.get_mut(&id) {
            s.last_seen = now;
        } else {
            self.sessions.insert(id, Session {
                id,
                model: model.to_string(),
                caps: 0,
                owner: 0,
                created: now,
                last_seen: now,
                requests: 0,
                bytes_rx: 0,
                stream: StreamDecoder::default(),
                point: 0,
                point_frames: 0,
                stream_point: 0,
                prefill: PrefillAssembler::default(),
            });
            self.note_admitted();
        }
        true
    }

    /// Whether `id` is currently owned by a live connection other
    /// than `conn` — checked *before* `hello` so a refused takeover
    /// cannot refresh or rewrite the foreign session's state.
    pub fn owned_by_other(&self, id: u64, conn: u64) -> bool {
        self.sessions
            .get(&id)
            .map(|s| s.owner != 0 && s.owner != conn)
            .unwrap_or(false)
    }

    /// Bind session `id` to connection nonce `conn` (nonzero).
    /// Refuses when another live connection owns the session;
    /// re-binding by the same connection is idempotent.  Ownership is
    /// undone by [`SessionManager::release_owner`] at connection
    /// teardown (or implicitly by TTL eviction of the session).
    pub fn bind_owner(&mut self, id: u64, conn: u64) -> bool {
        match self.sessions.get_mut(&id) {
            Some(s) if s.owner == 0 || s.owner == conn => {
                s.owner = conn;
                true
            }
            Some(_) => false,
            None => false,
        }
    }

    /// Release `conn`'s ownership of `id` (no-op if the session is
    /// gone or owned by someone else — eviction may already have
    /// recycled the id).
    pub fn release_owner(&mut self, id: u64, conn: u64) {
        if let Some(s) = self.sessions.get_mut(&id) {
            if s.owner == conn {
                s.owner = 0;
            }
        }
    }

    /// Decoder for a stream **keyframe**: (re-)admits the session
    /// under the same TTL/LRU rules as [`SessionManager::hello`] and
    /// records the request.  `None` means admission was refused (table
    /// full of live sessions).
    pub fn stream_key_decoder(&mut self, id: u64, bytes: u64)
        -> Option<&mut StreamDecoder> {
        if !self.admit(id, "") {
            return None;
        }
        let s = self.sessions.get_mut(&id)?;
        s.requests += 1;
        s.bytes_rx += bytes;
        Some(&mut s.stream)
    }

    /// Decoder for a stream **delta**: only for a live (non-expired)
    /// session.  An expired session is evicted here and `None`
    /// returned, which the protocol surfaces to the client as
    /// "keyframe required" — the resync path.
    pub fn stream_delta_decoder(&mut self, id: u64, bytes: u64)
        -> Option<&mut StreamDecoder> {
        let expired = self
            .sessions
            .get(&id)
            .map(|s| s.last_seen.elapsed() >= self.ttl)
            .unwrap_or(false);
        if expired {
            self.sessions.remove(&id);
            self.note_evicted(id, EVICT_TTL);
            return None;
        }
        let s = self.sessions.get_mut(&id)?;
        s.last_seen = Instant::now();
        s.requests += 1;
        s.bytes_rx += bytes;
        Some(&mut s.stream)
    }

    /// Assembler for a prefill **restart** (keyframe chunk 0):
    /// (re-)admits the session under the same TTL/LRU rules as a
    /// stream keyframe and records the request.  `None` means
    /// admission was refused (table full of live sessions).
    pub fn prefill_restart(&mut self, id: u64, bytes: u64)
        -> Option<&mut PrefillAssembler> {
        if !self.admit(id, "") {
            return None;
        }
        let s = self.sessions.get_mut(&id)?;
        s.requests += 1;
        s.bytes_rx += bytes;
        Some(&mut s.prefill)
    }

    /// Assembler for a **follow-up** prefill chunk: only for a live
    /// (non-expired) session — mid-assembly state evaporated with an
    /// evicted session, so the protocol surfaces `None` as "restart
    /// from chunk 0", the prefill resync path.
    pub fn prefill_assembler(&mut self, id: u64, bytes: u64)
        -> Option<&mut PrefillAssembler> {
        let expired = self
            .sessions
            .get(&id)
            .map(|s| s.last_seen.elapsed() >= self.ttl)
            .unwrap_or(false);
        if expired {
            self.sessions.remove(&id);
            self.note_evicted(id, EVICT_TTL);
            return None;
        }
        let s = self.sessions.get_mut(&id)?;
        s.last_seen = Instant::now();
        s.requests += 1;
        s.bytes_rx += bytes;
        Some(&mut s.prefill)
    }

    /// Seed the session's decode-stream state from a completed
    /// prefill plane: the stream decoder behaves as if a keyframe
    /// with sequence 0 carried the plane (the device-side
    /// `StreamEncoder::seed` mirror), so decode step 1 may arrive as
    /// a delta.  Returns false for unknown sessions or invalid
    /// geometry.
    pub fn seed_stream_from_prefill(&mut self, id: u64, geom: BlockGeom,
                                    plane: &[f32], point: u8) -> bool {
        match self.sessions.get_mut(&id) {
            Some(s) => {
                if s.stream.apply_key(0, geom, plane).is_err() {
                    return false;
                }
                s.stream_point = point;
                true
            }
            None => false,
        }
    }

    pub fn get(&self, id: u64) -> Option<&Session> {
        self.sessions.get(&id)
    }

    /// The ladder point the session's frames currently ride.
    pub fn point_of(&self, id: u64) -> Option<u8> {
        self.sessions.get(&id).map(|s| s.point)
    }

    /// The ladder point of the session's stream geometry (moved only
    /// by stream keyframes, via
    /// [`SessionManager::set_stream_point`]).
    pub fn stream_point_of(&self, id: u64) -> Option<u8> {
        self.sessions.get(&id).map(|s| s.stream_point)
    }

    /// Record the stream geometry's ladder point after a successful
    /// keyframe apply.
    pub fn set_stream_point(&mut self, id: u64, point: u8) {
        if let Some(s) = self.sessions.get_mut(&id) {
            s.stream_point = point;
        }
    }

    /// Record the ladder point a data frame used.  Returns
    /// `Some(previous dwell in frames)` when this frame *switched*
    /// the session to a new point — the caller records it in the
    /// dwell-time histogram — and `None` when the point is unchanged
    /// (dwell grows) or the session is unknown.
    pub fn note_point(&mut self, id: u64, point: u8) -> Option<u64> {
        let s = self.sessions.get_mut(&id)?;
        if s.point == point {
            s.point_frames = s.point_frames.saturating_add(1);
            None
        } else {
            let dwell = s.point_frames;
            s.point = point;
            s.point_frames = 1;
            Some(dwell)
        }
    }

    /// Record a request; returns false for unknown sessions.
    pub fn touch(&mut self, id: u64, bytes: u64) -> bool {
        match self.sessions.get_mut(&id) {
            Some(s) => {
                s.last_seen = Instant::now();
                s.requests += 1;
                s.bytes_rx += bytes;
                true
            }
            None => false,
        }
    }

    pub fn evict_expired(&mut self) {
        let ttl = self.ttl;
        if self.obs.is_none() {
            self.sessions.retain(|_, s| s.last_seen.elapsed() < ttl);
            return;
        }
        let dead: Vec<u64> = self
            .sessions
            .iter()
            .filter(|(_, s)| s.last_seen.elapsed() >= ttl)
            .map(|(&id, _)| id)
            .collect();
        for id in dead {
            self.sessions.remove(&id);
            self.note_evicted(id, EVICT_TTL);
        }
    }

    pub fn remove(&mut self, id: u64) {
        self.sessions.remove(&id);
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }
}

// ---------------------------------------------------------------------------
// sharding
// ---------------------------------------------------------------------------

/// N independently-locked [`SessionManager`] shards keyed by a
/// session-id hash — the serving core's session table.  There is no
/// global lock on the data path: a frame for session `s` locks only
/// `shard(s)`, so connections on different sessions proceed in
/// parallel.  Multi-step protocol sequences (ownership check → hello
/// → bind) stay atomic because [`ShardedSessions::with`] runs the
/// whole closure under the one shard lock the session lives in.
pub struct ShardedSessions {
    shards: Vec<Mutex<SessionManager>>,
}

impl ShardedSessions {
    /// `max_sessions` is the whole-table budget; each shard gets an
    /// equal slice (rounded up), so admission pressure is enforced
    /// per shard.
    pub fn new(ttl: Duration, max_sessions: usize, shards: usize)
        -> ShardedSessions {
        let n = shards.max(1);
        let per_shard = max_sessions.div_ceil(n).max(1);
        ShardedSessions {
            shards: (0..n)
                .map(|_| Mutex::new(SessionManager::new(ttl, per_shard)))
                .collect(),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Attach per-shard observability: shard `i` gets `metrics[i]`
    /// and the shared flight recorder, so its admissions/evictions
    /// are counted and eviction events land in the flight ring.
    /// Called once by the serving core at startup.
    pub fn attach_obs(&self, metrics: &[Arc<ShardMetrics>],
                      flight: &Arc<FlightRecorder>) {
        for (i, s) in self.shards.iter().enumerate() {
            s.lock().unwrap().set_obs(i as u16,
                                      metrics[i % metrics.len()].clone(),
                                      flight.clone());
        }
    }

    /// The shard index session `id` lives in.  Fibonacci-multiply
    /// hashing spreads the sequential ids tests and benches hand out
    /// across shards instead of clustering them modulo-N.
    pub fn shard_of(&self, id: u64) -> usize {
        let h = (id ^ (id >> 32)).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        ((h >> 32) as usize) % self.shards.len()
    }

    /// Run `f` under the lock of the shard owning session `id`.  This
    /// is the only way in: every caller names the session it is
    /// about, so cross-shard lock nesting cannot arise from this API
    /// (callers needing two sessions take the shards sequentially).
    pub fn with<R>(&self, id: u64, f: impl FnOnce(&mut SessionManager) -> R)
        -> R {
        let mut guard = self.shards[self.shard_of(id)].lock().unwrap();
        f(&mut guard)
    }

    // Delegates for the common single-op calls (each is one shard
    // lock); multi-step sequences use `with` to stay atomic.

    pub fn hello(&self, id: u64, model: &str, caps: u32) -> bool {
        self.with(id, |m| m.hello(id, model, caps))
    }

    pub fn readmit(&self, id: u64) -> bool {
        self.with(id, |m| m.readmit(id))
    }

    pub fn touch(&self, id: u64, bytes: u64) -> bool {
        self.with(id, |m| m.touch(id, bytes))
    }

    pub fn owned_by_other(&self, id: u64, conn: u64) -> bool {
        self.with(id, |m| m.owned_by_other(id, conn))
    }

    pub fn bind_owner(&self, id: u64, conn: u64) -> bool {
        self.with(id, |m| m.bind_owner(id, conn))
    }

    pub fn release_owner(&self, id: u64, conn: u64) {
        self.with(id, |m| m.release_owner(id, conn))
    }

    pub fn note_point(&self, id: u64, point: u8) -> Option<u64> {
        self.with(id, |m| m.note_point(id, point))
    }

    pub fn remove(&self, id: u64) {
        self.with(id, |m| m.remove(id))
    }

    /// Sweep every shard's expired sessions (shards locked one at a
    /// time, never together).
    pub fn evict_expired(&self) {
        for s in &self.shards {
            s.lock().unwrap().evict_expired();
        }
    }

    /// Total live sessions across shards (momentary: each shard is
    /// read under its own lock, one at a time).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Per-shard live-session counts, for the stress suite's
    /// per-shard invariant checks.
    pub fn shard_lens(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.lock().unwrap().len()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hello_touch_flow() {
        let mut m = SessionManager::new(Duration::from_secs(60), 10);
        assert!(m.hello(1, "x", 0));
        assert!(m.touch(1, 100));
        assert!(!m.touch(2, 100)); // unknown
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn admission_control_when_full_of_active() {
        let mut m = SessionManager::new(Duration::from_secs(60), 2);
        assert!(m.hello(1, "x", 0));
        assert!(m.hello(2, "x", 0));
        // both active within TTL: third must be refused
        assert!(!m.hello(3, "x", 0));
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn ttl_eviction() {
        let mut m = SessionManager::new(Duration::from_millis(10), 10);
        m.hello(1, "x", 0);
        std::thread::sleep(Duration::from_millis(20));
        m.evict_expired();
        assert!(m.is_empty());
    }

    #[test]
    fn stale_session_evicted_for_new() {
        let mut m = SessionManager::new(Duration::from_millis(10), 1);
        m.hello(1, "x", 0);
        std::thread::sleep(Duration::from_millis(20));
        assert!(m.hello(2, "x", 0));
        assert!(m.touch(2, 1));
        assert!(!m.touch(1, 1));
    }

    // -- stream-state lifecycle ------------------------------------------

    use crate::codec::stream::BlockGeom;

    const GEOM: BlockGeom = BlockGeom { rows: 4, cols: 8, ks: 1, kd: 3 };

    #[test]
    fn ttl_eviction_mid_stream_forces_keyframe_resync() {
        let mut m = SessionManager::new(Duration::from_millis(10), 4);
        assert!(m.hello(1, "x", 0));
        let packed = vec![1.0f32, 2.0, 3.0];
        m.stream_key_decoder(1, 12)
            .unwrap()
            .apply_key(0, GEOM, &packed)
            .unwrap();
        m.stream_delta_decoder(1, 8)
            .unwrap()
            .apply_delta(1, GEOM, &[(0, 5.0)])
            .unwrap();
        assert_eq!(m.get(1).unwrap().requests, 2);
        assert_eq!(m.get(1).unwrap().bytes_rx, 20);

        std::thread::sleep(Duration::from_millis(20));
        // stream state expired mid-generation: the delta path refuses
        // (and evicts) — the decoder state is gone, not stale
        assert!(m.stream_delta_decoder(1, 8).is_none());
        assert_eq!(m.len(), 0);
        // the keyframe path re-admits and reseeds the decoder
        let dec = m.stream_key_decoder(1, 12).unwrap();
        dec.apply_key(7, GEOM, &packed).unwrap();
        assert_eq!(dec.block(), &packed[..]);
        assert!(m.touch(1, 1));
    }

    #[test]
    fn stream_admission_under_max_sessions_pressure() {
        let mut m = SessionManager::new(Duration::from_secs(60), 2);
        assert!(m.hello(1, "x", 0));
        assert!(m.hello(2, "x", 0));
        // table full of live sessions: a new stream may not evict them
        assert!(m.stream_key_decoder(3, 0).is_none());
        assert_eq!(m.len(), 2);
        // but existing sessions keep streaming (and keep their model)
        assert!(m.stream_key_decoder(2, 0).is_some());
        assert_eq!(m.get(2).unwrap().model, "x");
    }

    #[test]
    fn readmit_revives_an_evicted_session() {
        let mut m = SessionManager::new(Duration::from_secs(60), 4);
        // unknown session: touch refuses, readmit creates it
        assert!(!m.touch(3, 1));
        assert!(m.readmit(3));
        assert!(m.touch(3, 1));
        // under live-table pressure, readmit refuses like hello does
        let mut full = SessionManager::new(Duration::from_secs(60), 1);
        assert!(full.hello(1, "x", 0));
        assert!(!full.readmit(2));
    }

    #[test]
    fn ownership_blocks_takeover_until_released() {
        let mut m = SessionManager::new(Duration::from_secs(60), 4);
        assert!(m.hello(7, "x", 0));
        assert!(m.bind_owner(7, 101));
        assert!(m.bind_owner(7, 101), "same connection re-binds freely");
        // another live connection may not take the session over
        assert!(!m.bind_owner(7, 102));
        // wrong releaser is a no-op; the right one frees it
        m.release_owner(7, 102);
        assert!(!m.bind_owner(7, 102));
        m.release_owner(7, 101);
        assert!(m.bind_owner(7, 102), "released session is re-bindable");
        // unknown sessions cannot be bound at all
        assert!(!m.bind_owner(99, 101));
    }

    #[test]
    fn caps_survive_keyframe_readmission() {
        let mut m = SessionManager::new(Duration::from_secs(60), 4);
        assert!(m.hello(9, "x", 0b101));
        assert_eq!(m.get(9).unwrap().caps, 0b101);
        // keyframe re-admission must not erase the negotiated bits
        assert!(m.stream_key_decoder(9, 4).is_some());
        assert_eq!(m.get(9).unwrap().caps, 0b101);
        assert_eq!(m.get(9).unwrap().model, "x");
        // a fresh handshake re-records them
        assert!(m.hello(9, "x", 0b1));
        assert_eq!(m.get(9).unwrap().caps, 0b1);
    }

    #[test]
    fn note_point_tracks_dwell_and_switches() {
        let mut m = SessionManager::new(Duration::from_secs(60), 4);
        assert!(m.note_point(1, 0).is_none(), "unknown session");
        assert!(m.hello(1, "x", 0));
        assert_eq!(m.point_of(1), Some(0));
        // three frames at the primary point: dwell grows, no switch
        for _ in 0..3 {
            assert!(m.note_point(1, 0).is_none());
        }
        // downshift: the completed dwell comes back
        assert_eq!(m.note_point(1, 2), Some(3));
        assert_eq!(m.point_of(1), Some(2));
        assert!(m.note_point(1, 2).is_none());
        // upshift after two frames at point 2
        assert_eq!(m.note_point(1, 0), Some(2));
    }

    /// Prefill reassembly needs a plane of more than one chunk, so a
    /// taller block than the stream-lifecycle tests use.
    const PGEOM: BlockGeom = BlockGeom { rows: 4, cols: 8, ks: 3, kd: 3 };

    #[test]
    fn prefill_lifecycle_mirrors_the_stream_decoder_rules() {
        let mut m = SessionManager::new(Duration::from_millis(10), 4);
        assert!(m.hello(1, "x", 0));
        // restart path admits + accounts, follow-up path is live-only
        let asm = m.prefill_restart(1, 12).unwrap();
        asm.apply(PGEOM, 0, false, true, &[1.0, 2.0, 3.0], &[]).unwrap();
        assert!(asm.is_active());
        assert_eq!(m.get(1).unwrap().requests, 1);
        assert_eq!(m.get(1).unwrap().bytes_rx, 12);
        assert!(m.prefill_assembler(1, 8).is_some());
        assert_eq!(m.get(1).unwrap().bytes_rx, 20);

        std::thread::sleep(Duration::from_millis(20));
        // eviction mid-assembly: the follow-up path refuses (and
        // evicts) — half-built planes never survive a TTL expiry
        assert!(m.prefill_assembler(1, 8).is_none());
        assert_eq!(m.len(), 0);
        // a restart re-admits from scratch
        let asm = m.prefill_restart(1, 12).unwrap();
        assert!(!asm.is_active() && !asm.is_rejected());
        asm.apply(PGEOM, 0, false, true, &[1.0, 2.0, 3.0], &[]).unwrap();
        assert!(m.get(1).unwrap().prefill.is_active());

        // admission pressure: restarts may not evict live sessions
        let mut full = SessionManager::new(Duration::from_secs(60), 1);
        assert!(full.hello(7, "x", 0));
        assert!(full.prefill_restart(8, 0).is_none());
    }

    #[test]
    fn seed_stream_from_prefill_primes_delta_continuation() {
        let mut m = SessionManager::new(Duration::from_secs(60), 4);
        assert!(!m.seed_stream_from_prefill(1, GEOM, &[0.0; 3], 0),
                "unknown session");
        assert!(m.hello(1, "x", 0));
        // wrong plane length is refused, stream stays unsynced
        assert!(!m.seed_stream_from_prefill(1, GEOM, &[0.0; 2], 0));
        assert!(!m.get(1).unwrap().stream.is_synced());
        let plane = [1.0f32, 2.0, 3.0];
        assert!(m.seed_stream_from_prefill(1, GEOM, &plane, 2));
        assert_eq!(m.stream_point_of(1), Some(2));
        let s = m.get(1).unwrap();
        assert!(s.stream.is_synced());
        assert_eq!(s.stream.next_seq(), 1, "decode step 1 rides a delta");
        assert_eq!(s.stream.block(), &plane[..]);
    }

    #[test]
    fn touch_after_remove_is_refused() {
        let mut m = SessionManager::new(Duration::from_secs(60), 4);
        assert!(m.hello(5, "x", 0));
        assert!(m.touch(5, 10));
        m.remove(5);
        assert!(!m.touch(5, 10));
        assert!(m.stream_delta_decoder(5, 0).is_none());
        assert!(m.get(5).is_none());
        // a keyframe after removal re-admits from scratch
        assert!(m.stream_key_decoder(5, 0).is_some());
        assert!(!m.get(5).unwrap().stream.is_synced());
    }

    // -- sharding --------------------------------------------------------

    #[test]
    fn sharded_ops_route_to_one_stable_shard() {
        let s = ShardedSessions::new(Duration::from_secs(60), 64, 4);
        assert_eq!(s.shard_count(), 4);
        for id in 0..200u64 {
            let a = s.shard_of(id);
            assert_eq!(a, s.shard_of(id), "shard map must be stable");
            assert!(a < 4);
        }
        // ids spread across shards rather than clustering in one
        let shards: std::collections::HashSet<usize> =
            (0..64u64).map(|id| s.shard_of(id)).collect();
        assert!(shards.len() >= 3, "64 ids landed on {} shard(s)",
                shards.len());

        assert!(s.hello(7, "x", 0b1));
        assert!(s.touch(7, 10));
        assert!(!s.touch(8, 10), "unknown session on another shard");
        assert_eq!(s.len(), 1);
        let lens = s.shard_lens();
        assert_eq!(lens.iter().sum::<usize>(), 1);
        assert_eq!(lens[s.shard_of(7)], 1, "session must live in its shard");
    }

    #[test]
    fn sharded_admission_budget_is_per_shard() {
        // 8 total over 4 shards = 2 per shard: a third live session
        // hashed to the same shard is refused even though the table
        // as a whole has room
        let s = ShardedSessions::new(Duration::from_secs(60), 8, 4);
        let mut by_shard: HashMap<usize, Vec<u64>> = HashMap::new();
        for id in 0..64u64 {
            by_shard.entry(s.shard_of(id)).or_default().push(id);
        }
        let ids = by_shard.values().find(|v| v.len() >= 3).unwrap();
        assert!(s.hello(ids[0], "x", 0));
        assert!(s.hello(ids[1], "x", 0));
        assert!(!s.hello(ids[2], "x", 0),
                "third live session in a 2-budget shard must be refused");
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn obs_hook_counts_admissions_and_evictions() {
        let s = ShardedSessions::new(Duration::from_millis(10), 16, 2);
        let metrics: Vec<Arc<ShardMetrics>> =
            (0..2).map(|_| Arc::new(ShardMetrics::default())).collect();
        let flight = Arc::new(FlightRecorder::new(16));
        s.attach_obs(&metrics, &flight);
        assert!(s.hello(3, "x", 0));
        assert!(s.hello(4, "x", 0));
        let admitted: u64 = metrics.iter()
            .map(|m| m.admitted.load(Ordering::Relaxed)).sum();
        assert_eq!(admitted, 2);
        // refreshing an existing session is not a new admission
        assert!(s.hello(3, "x", 0));
        let again: u64 = metrics.iter()
            .map(|m| m.admitted.load(Ordering::Relaxed)).sum();
        assert_eq!(again, 2);
        std::thread::sleep(Duration::from_millis(20));
        s.evict_expired();
        let evicted: u64 = metrics.iter()
            .map(|m| m.evicted.load(Ordering::Relaxed)).sum();
        assert_eq!(evicted, 2);
        // each eviction landed in the flight ring with the session's
        // own shard index and the TTL cause word
        let dump = flight.dump();
        assert_eq!(dump.len(), 2);
        for e in dump {
            assert_eq!(e.kind, FlightKind::SessionEvict);
            assert_eq!(e.shard as usize, s.shard_of(e.session));
            assert_eq!(e.aux, EVICT_TTL);
            assert!([3, 4].contains(&e.session));
        }
        // per-shard eviction counts match where the sessions lived
        for sid in [3u64, 4] {
            assert!(metrics[s.shard_of(sid)].evicted
                        .load(Ordering::Relaxed) >= 1);
        }
    }

    #[test]
    fn sharded_ownership_and_eviction() {
        let s = ShardedSessions::new(Duration::from_millis(10), 16, 2);
        assert!(s.hello(3, "x", 0));
        assert!(s.bind_owner(3, 101));
        assert!(s.owned_by_other(3, 102));
        assert!(!s.bind_owner(3, 102));
        s.release_owner(3, 101);
        assert!(!s.owned_by_other(3, 102));
        std::thread::sleep(Duration::from_millis(20));
        s.evict_expired();
        assert!(s.is_empty());
        assert!(s.readmit(3));
        assert!(s.with(3, |m| m.get(3).is_some()));
        s.remove(3);
        assert_eq!(s.len(), 0);
    }
}
