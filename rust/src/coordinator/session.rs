//! Session manager: per-client server-side state with TTL + LRU
//! eviction.  In the paper's recompute regime the state is light
//! (accounting + admission); the struct carries an optional opaque
//! context slot so a KV-cache mode can hang per-session tensors here.

use std::collections::HashMap;
use std::time::{Duration, Instant};

#[derive(Debug)]
pub struct Session {
    pub id: u64,
    pub model: String,
    pub created: Instant,
    pub last_seen: Instant,
    pub requests: u64,
    pub bytes_rx: u64,
}

pub struct SessionManager {
    sessions: HashMap<u64, Session>,
    ttl: Duration,
    max_sessions: usize,
}

impl SessionManager {
    pub fn new(ttl: Duration, max_sessions: usize) -> SessionManager {
        SessionManager { sessions: HashMap::new(), ttl, max_sessions }
    }

    /// Register (or refresh) a session.  Returns false if the table is
    /// full even after eviction — admission control.
    pub fn hello(&mut self, id: u64, model: &str) -> bool {
        self.evict_expired();
        if !self.sessions.contains_key(&id) && self.sessions.len() >= self.max_sessions {
            // LRU eviction of the stalest entry
            if let Some((&stale, _)) = self
                .sessions
                .iter()
                .min_by_key(|(_, s)| s.last_seen)
            {
                // never evict a session seen within the TTL window
                if self.sessions[&stale].last_seen.elapsed() < self.ttl {
                    return false;
                }
                self.sessions.remove(&stale);
            }
        }
        let now = Instant::now();
        self.sessions
            .entry(id)
            .and_modify(|s| s.last_seen = now)
            .or_insert(Session {
                id,
                model: model.to_string(),
                created: now,
                last_seen: now,
                requests: 0,
                bytes_rx: 0,
            });
        true
    }

    /// Record a request; returns false for unknown sessions.
    pub fn touch(&mut self, id: u64, bytes: u64) -> bool {
        match self.sessions.get_mut(&id) {
            Some(s) => {
                s.last_seen = Instant::now();
                s.requests += 1;
                s.bytes_rx += bytes;
                true
            }
            None => false,
        }
    }

    pub fn evict_expired(&mut self) {
        let ttl = self.ttl;
        self.sessions.retain(|_, s| s.last_seen.elapsed() < ttl);
    }

    pub fn remove(&mut self, id: u64) {
        self.sessions.remove(&id);
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hello_touch_flow() {
        let mut m = SessionManager::new(Duration::from_secs(60), 10);
        assert!(m.hello(1, "x"));
        assert!(m.touch(1, 100));
        assert!(!m.touch(2, 100)); // unknown
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn admission_control_when_full_of_active() {
        let mut m = SessionManager::new(Duration::from_secs(60), 2);
        assert!(m.hello(1, "x"));
        assert!(m.hello(2, "x"));
        // both active within TTL: third must be refused
        assert!(!m.hello(3, "x"));
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn ttl_eviction() {
        let mut m = SessionManager::new(Duration::from_millis(10), 10);
        m.hello(1, "x");
        std::thread::sleep(Duration::from_millis(20));
        m.evict_expired();
        assert!(m.is_empty());
    }

    #[test]
    fn stale_session_evicted_for_new() {
        let mut m = SessionManager::new(Duration::from_millis(10), 1);
        m.hello(1, "x");
        std::thread::sleep(Duration::from_millis(20));
        assert!(m.hello(2, "x"));
        assert!(m.touch(2, 1));
        assert!(!m.touch(1, 1));
    }
}
