//! Shared poll loop: a fixed pool of worker threads multiplexing
//! every registered connection through non-blocking
//! [`FrameRx::try_recv`] readiness checks, in place of the old
//! blocking thread per connection.
//!
//! Lifecycle of a connection:
//!
//! 1. **register** — the transport is split, the service opens a
//!    [`ConnState`] (codec engine + reply channel + ownership nonce),
//!    and the assembled [`PolledConn`] joins the shared round-robin
//!    queue.
//! 2. **visit** — a worker pops the connection, drains up to
//!    [`INBOUND_QUANTUM`] inbound frames through
//!    [`ServingService::handle`] (replies are routed through the
//!    connection's reply channel so they stay ordered with the
//!    compute workers' `Token` frames), flushes the reply channel
//!    into the tx half, then pushes the connection back.
//! 3. **retire** — on peer disconnect, a typed `Close`, service
//!    shutdown, or the per-connection idle deadline, the worker
//!    flushes any queued replies, releases the session-ownership
//!    binding via [`ServingService::close_conn`], and drops the
//!    connection.
//!
//! A hung peer therefore costs one failed readiness probe per visit —
//! never a parked worker — and is eventually collected by the idle
//! deadline (the `idle_disconnects` metric counts those).  When a
//! full pass over the queue makes no progress the worker naps briefly
//! instead of spinning.

use super::obs::{DumpOnPanic, FlightKind, StepTrace};
use super::server::{ConnState, Reply, Response, ServingService};
use super::transport::{FrameRx, FrameTx, Transport};
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Max inbound frames handled per visit before the connection yields
/// the worker — keeps one chatty peer from starving the queue.
const INBOUND_QUANTUM: usize = 32;

/// Worker nap after a full no-progress pass over the queue.
const IDLE_NAP: Duration = Duration::from_micros(200);

/// One registered connection as the poll workers see it.
struct PolledConn {
    tx: Box<dyn FrameTx>,
    rx: Box<dyn FrameRx>,
    /// Held so the reply channel never reads Disconnected while the
    /// connection lives; handle() replies are sent here to stay FIFO
    /// with the compute workers' Token frames.
    reply_tx: mpsc::Sender<Reply>,
    reply_rx: mpsc::Receiver<Reply>,
    conn: ConnState,
    /// Last time the peer produced a frame — the idle deadline ticks
    /// from here.
    last_rx: Instant,
}

struct PollShared {
    service: Arc<ServingService>,
    queue: Mutex<VecDeque<PolledConn>>,
    /// Live connection count — sizes a worker's "full pass" estimate
    /// for idle pacing (and is handy for tests).
    conns: AtomicUsize,
    stop: AtomicBool,
    /// None = no idle deadline (`idle_deadline_ms = 0`).
    idle: Option<Duration>,
}

/// The worker pool.  Owned by the service handle; `register` may be
/// called from any thread (the TCP accept loop, in-proc connectors).
pub struct PollPool {
    shared: Arc<PollShared>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl PollPool {
    pub fn start(service: Arc<ServingService>, workers: usize,
                 idle: Option<Duration>) -> PollPool {
        let shared = Arc::new(PollShared {
            service,
            queue: Mutex::new(VecDeque::new()),
            conns: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
            idle,
        });
        let n = workers.max(1);
        let handles = (0..n)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("fc-poll-{i}"))
                    .spawn(move || worker_loop(&shared, i))
                    .expect("spawn poll worker")
            })
            .collect();
        PollPool { shared, workers: Mutex::new(handles) }
    }

    /// Split the transport and enter it into the shared poll queue.
    /// Returns once the connection is registered — frames flow as
    /// soon as a worker visits it.
    pub fn register(&self, transport: Box<dyn Transport>) -> Result<()> {
        let peer = transport.peer();
        let (tx, rx) = transport.split()?;
        let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
        let conn = self.shared.service.open_conn(reply_tx.clone(), peer);
        self.shared.service.metrics.conns_opened
            .fetch_add(1, Ordering::Relaxed);
        self.shared.conns.fetch_add(1, Ordering::Relaxed);
        self.shared.queue.lock().unwrap().push_back(PolledConn {
            tx, rx, reply_tx, reply_rx, conn, last_rx: Instant::now(),
        });
        Ok(())
    }

    /// Live registered connections (diagnostic).
    pub fn conn_count(&self) -> usize {
        self.shared.conns.load(Ordering::Relaxed)
    }

    /// Stop the workers, join them, and retire every connection still
    /// in the queue (releasing session-ownership bindings).
    pub fn shutdown(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        for h in self.workers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
        let mut q = self.shared.queue.lock().unwrap();
        while let Some(pc) = q.pop_front() {
            retire(&self.shared, pc);
        }
    }
}

/// Send one reply on the wire and, for sampled steps, stamp the tx
/// stage and retire the trace — the flush point is the only place
/// that knows when the frame actually left.
fn flush_reply(shared: &PollShared, pc: &mut PolledConn, reply: Reply)
    -> bool {
    let t0 = Instant::now();
    match pc.tx.send(&reply.frame) {
        Ok(n) => {
            shared.service.metrics.bytes_tx
                .fetch_add(n as u64, Ordering::Relaxed);
            if let Some(t) = reply.trace {
                shared.service.obs().tracer.finish(StepTrace::finish(
                    *t, t0.elapsed().as_micros() as u64));
            }
            true
        }
        Err(_) => false,
    }
}

/// Flush queued replies and release the connection's session binding.
fn retire(shared: &PollShared, mut pc: PolledConn) {
    while let Ok(reply) = pc.reply_rx.try_recv() {
        if !flush_reply(shared, &mut pc, reply) {
            break;
        }
    }
    shared.service.close_conn(&pc.conn);
    shared.service.metrics.conns_closed.fetch_add(1, Ordering::Relaxed);
    shared.conns.fetch_sub(1, Ordering::Relaxed);
}

/// Visit one connection: drain inbound, flush replies, check the
/// idle deadline.  Returns (made_progress, close).  `wid` names the
/// visiting worker's occupancy gauges.
fn visit(shared: &PollShared, pc: &mut PolledConn, wid: usize)
    -> (bool, bool) {
    let t_visit = Instant::now();
    let mut progress = false;
    let mut close = false;
    let mut frames = 0u64;
    for _ in 0..INBOUND_QUANTUM {
        match pc.rx.try_recv() {
            Ok(Some(frame)) => {
                progress = true;
                frames += 1;
                pc.last_rx = Instant::now();
                match shared.service.handle(&mut pc.conn, frame) {
                    Response::None => {}
                    Response::Reply(f) => {
                        // cannot fail: pc.reply_tx keeps the channel open
                        let _ = pc.reply_tx.send(f.into());
                    }
                    Response::Close => {
                        close = true;
                        break;
                    }
                }
            }
            Ok(None) => break, // nothing buffered right now
            Err(_) => {
                // peer disconnected / framing error mid-stream
                shared.service.obs().flight.record(
                    FlightKind::RxError, pc.conn.session(),
                    shared.service.shard_of(pc.conn.session()) as u16, 0, 0);
                close = true;
                break;
            }
        }
    }
    loop {
        match pc.reply_rx.try_recv() {
            Ok(reply) => {
                progress = true;
                if !flush_reply(shared, pc, reply) {
                    close = true;
                    break;
                }
            }
            Err(mpsc::TryRecvError::Empty) => break,
            Err(mpsc::TryRecvError::Disconnected) => unreachable!(),
        }
    }
    if let Some(idle) = shared.idle {
        if !close && pc.last_rx.elapsed() >= idle {
            shared.service.metrics.idle_disconnects
                .fetch_add(1, Ordering::Relaxed);
            shared.service.obs().flight.record(
                FlightKind::IdleDisconnect, pc.conn.session(),
                shared.service.shard_of(pc.conn.session()) as u16, 0,
                pc.last_rx.elapsed().as_millis() as u64);
            crate::debug!("poll", "{}: idle deadline", pc.conn.peer());
            close = true;
        }
    }
    if let Some(w) = shared.service.obs().workers.get(wid) {
        w.visits.fetch_add(1, Ordering::Relaxed);
        w.frames.fetch_add(frames, Ordering::Relaxed);
        w.busy_us.fetch_add(t_visit.elapsed().as_micros() as u64,
                            Ordering::Relaxed);
    }
    (progress, close)
}

fn worker_loop(shared: &PollShared, wid: usize) {
    let _postmortem = DumpOnPanic(shared.service.obs().flight.clone());
    let nap = |shared: &PollShared| {
        if let Some(w) = shared.service.obs().workers.get(wid) {
            w.naps.fetch_add(1, Ordering::Relaxed);
        }
        std::thread::sleep(IDLE_NAP);
    };
    // consecutive no-progress visits; once it covers every live
    // connection the worker has made a full dry pass and naps
    let mut dry_visits = 0usize;
    while !shared.stop.load(Ordering::SeqCst) {
        let Some(mut pc) = shared.queue.lock().unwrap().pop_front() else {
            nap(shared);
            continue;
        };
        let (progress, close) = visit(shared, &mut pc, wid);
        if close {
            retire(shared, pc);
        } else {
            shared.queue.lock().unwrap().push_back(pc);
        }
        if progress {
            dry_visits = 0;
        } else {
            dry_visits += 1;
            if dry_visits >= shared.conns.load(Ordering::Relaxed).max(1) {
                dry_visits = 0;
                nap(shared);
            }
        }
    }
}
