//! Serving observability: per-step trace records, the lock-free
//! flight recorder, and the sharded metric families that extend the
//! legacy global [`super::metrics::Metrics`] blob.
//!
//! Three independent mechanisms live here, all threaded through the
//! serving core by `start_service`:
//!
//! * **Step traces** ([`Tracer`]) — a span id minted deterministically
//!   from `(session, request)` (no wire change: both ends derive the
//!   identical id from fields already in every data frame), sampled
//!   1-in-N, carried in-process through poll visit → feed enqueue →
//!   compute → reply flush, and finalized into a [`StepTrace`] with
//!   per-stage timings plus the codec's [`StageTimes`].  The cost
//!   contract when tracing is off is **one relaxed atomic load and a
//!   branch** per data frame ([`Tracer::begin`]).
//! * **Flight recorder** ([`FlightRecorder`]) — a fixed-size
//!   seqlock-style ring of recent structured events (rejects,
//!   evictions, idle disconnects, ladder switches, keyframe resyncs,
//!   rx errors).  Writers are lock-free (one `fetch_add` plus five
//!   atomic stores); readers validate slot versions and skip torn
//!   slots, so a dump is safe from any thread at any time — including
//!   a panicking one ([`DumpOnPanic`]).
//! * **Sharded metric families** ([`ShardMetrics`], [`BucketMetrics`],
//!   [`WorkerMetrics`]) — per-session-shard admission/eviction
//!   counters, per-batch-bucket enqueue/wait accounting, and
//!   per-poll-worker occupancy gauges (visits, frame quanta, dry-pass
//!   naps, busy time), all plain relaxed atomics, aggregated into the
//!   Stats-frame JSON next to the legacy keys.

use crate::codec::StageTimes;
use crate::util::hist::Histogram;
use crate::util::json::Json;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

// ---------------------------------------------------------------------------
// span ids + step traces
// ---------------------------------------------------------------------------

/// Mint the span id for one decode step.  Deterministic in
/// `(session, request)` — the client mints it at `prepare_step` and
/// the server re-derives the identical id from the frame header, so
/// the trace needs no new wire field and protocol v3 stays
/// byte-identical.
pub fn span_id(session: u64, request: u64) -> u64 {
    let mut x = session
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ request.wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
    x ^= x >> 33;
    x = x.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    x ^= x >> 33;
    x.max(1)
}

/// A sampled step's in-flight trace state, carried through the
/// serving pipeline inside the `GroupItem` / reply wrapper (never on
/// the wire).  Stage fields are stamped by whichever stage ran them.
#[derive(Debug)]
pub struct TraceInFlight {
    pub span: u64,
    pub session: u64,
    pub request: u64,
    pub bucket: usize,
    pub point: u8,
    pub shard: usize,
    /// Frame receive time — every later stage is measured against it.
    pub t_rx: Instant,
    pub decompress_us: u64,
    pub queue_wait_us: u64,
    pub exec_us: u64,
    /// Codec per-stage breakdown for this frame's unpack (from the
    /// connection engine's [`StageTimes`], enabled only while a
    /// sampled frame decompresses).
    pub codec: StageTimes,
}

/// One completed per-step trace record.
#[derive(Debug, Clone)]
pub struct StepTrace {
    pub span: u64,
    pub session: u64,
    pub request: u64,
    pub bucket: usize,
    pub point: u8,
    pub shard: usize,
    pub queue_wait_us: u64,
    pub decompress_us: u64,
    pub exec_us: u64,
    /// Reply serialization + transmit, stamped at the tx flush.
    pub tx_us: u64,
    /// rx → reply-on-the-wire, the span's full server residency.
    pub total_us: u64,
    pub codec_row_fft_us: u64,
    pub codec_col_fft_us: u64,
    pub codec_pack_us: u64,
    pub codec_quant_us: u64,
    pub codec_wire_us: u64,
}

impl StepTrace {
    /// Finalize an in-flight trace at the moment its reply hit the
    /// wire.
    pub fn finish(t: TraceInFlight, tx_us: u64) -> StepTrace {
        StepTrace {
            span: t.span,
            session: t.session,
            request: t.request,
            bucket: t.bucket,
            point: t.point,
            shard: t.shard,
            queue_wait_us: t.queue_wait_us,
            decompress_us: t.decompress_us,
            exec_us: t.exec_us,
            tx_us,
            total_us: t.t_rx.elapsed().as_micros() as u64,
            codec_row_fft_us: t.codec.row_fft.as_micros() as u64,
            codec_col_fft_us: t.codec.col_fft.as_micros() as u64,
            codec_pack_us: t.codec.pack.as_micros() as u64,
            codec_quant_us: t.codec.quant.as_micros() as u64,
            codec_wire_us: t.codec.wire.as_micros() as u64,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("span", Json::Num(self.span as f64));
        j.set("session", Json::Num(self.session as f64));
        j.set("request", Json::Num(self.request as f64));
        j.set("bucket", Json::Num(self.bucket as f64));
        j.set("point", Json::Num(self.point as f64));
        j.set("shard", Json::Num(self.shard as f64));
        j.set("queue_wait_us", Json::Num(self.queue_wait_us as f64));
        j.set("decompress_us", Json::Num(self.decompress_us as f64));
        j.set("exec_us", Json::Num(self.exec_us as f64));
        j.set("tx_us", Json::Num(self.tx_us as f64));
        j.set("total_us", Json::Num(self.total_us as f64));
        let mut c = Json::obj();
        c.set("row_fft_us", Json::Num(self.codec_row_fft_us as f64));
        c.set("col_fft_us", Json::Num(self.codec_col_fft_us as f64));
        c.set("pack_us", Json::Num(self.codec_pack_us as f64));
        c.set("quant_us", Json::Num(self.codec_quant_us as f64));
        c.set("wire_us", Json::Num(self.codec_wire_us as f64));
        j.set("codec", c);
        j
    }
}

/// How many completed traces the tracer retains (oldest dropped).
pub const TRACE_CAPACITY: usize = 1024;

/// Per-step trace control: deterministic 1-in-N sampling and the ring
/// of completed records.  `sample == 0` disables tracing entirely —
/// the begin path is then a single relaxed load + branch, which is
/// the hot-path cost contract the observability layer ships under.
pub struct Tracer {
    sample: AtomicU64,
    done: Mutex<VecDeque<StepTrace>>,
}

impl Tracer {
    pub fn new(sample: u64) -> Tracer {
        Tracer { sample: AtomicU64::new(sample),
                 done: Mutex::new(VecDeque::new()) }
    }

    /// Current 1-in-N sampling divisor (0 = tracing off).
    pub fn sample(&self) -> u64 {
        self.sample.load(Ordering::Relaxed)
    }

    pub fn set_sample(&self, n: u64) {
        self.sample.store(n, Ordering::Relaxed);
    }

    /// Whether the span for `(session, request)` is sampled.  The
    /// decision is a pure function of the ids and the divisor, so the
    /// client can predict exactly which of its steps the server
    /// traced.
    pub fn sampled(&self, session: u64, request: u64) -> bool {
        let n = self.sample.load(Ordering::Relaxed);
        n != 0 && span_id(session, request) % n == 0
    }

    /// Start a trace for one data frame, or `None` when the step is
    /// not sampled.  The disabled path returns after one relaxed
    /// atomic load and a branch.
    #[inline]
    pub fn begin(&self, session: u64, request: u64, t_rx: Instant)
        -> Option<Box<TraceInFlight>> {
        let n = self.sample.load(Ordering::Relaxed);
        if n == 0 {
            return None;
        }
        let span = span_id(session, request);
        if span % n != 0 {
            return None;
        }
        Some(Box::new(TraceInFlight {
            span,
            session,
            request,
            bucket: 0,
            point: 0,
            shard: 0,
            t_rx,
            decompress_us: 0,
            queue_wait_us: 0,
            exec_us: 0,
            codec: StageTimes::default(),
        }))
    }

    /// Retire a completed trace into the bounded ring.
    pub fn finish(&self, trace: StepTrace) {
        let mut q = self.done.lock().unwrap();
        if q.len() >= TRACE_CAPACITY {
            q.pop_front();
        }
        q.push_back(trace);
    }

    /// Completed traces retained so far (oldest first).
    pub fn completed(&self) -> Vec<StepTrace> {
        self.done.lock().unwrap().iter().cloned().collect()
    }
}

// ---------------------------------------------------------------------------
// flight recorder
// ---------------------------------------------------------------------------

/// Event kinds the flight recorder distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum FlightKind {
    /// Hello with a bad magic or protocol version.
    ProtoReject = 1,
    /// Stream frame refused (sequence gap, evicted state, illegal
    /// mid-stream ladder switch) — `seq` carries the frame's sequence
    /// number.
    StreamReject = 2,
    /// Data frame refused before the codec (bad bucket/point
    /// geometry, admission, unpack failure).
    BadRequest = 3,
    /// Session dropped by TTL/LRU eviction in its shard.
    SessionEvict = 4,
    /// Connection cut by the poll loop's idle deadline.
    IdleDisconnect = 5,
    /// Session switched quality-ladder points (`aux` = new point).
    LadderSwitch = 6,
    /// A keyframe resynced a desynced stream (`seq` = keyframe seq).
    KeyframeResync = 7,
    /// Receive-side transport failure: peer vanished mid-stream or
    /// sent an oversize/garbage frame the codec layer refused.
    RxError = 8,
    /// An entropy-capable sender fell back to a raw payload
    /// mid-stream (its try-and-compare lost) — recorded only for
    /// connections that previously sent coded frames, so the ring is
    /// not flooded by peers that simply never enabled entropy.
    EntropyFallback = 9,
    /// Prefill chunk refused (chunk-index gap, bad geometry, chunk
    /// without a keyframe chunk 0) — `seq` carries the chunk index;
    /// the client restarts the prompt from chunk 0.
    PrefillReject = 10,
}

impl FlightKind {
    fn from_u8(v: u8) -> Option<FlightKind> {
        Some(match v {
            1 => FlightKind::ProtoReject,
            2 => FlightKind::StreamReject,
            3 => FlightKind::BadRequest,
            4 => FlightKind::SessionEvict,
            5 => FlightKind::IdleDisconnect,
            6 => FlightKind::LadderSwitch,
            7 => FlightKind::KeyframeResync,
            8 => FlightKind::RxError,
            9 => FlightKind::EntropyFallback,
            10 => FlightKind::PrefillReject,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            FlightKind::ProtoReject => "proto_reject",
            FlightKind::StreamReject => "stream_reject",
            FlightKind::BadRequest => "bad_request",
            FlightKind::SessionEvict => "session_evict",
            FlightKind::IdleDisconnect => "idle_disconnect",
            FlightKind::LadderSwitch => "ladder_switch",
            FlightKind::KeyframeResync => "keyframe_resync",
            FlightKind::RxError => "rx_error",
            FlightKind::EntropyFallback => "entropy_fallback",
            FlightKind::PrefillReject => "prefill_reject",
        }
    }
}

/// One structured flight-recorder event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlightEvent {
    /// Microseconds since the recorder (≈ the service) started.
    pub t_us: u64,
    pub kind: FlightKind,
    pub session: u64,
    pub shard: u16,
    /// Stream sequence number where applicable, else 0.
    pub seq: u32,
    /// Kind-specific extra word (ladder point, protocol version, …).
    pub aux: u64,
}

impl std::fmt::Display for FlightEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "+{:>10}us {:<16} session={} shard={} seq={} aux={}",
               self.t_us, self.kind.name(), self.session, self.shard,
               self.seq, self.aux)
    }
}

impl FlightEvent {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("t_us", Json::Num(self.t_us as f64));
        j.set("kind", Json::Str(self.kind.name().to_string()));
        j.set("session", Json::Num(self.session as f64));
        j.set("shard", Json::Num(self.shard as f64));
        j.set("seq", Json::Num(self.seq as f64));
        j.set("aux", Json::Num(self.aux as f64));
        j
    }
}

/// Default ring capacity — recent events only, by design.
pub const FLIGHT_CAPACITY: usize = 256;

/// One ring slot: a seqlock version word plus four packed data words.
/// The version is `2*idx + 1` while logical event `idx` is being
/// written and `2*idx + 2` once it is complete, so a reader can tell
/// a torn or recycled slot from a settled one without any lock.
struct Slot {
    ver: AtomicU64,
    w: [AtomicU64; 4],
}

/// Fixed-size lock-free ring of recent structured events.  Recording
/// is wait-free for writers (`fetch_add` + 6 stores, no CAS loops);
/// dumping is safe concurrently with writers — a slot whose version
/// does not settle is skipped rather than read torn.
pub struct FlightRecorder {
    slots: Vec<Slot>,
    /// Total events ever recorded; `head % slots.len()` is the next
    /// slot to write.
    head: AtomicU64,
    start: Instant,
}

impl FlightRecorder {
    pub fn new(capacity: usize) -> FlightRecorder {
        FlightRecorder {
            slots: (0..capacity.max(1))
                .map(|_| Slot { ver: AtomicU64::new(0),
                                w: Default::default() })
                .collect(),
            head: AtomicU64::new(0),
            start: Instant::now(),
        }
    }

    /// Record one event.  Lock-free; callable from any worker thread.
    pub fn record(&self, kind: FlightKind, session: u64, shard: u16,
                  seq: u32, aux: u64) {
        let t_us = self.start.elapsed().as_micros() as u64;
        let idx = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(idx % self.slots.len() as u64) as usize];
        slot.ver.store(idx * 2 + 1, Ordering::Release);
        slot.w[0].store(t_us, Ordering::Relaxed);
        slot.w[1].store(session, Ordering::Relaxed);
        slot.w[2].store(((kind as u64) << 56) | ((shard as u64) << 40)
                        | seq as u64,
                        Ordering::Relaxed);
        slot.w[3].store(aux, Ordering::Relaxed);
        slot.ver.store(idx * 2 + 2, Ordering::Release);
    }

    /// Total events recorded since start (including any the ring has
    /// since overwritten).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    pub fn is_empty(&self) -> bool {
        self.recorded() == 0
    }

    /// Snapshot the ring: the most recent events, oldest first.
    /// Slots being concurrently rewritten are skipped (their newer
    /// contents belong to a later logical position anyway).
    pub fn dump(&self) -> Vec<FlightEvent> {
        let head = self.head.load(Ordering::Acquire);
        let n = self.slots.len() as u64;
        let lo = head.saturating_sub(n);
        let mut out = Vec::with_capacity((head - lo) as usize);
        for pos in lo..head {
            let slot = &self.slots[(pos % n) as usize];
            for _ in 0..64 {
                let v1 = slot.ver.load(Ordering::Acquire);
                if v1 > pos * 2 + 2 {
                    break; // recycled by a newer event — skip
                }
                if v1 != pos * 2 + 2 {
                    std::hint::spin_loop(); // writer mid-flight
                    continue;
                }
                let w0 = slot.w[0].load(Ordering::Acquire);
                let w1 = slot.w[1].load(Ordering::Acquire);
                let w2 = slot.w[2].load(Ordering::Acquire);
                let w3 = slot.w[3].load(Ordering::Acquire);
                if slot.ver.load(Ordering::Acquire) != v1 {
                    continue; // torn read — retry
                }
                if let Some(kind) = FlightKind::from_u8((w2 >> 56) as u8) {
                    out.push(FlightEvent {
                        t_us: w0,
                        kind,
                        session: w1,
                        shard: ((w2 >> 40) & 0xFFFF) as u16,
                        seq: (w2 & 0xFFFF_FFFF) as u32,
                        aux: w3,
                    });
                }
                break;
            }
        }
        out
    }

    /// Human-readable dump, one event per line (post-mortems).
    pub fn dump_text(&self) -> String {
        let events = self.dump();
        if events.is_empty() {
            return "flight recorder: no events".to_string();
        }
        let mut s = format!("flight recorder: {} recent of {} total\n",
                            events.len(), self.recorded());
        for e in &events {
            s.push_str(&format!("  {e}\n"));
        }
        s
    }
}

/// Drop guard for worker threads: if the thread unwinds, the flight
/// recorder's recent events are printed to stderr so the panic is
/// diagnosable post-mortem without a debugger attached.
pub struct DumpOnPanic(pub Arc<FlightRecorder>);

impl Drop for DumpOnPanic {
    fn drop(&mut self) {
        if std::thread::panicking() {
            eprintln!("[flight-recorder] worker panicked; {}",
                      self.0.dump_text());
        }
    }
}

// ---------------------------------------------------------------------------
// sharded metric families
// ---------------------------------------------------------------------------

/// Per-session-shard counters (live count is read momentarily from
/// the shard itself — only monotone counters live here).
#[derive(Default)]
pub struct ShardMetrics {
    /// Sessions newly created in this shard (hello / readmit /
    /// stream-keyframe admission).
    pub admitted: AtomicU64,
    /// Sessions dropped by TTL sweep, LRU pressure, or delta-path
    /// expiry.
    pub evicted: AtomicU64,
}

/// Per-batch-bucket queue accounting (depth is read momentarily from
/// the feed's micro-queue).
#[derive(Default)]
pub struct BucketMetrics {
    /// Items enqueued into this bucket's micro-queue.
    pub enqueued: AtomicU64,
    /// Groups flushed out of this bucket.
    pub groups: AtomicU64,
    /// Per-item queue wait, µs.
    pub wait_us: Histogram,
    /// Raw-equivalent body bytes of this bucket's entropy-coded
    /// frames (what the payloads would have cost uncoded).  Coded
    /// frames only, so `pre / post` is the bucket's realized
    /// entropy-coding ratio.
    pub pre_bytes: AtomicU64,
    /// Actual coded body bytes of the same frames.
    pub post_bytes: AtomicU64,
}

/// Per-poll-worker occupancy gauges.
#[derive(Default)]
pub struct WorkerMetrics {
    /// Connections visited.
    pub visits: AtomicU64,
    /// Inbound frames handled across visits (per-visit quantum is
    /// `frames / visits`).
    pub frames: AtomicU64,
    /// 200µs naps after a full dry pass over the queue.
    pub naps: AtomicU64,
    /// Wall time spent inside visits, µs — occupancy is
    /// `busy_us / uptime`.
    pub busy_us: AtomicU64,
}

/// The service-wide observability bundle: one per running service,
/// shared by every worker.
pub struct Obs {
    pub tracer: Tracer,
    pub flight: Arc<FlightRecorder>,
    pub shards: Vec<Arc<ShardMetrics>>,
    /// Sorted by bucket id, mirroring the feed's bucket set.
    pub buckets: Vec<(usize, BucketMetrics)>,
    pub workers: Vec<WorkerMetrics>,
    snapshots: Mutex<Vec<String>>,
}

impl Obs {
    pub fn new(trace_sample: u64, shards: usize, bucket_ids: &[usize],
               poll_workers: usize) -> Obs {
        let mut ids: Vec<usize> = bucket_ids.to_vec();
        ids.sort_unstable();
        ids.dedup();
        Obs {
            tracer: Tracer::new(trace_sample),
            flight: Arc::new(FlightRecorder::new(FLIGHT_CAPACITY)),
            shards: (0..shards.max(1))
                .map(|_| Arc::new(ShardMetrics::default()))
                .collect(),
            buckets: ids.into_iter()
                .map(|b| (b, BucketMetrics::default()))
                .collect(),
            workers: (0..poll_workers.max(1))
                .map(|_| WorkerMetrics::default())
                .collect(),
            snapshots: Mutex::new(Vec::new()),
        }
    }

    /// The metric family for one batch bucket.
    pub fn bucket(&self, id: usize) -> Option<&BucketMetrics> {
        self.buckets
            .binary_search_by_key(&id, |(b, _)| *b)
            .ok()
            .map(|i| &self.buckets[i].1)
    }

    /// Append one snapshot JSONL line (the `snapshot_interval_ms`
    /// background tick).
    pub fn push_snapshot(&self, line: String) {
        self.snapshots.lock().unwrap().push(line);
    }

    /// All snapshot lines emitted so far, in order.
    pub fn snapshots(&self) -> Vec<String> {
        self.snapshots.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_is_deterministic_and_mixes() {
        assert_eq!(span_id(7, 42), span_id(7, 42));
        assert_ne!(span_id(7, 42), span_id(7, 43));
        assert_ne!(span_id(7, 42), span_id(8, 42));
        assert_ne!(span_id(0, 0), 0, "spans are never zero");
        // sequential requests must spread over the sampling residues,
        // or 1-in-N sampling would alias whole sessions away
        let hits = (0..1000u64).filter(|&r| span_id(5, r) % 4 == 0).count();
        assert!((150..400).contains(&hits), "1-in-4 sampled {hits}/1000");
    }

    #[test]
    fn tracer_sampling_contract() {
        let t = Tracer::new(0);
        let now = Instant::now();
        assert!(t.begin(1, 1, now).is_none(), "disabled: no allocation");
        assert!(!t.sampled(1, 1));
        t.set_sample(1);
        for r in 0..20 {
            assert!(t.begin(9, r, now).is_some(), "1-in-1 samples all");
        }
        t.set_sample(3);
        for r in 0..200u64 {
            // begin() and sampled() must agree exactly — the client
            // predicts server sampling through the same function
            assert_eq!(t.begin(9, r, now).is_some(), t.sampled(9, r));
            assert_eq!(t.sampled(9, r), span_id(9, r) % 3 == 0);
        }
    }

    #[test]
    fn tracer_ring_caps_and_orders() {
        let t = Tracer::new(1);
        for i in 0..(TRACE_CAPACITY + 10) as u64 {
            let inflight = t.begin(1, i, Instant::now()).unwrap();
            t.finish(StepTrace::finish(*inflight, 5));
        }
        let done = t.completed();
        assert_eq!(done.len(), TRACE_CAPACITY, "ring must cap");
        assert_eq!(done.last().unwrap().request, (TRACE_CAPACITY + 9) as u64,
                   "newest trace retained");
        assert_eq!(done[0].request, 10, "oldest traces dropped");
        assert_eq!(done[0].tx_us, 5);
        let j = done[0].to_json();
        assert_eq!(j.usize_or("request", 0), 10);
        assert!(j.path("codec.row_fft_us").is_some());
    }

    #[test]
    fn flight_event_roundtrip_packs_all_fields() {
        let r = FlightRecorder::new(8);
        r.record(FlightKind::StreamReject, u64::MAX - 3, 1023,
                 0xDEAD_BEEF, 77);
        let d = r.dump();
        assert_eq!(d.len(), 1);
        let e = d[0];
        assert_eq!(e.kind, FlightKind::StreamReject);
        assert_eq!(e.session, u64::MAX - 3);
        assert_eq!(e.shard, 1023);
        assert_eq!(e.seq, 0xDEAD_BEEF);
        assert_eq!(e.aux, 77);
        assert!(e.to_json().get("kind").and_then(|v| v.as_str())
                == Some("stream_reject"));
        assert!(format!("{e}").contains("stream_reject"));
        // every kind byte roundtrips through the packed word
        for k in 1..=10u8 {
            let kind = FlightKind::from_u8(k).unwrap();
            r.record(kind, 1, 0, 0, 0);
            assert_eq!(r.dump().last().unwrap().kind, kind);
        }
        assert!(FlightKind::from_u8(11).is_none());
        assert_eq!(FlightKind::EntropyFallback.name(), "entropy_fallback");
        assert_eq!(FlightKind::PrefillReject.name(), "prefill_reject");
    }

    #[test]
    fn flight_ring_keeps_most_recent_on_wrap() {
        let r = FlightRecorder::new(4);
        for i in 0..10u32 {
            r.record(FlightKind::SessionEvict, 100 + i as u64, 0, i, 0);
        }
        assert_eq!(r.recorded(), 10);
        let d = r.dump();
        assert_eq!(d.len(), 4, "ring holds the last capacity events");
        let seqs: Vec<u32> = d.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9], "oldest first, newest last");
        assert!(r.dump_text().contains("4 recent of 10 total"));
    }

    #[test]
    fn flight_concurrent_writers_never_produce_garbage() {
        let r = Arc::new(FlightRecorder::new(64));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let r = r.clone();
                s.spawn(move || {
                    for i in 0..2000u32 {
                        r.record(FlightKind::RxError, t * 10_000 + i as u64,
                                 t as u16, i, t);
                    }
                });
            }
            let reader = {
                let (r, stop) = (r.clone(), stop.clone());
                s.spawn(move || {
                    let mut dumps = 0usize;
                    while !stop.load(Ordering::Relaxed) {
                        for e in r.dump() {
                            // every decoded event must be one a writer
                            // actually produced — no torn mixes
                            assert_eq!(e.kind, FlightKind::RxError);
                            let t = e.session / 10_000;
                            assert_eq!(e.session % 10_000, e.seq as u64);
                            assert_eq!(e.aux, t);
                            assert_eq!(e.shard as u64, t);
                        }
                        dumps += 1;
                    }
                    dumps
                })
            };
            // writers finish, then the reader sees a settled ring
            std::thread::sleep(std::time::Duration::from_millis(20));
            stop.store(true, Ordering::Relaxed);
            assert!(reader.join().unwrap() > 0);
        });
        assert_eq!(r.recorded(), 8000);
        assert_eq!(r.dump().len(), 64, "settled ring dumps every slot");
    }

    #[test]
    fn obs_bucket_lookup_and_snapshots() {
        let o = Obs::new(0, 4, &[64, 16, 32, 16], 2);
        assert_eq!(o.buckets.len(), 3, "bucket ids dedup + sort");
        assert!(o.bucket(16).is_some());
        assert!(o.bucket(99).is_none());
        o.bucket(32).unwrap().enqueued.fetch_add(2, Ordering::Relaxed);
        assert_eq!(o.bucket(32).unwrap().enqueued.load(Ordering::Relaxed), 2);
        o.bucket(32).unwrap().pre_bytes.fetch_add(100, Ordering::Relaxed);
        o.bucket(32).unwrap().post_bytes.fetch_add(60, Ordering::Relaxed);
        assert_eq!(o.bucket(32).unwrap().pre_bytes.load(Ordering::Relaxed),
                   100);
        assert_eq!(o.shards.len(), 4);
        assert_eq!(o.workers.len(), 2);
        o.push_snapshot("{\"t_ms\":1}".into());
        o.push_snapshot("{\"t_ms\":2}".into());
        assert_eq!(o.snapshots().len(), 2);
    }
}
