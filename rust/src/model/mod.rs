//! Model runtime: manifest-driven metadata, weight loading, the
//! byte-level tokenizer, and the composable split executor that runs
//! client layers / codec boundary / server layers at ANY split depth.

pub mod executor;
pub mod tokenizer;
pub mod weights;

use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// Geometry + artifact paths for one model, read from the manifest.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub vocab_size: usize,
    pub max_seq: usize,
    pub qkv_bias: bool,
    /// hidden-axis rfft band of the layer-1 activations (kd = 2b-1)
    pub l1_freq_bins: usize,
    pub n_params: usize,
    pub weights_path: String,
    pub golden_path: String,
    pub eval_batch: usize,
    pub eval_seq: usize,
    pub embed_hlo: String,
    pub layer_hlo: String,
    pub head_hlo: String,
    pub layer_weight_names: Vec<String>,
}

impl ModelMeta {
    pub fn from_manifest(name: &str, j: &Json) -> Result<ModelMeta> {
        let art = |k: &str| -> Result<String> {
            j.path(&format!("artifacts.{k}.path"))
                .and_then(|v| v.as_str())
                .map(String::from)
                .ok_or_else(|| anyhow!("model {name}: missing artifact {k}"))
        };
        Ok(ModelMeta {
            name: name.to_string(),
            d_model: j.usize_or("d_model", 0),
            n_layers: j.usize_or("n_layers", 0),
            n_heads: j.usize_or("n_heads", 0),
            n_kv_heads: j.usize_or("n_kv_heads", 0),
            d_ff: j.usize_or("d_ff", 0),
            vocab_size: j.usize_or("vocab_size", 259),
            max_seq: j.usize_or("max_seq", 64),
            qkv_bias: j.get("qkv_bias").and_then(|v| v.as_bool()).unwrap_or(false),
            l1_freq_bins: j.usize_or("l1_freq_bins", 8),
            n_params: j.usize_or("n_params", 0),
            weights_path: j.str_or("weights", ""),
            golden_path: j.str_or("golden", ""),
            eval_batch: j.usize_or("eval_batch", 8),
            eval_seq: j.usize_or("eval_seq", 64),
            embed_hlo: art("embed")?,
            layer_hlo: art("layer")?,
            head_hlo: art("head")?,
            layer_weight_names: j
                .get("layer_weight_names")
                .and_then(|v| v.as_arr())
                .map(|a| {
                    a.iter()
                        .filter_map(|v| v.as_str().map(String::from))
                        .collect()
                })
                .unwrap_or_default(),
        })
    }

    /// The calibrated FC hidden-axis block width for this model.
    pub fn kd_band(&self) -> usize {
        2 * self.l1_freq_bins - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn parses_manifest_entry() {
        let j = parse(
            r#"{"d_model": 96, "n_layers": 6, "n_heads": 4, "n_kv_heads": 4,
                "d_ff": 256, "l1_freq_bins": 7, "n_params": 714528,
                "weights": "weights/x.fcw", "golden": "golden/x.golden.fcw",
                "eval_batch": 8, "eval_seq": 64,
                "layer_weight_names": ["ln1", "wq"],
                "artifacts": {"embed": {"path": "e.hlo"},
                               "layer": {"path": "l.hlo"},
                               "head": {"path": "h.hlo"}}}"#,
        )
        .unwrap();
        let m = ModelMeta::from_manifest("x", &j).unwrap();
        assert_eq!(m.d_model, 96);
        assert_eq!(m.kd_band(), 13);
        assert_eq!(m.layer_hlo, "l.hlo");
        assert_eq!(m.layer_weight_names, vec!["ln1", "wq"]);
    }

    #[test]
    fn missing_artifact_is_error() {
        let j = parse(r#"{"d_model": 96, "artifacts": {}}"#).unwrap();
        assert!(ModelMeta::from_manifest("x", &j).is_err());
    }
}
