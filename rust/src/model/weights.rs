//! Weight set: the model's `.fcw` tensors plus helpers that assemble
//! artifact argument lists in the canonical order recorded in the
//! manifest (weight_args templates with `{i}` layer substitution).

use super::ModelMeta;
use crate::tensor::{io, Tensor};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;

pub struct Weights {
    pub tensors: BTreeMap<String, Tensor>,
}

impl Weights {
    pub fn load(artifacts_root: impl AsRef<Path>, meta: &ModelMeta) -> Result<Weights> {
        let path = artifacts_root.as_ref().join(&meta.weights_path);
        Ok(Weights { tensors: io::read_fcw(path)? })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow!("weight '{name}' missing"))
    }

    /// Arguments for the per-layer artifact at layer `i`:
    /// `layers.{i}.<name>` in canonical order.
    pub fn layer_args(&self, meta: &ModelMeta, i: usize) -> Result<Vec<Tensor>> {
        meta.layer_weight_names
            .iter()
            .map(|n| self.get(&format!("layers.{i}.{n}")).cloned())
            .collect()
    }

    pub fn embed_args(&self) -> Result<Vec<Tensor>> {
        Ok(vec![self.get("tok_emb")?.clone()])
    }

    pub fn head_args(&self) -> Result<Vec<Tensor>> {
        Ok(vec![self.get("final_norm")?.clone(), self.get("lm_head")?.clone()])
    }

    /// Stacked layer weights [lo, hi) for the fused server artifact:
    /// one tensor per canonical name with a new leading axis.
    pub fn stacked_layer_args(&self, meta: &ModelMeta, lo: usize, hi: usize)
        -> Result<Vec<Tensor>> {
        let mut out = Vec::new();
        for n in &meta.layer_weight_names {
            let first = self.get(&format!("layers.{lo}.{n}"))?;
            let mut shape = vec![hi - lo];
            shape.extend_from_slice(&first.shape);
            let mut data = Vec::with_capacity(shape.iter().product());
            for i in lo..hi {
                data.extend_from_slice(self.get(&format!("layers.{i}.{n}"))?.as_f32());
            }
            out.push(Tensor::f32(shape, data));
        }
        Ok(out)
    }

    pub fn total_params(&self) -> usize {
        self.tensors.values().map(|t| t.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn fake() -> (Weights, ModelMeta) {
        let mut tensors = BTreeMap::new();
        tensors.insert("tok_emb".into(), Tensor::zeros_f32(vec![10, 4]));
        tensors.insert("final_norm".into(), Tensor::zeros_f32(vec![4]));
        tensors.insert("lm_head".into(), Tensor::zeros_f32(vec![4, 10]));
        for i in 0..2 {
            tensors.insert(format!("layers.{i}.ln1"), Tensor::f32(vec![4], vec![i as f32; 4]));
            tensors.insert(format!("layers.{i}.wq"), Tensor::zeros_f32(vec![4, 4]));
        }
        let meta = ModelMeta {
            name: "t".into(), d_model: 4, n_layers: 2, n_heads: 1,
            n_kv_heads: 1, d_ff: 8, vocab_size: 10, max_seq: 8,
            qkv_bias: false, l1_freq_bins: 2, n_params: 0,
            weights_path: String::new(), golden_path: String::new(),
            eval_batch: 1, eval_seq: 8,
            embed_hlo: String::new(), layer_hlo: String::new(),
            head_hlo: String::new(),
            layer_weight_names: vec!["ln1".into(), "wq".into()],
        };
        (Weights { tensors }, meta)
    }

    #[test]
    fn layer_args_ordered() {
        let (w, meta) = fake();
        let args = w.layer_args(&meta, 1).unwrap();
        assert_eq!(args.len(), 2);
        assert_eq!(args[0].as_f32()[0], 1.0); // layer 1's ln1
        assert_eq!(args[1].shape, vec![4, 4]);
    }

    #[test]
    fn stacked_args_shape() {
        let (w, meta) = fake();
        let args = w.stacked_layer_args(&meta, 0, 2).unwrap();
        assert_eq!(args[0].shape, vec![2, 4]);
        assert_eq!(args[1].shape, vec![2, 4, 4]);
        // layer order preserved in the stack
        assert_eq!(args[0].as_f32()[0], 0.0);
        assert_eq!(args[0].as_f32()[4], 1.0);
    }

    #[test]
    fn missing_weight_errors() {
        let (w, meta) = fake();
        assert!(w.layer_args(&meta, 5).is_err());
    }
}
