//! Byte-level tokenizer — mirror of python/compile/datasets.py
//! (token = byte; BOS/EOS/PAD specials above 255).

pub const VOCAB_SIZE: usize = 259;
pub const BOS: i32 = 256;
pub const EOS: i32 = 257;
pub const PAD: i32 = 258;

pub fn encode(text: &str) -> Vec<i32> {
    text.bytes().map(|b| b as i32).collect()
}

pub fn encode_prompt(text: &str) -> Vec<i32> {
    let mut v = Vec::with_capacity(text.len() + 1);
    v.push(BOS);
    v.extend(text.bytes().map(|b| b as i32));
    v
}

pub fn decode(ids: &[i32]) -> String {
    let bytes: Vec<u8> = ids
        .iter()
        .filter(|&&i| (0..256).contains(&i))
        .map(|&i| i as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Pad (or truncate) to `len` with PAD.
pub fn pad_to(ids: &[i32], len: usize) -> Vec<i32> {
    let mut v: Vec<i32> = ids.iter().copied().take(len).collect();
    v.resize(len, PAD);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let s = "Q mira hue ? A blue .";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn prompt_has_bos() {
        let ids = encode_prompt("hi");
        assert_eq!(ids, vec![BOS, 104, 105]);
        assert_eq!(decode(&ids), "hi"); // specials dropped
    }

    #[test]
    fn padding() {
        let ids = vec![1, 2, 3];
        assert_eq!(pad_to(&ids, 5), vec![1, 2, 3, PAD, PAD]);
        assert_eq!(pad_to(&ids, 2), vec![1, 2]);
    }

    #[test]
    fn matches_python_ids() {
        // "Q" = 81, " " = 32 (byte identity)
        assert_eq!(encode("Q "), vec![81, 32]);
    }
}
