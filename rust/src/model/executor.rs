//! The composable split executor: embed → layers 0..k (client) →
//! host-side codec round-trip on the boundary activation → layers
//! k..L (server) → head, all through the per-layer HLO artifacts.
//!
//! This is the eval harness's engine: because the layer artifact takes
//! its weights as arguments, ANY split depth and ANY codec/ratio can
//! be exercised without re-lowering (DESIGN.md §3).  The fused
//! serving path (pallas codec baked into client/server HLOs) lives in
//! the coordinator instead.

use super::{weights::Weights, ModelMeta};
use crate::codec::{fourier::FourierCodec, block_ratio, fc_block, Codec};
use crate::runtime::{ArtifactStore, Executable};
use crate::tensor::{MatViewMut, Tensor};
use anyhow::{bail, Result};
use std::sync::Arc;

pub struct SplitExecutor {
    pub meta: ModelMeta,
    pub weights: Weights,
    embed: Arc<Executable>,
    layer: Arc<Executable>,
    head: Arc<Executable>,
}

/// What to do at the split boundary.
#[derive(Clone)]
pub enum Boundary<'a> {
    /// No compression (paper's baseline).
    None,
    /// A codec at a target ratio, applied per batch element on the
    /// cropped `len × D` activation (PAD rows are zeroed, not sent).
    Codec { codec: &'a dyn Codec, ratio: f64 },
    /// FourierCompress with an explicit block (ratio sweeps).
    FcBlock { ks: usize, kd: usize },
}

impl SplitExecutor {
    pub fn new(store: &ArtifactStore, model: &str) -> Result<SplitExecutor> {
        let meta = ModelMeta::from_manifest(model, store.model_meta(model)?)?;
        let weights = Weights::load(&store.root, &meta)?;
        Ok(SplitExecutor {
            embed: store.get(&meta.embed_hlo)?,
            layer: store.get(&meta.layer_hlo)?,
            head: store.get(&meta.head_hlo)?,
            meta,
            weights,
        })
    }

    /// Run a full batch through the split pipeline.
    ///
    /// * `tokens`: `[B, S]` i32, padded to the artifact geometry.
    /// * `lens`: true sequence length per element (codec crops to it).
    /// * `split`: number of client-side layers (0 = compress raw
    ///   embeddings, paper's setting is 1).
    ///
    /// Returns logits `[B, S, V]` and the mean achieved ratio.
    pub fn forward_split(&self, tokens: &Tensor, lens: &[usize], split: usize,
                         boundary: &Boundary) -> Result<(Tensor, f64)> {
        let (b, s) = (tokens.shape[0], tokens.shape[1]);
        if b != self.meta.eval_batch || s != self.meta.eval_seq {
            bail!("batch geometry {b}x{s} != artifact {}x{}",
                  self.meta.eval_batch, self.meta.eval_seq);
        }
        if split > self.meta.n_layers {
            bail!("split {split} > n_layers {}", self.meta.n_layers);
        }

        // embed
        let mut args = vec![tokens.clone()];
        args.extend(self.weights.embed_args()?);
        let mut h = self.embed.run(&args)?.remove(0);

        // client layers
        for i in 0..split {
            h = self.run_layer(i, h)?;
        }

        // boundary codec
        let ratio = self.apply_boundary(&mut h, lens, boundary)?;

        // server layers
        for i in split..self.meta.n_layers {
            h = self.run_layer(i, h)?;
        }

        // head
        let mut args = vec![h];
        args.extend(self.weights.head_args()?);
        let logits = self.head.run(&args)?.remove(0);
        Ok((logits, ratio))
    }

    fn run_layer(&self, i: usize, h: Tensor) -> Result<Tensor> {
        let mut args = vec![h];
        args.extend(self.weights.layer_args(&self.meta, i)?);
        Ok(self.layer.run(&args)?.remove(0))
    }

    /// Extract per-layer activations (after each block) for the
    /// analysis driver (Fig 2).  Returns L tensors of shape [B, S, D].
    pub fn activations(&self, tokens: &Tensor) -> Result<Vec<Tensor>> {
        let mut args = vec![tokens.clone()];
        args.extend(self.weights.embed_args()?);
        let mut h = self.embed.run(&args)?.remove(0);
        let mut acts = Vec::with_capacity(self.meta.n_layers);
        for i in 0..self.meta.n_layers {
            h = self.run_layer(i, h)?;
            acts.push(h.clone());
        }
        Ok(acts)
    }

    fn apply_boundary(&self, h: &mut Tensor, lens: &[usize], boundary: &Boundary)
        -> Result<f64> {
        let (b, s, d) = (h.shape[0], h.shape[1], h.shape[2]);
        // [B, S, D] as a (B·S) × D token-row matrix
        let mut mat = MatViewMut::new(h.as_f32_mut(), b * s, d);
        let mut ratios = Vec::with_capacity(b);
        for e in 0..b {
            let len = lens.get(e).copied().unwrap_or(s).clamp(1, s);
            let first = e * s; // this element's first token row
            let crop: Vec<f32> =
                mat.as_slice()[first * d..(first + len) * d].to_vec();
            let (recon, ratio) = match boundary {
                Boundary::None => (crop, 1.0),
                Boundary::Codec { codec, ratio } => {
                    let p = codec.compress(&crop, len, d, *ratio)?;
                    (codec.decompress(&p)?, p.achieved_ratio())
                }
                Boundary::FcBlock { ks, kd } => {
                    let ks = (*ks).min(len);
                    let ks = if ks == len { ks } else if ks % 2 == 0 { ks.max(2) - 1 } else { ks };
                    let fc = FourierCodec::default();
                    let p = fc.compress_block(&crop, len, d, ks, *kd)?;
                    (fc.decompress(&p)?, p.achieved_ratio())
                }
            };
            mat.as_slice_mut()[first * d..(first + len) * d]
                .copy_from_slice(&recon);
            // zero the PAD rows: they were never transmitted
            if !matches!(boundary, Boundary::None) {
                for r in first + len..first + s {
                    mat.row_mut(r).fill(0.0);
                }
            }
            ratios.push(ratio);
        }
        Ok(ratios.iter().sum::<f64>() / ratios.len().max(1) as f64)
    }

    /// FC block for this model at a target ratio over `len` rows.
    pub fn fc_block_for(&self, len: usize, ratio: f64) -> (usize, usize) {
        fc_block(len, self.meta.d_model, ratio, Some(self.meta.kd_band()))
    }

    pub fn fc_ratio_for(&self, len: usize, ks: usize, kd: usize) -> f64 {
        block_ratio(len, self.meta.d_model, ks, kd)
    }
}
