//! Pure-Rust reference interpreter for the small fixed family of
//! executables this repo lowers to HLO: `embed`, the Llama-style
//! transformer `layer` (RMSNorm / RoPE / (grouped-query) causal
//! attention / SwiGLU MLP), the LM `head`, the fused serving graphs
//! (`client_fused` = embed + layer 0 + FC compress, `server_fused` =
//! FC decompress + layers 1..L + head), and the standalone codec
//! kernels (`fc_compress` / `fc_decompress`).
//!
//! This is the hermetic counterpart of python/compile/model.py and
//! kernels/ref.py: the math mirrors those references exactly (weight
//! order, RoPE pairing, softmax masking, centred frequency blocks), so
//! an [`InterpExec`] is a drop-in replacement for a compiled PJRT
//! executable.  `ArtifactStore::get` constructs one transparently
//! whenever the manifest carries an `interp` spec for an artifact
//! whose HLO file does not exist — which is how the
//! `testkit`-forged artifact trees make the full split-inference
//! stack run (and be tested) from a bare `cargo test`, no XLA
//! toolchain required.
//!
//! Everything is shape-polymorphic: geometry that HLO bakes in (batch,
//! seq) is read off the argument tensors, and only the knobs a shape
//! cannot carry (head counts, RoPE theta, RMS eps, the FC block) come
//! from the spec.  Performance is a non-goal — forged models are tiny
//! (d_model ≈ 32) and the naive O(S²·D) attention is microseconds at
//! that scale.

use crate::codec::{centered_indices, valid_block_axis};
use crate::dsp::complex::C64;
use crate::dsp::fft2d;
use crate::tensor::{MatView, Tensor};
use crate::util::json::Json;
use anyhow::{bail, ensure, Result};

// ---------------------------------------------------------------------------
// specs
// ---------------------------------------------------------------------------

/// The per-layer geometry an HLO module closes over (everything else
/// is derived from argument shapes at run time).
#[derive(Debug, Clone)]
pub struct LayerGeom {
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub rope_theta: f64,
    pub rms_eps: f32,
    pub qkv_bias: bool,
}

impl LayerGeom {
    pub fn from_spec(spec: &Json) -> Result<LayerGeom> {
        let n_heads = spec.usize_or("n_heads", 0);
        ensure!(n_heads >= 1, "interp spec: n_heads missing");
        let n_kv_heads = spec.usize_or("n_kv_heads", n_heads);
        ensure!(n_kv_heads >= 1 && n_heads % n_kv_heads == 0,
                "interp spec: n_heads {n_heads} not divisible by n_kv_heads \
                 {n_kv_heads}");
        Ok(LayerGeom {
            n_heads,
            n_kv_heads,
            rope_theta: spec.f64_or("rope_theta", 10000.0),
            rms_eps: spec.f64_or("rms_eps", 1e-5) as f32,
            qkv_bias: spec.get("qkv_bias").and_then(|v| v.as_bool())
                .unwrap_or(false),
        })
    }
}

#[derive(Debug, Clone)]
enum InterpOp {
    Embed,
    Layer(LayerGeom),
    Head { rms_eps: f32 },
    ClientFused { geom: LayerGeom, ks: usize, kd: usize },
    ServerFused { geom: LayerGeom, seq: usize },
    FcCompress { ks: usize, kd: usize },
    FcDecompress { seq: usize, hidden: usize },
}

/// An interpreted executable: the hermetic stand-in for one compiled
/// HLO artifact.
#[derive(Debug, Clone)]
pub struct InterpExec {
    pub name: String,
    op: InterpOp,
}

impl InterpExec {
    /// Build from a manifest `interp` spec (`{"op": "...", ...}`).
    pub fn from_spec(name: &str, spec: &Json) -> Result<InterpExec> {
        let op = spec.str_or("op", "");
        let op = match op.as_str() {
            "embed" => InterpOp::Embed,
            "layer" => InterpOp::Layer(LayerGeom::from_spec(spec)?),
            "head" => InterpOp::Head {
                rms_eps: spec.f64_or("rms_eps", 1e-5) as f32,
            },
            "client_fused" => {
                let (ks, kd) = (spec.usize_or("ks", 0), spec.usize_or("kd", 0));
                ensure!(ks >= 1 && kd >= 1,
                        "interp spec {name}: client_fused needs ks/kd");
                InterpOp::ClientFused { geom: LayerGeom::from_spec(spec)?, ks, kd }
            }
            "server_fused" => {
                let seq = spec.usize_or("seq", 0);
                ensure!(seq >= 1, "interp spec {name}: server_fused needs seq");
                InterpOp::ServerFused { geom: LayerGeom::from_spec(spec)?, seq }
            }
            "fc_compress" => {
                let (ks, kd) = (spec.usize_or("ks", 0), spec.usize_or("kd", 0));
                ensure!(ks >= 1 && kd >= 1,
                        "interp spec {name}: fc_compress needs ks/kd");
                InterpOp::FcCompress { ks, kd }
            }
            "fc_decompress" => {
                let (seq, hidden) =
                    (spec.usize_or("seq", 0), spec.usize_or("hidden", 0));
                ensure!(seq >= 1 && hidden >= 1,
                        "interp spec {name}: fc_decompress needs seq/hidden");
                InterpOp::FcDecompress { seq, hidden }
            }
            other => bail!("artifact {name}: unknown interp op '{other}'"),
        };
        Ok(InterpExec { name: name.to_string(), op })
    }

    /// Execute with host tensors — same contract as the compiled
    /// backends (outputs in the artifact's tuple order).
    pub fn run(&self, args: &[Tensor]) -> Result<Vec<Tensor>> {
        match &self.op {
            InterpOp::Embed => {
                ensure!(args.len() == 2, "{}: embed wants 2 args", self.name);
                Ok(vec![embed(&args[0], &args[1])?])
            }
            InterpOp::Layer(geom) => {
                ensure!(args.len() >= 2, "{}: layer wants h + weights", self.name);
                Ok(vec![layer_forward(geom, &args[0], &args[1..])?])
            }
            InterpOp::Head { rms_eps } => {
                ensure!(args.len() == 3, "{}: head wants 3 args", self.name);
                Ok(vec![head_forward(&args[0], &args[1], &args[2], *rms_eps)?])
            }
            InterpOp::ClientFused { geom, ks, kd } => {
                ensure!(args.len() >= 3,
                        "{}: client_fused wants tokens + tok_emb + weights",
                        self.name);
                let h = embed(&args[0], &args[1])?;
                let h = layer_forward(geom, &h, &args[2..])?;
                let (b, s, d) = (h.shape[0], h.shape[1], h.shape[2]);
                ensure!(valid_block_axis(s, *ks) && valid_block_axis(d, *kd),
                        "{}: bad block {ks}x{kd} for {s}x{d}", self.name);
                let data = h.as_f32();
                let mut re_all = Vec::with_capacity(b * ks * kd);
                let mut im_all = Vec::with_capacity(b * ks * kd);
                for e in 0..b {
                    let a = &data[e * s * d..(e + 1) * s * d];
                    let (re, im) = fc_compress_naive(a, s, d, *ks, *kd);
                    re_all.extend_from_slice(&re);
                    im_all.extend_from_slice(&im);
                }
                Ok(vec![
                    Tensor::f32(vec![b, *ks, *kd], re_all),
                    Tensor::f32(vec![b, *ks, *kd], im_all),
                ])
            }
            InterpOp::ServerFused { geom, seq } => {
                ensure!(args.len() >= 4,
                        "{}: server_fused wants re/im + weights + head",
                        self.name);
                let (re, im) = (&args[0], &args[1]);
                ensure!(re.shape.len() == 3 && re.shape == im.shape,
                        "{}: re/im must be [B, ks, kd]", self.name);
                let (b, ks, kd) = (re.shape[0], re.shape[1], re.shape[2]);
                let final_norm = &args[args.len() - 2];
                let lm_head = &args[args.len() - 1];
                let d = final_norm.len();
                ensure!(valid_block_axis(*seq, ks) && valid_block_axis(d, kd),
                        "{}: bad block {ks}x{kd} for {seq}x{d}", self.name);
                let mut hdata = Vec::with_capacity(b * seq * d);
                for e in 0..b {
                    let rs = &re.as_f32()[e * ks * kd..(e + 1) * ks * kd];
                    let is = &im.as_f32()[e * ks * kd..(e + 1) * ks * kd];
                    hdata.extend_from_slice(&fc_decompress_naive(
                        rs, is, *seq, d, ks, kd));
                }
                let mut h = Tensor::f32(vec![b, *seq, d], hdata);
                let stacked = &args[2..args.len() - 2];
                let n_stack =
                    stacked.first().map(|t| t.shape[0]).unwrap_or(0);
                for t in stacked {
                    ensure!(!t.shape.is_empty() && t.shape[0] == n_stack,
                            "{}: ragged stacked weights", self.name);
                }
                for i in 0..n_stack {
                    let ws: Vec<Tensor> = stacked
                        .iter()
                        .map(|t| slice_leading(t, i))
                        .collect();
                    h = layer_forward(geom, &h, &ws)?;
                }
                Ok(vec![head_forward(&h, final_norm, lm_head, geom.rms_eps)?])
            }
            InterpOp::FcCompress { ks, kd } => {
                ensure!(args.len() == 1 && args[0].shape.len() == 2,
                        "{}: fc_compress wants one [S, D] arg", self.name);
                let (s, d) = (args[0].shape[0], args[0].shape[1]);
                ensure!(valid_block_axis(s, *ks) && valid_block_axis(d, *kd),
                        "{}: bad block {ks}x{kd} for {s}x{d}", self.name);
                let (re, im) = fc_compress_naive(args[0].as_f32(), s, d, *ks, *kd);
                Ok(vec![
                    Tensor::f32(vec![*ks, *kd], re),
                    Tensor::f32(vec![*ks, *kd], im),
                ])
            }
            InterpOp::FcDecompress { seq, hidden } => {
                ensure!(args.len() == 2 && args[0].shape.len() == 2
                        && args[0].shape == args[1].shape,
                        "{}: fc_decompress wants re/im [ks, kd]", self.name);
                let (ks, kd) = (args[0].shape[0], args[0].shape[1]);
                ensure!(valid_block_axis(*seq, ks) && valid_block_axis(*hidden, kd),
                        "{}: bad block {ks}x{kd} for {seq}x{hidden}", self.name);
                let a = fc_decompress_naive(args[0].as_f32(), args[1].as_f32(),
                                            *seq, *hidden, ks, kd);
                Ok(vec![Tensor::f32(vec![*seq, *hidden], a)])
            }
        }
    }
}

/// Extract sub-tensor `i` along a stacked tensor's leading axis.
fn slice_leading(t: &Tensor, i: usize) -> Tensor {
    let tail: Vec<usize> = t.shape[1..].to_vec();
    let n: usize = tail.iter().product();
    Tensor::f32(tail, t.as_f32()[i * n..(i + 1) * n].to_vec())
}

// ---------------------------------------------------------------------------
// transformer building blocks (mirrors python/compile/model.py)
// ---------------------------------------------------------------------------

/// `tokens [B, S] i32` + `tok_emb [V, D]` → `h [B, S, D]`.
pub fn embed(tokens: &Tensor, tok_emb: &Tensor) -> Result<Tensor> {
    ensure!(tokens.shape.len() == 2, "embed: tokens must be [B, S]");
    ensure!(tok_emb.shape.len() == 2, "embed: tok_emb must be [V, D]");
    let (b, s) = (tokens.shape[0], tokens.shape[1]);
    let (v, d) = (tok_emb.shape[0], tok_emb.shape[1]);
    let emb = tok_emb.as_f32();
    let mut out = Vec::with_capacity(b * s * d);
    for &t in tokens.as_i32() {
        ensure!(t >= 0 && (t as usize) < v, "embed: token {t} out of vocab {v}");
        let t = t as usize;
        out.extend_from_slice(&emb[t * d..(t + 1) * d]);
    }
    Ok(Tensor::f32(vec![b, s, d], out))
}

/// One transformer block over `h [B, S, D]`; weights in the canonical
/// manifest order (`ln1, wq, wk, wv, [bq, bk, bv,] wo, ln2, w_gate,
/// w_up, w_down`).
pub fn layer_forward(geom: &LayerGeom, h: &Tensor, weights: &[Tensor])
    -> Result<Tensor> {
    ensure!(h.shape.len() == 3, "layer: h must be [B, S, D]");
    let (b, s, d) = (h.shape[0], h.shape[1], h.shape[2]);
    ensure!(d % geom.n_heads == 0, "layer: d {d} % n_heads {}", geom.n_heads);
    let hd = d / geom.n_heads;
    ensure!(hd % 2 == 0, "layer: head_dim {hd} must be even for RoPE");
    let kv_dim = geom.n_kv_heads * hd;
    let lw = LayerWeights::parse(weights, geom.qkv_bias, d, kv_dim)?;
    let f = lw.d_ff;
    let (cos, sin) = rope_tables(s, hd, geom.rope_theta);
    let eps = geom.rms_eps;

    let mut out = h.as_f32().to_vec();
    let mut x = vec![0.0f32; s * d];
    for e in 0..b {
        let base = e * s * d;
        // attention sub-block
        for t in 0..s {
            rmsnorm_row(&out[base + t * d..base + (t + 1) * d], lw.ln1, eps,
                        &mut x[t * d..(t + 1) * d]);
        }
        let mut q = matmul(&x, s, d, lw.wq, d);
        let mut k = matmul(&x, s, d, lw.wk, kv_dim);
        let mut v = matmul(&x, s, d, lw.wv, kv_dim);
        if let (Some(bq), Some(bk), Some(bv)) = (lw.bq, lw.bk, lw.bv) {
            add_row_bias(&mut q, s, d, bq);
            add_row_bias(&mut k, s, kv_dim, bk);
            add_row_bias(&mut v, s, kv_dim, bv);
        }
        apply_rope(&mut q, s, geom.n_heads, hd, &cos, &sin);
        apply_rope(&mut k, s, geom.n_kv_heads, hd, &cos, &sin);

        let rep = geom.n_heads / geom.n_kv_heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut attn = vec![0.0f32; s * d];
        let mut probs = vec![0.0f32; s];
        for head in 0..geom.n_heads {
            let kvh = head / rep;
            for t in 0..s {
                let qrow = &q[t * d + head * hd..t * d + head * hd + hd];
                // causal logits over keys 0..=t, max-subtracted softmax
                let mut m = f32::MIN;
                for (j, p) in probs.iter_mut().enumerate().take(t + 1) {
                    let krow = &k[j * kv_dim + kvh * hd
                                  ..j * kv_dim + kvh * hd + hd];
                    let mut dot = 0.0f32;
                    for (a, bq_) in qrow.iter().zip(krow) {
                        dot += a * bq_;
                    }
                    let logit = dot * scale;
                    *p = logit;
                    m = m.max(logit);
                }
                let mut z = 0.0f32;
                for p in probs.iter_mut().take(t + 1) {
                    *p = (*p - m).exp();
                    z += *p;
                }
                let arow = &mut attn[t * d + head * hd..t * d + head * hd + hd];
                for (j, p) in probs.iter().enumerate().take(t + 1) {
                    let w = p / z;
                    let vrow = &v[j * kv_dim + kvh * hd
                                  ..j * kv_dim + kvh * hd + hd];
                    for (acc, vv) in arow.iter_mut().zip(vrow) {
                        *acc += w * vv;
                    }
                }
            }
        }
        let proj = matmul(&attn, s, d, lw.wo, d);
        for (o, p) in out[base..base + s * d].iter_mut().zip(&proj) {
            *o += p;
        }

        // MLP sub-block
        for t in 0..s {
            rmsnorm_row(&out[base + t * d..base + (t + 1) * d], lw.ln2, eps,
                        &mut x[t * d..(t + 1) * d]);
        }
        let gate = matmul(&x, s, d, lw.w_gate, f);
        let up = matmul(&x, s, d, lw.w_up, f);
        let mut act = vec![0.0f32; s * f];
        for (a, (g, u)) in act.iter_mut().zip(gate.iter().zip(&up)) {
            *a = silu(*g) * u;
        }
        let down = matmul(&act, s, f, lw.w_down, d);
        for (o, p) in out[base..base + s * d].iter_mut().zip(&down) {
            *o += p;
        }
    }
    Ok(Tensor::f32(vec![b, s, d], out))
}

/// `h [B, S, D]` + `final_norm [D]` + `lm_head [D, V]` → logits
/// `[B, S, V]`.
pub fn head_forward(h: &Tensor, final_norm: &Tensor, lm_head: &Tensor,
                    rms_eps: f32) -> Result<Tensor> {
    ensure!(h.shape.len() == 3, "head: h must be [B, S, D]");
    let (b, s, d) = (h.shape[0], h.shape[1], h.shape[2]);
    ensure!(final_norm.len() == d, "head: final_norm len != D");
    ensure!(lm_head.shape.len() == 2 && lm_head.shape[0] == d,
            "head: lm_head must be [D, V]");
    let v = lm_head.shape[1];
    let rows = b * s;
    let mut x = vec![0.0f32; rows * d];
    let data = h.as_f32();
    for t in 0..rows {
        rmsnorm_row(&data[t * d..(t + 1) * d], final_norm.as_f32(), rms_eps,
                    &mut x[t * d..(t + 1) * d]);
    }
    let logits = matmul(&x, rows, d, lm_head.as_f32(), v);
    Ok(Tensor::f32(vec![b, s, v], logits))
}

struct LayerWeights<'a> {
    ln1: &'a [f32],
    wq: &'a [f32],
    wk: &'a [f32],
    wv: &'a [f32],
    bq: Option<&'a [f32]>,
    bk: Option<&'a [f32]>,
    bv: Option<&'a [f32]>,
    wo: &'a [f32],
    ln2: &'a [f32],
    w_gate: &'a [f32],
    w_up: &'a [f32],
    w_down: &'a [f32],
    d_ff: usize,
}

impl<'a> LayerWeights<'a> {
    fn parse(args: &'a [Tensor], qkv_bias: bool, d: usize, kv_dim: usize)
        -> Result<LayerWeights<'a>> {
        let need = if qkv_bias { 12 } else { 9 };
        ensure!(args.len() == need,
                "layer: got {} weights, canonical order needs {need}",
                args.len());
        let shape_ok = |t: &Tensor, want: &[usize]| t.shape == want;
        let off = if qkv_bias { 3 } else { 0 };
        let (ln1, wq, wk, wv) = (&args[0], &args[1], &args[2], &args[3]);
        let (wo, ln2) = (&args[4 + off], &args[5 + off]);
        let (w_gate, w_up, w_down) =
            (&args[6 + off], &args[7 + off], &args[8 + off]);
        ensure!(shape_ok(ln1, &[d]) && shape_ok(wq, &[d, d])
                && shape_ok(wk, &[d, kv_dim]) && shape_ok(wv, &[d, kv_dim])
                && shape_ok(wo, &[d, d]) && shape_ok(ln2, &[d]),
                "layer: attention weight shapes inconsistent with d={d}, \
                 kv={kv_dim}");
        ensure!(w_gate.shape.len() == 2 && w_gate.shape[0] == d
                && w_up.shape == w_gate.shape,
                "layer: w_gate/w_up must be [D, F]");
        let d_ff = w_gate.shape[1];
        ensure!(shape_ok(w_down, &[d_ff, d]), "layer: w_down must be [F, D]");
        let (bq, bk, bv) = if qkv_bias {
            ensure!(shape_ok(&args[4], &[d]) && shape_ok(&args[5], &[kv_dim])
                    && shape_ok(&args[6], &[kv_dim]),
                    "layer: qkv bias shapes inconsistent");
            (Some(args[4].as_f32()), Some(args[5].as_f32()),
             Some(args[6].as_f32()))
        } else {
            (None, None, None)
        };
        Ok(LayerWeights {
            ln1: ln1.as_f32(),
            wq: wq.as_f32(),
            wk: wk.as_f32(),
            wv: wv.as_f32(),
            bq, bk, bv,
            wo: wo.as_f32(),
            ln2: ln2.as_f32(),
            w_gate: w_gate.as_f32(),
            w_up: w_up.as_f32(),
            w_down: w_down.as_f32(),
            d_ff,
        })
    }
}

/// RMSNorm of one row: `(x / sqrt(mean(x²) + eps)) * w`.
pub fn rmsnorm_row(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    let mut ms = 0.0f64;
    for &v in x {
        ms += (v as f64) * (v as f64);
    }
    let inv = 1.0 / ((ms / x.len().max(1) as f64) as f32 + eps).sqrt();
    for ((o, &v), &g) in out.iter_mut().zip(x).zip(w) {
        *o = v * inv * g;
    }
}

/// Naive row-major `[m, k] × [k, n]` matmul (f32 accumulate — forged
/// models are tiny, parity is with jnp's f32 math anyway).
fn matmul(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let orow = &mut out[i * n..(i + 1) * n];
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

fn add_row_bias(x: &mut [f32], rows: usize, cols: usize, bias: &[f32]) {
    for r in 0..rows {
        for (v, &b) in x[r * cols..(r + 1) * cols].iter_mut().zip(bias) {
            *v += b;
        }
    }
}

fn silu(z: f32) -> f32 {
    z / (1.0 + (-z).exp())
}

/// cos/sin tables `[S, hd/2]` — same pairing as python `rope_tables`.
fn rope_tables(s: usize, hd: usize, theta: f64) -> (Vec<f32>, Vec<f32>) {
    let half = hd / 2;
    let mut cos = Vec::with_capacity(s * half);
    let mut sin = Vec::with_capacity(s * half);
    for t in 0..s {
        for j in 0..half {
            let inv = 1.0 / theta.powf((2 * j) as f64 / hd as f64);
            let ang = t as f64 * inv;
            cos.push(ang.cos() as f32);
            sin.push(ang.sin() as f32);
        }
    }
    (cos, sin)
}

/// Rotate (x[2j], x[2j+1]) pairs per head — python `apply_rope`.
fn apply_rope(x: &mut [f32], s: usize, n_heads: usize, hd: usize,
              cos: &[f32], sin: &[f32]) {
    let half = hd / 2;
    let stride = n_heads * hd;
    for t in 0..s {
        for head in 0..n_heads {
            for j in 0..half {
                let i0 = t * stride + head * hd + 2 * j;
                let (x1, x2) = (x[i0], x[i0 + 1]);
                let (c, sn) = (cos[t * half + j], sin[t * half + j]);
                x[i0] = x1 * c - x2 * sn;
                x[i0 + 1] = x1 * sn + x2 * c;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// FourierCompress naive reference (mirrors kernels/ref.py)
// ---------------------------------------------------------------------------

/// `A [S, D]` → full `(re, im) [ks, kd]` centred block via a full 2-D
/// FFT — the naive reference the optimised codec in `codec::fourier`
/// is checked against.
pub fn fc_compress_naive(a: &[f32], s: usize, d: usize, ks: usize, kd: usize)
    -> (Vec<f32>, Vec<f32>) {
    assert_eq!(a.len(), s * d, "fc_compress_naive: shape mismatch");
    let spec = fft2d::fft2_real(MatView::new(a, s, d));
    let ui = centered_indices(s, ks);
    let vi = centered_indices(d, kd);
    let mut re = Vec::with_capacity(ks * kd);
    let mut im = Vec::with_capacity(ks * kd);
    for &u in &ui {
        for &v in &vi {
            let c = spec[u * d + v];
            re.push(c.re as f32);
            im.push(c.im as f32);
        }
    }
    (re, im)
}

/// `(re, im) [ks, kd]` → `A' [S, D]`: scatter the centred block into a
/// zero spectrum, inverse FFT, take the real part.
pub fn fc_decompress_naive(re: &[f32], im: &[f32], s: usize, d: usize,
                           ks: usize, kd: usize) -> Vec<f32> {
    assert_eq!(re.len(), ks * kd, "fc_decompress_naive: re shape mismatch");
    assert_eq!(im.len(), ks * kd, "fc_decompress_naive: im shape mismatch");
    let ui = centered_indices(s, ks);
    let vi = centered_indices(d, kd);
    let mut spec = vec![C64::ZERO; s * d];
    for (i, &u) in ui.iter().enumerate() {
        for (j, &v) in vi.iter().enumerate() {
            spec[u * d + v] = C64::new(re[i * kd + j] as f64,
                                       im[i * kd + j] as f64);
        }
    }
    fft2d::ifft2(&mut spec, s, d);
    spec.iter().map(|c| c.re as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::rel_error;
    use crate::util::rng::Rng;

    fn geom() -> LayerGeom {
        LayerGeom { n_heads: 2, n_kv_heads: 2, rope_theta: 10000.0,
                    rms_eps: 1e-5, qkv_bias: false }
    }

    fn rand_tensor(rng: &mut Rng, shape: Vec<usize>, scale: f32) -> Tensor {
        let n: usize = shape.iter().product();
        let mut v = vec![0.0f32; n];
        rng.fill_normal_f32(&mut v, scale);
        Tensor::f32(shape, v)
    }

    fn layer_weights(rng: &mut Rng, d: usize, kv: usize, f: usize,
                     bias: bool) -> Vec<Tensor> {
        let s = 1.0 / (d as f32).sqrt();
        let mut w = vec![
            Tensor::f32(vec![d], vec![1.0; d]),
            rand_tensor(rng, vec![d, d], s),
            rand_tensor(rng, vec![d, kv], s),
            rand_tensor(rng, vec![d, kv], s),
        ];
        if bias {
            w.push(rand_tensor(rng, vec![d], 0.05));
            w.push(rand_tensor(rng, vec![kv], 0.05));
            w.push(rand_tensor(rng, vec![kv], 0.05));
        }
        w.push(rand_tensor(rng, vec![d, d], s));
        w.push(Tensor::f32(vec![d], vec![1.0; d]));
        w.push(rand_tensor(rng, vec![d, f], s));
        w.push(rand_tensor(rng, vec![d, f], s));
        w.push(rand_tensor(rng, vec![f, d], s));
        w
    }

    #[test]
    fn embed_gathers_rows() {
        let emb = Tensor::f32(vec![4, 2],
                              vec![0., 1., 10., 11., 20., 21., 30., 31.]);
        let toks = Tensor::i32(vec![1, 3], vec![2, 0, 3]);
        let h = embed(&toks, &emb).unwrap();
        assert_eq!(h.shape, vec![1, 3, 2]);
        assert_eq!(h.as_f32(), &[20., 21., 0., 1., 30., 31.]);
        // out-of-vocab is an error, not UB
        assert!(embed(&Tensor::i32(vec![1, 1], vec![9]), &emb).is_err());
    }

    #[test]
    fn layer_preserves_shape_and_is_causal() {
        let (d, kv, f, s) = (8usize, 8usize, 16usize, 6usize);
        let mut rng = Rng::new(1);
        let w = layer_weights(&mut rng, d, kv, f, false);
        let h = rand_tensor(&mut rng, vec![1, s, d], 1.0);
        let out = layer_forward(&geom(), &h, &w).unwrap();
        assert_eq!(out.shape, vec![1, s, d]);
        // causality: perturbing a late token must not change early rows
        let mut h2 = h.clone();
        h2.as_f32_mut()[(s - 1) * d] += 3.0;
        let out2 = layer_forward(&geom(), &h2, &w).unwrap();
        for t in 0..s - 1 {
            for c in 0..d {
                assert_eq!(out.as_f32()[t * d + c], out2.as_f32()[t * d + c],
                           "row {t} changed by a future token");
            }
        }
    }

    #[test]
    fn gqa_and_bias_paths_run() {
        let (d, f, s) = (8usize, 16usize, 5usize);
        let g = LayerGeom { n_heads: 4, n_kv_heads: 2, rope_theta: 10000.0,
                            rms_eps: 1e-5, qkv_bias: true };
        let kv = g.n_kv_heads * (d / g.n_heads);
        let mut rng = Rng::new(2);
        let w = layer_weights(&mut rng, d, kv, f, true);
        let h = rand_tensor(&mut rng, vec![2, s, d], 1.0);
        let out = layer_forward(&g, &h, &w).unwrap();
        assert_eq!(out.shape, vec![2, s, d]);
        assert!(out.as_f32().iter().all(|v| v.is_finite()));
        // wrong weight count is rejected
        assert!(layer_forward(&geom(), &h, &w[..8]).is_err());
    }

    #[test]
    fn batch_elements_are_independent() {
        let (d, f, s) = (8usize, 16usize, 4usize);
        let mut rng = Rng::new(3);
        let w = layer_weights(&mut rng, d, d, f, false);
        let a = rand_tensor(&mut rng, vec![1, s, d], 1.0);
        let b = rand_tensor(&mut rng, vec![1, s, d], 1.0);
        let mut both = a.as_f32().to_vec();
        both.extend_from_slice(b.as_f32());
        let batched =
            layer_forward(&geom(), &Tensor::f32(vec![2, s, d], both), &w)
                .unwrap();
        let oa = layer_forward(&geom(), &a, &w).unwrap();
        let ob = layer_forward(&geom(), &b, &w).unwrap();
        assert_eq!(&batched.as_f32()[..s * d], oa.as_f32());
        assert_eq!(&batched.as_f32()[s * d..], ob.as_f32());
    }

    #[test]
    fn head_shapes_and_norm() {
        let (d, v) = (4usize, 10usize);
        let mut rng = Rng::new(4);
        let h = rand_tensor(&mut rng, vec![1, 2, d], 1.0);
        let fnorm = Tensor::f32(vec![d], vec![1.0; d]);
        let lm = rand_tensor(&mut rng, vec![d, v], 0.5);
        let logits = head_forward(&h, &fnorm, &lm, 1e-5).unwrap();
        assert_eq!(logits.shape, vec![1, 2, v]);
    }

    #[test]
    fn fc_naive_roundtrip_exact_for_bandlimited() {
        // signal synthesised inside the kept band → exact recovery
        let (s, d, ks, kd) = (8usize, 16usize, 5usize, 7usize);
        let mut rng = Rng::new(5);
        let mut a = vec![0.0f32; s * d];
        // band-limited along the hidden axis only (bins < (kd+1)/2)
        for bin in 0..(kd + 1) / 2 {
            let amp = rng.normal() as f32;
            for r in 0..s {
                for c in 0..d {
                    let ang = 2.0 * std::f32::consts::PI * bin as f32 * c as f32
                        / d as f32;
                    a[r * d + c] += amp * ang.cos();
                }
            }
        }
        let (re, im) = fc_compress_naive(&a, s, d, s, kd);
        let back = fc_decompress_naive(&re, &im, s, d, s, kd);
        assert!(rel_error(&a, &back) < 1e-5);
        // and a strict (ks < s) block stays finite + deterministic
        let (re2, im2) = fc_compress_naive(&a, s, d, ks, kd);
        let (re3, im3) = fc_compress_naive(&a, s, d, ks, kd);
        assert_eq!(re2, re3);
        assert_eq!(im2, im3);
    }

    #[test]
    fn fused_graphs_match_composable_pipeline() {
        // client_fused + server_fused == embed → layer → naive codec
        // round-trip → layer → head, the defining identity of the
        // serving artifacts.
        let (d, f, s, v) = (8usize, 16usize, 8usize, 12usize);
        let (ks, kd) = (5usize, 5usize);
        let mut rng = Rng::new(6);
        let w0 = layer_weights(&mut rng, d, d, f, false);
        let w1 = layer_weights(&mut rng, d, d, f, false);
        let emb = rand_tensor(&mut rng, vec![v, d], 0.1);
        let fnorm = Tensor::f32(vec![d], vec![1.0; d]);
        let lm = rand_tensor(&mut rng, vec![d, v], 0.5);
        let toks = Tensor::i32(vec![1, s], (0..s as i32).collect());

        // composable path
        let h = embed(&toks, &emb).unwrap();
        let h = layer_forward(&geom(), &h, &w0).unwrap();
        let (re, im) = fc_compress_naive(&h.as_f32()[..s * d], s, d, ks, kd);
        let hprime = Tensor::f32(vec![1, s, d],
                                 fc_decompress_naive(&re, &im, s, d, ks, kd));
        let hprime = layer_forward(&geom(), &hprime, &w1).unwrap();
        let want = head_forward(&hprime, &fnorm, &lm, 1e-5).unwrap();

        // fused path through InterpExec
        let mut spec = Json::obj();
        spec.set("op", Json::Str("client_fused".into()));
        spec.set("n_heads", Json::Num(2.0));
        spec.set("ks", Json::Num(ks as f64));
        spec.set("kd", Json::Num(kd as f64));
        let client = InterpExec::from_spec("client", &spec).unwrap();
        let mut cargs = vec![toks.clone(), emb.clone()];
        cargs.extend(w0.iter().cloned());
        let cout = client.run(&cargs).unwrap();
        assert_eq!(cout[0].shape, vec![1, ks, kd]);
        assert_eq!(cout[0].as_f32(), &re[..]);
        assert_eq!(cout[1].as_f32(), &im[..]);

        let mut sspec = Json::obj();
        sspec.set("op", Json::Str("server_fused".into()));
        sspec.set("n_heads", Json::Num(2.0));
        sspec.set("seq", Json::Num(s as f64));
        let server = InterpExec::from_spec("server", &sspec).unwrap();
        // stack the single server layer along a new leading axis
        let mut sargs = vec![cout[0].clone(), cout[1].clone()];
        for t in &w1 {
            let mut shape = vec![1usize];
            shape.extend_from_slice(&t.shape);
            sargs.push(Tensor::f32(shape, t.as_f32().to_vec()));
        }
        sargs.push(fnorm.clone());
        sargs.push(lm.clone());
        let sout = server.run(&sargs).unwrap();
        assert_eq!(sout[0].shape, vec![1, s, v]);
        assert_eq!(sout[0].as_f32(), want.as_f32());
    }

    #[test]
    fn spec_parsing_rejects_garbage() {
        let mut bad = Json::obj();
        bad.set("op", Json::Str("warp_drive".into()));
        assert!(InterpExec::from_spec("x", &bad).is_err());
        let mut no_heads = Json::obj();
        no_heads.set("op", Json::Str("layer".into()));
        assert!(InterpExec::from_spec("x", &no_heads).is_err());
        let mut ok = Json::obj();
        ok.set("op", Json::Str("embed".into()));
        assert!(InterpExec::from_spec("x", &ok).is_ok());
    }
}
