//! The real PJRT backend (feature `xla`): compiles HLO text through
//! the xla_extension bindings and executes on the CPU client.

use crate::tensor::{Tensor, TensorData};
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// The PJRT client.  One per process; executables keep it alive via Arc.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(wrap)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(wrap)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path.file_name().unwrap_or_default().to_string_lossy().into(),
        })
    }

    /// Interpreted artifacts (manifest `interp` specs) are a host-side
    /// testing facility; the compiled backend refuses them so a forged
    /// tree can never silently shadow a real deployment.
    pub fn load_interp(&self, name: &str,
                       _spec: &crate::util::json::Json) -> Result<Executable> {
        bail!("artifact {name}: interp specs are not supported on the pjrt \
               backend (compile the HLO artifact instead)")
    }
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

/// A compiled artifact.
///
/// SAFETY: the PJRT CPU client is internally synchronised and the
/// executable objects are immutable after compilation; the coordinator
/// shares them across worker threads behind `Arc`.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Executable {
    /// Compiled artifacts are never interpreter-backed (parity with
    /// the stub backend's surface, which tests probe).
    pub fn is_interpreted(&self) -> bool {
        false
    }

    /// Execute with host tensors; returns the flattened output tuple.
    pub fn run(&self, args: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> =
            args.iter().map(tensor_to_literal).collect::<Result<_>>()?;
        self.run_literals(&literals)
    }

    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<Tensor>> {
        let result = self
            .exe
            .execute::<xla::Literal>(literals)
            .map_err(wrap)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("{}: empty result", self.name))?
            .to_literal_sync()
            .map_err(wrap)?;
        // python lowers with return_tuple=True
        let parts = lit.to_tuple().map_err(wrap)?;
        parts.iter().map(literal_to_tensor).collect()
    }
}

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims = t.dims_i64();
    let lit = match &t.data {
        TensorData::F32(v) => xla::Literal::vec1(v.as_slice()),
        TensorData::I32(v) => xla::Literal::vec1(v.as_slice()),
    };
    lit.reshape(&dims).map_err(wrap)
}

pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(wrap)?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            Ok(Tensor::f32(dims, lit.to_vec::<f32>().map_err(wrap)?))
        }
        xla::ElementType::S32 => {
            Ok(Tensor::i32(dims, lit.to_vec::<i32>().map_err(wrap)?))
        }
        other => bail!("unsupported output element type {other:?}"),
    }
}
