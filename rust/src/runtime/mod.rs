//! Runtime: loads the AOT HLO-text artifacts the python build step
//! produced (compiled once on the CPU PJRT client and executed from
//! the request path), or — hermetically — interprets them with the
//! pure-Rust reference interpreter.
//!
//! Two backends share one surface (`Runtime` / `Executable`):
//!
//! * `pjrt` (feature `xla`) — the real PJRT client.  Interchange is
//!   HLO *text* (python lowered with return_tuple=True, so every
//!   output is a tuple) — see /opt/xla-example/README.md for why
//!   serialized protos are rejected by xla_extension 0.5.1.
//! * `stub` (default) — a hermetic no-accelerator build.  Compiled
//!   artifacts are unavailable, but the backend can build
//!   **interpreted** executables from manifest `interp` specs (see
//!   [`interp`]), which `ArtifactStore::get` selects transparently
//!   whenever an artifact's HLO file does not exist.  With a
//!   `testkit`-forged tree this makes the entire split-inference
//!   stack — embed/layer/head, the fused client/server graphs, the
//!   TCP coordinator — executable from a bare `cargo test`.

pub mod interp;
pub mod store;

// The `xla` feature only declares intent: the xla_extension bindings
// are not in the hermetic dependency set.  `pjrt` is additionally
// gated on the hand-set `xla_runtime_wired` cfg (declared in
// Cargo.toml's [lints.rust] check-cfg) so that `--features xla`
// without the dependency produces exactly one actionable error
// instead of an unresolved-import cascade from pjrt.rs.
#[cfg(all(feature = "xla", not(xla_runtime_wired)))]
compile_error!(
    "feature `xla` requires the xla_extension bindings: add the `xla` crate \
     to [dependencies] in rust/Cargo.toml and build with \
     RUSTFLAGS=\"--cfg xla_runtime_wired\" (see rust/README.md)"
);

#[cfg(all(feature = "xla", xla_runtime_wired))]
mod pjrt;
#[cfg(all(feature = "xla", xla_runtime_wired))]
pub use pjrt::{literal_to_tensor, tensor_to_literal, Executable, Runtime};

#[cfg(not(all(feature = "xla", xla_runtime_wired)))]
mod stub;
#[cfg(not(all(feature = "xla", xla_runtime_wired)))]
pub use stub::{Executable, Runtime};

pub use store::ArtifactStore;
