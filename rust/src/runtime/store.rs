//! Artifact store: lazy-compiling, caching registry over the
//! `artifacts/` directory + manifest.  One store per process; all
//! executables are shared via Arc (compilation happens once per
//! artifact regardless of how many threads request it).

use super::{Executable, Runtime};
use crate::util::json::{self, Json};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

pub struct ArtifactStore {
    pub runtime: Arc<Runtime>,
    pub root: PathBuf,
    pub manifest: Json,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl ArtifactStore {
    pub fn open(root: impl Into<PathBuf>) -> Result<ArtifactStore> {
        let root = root.into();
        let man_path = root.join("manifest.json");
        let text = std::fs::read_to_string(&man_path)
            .with_context(|| format!("reading {} (run `make artifacts`)",
                                     man_path.display()))?;
        let manifest = json::parse(&text)?;
        Ok(ArtifactStore {
            runtime: Arc::new(Runtime::cpu()?),
            root,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Get (compiling if needed) the artifact with the given hlo file
    /// name (relative to `artifacts/hlo/`).
    pub fn get(&self, hlo_name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(hlo_name) {
            return Ok(e.clone());
        }
        let path = self.root.join("hlo").join(hlo_name);
        let exe = Arc::new(self.runtime.load_hlo_text(&path)?);
        self.cache
            .lock()
            .unwrap()
            .insert(hlo_name.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    pub fn model_names(&self) -> Vec<String> {
        self.manifest
            .get("models")
            .and_then(|m| m.as_obj())
            .map(|o| o.iter().map(|(k, _)| k.clone()).collect())
            .unwrap_or_default()
    }

    pub fn model_meta(&self, name: &str) -> Result<&Json> {
        self.manifest
            .path(&format!("models.{name}"))
            .ok_or_else(|| anyhow!("model '{name}' not in manifest"))
    }

    pub fn dataset_names(&self) -> Vec<String> {
        self.manifest
            .get("datasets")
            .and_then(|m| m.as_obj())
            .map(|o| o.iter().map(|(k, _)| k.clone()).collect())
            .unwrap_or_default()
    }
}
