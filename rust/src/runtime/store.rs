//! Artifact store: lazy-compiling, caching registry over the
//! `artifacts/` directory + manifest.  One store per process; all
//! executables are shared via Arc (compilation happens once per
//! artifact regardless of how many threads request it).
//!
//! Backend selection per artifact: a compiled HLO file under
//! `artifacts/hlo/` always wins; when the file does not exist and the
//! manifest carries an `interp` spec for the name (forged trees —
//! `testkit`), the runtime builds a reference-interpreter executable
//! instead, so callers never know which backend served them.

use super::{Executable, Runtime};
use crate::util::json::{self, Json};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

pub struct ArtifactStore {
    pub runtime: Arc<Runtime>,
    pub root: PathBuf,
    pub manifest: Json,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl ArtifactStore {
    pub fn open(root: impl Into<PathBuf>) -> Result<ArtifactStore> {
        let root = root.into();
        let man_path = root.join("manifest.json");
        let text = std::fs::read_to_string(&man_path)
            .with_context(|| format!("reading {} (run `make artifacts`)",
                                     man_path.display()))?;
        let manifest = json::parse(&text)?;
        Ok(ArtifactStore {
            runtime: Arc::new(Runtime::cpu()?),
            root,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Get (compiling if needed) the artifact with the given hlo file
    /// name (relative to `artifacts/hlo/`).  Falls back to the
    /// reference interpreter when the HLO file is absent but the
    /// manifest carries an `interp` spec for the name.
    pub fn get(&self, hlo_name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(hlo_name) {
            return Ok(e.clone());
        }
        let path = self.root.join("hlo").join(hlo_name);
        let exe = if !path.exists() {
            if let Some(spec) = self.interp_spec(hlo_name) {
                Arc::new(self.runtime.load_interp(hlo_name, spec)?)
            } else {
                // keep the compiled backend's "cannot load" diagnostic
                Arc::new(self.runtime.load_hlo_text(&path)?)
            }
        } else {
            Arc::new(self.runtime.load_hlo_text(&path)?)
        };
        self.cache
            .lock()
            .unwrap()
            .insert(hlo_name.to_string(), exe.clone());
        Ok(exe)
    }

    /// The manifest's `interp` spec for an artifact name, if any
    /// (artifact names contain dots, so this is a flat key lookup, not
    /// a `Json::path`).
    pub fn interp_spec(&self, hlo_name: &str) -> Option<&Json> {
        self.manifest.get("interp").and_then(|m| m.get(hlo_name))
    }

    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    pub fn model_names(&self) -> Vec<String> {
        self.manifest
            .get("models")
            .and_then(|m| m.as_obj())
            .map(|o| o.iter().map(|(k, _)| k.clone()).collect())
            .unwrap_or_default()
    }

    pub fn model_meta(&self, name: &str) -> Result<&Json> {
        self.manifest
            .path(&format!("models.{name}"))
            .ok_or_else(|| anyhow!("model '{name}' not in manifest"))
    }

    pub fn dataset_names(&self) -> Vec<String> {
        self.manifest
            .get("datasets")
            .and_then(|m| m.as_obj())
            .map(|o| o.iter().map(|(k, _)| k.clone()).collect())
            .unwrap_or_default()
    }
}
