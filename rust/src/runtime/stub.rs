//! Hermetic no-accelerator backend (default build): the container that
//! runs tier-1 tests has no XLA toolchain, so `Runtime::cpu()` always
//! succeeds and executables come in two flavours:
//!
//! * **interpreted** — built by [`Runtime::load_interp`] from a
//!   manifest `interp` spec (see [`super::interp`]); `run` executes
//!   the pure-Rust reference interpreter.  This is how forged artifact
//!   trees (`testkit`) make the full split-inference stack executable
//!   from a bare `cargo test`.
//! * **unavailable** — anything that would need a compiled HLO
//!   artifact; loading or executing fails with an actionable message.

use super::interp::InterpExec;
use crate::tensor::Tensor;
use crate::util::json::Json;
use anyhow::{bail, Result};
use std::path::Path;

const UNAVAILABLE: &str =
    "xla runtime unavailable in this build (enable the `xla` feature and \
     wire the xla_extension dependency)";

pub struct Runtime {
    _priv: (),
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { _priv: () })
    }

    pub fn platform(&self) -> String {
        "stub (no xla feature; interp-capable)".to_string()
    }

    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        bail!("{UNAVAILABLE}: cannot load {}", path.as_ref().display())
    }

    /// Build an interpreted executable from a manifest `interp` spec.
    pub fn load_interp(&self, name: &str, spec: &Json) -> Result<Executable> {
        Ok(Executable {
            name: name.to_string(),
            interp: Some(InterpExec::from_spec(name, spec)?),
        })
    }
}

/// A runnable artifact: either an interpreted executable (forged
/// trees) or a placeholder that reports the missing XLA toolchain.
#[derive(Debug)]
pub struct Executable {
    pub name: String,
    interp: Option<InterpExec>,
}

impl Executable {
    pub fn run(&self, args: &[Tensor]) -> Result<Vec<Tensor>> {
        match &self.interp {
            Some(ix) => ix.run(args),
            None => bail!("{UNAVAILABLE}: cannot execute {}", self.name),
        }
    }

    /// Whether this executable is backed by the reference interpreter
    /// (vs a compiled artifact — always true for runnable stubs).
    pub fn is_interpreted(&self) -> bool {
        self.interp.is_some()
    }
}
