//! Hermetic no-accelerator backend (default build): the container that
//! runs tier-1 tests has no XLA toolchain, so `Runtime::cpu()`
//! succeeds (letting `ArtifactStore` and config plumbing construct)
//! but any attempt to load or execute an artifact fails with an
//! actionable message.  Tests that need artifacts already skip when
//! `artifacts/manifest.json` is absent, so this backend never fires in
//! the tier-1 path.

use crate::tensor::Tensor;
use anyhow::{bail, Result};
use std::path::Path;

const UNAVAILABLE: &str =
    "xla runtime unavailable in this build (enable the `xla` feature and \
     wire the xla_extension dependency)";

pub struct Runtime {
    _priv: (),
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { _priv: () })
    }

    pub fn platform(&self) -> String {
        "stub (no xla feature)".to_string()
    }

    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        bail!("{UNAVAILABLE}: cannot load {}", path.as_ref().display())
    }
}

/// A compiled artifact (stub: cannot be constructed through the public
/// API because `load_hlo_text` always errors first).
pub struct Executable {
    pub name: String,
}

impl Executable {
    pub fn run(&self, _args: &[Tensor]) -> Result<Vec<Tensor>> {
        bail!("{UNAVAILABLE}: cannot execute {}", self.name)
    }
}
