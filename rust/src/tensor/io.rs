//! `.fcw` reader/writer — must stay byte-compatible with
//! python/compile/tensor_io.py (magic "FCW1", little-endian).

use super::{Tensor, TensorData};
use anyhow::{bail, ensure, Result, Context};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"FCW1";

pub fn read_fcw(path: impl AsRef<Path>) -> Result<BTreeMap<String, Tensor>> {
    let bytes = std::fs::read(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    read_fcw_bytes(&bytes)
}

pub fn read_fcw_bytes(bytes: &[u8]) -> Result<BTreeMap<String, Tensor>> {
    let mut cur = std::io::Cursor::new(bytes);
    let mut magic = [0u8; 4];
    cur.read_exact(&mut magic)?;
    ensure!(&magic == MAGIC, "bad .fcw magic {:?}", magic);
    let n = read_u32(&mut cur)?;
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let name_len = read_u16(&mut cur)? as usize;
        let mut name = vec![0u8; name_len];
        cur.read_exact(&mut name)?;
        let name = String::from_utf8(name)?;
        let mut hdr = [0u8; 2];
        cur.read_exact(&mut hdr)?;
        let (dtype, ndim) = (hdr[0], hdr[1] as usize);
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut cur)? as usize);
        }
        let count: usize = shape.iter().product();
        let mut buf = vec![0u8; count * 4];
        cur.read_exact(&mut buf)?;
        let data = match dtype {
            0 => TensorData::F32(
                buf.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            1 => TensorData::I32(
                buf.chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            d => bail!("unknown dtype id {d}"),
        };
        out.insert(name, Tensor { shape, data });
    }
    Ok(out)
}

pub fn write_fcw(path: impl AsRef<Path>,
                 tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        f.write_all(&(name.len() as u16).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        let dtype = match t.data {
            TensorData::F32(_) => 0u8,
            TensorData::I32(_) => 1u8,
        };
        f.write_all(&[dtype, t.shape.len() as u8])?;
        for &d in &t.shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        match &t.data {
            TensorData::F32(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            TensorData::I32(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u16(r: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("a".into(), Tensor::f32(vec![2, 3], (0..6).map(|i| i as f32).collect()));
        m.insert("b.i32".into(), Tensor::i32(vec![4], vec![-1, 0, 7, 1 << 20]));
        m.insert("scalar".into(), Tensor::f32(vec![], vec![3.5]));
        let dir = std::env::temp_dir().join("fcw_test_roundtrip.fcw");
        write_fcw(&dir, &m).unwrap();
        let back = read_fcw(&dir).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(read_fcw_bytes(b"NOPE\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut m = BTreeMap::new();
        m.insert("a".into(), Tensor::f32(vec![8], vec![1.0; 8]));
        let path = std::env::temp_dir().join("fcw_test_trunc.fcw");
        write_fcw(&path, &m).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(read_fcw_bytes(&bytes[..bytes.len() - 5]).is_err());
    }
}
