//! Host tensors + `.fcw` container IO (the python↔rust interchange,
//! format defined in python/compile/tensor_io.py).

pub mod io;
pub mod view;

pub use view::{MatView, MatViewMut};

use std::fmt;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host-side dense tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::I32(data) }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::f32(shape, vec![0.0; n])
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            _ => panic!("tensor is not i32"),
        }
    }

    /// The trailing-2-D matrix view of an f32 tensor: `[R, C]` maps
    /// directly, `[B, S, D]` flattens the leading axes into rows.
    /// Panics on rank < 2 or non-f32 data.
    pub fn mat_view(&self) -> MatView<'_> {
        assert!(self.shape.len() >= 2, "mat_view needs rank >= 2");
        let cols = *self.shape.last().unwrap();
        let rows = self.shape[..self.shape.len() - 1].iter().product();
        MatView::new(self.as_f32(), rows, cols)
    }

    /// Dims as i64 (what the xla crate's literal APIs want).
    pub fn dims_i64(&self) -> Vec<i64> {
        self.shape.iter().map(|&d| d as i64).collect()
    }

    /// Max |a - b| over two f32 tensors of identical shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.as_f32()
            .iter()
            .zip(other.as_f32())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl fmt::Display for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}<{:?}>", self.shape, self.dtype())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.dims_i64(), vec![2, 3]);
        assert_eq!(t.as_f32()[4], 5.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::f32(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn max_abs_diff() {
        let a = Tensor::f32(vec![3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::f32(vec![3], vec![1.0, 2.5, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
