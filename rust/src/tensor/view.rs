//! Borrowed row-major matrix views — the crate-wide replacement for
//! the loose `(&[f32], rows, cols)` triplets that used to flow between
//! the codecs, the DSP layer, and the coordinator.  A [`MatView`] is
//! `Copy` and carries its shape, so a shape mismatch is caught at the
//! construction site instead of deep inside a transform.

use std::fmt;

/// An immutable row-major `rows × cols` f32 matrix view.
#[derive(Clone, Copy)]
pub struct MatView<'a> {
    data: &'a [f32],
    rows: usize,
    cols: usize,
}

impl<'a> MatView<'a> {
    /// Wrap `data` as a `rows × cols` matrix.  Panics on shape
    /// mismatch (use [`MatView::try_new`] for fallible callers).
    pub fn new(data: &'a [f32], rows: usize, cols: usize) -> MatView<'a> {
        assert_eq!(data.len(), rows * cols,
                   "MatView: {} elements cannot be {rows}x{cols}", data.len());
        MatView { data, rows, cols }
    }

    pub fn try_new(data: &'a [f32], rows: usize, cols: usize)
        -> Option<MatView<'a>> {
        (data.len() == rows * cols).then_some(MatView { data, rows, cols })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_slice(&self) -> &'a [f32] {
        self.data
    }

    /// Row `r` as a contiguous slice.
    pub fn row(&self, r: usize) -> &'a [f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    /// The raw bytes this matrix occupies uncompressed (4·rows·cols) —
    /// the numerator of every compression-ratio account.
    pub fn raw_bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// A view of the leading `rows` rows (the eval path crops PAD
    /// rows before compressing).
    pub fn crop_rows(&self, rows: usize) -> MatView<'a> {
        assert!(rows <= self.rows, "crop {rows} > {}", self.rows);
        MatView { data: &self.data[..rows * self.cols], rows, cols: self.cols }
    }
}

impl fmt::Debug for MatView<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "MatView[{}x{}]", self.rows, self.cols)
    }
}

/// A mutable row-major `rows × cols` f32 matrix view.
pub struct MatViewMut<'a> {
    data: &'a mut [f32],
    rows: usize,
    cols: usize,
}

impl<'a> MatViewMut<'a> {
    pub fn new(data: &'a mut [f32], rows: usize, cols: usize)
        -> MatViewMut<'a> {
        assert_eq!(data.len(), rows * cols,
                   "MatViewMut: {} elements cannot be {rows}x{cols}",
                   data.len());
        MatViewMut { data, rows, cols }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn as_slice(&self) -> &[f32] {
        self.data
    }

    pub fn as_slice_mut(&mut self) -> &mut [f32] {
        self.data
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Reborrow as an immutable view.
    pub fn as_view(&self) -> MatView<'_> {
        MatView { data: self.data, rows: self.rows, cols: self.cols }
    }
}

impl fmt::Debug for MatViewMut<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "MatViewMut[{}x{}]", self.rows, self.cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn view_shape_and_access() {
        let d = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let v = MatView::new(&d, 2, 3);
        assert_eq!(v.rows(), 2);
        assert_eq!(v.cols(), 3);
        assert_eq!(v.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(v.at(0, 2), 3.0);
        assert_eq!(v.raw_bytes(), 24);
    }

    #[test]
    #[should_panic]
    fn view_shape_mismatch_panics() {
        let d = vec![0.0f32; 5];
        MatView::new(&d, 2, 3);
    }

    #[test]
    fn try_new_is_fallible() {
        let d = vec![0.0f32; 6];
        assert!(MatView::try_new(&d, 2, 3).is_some());
        assert!(MatView::try_new(&d, 3, 3).is_none());
    }

    #[test]
    fn crop_rows_narrows() {
        let d: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let v = MatView::new(&d, 4, 3);
        let c = v.crop_rows(2);
        assert_eq!(c.rows(), 2);
        assert_eq!(c.as_slice(), &d[..6]);
    }

    #[test]
    fn mut_view_roundtrip() {
        let mut d = vec![0.0f32; 6];
        let mut v = MatViewMut::new(&mut d, 2, 3);
        v.row_mut(1)[0] = 7.0;
        assert_eq!(v.as_view().at(1, 0), 7.0);
        assert_eq!(d[3], 7.0);
    }
}
