//! FourierCompress — layer-aware spectral activation compression for
//! collaborative LLM inference (reproduction; see DESIGN.md).
//!
//! Crate layout mirrors the three-layer architecture:
//!
//! * [`runtime`] — PJRT client wrapper: loads the AOT HLO artifacts the
//!   python build step produced and executes them on the request path.
//! * [`model`] — model metadata, weight loading, and the composable
//!   split executor (client layers / codec boundary / server layers).
//! * [`codec`] — the FourierCompress codec and every baseline the
//!   paper compares against (Top-k, QR, FWSVD, ASVD, SVD-LLM, INT8),
//!   plus the spectral delta stream (`codec::stream`) and the
//!   adaptive (ks, kd) rate ladder + controller (`codec::rate`).
//! * [`coordinator`] — the serving system (API v2): versioned wire
//!   protocol with a negotiated handshake (capabilities + bucket
//!   quality ladders), pluggable transports (TCP / in-proc / shaped),
//!   the transport-agnostic `ServingService` core, dynamic batcher,
//!   session manager, metrics.
//! * [`net`] — simulated bandwidth/latency channel + deterministic
//!   frame-drop plans.
//! * [`sim`] — discrete-event multi-client simulator (Fig 7).
//! * [`eval`] — MCQ accuracy harness + activation analysis (Tables
//!   II/III, Figs 2/4/5).
//! * [`testkit`] — the synthetic artifact forge: deterministic
//!   miniature models + goldens that make the whole stack run (and be
//!   tested) through [`runtime::interp`] without XLA.
//! * [`dsp`], [`linalg`], [`tensor`], [`util`], [`config`] — zero-dep
//!   substrates (FFT, QR/SVD, `.fcw` IO, JSON, RNG, config system).

// Hand-rolled DSP/linalg kernels index heavily and pass explicit
// geometry; these pedantic lints fight that idiom without making the
// butterflies clearer.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_memcpy,
    clippy::type_complexity
)]

pub mod codec;
pub mod config;
pub mod coordinator;
pub mod dsp;
pub mod eval;
pub mod linalg;
pub mod model;
pub mod net;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod testkit;
pub mod util;
