//! MCQ scoring: every (item, choice) pair becomes one padded sequence
//! `BOS + prompt + " <choice> ."`; the model scores the choice
//! continuation by length-normalised log-likelihood, exactly the
//! standard lm-eval recipe the paper uses.  Sequences are packed into
//! the artifact batch (B=8), so one artifact pipeline pass scores two
//! items (4 choices each).

use super::items::Item;
use crate::model::executor::{Boundary, SplitExecutor};
use crate::model::tokenizer;
use crate::tensor::Tensor;
use anyhow::Result;

pub struct McqScorer<'a> {
    pub exec: &'a SplitExecutor,
}

#[derive(Debug, Clone, Default)]
pub struct EvalOutcome {
    pub correct: usize,
    pub total: usize,
    pub mean_ratio: f64,
}

impl EvalOutcome {
    pub fn accuracy(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.correct as f64 / self.total as f64
        }
    }
}

struct Seq {
    tokens: Vec<i32>,
    prompt_len: usize,
    len: usize,
}

impl<'a> McqScorer<'a> {
    pub fn new(exec: &'a SplitExecutor) -> McqScorer<'a> {
        McqScorer { exec }
    }

    fn build_seq(&self, item: &Item, choice: usize) -> Seq {
        let s = self.exec.meta.eval_seq;
        let mut ids = tokenizer::encode_prompt(&item.prompt);
        let prompt_len = ids.len();
        ids.extend(tokenizer::encode(&format!(" {} .", item.choices[choice])));
        let len = ids.len().min(s);
        Seq { tokens: tokenizer::pad_to(&ids, s), prompt_len: prompt_len.min(len), len }
    }

    /// Score a whole dataset at one (split, boundary) configuration.
    pub fn evaluate(&self, items: &[Item], split: usize, boundary: &Boundary)
        -> Result<EvalOutcome> {
        let b = self.exec.meta.eval_batch;
        let s = self.exec.meta.eval_seq;
        debug_assert_eq!(b % 4, 0, "batch must hold whole items");
        let items_per_batch = b / 4;

        let mut outcome = EvalOutcome::default();
        let mut ratio_sum = 0.0;
        let mut ratio_n = 0usize;

        for chunk in items.chunks(items_per_batch) {
            // assemble the batch (pad the tail by repeating seq 0)
            let mut seqs: Vec<Seq> = Vec::with_capacity(b);
            for item in chunk {
                for c in 0..4 {
                    seqs.push(self.build_seq(item, c));
                }
            }
            while seqs.len() < b {
                seqs.push(self.build_seq(&chunk[0], 0));
            }
            let mut toks = Vec::with_capacity(b * s);
            // the codec operates on the whole padded bucket, exactly as
            // the serving path transmits it (ratio accounting is per
            // bucket raw bytes); per-item cropping is available through
            // forward_split directly as an ablation.
            let lens = vec![s; b];
            for sq in &seqs {
                toks.extend_from_slice(&sq.tokens);
            }
            let tokens = Tensor::i32(vec![b, s], toks);
            let (logits, ratio) = self.exec.forward_split(&tokens, &lens, split,
                                                          boundary)?;
            ratio_sum += ratio;
            ratio_n += 1;

            // pick argmax choice per item
            let v = self.exec.meta.vocab_size;
            let lg = logits.as_f32();
            for (ii, item) in chunk.iter().enumerate() {
                let mut best = (f64::MIN, 0usize);
                for c in 0..4 {
                    let e = ii * 4 + c;
                    let sq = &seqs[e];
                    let lp = choice_logprob(lg, e, s, v, sq);
                    if lp > best.0 {
                        best = (lp, c);
                    }
                }
                outcome.total += 1;
                if best.1 == item.answer {
                    outcome.correct += 1;
                }
            }
        }
        outcome.mean_ratio = if ratio_n > 0 { ratio_sum / ratio_n as f64 } else { 1.0 };
        Ok(outcome)
    }
}

/// Length-normalised log P(choice | prompt) from row `e` of the batch.
fn choice_logprob(logits: &[f32], e: usize, s: usize, v: usize, sq: &Seq) -> f64 {
    let mut sum = 0.0f64;
    let mut n = 0usize;
    // predict tokens prompt_len .. len-1 from positions one earlier
    for pos in (sq.prompt_len - 1)..(sq.len - 1) {
        let row = &logits[e * s * v + pos * v..e * s * v + (pos + 1) * v];
        let target = sq.tokens[pos + 1] as usize;
        sum += log_softmax_at(row, target);
        n += 1;
    }
    if n == 0 {
        f64::MIN
    } else {
        sum / n as f64
    }
}

pub(crate) fn log_softmax_at(row: &[f32], idx: usize) -> f64 {
    let m = row.iter().fold(f32::MIN, |a, &b| a.max(b)) as f64;
    let z: f64 = row.iter().map(|&x| ((x as f64) - m).exp()).sum();
    (row[idx] as f64 - m) - z.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_normalises() {
        let row = vec![1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|i| log_softmax_at(&row, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(log_softmax_at(&row, 2) > log_softmax_at(&row, 0));
    }

    #[test]
    fn log_softmax_stable_large_values() {
        let row = vec![1000.0f32, 1001.0];
        let lp = log_softmax_at(&row, 1);
        assert!(lp.is_finite() && lp < 0.0);
    }
}
