//! Experiment drivers that regenerate the paper's accuracy tables and
//! figures (E4/E5/E6/E8 in DESIGN.md §5).  Results are printed as
//! aligned tables and dumped as JSON under `results/`.

use super::items::{load_dataset, Item};
use super::scorer::McqScorer;
use crate::codec;
use crate::config::EvalConfig;
use crate::model::executor::{Boundary, SplitExecutor};
use crate::runtime::ArtifactStore;
use crate::util::json::Json;
use anyhow::Result;
use std::collections::BTreeMap;

pub struct EvalContext {
    pub store: ArtifactStore,
    pub cfg: EvalConfig,
}

impl EvalContext {
    pub fn new(cfg: EvalConfig) -> Result<EvalContext> {
        let store = ArtifactStore::open(cfg.artifacts.clone())?;
        Ok(EvalContext { store, cfg })
    }

    pub fn models(&self) -> Vec<String> {
        if self.cfg.models.is_empty() {
            self.store.model_names()
        } else {
            self.cfg.models.clone()
        }
    }

    pub fn datasets(&self) -> Vec<String> {
        if self.cfg.datasets.is_empty() {
            self.store.dataset_names()
        } else {
            self.cfg.datasets.clone()
        }
    }

    pub fn load_items(&self, ds: &str) -> Result<Vec<Item>> {
        let rel = self
            .store
            .manifest
            .path(&format!("datasets.{ds}.path"))
            .and_then(|v| v.as_str())
            .unwrap_or("")
            .to_string();
        load_dataset(self.store.root.join(rel), self.cfg.max_items)
    }

    fn save(&self, name: &str, value: &Json) -> Result<()> {
        std::fs::create_dir_all(&self.cfg.out)?;
        let path = format!("{}/{name}.json", self.cfg.out);
        std::fs::write(&path, value.to_string_pretty())?;
        crate::info!("eval", "wrote {path}");
        Ok(())
    }
}

fn jnum(v: f64) -> Json {
    Json::Num((v * 10000.0).round() / 10000.0)
}

/// Table II: FC accuracy per (model, dataset, ratio) + the derived
/// near-lossless max ratio per dataset.
pub fn table2(ctx: &EvalContext) -> Result<Json> {
    let mut out = Json::obj();
    let datasets = ctx.datasets();
    for model in ctx.models() {
        let exec = SplitExecutor::new(&ctx.store, &model)?;
        let scorer = McqScorer::new(&exec);
        let mut mj = Json::obj();
        for ds in &datasets {
            let items = ctx.load_items(ds)?;
            let base = scorer.evaluate(&items, 1, &Boundary::None)?;
            let mut dj = Json::obj();
            dj.set("baseline", jnum(base.accuracy()));
            let mut best_ratio = 1.0f64;
            let fc = codec::fourier::FourierCodec::with_hint(exec.meta.kd_band());
            for &ratio in &ctx.cfg.ratios {
                let o = scorer.evaluate(&items, 1,
                    &Boundary::Codec { codec: &fc, ratio })?;
                dj.set(&format!("r{ratio:.0}"), jnum(o.accuracy()));
                dj.set(&format!("r{ratio:.0}_achieved"), jnum(o.mean_ratio));
                // near-lossless: within 0.3 points of baseline
                if base.accuracy() - o.accuracy() <= 0.003 && o.mean_ratio > best_ratio {
                    best_ratio = o.mean_ratio;
                }
            }
            dj.set("near_lossless_ratio", jnum(best_ratio));
            crate::info!("table2", "{model}/{ds}: base={:.3} nl_ratio={:.1}",
                         base.accuracy(), best_ratio);
            mj.set(ds, dj);
        }
        out.set(&model, mj);
    }
    ctx.save("table2", &out)?;
    Ok(out)
}

/// Per-dataset near-lossless ratios from a table2 result (fallback:
/// the paper's 7.6 average).
pub fn nl_ratios(table2: &Json, model: &str, datasets: &[String])
    -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    for ds in datasets {
        let r = table2
            .path(&format!("{model}.{ds}.near_lossless_ratio"))
            .and_then(|v| v.as_f64())
            .filter(|&r| r > 1.5)
            .unwrap_or(7.6);
        out.insert(ds.clone(), r);
    }
    out
}

/// Table III: all methods at the per-dataset Table-II ratios.
pub fn table3(ctx: &EvalContext, t2: &Json) -> Result<Json> {
    let mut out = Json::obj();
    let datasets = ctx.datasets();
    for model in ctx.models() {
        let exec = SplitExecutor::new(&ctx.store, &model)?;
        let scorer = McqScorer::new(&exec);
        let ratios = nl_ratios(t2, &model, &datasets);
        let mut mj = Json::obj();

        // baseline row
        let mut base_row = Json::obj();
        let mut base_accs = BTreeMap::new();
        for ds in &datasets {
            let items = ctx.load_items(ds)?;
            let o = scorer.evaluate(&items, 1, &Boundary::None)?;
            base_row.set(ds, jnum(o.accuracy()));
            base_accs.insert(ds.clone(), o.accuracy());
        }
        mj.set("baseline", base_row);

        for method in &ctx.cfg.methods {
            let fc_hint = exec.meta.kd_band();
            let c: Box<dyn codec::Codec> = if method == "fc" {
                Box::new(codec::fourier::FourierCodec::with_hint(fc_hint))
            } else {
                codec::by_name(method)?
            };
            let mut row = Json::obj();
            let mut avg = 0.0;
            for ds in &datasets {
                let items = ctx.load_items(ds)?;
                let o = scorer.evaluate(&items, 1,
                    &Boundary::Codec { codec: c.as_ref(), ratio: ratios[ds] })?;
                row.set(ds, jnum(o.accuracy()));
                avg += o.accuracy();
            }
            avg /= datasets.len().max(1) as f64;
            row.set("avg", jnum(avg));
            crate::info!("table3", "{model}/{method}: avg={avg:.3}");
            mj.set(method, row);
        }
        out.set(&model, mj);
    }
    ctx.save("table3", &out)?;
    Ok(out)
}

/// Fig 4: split-layer sweep, all methods, subset of datasets.  Uses
/// the model's near-lossless operating ratio so that layer 1 is the
/// favourable case and depth does the damage (the paper's setting:
/// "their respective optimal compression ratios").
pub fn fig4(ctx: &EvalContext, model: &str, datasets: &[&str]) -> Result<Json> {
    let exec = SplitExecutor::new(&ctx.store, model)?;
    let ratio = exec.meta.d_model as f64 / exec.meta.kd_band() as f64 * 0.99;
    let scorer = McqScorer::new(&exec);
    let splits: Vec<usize> = if ctx.cfg.split_layers.len() > 1 {
        ctx.cfg.split_layers.clone()
    } else {
        (1..=exec.meta.n_layers).collect()
    };
    let mut out = Json::obj();
    for ds in datasets {
        let items = ctx.load_items(ds)?;
        let mut dj = Json::obj();
        let base = scorer.evaluate(&items, 1, &Boundary::None)?;
        dj.set("baseline", jnum(base.accuracy()));
        for method in &ctx.cfg.methods {
            let c: Box<dyn codec::Codec> = if method == "fc" {
                Box::new(codec::fourier::FourierCodec::with_hint(exec.meta.kd_band()))
            } else {
                codec::by_name(method)?
            };
            let mut arr = Vec::new();
            for &k in &splits {
                let o = scorer.evaluate(&items, k,
                    &Boundary::Codec { codec: c.as_ref(), ratio })?;
                arr.push(jnum(o.accuracy()));
            }
            dj.set(method, Json::Arr(arr));
        }
        dj.set("ratio", jnum(ratio));
        dj.set("splits",
               Json::Arr(splits.iter().map(|&k| Json::Num(k as f64)).collect()));
        crate::info!("fig4", "{model}/{ds} done");
        out.set(ds, dj);
    }
    ctx.save("fig4", &out)?;
    Ok(out)
}

/// Fig 5: fine ratio sweep for fc / svdllm / topk.
pub fn fig5(ctx: &EvalContext, model: &str, datasets: &[&str]) -> Result<Json> {
    let exec = SplitExecutor::new(&ctx.store, model)?;
    let scorer = McqScorer::new(&exec);
    let ratios = [2.0, 4.0, 6.0, 8.0, 10.0, 12.0, 16.0];
    let mut out = Json::obj();
    out.set("ratios",
            Json::Arr(ratios.iter().map(|&r| Json::Num(r)).collect()));
    for ds in datasets {
        let items = ctx.load_items(ds)?;
        let mut dj = Json::obj();
        let base = scorer.evaluate(&items, 1, &Boundary::None)?;
        dj.set("baseline", jnum(base.accuracy()));
        for method in ["fc", "svdllm", "topk"] {
            let c: Box<dyn codec::Codec> = if method == "fc" {
                Box::new(codec::fourier::FourierCodec::with_hint(exec.meta.kd_band()))
            } else {
                codec::by_name(method)?
            };
            let mut arr = Vec::new();
            for &ratio in &ratios {
                let o = scorer.evaluate(&items, 1,
                    &Boundary::Codec { codec: c.as_ref(), ratio })?;
                arr.push(jnum(o.accuracy()));
            }
            dj.set(method, Json::Arr(arr));
        }
        crate::info!("fig5", "{model}/{ds} done");
        out.set(ds, dj);
    }
    ctx.save("fig5", &out)?;
    Ok(out)
}

/// Render a {model: {method: {ds: acc}}} JSON as an aligned text table.
pub fn render_table(j: &Json, datasets: &[String]) -> String {
    let mut s = String::new();
    if let Some(models) = j.as_obj() {
        for (model, mj) in models {
            s.push_str(&format!("\n== {model} ==\n{:10}", "method"));
            for ds in datasets {
                s.push_str(&format!(" {ds:>6}"));
            }
            s.push('\n');
            if let Some(rows) = mj.as_obj() {
                for (method, row) in rows {
                    s.push_str(&format!("{method:10}"));
                    for ds in datasets {
                        let v = row.get(ds).and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
                        s.push_str(&format!(" {:6.1}", v * 100.0));
                    }
                    if let Some(avg) = row.get("avg").and_then(|v| v.as_f64()) {
                        s.push_str(&format!("  avg {:5.1}", avg * 100.0));
                    }
                    s.push('\n');
                }
            }
        }
    }
    s
}
