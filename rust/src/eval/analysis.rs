//! Activation analysis — the data behind Fig 2:
//!  (a) per-layer reconstruction error of FC / Top-k / SVD at a fixed
//!      ratio (plus activation dumps for the heatmaps),
//!  (b) cross-token activation similarity vs layer across datasets,
//!  (c) 2-D spectrum energy concentration vs block size.

use super::items::Item;
use super::tables::EvalContext;
use crate::codec::{self, rel_error, Codec};
use crate::dsp::fft2d::fft2_real;
use crate::model::executor::SplitExecutor;
use crate::model::tokenizer;
use crate::tensor::{MatView, Tensor};
use crate::util::json::Json;
use anyhow::Result;

fn batch_tokens(exec: &SplitExecutor, items: &[Item]) -> (Tensor, Vec<usize>) {
    let (b, s) = (exec.meta.eval_batch, exec.meta.eval_seq);
    let mut toks = Vec::with_capacity(b * s);
    let mut lens = Vec::with_capacity(b);
    for e in 0..b {
        let it = &items[e % items.len()];
        let ids = tokenizer::encode_prompt(
            &format!("{} {} .", it.prompt, it.choices[it.answer]));
        lens.push(ids.len().min(s));
        toks.extend(tokenizer::pad_to(&ids, s));
    }
    (Tensor::i32(vec![b, s], toks), lens)
}

/// Mean pairwise cosine similarity between token activation vectors —
/// the Fig 2(b) metric ("activation similarity").
pub fn token_similarity(act: MatView<'_>) -> f64 {
    let rows = act.rows();
    let mut norms = vec![0.0f64; rows];
    for (r, norm) in norms.iter_mut().enumerate() {
        *norm = act.row(r)
            .iter()
            .map(|&v| (v as f64) * (v as f64))
            .sum::<f64>()
            .sqrt()
            .max(1e-12);
    }
    let mut sum = 0.0;
    let mut n = 0usize;
    for i in 0..rows {
        for j in (i + 1)..rows {
            let dot: f64 = act.row(i)
                .iter()
                .zip(act.row(j))
                .map(|(&a, &b)| a as f64 * b as f64)
                .sum();
            sum += dot / (norms[i] * norms[j]);
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

/// Energy fraction captured by the centred (ks, kd) block — Fig 2(c).
pub fn block_energy_fraction(act: MatView<'_>, ks: usize, kd: usize) -> f64 {
    let (rows, cols) = (act.rows(), act.cols());
    let spec = fft2_real(act);
    let total: f64 = spec.iter().map(|c| c.norm_sq()).sum();
    let ui = codec::centered_indices(rows, ks);
    let vi = codec::centered_indices(cols, kd);
    let mut e = 0.0;
    for &u in &ui {
        for &v in &vi {
            e += spec[u * cols + v].norm_sq();
        }
    }
    e / total.max(1e-30)
}

/// Full Fig-2 analysis dump for one model.
pub fn analyze(ctx: &EvalContext, model: &str, ratio: f64) -> Result<Json> {
    let exec = SplitExecutor::new(&ctx.store, model)?;
    let mut out = Json::obj();
    out.set("model", Json::Str(model.into()));
    out.set("ratio", Json::Num(ratio));

    // (b) similarity vs layer, across 4 datasets (paper's selection)
    let mut sim = Json::obj();
    for ds in ["pa", "ae", "cq", "oa"] {
        let items = ctx.load_items(ds)?;
        let (tokens, lens) = batch_tokens(&exec, &items);
        let acts = exec.activations(&tokens)?;
        let d = exec.meta.d_model;
        let mut arr = Vec::new();
        for act in &acts {
            // mean over batch elements, cropped to true length
            let s = act.shape[1];
            let mut v = 0.0;
            for e in 0..act.shape[0] {
                let len = lens[e];
                v += token_similarity(MatView::new(
                    &act.as_f32()[e * s * d..e * s * d + len * d], len, d));
            }
            arr.push(Json::Num(v / act.shape[0] as f64));
        }
        sim.set(ds, Json::Arr(arr));
    }
    out.set("similarity_by_layer", sim);

    // (a) per-layer reconstruction error per method at the same ratio
    let items = ctx.load_items("oa")?;
    let (tokens, lens) = batch_tokens(&exec, &items);
    let acts = exec.activations(&tokens)?;
    let d = exec.meta.d_model;
    let mut errs = Json::obj();
    let fc = codec::fourier::FourierCodec::with_hint(exec.meta.kd_band());
    let methods: Vec<(&str, Box<dyn Codec>)> = vec![
        ("fc", Box::new(fc)),
        ("topk", codec::by_name("topk")?),
        ("svdllm", codec::by_name("svdllm")?),
    ];
    for (name, c) in &methods {
        let mut arr = Vec::new();
        for act in &acts {
            let s = act.shape[1];
            let mut v = 0.0;
            for e in 0..act.shape[0] {
                let len = lens[e];
                let crop = &act.as_f32()[e * s * d..e * s * d + len * d];
                let rec = c.roundtrip(crop, len, d, ratio)?;
                v += rel_error(crop, &rec);
            }
            arr.push(Json::Num(v / act.shape[0] as f64));
        }
        errs.set(name, Json::Arr(arr));
    }
    out.set("recon_error_by_layer", errs);

    // (c) spectrum energy concentration vs block size, layer 1 vs deep
    let mut spec = Json::obj();
    for (label, idx) in [("layer1", 0usize), ("mid", exec.meta.n_layers / 2),
                         ("last", exec.meta.n_layers - 1)] {
        // [B, S, D] viewed as token rows; the first `len` rows are
        // element 0's true-length crop
        let len = lens[0];
        let crop = acts[idx].mat_view().crop_rows(len);
        let mut arr = Vec::new();
        for frac in [0.02, 0.05, 0.1, 0.2, 0.4, 0.8] {
            let budget = ((len * d) as f64 * frac).max(1.0);
            let kd = exec.meta.kd_band().min(d);
            let ks_raw = (budget / kd as f64) as usize;
            let ks = ks_raw.clamp(1, len);
            let ks = if ks == len { ks } else if ks % 2 == 0 { ks.max(2) - 1 } else { ks };
            arr.push(Json::Num(block_energy_fraction(crop, ks, kd)));
        }
        spec.set(label, Json::Arr(arr));
    }
    out.set("energy_fraction", spec);

    // heatmap dump (first item, layer 1 + last): original vs fc recon
    let len = lens[0];
    let crop = acts[0].mat_view().crop_rows(len).as_slice();
    let fc2 = codec::fourier::FourierCodec::with_hint(exec.meta.kd_band());
    let rec = fc2.roundtrip(crop, len, d, ratio)?;
    out.set("heatmap_rows", Json::Num(len as f64));
    out.set("heatmap_cols", Json::Num(d as f64));
    out.set("heatmap_orig",
            Json::Arr(crop.iter().step_by(4).map(|&v| Json::Num(v as f64)).collect()));
    out.set("heatmap_fc_err",
            Json::Arr(crop.iter().zip(&rec).step_by(4)
                .map(|(&a, &b)| Json::Num((a - b).abs() as f64)).collect()));
    Ok(out)
}
