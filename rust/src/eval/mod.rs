//! Evaluation harness: MCQ accuracy under activation compression —
//! regenerates Tables II/III and Figs 4/5 — plus the activation
//! analysis behind Fig 2.

pub mod analysis;
pub mod items;
pub mod scorer;
pub mod tables;

pub use items::{load_dataset, Item};
pub use scorer::McqScorer;
