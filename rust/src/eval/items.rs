//! Dataset items: JSONL loader for the synthetic MCQ benchmarks the
//! python build step generated (schema: prompt / choices[4] / answer).

use crate::util::json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

#[derive(Debug, Clone, PartialEq)]
pub struct Item {
    pub prompt: String,
    pub choices: Vec<String>,
    pub answer: usize,
}

pub fn load_dataset(path: impl AsRef<Path>, max_items: usize) -> Result<Vec<Item>> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = json::parse(line)
            .with_context(|| format!("line {}", lineno + 1))?;
        let prompt = j
            .get("prompt")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("line {}: no prompt", lineno + 1))?
            .to_string();
        let choices: Vec<String> = j
            .get("choices")
            .and_then(|v| v.as_arr())
            .map(|a| {
                a.iter()
                    .filter_map(|c| c.as_str().map(String::from))
                    .collect()
            })
            .unwrap_or_default();
        let answer = j.usize_or("answer", usize::MAX);
        if choices.len() != 4 || answer >= 4 {
            bail!("line {}: malformed item", lineno + 1);
        }
        out.push(Item { prompt, choices, answer });
        if out.len() >= max_items {
            break;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_and_truncates() {
        let path = std::env::temp_dir().join("fc_items_test.jsonl");
        let line = r#"{"prompt": "Q x hue ? A", "choices": ["a","b","c","d"], "answer": 1}"#;
        std::fs::write(&path, format!("{line}\n{line}\n{line}\n")).unwrap();
        let items = load_dataset(&path, 2).unwrap();
        assert_eq!(items.len(), 2);
        assert_eq!(items[0].answer, 1);
        assert_eq!(items[0].choices[3], "d");
    }

    #[test]
    fn rejects_malformed() {
        let path = std::env::temp_dir().join("fc_items_bad.jsonl");
        std::fs::write(&path, r#"{"prompt": "p", "choices": ["a"], "answer": 0}"#)
            .unwrap();
        assert!(load_dataset(&path, 10).is_err());
    }
}
